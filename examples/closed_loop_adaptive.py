"""Closed-loop consolidation without a profiling pass.

    PYTHONPATH=src python examples/closed_loop_adaptive.py

The paper's scheduler needs a 52 900-pair offline profiling run before it can
place anything. This example starts from *zero interference knowledge* (the
optimistic uniform prior), lets the ``AdaptiveEngine`` place arrival segments
from its current estimate, and watches the streaming estimator recover the
D-matrix from completion telemetry alone -- then congests a server mid-run
(``telemetry.drift.congest_server``) and watches the loop notice and recover.

The simulator remains the ground truth throughout: the engine's placements
are scored against *estimated* dynamics, the outcomes it observes come from
the *true* (possibly drifted) server specs.
"""
import numpy as np

from repro.core import (
    M1,
    M2,
    AdaptiveEngine,
    ConsolidationEngine,
    Workload,
    profile_pairwise_fast,
    snap_to_grid,
)
from repro.core.workload import FS_GRID, RS_GRID
from repro.telemetry import congestion_at

SEGMENTS, DRIFT_AT, SEG_GAP = 10, 5, 10.0


def stationary_segment(seed=3, n=32, gap=2e-5, passes=8):
    rng = np.random.default_rng(seed)
    out, t = [], 0.0
    for _ in range(n):
        fs = float(rng.choice(FS_GRID[10:15]))
        w = snap_to_grid(
            Workload(fs=fs, rs=float(rng.choice(RS_GRID[5:8])), data_total=fs * passes))
        t += float(rng.exponential(gap))
        out.append((t, w))
    return out


def main():
    servers = [M1, M2]
    seg = stationary_segment()
    arrivals = [(t + k * SEG_GAP, w) for k in range(SEGMENTS) for t, w in seg]
    drift = congestion_at(servers, DRIFT_AT, server=0, factor=0.4)

    # decay is per observation-unit; each server's estimator sees ~16 of a
    # segment's 32 completions, so 0.9935^16 ~ 0.9 of old evidence kept per
    # segment -- fast enough forgetting to re-converge after the drift
    adaptive = AdaptiveEngine(servers, prior=0.0, drift=drift, decay=0.9935)

    # the oracle re-profiles instantly at every drift (what telemetry replaces)
    mk_oracle = {}

    def oracle_duration(k):
        specs = drift.specs_at(servers, k)
        if specs not in mk_oracle:
            oracle = ConsolidationEngine(
                list(specs), D=[profile_pairwise_fast(s) for s in specs])
            mk_oracle[specs] = oracle.run(seg, backend="jax").makespan - seg[0][0]
        return mk_oracle[specs]

    print(f"{SEGMENTS} segments x {len(seg)} arrivals on [M1, M2]; "
          f"server 0's shared bandwidth congests to 40% at segment {DRIFT_AT}\n")
    print("seg  phase        adaptive   oracle    regret   observations")

    def report(k, res, eng):
        dur = res.makespan - (seg[0][0] + k * SEG_GAP)
        mk = oracle_duration(k)
        phase = ("drift!" if k == DRIFT_AT
                 else "post-drift" if k > DRIFT_AT else "stationary")
        n_obs = sum(e.n_obs for e in eng.estimators)
        print(f"{k:3d}  {phase:<11s}  {dur:8.4f}  {mk:7.4f}  "
              f"{(dur / mk - 1) * 100:+6.1f}%   {n_obs}")

    adaptive.run(arrivals, segments=SEGMENTS, on_segment=report)

    est = adaptive.estimators[0]
    truth = profile_pairwise_fast(drift.specs_at(servers, SEGMENTS - 1)[0])
    mask = est.observed_mask()
    err = np.abs(est.estimate_D() - truth)[mask]
    print(f"\nserver-0 estimator: {est.n_obs} observations, "
          f"{mask.sum()} confident pairs, |D_hat - D_true| mean "
          f"{err.mean():.4f} / max {err.max():.4f} (post-drift truth)")


if __name__ == "__main__":
    main()
