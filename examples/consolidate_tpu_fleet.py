"""The paper's algorithm at TPU-fleet scale (DESIGN.md §2): pack (arch x
shape) serving/training jobs onto pod slices using resource vectors from the
multi-pod dry-run artifacts.

    PYTHONPATH=src python examples/consolidate_tpu_fleet.py

Falls back to representative synthetic profiles when artifacts/dryrun is
absent (run `python -m repro.launch.dryrun --all` to use measured vectors).
"""
import json
import pathlib

from repro.core import FleetState, JobProfile, PodSpec, fleet_throughput_report, pack_jobs

ART = pathlib.Path(__file__).resolve().parents[1] / "artifacts" / "dryrun"

jobs = []
if ART.exists():
    for f in sorted(ART.glob("*__decode_32k__single.json")) + sorted(
            ART.glob("*__prefill_32k__single.json")):
        rec = json.loads(f.read_text())
        if "skip" in rec:
            continue
        jobs.append(JobProfile(
            name=rec["cell"], flops=rec["flops"] * rec["chips"],
            bytes_accessed=rec["bytes_accessed"] * rec["chips"],
            collective_bytes=rec["collective_bytes"] * rec["chips"],
            hbm_bytes=rec["peak_memory_per_device"], chips=rec["chips"],
        ))
if not jobs:
    print("(no dry-run artifacts; using synthetic job profiles)")
    jobs = [JobProfile(name=f"svc{i}", flops=3e15 * (1 + i % 3),
                       bytes_accessed=4e14, collective_bytes=2e13,
                       hbm_bytes=(2 + i % 4) * 2**30, chips=256)
            for i in range(10)]

fleet = FleetState.empty([PodSpec(name=f"pod{i}") for i in range(4)], model="additive")
placements, fleet = pack_jobs(fleet, jobs)

print(f"{len(jobs)} jobs -> 4 pods")
for job, p in zip(jobs, placements):
    print(f"  {job.name[:48]:48s} -> {'pod %d' % p if p is not None else 'QUEUED (criteria)'}")
print("\nper-pod report:")
for row in fleet_throughput_report(fleet):
    print(f"  {row['pod']}: {row['job'][:40]:40s} degradation={row['degradation']:5.1%} "
          f"eff={row['eff_steps_per_s']:.2f} steps/s (solo {row['solo_steps_per_s']:.2f})")
