"""Quickstart: the paper's consolidation pipeline end to end in ~40 lines.

    PYTHONPATH=src python examples/quickstart.py

1. profile pairwise degradations D_{i,j} on the Table-I servers (the 52_900-
   run experiment of §VIII, vectorized);
2. pack an arriving workload sequence with the greedy of Fig 8;
3. verify the two §V criteria hold and compare against brute-force optimal.
"""
from repro.core import (
    PAPER_CLUSTER,
    ClusterState,
    average_min_throughput_simulated,
    brute_force,
    greedy_sequence,
    parse_workloads,
    profile_pairwise_fast,
    snap_to_grid,
)

# 1. profile the testbed (simulator stands in for TestDFSIO runs)
servers = list(PAPER_CLUSTER)
D = [profile_pairwise_fast(s) for s in servers]
print(f"profiled D matrices: {len(D)} servers x {D[0].shape} types")

# 2. initial state + arrivals (paper Table III, sequence 1)
state = ClusterState.empty(servers, D, alpha=1.3)
initial = [
    "(32KB, 64KB), (4KB, 16KB), (16KB, 32MB)",
    "(32KB, 64MB), (512KB, 2MB), (128KB, 512KB)",
    "(256KB, 1MB), (4KB, 2MB), (32KB, 8MB)",
    "(2KB, 32KB), (512KB, 64MB), (8KB, 4MB)",
]
for i, txt in enumerate(initial):
    state.assignments[i] = [snap_to_grid(w) for w in parse_workloads(txt)]

arrivals = [snap_to_grid(w) for w in parse_workloads(
    "(16KB, 64KB), (32KB, 1MB), (64KB, 64MB), (32KB, 2MB), (8KB, 64MB)")]
placements, queued = greedy_sequence(state, arrivals)
print(f"greedy placements: {placements}  queued: {len(queued)}")

# 3. criteria + optimality
for i, server in enumerate(servers):
    c = state.check(i)
    print(f"  {server.name}: cache_in_use={c.cache_in_use:5.1%} "
          f"max_degradation={c.max_degradation:5.1%} ok={c.ok}")
print(f"avg min throughput (simulated): {average_min_throughput_simulated(state):.3f}")

opt_cost, opt_assign = brute_force(
    ClusterState.empty(servers, D, alpha=1.3), arrivals, allow_queue=True)
greedy_cost = state.total_avg_load() + len(queued)
print(f"greedy total load {greedy_cost:.3f} vs fresh-cluster optimal {opt_cost:.3f}")
