"""End-to-end training example: a reduced llama-family model through the full
substrate stack (chunk-store pipeline -> pjit train step -> checkpoints),
with a mid-run simulated failure + restart to demonstrate fault tolerance.

    PYTHONPATH=src python examples/train_end_to_end.py
"""
import tempfile

from repro.launch.train import main as train

with tempfile.TemporaryDirectory() as ckpt:
    print("=== phase 1: train 40 steps, checkpoint every 20 ===")
    train(["--arch", "tinyllama-1.1b", "--smoke", "--steps", "40",
           "--batch", "4", "--seq", "64", "--lr", "3e-3",
           "--ckpt", ckpt, "--ckpt-every", "20"])

    print("\n=== phase 2: 'crash' after step 40; restart resumes and runs to 60 ===")
    losses = train(["--arch", "tinyllama-1.1b", "--smoke", "--steps", "60",
                    "--batch", "4", "--seq", "64", "--lr", "3e-3",
                    "--ckpt", ckpt, "--ckpt-every", "20"])
    print(f"\nresumed run executed {len(losses)} steps (expected 20)")
