"""Serving example: batched prefill+decode for a small model, gated by the
paper's consolidation admission (criteria of §V on the pod fleet).

    PYTHONPATH=src python examples/serve_with_admission.py
"""
from repro.launch.serve import main as serve

serve(["--arch", "tinyllama-1.1b", "--smoke",
       "--requests", "4", "--prompt-len", "32", "--gen", "16"])
