"""Serving example: batched prefill+decode for a small model, gated by the
paper's consolidation admission (criteria of §V on the pod fleet).

Admission runs with the ``repro.obs`` metrics plane on, so the driver prints
a p50/p99 waiting-time and slowdown table next to the placements -- the
paper's utilization-floor criterion reported as a live serving SLO.

    PYTHONPATH=src python examples/serve_with_admission.py
"""
from repro.launch.serve import main as serve

serve(["--arch", "tinyllama-1.1b", "--smoke",
       "--requests", "4", "--prompt-len", "32", "--gen", "16"])
