"""Closed-loop throughput: fused device scan vs host-alternating oracle.

The question this benchmark answers is the PR-7 tentpole's: how much of the
adaptive cycle's wall clock was host orchestration?  Both paths run the SAME
``AdaptiveEngine`` (same estimators, same fleet controller, same decisions --
``tests/test_closed_loop.py`` proves placement/eviction equivalence); the
only difference is ``run(device_loop=True)`` compiling the whole
observe -> estimate -> detect -> act cycle into one ``lax.scan`` program
versus the reference path re-entering Python between every segment.

Tiers sweep the fleet size (4 / 16 / 64 servers).  The closed-loop regime is
per-job adaptation -- one arrival per segment, every placement immediately
feeds back into the next decision -- which is where loop overhead dominates
and consolidation control is tightest; a batched row (4 jobs/segment) at the
16-server tier shows how the advantage shrinks as segment compute grows.
``decay=1.0`` (the engine default) keeps the fused path on its sparse bank
tables; ``ring_capacity=256`` bounds telemetry-ring writes identically for
both paths.

Protocol: warm both paths once (compilation excluded), then time repeated
full runs and report min-of-reps per segment.  The acceptance bar is the
fused loop at >= 5x the host path's segments/sec at the 16-server tier.

``--smoke`` shrinks to a 3-server fleet with few segments, checks the two
paths place identically right here (belt to the test suite's braces), and
pushes one single-server device loop through the Pallas scatter in
interpret mode so the kernel branch of the fused estimator runs in CI.
``--profile`` additionally dumps a ``jax.profiler`` trace of one warm
device-loop dispatch under ``profile_closed_loop/`` for op-level timing.
"""
from __future__ import annotations

import time

import numpy as np

from repro.configs.base import MeshConfig
from repro.core import M1, AdaptiveEngine, Workload, snap_to_grid
from repro.core.workload import FS_GRID, RS_GRID
from repro.fleet import FleetController

#: (servers, jobs per segment, segments) per tier; the 16-server row is the
#: acceptance gate, the batched row is reported for honesty about granularity
TIERS = [(4, 1, 64), (16, 1, 64), (64, 1, 32)]
BATCHED = [(16, 4, 16)]
GATE_M, GATE_X = 16, 5.0

REPS = 5


def _arrivals(seed: int, n_seg: int, segments: int, gap: float = 2e-5):
    """``segments`` replays of one ``n_seg``-job chunk, 10 s apart."""
    rng = np.random.default_rng(seed)
    seg, t = [], 0.0
    for _ in range(n_seg):
        fs = float(rng.choice(FS_GRID[10:14]))
        w = snap_to_grid(Workload(fs=fs, rs=float(rng.choice(RS_GRID[5:8])),
                                  data_total=fs * 6))
        t += float(rng.exponential(gap))
        seg.append((t, w))
    return [(t + k * 10.0, w) for k in range(segments) for t, w in seg]


def _engine(m: int) -> AdaptiveEngine:
    return AdaptiveEngine([M1] * m, prior=0.0, decay=1.0,
                          fleet=FleetController(mesh=MeshConfig()),
                          ring_capacity=256)


def _time_path(m, n_seg, segments, device_loop, reps=REPS, profile_dir=None):
    arr = _arrivals(0, n_seg, segments)
    eng = _engine(m)
    eng.run(arr, segments=segments, device_loop=device_loop)  # compile/warm
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        res = eng.run(arr, segments=segments, device_loop=device_loop)
        ts.append(time.perf_counter() - t0)
    if profile_dir is not None:
        import jax

        with jax.profiler.trace(profile_dir):
            eng.run(arr, segments=segments, device_loop=device_loop)
    placements = tuple(p for seg in res.segments for p in seg.placements)
    return min(ts) / segments, placements


def _tier(emit, m, n_seg, segments, tag, profile=False):
    prof = "profile_closed_loop" if profile else None
    host_s, host_pl = _time_path(m, n_seg, segments, device_loop=False)
    dev_s, dev_pl = _time_path(m, n_seg, segments, device_loop=True,
                               profile_dir=prof)
    if host_pl != dev_pl:
        raise AssertionError(
            f"device loop diverged from host oracle at m={m}: "
            f"{dev_pl} != {host_pl}")
    ratio = host_s / dev_s
    emit(f"closed_loop/host_{tag}", host_s * 1e6,
         f"m={m};jobs_per_seg={n_seg};segments={segments};"
         f"segs_per_s={1.0 / host_s:.1f}", unit="us_per_segment")
    emit(f"closed_loop/device_{tag}", dev_s * 1e6,
         f"m={m};jobs_per_seg={n_seg};segments={segments};"
         f"segs_per_s={1.0 / dev_s:.1f}", unit="us_per_segment")
    emit(f"closed_loop/speedup_{tag}", ratio,
         f"m={m};jobs_per_seg={n_seg};device_segs_per_s={1.0 / dev_s:.1f};"
         + (f"gate=>= {GATE_X}x" if (m == GATE_M and n_seg == 1) else "info"),
         unit="x_host_over_device")
    return ratio


def _smoke_pallas_loop(segments=6):
    """One single-server device loop through the Pallas pair scatter
    (interpret mode off-TPU): the ``use_pallas and m == 1`` branch of the
    fused bank update, compiled inside the scan."""
    eng = AdaptiveEngine([M1], prior=0.0, stream=True, scatter="pallas",
                         ring_capacity=64)
    res = eng.run(_arrivals(3, 2, segments), segments=segments,
                  device_loop=True)
    return float(sum(res.n_obs))


def run(emit, smoke: bool = False, profile: bool = False):
    if smoke:
        m, n_seg, segments = 3, 2, 6
        ratio = _tier(emit, m, n_seg, segments, f"m{m}", profile=profile)
        emit("closed_loop/smoke_match", 1.0,
             f"m={m};segments={segments};host/device placements identical",
             unit="bool")
        rows = _smoke_pallas_loop()
        emit("closed_loop/smoke_pallas_loop", rows,
             "m=1 scatter=pallas interpret inside the compiled scan",
             unit="rows")
        return
    gate = None
    for m, n_seg, segments in TIERS:
        ratio = _tier(emit, m, n_seg, segments, f"m{m}",
                      profile=profile and m == GATE_M)
        if m == GATE_M:
            gate = ratio
    for m, n_seg, segments in BATCHED:
        _tier(emit, m, n_seg, segments, f"m{m}_batched{n_seg}")
    emit("closed_loop/gate_16server", float(gate is not None and gate >= GATE_X),
         f"speedup_m16={gate:.2f};bar={GATE_X}x", unit="bool")
