"""Closed-loop benchmark: makespan regret of the adaptive engine vs the
true-D oracle as observations accumulate (DESIGN.md §9).

Protocol: one stationary 32-arrival segment is replayed K times. The
``AdaptiveEngine`` starts from the optimistic uniform prior (D = 0: no
profiling at all), places each segment from its current estimate, and folds
the segment's completion observations into its per-server estimators. The
oracle is a ``ConsolidationEngine`` holding the *true* profiled D for the
specs in effect, run under the identical segmented protocol. Because the
segment is replayed verbatim, the oracle's segment duration is a constant
and every change in the adaptive engine's duration is attributable to its
estimates; regret_k = duration_adaptive(k) / duration_oracle - 1.

Halfway through, a drift event congests server 0's shared storage subsystem
to 40% of nominal (``telemetry.drift.congest_server`` -- the co-tenant-noise
/ failing-controller scenario, which moves the *pairwise D-matrix itself*,
not just base rates): the oracle re-profiles instantly, the adaptive engine
must notice from telemetry alone -- regret spikes around the drift segment
and recovers as fresh observations overwrite the stale estimate
(confidence decay sheds the pre-drift evidence). Rows are averaged over
independent trace seeds to separate the learning trend from placement-tie
noise.

Regret can go slightly negative: the "oracle" is the paper's greedy with the
true D, not the optimal placement, and an imperfect estimate occasionally
packs better.
"""
from __future__ import annotations

import numpy as np

from repro.core import (
    M1,
    M2,
    AdaptiveEngine,
    ConsolidationEngine,
    Workload,
    profile_pairwise_fast,
    snap_to_grid,
)
from repro.core.workload import FS_GRID, RS_GRID
from repro.telemetry import congestion_at

#: replay gap between segments on the trace clock (any value >> a segment)
SEG_GAP = 10.0


def _segment(seed: int, n: int, gap: float = 2e-5, passes: int = 8):
    """One stationary arrival segment: heavy LLC-resident co-run pressure."""
    rng = np.random.default_rng(seed)
    out, t = [], 0.0
    for _ in range(n):
        fs = float(rng.choice(FS_GRID[10:15]))
        w = snap_to_grid(
            Workload(fs=fs, rs=float(rng.choice(RS_GRID[5:8])), data_total=fs * passes))
        t += float(rng.exponential(gap))
        out.append((t, w))
    return out


def run(emit, smoke: bool = False):
    servers = [M1, M2]
    if smoke:
        seeds, n_seg, segments, drift_at = (3,), 16, 6, 3
    else:
        seeds, n_seg, segments, drift_at = (3, 7, 11), 32, 12, 6
    drift = congestion_at(servers, drift_at, server=0, factor=0.4)

    regret = np.zeros(segments)
    d_err = np.zeros(segments)
    obs_cum = np.zeros(segments)
    oracle_D = {}  # spec -> true profiled D, shared across seeds and phases

    for seed in seeds:
        seg = _segment(seed, n_seg)
        arrivals = [(t + k * SEG_GAP, w) for k in range(segments) for t, w in seg]

        snaps = []

        def snapshot(k, res, eng):
            # post-update estimation error on server 0 (the one that drifts):
            # RMSE of the estimated vs true D over confidently-observed pairs
            true_spec = drift.specs_at(servers, k)[0]
            if true_spec not in oracle_D:
                oracle_D[true_spec] = profile_pairwise_fast(true_spec)
            est = eng.estimators[0]
            mask = est.observed_mask()
            err = (est.estimate_D() - oracle_D[true_spec])[mask]
            snaps.append(float(np.sqrt(np.mean(err**2))) if mask.any() else float("nan"))

        # decay < 1: confidence on pre-drift evidence fades, so pairs the
        # drifted world re-observes re-converge and unobservable ones fall
        # back toward the prior instead of pinning stale estimates. Decay is
        # per observation-unit (chunk-invariant): 0.997^32 ~ 0.9 per segment.
        adaptive = AdaptiveEngine(servers, prior=0.0, drift=drift, decay=0.997)
        res = adaptive.run(arrivals, segments=segments, on_segment=snapshot)

        mk_oracle = {}  # per-seed (seg differs); D matrices reuse oracle_D
        for k in range(segments):
            specs_k = drift.specs_at(servers, k)
            if specs_k not in mk_oracle:
                for s in specs_k:
                    if s not in oracle_D:
                        oracle_D[s] = profile_pairwise_fast(s)
                oracle = ConsolidationEngine(
                    list(specs_k), D=[oracle_D[s] for s in specs_k])
                mk_oracle[specs_k] = oracle.run(seg, backend="jax").makespan - seg[0][0]
            regret[k] += (res.durations[k] - mk_oracle[specs_k]) / mk_oracle[specs_k]
            d_err[k] += snaps[k]
            obs_cum[k] += sum(res.n_obs[: k + 1])

    regret /= len(seeds)
    d_err /= len(seeds)
    obs_cum /= len(seeds)

    for k in range(segments):
        phase = "stationary" if k < drift_at else ("drift" if k == drift_at else "post-drift")
        emit(f"adaptive/regret_seg{k:02d}", 100.0 * regret[k],
             f"phase={phase};obs={obs_cum[k]:.0f};d_rmse={d_err[k]:.4f}",
             unit="makespan_regret_pct")

    early = float(np.mean(regret[:2]))
    conv = float(np.mean(regret[drift_at - 2:drift_at]))
    # estimates refresh at segment boundaries, so the spike lands within a
    # segment or two of the event; "late" is where the loop settled
    spike = float(np.max(regret[drift_at:drift_at + 2]))
    late = float(regret[-1])
    emit("adaptive/convergence", 100.0 * (early - conv),
         f"early={early * 100:.1f}%;pre_drift={conv * 100:.1f}%;"
         f"shrinks={conv < early};seeds={len(seeds)}",
         unit="regret_drop_pct")
    emit("adaptive/drift_recovery", 100.0 * (spike - late),
         f"spike={spike * 100:.1f}%;late={late * 100:.1f}%;"
         f"recovers={late < spike};seeds={len(seeds)}",
         unit="regret_drop_pct")
