"""Benchmark harness: one module per paper table/figure (DESIGN.md §7).

    PYTHONPATH=src python -m benchmarks.run [--only fig9] [--smoke] [--out-dir .]
                                           [--compare <baseline-dir>]

Output format: ``name,us_per_call,derived`` on stdout, plus one
``BENCH_<suite>.json`` per suite so the performance trajectory is tracked
across PRs. Each file records ``{"suite", "meta": {"commit", "smoke"},
"records": [{name, value, unit, meta}, ...]}`` -- the git commit stamps
every suite so a regression can be bisected straight from the JSON, and
``smoke`` marks reduced-size CI runs that must not be compared against full
runs. ``--smoke`` is the PR-gate mode: every module shrinks its problem
sizes enough to finish in CI while still exercising the full code path.

``--compare <dir>`` diffs each freshly written suite against the
``BENCH_<suite>.json`` in ``dir`` and exits non-zero on regression:
time-unit records (``us_*``) past the suite's relative threshold
(:data:`COMPARE_THRESHOLDS`), any ``bool`` record flipping, or any
baseline record missing from the new run (a silently dropped gate is a
regression too). Non-time value records are reported informationally only
-- regret/ratio trajectories move for legitimate reasons and have their own
in-suite gates. Smoke baselines only compare against smoke runs.
"""
from __future__ import annotations

import argparse
import inspect
import json
import pathlib
import subprocess
import sys
import traceback

from . import (
    adaptive_regret,
    closed_loop,
    fig6_llc_loss,
    fig9_greedy_vs_optimal,
    fig12_single_workload,
    fig34_consolidation,
    fleet_health,
    obs_overhead,
    roofline_table,
    scale_scheduler,
    table2_greedy_example,
    telemetry_throughput,
)

MODULES = [
    ("fig12", fig12_single_workload),
    ("fig34", fig34_consolidation),
    ("fig6", fig6_llc_loss),
    ("table2", table2_greedy_example),
    ("fig9", fig9_greedy_vs_optimal),
    ("scale", scale_scheduler),
    ("adaptive", adaptive_regret),
    ("telemetry", telemetry_throughput),
    ("fleet", fleet_health),
    ("roofline", roofline_table),
    ("closedloop", closed_loop),
    ("obs", obs_overhead),
]


#: default relative regression threshold for time-unit records: smoke CI
#: shares a noisy runner, so the gate is generous -- it exists to catch
#: order-of-magnitude cliffs (an accidental retrace, a host sync in the hot
#: loop), not single-digit-percent drift
COMPARE_DEFAULT_THRESHOLD = 0.5
#: per-suite overrides: suites timing very short kernels (sub-100us) see
#: proportionally more scheduler noise
COMPARE_THRESHOLDS = {
    "scale": 0.75,
    "telemetry": 0.75,
    "obs": 0.75,
    "closedloop": 0.75,
}
#: units where the value is a duration and bigger means slower
TIME_UNITS = ("us_per_call", "us_per_segment", "us_total")


def compare_suite(suite: str, baseline: dict, current: dict) -> "list[str]":
    """Diff one suite's records against a baseline; returns regression
    messages (empty = pass)."""
    failures: list[str] = []
    if bool(baseline.get("meta", {}).get("smoke")) != bool(
            current.get("meta", {}).get("smoke")):
        return [f"{suite}: smoke flag differs from baseline -- full and "
                f"smoke runs are not comparable"]
    base = {r["name"]: r for r in baseline.get("records", [])}
    cur = {r["name"]: r for r in current.get("records", [])}
    thr = COMPARE_THRESHOLDS.get(suite, COMPARE_DEFAULT_THRESHOLD)
    for name, b in base.items():
        c = cur.get(name)
        if c is None:
            failures.append(f"{suite}/{name}: present in baseline, missing "
                            f"from this run")
            continue
        bv, cv = float(b["value"]), float(c["value"])
        if b.get("unit") == "bool":
            if cv != bv:
                failures.append(
                    f"{suite}/{name}: gate flipped {bv:g} -> {cv:g}")
        elif b.get("unit") in TIME_UNITS and bv > 0:
            rel = cv / bv - 1.0
            if rel > thr:
                failures.append(
                    f"{suite}/{name}: {bv:g} -> {cv:g} {b['unit']} "
                    f"(+{rel:.0%} exceeds the +{thr:.0%} gate)")
    return failures


def git_commit() -> str:
    try:
        return subprocess.run(
            ["git", "rev-parse", "HEAD"],
            cwd=pathlib.Path(__file__).resolve().parent,
            capture_output=True, text=True, check=True,
        ).stdout.strip()
    except (OSError, subprocess.CalledProcessError):
        return "unknown"


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None, help="run benches whose tag contains this")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced problem sizes (the CI PR gate)")
    ap.add_argument("--profile", action="store_true",
                    help="modules that support it dump a jax.profiler trace "
                         "(closed_loop: one warm device-loop dispatch)")
    ap.add_argument("--out-dir", default=str(pathlib.Path(__file__).resolve().parents[1]),
                    help="directory for BENCH_<suite>.json records")
    ap.add_argument("--compare", default=None, metavar="BASELINE_DIR",
                    help="diff each suite against BASELINE_DIR/BENCH_<suite>"
                         ".json and exit non-zero on regression")
    args = ap.parse_args()
    out_dir = pathlib.Path(args.out_dir)
    out_dir.mkdir(parents=True, exist_ok=True)
    meta = {"commit": git_commit(), "smoke": bool(args.smoke)}

    if args.smoke:
        # refuse to measure an impure hot path: the same gate CI runs as the
        # static-analysis job (unbaselined findings -> SystemExit)
        from repro.analysis import preflight

        preflight()
        print("analysis preflight: clean")

    print("name,us_per_call,derived")

    records: list[dict] = []

    def emit(name: str, us: float, derived: str, unit: str = "us_per_call"):
        print(f"{name},{us:.2f},{derived}")
        sys.stdout.flush()
        records.append({"name": name, "value": round(us, 3), "unit": unit,
                        "meta": derived})

    failures = []
    regressions: list[str] = []
    for tag, mod in MODULES:
        if args.only and args.only not in tag:
            continue
        records = []
        try:
            kwargs = {}
            if "profile" in inspect.signature(mod.run).parameters:
                kwargs["profile"] = args.profile
            mod.run(emit, smoke=args.smoke, **kwargs)
        except Exception as e:  # noqa: BLE001 -- report and continue
            failures.append((tag, e))
            traceback.print_exc()
            emit(f"{tag}/ERROR", 0.0, repr(e)[:120])
        path = out_dir / f"BENCH_{tag}.json"
        suite = {"suite": tag, "meta": meta, "records": records}
        path.write_text(json.dumps(suite, indent=2) + "\n")
        if args.compare:
            base_path = pathlib.Path(args.compare) / f"BENCH_{tag}.json"
            if not base_path.exists():
                print(f"compare: no baseline for {tag} "
                      f"({base_path}), skipping")
                continue
            found = compare_suite(tag, json.loads(base_path.read_text()),
                                  suite)
            regressions.extend(found)
            status = "ok" if not found else f"{len(found)} REGRESSION(S)"
            print(f"compare: {tag:<12} vs {base_path}: {status}")
    for r in regressions:
        print(f"REGRESSION: {r}", file=sys.stderr)
    if failures:
        raise SystemExit(f"{len(failures)} benchmark modules failed: {[t for t, _ in failures]}")
    if regressions:
        raise SystemExit(
            f"{len(regressions)} benchmark regressions vs {args.compare}")


if __name__ == "__main__":
    main()
