"""Benchmark harness: one module per paper table/figure (DESIGN.md §7).

    PYTHONPATH=src python -m benchmarks.run [--only fig9] [--smoke] [--out-dir .]

Output format: ``name,us_per_call,derived`` on stdout, plus one
``BENCH_<suite>.json`` per suite so the performance trajectory is tracked
across PRs. Each file records ``{"suite", "meta": {"commit", "smoke"},
"records": [{name, value, unit, meta}, ...]}`` -- the git commit stamps
every suite so a regression can be bisected straight from the JSON, and
``smoke`` marks reduced-size CI runs that must not be compared against full
runs. ``--smoke`` is the PR-gate mode: every module shrinks its problem
sizes enough to finish in CI while still exercising the full code path.
"""
from __future__ import annotations

import argparse
import inspect
import json
import pathlib
import subprocess
import sys
import traceback

from . import (
    adaptive_regret,
    closed_loop,
    fig6_llc_loss,
    fig9_greedy_vs_optimal,
    fig12_single_workload,
    fig34_consolidation,
    fleet_health,
    obs_overhead,
    roofline_table,
    scale_scheduler,
    table2_greedy_example,
    telemetry_throughput,
)

MODULES = [
    ("fig12", fig12_single_workload),
    ("fig34", fig34_consolidation),
    ("fig6", fig6_llc_loss),
    ("table2", table2_greedy_example),
    ("fig9", fig9_greedy_vs_optimal),
    ("scale", scale_scheduler),
    ("adaptive", adaptive_regret),
    ("telemetry", telemetry_throughput),
    ("fleet", fleet_health),
    ("roofline", roofline_table),
    ("closedloop", closed_loop),
    ("obs", obs_overhead),
]


def git_commit() -> str:
    try:
        return subprocess.run(
            ["git", "rev-parse", "HEAD"],
            cwd=pathlib.Path(__file__).resolve().parent,
            capture_output=True, text=True, check=True,
        ).stdout.strip()
    except (OSError, subprocess.CalledProcessError):
        return "unknown"


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None, help="run benches whose tag contains this")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced problem sizes (the CI PR gate)")
    ap.add_argument("--profile", action="store_true",
                    help="modules that support it dump a jax.profiler trace "
                         "(closed_loop: one warm device-loop dispatch)")
    ap.add_argument("--out-dir", default=str(pathlib.Path(__file__).resolve().parents[1]),
                    help="directory for BENCH_<suite>.json records")
    args = ap.parse_args()
    out_dir = pathlib.Path(args.out_dir)
    out_dir.mkdir(parents=True, exist_ok=True)
    meta = {"commit": git_commit(), "smoke": bool(args.smoke)}

    if args.smoke:
        # refuse to measure an impure hot path: the same gate CI runs as the
        # static-analysis job (unbaselined findings -> SystemExit)
        from repro.analysis import preflight

        preflight()
        print("analysis preflight: clean")

    print("name,us_per_call,derived")

    records: list[dict] = []

    def emit(name: str, us: float, derived: str, unit: str = "us_per_call"):
        print(f"{name},{us:.2f},{derived}")
        sys.stdout.flush()
        records.append({"name": name, "value": round(us, 3), "unit": unit,
                        "meta": derived})

    failures = []
    for tag, mod in MODULES:
        if args.only and args.only not in tag:
            continue
        records = []
        try:
            kwargs = {}
            if "profile" in inspect.signature(mod.run).parameters:
                kwargs["profile"] = args.profile
            mod.run(emit, smoke=args.smoke, **kwargs)
        except Exception as e:  # noqa: BLE001 -- report and continue
            failures.append((tag, e))
            traceback.print_exc()
            emit(f"{tag}/ERROR", 0.0, repr(e)[:120])
        path = out_dir / f"BENCH_{tag}.json"
        path.write_text(
            json.dumps({"suite": tag, "meta": meta, "records": records}, indent=2)
            + "\n")
    if failures:
        raise SystemExit(f"{len(failures)} benchmark modules failed: {[t for t, _ in failures]}")


if __name__ == "__main__":
    main()
