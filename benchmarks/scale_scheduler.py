"""Beyond-paper: scheduler throughput at fleet scale.

Three layers of the unified consolidation stack are timed on a 16-server
rack (2x M1/M2 alternating):

  * the pure-Python greedy over a 64-arrival sequence (the §VIII experiment);
  * the vectorized JAX greedy (jit + lax.scan) over the same sequence;
  * the full online engine -- arrive/queue/complete/drain over a 256-arrival
    *timed* trace -- as the Python ``OnlineScheduler`` oracle vs the
    device-resident ``ConsolidationEngine`` (engine_jax.run_trace), reported
    as end-to-end makespan-simulation cost per scheduling decision.

Offline refinement (``local_search`` vs its array backend) rides along.

PR 9 adds the fleet tiers: the pod-hierarchical scorer at 1k and 10k
servers against the dense 64-server baseline (per-decision cost must stay
flat -- the O(m/pods + pods) contract), with an in-bench assert that the
hierarchy is decision-identical to the dense scan, plus a sharded-vs-
replicated column timed in a subprocess with simulated host devices
(``--smoke``: 16 servers on 2 devices).
"""
from __future__ import annotations

import json
import math
import os
import subprocess
import sys
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (
    M1,
    M2,
    ClusterState,
    ConsolidationEngine,
    PackedCluster,
    Workload,
    counts_from_assignments,
    greedy_sequence,
    greedy_sequence_jax,
    profile_pairwise_fast,
    snap_to_grid,
    type_index,
)
from repro.core.workload import FS_GRID, RS_GRID

N_ARRIVALS_ONLINE = 256
N_SERVERS = 16


def _random_workloads(n, seed=0):
    rng = np.random.default_rng(seed)
    return [
        snap_to_grid(Workload(fs=float(rng.choice(FS_GRID[:18])), rs=float(rng.choice(RS_GRID))))
        for _ in range(n)
    ]


def _arrival_trace(n, seed=1, gap=2e-5, passes=8):
    """Timed arrivals with multi-pass data totals (sustained co-run sets)."""
    rng = np.random.default_rng(seed)
    out, t = [], 0.0
    for _ in range(n):
        fs = float(rng.choice(FS_GRID[:18]))
        w = snap_to_grid(
            Workload(fs=fs, rs=float(rng.choice(RS_GRID)), data_total=fs * passes))
        t += float(rng.exponential(gap))
        out.append((t, w))
    return out


def run(emit, smoke: bool = False):
    n_online = 64 if smoke else N_ARRIVALS_ONLINE
    n_servers = 8 if smoke else N_SERVERS
    servers = [M1, M2] * (n_servers // 2)
    D = [profile_pairwise_fast(s) for s in servers[:2]] * (n_servers // 2)
    arrivals = _random_workloads(32 if smoke else 64)

    # python greedy
    state = ClusterState.empty(servers, D, alpha=1.3)
    t0 = time.perf_counter()
    placements, queued = greedy_sequence(state, arrivals)
    py_us = (time.perf_counter() - t0) * 1e6 / len(arrivals)
    emit(f"scale/greedy_python/{n_servers}srv", py_us,
         f"placed={sum(p is not None for p in placements)};queued={len(queued)}")

    # beyond-paper: offline local-search refinement on top of the greedy
    from repro.core.refine import local_search, local_search_engine

    t0 = time.perf_counter()
    refined, n_moves = local_search(state, max_iters=20)
    ref_us = (time.perf_counter() - t0) * 1e6
    emit(f"scale/greedy+local_search/{n_servers}srv", ref_us,
         f"moves={n_moves};load_before={state.total_avg_load():.3f};"
         f"load_after={refined.total_avg_load():.3f};descent=first-improvement",
         unit="us_total")

    local_search_engine(state, max_iters=20)  # compile
    t0 = time.perf_counter()
    refined_e, n_moves_e = local_search_engine(state, max_iters=20)
    refe_us = (time.perf_counter() - t0) * 1e6
    # NOTE: not like-for-like with the python row -- best-improvement takes a
    # different descent path to a different final objective; compare the
    # wall-time columns knowing the work differs.
    emit(f"scale/greedy+local_search_jax/{n_servers}srv", refe_us,
         f"moves={n_moves_e};load_after={refined_e.total_avg_load():.3f};"
         f"descent=best-improvement(not comparable to python row)",
         unit="us_total")

    # jax greedy (jit + scan), no runtime semantics -- the §VIII sequence
    cluster = PackedCluster.build(servers, D, alpha=1.3)
    counts0 = counts_from_assignments(cluster, [[] for _ in servers])
    wtypes = jnp.asarray([type_index(w) for w in arrivals])
    greedy_sequence_jax(cluster, counts0, wtypes)[1].block_until_ready()  # compile
    t0 = time.perf_counter()
    _, pj = greedy_sequence_jax(cluster, counts0, wtypes)
    pj.block_until_ready()
    jx_us = (time.perf_counter() - t0) * 1e6 / len(arrivals)
    placed = int((np.asarray(pj) >= 0).sum())
    emit(f"scale/greedy_jax/{n_servers}srv", jx_us,
         f"placed={placed};speedup_vs_python={py_us / jx_us:.1f}x")

    # the online engine: full arrive/queue/complete/drain runtime, 256 arrivals
    trace = _arrival_trace(n_online, gap=2e-5, passes=8)
    engine = ConsolidationEngine(servers, D, alpha=1.3)

    t0 = time.perf_counter()
    res_py = engine.run(trace, backend="numpy")
    eng_py_us = (time.perf_counter() - t0) * 1e6 / len(trace)
    emit(f"scale/engine_python/{n_servers}srv", eng_py_us,
         f"makespan={res_py.makespan:.4f};queued={sum(res_py.was_queued)};"
         f"maxdeg={res_py.max_observed_degradation:.3f}")

    engine.run(trace, backend="jax")  # compile
    t0 = time.perf_counter()
    res_jx = engine.run(trace, backend="jax")
    eng_jx_us = (time.perf_counter() - t0) * 1e6 / len(trace)
    same = res_py.placements == res_jx.placements
    emit(f"scale/engine_jax/{n_servers}srv", eng_jx_us,
         f"makespan={res_jx.makespan:.4f};placements_match={same};"
         f"speedup_vs_python={eng_py_us / eng_jx_us:.1f}x")

    _run_fleet_tiers(emit, smoke)
    _run_sharded_column(emit, smoke)


# --- PR 9: fleet tiers + sharded column ---------------------------------------

def _tier_cluster(m, seed=11):
    """An m-server fleet of jittered M1/M2 variants (LLC sizes spread
    +-10%): a perfectly uniform fleet ties every pod's scores, which is
    both unrealistic and the hierarchy's worst case (every pod must be
    scored to break the tie)."""
    import dataclasses

    rng = np.random.default_rng(seed)
    jitter = rng.uniform(0.9, 1.1, m)
    servers = [
        dataclasses.replace([M1, M2][i % 2],
                            llc_bytes=[M1, M2][i % 2].llc_bytes * jitter[i])
        for i in range(m)]
    D2 = [profile_pairwise_fast(M1), profile_pairwise_fast(M2)]
    return PackedCluster.build(servers, D2 * (m // 2), alpha=1.3)


def _time_per_decision(fn, *args, repeats: int = 3):
    """(us_per_decision, placements) of a jitted greedy scan, post-compile.

    Best of ``repeats`` timed calls: the tier ratio below sits near its
    acceptance threshold, and single-call timings on a shared core are
    noisy in exactly the range that flips it.
    """
    fn(*args)[1].block_until_ready()  # compile
    best = math.inf
    for _ in range(repeats):
        t0 = time.perf_counter()
        _, p = fn(*args)
        p.block_until_ready()
        best = min(best, time.perf_counter() - t0)
    return best * 1e6 / int(p.shape[0]), np.asarray(p)


def _run_fleet_tiers(emit, smoke: bool):
    """Dense baseline vs pod-hierarchical greedy at fleet scale.

    The hierarchical scan keeps the ``counts @ D`` interference aggregate in
    the scan carry and refreshes only the placed server's row per decision,
    so per-decision cost is O(m T) instead of the dense O(m T^2) rescore --
    the 1k tier must land within 2x of the 64-server baseline ("flat
    scaling"). Placements are asserted bitwise-equal to the dense scan
    wherever the dense scan is affordable (the 10k tier runs
    hierarchical-only).
    """
    from repro.core.binpack_jax import greedy_sequence_hier
    from repro.distributed.server_axis import ServerAxis

    arrivals = _random_workloads(32 if smoke else 64, seed=3)
    wtypes = jnp.asarray([type_index(w) for w in arrivals])
    base_m = 16 if smoke else 64
    tiers = [(16, 4)] if smoke else [(1024, 32), (10240, 80)]

    base_cluster = _tier_cluster(base_m)
    base_c0 = counts_from_assignments(base_cluster, [[] for _ in range(base_m)])
    base_us, base_p = _time_per_decision(
        greedy_sequence_jax, base_cluster, base_c0, wtypes)
    emit(f"scale/tier_dense/{base_m}srv", base_us,
         f"placed={int((base_p >= 0).sum())};role=per-decision-baseline")

    for m, pods in tiers:
        cluster = _tier_cluster(m)
        c0 = counts_from_assignments(cluster, [[] for _ in range(m)])
        axis = ServerAxis(pods=pods)
        # empty fleet: the col0 aggregate seed is exactly zero
        col0 = jnp.zeros((m, cluster.T), jnp.float32)
        hier_us, p_h = _time_per_decision(
            greedy_sequence_hier, cluster, c0, wtypes, axis, "sum_avg", col0)
        detail = f"pods={pods};placed={int((p_h >= 0).sum())}"
        if m <= 1024:
            dense_us, p_d = _time_per_decision(
                greedy_sequence_jax, cluster, c0, wtypes)
            assert np.array_equal(p_h, p_d), (
                f"hier placements diverge from dense at m={m}")
            detail += f";placements_match_dense=True;dense_us={dense_us:.1f}"
        ratio = hier_us / base_us
        detail += f";vs_{base_m}srv={ratio:.2f}x;flat_scaling={ratio <= 2.0}"
        emit(f"scale/tier_hier/{m}srv", hier_us, detail)


_SHARDED_PROBE = """
import json, time
import numpy as np
import jax
import jax.numpy as jnp
from repro.core import (M1, M2, PackedCluster, Workload,
                        counts_from_assignments, profile_pairwise_fast,
                        snap_to_grid, type_index)
from repro.core.binpack_jax import greedy_sequence_jax, greedy_sequence_sharded
from repro.core.workload import FS_GRID, RS_GRID
from repro.distributed.server_axis import ServerAxis

m, devices, q = {m}, {devices}, {q}
assert len(jax.devices()) >= devices, jax.devices()
servers = [M1, M2] * (m // 2)
D2 = [profile_pairwise_fast(M1), profile_pairwise_fast(M2)]
cluster = PackedCluster.build(servers, D2 * (m // 2), alpha=1.3)
counts0 = counts_from_assignments(cluster, [[] for _ in range(m)])
rng = np.random.default_rng(0)
wl = [snap_to_grid(Workload(fs=float(rng.choice(FS_GRID[:18])),
                            rs=float(rng.choice(RS_GRID)))) for _ in range(q)]
wtypes = jnp.asarray([type_index(w) for w in wl])
axis = ServerAxis.over_host_devices(devices)

def bench(fn, *args):
    fn(*args)[1].block_until_ready()
    t0 = time.perf_counter()
    _, p = fn(*args)
    p.block_until_ready()
    return (time.perf_counter() - t0) * 1e6 / q, np.asarray(p)

dense_us, p_d = bench(greedy_sequence_jax, cluster, counts0, wtypes)
sh_us, p_s = bench(greedy_sequence_sharded, cluster, counts0, wtypes, axis)
assert np.array_equal(p_d, p_s), (p_d, p_s)
print("PROBE_RESULT " + json.dumps(
    dict(dense_us=dense_us, sharded_us=sh_us, placed=int((p_d >= 0).sum()))))
"""


def _run_sharded_column(emit, smoke: bool):
    """Sharded vs replicated per-decision cost on simulated host devices.

    A subprocess sets ``XLA_FLAGS=--xla_force_host_platform_device_count``
    *before* importing jax (this process's device count is already frozen),
    runs the dense scan and the shard_map scan on the same fleet, asserts
    placements bitwise-equal, and reports both timings. On forced CPU
    devices the collectives are pure overhead -- the column prices the
    mesh crossing, it does not claim a speedup.
    """
    m, devices = (16, 2) if smoke else (64, 4)
    q = 32 if smoke else 64
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "") +
                        f" --xla_force_host_platform_device_count={devices}")
    env["JAX_PLATFORMS"] = "cpu"
    src = str(Path(__file__).resolve().parents[1] / "src")
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    proc = subprocess.run(
        [sys.executable, "-c", _SHARDED_PROBE.format(m=m, devices=devices, q=q)],
        env=env, capture_output=True, text=True, timeout=900)
    if proc.returncode != 0:
        emit(f"scale/greedy_sharded/{m}srv", float("nan"),
             f"devices={devices};probe_failed={proc.stderr.strip()[-160:]!r}")
        return
    line = next(l for l in proc.stdout.splitlines()
                if l.startswith("PROBE_RESULT "))
    r = json.loads(line[len("PROBE_RESULT "):])
    emit(f"scale/greedy_replicated/{m}srv", r["dense_us"],
         f"devices={devices};placed={r['placed']};role=sharded-column-baseline")
    emit(f"scale/greedy_sharded/{m}srv", r["sharded_us"],
         f"devices={devices};placed={r['placed']};placements_match_dense=True;"
         f"overhead_vs_replicated={r['sharded_us'] / r['dense_us']:.2f}x")
