"""Beyond-paper: scheduler throughput at fleet scale -- the pure-Python
greedy vs the vectorized JAX greedy (jit + lax.scan) vs the Pallas scoring
kernel (interpret mode on CPU; the derived column reports per-decision cost).
"""
from __future__ import annotations

import time

import jax.numpy as jnp
import numpy as np

from repro.core import (
    M1,
    M2,
    ClusterState,
    PackedCluster,
    Workload,
    counts_from_assignments,
    greedy_sequence,
    greedy_sequence_jax,
    profile_pairwise_fast,
    snap_to_grid,
)
from repro.core.workload import FS_GRID, RS_GRID


def _random_workloads(n, seed=0):
    rng = np.random.default_rng(seed)
    return [
        snap_to_grid(Workload(fs=float(rng.choice(FS_GRID[:18])), rs=float(rng.choice(RS_GRID))))
        for _ in range(n)
    ]


def run(emit):
    servers = [M1, M2] * 8  # a 16-server rack
    D = [profile_pairwise_fast(s) for s in servers[:2]] * 8
    arrivals = _random_workloads(64)

    # python greedy
    state = ClusterState.empty(servers, D, alpha=1.3)
    t0 = time.perf_counter()
    placements, queued = greedy_sequence(state, arrivals)
    py_us = (time.perf_counter() - t0) * 1e6 / len(arrivals)
    emit("scale/greedy_python/16srv", py_us,
         f"placed={sum(p is not None for p in placements)};queued={len(queued)}")

    # beyond-paper: offline local-search refinement on top of the greedy
    from repro.core.refine import local_search

    t0 = time.perf_counter()
    refined, n_moves = local_search(state, max_iters=20)
    ref_us = (time.perf_counter() - t0) * 1e6
    emit("scale/greedy+local_search/16srv", ref_us,
         f"moves={n_moves};load_before={state.total_avg_load():.3f};"
         f"load_after={refined.total_avg_load():.3f}")

    # jax greedy (jit)
    cluster = PackedCluster.build(servers, D, alpha=1.3)
    counts0 = counts_from_assignments(cluster, [[] for _ in servers])
    wtypes = jnp.asarray([__import__("repro.core", fromlist=["type_index"]).type_index(w)
                          for w in arrivals])
    greedy_sequence_jax(cluster, counts0, wtypes)[1].block_until_ready()  # compile
    t0 = time.perf_counter()
    _, pj = greedy_sequence_jax(cluster, counts0, wtypes)
    pj.block_until_ready()
    jx_us = (time.perf_counter() - t0) * 1e6 / len(arrivals)
    placed = int((np.asarray(pj) >= 0).sum())
    emit("scale/greedy_jax/16srv", jx_us,
         f"placed={placed};speedup_vs_python={py_us / jx_us:.1f}x")
