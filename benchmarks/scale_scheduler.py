"""Beyond-paper: scheduler throughput at fleet scale.

Three layers of the unified consolidation stack are timed on a 16-server
rack (2x M1/M2 alternating):

  * the pure-Python greedy over a 64-arrival sequence (the §VIII experiment);
  * the vectorized JAX greedy (jit + lax.scan) over the same sequence;
  * the full online engine -- arrive/queue/complete/drain over a 256-arrival
    *timed* trace -- as the Python ``OnlineScheduler`` oracle vs the
    device-resident ``ConsolidationEngine`` (engine_jax.run_trace), reported
    as end-to-end makespan-simulation cost per scheduling decision.

Offline refinement (``local_search`` vs its array backend) rides along.
"""
from __future__ import annotations

import time

import jax.numpy as jnp
import numpy as np

from repro.core import (
    M1,
    M2,
    ClusterState,
    ConsolidationEngine,
    PackedCluster,
    Workload,
    counts_from_assignments,
    greedy_sequence,
    greedy_sequence_jax,
    profile_pairwise_fast,
    snap_to_grid,
    type_index,
)
from repro.core.workload import FS_GRID, RS_GRID

N_ARRIVALS_ONLINE = 256
N_SERVERS = 16


def _random_workloads(n, seed=0):
    rng = np.random.default_rng(seed)
    return [
        snap_to_grid(Workload(fs=float(rng.choice(FS_GRID[:18])), rs=float(rng.choice(RS_GRID))))
        for _ in range(n)
    ]


def _arrival_trace(n, seed=1, gap=2e-5, passes=8):
    """Timed arrivals with multi-pass data totals (sustained co-run sets)."""
    rng = np.random.default_rng(seed)
    out, t = [], 0.0
    for _ in range(n):
        fs = float(rng.choice(FS_GRID[:18]))
        w = snap_to_grid(
            Workload(fs=fs, rs=float(rng.choice(RS_GRID)), data_total=fs * passes))
        t += float(rng.exponential(gap))
        out.append((t, w))
    return out


def run(emit, smoke: bool = False):
    n_online = 64 if smoke else N_ARRIVALS_ONLINE
    n_servers = 8 if smoke else N_SERVERS
    servers = [M1, M2] * (n_servers // 2)
    D = [profile_pairwise_fast(s) for s in servers[:2]] * (n_servers // 2)
    arrivals = _random_workloads(32 if smoke else 64)

    # python greedy
    state = ClusterState.empty(servers, D, alpha=1.3)
    t0 = time.perf_counter()
    placements, queued = greedy_sequence(state, arrivals)
    py_us = (time.perf_counter() - t0) * 1e6 / len(arrivals)
    emit(f"scale/greedy_python/{n_servers}srv", py_us,
         f"placed={sum(p is not None for p in placements)};queued={len(queued)}")

    # beyond-paper: offline local-search refinement on top of the greedy
    from repro.core.refine import local_search, local_search_engine

    t0 = time.perf_counter()
    refined, n_moves = local_search(state, max_iters=20)
    ref_us = (time.perf_counter() - t0) * 1e6
    emit(f"scale/greedy+local_search/{n_servers}srv", ref_us,
         f"moves={n_moves};load_before={state.total_avg_load():.3f};"
         f"load_after={refined.total_avg_load():.3f};descent=first-improvement",
         unit="us_total")

    local_search_engine(state, max_iters=20)  # compile
    t0 = time.perf_counter()
    refined_e, n_moves_e = local_search_engine(state, max_iters=20)
    refe_us = (time.perf_counter() - t0) * 1e6
    # NOTE: not like-for-like with the python row -- best-improvement takes a
    # different descent path to a different final objective; compare the
    # wall-time columns knowing the work differs.
    emit(f"scale/greedy+local_search_jax/{n_servers}srv", refe_us,
         f"moves={n_moves_e};load_after={refined_e.total_avg_load():.3f};"
         f"descent=best-improvement(not comparable to python row)",
         unit="us_total")

    # jax greedy (jit + scan), no runtime semantics -- the §VIII sequence
    cluster = PackedCluster.build(servers, D, alpha=1.3)
    counts0 = counts_from_assignments(cluster, [[] for _ in servers])
    wtypes = jnp.asarray([type_index(w) for w in arrivals])
    greedy_sequence_jax(cluster, counts0, wtypes)[1].block_until_ready()  # compile
    t0 = time.perf_counter()
    _, pj = greedy_sequence_jax(cluster, counts0, wtypes)
    pj.block_until_ready()
    jx_us = (time.perf_counter() - t0) * 1e6 / len(arrivals)
    placed = int((np.asarray(pj) >= 0).sum())
    emit(f"scale/greedy_jax/{n_servers}srv", jx_us,
         f"placed={placed};speedup_vs_python={py_us / jx_us:.1f}x")

    # the online engine: full arrive/queue/complete/drain runtime, 256 arrivals
    trace = _arrival_trace(n_online, gap=2e-5, passes=8)
    engine = ConsolidationEngine(servers, D, alpha=1.3)

    t0 = time.perf_counter()
    res_py = engine.run(trace, backend="numpy")
    eng_py_us = (time.perf_counter() - t0) * 1e6 / len(trace)
    emit(f"scale/engine_python/{n_servers}srv", eng_py_us,
         f"makespan={res_py.makespan:.4f};queued={sum(res_py.was_queued)};"
         f"maxdeg={res_py.max_observed_degradation:.3f}")

    engine.run(trace, backend="jax")  # compile
    t0 = time.perf_counter()
    res_jx = engine.run(trace, backend="jax")
    eng_jx_us = (time.perf_counter() - t0) * 1e6 / len(trace)
    same = res_py.placements == res_jx.placements
    emit(f"scale/engine_jax/{n_servers}srv", eng_jx_us,
         f"makespan={res_jx.makespan:.4f};placements_match={same};"
         f"speedup_vs_python={eng_py_us / eng_jx_us:.1f}x")
