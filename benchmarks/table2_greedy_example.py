"""Paper Table II: the worked greedy example -- two servers with loads
(30%,40%) and (40%,45%); allocating W must pick the server that minimizes the
sum of average loads (B, total 80 < 82.5), NOT the lower post-allocation
average (A)."""
from __future__ import annotations

import time


def run(emit, smoke: bool = False):
    del smoke  # arithmetic only
    t0 = time.perf_counter()
    # The table's numbers, verbatim.
    before = {"A": (30.0, 40.0), "B": (40.0, 45.0)}
    after = {"A": (35.0, 45.0), "B": (42.0, 48.0)}
    avg = lambda t: sum(t) / 2
    sum_if_a = avg(after["A"]) + avg(before["B"])  # 40 + 42.5 = 82.5
    sum_if_b = avg(before["A"]) + avg(after["B"])  # 35 + 45   = 80
    paper_choice = "B" if sum_if_b < sum_if_a else "A"

    # our implementation's objective ('sum_avg' = minimize the increase)
    delta_a = avg(after["A"]) - avg(before["A"])  # 5.0
    delta_b = avg(after["B"]) - avg(before["B"])  # 2.5
    ours = "B" if delta_b < delta_a else "A"
    # and the literal Fig-8 pseudocode would pick the min post-allocation avg
    fig8_literal = "A" if avg(after["A"]) < avg(after["B"]) else "B"

    dt = (time.perf_counter() - t0) * 1e6
    emit("table2/greedy_objective", dt,
         f"paper_pick={paper_choice};ours={ours};fig8_literal={fig8_literal};"
         f"sum_if_A={sum_if_a};sum_if_B={sum_if_b};match={ours == paper_choice}")
