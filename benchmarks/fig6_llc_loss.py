"""Paper Figure 6: the effect of losing the LLC on throughput -- degradation
exceeds 50% for every RS > 8KB (the basis of criterion 2)."""
from __future__ import annotations

import time

from repro.core import M1, M2, Workload
from repro.core.simulator import throughput_after_cache
from repro.core.units import KB, MB
from repro.core.workload import RS_GRID


def run(emit, smoke: bool = False):
    del smoke  # cheap: 10 RS points per server
    for server in (M1, M2):
        t0 = time.perf_counter()
        rows = []
        for rs in RS_GRID:
            w = Workload(fs=2 * MB, rs=rs)
            keep = throughput_after_cache(server, w, False)
            lose = throughput_after_cache(server, w, True)
            rows.append((rs, 1 - lose / keep))
        dt = (time.perf_counter() - t0) * 1e6 / len(rows)
        above = [rs for rs, d in rows if d > 0.5]
        threshold = min(above) / KB if above else float("inf")
        emit(f"fig6/{server.name}", dt,
             f"deg_at_512KB={rows[-1][1]:.3f};first_RS_above_50pct={threshold:.0f}KB")
