"""Shared setup for the paper-reproduction benchmarks: Table I servers,
Table III initial states + arrival sequences, profiled D matrices."""
from __future__ import annotations

import functools

from repro.core import (
    PAPER_CLUSTER,
    ClusterState,
    parse_workloads,
    profile_pairwise_fast,
    snap_to_grid,
)

INITIAL = {
    0: "(32KB, 64KB), (4KB, 16KB), (16KB, 32MB)",
    1: "(32KB, 64MB), (512KB, 2MB), (128KB, 512KB)",
    2: "(256KB, 1MB), (4KB, 2MB), (32KB, 8MB)",
    3: "(2KB, 32KB), (512KB, 64MB), (8KB, 4MB)",
}
SEQUENCES = [
    "(16KB, 64KB), (32KB, 1MB), (64KB, 64MB), (32KB, 2MB), (8KB, 64MB)",
    "(4KB, 16KB), (2KB, 16MB), (2KB, 8KB), (32KB, 256KB), (16KB, 64MB)",
    "(256KB, 2MB), (8KB, 3MB), (32KB, 64MB), (4KB, 256MB), (8KB, 32MB)",
]


@functools.lru_cache(maxsize=None)
def d_matrices():
    return tuple(profile_pairwise_fast(s) for s in PAPER_CLUSTER)


def paper_state(alpha: float = 1.3) -> ClusterState:
    state = ClusterState.empty(list(PAPER_CLUSTER), list(d_matrices()), alpha=alpha)
    for i, txt in INITIAL.items():
        state.assignments[i] = [snap_to_grid(w) for w in parse_workloads(txt)]
    return state


def sequences():
    return [[snap_to_grid(w) for w in parse_workloads(s)] for s in SEQUENCES]
