"""Telemetry ingest throughput: host vs device estimator paths (ISSUE 4).

The question this suite answers: how many completion observations per second
can one estimator absorb, starting from where they are born -- the device-
resident telemetry arrays ``engine_jax.run_trace`` emits? The ROADMAP's
million-user setting turns the observe -> estimate loop into a streaming
ingest problem, and the two paths differ exactly where it matters at scale:

  host    ``observations_from_trace`` + per-server ``for_server`` +
          ``StreamingEstimator.update`` -- the float64 reference semantics
          (what PR 2's AdaptiveEngine runs, with this PR's satellite fixes:
          jitted single-launch stacked scatter), but every segment round-
          trips through ``np.asarray``: device -> host transfer of the trace
          arrays, numpy filtering/residuals, one sliced log + update call
          per server, a device scatter with transfers both ways each.
  device  ``ObservationRing.push_trace`` + ``EstimatorBank.update_device``
          -- the same records never leave the device: one fused rows->ring
          launch per segment, then one fused masking/residual/scatter/LMS
          program per ring-full that updates EVERY server's estimator (the
          per-server split becomes scatter indices, so the batch streams
          once regardless of fleet size). State stays device-resident.

Both paths consume identical synthetic trace telemetry arriving in fixed
chunks (the cadence segment boundaries impose), warmed up before timing so
jit compilation is excluded. They differ in *refresh* cadence, which is the
architectural point: the host path has no buffer, so every chunk is an
estimator update; the ring decouples ingest from estimation, so the device
path refreshes once per ring-full (``device_chunked`` pins the device path
to the host's per-chunk refresh cadence for a like-for-like program
comparison). Timing repetitions interleave the paths so machine-noise
epochs land on both. Reported as observations/sec per tier plus the
device/host speedup; the acceptance bar is >= 5x at the 64k tier.
``--smoke`` shrinks the stream for CI and additionally pushes one block
through the Pallas scatter in interpret mode, so the kernel path is
exercised off-TPU on every PR.
"""
from __future__ import annotations

import time
from typing import NamedTuple

import jax.numpy as jnp
import numpy as np

from repro.telemetry import (
    EstimatorBank,
    ObservationRing,
    StreamingEstimator,
    observations_from_trace,
)

#: the paper's grid size; matches what AdaptiveEngine's estimators use
T = 230
#: servers in the fleet (per-server estimators, as AdaptiveEngine holds)
M = 2
#: observations per ingest chunk (a segment boundary's worth of completions --
#: generous: the repo's closed-loop segments run 16-48 arrivals)
CHUNK = 128
#: ring capacity = device refresh cadence (rows per fused estimator update)
CAPACITY = 4096
#: timing repetitions per path; the minimum is reported (machine-noise guard)
REPS = 3


class _FakeTrace(NamedTuple):
    """The telemetry fields of an ``EngineTrace``, synthesized on device."""

    place_time: jnp.ndarray
    finish_time: jnp.ndarray
    placement: jnp.ndarray
    obs_co: jnp.ndarray
    obs_lost: jnp.ndarray
    obs_logr: jnp.ndarray


def _synthetic_stream(rng: np.random.Generator, n: int, chunk: int):
    """(trace, arr_type, arr_bytes) per chunk, trace arrays staged on device.

    Rates follow a plausible log-linear world; a few rows per chunk never
    complete (placement -1), exercising both paths' filtering.
    """
    chunks = []
    for start in range(0, n, chunk):
        b = min(chunk, n - start)
        t = rng.integers(0, T, b).astype(np.int32)
        co = np.zeros((b, T))
        rows = np.repeat(np.arange(b), 2)
        co[rows, rng.integers(0, T, 2 * b)] += 1.0
        # solo runs anchor the base rates but are rare in a consolidated
        # fleet (co-location is the scheduler's whole objective)
        co[rng.random(b) < 0.1] = 0.0
        y = rng.normal(1.0, 0.2, b)
        dur = rng.uniform(0.5, 2.0, b)
        placement = rng.integers(0, M, b).astype(np.int32)
        placement[rng.random(b) < 0.02] = -1  # queued at deadlock: no record
        trace = _FakeTrace(
            place_time=jnp.zeros(b, jnp.float32),
            finish_time=jnp.asarray(dur, jnp.float32),
            placement=jnp.asarray(placement),
            obs_co=jnp.asarray(co * dur[:, None], jnp.float32),
            obs_lost=jnp.asarray((rng.random(b) < 0.05) * dur, jnp.float32),
            obs_logr=jnp.asarray(y * dur, jnp.float32),
        )
        chunks.append((trace, jnp.asarray(t), np.exp(y) * dur))
    return chunks


def _estimator(scatter: str) -> StreamingEstimator:
    return StreamingEstimator(T=T, prior_D=0.0, lr=0.5, decay=0.999,
                              confidence_floor=2.0, scatter=scatter)


def _run_host(chunks) -> float:
    ests = [_estimator("jnp") for _ in range(M)]
    t0 = time.perf_counter()
    for trace, arr_type, arr_bytes in chunks:
        obs = observations_from_trace(trace, np.asarray(arr_type), arr_bytes)
        for s, est in enumerate(ests):
            est.update(obs.for_server(s))
    return time.perf_counter() - t0


def _run_device(chunks, ring_cadence: bool) -> "tuple[float, EstimatorBank]":
    """Push every chunk; refresh per ring-full, or per chunk when pinned."""
    bank = EstimatorBank([_estimator("jnp") for _ in range(M)])
    ring = ObservationRing(CAPACITY, T)
    pending = 0
    t0 = time.perf_counter()
    for trace, arr_type, _ in chunks:
        pushed = ring.push_trace(trace, arr_type)
        if not ring_cadence:
            # host-cadence pin: consume this block, without per-call syncs
            bank.update_device(pushed, sync=False)
            continue
        pending += pushed.rows
        if pending >= CAPACITY:
            # the ring rolled over with exactly `pending` fresh rows (the
            # capacity is a chunk multiple): one fused update consumes it
            bank.update_device(ring.view(), sync=False)
            pending = 0
    if pending:
        # flush: remaining rows never wrapped
        bank.update_device(ring.view(), sync=False)
    # the stream is fully absorbed once every member state materializes
    bank.estimators[0].device_state().L_t.block_until_ready()
    return time.perf_counter() - t0, bank


def _time_paths(chunks) -> tuple[float, float, float]:
    """Best-of-REPS per path, *interleaved* within each repetition so an
    epoch of machine noise (frequency scaling, a noisy neighbor) lands on
    every path instead of skewing whichever ran during it."""
    _run_host(chunks)  # warm the jitted scatter across the chunk shapes
    _run_device(chunks, ring_cadence=True)  # warm the push + update jits
    _run_device(chunks, ring_cadence=False)
    host_s = dev_s = chunked_s = float("inf")
    for _ in range(REPS):
        host_s = min(host_s, _run_host(chunks))
        dt, bank = _run_device(chunks, ring_cadence=True)
        dev_s = min(dev_s, dt)
        chunked_s = min(chunked_s, _run_device(chunks, ring_cadence=False)[0])
    for est in bank.estimators:
        est.estimate_D()  # sanity: the lazy host sync works after a timed run
    return host_s, dev_s, chunked_s


def run(emit, smoke: bool = False):
    rng = np.random.default_rng(0)
    tiers = [1024] if smoke else [1024, 16384, 65536]

    for n in tiers:
        chunks = _synthetic_stream(rng, n, CHUNK)
        host_s, dev_s, chunked_s = _time_paths(chunks)
        host_rate, dev_rate, chunked_rate = n / host_s, n / dev_s, n / chunked_s
        tag = f"{n // 1024}k"
        emit(f"telemetry/host_{tag}", host_rate,
             f"chunk={CHUNK};sec={host_s:.3f}", unit="obs_per_sec")
        emit(f"telemetry/device_{tag}", dev_rate,
             f"chunk={CHUNK};refresh={CAPACITY};sec={dev_s:.3f}",
             unit="obs_per_sec")
        emit(f"telemetry/device_chunked_{tag}", chunked_rate,
             f"chunk={CHUNK};refresh={CHUNK};sec={chunked_s:.3f}",
             unit="obs_per_sec")
        emit(f"telemetry/speedup_{tag}", dev_rate / host_rate,
             "device_over_host;target>=5x_at_64k", unit="ratio")

    if smoke:
        # PR-gate coverage of the kernel path: one block through the Pallas
        # stacked-statistic scatter (interpret mode off-TPU)
        est = _estimator("pallas")
        ring = ObservationRing(CHUNK, T)
        trace, arr_type, _ = chunks[0]
        used = est.update_device(ring.push_trace(trace, arr_type), server=0)
        err = float(np.abs(est.estimate_D()).max())  # forces the host sync
        emit("telemetry/pallas_interpret_block", float(used),
             f"rows_consumed;max_D={err:.3f}", unit="observations")
