"""Deliverable g: the roofline table, read from the dry-run artifacts
(artifacts/dryrun/*.json). Reports the three terms, the dominant bottleneck,
MODEL_FLOPS/HLO ratio and the roofline fraction per (arch x shape) cell."""
from __future__ import annotations

import json
import pathlib
import time

from repro.launch.roofline import CellArtifact

ARTIFACTS = pathlib.Path(__file__).resolve().parents[1] / "artifacts" / "dryrun"


def run(emit, smoke: bool = False):
    del smoke  # reads precomputed artifacts
    if not ARTIFACTS.exists():
        emit("roofline/missing", 0.0, "run `python -m repro.launch.dryrun --all` first")
        return
    t0 = time.perf_counter()
    count = 0
    for f in sorted(ARTIFACTS.glob("*.json")):
        rec = json.loads(f.read_text())
        if "skip" in rec:
            emit(f"roofline/{rec['cell']}", 0.0, rec["skip"])
            continue
        art = CellArtifact(**rec)
        if art.mesh != "single":
            continue  # the roofline table is single-pod (multi-pod proves sharding)
        t = art.terms()
        count += 1
        emit(
            f"roofline/{art.cell}",
            (time.perf_counter() - t0) * 1e6 / max(count, 1),
            f"compute={t['compute_s']*1e3:.2f}ms;memory={t['memory_s']*1e3:.2f}ms;"
            f"collective={t['collective_s']*1e3:.2f}ms;bottleneck={art.bottleneck()};"
            f"useful_flops={art.useful_flops_ratio():.3f};"
            f"roofline_frac={art.roofline_fraction():.4f};"
            f"mem_per_dev={art.peak_memory_per_device/2**30:.2f}GiB;"
            f"fits={art.extras.get('fits_hbm')}",
        )
