"""Paper Figures 1-2: single-workload throughput surfaces vs (FS, RS) for
read and write on M1 and M2. Emits the full surface to CSV-able rows and
derives the paper's three observable claims."""
from __future__ import annotations

import time

import numpy as np

from repro.core import M1, M2, solo_throughput_grid
from repro.core.throughput import level_of
from repro.core.units import MB
from repro.core.workload import FS_GRID, RS_GRID


def run(emit, smoke: bool = False):
    del smoke  # cheap: full grid in milliseconds
    t0 = time.perf_counter()
    n = 0
    for server in (M1, M2):
        for op in ("read", "write"):
            grid = solo_throughput_grid(server, RS_GRID, FS_GRID, op)
            n += grid.size
            levels = sorted({level_of(server, fs, op) for fs in FS_GRID})
            # derived checks straight off the figure:
            #  (a) #throughput levels (3 write / 2 read);
            #  (b) RS-monotonicity everywhere;
            #  (c) the write level-3 onset at filecache+diskcache.
            mono = bool(np.all(np.diff(grid, axis=0) > 0))
            spill_mb = server.cache_spill_bytes / MB
            emit(
                f"fig12/{server.name}/{op}",
                (time.perf_counter() - t0) * 1e6 / max(n, 1),
                f"levels={len(levels)};rs_monotone={mono};spill_at={spill_mb:.0f}MB;"
                f"peak={grid.max()/1e9:.2f}GBps;floor={grid.min()/1e6:.2f}MBps",
            )
