"""Paper Figure 9 + Table III: greedy vs brute-force optimal on the 4-server
cloud prototype for alpha in {1.0, 1.3, 1.5}, three arrival sequences.
The bar metric is the average minimum relative throughput across servers."""
from __future__ import annotations

import time

from repro.core import (
    average_min_throughput_simulated,
    brute_force,
    greedy_sequence,
)

from .paper_setup import paper_state, sequences


def run(emit, smoke: bool = False):
    alphas = (1.3,) if smoke else (1.0, 1.3, 1.5)  # brute force is exponential
    for alpha in alphas:
        for si, seq in enumerate(sequences(), start=1):
            if smoke and si > 1:
                break
            # greedy
            t0 = time.perf_counter()
            g_state = paper_state(alpha)
            placements, queued = greedy_sequence(g_state, seq)
            g_us = (time.perf_counter() - t0) * 1e6 / len(seq)
            g_metric = average_min_throughput_simulated(g_state)
            g_cost = g_state.total_avg_load() + len(queued)

            # brute force optimal
            t0 = time.perf_counter()
            try:
                o_cost, assign = brute_force(paper_state(alpha), seq)
                o_state = paper_state(alpha)
                for w, s in zip(seq, assign):
                    if s is not None:
                        o_state.assignments[s].append(w)
                o_metric = average_min_throughput_simulated(o_state)
                ratio = g_cost / o_cost
            except RuntimeError:
                o_metric, ratio = float("nan"), float("nan")
            bf_us = (time.perf_counter() - t0) * 1e6

            emit(
                f"fig9/alpha={alpha}/seq{si}", g_us,
                f"greedy_min_thr={g_metric:.3f};optimal_min_thr={o_metric:.3f};"
                f"cost_ratio={ratio:.3f};queued={len(queued)};bf_us={bf_us:.0f}",
            )
