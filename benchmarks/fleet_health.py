"""Fleet-health benchmark: pooled warm-up, CUSUM latency, failure eviction.

Three questions about the `repro.fleet` control plane (DESIGN.md §11), each
a ROADMAP scenario, all answered under the multi-tenant noise world where
relevant:

  warm-up   How much faster does a *pooled* estimator reach the per-server
            regret floor, and how much unit-to-unit hardware variance
            (``perturb_spec`` scale) can one shared profile absorb before
            per-server estimation pays for itself? Sweeps scale in
            {0, 0.05, 0.1, 0.2}; the acceptance bar is pooled reaching the
            floor in <= 1/2 the observations at scale <= 0.05.
  split     How quickly does the CUSUM notice a genuinely diverged pool
            member? A deterministic ``congest_server`` divergence is
            injected on one server *under* stochastic co-tenant noise on
            the others (``stochastic_congestion``); the bar is a split
            within 3 segments of the injection.
  evict     Does the failure path close? One server ``gradual_decay``\\ s
            toward zero; the bar is an eviction event after which the
            decayed server receives zero placements, with its in-flight
            work requeued onto survivors.

Protocol (warm-up): one stationary segment replayed K times, exactly the
``adaptive_regret`` protocol -- the oracle (true profiled D per unit) is a
constant per fleet, so every change in segment duration is attributable to
the estimates. Pooled and per-server engines see identical traces.

``--smoke`` shrinks the fleet and trace for CI and additionally pushes one
update through the Pallas stacked scatter in interpret mode so the kernel
path behind the pooled bank runs on every PR.
"""
from __future__ import annotations

import numpy as np

from repro.configs.base import MeshConfig
from repro.core import (
    M1,
    AdaptiveEngine,
    ConsolidationEngine,
    Workload,
    profile_pairwise_fast,
    snap_to_grid,
)
from repro.core.workload import FS_GRID, RS_GRID
from repro.fleet import FleetController
from repro.telemetry import (
    congestion_at,
    gradual_decay,
    merge_schedules,
    stochastic_congestion,
)

#: the warm-up protocol is exactly adaptive_regret's -- share its trace
#: generator and replay gap so the two benchmarks' baselines cannot drift
from .adaptive_regret import SEG_GAP, _segment

#: regret within this absolute margin of the floor counts as "warmed up"
FLOOR_TOL = 0.02


def _replay(seg, segments):
    return [(t + k * SEG_GAP, w) for k in range(segments) for t, w in seg]


def _perturbed_fleet(m, scale, seed0=100):
    from repro.telemetry import perturb_spec

    return [perturb_spec(M1, scale, seed=seed0 + i) for i in range(m)]


def _oracle_duration(servers, seg):
    """True-D greedy duration of one segment (the regret denominator)."""
    oracle = ConsolidationEngine(
        list(servers), D=[profile_pairwise_fast(s) for s in servers])
    return oracle.run(seg, backend="jax").makespan - seg[0][0]


def _obs_to_floor(regret, obs_cum, floor):
    """Cumulative observations when regret first *stays* at the floor.

    "Stays" is literal: the earliest segment from which every later segment
    remains within tolerance of the floor (a lucky transient dip that later
    regresses does not count as warmed up). Returns ``(inf, None)`` when the
    curve never settles there -- pooling at high heterogeneity genuinely
    does not converge to the per-server floor, and reporting the last
    segment instead would dress that up as near-parity.
    """
    if regret[-1] > floor + FLOOR_TOL:
        return float("inf"), None
    k = len(regret) - 1
    for j in range(len(regret) - 1, -1, -1):
        if regret[j] > floor + FLOOR_TOL:
            break
        k = j
    return float(obs_cum[k]), k


def _warmup_sweep(emit, scales, m, n_seg, segments, seeds=(3, 7, 11)):
    """Pooled vs per-server warm-up regret across hardware heterogeneity.

    Regret curves are averaged over independent trace seeds (the
    ``adaptive_regret`` protocol): single-trace curves bounce around the
    floor with placement-tie noise, which the strict stays-at-the-floor
    warm-up rule would otherwise read as late convergence.
    """
    crossovers = {}
    for scale in scales:
        servers = _perturbed_fleet(m, scale)
        regret = {"pooled": np.zeros(segments), "per_server": np.zeros(segments)}
        obs_cum = {k: np.zeros(segments) for k in regret}
        splits = 0
        for seed in seeds:
            seg = _segment(seed, n_seg)
            arrivals = _replay(seg, segments)
            oracle_dur = _oracle_duration(servers, seg)
            paths = {
                "pooled": AdaptiveEngine(
                    servers, prior=0.0, decay=0.997,
                    fleet=FleetController(pools=[0] * m)),
                "per_server": AdaptiveEngine(
                    servers, prior=0.0, decay=0.997, stream=True),
            }
            for name, eng in paths.items():
                res = eng.run(arrivals, segments=segments)
                regret[name] += [(d - oracle_dur) / oracle_dur
                                 for d in res.durations]
                obs_cum[name] += np.cumsum(res.n_obs)
                if name == "pooled":
                    splits += len(eng.fleet.events_of("split"))
        for name in regret:
            regret[name] /= len(seeds)
            obs_cum[name] /= len(seeds)

        floor = float(np.mean(regret["per_server"][-2:]))
        obs_pool, k_pool = _obs_to_floor(regret["pooled"], obs_cum["pooled"], floor)
        obs_per, k_per = _obs_to_floor(regret["per_server"], obs_cum["per_server"], floor)
        if np.isfinite(obs_pool) and np.isfinite(obs_per):
            ratio = obs_pool / max(obs_per, 1.0)
        else:
            ratio = float("inf")  # one side never settled: no crossover
        crossovers[scale] = ratio
        emit(
            f"fleet/warmup_scale{scale:g}",
            ratio if np.isfinite(ratio) else -1.0,  # -1 = no convergence
            f"obs_pooled={obs_pool:.0f}@seg{k_pool};obs_per={obs_per:.0f}@seg{k_per};"
            f"floor={100 * floor:.1f}%;early_pooled={100 * regret['pooled'][0]:.1f}%;"
            f"early_per={100 * regret['per_server'][0]:.1f}%;splits={splits};"
            f"seeds={len(seeds)}",
            unit="obs_ratio_pooled_over_per",
        )
    fast = [s for s in scales if s <= 0.05]
    ok = all(crossovers[s] <= 0.5 for s in fast)
    emit(
        "fleet/warmup_halved_at_low_scale", float(ok),
        ";".join(f"scale{s:g}={crossovers[s]:.2f}" for s in scales)
        + ";bar=ratio<=0.5 at scale<=0.05",
        unit="bool",
    )


def _stream_segment(seed: int, n: int, gap: float = 2e-5, passes: int = 3):
    """A streaming arrival segment: above-LLC file sets (levels 2-3).

    Congestion (``congest_server``) steals *shared* storage bandwidth, which
    LLC-resident workloads barely touch -- the drift is only observable
    through runs that stream the shared subsystem (for these types the
    congested pair log-rate shifts by ~1 per co-resident; solo rates do not
    move at all).
    """
    rng = np.random.default_rng(seed)
    out, t = [], 0.0
    for _ in range(n):
        fs = float(rng.choice(FS_GRID[14:17]))
        w = snap_to_grid(
            Workload(fs=fs, rs=float(rng.choice(RS_GRID[5:8])), data_total=fs * passes))
        t += float(rng.exponential(gap))
        out.append((t, w))
    return out


def _split_latency(emit, m, n_seg, segments, inject_at, seed=5, factor=0.35):
    """Segments from an injected congest divergence to its split event.

    The fleet starts from the *profiled* prior (the realistic deployment for
    drift detection: the offline matrix exists, the question is noticing
    when a unit leaves it) -- detection must race the closed loop itself,
    which observes the slowdown and starts off-loading the congested server
    within a segment or two, starving the detector of co-run evidence.
    """
    servers = [M1] * m
    noise = stochastic_congestion(
        servers, rate=0.25, seed=seed, segments=segments,
        servers=list(range(1, m)))  # keep the injected server out of the noise
    drift = merge_schedules(
        noise, congestion_at(servers, inject_at, server=0, factor=factor))
    fleet = FleetController()  # same-spec fleet: 'spec' pools all of it
    eng = AdaptiveEngine(servers, prior="profiled", decay=0.997, drift=drift,
                         fleet=fleet)
    eng.run(_replay(_stream_segment(seed, n_seg), segments), segments=segments)

    split_segs = [ev.segment for ev in fleet.events_of("split") if ev.server == 0]
    latency = (split_segs[0] - inject_at) if split_segs else float("inf")
    other = sorted({ev.server for ev in fleet.events_of("split")} - {0})
    emit(
        "fleet/cusum_split_latency", float(latency),
        f"inject_seg={inject_at};split_seg={split_segs[0] if split_segs else None};"
        f"within_3={latency <= 3};noise_splits={other};evictions={len(fleet.evicted())}",
        unit="segments",
    )


def _eviction_trace(emit, m, n_seg, segments, decay_from, seed=7, failing=1,
                    rate=0.5):
    """gradual_decay to ~zero: detection, masking, and requeue end to end."""
    servers = [M1] * m
    drift = gradual_decay(servers, server=failing, rate=rate,
                          start=decay_from, segments=segments)
    fleet = FleetController(mesh=MeshConfig())
    eng = AdaptiveEngine(servers, prior=0.0, decay=0.997, drift=drift, fleet=fleet)
    seg = _segment(seed, n_seg)
    res = eng.run(_replay(seg, segments), segments=segments)

    evs = fleet.events_of("evict")
    evict_seg = evs[0].segment if evs else None
    after = (0 if evict_seg is None else
             sum(1 for r in res.segments[evict_seg + 1:]
                 for p in r.placements if p == failing))
    requeued = (0 if evict_seg is None or evict_seg + 1 >= segments else
                len(res.segments[evict_seg + 1].placements) - n_seg)
    zero_after = evict_seg is not None and after == 0
    emit(
        "fleet/eviction_zero_placements_after", float(zero_after),
        f"evict_seg={evict_seg};decay_from={decay_from};on_failing_after={after};"
        f"requeued={requeued};remesh_plans={len(fleet.plans)};"
        f"dead={not fleet.monitor.hosts[failing].alive}",
        unit="bool",
    )


def _smoke_pallas_scatter(n_seg, seed=11):
    """Push one fused update through the Pallas stacked scatter (interpret
    mode off-TPU), so the kernel path behind the pooled bank runs in CI."""
    from repro.core.engine import GRID_T
    from repro.telemetry import StreamingEstimator

    servers = [M1]
    engine = ConsolidationEngine(servers, D=profile_pairwise_fast(M1))
    res = engine.run(_segment(seed, n_seg), backend="jax", telemetry="device")
    est = StreamingEstimator(T=GRID_T, prior_D=0.0, scatter="pallas")
    return est.update_device(res.stream_block, server=0)


def run(emit, smoke: bool = False):
    if smoke:
        # tiny fleet, but m = 3 keeps a majority behind the pool-centered
        # CUSUM (with 2 members, "who diverged" is genuinely ambiguous);
        # the harsher decay rate compensates the shorter window so the
        # detection -> eviction path still fires in CI
        m, n_seg, segments = 3, 14, 5
        scales = (0.0, 0.05)
        # injections land on the first post-burn-in segment (the controller
        # withholds actions for warmup_segments=2); the harsher congestion
        # compensates the thin per-segment evidence (~3 rows on the
        # injected server) so detection still fires inside the window
        inject_at, decay_from, decay_rate = 2, 1, 0.65
        inject_factor = 0.15
    else:
        m, n_seg, segments = 4, 24, 8
        scales = (0.0, 0.05, 0.1, 0.2)
        inject_at, decay_from, decay_rate = 3, 2, 0.5
        inject_factor = 0.35

    _warmup_sweep(emit, scales, m, n_seg, segments,
                  seeds=(3,) if smoke else (3, 7, 11))
    _split_latency(emit, m, n_seg, segments, inject_at, factor=inject_factor)
    _eviction_trace(emit, m, n_seg, segments, decay_from, rate=decay_rate)
    if smoke:
        used = _smoke_pallas_scatter(n_seg)
        emit("fleet/smoke_pallas_scatter", float(used),
             "stacked pair_scatter in interpret mode", unit="rows")
