"""Paper Figures 3-4: (a) multi-workload throughput surfaces vs (N, FS) at
RS = 64KB and 256KB on M1 with the predicted TDPs (Eqn 2) overlaid;
(b) additive-model validation (Eqn 3 prediction vs simulator ground truth)."""
from __future__ import annotations

import time

import numpy as np

from repro.core import (
    M1,
    Workload,
    corun_throughput_grid,
    predict_degradations,
    predict_tdp_n,
    simulate_corun,
)
from repro.core.contention import profile_pairwise_fast
from repro.core.units import KB, MB
from repro.core.workload import FS_GRID


def run(emit, smoke: bool = False):
    fs_grid = [f for f in FS_GRID if f <= 8 * MB]
    n_grid = list(range(1, 6 if smoke else 9))

    for rs in (64 * KB,) if smoke else (64 * KB, 256 * KB):
        t0 = time.perf_counter()
        grid = corun_throughput_grid(M1, rs, fs_grid, n_grid)
        dt = (time.perf_counter() - t0) * 1e6 / grid.size
        # locate the observed cliff per N and compare with Eqn-2 prediction
        hits, preds = [], []
        for ni, n in enumerate(n_grid):
            drop = grid[ni] / grid[ni][0]
            cliff = next((fs_grid[j] for j in range(len(fs_grid)) if drop[j] < 0.5), None)
            if cliff is not None and n > 1:
                # predicted critical FS from Eqn (1): alpha*C/n - rs, alpha=tolerance
                pred = M1.llc_tolerance * M1.llc_bytes / n - rs
                hits.append(cliff)
                preds.append(pred)
        if hits:
            ratio = float(np.mean(np.asarray(hits) / np.asarray(preds)))
        else:
            ratio = float("nan")
        emit(f"fig34a/tdp_surface/rs={int(rs/KB)}KB", dt,
             f"cliffs_found={len(hits)};observed_over_predicted={ratio:.2f}")

    # (b) model validation: Eqn-3 prediction vs actual for N = 2..5
    D = profile_pairwise_fast(M1)
    t0 = time.perf_counter()
    errs = []
    for rs in (64 * KB, 256 * KB):
        for fs in (256 * KB, 512 * KB, 1 * MB):
            for n in (2, 3, 4, 5):
                ws = [Workload(fs=fs, rs=rs)] * n
                pred = predict_degradations(D, ws)
                act = np.asarray(simulate_corun(M1, ws).degradations)
                if act.max() < 0.5:  # the paper validates in the useful regime
                    errs.append(np.abs(pred - act).max())
    dt = (time.perf_counter() - t0) * 1e6 / max(len(errs), 1)
    emit("fig34b/additive_model_validation", dt,
         f"cases={len(errs)};max_abs_err={max(errs):.4f};mean_abs_err={np.mean(errs):.4f}")
