"""Observability-plane overhead: device loop with in-carry counters (and
the decision flight recorder) on vs off.

The PR-8 tentpole threads a ``MetricFrame`` (counters, high-water gauges,
log-binned histograms, per-server columns) through the fused closed loop's
carry. The instrumentation is a handful of scatter-adds per event against a
scan body dominated by the O(m*T^2) estimator update, so it should be close
to free -- this benchmark holds it to that claim. The decision flight
recorder (``obs.recorder``, one packed provenance row per placement riding
the same carry behind ``record=``) gets the identical treatment and the
identical bar.

Protocol mirrors ``benchmarks/closed_loop.py``: identical arrivals, separate
engines per configuration (one compile cache each, no cross-warming), warm
once to exclude compilation, then min-of-reps wall clock per full device-loop
run. The acceptance gates are metrics-on overhead <= 5% and recorder-on
overhead <= 5% of the all-off per-segment time at the 16-server tier.

Honesty checks ride along: the metrics-on run's counters are compared
against host-visible oracle counts (arrivals/segments/placements from the
returned segments), the recorder-on run's ring must reconstruct every
placement of its own run (``obs.explain.check_reconstruction``), and the
on-run's frame is flattened into the BENCH records via ``snapshot_records``
so the JSON shows what a run report carries.

``--smoke`` shrinks to the 3-server tier with a handful of segments.
"""
from __future__ import annotations

import time

import numpy as np

from repro.configs.base import MeshConfig
from repro.core import M1, AdaptiveEngine, Workload, snap_to_grid
from repro.core.workload import FS_GRID, RS_GRID
from repro.fleet import FleetController
from repro.obs import metrics as M
from repro.obs.report import snapshot_records

#: (servers, jobs per segment, segments); the 16-server row is the gate
TIERS = [(4, 1, 64), (16, 1, 64)]
GATE_M, GATE_FRAC = 16, 0.05
REPS = 5


def _arrivals(seed: int, n_seg: int, segments: int, gap: float = 2e-5):
    rng = np.random.default_rng(seed)
    seg, t = [], 0.0
    for _ in range(n_seg):
        fs = float(rng.choice(FS_GRID[10:14]))
        w = snap_to_grid(Workload(fs=fs, rs=float(rng.choice(RS_GRID[5:8])),
                                  data_total=fs * 6))
        t += float(rng.exponential(gap))
        seg.append((t, w))
    return [(t + k * 10.0, w) for k in range(segments) for t, w in seg]


def _engine(m: int) -> AdaptiveEngine:
    return AdaptiveEngine([M1] * m, prior=0.0, decay=1.0,
                          fleet=FleetController(mesh=MeshConfig()),
                          ring_capacity=256)


def _time_path(m, n_seg, segments, metrics, record=False, reps=REPS):
    arr = _arrivals(0, n_seg, segments)
    eng = _engine(m)
    eng.run(arr, segments=segments, device_loop=True, metrics=metrics,
            record=record)
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        res = eng.run(arr, segments=segments, device_loop=True,
                      metrics=metrics, record=record)
        ts.append(time.perf_counter() - t0)
    return min(ts) / segments, res


def _check_counters(res, n_arrivals: int, segments: int) -> "list[str]":
    """Frame counters vs the host-visible oracle of the same run."""
    frame = res.metrics
    placed = sum(
        1 for seg in res.segments for p in seg.placements if p is not None)
    oracle = {"arrivals": n_arrivals, "segments": segments,
              "placements": placed}
    return [f"{name}: frame {M.counter_value(frame, name)} != oracle {want}"
            for name, want in oracle.items()
            if M.counter_value(frame, name) != want]


def _check_recorder(res) -> "list[str]":
    """The recorder-on run's ring vs the host-visible placements."""
    from repro.obs.explain import check_reconstruction

    if res.decisions is None:
        return ["record=True returned no decision ring"]
    return check_reconstruction(
        res.decisions, [seg.placements for seg in res.segments])


def _tier(emit, m, n_seg, segments, tag):
    off_s, _ = _time_path(m, n_seg, segments, metrics=False)
    on_s, on_res = _time_path(m, n_seg, segments, metrics=True)
    rec_s, rec_res = _time_path(m, n_seg, segments, metrics=False,
                                record=True)
    overhead = on_s / off_s - 1.0
    rec_overhead = rec_s / off_s - 1.0
    emit(f"obs/off_{tag}", off_s * 1e6,
         f"m={m};jobs_per_seg={n_seg};segments={segments};"
         f"segs_per_s={1.0 / off_s:.1f}", unit="us_per_segment")
    emit(f"obs/on_{tag}", on_s * 1e6,
         f"m={m};jobs_per_seg={n_seg};segments={segments};"
         f"segs_per_s={1.0 / on_s:.1f}", unit="us_per_segment")
    emit(f"obs/rec_{tag}", rec_s * 1e6,
         f"m={m};jobs_per_seg={n_seg};segments={segments};recorder-on;"
         f"segs_per_s={1.0 / rec_s:.1f}", unit="us_per_segment")
    emit(f"obs/overhead_{tag}", overhead,
         f"m={m};on/off-1;"
         + (f"gate=<= {GATE_FRAC:.0%}" if m == GATE_M else "info"),
         unit="frac")
    emit(f"obs/rec_overhead_{tag}", rec_overhead,
         f"m={m};rec/off-1;"
         + (f"gate=<= {GATE_FRAC:.0%}" if m == GATE_M else "info"),
         unit="frac")
    mismatches = _check_counters(on_res, n_seg * segments, segments)
    emit(f"obs/counters_exact_{tag}", float(not mismatches),
         ";".join(mismatches) if mismatches
         else f"m={m};arrivals/segments/placements match host oracle",
         unit="bool")
    rec_fail = _check_recorder(rec_res)
    emit(f"obs/recorder_faithful_{tag}", float(not rec_fail),
         ";".join(f[:80] for f in rec_fail) if rec_fail
         else f"m={m};ring reconstructs every placement",
         unit="bool")
    return overhead, rec_overhead, on_res


def run(emit, smoke: bool = False):
    if smoke:
        _, _, on_res = _tier(emit, 3, 2, 6, "m3")
        for name, value, unit in snapshot_records(on_res.metrics):
            emit(name, value, "smoke device-loop metrics snapshot", unit=unit)
        return
    gate_res = None
    for m, n_seg, segments in TIERS:
        overhead, rec_overhead, on_res = _tier(emit, m, n_seg, segments,
                                               f"m{m}")
        if m == GATE_M:
            gate_res = (overhead, rec_overhead, on_res)
    overhead, rec_overhead, on_res = gate_res
    emit("obs/gate_16server", float(overhead <= GATE_FRAC),
         f"overhead_m16={overhead:.4f};bar={GATE_FRAC}", unit="bool")
    emit("obs/gate_recorder_16server", float(rec_overhead <= GATE_FRAC),
         f"rec_overhead_m16={rec_overhead:.4f};bar={GATE_FRAC}", unit="bool")
    for name, value, unit in snapshot_records(on_res.metrics):
        emit(name, value, "16-server device-loop metrics snapshot", unit=unit)
