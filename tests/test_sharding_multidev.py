"""Multi-device numerical equivalence: the sharded model (8 fake devices,
TP=4 x DP=2, all the shard_map paths active) must match the single-device
model bit-for-bit-ish. Runs in a subprocess so the main pytest process keeps
its single device."""
import os
import subprocess
import sys
import textwrap

import pytest
from conftest import requires_native_shard_map

PROBE = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import dataclasses
    import jax, jax.numpy as jnp, numpy as np
    from repro.configs import SMOKES, MeshConfig, sharding_rules
    from repro.models import build_model, materialize
    from repro.models import layers as ML
    from repro.distributed.sharding import named, param_specs, batch_specs
    from jax.sharding import NamedSharding, PartitionSpec

    failures = []
    for arch in ["llama3.2-3b", "moonshot-v1-16b-a3b", "rwkv6-7b", "starcoder2-7b"]:
        cfg = SMOKES[arch]
        # smoke dims must divide the tiny mesh: d_ff=128/4, heads 4/4, E 4/4
        cfg = dataclasses.replace(cfg, moe_capacity_factor=8.0) if cfg.moe_experts else cfg
        model = build_model(cfg)
        rng = jax.random.PRNGKey(0)
        params = materialize(model.param_infos(), rng)
        B, S = 4, 32
        batch = {
            "tokens": jax.random.randint(rng, (B, S), 0, cfg.vocab),
            "labels": jax.random.randint(rng, (B, S), 0, cfg.vocab),
        }
        loss_ref = float(model.loss(params, batch)[0])

        mesh = jax.make_mesh((2, 4), ("data", "model"))
        mesh_cfg = MeshConfig(data=2, model=4)
        rules = sharding_rules(cfg, mesh_cfg)
        p_sh = named(mesh, param_specs(model, mesh_cfg))
        params_sharded = jax.tree_util.tree_map(
            lambda a, s: jax.device_put(a, s), params, p_sh)
        b_sh = named(mesh, batch_specs(model, mesh_cfg,
                     {k: jax.ShapeDtypeStruct(v.shape, v.dtype) for k, v in batch.items()}))
        batch_sharded = {k: jax.device_put(v, b_sh[k]) for k, v in batch.items()}

        with mesh, ML.activation_sharding(mesh, rules):
            loss_sh = float(jax.jit(lambda p, b: model.loss(p, b)[0])(params_sharded, batch_sharded))
        err = abs(loss_sh - loss_ref) / max(abs(loss_ref), 1e-9)
        print(f"{arch}: ref={loss_ref:.5f} sharded={loss_sh:.5f} rel={err:.2e}")
        if err > 2e-2:
            failures.append(arch)
    assert not failures, failures
    print("SHARDED-EQUIVALENCE OK")
    """
)


@pytest.mark.slow
@requires_native_shard_map
def test_sharded_loss_matches_single_device():
    env = dict(os.environ)
    env["PYTHONPATH"] = env.get("PYTHONPATH", "") + os.pathsep + os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src"))
    # pin the subprocess to the host platform: device-count forcing is
    # CPU-only and probing for a TPU runtime hangs in CI sandboxes
    env["JAX_PLATFORMS"] = "cpu"
    r = subprocess.run([sys.executable, "-c", PROBE], capture_output=True, text=True,
                       env=env, timeout=560)
    assert "SHARDED-EQUIVALENCE OK" in r.stdout, r.stdout + "\n" + r.stderr[-3000:]
