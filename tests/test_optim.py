"""Optimizers, schedules, gradient utilities."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st

from repro.optim import (
    OptConfig,
    adafactor_init,
    adafactor_update,
    adamw8bit_init,
    adamw8bit_update,
    adamw_init,
    adamw_update,
    bucket_by_size,
    warmup_cosine,
)
from repro.optim.adamw import _dq8, _q8


def _scalar_adamw_reference(p, g, m, v, step, lr, cfg):
    """Textbook AdamW on scalars (the oracle)."""
    m2 = cfg.b1 * m + (1 - cfg.b1) * g
    v2 = cfg.b2 * v + (1 - cfg.b2) * g * g
    mh = m2 / (1 - cfg.b1**step)
    vh = v2 / (1 - cfg.b2**step)
    return p - lr * (mh / (np.sqrt(vh) + cfg.eps) + cfg.weight_decay * p), m2, v2


def test_adamw_matches_scalar_reference():
    cfg = OptConfig(grad_clip=1e9)  # disable clipping for the comparison
    params = {"w": jnp.asarray([0.5, -1.0, 2.0])}
    grads = {"w": jnp.asarray([0.1, -0.2, 0.05])}
    state = adamw_init(params)
    lr = 1e-2
    new_p, new_s, _ = adamw_update(params, grads, state, lr, cfg)
    for i in range(3):
        want, _, _ = _scalar_adamw_reference(
            0.5 if i == 0 else (-1.0 if i == 1 else 2.0),
            [0.1, -0.2, 0.05][i], 0.0, 0.0, 1, lr, cfg)
        assert float(new_p["w"][i]) == pytest.approx(want, rel=1e-5)


def test_adamw_clipping_bounds_update():
    cfg = OptConfig(grad_clip=1.0, weight_decay=0.0)
    params = {"w": jnp.zeros(4)}
    grads = {"w": jnp.full(4, 100.0)}
    state = adamw_init(params)
    new_p, _, stats = adamw_update(params, grads, state, 1.0, cfg)
    assert float(stats["grad_norm"]) == pytest.approx(200.0)
    # post-clip effective gradient has norm 1 -> first Adam step is ~ -lr
    assert np.all(np.abs(np.asarray(new_p["w"])) <= 1.0 + 1e-5)


def test_adamw8bit_tracks_fp32_adamw():
    """Over a short trajectory the 8-bit optimizer follows fp32 AdamW: the
    accumulated updates point the same way (cosine > 0.95) and the absolute
    divergence stays within a few lr units (int8 v is coarse early on)."""
    cfg = OptConfig(grad_clip=1e9, weight_decay=0.0)
    rng = np.random.default_rng(0)
    p0 = jnp.asarray(rng.normal(size=512).astype(np.float32))
    params32, params8 = {"w": p0}, {"w": p0}
    s32, s8 = adamw_init(params32), adamw8bit_init(params8)
    lr = 1e-2
    for t in range(5):
        g = {"w": jnp.asarray(rng.normal(size=512).astype(np.float32) * 0.1)}
        params32, s32, _ = adamw_update(params32, g, s32, lr, cfg)
        params8, s8, _ = adamw8bit_update(params8, g, s8, lr, cfg)
    d32 = np.asarray(params32["w"]) - np.asarray(p0)
    d8 = np.asarray(params8["w"]) - np.asarray(p0)
    cos = float(np.dot(d32, d8) / (np.linalg.norm(d32) * np.linalg.norm(d8)))
    assert cos > 0.95
    assert np.abs(d32 - d8).max() < 5 * lr


def test_adafactor_state_is_factored():
    params = {"w": jnp.zeros((64, 32, 16))}
    state = adafactor_init(params)
    assert state["state"]["w"]["vr"].shape == (64, 32)
    assert state["state"]["w"]["vc"].shape == (16,)
    g = {"w": jnp.ones((64, 32, 16))}
    new_p, new_s, _ = adafactor_update(params, g, state, 1e-2, OptConfig())
    assert np.all(np.isfinite(np.asarray(new_p["w"])))
    assert float(jnp.abs(new_p["w"]).max()) > 0


def test_adafactor_moves_toward_minimum():
    cfg = OptConfig(weight_decay=0.0)
    params = {"w": jnp.full((8, 8), 5.0)}
    state = adafactor_init(params)
    for _ in range(50):
        g = {"w": 2 * params["w"]}  # d/dw ||w||^2
        params, state, _ = adafactor_update(params, g, state, 0.1, cfg)
    assert float(jnp.abs(params["w"]).mean()) < 2.0


@settings(max_examples=30, deadline=None)
@given(st.lists(st.floats(-100, 100), min_size=8, max_size=64))
def test_q8_roundtrip_error_bounded(xs):
    x = jnp.asarray(np.array(xs, np.float32))
    q, s = _q8(x, block=16)
    back = _dq8(q, s, x.shape, 16)
    scale = max(abs(min(xs)), abs(max(xs)), 1e-12)
    assert float(jnp.abs(back - x).max()) <= scale / 127.0 + 1e-6


def test_warmup_cosine_shape():
    lrs = [float(warmup_cosine(s, peak_lr=1.0, warmup_steps=10, total_steps=100))
           for s in range(100)]
    assert lrs[0] == 0.0
    assert max(lrs) == pytest.approx(1.0, abs=0.02)
    assert np.argmax(lrs) == 10
    assert lrs[-1] < 0.2  # decayed
    assert lrs[-1] >= 0.099  # floor at final_frac * peak


def test_bucket_by_size_preserves_all_leaves():
    tree = {"a": jnp.zeros(1000), "b": jnp.zeros(2000), "c": jnp.zeros(10)}
    buckets = bucket_by_size(tree, bucket_bytes=6000)
    flat = [p for b in buckets for p in b]
    assert len(flat) == 3
    assert len(buckets) >= 2
