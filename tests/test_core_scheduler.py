"""Online scheduler + makespan analysis (paper §V, Fig 5)."""
import numpy as np
import pytest

from repro.core import (
    M1,
    ClusterState,
    OnlineScheduler,
    Workload,
    makespan_consolidated,
    makespan_sequential,
    profile_pairwise_fast,
    simulate_corun,
    snap_to_grid,
)
from repro.core.units import KB, MB


def test_fig5_lemma_consolidation_beats_sequential_under_50pct():
    """Fig 5: if every D_i < 0.5 then consolidating beats running sequentially."""
    ws = [Workload(fs=512 * KB, rs=64 * KB)] * 3
    res = simulate_corun(M1, ws)
    assert res.max_degradation < 0.5
    assert makespan_consolidated(M1, ws) < makespan_sequential(M1, ws)


def test_fig5_lemma_violation_means_sequential_wins():
    """Fig 5 second scenario: past 50% degradation, sequential can win."""
    ws = [Workload(fs=2 * MB, rs=512 * KB)] * 6  # far past the TDP
    res = simulate_corun(M1, ws)
    assert res.max_degradation > 0.5
    assert makespan_consolidated(M1, ws) > makespan_sequential(M1, ws)


def _one_server_state():
    D = profile_pairwise_fast(M1)
    return ClusterState.empty([M1], D, alpha=1.3)


def test_online_scheduler_completes_all_work():
    state = _one_server_state()
    sched = OnlineScheduler(state)
    ws = [snap_to_grid(Workload(fs=512 * KB, rs=64 * KB)) for _ in range(3)]
    result = sched.run([(0.0, ws[0]), (0.0, ws[1]), (0.01, ws[2])])
    finish_events = [e for e in result.events if e.kind == "finish"]
    assert len(finish_events) == 3
    assert result.makespan > 0


def test_online_scheduler_queues_then_places():
    """§V: a queued workload is placed 'upon completion of another workload'."""
    state = _one_server_state()
    sched = OnlineScheduler(state)
    heavy = snap_to_grid(Workload(fs=64 * MB, rs=512 * KB))
    arrivals = [(0.0, heavy)] * 5
    result = sched.run(arrivals)
    queue_events = [e for e in result.events if e.kind == "queue"]
    finish_events = [e for e in result.events if e.kind == "finish"]
    assert len(queue_events) >= 1  # at least one had to wait
    assert len(finish_events) == 5  # but everything eventually ran
    # placements after queueing happen only at/after a finish time
    placed_after_queue = [e for e in result.events if e.kind == "place"][len(arrivals) - len(queue_events):]
    first_finish = min(e.time for e in finish_events)
    for e in placed_after_queue:
        assert e.time >= first_finish - 1e-9
