"""TDP prediction (Eqns 1-2), pairwise profiling and the additive model
(Eqn 3) -- paper §IV, Figures 3-4 and 6."""
import numpy as np
import pytest
from _hyp import given, settings, st

from repro.core import (
    M1,
    Workload,
    predict_degradations,
    predict_tdp_n,
    profile_pairwise,
    profile_pairwise_fast,
    simulate_corun,
    tdp_lhs,
    tdp_lhs_naive,
)
from repro.core.simulator import throughput_after_cache
from repro.core.units import KB, MB
from repro.core.workload import grid_types


def test_tdp_worked_example():
    """Paper §IV.A: N=4, RS=256KB, FS=1280KB -> 4 x 1536KB = 6MB = M1's LLC."""
    assert predict_tdp_n(M1, 256 * KB, 1280 * KB) == pytest.approx(4.0)


def test_eqn2_excludes_large_files():
    """FS > CacheSize does not compete for the LLC (Eqn 2 vs Eqn 1)."""
    small = Workload(fs=1 * MB, rs=64 * KB)
    large = Workload(fs=64 * MB, rs=64 * KB)
    assert tdp_lhs(M1, [small, large]) == pytest.approx(small.rs + small.fs + large.rs)
    assert tdp_lhs_naive([small, large]) > tdp_lhs(M1, [small, large])


def test_cliff_at_physical_tolerance():
    """Fig 3-4a: moderate slope until the physical TDP (~1.29x LLC), sharp
    drop after -- the basis for the paper's alpha ~= 1.3 calibration."""
    w = Workload(fs=1280 * KB, rs=256 * KB)
    degs = [simulate_corun(M1, [w] * n).degradations[0] for n in range(1, 8)]
    # below the cliff: gentle (all < 10%); at the cliff: catastrophic (> 50%)
    assert all(d < 0.10 for d in degs[:5])
    assert degs[5] > 0.5  # N=6: 6 x 1536KB = 9MB > 7.76MB tolerance


def test_fig6_cache_loss_over_50pct_for_rs_above_8k():
    """Fig 6 / §V: losing the LLC costs > 50% throughput for RS > 8KB."""
    for rs in (16 * KB, 64 * KB, 256 * KB, 512 * KB):
        w = Workload(fs=2 * MB, rs=rs)
        keep = throughput_after_cache(M1, w, False)
        lose = throughput_after_cache(M1, w, True)
        assert 1 - lose / keep > 0.5, rs
    # and below 8KB the cliff is softer (overhead-dominated regime)
    w = Workload(fs=2 * MB, rs=1 * KB)
    assert 1 - throughput_after_cache(M1, w, True) / throughput_after_cache(M1, w, False) < 0.5


def test_fast_profile_matches_scalar():
    sub = [Workload(fs=f, rs=r) for r in (4 * KB, 64 * KB, 512 * KB)
           for f in (256 * KB, 2 * MB, 16 * MB, 256 * MB)]
    Ds = profile_pairwise(M1, sub)
    Df = profile_pairwise_fast(M1, sub)
    np.testing.assert_allclose(Ds, Df, atol=1e-12)


def test_pairwise_is_exact_for_pairs():
    """D_{i,j} is *defined* by pair runs, so the additive model is exact at N=2."""
    D = profile_pairwise_fast(M1)
    wi = Workload(fs=4 * MB, rs=64 * KB)
    wj = Workload(fs=512 * KB, rs=16 * KB)
    pred = predict_degradations(D, [wi, wj])
    act = simulate_corun(M1, [wi, wj]).degradations
    np.testing.assert_allclose(pred, act, atol=1e-9)


def test_additive_model_reasonable_at_n3():
    """Figures 3-4b: the additive model predicts N-way degradation with
    'reasonable accuracy' (paper's own wording) -- we require <= 10pp error
    in the pre-saturation regime."""
    D = profile_pairwise_fast(M1)
    for fs, rs in ((512 * KB, 64 * KB), (1 * MB, 32 * KB)):
        ws = [Workload(fs=fs, rs=rs)] * 3
        pred = predict_degradations(D, ws)
        act = np.array(simulate_corun(M1, ws).degradations)
        assert np.abs(pred - act).max() < 0.10


def test_profiling_grid_size_matches_paper():
    """§VIII: 10 RSs x 23 FSs = 230 types -> 52_900 pair experiments."""
    types = grid_types()
    assert len(types) == 230
    assert len(types) ** 2 == 52_900


@settings(max_examples=25, deadline=None)
@given(
    n=st.integers(1, 6),
    rs=st.sampled_from([4 * KB, 64 * KB, 512 * KB]),
    fs=st.sampled_from([256 * KB, 2 * MB, 32 * MB]),
)
def test_degradation_monotone_in_n(n, rs, fs):
    """§IV.A: increasing N always increases degradation."""
    w = Workload(fs=fs, rs=rs)
    d_n = simulate_corun(M1, [w] * n).degradations[0]
    d_n1 = simulate_corun(M1, [w] * (n + 1)).degradations[0]
    assert d_n1 >= d_n - 1e-12
    assert 0.0 <= d_n < 1.0
