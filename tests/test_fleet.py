"""Fleet health subsystem: pooling, CUSUM detection, eviction (ISSUE 5).

Contracts under test:
  * CUSUM chunk-invariance -- split-vs-merged residual batches leave the
    detector state bitwise equal (the PR 4 EWMA contract, extended to the
    detector's sequential scan).
  * Pool split-then-reseed equivalence -- a split-out server starts from
    exactly the pool posterior and diverges only with future telemetry.
  * End-to-end eviction -- a ``gradual_decay``-to-zero server is detected,
    masked out of candidate scoring (zero placements after detection), its
    in-flight work requeued, and the fault-tolerance plane notified.
  * One eviction threshold -- the straggler monitor and the fleet detector
    route through ``criteria.eviction_rate_floor``.
"""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.configs.base import MeshConfig
from repro.core import (
    M1,
    AdaptiveEngine,
    ConsolidationEngine,
    Workload,
    snap_to_grid,
)
from repro.core.criteria import DEGRADATION_LIMIT, eviction_rate_floor
from repro.core.workload import FS_GRID, RS_GRID
from repro.distributed.fault_tolerance import HeartbeatMonitor
from repro.fleet import CusumState, DriftDetector, FleetController, PooledEstimatorBank
from repro.telemetry import (
    ObservationLog,
    StreamingEstimator,
    block_from_log,
    gradual_decay,
    stochastic_congestion,
)

from _hyp import given, settings, st

T = len(RS_GRID) * len(FS_GRID)


# --- synthetic observation blocks --------------------------------------------

def _obs_log(rng, m=3, B=48, shift=None, types=6):
    """A synthetic observation batch over ``m`` servers.

    ``shift`` [m] adds a per-server offset to the log-rate -- the divergence
    the detector is supposed to see.
    """
    t = rng.integers(0, types, B).astype(np.int32)
    srv = rng.integers(0, m, B).astype(np.int32)
    co = np.zeros((B, T))
    y = np.zeros(B)
    for b in range(B):
        for c in rng.integers(0, types, rng.integers(0, 3)):
            co[b, c] += 1.0
        y[b] = -0.1 * co[b].sum() + rng.normal(0.0, 0.01)
        if shift is not None:
            y[b] += shift[srv[b]]
    return ObservationLog(
        wtype=t, server=srv, duration=np.ones(B), rate=np.exp(y),
        geo_rate=np.exp(y), co_counts=co, lost_frac=np.zeros(B))


def _slice_block(block, lo, hi):
    return type(block)(*(np.asarray(a)[lo:hi] for a in block))


def _rand_refs(rng, p):
    log_b = jnp.asarray(rng.normal(0.0, 0.2, (p, T)), jnp.float32)
    L_t = jnp.asarray(rng.normal(-0.05, 0.02, (p, T, T)), jnp.float32)
    return log_b, L_t


# --- CUSUM chunk invariance ---------------------------------------------------

def _check_cusum_chunk_invariance(seed, splits=4):
    """Split-vs-merged blocks leave the detector state bitwise equal.

    The detector folds rows strictly in stream order (a sequential scan, no
    reassociation), so chunking must not change a single bit of the state --
    the same contract the PR 4 exposure-based EWMA test pins for the
    estimator's confidence state.
    """
    rng = np.random.default_rng(seed)
    m = 3
    log_b, L_t = _rand_refs(rng, m)
    row_map = np.asarray([0, 0, 2], np.int32)  # a pool of two + a solo row

    log = _obs_log(rng, m=m, B=64, shift=np.array([0.0, -0.4, 0.1]))
    block = block_from_log(log)
    # void a few rows and push one server out of range: the masks must drop
    # identical rows on both paths
    scalars = np.asarray(block.scalars).copy()
    scalars[::11, 3] = 0.0
    ints = np.asarray(block.ints).copy()
    ints[::13, 1] = m + 5
    block = block._replace(scalars=jnp.asarray(scalars), ints=jnp.asarray(ints))

    merged = DriftDetector(m=m)
    split = DriftDetector(m=m)
    merged.update(block, log_b, L_t, row_map)
    bounds = np.linspace(0, 64, splits + 1).astype(int)
    for lo, hi in zip(bounds[:-1], bounds[1:]):
        split.update(_slice_block(block, lo, hi), log_b, L_t, row_map)

    for a, b, name in zip(merged.state, split.state, CusumState._fields):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                      err_msg=f"detector state {name}")


def test_cusum_chunk_invariance():
    _check_cusum_chunk_invariance(0)


@settings(max_examples=8, deadline=None)
@given(st.integers(min_value=1, max_value=10_000))
def test_cusum_chunk_invariance_property(seed):
    _check_cusum_chunk_invariance(seed, splits=1 + seed % 6)


def test_cusum_empty_block_is_identity():
    rng = np.random.default_rng(1)
    det = DriftDetector(m=2)
    log_b, L_t = _rand_refs(rng, 2)
    before = [np.asarray(a).copy() for a in det.state]
    used = det.update(_slice_block(block_from_log(_obs_log(rng, m=2)), 0, 0),
                      log_b, L_t, np.arange(2, dtype=np.int32))
    assert used == 0
    for a, b in zip(before, det.state):
        np.testing.assert_array_equal(a, np.asarray(b))


def test_cusum_detects_divergence_and_failure_level():
    """A shifted server fires the split flag; its (raw) residual level
    tracks the shift while healthy siblings stay quiet."""
    rng = np.random.default_rng(2)
    m = 4
    log_b, L_t = _rand_refs(rng, m)
    row_map = np.zeros(m, np.int32)  # one pool
    det = DriftDetector(m=m)
    # healthy warm-in: no flags
    for _ in range(4):
        det.update(block_from_log(_obs_log(rng, m=m)), log_b, L_t, row_map)
    assert not det.split_flags().any()
    assert not det.fail_flags().any()
    # server 3 collapses to ~25% of its predicted rate: its pool-centered
    # CUSUM fires within one batch, long before the healthy siblings (whose
    # centered residuals carry only the dragged pool mean, ~shift/m)
    shift = np.array([0.0, 0.0, 0.0, np.log(0.25)])
    det.update(block_from_log(_obs_log(rng, m=m, shift=shift)),
               log_b, L_t, row_map)
    assert det.split_flags()[3] and not det.split_flags()[:3].any()
    # more evidence: the raw level approximates the shift -> failure flag
    # (raw, not centered: siblings stay clear even once their CUSUM drifts)
    for _ in range(2):
        det.update(block_from_log(_obs_log(rng, m=m, shift=shift)),
                   log_b, L_t, row_map)
    assert det.level_hat()[3] == pytest.approx(np.log(0.25), abs=0.35)
    assert det.fail_flags()[3] and not det.fail_flags()[:3].any()


# --- pooling ------------------------------------------------------------------

def _estimators(n, **overrides):
    kw = dict(T=T, prior_D=0.0, lr=0.5, decay=0.995, confidence_floor=2.0,
              scatter="jnp")
    kw.update(overrides)
    return [StreamingEstimator(**kw) for _ in range(n)]


def test_pooled_bank_routes_members_to_one_row():
    """A pooled update equals one estimator consuming every member's rows --
    the ~m x warm-up is literally shared statistics."""
    rng = np.random.default_rng(3)
    logs = [_obs_log(rng, m=3, B=64) for _ in range(4)]

    pool = PooledEstimatorBank(_estimators(3), pools=["a", "a", "a"])
    solo = _estimators(1)[0]
    for log in logs:
        used_p = pool.update_device(block_from_log(log))
        merged = ObservationLog(**{**{f: getattr(log, f) for f in
                                      ("wtype", "duration", "rate", "geo_rate",
                                       "co_counts", "lost_frac")},
                                   "server": np.zeros(len(log), np.int32)})
        used_s = solo.update_device(block_from_log(merged))
        assert used_p == used_s
    lead = pool.estimator_for(2)  # all members resolve to the leader row
    assert lead is pool.estimator_for(0) is pool.estimator_for(1)
    np.testing.assert_allclose(lead.L, solo.L, atol=1e-6)
    np.testing.assert_allclose(lead.log_b, solo.log_b, atol=1e-6)
    assert lead.n_obs == solo.n_obs


def test_pool_split_then_reseed_equivalence():
    """The split-out row carries exactly the pool posterior at split time,
    then diverges only with its own telemetry (the pool stays untouched)."""
    rng = np.random.default_rng(4)
    pool = PooledEstimatorBank(_estimators(3), pools=[0, 0, 0])
    for _ in range(5):
        pool.update_device(block_from_log(_obs_log(rng, m=3)))

    snap = pool.estimator_for(2).export_posterior()
    assert pool.split(2) and pool.members(2) == (2,)
    assert pool.members(0) == (0, 1)
    est2, est0 = pool.estimator_for(2), pool.estimator_for(0)
    assert est2 is not est0
    # seeded from the pool posterior: estimates AND confidence match
    np.testing.assert_allclose(est2.L, est0.L, atol=1e-7)
    np.testing.assert_allclose(est2.n_pair, est0.n_pair, atol=1e-7)
    np.testing.assert_allclose(np.asarray(snap.log_b), est2.log_b, atol=1e-6)

    # rows for server 2 now update only row 2; the pool is untouched
    log = _obs_log(rng, m=3, shift=np.array([0.0, 0.0, -0.5]))
    only2 = log.select(log.server == 2)
    pool_L_before = est0.L.copy()
    pool.update_device(block_from_log(only2))
    np.testing.assert_allclose(pool.estimator_for(0).L, pool_L_before,
                               atol=1e-7)
    assert not np.allclose(pool.estimator_for(2).L, pool_L_before, atol=1e-4)

    # splitting a solo (or already-split) server is a no-op
    assert not pool.split(2)

    # seed_from restores an exported posterior exactly
    est2.seed_from(snap)
    np.testing.assert_allclose(est2.L, est0.L, atol=1e-7)


def test_pool_leader_split_migrates_pool():
    rng = np.random.default_rng(5)
    pool = PooledEstimatorBank(_estimators(3), pools=[0, 0, 0])
    pool.update_device(block_from_log(_obs_log(rng, m=3)))
    lead_L = pool.estimator_for(0).L.copy()
    assert pool.split(0)  # the leader leaves; the pool migrates to row 1
    assert pool.last_migration == (0, 1)  # recorded for row-keyed consumers
    assert pool.members(0) == (0,) and pool.members(1) == (1, 2)
    np.testing.assert_allclose(pool.estimator_for(1).L, lead_L, atol=1e-7)
    np.testing.assert_allclose(pool.estimator_for(0).L, lead_L, atol=1e-7)
    # a non-leader split records no migration
    assert pool.split(2) is False or pool.last_migration is None

    # the detector moves its pool-centering EWMA along the same migration
    det = DriftDetector(m=3)
    log_b, L_t = _rand_refs(rng, 3)
    det.update(block_from_log(_obs_log(rng, m=3)), log_b, L_t,
               np.zeros(3, np.int32))
    lvl0 = float(np.asarray(det.state.pool_level)[0])
    assert lvl0 != 0.0
    det.move_pool_row(0, 1)
    moved = np.asarray(det.state.pool_level)
    assert moved[1] == lvl0 and moved[0] == 0.0


def test_pool_drop_stops_routing_but_keeps_reads():
    rng = np.random.default_rng(6)
    pool = PooledEstimatorBank(_estimators(3), pools=[0, 0, 0])
    blk = block_from_log(_obs_log(rng, m=3, B=60))
    pool.update_device(blk)
    pool.drop(1)
    assert pool.last_migration is None  # non-leader: pool row untouched
    est = pool.estimator_for(1)  # reads still resolve -- to the live pool row
    assert est is pool.estimator_for(0)
    used = pool.update_device(block_from_log(_obs_log(rng, m=3, B=60)))
    assert used < 60  # server 1's rows were dropped
    assert pool.members(1) == ()
    assert est is pool.estimator_for(1)
    # dropping the leader migrates the survivors first
    pool.drop(0)
    assert pool.last_migration == (0, 2) and pool.members(2) == (2,)


# --- candidate-scoring mask ---------------------------------------------------

def _mini_trace(seed, n=12):
    rng = np.random.default_rng(seed)
    out, t = [], 0.0
    for _ in range(n):
        fs = float(rng.choice(FS_GRID[10:13]))
        w = snap_to_grid(Workload(fs=fs, rs=float(rng.choice(RS_GRID[5:7])),
                                  data_total=fs * 4))
        t += float(rng.exponential(1e-5))
        out.append((t, w))
    return out


def test_engine_active_mask_excludes_server():
    engine = ConsolidationEngine([M1, M1])
    trace = _mini_trace(7)
    res_all = engine.run(trace, backend="jax")
    assert any(p == 1 for p in res_all.placements)  # both servers in play

    engine.set_active([True, False])
    res_masked = engine.run(trace, backend="jax")
    assert all(p != 1 for p in res_masked.placements)

    # numpy oracle has no mask: refuse rather than silently ignore
    with pytest.raises(ValueError, match="mask"):
        engine.run(trace, backend="numpy")

    # restoring the mask restores the placements
    engine.set_active([True, True])
    assert engine.run(trace, backend="jax").placements == res_all.placements

    # the constructor takes the mask directly (one cluster build), and
    # 'auto' resolves a masked engine to jax even on a short trace
    ctor = ConsolidationEngine([M1, M1], active=[True, False])
    res_ctor = ctor.run(trace)
    assert res_ctor.backend == "jax"
    assert all(p != 1 for p in res_ctor.placements)

    # every scoring consumer honours the mask: local-search relocations
    # never target the evicted server, and assignments on it are infeasible
    from repro.core import evaluate_assignment, local_search_jax

    cluster = ctor.cluster
    counts = jnp.zeros((2, cluster.T), jnp.float32).at[0, 5].set(3.0)
    moved, n_moves = local_search_jax(cluster, counts)
    assert float(np.asarray(moved)[1].sum()) == 0.0
    wtypes = jnp.asarray([5], jnp.int32)
    _, ok_bad = evaluate_assignment(cluster, jnp.zeros_like(counts), wtypes,
                                    jnp.asarray([1]))
    _, ok_good = evaluate_assignment(cluster, jnp.zeros_like(counts), wtypes,
                                     jnp.asarray([0]))
    assert bool(ok_good) and not bool(ok_bad)


# --- one eviction threshold ---------------------------------------------------

def test_eviction_threshold_is_shared():
    """Straggler policy and the fleet detector read one conversion of the
    Eqn-4 limit; the boundary (exactly 2x slower) evicts on both."""
    assert eviction_rate_floor() == pytest.approx(1.0 - DEGRADATION_LIMIT)
    assert DriftDetector(m=2).fail_floor == eviction_rate_floor()
    assert FleetController().fail_floor == eviction_rate_floor()
    with pytest.raises(ValueError):
        eviction_rate_floor(1.5)

    mon = HeartbeatMonitor(n_hosts=3)
    for h in range(2):
        for t in range(10):
            mon.heartbeat(h, now=t, step_time=1.0)
    for t in range(10):
        mon.heartbeat(2, now=t, step_time=2.0)  # exactly the 2x boundary
    assert mon.stragglers() == [2]
    assert mon.stragglers(limit=0.6) == []  # laxer limit: 2x is tolerable


# --- drift scenario -----------------------------------------------------------

def test_stochastic_congestion_schedule():
    base = [M1, M1, M1]
    sched = stochastic_congestion(base, rate=0.5, seed=9, segments=6)
    assert sched.events  # a 50% rate over 18 draws congests something
    twin = stochastic_congestion(base, rate=0.5, seed=9, segments=6)
    assert sched == twin  # deterministic in the seed
    assert all(0 <= ev.segment < 6 for ev in sched.events)
    segs = [ev.segment for ev in sched.events]
    assert segs == sorted(segs)  # ordered: later events override earlier
    # congestion events actually move the spec; clears restore the base
    for ev in sched.events:
        if ":cong" in ev.spec.name:
            assert ev.spec.shared_bw < base[ev.server].shared_bw
        else:
            assert ev.spec == base[ev.server]
    assert stochastic_congestion(base, rate=0.0, seed=9).events == ()
    # restriction keeps excluded servers un-touched
    only12 = stochastic_congestion(base, rate=0.9, seed=9, servers=[1, 2])
    assert {ev.server for ev in only12.events} <= {1, 2}
    with pytest.raises(ValueError):
        stochastic_congestion(base, rate=1.5)


# --- end to end: decay -> detect -> evict -> requeue --------------------------

def test_gradual_decay_eviction_end_to_end():
    """The ISSUE 5 acceptance trace: a server decaying toward zero is
    evicted, receives no placements afterwards, its in-flight work is
    requeued, and the fault-tolerance plane is told."""
    rng = np.random.default_rng(11)
    seg, t = [], 0.0
    for _ in range(14):
        fs = float(rng.choice(FS_GRID[10:14]))
        w = snap_to_grid(Workload(fs=fs, rs=float(rng.choice(RS_GRID[5:8])),
                                  data_total=fs * 6))
        t += float(rng.exponential(2e-5))
        seg.append((t, w))
    segments, failing = 6, 1
    arrivals = [(t + k * 10.0, w) for k in range(segments) for t, w in seg]
    servers = [M1, M1, M1]
    drift = gradual_decay(servers, server=failing, rate=0.65, start=1,
                          segments=segments)
    fleet = FleetController(mesh=MeshConfig())
    eng = AdaptiveEngine(servers, prior=0.0, decay=0.997, drift=drift,
                         fleet=fleet)
    assert eng.stream and eng.bank is None  # the controller owns the bank
    res = eng.run(arrivals, segments=segments)

    evicts = fleet.events_of("evict")
    assert len(evicts) == 1 and evicts[0].server == failing
    k_ev = evicts[0].segment
    assert k_ev < segments - 1  # detected with segments to spare

    # the result records the event where it fired
    assert any(ev.kind == "evict" for ev in res.health[k_ev])
    # zero placements on the failing server after detection
    after = [p for r in res.segments[k_ev + 1:] for p in r.placements]
    assert after and all(p != failing for p in after)
    # in-flight work on the failing server was requeued into the next chunk
    on_failing = sum(1 for p in res.segments[k_ev].placements if p == failing)
    assert len(res.segments[k_ev + 1].placements) == len(seg) + on_failing
    # fault-tolerance plane: marked dead + a composed remesh plan
    assert not fleet.monitor.hosts[failing].alive
    assert fleet.monitor.hosts[1 - failing].alive
    assert len(fleet.plans) == 1 and fleet.plans[0].lost_fraction > 0
    assert fleet.active_mask().tolist() == [True, False, True]
    # the estimators keep serving reads for the evicted server
    assert fleet.current_D()[failing].shape == (T, T)


def test_never_evicts_last_server():
    """A failing sibling is evicted; the lone survivor never is -- a sick
    fleet still beats an empty one (and the pooled base route cannot fire
    for a shared row, so a pool never evicts wholesale)."""
    rng = np.random.default_rng(12)
    fleet = FleetController(warmup_segments=0)
    fleet.bind([M1, M1], _estimators(2))
    for k in range(4):
        fleet.observe(block_from_log(
            _obs_log(rng, m=2, shift=np.array([0.0, -2.0]))), segment=k)
    assert fleet.evicted() == (1,)
    for k in range(4, 10):  # now the survivor collapses too: still kept
        fleet.observe(block_from_log(
            _obs_log(rng, m=2, shift=np.array([-2.0, -2.0]))), segment=k)
    assert fleet.evicted() == (1,)
    assert fleet.active_mask().tolist() == [True, False]


def test_warmup_counts_controller_segments_not_caller_indices():
    """Burn-in happens once per controller lifetime: a second run that
    numbers its segments from 0 again must not re-trigger it (the model is
    already warm, and due actions must not be delayed or wiped)."""
    rng = np.random.default_rng(13)
    fleet = FleetController(warmup_segments=2)
    fleet.bind([M1, M1, M1], _estimators(3))
    for k in range(2):  # first run: burn-in consumed
        fleet.observe(block_from_log(_obs_log(rng, m=3)), segment=k)
    # "second run" restarts segment numbering at 0: actions still fire
    for k in range(3):
        fleet.observe(block_from_log(
            _obs_log(rng, m=3, shift=np.array([0.0, 0.0, -2.0]))), segment=k)
        if fleet.evicted():
            break
    assert fleet.evicted() == (2,)


def test_fleet_controller_binds_once():
    fleet = FleetController()
    AdaptiveEngine([M1, M1], fleet=fleet)
    with pytest.raises(RuntimeError, match="bound"):
        AdaptiveEngine([M1, M1], fleet=fleet)
    unbound = FleetController()
    with pytest.raises(RuntimeError, match="bind"):
        unbound.active_mask()
