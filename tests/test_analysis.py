"""Tests for the device-purity auditor (``repro.analysis``).

Three layers: golden jaxpr snapshots of the registered Pallas entry points
(a changed primitive histogram means the lowering changed -- bump the
snapshot deliberately, not accidentally), unit tests of each AST lint rule
on synthetic snippets, and the end-to-end contracts the CI gate stands on
(repo is finding-free vs the checked-in baseline, the 3-segment adaptive
rerun compiles nothing).
"""
import ast
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis import Finding, load_baseline, new_findings, run_all
from repro.analysis.ast_rules import (
    _RingViewLinter,
    _TaintLinter,
    discover_contexts,
)
from repro.analysis.jaxpr_audit import (
    PALLAS_COVERAGE,
    REGISTRY,
    TIER_DEVICE,
    VMEM_HEADROOM,
    VMEM_LIMIT_BYTES,
    audit_entry,
    get_entry,
    primitive_counts,
)
from repro.analysis.retrace import CompileCacheGuard, run_retrace_audit


# -- golden jaxpr snapshots ----------------------------------------------------
# Full recursive primitive histograms of the three consolidation-loop Pallas
# entries, traced at the registry's production shapes (T = 230). These are
# *snapshots*: a diff here is not necessarily a bug, but it is always a
# lowering change on a hot path and must be reviewed (then re-recorded).

GOLDEN_PRIMITIVES = {
    "kernels.consolidation.consolidation_scores": {
        "add": 4, "broadcast_in_dim": 7, "concatenate": 2,
        "convert_element_type": 6, "div": 1, "dot_general": 1, "eq": 1,
        "gather": 1, "get": 7, "gt": 1, "iota": 3, "lt": 2, "max": 1,
        "min": 1, "mul": 2, "pallas_call": 1, "pjit": 4, "reduce_max": 1,
        "reduce_sum": 2, "reshape": 2, "select_n": 3, "slice": 1,
        "squeeze": 1, "sub": 1, "swap": 2,
    },
    "kernels.telemetry.pair_scatter": {
        "add": 3, "broadcast_in_dim": 5, "cond": 1, "convert_element_type": 2,
        "dot_general": 3, "eq": 2, "get": 6, "iota": 1, "mul": 2,
        "pallas_call": 1, "pjit": 1, "program_id": 1, "reshape": 1,
        "slice": 2, "squeeze": 2, "swap": 5, "transpose": 1,
    },
    "engine.make_scorer[pallas]": {
        "add": 4, "broadcast_in_dim": 8, "concatenate": 2,
        "convert_element_type": 6, "div": 1, "dot_general": 1, "eq": 1,
        "gather": 1, "get": 7, "gt": 1, "iota": 3, "lt": 2, "max": 1,
        "min": 1, "mul": 3, "pallas_call": 1, "pjit": 5, "reduce_max": 1,
        "reduce_sum": 2, "reshape": 2, "select_n": 3, "slice": 1,
        "squeeze": 1, "sub": 1, "swap": 2,
    },
}


@pytest.mark.parametrize("name", sorted(GOLDEN_PRIMITIVES))
def test_golden_primitive_counts(name):
    entry = get_entry(name)
    closed, _ = entry.trace()
    assert primitive_counts(closed.jaxpr) == GOLDEN_PRIMITIVES[name], (
        f"the lowering of {name} changed -- review the diff, then update "
        "GOLDEN_PRIMITIVES")


def test_registry_is_clean():
    """Every registered hot entry audits with zero findings."""
    for entry in REGISTRY:
        findings, info = audit_entry(entry)
        assert findings == [], [f.render() for f in findings]
        if entry.pallas:
            assert info["pallas_sites"], f"{entry.name}: no pallas_call traced"


def test_pallas_sites_under_budget():
    budget = int(VMEM_LIMIT_BYTES * VMEM_HEADROOM)
    seen_files = set()
    for entry in REGISTRY:
        if not entry.pallas:
            continue
        _, info = audit_entry(entry)
        for site in info["pallas_sites"]:
            assert 0 < site["resident_bytes"] <= budget, site
        seen_files.add(entry.name)
    # the coverage list that gates new pallas_call sites is non-trivial
    assert len(PALLAS_COVERAGE) >= 5


def test_device_tier_rejects_callback():
    """A host callback inside a device-tier entry is flagged."""
    from repro.analysis.jaxpr_audit import HotEntry, _check_eqns

    def leaky(x):
        jax.debug.print("x = {}", x)  # lowers to debug_callback
        return x * 2.0

    entry = HotEntry("test.leaky", TIER_DEVICE,
                     lambda: (leaky, (jnp.ones((4,), jnp.float32),)))
    closed, _ = entry.trace()
    rules = {f.rule for f in _check_eqns(entry, closed)}
    assert "host-callback" in rules


def test_donation_missing_flagged():
    """An entry registered as donating whose trace never donates is flagged."""
    from repro.analysis.jaxpr_audit import HotEntry, _check_donation

    entry = HotEntry("test.nodonate", TIER_DEVICE,
                     lambda: (jax.jit(lambda x: x + 1.0),
                              (jnp.ones((4,), jnp.float32),)),
                     donated=True)
    closed, _ = entry.trace()
    rules = {f.rule for f in _check_donation(entry, closed)}
    assert "donation-missing" in rules


# -- AST rules on synthetic snippets -------------------------------------------

def _lint(src: str) -> list[Finding]:
    tree = ast.parse(textwrap.dedent(src))
    contexts = discover_contexts(tree)
    traced = {id(c.fn) for c in contexts}
    findings = []
    for ctx in contexts:
        findings += _TaintLinter(ctx, "snippet.py", traced).run()
    ring = _RingViewLinter("snippet.py")
    ring.visit(tree)
    return findings + ring.findings


def _rules(src: str) -> set:
    return {f.rule for f in _lint(src)}


def test_ast_traced_branch():
    assert "traced-branch" in _rules("""
        @jax.jit
        def f(x):
            if x > 0:
                return x
            return -x
    """)


def test_ast_traced_branch_static_ok():
    """Branching on static_argnames or shape metadata never flags."""
    assert _rules("""
        @partial(jax.jit, static_argnames=("mode",))
        def f(x, mode):
            if mode == "fast":
                return x
            m, n = x.shape
            if m > n:
                return x.T
            return x
    """) == set()


def test_ast_np_on_traced():
    assert "np-on-traced" in _rules("""
        @jax.jit
        def f(x):
            return np.asarray(x).sum()
    """)


def test_ast_host_item_and_coercion():
    rules = _rules("""
        @jax.jit
        def f(x):
            a = x.sum().item()
            b = float(x[0])
            return a + b
    """)
    assert "host-item" in rules and "host-coercion" in rules


def test_ast_loop_body_context():
    """while_loop bodies are traced contexts even without a jit decorator."""
    assert "traced-branch" in _rules("""
        def body(carry):
            if carry > 0:
                carry = carry - 1
            return carry
        def run(x):
            return jax.lax.while_loop(lambda c: c > 0, body, x)
    """)


def test_ast_pallas_kernel_context():
    """pallas_call kernels are traced contexts; kwargs stay static config."""
    findings = _rules("""
        def kernel(x_ref, o_ref, *, causal):
            if causal:
                o_ref[...] = x_ref[...]
            v = float(x_ref[0])
        def launch(x):
            return pl.pallas_call(kernel, out_shape=x)(x)
    """)
    # `if causal:` is partial-bound config (kw-only) -- not flagged;
    # float(x_ref[0]) syncs a traced ref -- flagged
    assert "traced-branch" not in findings
    assert "host-coercion" in findings


def test_ast_taint_propagates_through_assignment():
    assert "traced-branch" in _rules("""
        @jax.jit
        def f(x):
            y = x * 2
            z = y + 1
            if z > 0:
                return z
            return -z
    """)


def test_ast_stale_ring_view():
    assert "stale-ring-view" in _rules("""
        def f(ring, block):
            v = ring.view()
            ring.push(block)
            return v.co.sum()
    """)


def test_ast_ring_view_before_push_ok():
    assert "stale-ring-view" not in _rules("""
        def f(ring, block):
            v = ring.view()
            total = v.co.sum()
            ring.push(block)
            return total
    """)


# -- pair_scatter index-space contract -----------------------------------------

def test_pair_scatter_bounds_assert():
    from repro.kernels.telemetry import pair_scatter

    T = 16
    cbar = jnp.ones((3, T), jnp.float32)
    vals = jnp.ones((3,), jnp.float32)
    # negative types are the padding/eviction contract: accepted, dropped
    p, b = pair_scatter(jnp.array([0, -1, 5], jnp.int32), cbar, vals,
                        interpret=True)
    assert float(b.sum()) == 2.0
    # >= T is a misrouted index: debug mode (default under interpret) raises
    with pytest.raises(ValueError, match="index-space contract"):
        pair_scatter(jnp.array([0, T, 5], jnp.int32), cbar, vals,
                     interpret=True)
    # ... but the kernel's silent-drop semantics stay reachable
    p, b = pair_scatter(jnp.array([0, T, 5], jnp.int32), cbar, vals,
                        interpret=True, debug=False)
    assert float(b.sum()) == 2.0
    # under an enclosing trace the host check self-disables
    f = jax.jit(lambda t: pair_scatter(t, cbar, vals, interpret=True))
    f(jnp.array([0, T, 5], jnp.int32))


# -- compile-cache guard -------------------------------------------------------

def test_compile_cache_guard_counts_traces():
    @jax.jit
    def f(x):
        return x * 2.0

    with CompileCacheGuard({"f": f}) as g:
        f(jnp.ones((4,)))          # one trace
        f(jnp.ones((4,)))          # cache hit
    assert g.deltas == {"f": 1}

    with CompileCacheGuard({"f": f}) as g:
        f(jnp.ones((8,)))          # new shape: one more trace
    assert g.new_traces() == {"f": 1}
    with pytest.raises(AssertionError, match="compile-cache guard"):
        g.assert_max(0)

    with CompileCacheGuard({"f": f}) as g:
        f(jnp.ones((4,)))          # warm
    assert g.new_traces() == {}
    g.assert_max(0)


def test_adaptive_rerun_zero_recompiles():
    """The acceptance criterion: a 3-segment AdaptiveEngine stream run,
    rerun on the same engine, triggers zero new traces anywhere in the
    tracked per-segment hot loop."""
    stats = {}
    findings = run_retrace_audit(stats, segments=3)
    assert findings == [], [f.render() for f in findings]
    r = stats["retrace"]
    assert r["rerun_total"] == 0, r
    # warm run: at most one trace per tracked function (shared segment shape)
    assert all(v == 1 for v in r["warm_traces"].values()), r


# -- the CI contract -----------------------------------------------------------

def test_repo_is_finding_free():
    """The full static audit (jaxpr + AST) vs the checked-in baseline: zero
    unbaselined findings. This is exactly what the CI static-analysis job
    enforces via ``python -m repro.analysis``."""
    findings, stats = run_all(retrace=False)
    fresh = new_findings(findings, load_baseline())
    assert fresh == [], [f.render() for f in fresh]
    assert len(stats["jaxpr"]) == len(REGISTRY)
    assert stats["ast"]["files"] > 50


def test_finding_key_ignores_detail():
    """Baseline keys must survive rewording: detail is excluded."""
    a = Finding("ast", "traced-branch", "x.py:3", "old wording")
    b = Finding("ast", "traced-branch", "x.py:3", "new wording")
    assert a.key() == b.key()
