"""Alpha calibration (paper §V: actual TDP 7.76MB vs calculated 6MB -> ~1.3)."""
import pytest

from repro.core import M1, M2, PAPER_CLUSTER, parse_workloads, profile_pairwise_fast, snap_to_grid
from repro.core.calibrate import calibrate_alpha, pick_alpha, sweep_alpha


@pytest.mark.parametrize("server", [M1, M2])
def test_calibrated_alpha_recovers_physical_tolerance(server):
    """The procedure must recover the hardware's llc_tolerance (~1.29) from
    *observations only* -- within the N-granularity of the cliff search."""
    alpha = calibrate_alpha(server)
    assert server.llc_tolerance <= alpha <= server.llc_tolerance * 1.35


def test_alpha_sweep_prefers_balanced_setting():
    """Fig 9: the balanced alpha beats the conservative 1.0 (which queues
    admissible work) AND the aggressive 1.5 (which blows past the physical
    TDP). Cache-pressured scenario: one M1, a stream of LLC-resident
    workloads at 1.25MB competing bytes each -- alpha=1.0 admits 4/8,
    alpha~=1.3 admits 6 safely (7.5MB < 7.76MB tolerance), alpha=1.5 admits
    7 (8.75MB) and triggers the >50% cliff."""
    D = [profile_pairwise_fast(M1)]
    arrivals = [snap_to_grid(w) for w in parse_workloads("(256KB, 1MB), " * 8)]
    sweep = sweep_alpha([M1], D, [[]], arrivals, alphas=(1.0, 1.25, 1.5))
    best = pick_alpha(sweep)
    assert best == 1.25, sweep
    assert sweep[1.25] > sweep[1.0]  # conservative queues too much
    assert sweep[1.25] > sweep[1.5]  # aggressive loses the LLC
