"""Checkpointing: roundtrip, atomicity, corruption detection, resume."""
import json
import pathlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import Checkpointer


def _tree(seed=0):
    k = jax.random.PRNGKey(seed)
    return {
        "params": {"w": jax.random.normal(k, (16, 8)), "b": jnp.zeros(8)},
        "opt": {"m": jnp.ones((16, 8)), "step": jnp.asarray(7, jnp.int32)},
    }


def test_roundtrip_identity(tmp_path):
    ckpt = Checkpointer(tmp_path, async_save=False)
    tree = _tree()
    ckpt.save(3, tree)
    assert ckpt.latest_step() == 3
    back = ckpt.restore(3, tree)
    for a, b in zip(jax.tree_util.tree_leaves(tree), jax.tree_util.tree_leaves(back)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_async_save_then_wait(tmp_path):
    ckpt = Checkpointer(tmp_path, async_save=True)
    ckpt.save(1, _tree())
    ckpt.wait()
    assert ckpt.latest_step() == 1


def test_gc_keeps_latest(tmp_path):
    ckpt = Checkpointer(tmp_path, keep=2, async_save=False)
    for s in (1, 2, 3, 4):
        ckpt.save(s, _tree(s))
    assert ckpt.steps() == [3, 4]


def test_corruption_detected(tmp_path):
    ckpt = Checkpointer(tmp_path, async_save=False)
    tree = _tree()
    ckpt.save(5, tree)
    # flip bytes in one array
    f = next((tmp_path / "step_000000005" / "arrays").glob("*w*.npy"))
    arr = np.load(f)
    arr[0, 0] += 1
    np.save(f, arr)
    with pytest.raises(IOError, match="corruption"):
        ckpt.restore(5, tree)


def test_incomplete_write_invisible(tmp_path):
    """A crash mid-write (tmp dir present, no manifest) must be ignored."""
    ckpt = Checkpointer(tmp_path, async_save=False)
    ckpt.save(1, _tree())
    bad = tmp_path / ".tmp_step_000000009"
    (bad / "arrays").mkdir(parents=True)
    assert ckpt.latest_step() == 1


def test_restore_onto_new_sharding_struct(tmp_path):
    """Elastic-restore path: same shapes, fresh device placement."""
    ckpt = Checkpointer(tmp_path, async_save=False)
    tree = _tree()
    ckpt.save(2, tree)
    shardings = jax.tree_util.tree_map(
        lambda _: jax.sharding.SingleDeviceSharding(jax.devices()[0]), tree)
    back = ckpt.restore(2, tree, shardings=shardings)
    for a, b in zip(jax.tree_util.tree_leaves(tree), jax.tree_util.tree_leaves(back)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_shape_mismatch_rejected(tmp_path):
    ckpt = Checkpointer(tmp_path, async_save=False)
    ckpt.save(1, _tree())
    wrong = _tree()
    wrong["params"]["w"] = jnp.zeros((8, 8))
    with pytest.raises(ValueError, match="shape mismatch"):
        ckpt.restore(1, wrong)
