"""End-to-end behaviour: the training driver reduces loss and resumes from
checkpoints; the serving driver generates deterministic tokens."""
import numpy as np
import pytest

from repro.launch.serve import main as serve_main
from repro.launch.train import main as train_main


@pytest.mark.slow
def test_train_loss_decreases(tmp_path):
    losses = train_main([
        "--arch", "tinyllama-1.1b", "--smoke", "--steps", "60",
        "--batch", "4", "--seq", "64", "--lr", "3e-3",
        "--ckpt", str(tmp_path / "ck"), "--ckpt-every", "30",
    ])
    assert losses[-1] < losses[0] - 0.3, (losses[0], losses[-1])


@pytest.mark.slow
def test_train_resume_continues(tmp_path):
    ck = str(tmp_path / "ck")
    train_main(["--arch", "tinyllama-1.1b", "--smoke", "--steps", "20",
                "--batch", "2", "--seq", "32", "--ckpt", ck, "--ckpt-every", "10"])
    # second invocation resumes at step 20 and runs to 30
    losses = train_main(["--arch", "tinyllama-1.1b", "--smoke", "--steps", "30",
                         "--batch", "2", "--seq", "32", "--ckpt", ck,
                         "--ckpt-every", "10"])
    assert len(losses) == 10  # only the resumed tail ran


@pytest.mark.slow
def test_serve_generates():
    gen = serve_main(["--arch", "tinyllama-1.1b", "--smoke",
                      "--requests", "2", "--prompt-len", "16", "--gen", "8"])
    assert gen.shape == (2, 8)
    assert np.all(gen >= 0)
    # deterministic greedy decoding
    gen2 = serve_main(["--arch", "tinyllama-1.1b", "--smoke",
                       "--requests", "2", "--prompt-len", "16", "--gen", "8"])
    np.testing.assert_array_equal(gen, gen2)
