"""Pallas kernels vs their pure-jnp oracles (ref.py), interpret mode.

Each kernel is swept over shapes/dtypes per the assignment:
'sweep shapes/dtypes and assert_allclose against the ref.py pure-jnp oracle'.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import M1, PAPER_CLUSTER, PackedCluster, profile_pairwise_fast
from repro.kernels import ops, ref


def _gqa_ref(q, k, v, causal, q_offset=0):
    B, Sq, H, dh = q.shape
    Hkv = k.shape[2]
    G = H // Hkv
    kx = jnp.repeat(k, G, axis=2).transpose(0, 2, 1, 3).reshape(B * H, -1, dh)
    vx = jnp.repeat(v, G, axis=2).transpose(0, 2, 1, 3).reshape(B * H, -1, dh)
    qx = q.transpose(0, 2, 1, 3).reshape(B * H, Sq, dh)
    out = ref.attention_ref(qx, kx, vx, causal=causal, q_offset=q_offset)
    return out.reshape(B, H, Sq, dh).transpose(0, 2, 1, 3)


@pytest.mark.parametrize("dtype,tol", [(jnp.float32, 2e-5), (jnp.bfloat16, 2e-2)])
@pytest.mark.parametrize(
    "B,Sq,Skv,H,Hkv,dh,causal",
    [
        (1, 64, 64, 2, 2, 32, True),
        (2, 128, 128, 4, 2, 64, True),
        (1, 64, 128, 2, 1, 32, False),  # cross-attention-like
        (2, 1, 128, 4, 4, 32, True),  # decode: Sq=1
    ],
)
def test_flash_attention_sweep(B, Sq, Skv, H, Hkv, dh, causal, dtype, tol):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, Sq, H, dh), dtype)
    k = jax.random.normal(ks[1], (B, Skv, Hkv, dh), dtype)
    v = jax.random.normal(ks[2], (B, Skv, Hkv, dh), dtype)
    q_offset = Skv - Sq if causal else 0
    out = ops.gqa_flash_attention(q, k, v, causal=causal, q_offset=q_offset,
                                  mode="interpret", block_q=32, block_k=32)
    want = _gqa_ref(q, k, v, causal, q_offset)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), atol=tol, rtol=tol)


@pytest.mark.parametrize("B,S,H,dh,chunk", [(1, 32, 1, 8, 8), (2, 64, 2, 16, 16), (1, 48, 2, 16, 16)])
def test_rwkv6_scan_sweep(B, S, H, dh, chunk):
    ks = jax.random.split(jax.random.PRNGKey(1), 5)
    r = jax.random.normal(ks[0], (B, S, H, dh))
    k = jax.random.normal(ks[1], (B, S, H, dh))
    v = jax.random.normal(ks[2], (B, S, H, dh))
    wlog = -jnp.exp(jax.random.normal(ks[3], (B, S, H, dh)) * 0.5)
    u = jax.random.normal(ks[4], (H, dh)) * 0.1
    s0 = jnp.zeros((B, H, dh, dh))
    y, sT = ops.rwkv6_wkv(r, k, v, wlog, u, s0, chunk=chunk, mode="interpret")
    fold = lambda x: x.transpose(0, 2, 1, 3).reshape(B * H, S, dh)
    yr, sr = ref.rwkv6_ref(fold(r), fold(k), fold(v), fold(wlog),
                           jnp.broadcast_to(u[None], (B, H, dh)).reshape(B * H, dh),
                           s0.reshape(B * H, dh, dh))
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr.reshape(B, H, S, dh).transpose(0, 2, 1, 3)),
                               atol=5e-4, rtol=1e-3)
    np.testing.assert_allclose(np.asarray(sT.reshape(B * H, dh, dh)), np.asarray(sr),
                               atol=5e-4, rtol=1e-3)


def test_rwkv6_scan_nonzero_initial_state():
    B, S, H, dh = 1, 32, 1, 8
    ks = jax.random.split(jax.random.PRNGKey(2), 5)
    r, k, v = (jax.random.normal(ks[i], (B, S, H, dh)) for i in range(3))
    wlog = -jnp.exp(jax.random.normal(ks[3], (B, S, H, dh)) * 0.3)
    u = jax.random.normal(ks[4], (H, dh)) * 0.1
    s0 = jax.random.normal(ks[0], (B, H, dh, dh))
    y, sT = ops.rwkv6_wkv(r, k, v, wlog, u, s0, chunk=8, mode="interpret")
    fold = lambda x: x.transpose(0, 2, 1, 3).reshape(B * H, S, dh)
    yr, sr = ref.rwkv6_ref(fold(r), fold(k), fold(v), fold(wlog),
                           u.reshape(B * H, dh), s0.reshape(B * H, dh, dh))
    np.testing.assert_allclose(np.asarray(y[0, :, 0]), np.asarray(yr[0]), atol=5e-4, rtol=1e-3)


@pytest.mark.parametrize("B,S,E,N,chunk,eblock", [(1, 32, 16, 4, 8, 8), (2, 64, 32, 8, 16, 16)])
def test_mamba_scan_sweep(B, S, E, N, chunk, eblock):
    ks = jax.random.split(jax.random.PRNGKey(3), 3)
    da = jnp.exp(-jnp.abs(jax.random.normal(ks[0], (B, S, E, N))))
    dbu = jax.random.normal(ks[1], (B, S, E, N)) * 0.1
    c = jax.random.normal(ks[2], (B, S, N))
    h0 = jnp.zeros((B, E, N))
    y, hT = ops.mamba_ssm_scan(da, dbu, c, h0, chunk=chunk, eblock=eblock, mode="interpret")
    yr, hr = ref.mamba_ref(da, dbu, c)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), atol=1e-5, rtol=1e-5)
    np.testing.assert_allclose(np.asarray(hT), np.asarray(hr), atol=1e-5, rtol=1e-5)


def test_consolidation_scores_vs_ref_and_model():
    servers = list(PAPER_CLUSTER)[:2]
    Ds = [profile_pairwise_fast(s) for s in servers]
    cluster = PackedCluster.build(servers, Ds, alpha=1.3)
    counts = jnp.zeros((2, cluster.T)).at[0, 5].add(2).at[1, 40].add(1)
    wtypes = jnp.asarray([3, 77, 130, 229], jnp.int32)
    fs_res = cluster.resident * cluster.fs[None]
    cache, maxd = ops.greedy_scores(counts, cluster.D, cluster.rs, fs_res,
                                    cluster.llc_budget, wtypes, mode="interpret")
    cr, mr = ref.consolidation_scores_ref(
        counts, cluster.D, np.asarray(cluster.rs), np.asarray(cluster.fs),
        np.asarray(cluster.llc_budget), np.asarray(cluster.resident), wtypes)
    np.testing.assert_allclose(np.asarray(cache), np.asarray(cr), atol=1e-5)
    np.testing.assert_allclose(np.asarray(maxd), np.asarray(mr), atol=1e-5)


@pytest.mark.parametrize(
    "B,T,block_b",
    [
        (1, 16, 128),  # single observation
        (7, 230, 128),  # smaller than one block (padding path)
        (128, 230, 64),  # multiple full blocks
        (300, 64, 128),  # partial last block (B not a block_b multiple)
        (193, 64, 64),  # partial last block, exact smaller blocking
    ],
)
def test_pair_scatter_vs_ref(B, T, block_b):
    """Telemetry pair-statistic scatter kernel vs the float64 numpy oracle.

    Includes out-of-range types on *both* sides (-1, the wrapper's padding
    convention, and >= T, a masked/corrupt row): they must contribute
    nothing, exactly like the reference's explicit skip."""
    from repro.kernels.telemetry import pair_scatter

    rng = np.random.default_rng(B * 1000 + T)
    types = rng.integers(-1, T + 2, size=B).astype(np.int32)
    cbar = (rng.random((B, T)) * 2).astype(np.float32)
    vals = rng.normal(size=B).astype(np.float32)
    # debug=False: the >= T rows here exercise the kernel's silent-drop
    # semantics; the eager debug-mode bounds check (which treats >= T as a
    # misrouted index) has its own test in test_analysis.py
    pair, base = pair_scatter(jnp.asarray(types), jnp.asarray(cbar),
                              jnp.asarray(vals), block_b=block_b,
                              interpret=True, debug=False)
    pair_ref, base_ref = ref.pair_scatter_ref(types, cbar, vals)
    np.testing.assert_allclose(np.asarray(pair), pair_ref, atol=2e-5, rtol=1e-5)
    np.testing.assert_allclose(np.asarray(base), base_ref, atol=2e-5, rtol=1e-5)


@pytest.mark.parametrize("B,T,K,block_b", [
    (40, 64, 2, 128),  # the estimator's stacked (residual, weight) pair
    (300, 32, 3, 128),  # partial last block with a stacked axis
    (64, 230, 1, 64),  # K=1 stacked differs from the squeezed 1-D contract
])
def test_pair_scatter_stacked_statistics(B, T, K, block_b):
    """The kernel scatters K stacked statistics in one pass ([K, B] vals ->
    [K, T, T] / [K, T]), matching the float64 oracle per statistic."""
    from repro.kernels.telemetry import pair_scatter

    rng = np.random.default_rng(B + T + K)
    types = rng.integers(-1, T, size=B).astype(np.int32)
    cbar = (rng.random((B, T)) * 2).astype(np.float32)
    vals = rng.normal(size=(K, B)).astype(np.float32)
    pair, base = pair_scatter(jnp.asarray(types), jnp.asarray(cbar),
                              jnp.asarray(vals), block_b=block_b, interpret=True)
    assert pair.shape == (K, T, T) and base.shape == (K, T)
    pair_ref, base_ref = ref.pair_scatter_ref(types, cbar, vals)
    np.testing.assert_allclose(np.asarray(pair), pair_ref, atol=2e-5, rtol=1e-5)
    np.testing.assert_allclose(np.asarray(base), base_ref, atol=2e-5, rtol=1e-5)
    # stacking must agree with K independent single-statistic passes
    for k in range(K):
        p1, b1 = pair_scatter(jnp.asarray(types), jnp.asarray(cbar),
                              jnp.asarray(vals[k]), block_b=block_b, interpret=True)
        np.testing.assert_array_equal(np.asarray(p1), np.asarray(pair[k]))
        np.testing.assert_array_equal(np.asarray(b1), np.asarray(base[k]))


def test_pair_scatter_empty_batch_all_backends():
    """B = 0 returns zeros of the right shape on every backend, 1-D and
    stacked (the engine's empty-segment path hits this)."""
    from repro.kernels.telemetry import pair_scatter
    from repro.telemetry.estimator import make_scatter

    T = 16
    e_types = np.zeros(0, np.int32)
    e_cbar = np.zeros((0, T))
    pair, base = pair_scatter(jnp.asarray(e_types), jnp.asarray(e_cbar),
                              jnp.zeros((3, 0)), interpret=True)
    assert pair.shape == (3, T, T) and base.shape == (3, T)
    assert not np.asarray(pair).any() and not np.asarray(base).any()
    for backend in ("numpy", "jnp", "pallas"):
        p, b = make_scatter(backend)(e_types, e_cbar, np.zeros(0))
        assert p.shape == (T, T) and b.shape == (T,)
        assert not np.asarray(p).any() and not np.asarray(b).any()


def test_pair_scatter_matches_estimator_backends():
    """All three scatter backends implement one contract (estimator view),
    1-D and stacked. Tolerance reflects full-f32 accumulation: the jnp
    backend is jitted once and contracts with an explicit
    ``preferred_element_type`` (no bf16 downcast drift on any device)."""
    from repro.telemetry.estimator import make_scatter

    rng = np.random.default_rng(0)
    B, T = 40, 230
    types = rng.integers(0, T, size=B).astype(np.int32)
    cbar = (rng.random((B, T)) < 0.02).astype(np.float64) * rng.random((B, T))
    for vals in (rng.normal(size=B), rng.normal(size=(2, B))):
        want = make_scatter("numpy")(types, cbar, vals)
        for backend in ("jnp", "pallas"):
            got = make_scatter(backend)(types, cbar, vals)
            np.testing.assert_allclose(got[0], want[0], atol=1e-6)
            np.testing.assert_allclose(got[1], want[1], atol=1e-6)


def test_flash_attention_matches_model_layer():
    """Kernel path == the production jnp chunked_attention (same math)."""
    from repro.models.layers import chunked_attention

    B, S, Hkv, G, dh = 1, 64, 2, 2, 32
    ks = jax.random.split(jax.random.PRNGKey(4), 3)
    q = jax.random.normal(ks[0], (B, S, Hkv, G, dh))
    k = jax.random.normal(ks[1], (B, S, Hkv, dh))
    v = jax.random.normal(ks[2], (B, S, Hkv, dh))
    jnp_out = chunked_attention(q, k, v, causal=True, chunk=32)
    kq = q.reshape(B, S, Hkv * G, dh)
    kernel_out = ops.gqa_flash_attention(kq, k, v, causal=True, mode="interpret",
                                         block_q=32, block_k=32)
    np.testing.assert_allclose(
        np.asarray(jnp_out.reshape(B, S, -1, dh), np.float32),
        np.asarray(kernel_out, np.float32), atol=2e-5, rtol=2e-5)
