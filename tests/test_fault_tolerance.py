"""Fault tolerance: heartbeats, the Eqn-4 straggler rule, elastic re-mesh."""
import pytest

from repro.configs.base import MeshConfig
from repro.distributed.fault_tolerance import (
    HeartbeatMonitor,
    plan_elastic_remesh,
    scale_batch_for_mesh,
)


def test_dead_host_detection():
    mon = HeartbeatMonitor(n_hosts=4, timeout_s=10.0)
    for h in range(4):
        mon.heartbeat(h, now=0.0)
    mon.heartbeat(0, now=50.0)
    dead = mon.dead_hosts(now=55.0)
    assert set(dead) == {1, 2, 3}


def test_straggler_uses_paper_50pct_rule():
    """A host is a straggler iff its step-time inflation D = O/(AR+O) >= 0.5,
    i.e. it is >= 2x slower than the fleet median (criterion 1, Eqn 4)."""
    mon = HeartbeatMonitor(n_hosts=4)
    for h in range(3):
        for t in range(10):
            mon.heartbeat(h, now=t, step_time=1.0)
    for t in range(10):
        mon.heartbeat(3, now=t, step_time=1.9)  # 1.9x: below the 2x rule
    assert mon.stragglers() == []
    for t in range(10, 20):
        mon.heartbeat(3, now=t, step_time=2.5)  # 2.5x: past it
    assert mon.stragglers() == [3]


def test_remesh_multi_pod_drops_pod():
    mesh = MeshConfig(multi_pod=True, pods=2)
    plan = plan_elastic_remesh(mesh, lost_hosts=[33], hosts_per_pod=32)
    assert plan is not None
    assert plan.new.multi_pod is False  # 2 pods - 1 = single-pod config
    assert plan.new.n_devices == 256
    assert plan.lost_fraction == pytest.approx(0.5)


def test_remesh_single_pod_halves_data_axis():
    mesh = MeshConfig()
    plan = plan_elastic_remesh(mesh, lost_hosts=[3])
    assert plan.new.data == 8 and plan.new.model == 16


def test_remesh_noop_without_losses():
    assert plan_elastic_remesh(MeshConfig(), []) is None


def test_batch_scaling_policies():
    old, new = MeshConfig(multi_pod=True, pods=2), MeshConfig()
    assert scale_batch_for_mesh(256, old, new, keep_global=True) == 256
    assert scale_batch_for_mesh(256, old, new, keep_global=False) == 128
