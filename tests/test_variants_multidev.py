"""The §Perf variant paths must be *correct*, not just compilable: under a
real 8-device mesh, the dp layout and the tp layout must produce the same
loss as the single-device model; int8-KV decode must match bf16-KV decode to
quantization tolerance. Subprocess keeps the main process single-device."""
import os
import subprocess
import sys
import textwrap

import pytest
from conftest import requires_native_shard_map

PROBE = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import dataclasses
    import jax, jax.numpy as jnp, numpy as np
    from repro.configs import SMOKES, MeshConfig, sharding_rules
    from repro.models import build_model, materialize
    from repro.models import layers as ML
    from repro.distributed.sharding import named, param_specs, batch_specs, cache_specs
    from repro.models.params import abstract

    mesh = jax.make_mesh((2, 4), ("data", "model"))
    mesh_cfg = MeshConfig(data=2, model=4)
    rng = jax.random.PRNGKey(0)

    # --- dp vs tp layout: identical loss ---------------------------------
    base = SMOKES["tinyllama-1.1b"]
    model = build_model(base)
    params = materialize(model.param_infos(), rng)
    B, S = 4, 32
    batch = {"tokens": jax.random.randint(rng, (B, S), 0, base.vocab),
             "labels": jax.random.randint(rng, (B, S), 0, base.vocab)}
    ref = float(model.loss(params, batch)[0])

    for layout in ("tp", "dp"):
        cfg = dataclasses.replace(base, layout=layout)
        m = build_model(cfg)
        rules = sharding_rules(cfg, mesh_cfg)
        p_sh = named(mesh, param_specs(m, mesh_cfg))
        ps = jax.tree_util.tree_map(lambda a, s: jax.device_put(a, s), params, p_sh)
        with mesh, ML.activation_sharding(mesh, rules):
            got = float(jax.jit(lambda p, b: m.loss(p, b)[0])(ps, batch))
        err = abs(got - ref) / abs(ref)
        print(f"layout={layout}: loss={got:.5f} ref={ref:.5f} rel={err:.2e}")
        assert err < 2e-2, layout

    # --- int8 KV decode on the mesh vs bf16 KV ----------------------------
    cfgq = dataclasses.replace(base, kv_cache_dtype="int8")
    mq = build_model(cfgq)
    tokens = jax.random.randint(rng, (B, 17), 0, base.vocab)
    outs = {}
    for name, m in (("bf16", model), ("int8", mq)):
        cfg_m = m.cfg
        rules = sharding_rules(cfg_m, mesh_cfg)
        p_sh = named(mesh, param_specs(m, mesh_cfg))
        ps = jax.tree_util.tree_map(lambda a, s: jax.device_put(a, s), params, p_sh)
        with mesh, ML.activation_sharding(mesh, rules):
            cache = materialize(m.cache_infos(B, 24), rng)
            c_sh = named(mesh, cache_specs(m, mesh_cfg, B, 24))
            cache = jax.tree_util.tree_map(lambda a, s: jax.device_put(a, s), cache, c_sh)
            def run(p, c, t):
                _, c = m.prefill(p, {"tokens": t[:, :16]}, c)
                lg, _ = m.decode_step(p, c, t[:, 16:17])
                return lg
            outs[name] = np.asarray(jax.jit(run)(ps, cache, tokens), np.float32)
    rel = np.abs(outs["int8"] - outs["bf16"]).max() / (np.abs(outs["bf16"]).max() + 1e-9)
    print(f"int8-vs-bf16 KV decode rel err: {rel:.3e}")
    # smoke heads are 16-dim, so per-token int8 scales are coarse; the
    # full-config 128-dim heads land near 1e-2 (see test_models notes).
    # This bound checks the quantized path runs correctly on the mesh.
    assert rel < 0.2
    # argmax token agreement is the serving-level criterion
    agree = (outs["int8"].argmax(-1) == outs["bf16"].argmax(-1)).mean()
    print(f"argmax agreement: {agree:.2f}")
    print("VARIANTS OK")
    """
)


@pytest.mark.slow
@requires_native_shard_map
def test_perf_variants_numerically_correct_on_mesh():
    env = dict(os.environ)
    env["PYTHONPATH"] = env.get("PYTHONPATH", "") + os.pathsep + os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src"))
    # pin the subprocess to the host platform: device-count forcing is
    # CPU-only and probing for a TPU runtime hangs in CI sandboxes
    env["JAX_PLATFORMS"] = "cpu"
    r = subprocess.run([sys.executable, "-c", PROBE], capture_output=True, text=True,
                       env=env, timeout=560)
    assert "VARIANTS OK" in r.stdout, r.stdout + "\n" + r.stderr[-3000:]
