import os

# Tests run single-device (the dry-run owns the 512-device setting).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax
import pytest

jax.config.update("jax_enable_x64", False)

# partial-auto shard_map (manual over a subset of mesh axes) lowers to a
# PartitionId op that older jaxlibs' SPMD partitioner rejects; the native
# jax.shard_map releases handle it. The multidev probes exercise that path.
requires_native_shard_map = pytest.mark.skipif(
    not hasattr(jax, "shard_map"),
    reason="partial-auto shard_map needs a jax release with native jax.shard_map")
