import os

# Tests run single-device (the dry-run owns the 512-device setting).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax

jax.config.update("jax_enable_x64", False)
