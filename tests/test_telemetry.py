"""Telemetry subsystem: observation log, streaming estimator, closed loop.

Convergence contract (ISSUE 3): the streaming estimate of D converges to the
``profile_pairwise_fast`` ground truth under stationary traces, and
re-converges after an injected drift. The property tests run under
hypothesis when available (tests/_hyp.py shim) and as deterministic
fixed-seed tests always.
"""
import numpy as np
import pytest

from repro.core import (
    M1,
    M2,
    AdaptiveEngine,
    ConsolidationEngine,
    Workload,
    profile_pairwise_fast,
    snap_to_grid,
)
from repro.core.contention import pair_slowdown_matrices, type_tables
from repro.core.workload import FS_GRID, RS_GRID
from repro.telemetry import (
    EstimatorBank,
    ObservationLog,
    ObservationRing,
    StreamingEstimator,
    block_from_log,
    congestion_at,
    degrade_server,
)

from _hyp import given, settings, st

T = len(RS_GRID) * len(FS_GRID)

# a compact keep-regime pool: pairs stay under M1's physical TDP, so the
# estimator's single-regime model matches profile_pairwise_fast exactly
_POOL = [
    snap_to_grid(Workload(fs=float(fs), rs=float(rs)))
    for fs in FS_GRID[9:12]  # 512KB .. 2MB
    for rs in RS_GRID[5:7]  # 32KB, 64KB
]


# --- synthetic-observation helpers ------------------------------------------

def _truth(server):
    tt = type_tables(server)
    d_keep, _ = pair_slowdown_matrices(server)
    L = np.log1p(-np.clip(d_keep, 0.0, 1.0 - 1e-9))
    return tt["solo"], L, np.clip(-np.expm1(L), 0.0, 1.0)


def _synthetic_batch(rng, pool_idx, solo, L, B=64, noise=0.0):
    t = rng.choice(pool_idx, size=B)
    co = np.zeros((B, T))
    for b in range(B):
        # co-run sizes 0..3: the solo (size-0) observations anchor the base
        for c in rng.choice(pool_idx, size=rng.integers(0, 4)):
            co[b, c] += 1.0
    y = np.log(solo[t]) + np.einsum("bu,ub->b", co, L[:, t])
    if noise:
        y = y + rng.normal(0.0, noise, B)
    return ObservationLog(
        wtype=t.astype(np.int32), server=np.zeros(B, np.int32),
        duration=np.ones(B), rate=np.exp(y), geo_rate=np.exp(y), co_counts=co,
        lost_frac=np.zeros(B))


def _check_synthetic_convergence(seed):
    solo, L, D_true = _truth(M1)
    rng = np.random.default_rng(seed)
    pool_idx = rng.choice(T, size=8, replace=False)
    est = StreamingEstimator(T=T, prior_D=0.0, prior_solo=solo, lr=0.6,
                             confidence_floor=2.0, scatter="numpy")
    for _ in range(60):
        est.update(_synthetic_batch(rng, pool_idx, solo, L, noise=0.005))
    mask = est.observed_mask()
    assert mask.sum() >= len(pool_idx)  # the pool's pairs were actually seen
    err = np.abs(est.estimate_D() - D_true)[mask]
    assert err.max() < 0.03, err.max()
    sub = np.ix_(pool_idx, pool_idx)
    solo_err = np.abs(np.log(est.estimate_solo() / solo))[pool_idx]
    assert solo_err.max() < 0.02
    return est, pool_idx, sub


def test_estimator_converges_synthetic_stationary():
    _check_synthetic_convergence(0)


@settings(max_examples=10, deadline=None)
@given(st.integers(min_value=1, max_value=10_000))
def test_estimator_converges_synthetic_stationary_property(seed):
    _check_synthetic_convergence(seed)


def _check_synthetic_drift_reconvergence(seed):
    """After converging to world 1, feed world-2 observations: the estimate
    must leave world 1 and land on world 2 (the batch-local update tracks
    regardless of accumulated confidence)."""
    est, pool_idx, sub = _check_synthetic_convergence(seed)
    solo1, _, D1 = _truth(M1)
    drifted = degrade_server(M1, factor=0.5)
    solo2, L2, D2 = _truth(drifted)
    assert np.abs(D1[sub] - D2[sub]).max() > 0.01  # the drift is observable
    rng = np.random.default_rng(seed + 1)
    # farther to travel than from the fresh prior: world 1 -> world 2
    for _ in range(150):
        est.update(_synthetic_batch(rng, pool_idx, solo2, L2, noise=0.005))
    mask = est.observed_mask()
    err2 = np.abs(est.estimate_D() - D2)[mask]
    assert err2.max() < 0.03, err2.max()
    solo_err = np.abs(np.log(est.estimate_solo() / solo2))[pool_idx]
    assert solo_err.max() < 0.03


def test_estimator_reconverges_after_drift():
    _check_synthetic_drift_reconvergence(0)


@settings(max_examples=5, deadline=None)
@given(st.integers(min_value=1, max_value=10_000))
def test_estimator_reconverges_after_drift_property(seed):
    _check_synthetic_drift_reconvergence(seed)


def test_estimator_prior_fallback_below_confidence_floor():
    prior = np.full((T, T), 0.2)
    est = StreamingEstimator(T=T, prior_D=prior, scatter="numpy")
    np.testing.assert_allclose(est.estimate_D(), prior, atol=1e-7)
    assert not est.observed_mask().any()


# --- chunking invariance (exposure-based decay, ISSUE 4 satellite) -----------

def _check_chunking_invariance(seed, splits=8):
    """Split-vs-merged equivalence with decay < 1.

    Decay compounds per observation-unit with matching triangular weights
    inside each batch, so the *confidence state* (n_pair / n_base -- the
    half-life the old per-call decay silently tied to chunk size) must be
    bitwise-equivalent however the stream is chunked. The point estimates
    take batch-sequential LMS steps, so they agree to first order near
    convergence; both estimators are warmed on an identical stream first and
    the continuation's estimates are then compared tightly."""
    solo, L, D_true = _truth(M1)
    rng = np.random.default_rng(seed)
    pool_idx = rng.choice(T, size=8, replace=False)
    kw = dict(T=T, prior_D=0.0, prior_solo=solo, lr=0.6, decay=0.995,
              confidence_floor=2.0, scatter="numpy")
    merged_est, split_est = StreamingEstimator(**kw), StreamingEstimator(**kw)
    for _ in range(30):  # identical warm-up on both replicas
        batch = _synthetic_batch(rng, pool_idx, solo, L, B=64, noise=0.005)
        merged_est.update(batch)
        split_est.update(batch)

    tail = [_synthetic_batch(rng, pool_idx, solo, L, B=32, noise=0.005)
            for _ in range(splits)]
    merged_est.update(ObservationLog.merge(tail))
    for b in tail:
        split_est.update(b)

    # the confidence state is exactly chunk-invariant
    np.testing.assert_allclose(merged_est.n_pair, split_est.n_pair,
                               rtol=1e-12, atol=1e-12)
    np.testing.assert_allclose(merged_est.n_base, split_est.n_base,
                               rtol=1e-12, atol=1e-12)
    assert merged_est.n_obs == split_est.n_obs
    # point estimates: first-order invariant (identical LMS fixed point)
    np.testing.assert_allclose(merged_est.estimate_D(), split_est.estimate_D(),
                               atol=0.01)
    np.testing.assert_allclose(np.log(merged_est.estimate_solo()),
                               np.log(split_est.estimate_solo()), atol=0.01)


def test_estimator_chunking_invariance():
    _check_chunking_invariance(0)


@settings(max_examples=8, deadline=None)
@given(st.integers(min_value=1, max_value=10_000))
def test_estimator_chunking_invariance_property(seed):
    _check_chunking_invariance(seed)


def test_confidence_half_life_independent_of_chunking():
    """The regression the decay fix targets: under the old per-call decay, 8
    small updates forgot confidence 8x faster than 1 merged update of the
    same observations. Now the decayed mass depends only on the stream."""
    solo, L, _ = _truth(M1)
    rng = np.random.default_rng(3)
    pool_idx = rng.choice(T, size=6, replace=False)
    kw = dict(T=T, prior_D=0.0, prior_solo=solo, lr=0.5, decay=0.99,
              scatter="numpy")
    a, b = StreamingEstimator(**kw), StreamingEstimator(**kw)
    seed_batch = _synthetic_batch(rng, pool_idx, solo, L, B=64)
    a.update(seed_batch)
    b.update(seed_batch)
    # same continuation stream, chunked 1-vs-4
    cont = [_synthetic_batch(rng, pool_idx, solo, L, B=16) for _ in range(4)]
    a.update(ObservationLog.merge(cont))
    for c in cont:
        b.update(c)
    np.testing.assert_allclose(a.n_pair.sum(), b.n_pair.sum(), rtol=1e-12)


# --- engine-driven observations (the real loop) ------------------------------

def _pair_trace(server, seed, n_arrivals=48, passes=3.0):
    """Well-separated co-run events: mostly simultaneous pairs, with solo
    runs mixed in. The solos matter: pair-only telemetry determines only
    log_b_t + L[u, t] (base rate and pair effect shift together along an
    unidentifiable direction); solo observations anchor the base. Always
    exactly ``n_arrivals`` long so every engine run shares one jit shape."""
    rng = np.random.default_rng(seed)
    arrivals, k = [], 0
    while len(arrivals) < n_arrivals:
        group = 1 if rng.random() < 0.35 else 2
        for w in rng.choice(len(_POOL), size=group):
            wl = _POOL[w]
            arrivals.append(
                (k * 1.0, Workload(fs=wl.fs, rs=wl.rs, data_total=wl.fs * passes)))
        k += 1
    return arrivals[:n_arrivals]


def _check_engine_convergence(server, est, seed, rounds=5,
                              tol_max=0.03, tol_mean=0.01):
    """Stream engine telemetry into ``est``; assert it landed on the profile."""
    engine = ConsolidationEngine([server], D=profile_pairwise_fast(server))
    for r in range(rounds):
        res = engine.run(_pair_trace(server, seed + 17 * r), backend="jax",
                         telemetry=True)
        est.update(res.observations)
    D_true = profile_pairwise_fast(server)
    mask = est.observed_mask()
    assert mask.sum() >= 10
    err = np.abs(est.estimate_D() - D_true)[mask]
    assert err.max() < tol_max, err.max()
    assert err.mean() < tol_mean, err.mean()
    return est


def _fresh_estimator():
    # decay is per observation-unit: 0.9926^48 ~ 0.7 per 48-arrival round
    return StreamingEstimator(
        T=T, prior_D=0.0, prior_solo=type_tables(M1)["solo"], lr=0.6,
        decay=0.9926, confidence_floor=2.0, scatter="numpy")


def test_estimate_converges_to_profiled_D_from_engine_trace():
    """The headline contract: telemetry from the device engine alone recovers
    the 52 900-pair profiled matrix on the pairs the trace exercised."""
    _check_engine_convergence(M1, _fresh_estimator(), seed=0)


@settings(max_examples=3, deadline=None)
@given(st.integers(min_value=1, max_value=1_000))
def test_estimate_converges_to_profiled_D_from_engine_trace_property(seed):
    _check_engine_convergence(M1, _fresh_estimator(), seed=seed)


def test_estimate_reconverges_after_server_degradation():
    """Inject a drift (degraded server): the same estimator, fed telemetry
    from the degraded world, re-converges to the degraded profile (base
    rates halve -- the big observable -- and the pair matrix follows)."""
    est = _check_engine_convergence(M1, _fresh_estimator(), seed=3)
    drifted = degrade_server(M1, factor=0.5)
    solo_drift = np.log(type_tables(drifted)["solo"] / type_tables(M1)["solo"])
    assert np.abs(solo_drift).max() > 0.5  # the drift is observable
    est = _check_engine_convergence(drifted, est, seed=29, rounds=16,
                                    tol_max=0.06, tol_mean=0.02)
    # the estimator tracked the halved base rates from solo telemetry alone
    seen = est.n_base > 1.0
    assert seen.sum() >= 4
    base_err = np.abs(est.log_b - np.log(type_tables(drifted)["solo"]))[seen]
    assert base_err.max() < 0.05, base_err.max()


# --- observation-log semantics ----------------------------------------------

def test_observation_log_from_engine_is_physical():
    engine = ConsolidationEngine([M1], D=profile_pairwise_fast(M1))
    res = engine.run(_pair_trace(M1, seed=1), backend="jax", telemetry=True)
    obs = res.observations
    assert len(obs) == 48  # every arrival completed
    solo = type_tables(M1)["solo"]
    # observed rates can never beat solo by more than f32 noise
    assert np.all(obs.rate <= solo[obs.wtype] * 1.01)
    assert np.all(obs.geo_rate <= solo[obs.wtype] * 1.01)
    # pairs launched together: each saw about one co-resident on average
    assert obs.co_counts.sum(axis=1).mean() > 0.3
    assert np.all((obs.lost_frac >= 0.0) & (obs.lost_frac <= 1.0))
    assert np.all(obs.duration > 0.0)
    # telemetry must not perturb the run itself
    res0 = engine.run(_pair_trace(M1, seed=1), backend="jax")
    assert res0.placements == res.placements
    assert res0.makespan == res.makespan
    assert res0.observations is None


# --- the closed loop ---------------------------------------------------------

def _replayed_trace(segment, k):
    return [(t + j * 10.0, w) for j in range(k) for t, w in segment]


def test_adaptive_engine_regret_shrinks_and_recovers():
    """Acceptance: segment durations of the adaptive engine approach the
    true-D oracle's as observations accumulate, and recover after a drift."""
    servers = [M1, M2]
    rng = np.random.default_rng(5)
    seg, t = [], 0.0
    for _ in range(24):
        w = _POOL[int(rng.integers(len(_POOL)))]
        t += float(rng.exponential(2e-5))
        seg.append((t, Workload(fs=w.fs, rs=w.rs, data_total=w.fs * 8)))
    K, drift_at = 8, 5
    # congestion moves the D-matrix itself (degrade_server mostly moves base
    # rates, which placement does not consult -- no regret spike to recover)
    drift = congestion_at(servers, drift_at, server=0, factor=0.4)

    # per-observation-unit decay: 0.9956^24 ~ 0.9 per 24-arrival segment
    adaptive = AdaptiveEngine(servers, prior=0.0, drift=drift, decay=0.9956,
                              scatter="numpy")
    res = adaptive.run(_replayed_trace(seg, K), segments=K)
    assert res.total_obs >= K * len(seg) // 2

    mk = {}
    for k in range(K):
        specs = drift.specs_at(servers, k)
        if specs not in mk:
            oracle = ConsolidationEngine(
                list(specs), D=[profile_pairwise_fast(s) for s in specs])
            mk[specs] = oracle.run(seg, backend="jax").makespan - seg[0][0]
        assert mk[specs] > 0
    regret = [res.durations[k] / mk[drift.specs_at(servers, k)] - 1.0
              for k in range(K)]

    # stationary phase: late regret below the unprofiled start (within noise)
    assert np.mean(regret[drift_at - 2:drift_at]) < np.mean(regret[:2]) + 1e-6
    # drift recovery: the spike lands within a segment or two of the event
    # (estimates only refresh at segment boundaries); the loop must end back
    # near the oracle afterwards
    assert regret[-1] < max(regret[drift_at:drift_at + 2]) + 0.05
    assert regret[-1] < 0.25


def test_adaptive_engine_profiled_prior_matches_oracle_immediately():
    """With the profiled prior and no drift, segment 0 already places like
    the true-D engine (the estimator starts *at* the oracle's matrix)."""
    servers = [M1, M2]
    seg = _pair_trace(M1, seed=9, n_arrivals=16)
    adaptive = AdaptiveEngine(servers, prior="profiled", scatter="numpy")
    res = adaptive.run(seg, segments=1)
    oracle = ConsolidationEngine(
        servers, D=[profile_pairwise_fast(s) for s in servers])
    want = oracle.run(sorted(seg, key=lambda tw: tw[0]), backend="jax")
    assert res.segments[0].placements == want.placements
    assert res.segments[0].makespan == pytest.approx(want.makespan, rel=1e-6)


# --- the device-resident stream (ISSUE 4 tentpole) ---------------------------

def _obs_batch(rng, m=1, B=64):
    """A synthetic host batch with co-runs, solos, and lost-frac outliers."""
    t = rng.integers(0, T, B).astype(np.int32)
    co = np.zeros((B, T))
    for b in range(B):
        for c in rng.choice(T, size=rng.integers(0, 4)):
            co[b, c] += 1.0
    y = rng.normal(0.0, 0.3, B) + 1.0
    return ObservationLog(
        wtype=t, server=rng.integers(0, m, B).astype(np.int32),
        duration=np.ones(B), rate=np.exp(y), geo_rate=np.exp(y), co_counts=co,
        lost_frac=(rng.random(B) < 0.1) * 0.9)


def test_update_device_matches_host_estimator():
    """Acceptance: the fused device path reproduces the host numpy estimator
    on the same observation stream (L, log_b, n_pair within atol 1e-5)."""
    rng = np.random.default_rng(0)
    kw = dict(T=T, prior_D=0.0, lr=0.5, decay=0.995, confidence_floor=2.0,
              scatter="numpy")
    host, dev = StreamingEstimator(**kw), StreamingEstimator(**kw)
    used_h = used_d = 0
    for _ in range(12):
        log = _obs_batch(rng)
        used_h += host.update(log)
        used_d += dev.update_device(block_from_log(log))
    assert used_h == used_d and host.n_obs == dev.n_obs
    np.testing.assert_allclose(host.L, dev.L, atol=1e-5)
    np.testing.assert_allclose(host.log_b, dev.log_b, atol=1e-5)
    np.testing.assert_allclose(host.n_pair, dev.n_pair, atol=1e-5)
    np.testing.assert_allclose(host.n_base, dev.n_base, atol=1e-5)
    np.testing.assert_allclose(host.estimate_D(), dev.estimate_D(), atol=1e-5)


def test_update_device_matches_host_on_engine_telemetry():
    """Same acceptance contract on real engine traces: telemetry='device'
    blocks fed through update_device land where the host log path lands."""
    engine = ConsolidationEngine([M1], D=profile_pairwise_fast(M1))
    kw = dict(T=T, prior_D=0.0, prior_solo=type_tables(M1)["solo"], lr=0.6,
              decay=0.9926, confidence_floor=2.0, scatter="numpy")
    host, dev = StreamingEstimator(**kw), StreamingEstimator(**kw)
    for r in range(3):
        arrivals = _pair_trace(M1, seed=100 + r)
        res_h = engine.run(arrivals, backend="jax", telemetry=True)
        res_d = engine.run(arrivals, backend="jax", telemetry="device")
        assert res_d.observations is None and res_d.stream_block is not None
        uh = host.update(res_h.observations)
        ud = dev.update_device(res_d.stream_block, server=0)
        assert uh == ud
    np.testing.assert_allclose(host.L, dev.L, atol=1e-5)
    np.testing.assert_allclose(host.log_b, dev.log_b, atol=1e-5)
    np.testing.assert_allclose(host.n_pair, dev.n_pair, atol=1e-5)


def test_observation_ring_wrap_and_validity():
    """Rows keep their fixed shape; the mask -- not host filtering -- voids
    incomplete rows; once full, the oldest rows are overwritten."""
    rng = np.random.default_rng(1)
    ring = ObservationRing(capacity=96, T=T)
    logs = [_obs_batch(rng, B=40) for _ in range(4)]
    for log in logs:
        blk = ring.push(block_from_log(log))
        assert blk.rows == 40
    assert len(ring) == 96 and ring.total == 160 and ring.ptr == 160 % 96
    # the ring holds exactly the newest 96 rows (all valid here)
    held = ring.host_log()
    want = ObservationLog.merge(logs).select(np.arange(160 - 96, 160))
    np.testing.assert_array_equal(np.sort(held.wtype), np.sort(want.wtype))
    # invalid rows occupy slots but are masked out of the host view
    blk = block_from_log(_obs_batch(rng, B=10))
    blk = blk._replace(scalars=np.asarray(blk.scalars).copy())
    scalars = np.asarray(blk.scalars)
    scalars[::2, 3] = 0.0  # void every other row
    import jax.numpy as jnp

    ring2 = ObservationRing(capacity=16, T=T)
    ring2.push(blk._replace(scalars=jnp.asarray(scalars)))
    assert len(ring2) == 10
    assert len(ring2.host_log()) == 5
    # oversize pushes keep only the newest capacity rows
    ring3 = ObservationRing(capacity=8, T=T)
    ring3.push(block_from_log(_obs_batch(rng, B=20)))
    assert len(ring3) == 8 and ring3.total == 8


def test_estimator_bank_matches_per_server_updates():
    """One banked fused update == m independent per-server host updates."""
    m = 3
    rng = np.random.default_rng(2)
    kw = dict(T=T, prior_D=0.0, lr=0.5, decay=0.995, confidence_floor=2.0,
              scatter="numpy")
    hosts = [StreamingEstimator(**kw) for _ in range(m)]
    bank = EstimatorBank([StreamingEstimator(**kw) for _ in range(m)])
    for _ in range(6):
        log = _obs_batch(rng, m=m, B=96)
        used_h = sum(hosts[s].update(log.for_server(s)) for s in range(m))
        used_b = bank.update_device(block_from_log(log))
        assert used_h == used_b
    for s in range(m):
        np.testing.assert_allclose(hosts[s].L, bank.estimators[s].L, atol=1e-5)
        np.testing.assert_allclose(hosts[s].log_b, bank.estimators[s].log_b,
                                   atol=1e-5)
        np.testing.assert_allclose(hosts[s].n_pair, bank.estimators[s].n_pair,
                                   atol=1e-5)
        assert hosts[s].n_obs == bank.estimators[s].n_obs


def test_adaptive_engine_stream_mode_matches_host_mode():
    """stream=True (ring + banked device updates, no host ObservationLog)
    places like the host-log loop and lands on the same estimates."""
    servers = [M1, M2]
    rng = np.random.default_rng(7)
    seg = []
    t = 0.0
    for _ in range(20):
        w = _POOL[int(rng.integers(len(_POOL)))]
        t += float(rng.exponential(2e-5))
        seg.append((t, Workload(fs=w.fs, rs=w.rs, data_total=w.fs * 6)))
    arrivals = [(t + j * 10.0, w) for j in range(4) for t, w in seg]

    host = AdaptiveEngine(servers, prior=0.0, decay=0.996, scatter="jnp")
    res_h = host.run(arrivals, segments=4)
    stream = AdaptiveEngine(servers, prior=0.0, decay=0.996, scatter="jnp",
                            stream=True, ring_capacity=256)
    res_s = stream.run(arrivals, segments=4)

    assert res_s.n_obs == res_h.n_obs
    assert stream.ring.total == sum(len(r.placements) for r in res_s.segments)
    for rh, rs in zip(res_h.segments, res_s.segments):
        assert rh.placements == rs.placements
        assert rs.observations is None  # no host log was materialized
    for s in range(len(servers)):
        np.testing.assert_allclose(
            host.estimators[s].estimate_D(),
            stream.estimators[s].estimate_D(), atol=1e-4)
        np.testing.assert_allclose(
            host.estimators[s].log_b, stream.estimators[s].log_b, atol=1e-4)

    # a ring smaller than a segment bounds the *history*, never the update:
    # estimators still consume every observation (regression: the bank used
    # to be fed the push's capacity-truncated return)
    tiny = AdaptiveEngine(servers, prior=0.0, decay=0.996, scatter="jnp",
                          stream=True, ring_capacity=8)
    res_t = tiny.run(arrivals, segments=4)
    assert res_t.n_obs == res_h.n_obs
    # the ring kept only the newest capacity rows of each oversize push
    assert len(tiny.ring) == 8 and tiny.ring.total == 4 * 8


def test_adaptive_engine_caches_segment_engines():
    """Unchanged specs reuse the engine (set_D swaps only the scoring D);
    drift boundaries rebuild, revisited worlds reuse cached dynamics."""
    servers = [M1, M2]
    plain = AdaptiveEngine(servers, prior=0.0, scatter="numpy")
    e0 = plain.engine_for_segment(0)
    e1 = plain.engine_for_segment(1)
    assert e0 is e1  # no drift: one engine, D refreshed in place
    plain.estimators[0].n_pair = np.full((T, T), 10.0)
    plain.estimators[0].L = np.log1p(-np.full((T, T), 0.3))
    e2 = plain.engine_for_segment(2)
    assert e2 is e0
    np.testing.assert_allclose(np.asarray(e2.cluster.D[0]),
                               plain.estimators[0].estimate_D(), atol=1e-6)

    drift = congestion_at(servers, 2, server=0, factor=0.4)
    drifted = AdaptiveEngine(servers, prior=0.0, drift=drift, scatter="numpy")
    d0, d1 = drifted.engine_for_segment(0), drifted.engine_for_segment(1)
    d2, d3 = drifted.engine_for_segment(2), drifted.engine_for_segment(3)
    assert d0 is d1 and d2 is not d1 and d2 is d3
    assert d0._dyn is not None and d2._dyn is not None  # cached, not lazy
