"""Telemetry subsystem: observation log, streaming estimator, closed loop.

Convergence contract (ISSUE 3): the streaming estimate of D converges to the
``profile_pairwise_fast`` ground truth under stationary traces, and
re-converges after an injected drift. The property tests run under
hypothesis when available (tests/_hyp.py shim) and as deterministic
fixed-seed tests always.
"""
import numpy as np
import pytest

from repro.core import (
    M1,
    M2,
    AdaptiveEngine,
    ConsolidationEngine,
    Workload,
    profile_pairwise_fast,
    snap_to_grid,
)
from repro.core.contention import pair_slowdown_matrices, type_tables
from repro.core.workload import FS_GRID, RS_GRID
from repro.telemetry import (
    ObservationLog,
    StreamingEstimator,
    congestion_at,
    degrade_server,
)

from _hyp import given, settings, st

T = len(RS_GRID) * len(FS_GRID)

# a compact keep-regime pool: pairs stay under M1's physical TDP, so the
# estimator's single-regime model matches profile_pairwise_fast exactly
_POOL = [
    snap_to_grid(Workload(fs=float(fs), rs=float(rs)))
    for fs in FS_GRID[9:12]  # 512KB .. 2MB
    for rs in RS_GRID[5:7]  # 32KB, 64KB
]


# --- synthetic-observation helpers ------------------------------------------

def _truth(server):
    tt = type_tables(server)
    d_keep, _ = pair_slowdown_matrices(server)
    L = np.log1p(-np.clip(d_keep, 0.0, 1.0 - 1e-9))
    return tt["solo"], L, np.clip(-np.expm1(L), 0.0, 1.0)


def _synthetic_batch(rng, pool_idx, solo, L, B=64, noise=0.0):
    t = rng.choice(pool_idx, size=B)
    co = np.zeros((B, T))
    for b in range(B):
        # co-run sizes 0..3: the solo (size-0) observations anchor the base
        for c in rng.choice(pool_idx, size=rng.integers(0, 4)):
            co[b, c] += 1.0
    y = np.log(solo[t]) + np.einsum("bu,ub->b", co, L[:, t])
    if noise:
        y = y + rng.normal(0.0, noise, B)
    return ObservationLog(
        wtype=t.astype(np.int32), server=np.zeros(B, np.int32),
        duration=np.ones(B), rate=np.exp(y), geo_rate=np.exp(y), co_counts=co,
        lost_frac=np.zeros(B))


def _check_synthetic_convergence(seed):
    solo, L, D_true = _truth(M1)
    rng = np.random.default_rng(seed)
    pool_idx = rng.choice(T, size=8, replace=False)
    est = StreamingEstimator(T=T, prior_D=0.0, prior_solo=solo, lr=0.6,
                             confidence_floor=2.0, scatter="numpy")
    for _ in range(60):
        est.update(_synthetic_batch(rng, pool_idx, solo, L, noise=0.005))
    mask = est.observed_mask()
    assert mask.sum() >= len(pool_idx)  # the pool's pairs were actually seen
    err = np.abs(est.estimate_D() - D_true)[mask]
    assert err.max() < 0.03, err.max()
    sub = np.ix_(pool_idx, pool_idx)
    solo_err = np.abs(np.log(est.estimate_solo() / solo))[pool_idx]
    assert solo_err.max() < 0.02
    return est, pool_idx, sub


def test_estimator_converges_synthetic_stationary():
    _check_synthetic_convergence(0)


@settings(max_examples=10, deadline=None)
@given(st.integers(min_value=1, max_value=10_000))
def test_estimator_converges_synthetic_stationary_property(seed):
    _check_synthetic_convergence(seed)


def _check_synthetic_drift_reconvergence(seed):
    """After converging to world 1, feed world-2 observations: the estimate
    must leave world 1 and land on world 2 (the batch-local update tracks
    regardless of accumulated confidence)."""
    est, pool_idx, sub = _check_synthetic_convergence(seed)
    solo1, _, D1 = _truth(M1)
    drifted = degrade_server(M1, factor=0.5)
    solo2, L2, D2 = _truth(drifted)
    assert np.abs(D1[sub] - D2[sub]).max() > 0.01  # the drift is observable
    rng = np.random.default_rng(seed + 1)
    # farther to travel than from the fresh prior: world 1 -> world 2
    for _ in range(150):
        est.update(_synthetic_batch(rng, pool_idx, solo2, L2, noise=0.005))
    mask = est.observed_mask()
    err2 = np.abs(est.estimate_D() - D2)[mask]
    assert err2.max() < 0.03, err2.max()
    solo_err = np.abs(np.log(est.estimate_solo() / solo2))[pool_idx]
    assert solo_err.max() < 0.03


def test_estimator_reconverges_after_drift():
    _check_synthetic_drift_reconvergence(0)


@settings(max_examples=5, deadline=None)
@given(st.integers(min_value=1, max_value=10_000))
def test_estimator_reconverges_after_drift_property(seed):
    _check_synthetic_drift_reconvergence(seed)


def test_estimator_prior_fallback_below_confidence_floor():
    prior = np.full((T, T), 0.2)
    est = StreamingEstimator(T=T, prior_D=prior, scatter="numpy")
    np.testing.assert_allclose(est.estimate_D(), prior, atol=1e-7)
    assert not est.observed_mask().any()


# --- engine-driven observations (the real loop) ------------------------------

def _pair_trace(server, seed, n_arrivals=48, passes=3.0):
    """Well-separated co-run events: mostly simultaneous pairs, with solo
    runs mixed in. The solos matter: pair-only telemetry determines only
    log_b_t + L[u, t] (base rate and pair effect shift together along an
    unidentifiable direction); solo observations anchor the base. Always
    exactly ``n_arrivals`` long so every engine run shares one jit shape."""
    rng = np.random.default_rng(seed)
    arrivals, k = [], 0
    while len(arrivals) < n_arrivals:
        group = 1 if rng.random() < 0.35 else 2
        for w in rng.choice(len(_POOL), size=group):
            wl = _POOL[w]
            arrivals.append(
                (k * 1.0, Workload(fs=wl.fs, rs=wl.rs, data_total=wl.fs * passes)))
        k += 1
    return arrivals[:n_arrivals]


def _check_engine_convergence(server, est, seed, rounds=5,
                              tol_max=0.03, tol_mean=0.01):
    """Stream engine telemetry into ``est``; assert it landed on the profile."""
    engine = ConsolidationEngine([server], D=profile_pairwise_fast(server))
    for r in range(rounds):
        res = engine.run(_pair_trace(server, seed + 17 * r), backend="jax",
                         telemetry=True)
        est.update(res.observations)
    D_true = profile_pairwise_fast(server)
    mask = est.observed_mask()
    assert mask.sum() >= 10
    err = np.abs(est.estimate_D() - D_true)[mask]
    assert err.max() < tol_max, err.max()
    assert err.mean() < tol_mean, err.mean()
    return est


def _fresh_estimator():
    return StreamingEstimator(
        T=T, prior_D=0.0, prior_solo=type_tables(M1)["solo"], lr=0.6,
        decay=0.7, confidence_floor=2.0, scatter="numpy")


def test_estimate_converges_to_profiled_D_from_engine_trace():
    """The headline contract: telemetry from the device engine alone recovers
    the 52 900-pair profiled matrix on the pairs the trace exercised."""
    _check_engine_convergence(M1, _fresh_estimator(), seed=0)


@settings(max_examples=3, deadline=None)
@given(st.integers(min_value=1, max_value=1_000))
def test_estimate_converges_to_profiled_D_from_engine_trace_property(seed):
    _check_engine_convergence(M1, _fresh_estimator(), seed=seed)


def test_estimate_reconverges_after_server_degradation():
    """Inject a drift (degraded server): the same estimator, fed telemetry
    from the degraded world, re-converges to the degraded profile (base
    rates halve -- the big observable -- and the pair matrix follows)."""
    est = _check_engine_convergence(M1, _fresh_estimator(), seed=3)
    drifted = degrade_server(M1, factor=0.5)
    solo_drift = np.log(type_tables(drifted)["solo"] / type_tables(M1)["solo"])
    assert np.abs(solo_drift).max() > 0.5  # the drift is observable
    est = _check_engine_convergence(drifted, est, seed=29, rounds=16,
                                    tol_max=0.06, tol_mean=0.02)
    # the estimator tracked the halved base rates from solo telemetry alone
    seen = est.n_base > 1.0
    assert seen.sum() >= 4
    base_err = np.abs(est.log_b - np.log(type_tables(drifted)["solo"]))[seen]
    assert base_err.max() < 0.05, base_err.max()


# --- observation-log semantics ----------------------------------------------

def test_observation_log_from_engine_is_physical():
    engine = ConsolidationEngine([M1], D=profile_pairwise_fast(M1))
    res = engine.run(_pair_trace(M1, seed=1), backend="jax", telemetry=True)
    obs = res.observations
    assert len(obs) == 48  # every arrival completed
    solo = type_tables(M1)["solo"]
    # observed rates can never beat solo by more than f32 noise
    assert np.all(obs.rate <= solo[obs.wtype] * 1.01)
    assert np.all(obs.geo_rate <= solo[obs.wtype] * 1.01)
    # pairs launched together: each saw about one co-resident on average
    assert obs.co_counts.sum(axis=1).mean() > 0.3
    assert np.all((obs.lost_frac >= 0.0) & (obs.lost_frac <= 1.0))
    assert np.all(obs.duration > 0.0)
    # telemetry must not perturb the run itself
    res0 = engine.run(_pair_trace(M1, seed=1), backend="jax")
    assert res0.placements == res.placements
    assert res0.makespan == res.makespan
    assert res0.observations is None


# --- the closed loop ---------------------------------------------------------

def _replayed_trace(segment, k):
    return [(t + j * 10.0, w) for j in range(k) for t, w in segment]


def test_adaptive_engine_regret_shrinks_and_recovers():
    """Acceptance: segment durations of the adaptive engine approach the
    true-D oracle's as observations accumulate, and recover after a drift."""
    servers = [M1, M2]
    rng = np.random.default_rng(5)
    seg, t = [], 0.0
    for _ in range(24):
        w = _POOL[int(rng.integers(len(_POOL)))]
        t += float(rng.exponential(2e-5))
        seg.append((t, Workload(fs=w.fs, rs=w.rs, data_total=w.fs * 8)))
    K, drift_at = 8, 5
    # congestion moves the D-matrix itself (degrade_server mostly moves base
    # rates, which placement does not consult -- no regret spike to recover)
    drift = congestion_at(servers, drift_at, server=0, factor=0.4)

    adaptive = AdaptiveEngine(servers, prior=0.0, drift=drift, decay=0.9,
                              scatter="numpy")
    res = adaptive.run(_replayed_trace(seg, K), segments=K)
    assert res.total_obs >= K * len(seg) // 2

    mk = {}
    for k in range(K):
        specs = drift.specs_at(servers, k)
        if specs not in mk:
            oracle = ConsolidationEngine(
                list(specs), D=[profile_pairwise_fast(s) for s in specs])
            mk[specs] = oracle.run(seg, backend="jax").makespan - seg[0][0]
        assert mk[specs] > 0
    regret = [res.durations[k] / mk[drift.specs_at(servers, k)] - 1.0
              for k in range(K)]

    # stationary phase: late regret below the unprofiled start (within noise)
    assert np.mean(regret[drift_at - 2:drift_at]) < np.mean(regret[:2]) + 1e-6
    # drift recovery: the spike lands within a segment or two of the event
    # (estimates only refresh at segment boundaries); the loop must end back
    # near the oracle afterwards
    assert regret[-1] < max(regret[drift_at:drift_at + 2]) + 0.05
    assert regret[-1] < 0.25


def test_adaptive_engine_profiled_prior_matches_oracle_immediately():
    """With the profiled prior and no drift, segment 0 already places like
    the true-D engine (the estimator starts *at* the oracle's matrix)."""
    servers = [M1, M2]
    seg = _pair_trace(M1, seed=9, n_arrivals=16)
    adaptive = AdaptiveEngine(servers, prior="profiled", scatter="numpy")
    res = adaptive.run(seg, segments=1)
    oracle = ConsolidationEngine(
        servers, D=[profile_pairwise_fast(s) for s in servers])
    want = oracle.run(sorted(seg, key=lambda tw: tw[0]), backend="jax")
    assert res.segments[0].placements == want.placements
    assert res.segments[0].makespan == pytest.approx(want.makespan, rel=1e-6)
