"""Metrics plane: histogram math, chunk-invariant merge, counter exactness.

The contract the ``repro.obs`` frame makes (DESIGN.md §14): percentiles
extracted from the log-binned histograms agree with ``numpy.percentile`` to
within quantization (1.5 bin widths in log space); per-segment frames merge
bit-exactly to the single-pass frame (integer-valued f32 weights keep the
accumulation associative); and the counters are *exact* -- they bit-match
host-visible oracle counts from the same run, on both the host-alternating
path and the fused device loop.
"""
from __future__ import annotations

import numpy as np
import pytest
from numpy.random import default_rng

from _hyp import given, settings, st
from repro.configs.base import MeshConfig
from repro.core import M1, M2, AdaptiveEngine, ConsolidationEngine, Workload, snap_to_grid
from repro.core.workload import FS_GRID, RS_GRID
from repro.fleet import FleetController
from repro.obs import metrics as M
from repro.obs.report import render_report
from repro.telemetry import gradual_decay

SEG_GAP = 10.0


def _segment(seed: int, n: int, gap: float = 2e-5):
    rng = default_rng(seed)
    out, t = [], 0.0
    for _ in range(n):
        fs = float(rng.choice(FS_GRID[10:14]))
        w = snap_to_grid(Workload(fs=fs, rs=float(rng.choice(RS_GRID[5:8])),
                                  data_total=fs * 6))
        t += float(rng.exponential(gap))
        out.append((t, w))
    return out


def _replay(seg, segments):
    return [(t + k * SEG_GAP, w) for k in range(segments) for t, w in seg]


# -- histogram math ------------------------------------------------------------

@pytest.mark.parametrize("spec", M.HISTOGRAMS, ids=lambda s: s.name)
def test_percentiles_match_numpy(spec):
    rng = default_rng(0)
    lo, hi = spec.lo * spec.bin_ratio(), spec.hi / spec.bin_ratio()
    vals = np.exp(rng.uniform(np.log(lo), np.log(hi), size=4096))
    frame = M.observe(M.zeros(1), spec.name, vals.astype(np.float32))
    est = np.asarray(M.percentiles(frame, spec.name, (50.0, 95.0, 99.0)))
    ref = np.percentile(vals, [50.0, 95.0, 99.0])
    tol = 1.5 * np.log(spec.bin_ratio())
    np.testing.assert_array_less(np.abs(np.log(est) - np.log(ref)), tol)


def test_observe_clips_out_of_range():
    spec = M.HISTOGRAMS[0]
    vals = np.array([0.0, spec.lo / 10, spec.hi * 10, np.inf], np.float32)
    frame = M.observe(M.zeros(1), spec.name, vals)
    counts = M.hist_counts(frame, spec.name)
    assert counts.sum() == len(vals)
    assert counts[0] == 2 and counts[-1] == 2  # under -> first, over -> last


@settings(max_examples=20, deadline=None)
@given(st.integers(min_value=0, max_value=10_000), st.integers(1, 12),
       st.integers(1, 400))
def test_merge_chunk_invariance(seed, chunks, n):
    """Any chunking of an observation stream merges to the bit-identical
    frame: counters add, gauges max, histogram bins add -- all associative
    for integer-valued f32 accumulation below 2^24."""
    rng = default_rng(seed)
    spec = M.HISTOGRAMS[seed % len(M.HISTOGRAMS)]
    vals = np.exp(rng.uniform(np.log(spec.lo / 10), np.log(spec.hi * 10),
                              size=n)).astype(np.float32)
    whole = M.observe(M.zeros(2), spec.name, vals)
    whole = M.count(whole, "events", n)
    whole = M.gauge_max(whole, "queue_peak", float(n))
    parts = M.zeros(2)
    for chunk in np.array_split(vals, chunks):
        part = M.observe(M.zeros(2), spec.name, chunk)
        part = M.count(part, "events", len(chunk))
        part = M.gauge_max(part, "queue_peak", float(len(chunk)))
        parts = M.merge(parts, part)
    for field in ("counters", "hist"):
        np.testing.assert_array_equal(np.asarray(getattr(whole, field)),
                                      np.asarray(getattr(parts, field)))
    assert M.gauge_value(parts, "queue_peak") == float(n)


# -- counter exactness: single-run engine --------------------------------------

def _engine_run(n=16):
    arrivals = []
    for i in range(n):
        w = snap_to_grid(Workload(
            fs=FS_GRID[(5 * i) % len(FS_GRID)], rs=RS_GRID[i % len(RS_GRID)],
            data_total=48e6))
        arrivals.append((0.5 * i, w))
    engine = ConsolidationEngine([M1, M2], backend="jax")
    return engine.run(arrivals, metrics=True)


def test_engine_counters_match_host_oracle():
    res = _engine_run()
    frame = res.metrics
    assert M.counter_value(frame, "arrivals") == len(res.placements)
    placed = sum(1 for p in res.placements if p is not None)
    assert M.counter_value(frame, "placements") == placed
    assert M.counter_value(frame, "queued") == sum(res.was_queued)
    assert M.counter_value(frame, "finishes") == sum(
        1 for t in res.finish_times if np.isfinite(t))
    assert M.counter_value(frame, "deadlocks") == 0
    per_server = M.server_values(frame, "placements")
    for s in range(2):
        assert int(per_server[s]) == sum(1 for p in res.placements if p == s)
    # one waiting-time and one headroom sample per successful placement
    for hist in ("waiting_time", "headroom"):
        assert int(M.hist_counts(frame, hist).sum()) == placed


def test_metrics_off_returns_none():
    engine = ConsolidationEngine([M1, M2], backend="jax")
    res = engine.run([(0.0, snap_to_grid(Workload(fs=FS_GRID[12],
                                                  rs=RS_GRID[5],
                                                  data_total=48e6)))])
    assert res.metrics is None


def test_metrics_requires_jax_backend():
    engine = ConsolidationEngine([M1, M2], backend="numpy")
    with pytest.raises(ValueError, match="jax"):
        engine.run([(0.0, snap_to_grid(Workload(fs=FS_GRID[12],
                                                rs=RS_GRID[5],
                                                data_total=48e6)))],
                   metrics=True)


# -- counter exactness: adaptive runs, health bit-match ------------------------

def _adaptive(m=3, drift=None):
    return AdaptiveEngine([M1] * m, prior=0.0, decay=0.997,
                          drift=drift, fleet=FleetController(mesh=MeshConfig()),
                          ring_capacity=256)


def test_eviction_counters_bitmatch_health():
    """The decisive fleet scenario: splits/evictions/requeues counters must
    equal the host-visible health-event and requeue counts of the SAME run."""
    segments, n_seg, failing = 6, 14, 1
    servers = [M1] * 3
    drift = gradual_decay(servers, server=failing, rate=0.65, start=1,
                          segments=segments)
    arrivals = _replay(_segment(11, n_seg), segments)
    eng = _adaptive(drift=drift)
    res = eng.run(arrivals, segments=segments, metrics=True)
    frame = res.metrics
    events = [ev for evs in res.health for ev in evs]
    assert M.counter_value(frame, "evictions") == sum(
        1 for ev in events if ev.kind == "evict") > 0
    assert M.counter_value(frame, "splits") == sum(
        1 for ev in events if ev.kind == "split")
    # every requeued job is placed twice: once before the eviction, once after
    total_placed = sum(len(seg.placements) for seg in res.segments)
    assert M.counter_value(frame, "requeues") == total_placed - len(arrivals) > 0
    assert M.counter_value(frame, "segments") == segments
    text = render_report(res, title="eviction run")
    assert "health-event timeline:" in text and "evict" in text


def test_host_device_metrics_parity():
    """The fused device loop and the host oracle produce the same decision
    counters, per-server columns, and event histograms bit-for-bit.  Device-
    only extras are excluded: ``d_cols_refreshed`` counts posterior-D column
    refreshes the host path does wholesale, and the ``cusum_level`` histogram
    is only observable inside the compiled detector."""
    segments, n_seg = 6, 12
    arrivals = _replay(_segment(11, n_seg), segments)
    frames = []
    for device_loop in (False, True):
        eng = AdaptiveEngine([M1] * 3, prior=0.0, decay=1.0, stream=True,
                             fleet=FleetController(mesh=MeshConfig()),
                             ring_capacity=256)
        res = eng.run(arrivals, segments=segments, device_loop=device_loop,
                      metrics=True)
        frames.append(res.metrics)
    host, dev = frames
    shared = [c for c in M.COUNTERS if c != "d_cols_refreshed"]
    for name in shared:
        assert M.counter_value(host, name) == M.counter_value(dev, name), name
    np.testing.assert_array_equal(np.asarray(host.per_server),
                                  np.asarray(dev.per_server))
    for spec in M.HISTOGRAMS:
        if spec.name == "cusum_level":
            continue
        np.testing.assert_array_equal(M.hist_counts(host, spec.name),
                                      M.hist_counts(dev, spec.name),
                                      err_msg=spec.name)
    assert M.counter_value(dev, "arrivals") == len(arrivals)


def test_adaptive_metrics_off_returns_none():
    arrivals = _replay(_segment(3, 4), 2)
    eng = AdaptiveEngine([M1] * 2, prior=0.0, stream=True)
    res = eng.run(arrivals, segments=2)
    assert res.metrics is None
    res_dev = eng.run(arrivals, segments=2, device_loop=True)
    assert res_dev.metrics is None
