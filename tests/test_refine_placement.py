"""Beyond-paper: local-search refinement + consolidation-aware loader placement."""
import numpy as np
import pytest

from repro.core import M1, M2, ClusterState, Workload, greedy_sequence, profile_pairwise_fast, snap_to_grid
from repro.core.refine import local_search
from repro.core.units import KB, MB
from repro.data import synthetic_store
from repro.data.placement import max_safe_ranks_per_host, place_loaders


def test_local_search_never_worse_and_stays_feasible():
    servers = [M1, M2]
    D = [profile_pairwise_fast(s) for s in servers]
    rng = np.random.default_rng(3)
    state = ClusterState.empty(servers, D, alpha=1.3)
    ws = [snap_to_grid(Workload(fs=float(rng.choice([256 * KB, 1 * MB, 4 * MB])),
                                rs=float(rng.choice([16 * KB, 64 * KB, 256 * KB]))))
          for _ in range(8)]
    # deliberately bad assignment: everything that fits on server 0
    for w in ws:
        state.assignments[0].append(w)
        if not state.check(0).ok:
            state.assignments[0].pop()
            state.assignments[1].append(w)
    before = state.total_avg_load()
    refined, n = local_search(state)
    assert refined.total_avg_load() <= before + 1e-12
    assert refined.feasible()


def test_local_search_improves_unbalanced_packing():
    servers = [M1, M1]
    D = [profile_pairwise_fast(M1)] * 2
    state = ClusterState.empty(servers, D, alpha=1.3)
    w = snap_to_grid(Workload(fs=1 * MB, rs=64 * KB))
    state.assignments[0] = [w, w, w]  # lopsided but feasible
    before = state.total_avg_load()
    refined, n = local_search(state)
    assert n >= 1
    assert refined.total_avg_load() < before
    sizes = sorted(len(a) for a in refined.assignments)
    assert sizes == [1, 2]  # rebalanced


def test_loader_placement_respects_host_capacity():
    store = synthetic_store(block_mb=64)
    placements, state = place_loaders(store, n_ranks=12, hosts=[M1, M2])
    assert state.feasible()
    placed = [p for p in placements if p.host is not None]
    queued = [p for p in placements if p.host is None]
    assert len(placed) >= 2
    # per-host safe capacity bounds what the greedy placed there
    cap1 = max_safe_ranks_per_host(store, M1)
    per_host = {h: sum(1 for p in placed if p.host == h) for h in (0, 1)}
    assert per_host[0] <= cap1
    # the 64MB-chunk loader streams past the LLC: capacity is bandwidth-bound
    assert 1 <= cap1 <= 8


def test_greedy_plus_refine_beats_greedy_alone_or_ties():
    servers = [M1, M2, M1]
    D = [profile_pairwise_fast(s) for s in servers[:2]] + [D0 := None]
    D[2] = D[0]
    rng = np.random.default_rng(11)
    ws = [snap_to_grid(Workload(fs=float(rng.choice([512 * KB, 2 * MB, 16 * MB])),
                                rs=float(rng.choice([8 * KB, 64 * KB, 512 * KB]))))
          for _ in range(9)]
    state = ClusterState.empty(servers, D, alpha=1.3)
    _, queued = greedy_sequence(state, ws)
    g = state.total_avg_load()
    refined, _ = local_search(state)
    assert refined.total_avg_load() <= g + 1e-12
