"""Device-loop equivalence: the fused closed loop vs the host oracle.

PR 7's tentpole compiles the whole observe -> estimate -> detect -> act
cycle into one ``lax.scan`` program (``core.closed_loop``); the
host-alternating ``AdaptiveEngine.run`` path is kept as the reference
oracle.  These tests pin the contract that makes that safe: *decisions* --
placements, queueing, split/evict events and their timing, requeue routing,
pool row maps, active masks -- are identical, and *float state* -- posterior
D, CUSUM statistics -- agrees to tolerance (the fused path fuses the same
arithmetic differently, so 1e-8-scale FMA drift is expected and absorbed by
the scheduler's score-margin tie collapse before it can reach a decision).
"""
from __future__ import annotations

import numpy as np
import pytest
from numpy.random import default_rng

import jax.numpy as jnp

from _hyp import given, settings, st
from repro.configs.base import MeshConfig
from repro.core import M1, AdaptiveEngine, Workload, snap_to_grid
from repro.core.workload import FS_GRID, RS_GRID
from repro.fleet import FleetController
from repro.telemetry import gradual_decay, stochastic_congestion

SEG_GAP = 10.0


def _segment(seed: int, n: int, gap: float = 2e-5):
    rng = default_rng(seed)
    out, t = [], 0.0
    for _ in range(n):
        fs = float(rng.choice(FS_GRID[10:14]))
        w = snap_to_grid(Workload(fs=fs, rs=float(rng.choice(RS_GRID[5:8])),
                                  data_total=fs * 6))
        t += float(rng.exponential(gap))
        out.append((t, w))
    return out


def _replay(seg, segments):
    return [(t + k * SEG_GAP, w) for k in range(segments) for t, w in seg]


def _run_pair(arrivals, segments, *, drift=None, m=3, decay=0.997, seed=11):
    """The same run down both paths; returns (host, device) triples."""
    out = []
    for device_loop in (False, True):
        servers = [M1] * m
        fleet = FleetController(mesh=MeshConfig())
        eng = AdaptiveEngine(servers, prior=0.0, decay=decay,
                             drift=drift([M1] * m) if drift else None,
                             fleet=fleet, ring_capacity=256)
        res = eng.run(arrivals, segments=segments, device_loop=device_loop)
        out.append((eng, fleet, res))
    return out


def _events(res):
    return [(ev.kind, ev.server, ev.segment)
            for evs in res.health for ev in evs]


def _assert_equivalent(host, dev, tol=1e-5):
    (h_eng, h_fleet, h_res), (d_eng, d_fleet, d_res) = host, dev
    # decisions: exact
    for k, (a, b) in enumerate(zip(h_res.segments, d_res.segments)):
        assert list(a.placements) == list(b.placements), f"segment {k}"
        assert list(a.was_queued) == list(b.was_queued), f"segment {k}"
    assert _events(h_res) == _events(d_res)
    assert list(h_res.n_obs) == list(d_res.n_obs)
    assert np.array_equal(h_fleet.pool.row_of, d_fleet.pool.row_of)
    assert np.array_equal(h_fleet.pool._read_row, d_fleet.pool._read_row)
    assert np.array_equal(h_fleet.active_mask(), d_fleet.active_mask())
    assert len(h_fleet.plans) == len(d_fleet.plans)
    assert h_eng.ring.total == d_eng.ring.total
    # float state: tolerance-bounded
    hD, dD = np.stack(h_fleet.current_D()), np.stack(d_fleet.current_D())
    np.testing.assert_allclose(dD, hD, atol=tol)
    for a, b in zip(h_fleet.detector.state, d_fleet.detector.state):
        np.testing.assert_allclose(np.asarray(b), np.asarray(a), atol=tol)
    for a, b in zip(h_res.segments, d_res.segments):
        for x, y in zip(a.finish_times, b.finish_times):
            assert x == pytest.approx(y, rel=1e-4)


def test_stationary_equivalence():
    arrivals = _replay(_segment(11, 12), 6)
    host, dev = _run_pair(arrivals, 6)
    _assert_equivalent(host, dev)


def test_stochastic_congestion_equivalence():
    def drift(servers):
        return stochastic_congestion(servers, rate=0.3, seed=5, segments=6,
                                     servers=[1, 2])

    arrivals = _replay(_segment(7, 12), 6)
    host, dev = _run_pair(arrivals, 6, drift=drift)
    _assert_equivalent(host, dev)


def test_eviction_timing_equivalence():
    """The decisive case: a decaying server must be evicted in the SAME
    segment down both paths, with its in-flight work requeued identically
    (mirrors test_fleet's gradual-decay end-to-end scenario)."""
    segments, n_seg, failing = 6, 14, 1

    def drift(servers):
        return gradual_decay(servers, server=failing, rate=0.65, start=1,
                             segments=segments)

    arrivals = _replay(_segment(11, n_seg), segments)
    host, dev = _run_pair(arrivals, segments, drift=drift)
    _assert_equivalent(host, dev)
    evs = _events(host[2])
    evicts = [(s, seg) for kind, s, seg in evs if kind == "evict"]
    assert evicts and evicts[0][0] == failing, evs
    k_ev = evicts[0][1]
    # the requeue lands in the next segment, identically on both paths
    for _, _, res in (host, dev):
        assert len(res.segments[k_ev + 1].placements) > n_seg
        after = [p for r in res.segments[k_ev + 1:] for p in r.placements]
        assert failing not in after


@settings(max_examples=5, deadline=None)
@given(st.integers(min_value=0, max_value=10_000), st.integers(1, 8),
       st.integers(1, 3))
def test_chunk_invariance(seed, segments, n_seg):
    """Equivalence is not an artifact of one segment split: for arbitrary
    (segments, jobs-per-segment) chunkings of a stream, the fused loop and
    the host oracle place and queue identically."""
    arrivals = _replay(_segment(seed, n_seg), segments)
    host, dev = _run_pair(arrivals, segments, seed=seed)
    _assert_equivalent(host, dev)


def test_sparse_bank_tables_match_dense():
    """The fused path's sparse decay/co-update (first-occurrence slot
    folding) is the dense ``_bank_core`` arithmetic rearranged into the
    same in-order scatter sums -- the tables must match to float32
    round-off, at decay=1.0 (sparse fast path) and decay<1 alike."""
    from repro.fleet import FleetController as FC
    from repro.telemetry.estimator import _update_bank
    from repro.telemetry.log import RingBlock

    m, T, B = 4, 230, 12
    fleet = FC(mesh=MeshConfig())
    AdaptiveEngine([M1] * m, prior=0.0, fleet=fleet)  # binds the pool
    bank = fleet.pool.bank.stacked_state()
    rng = default_rng(0)
    ints = jnp.asarray(
        np.stack([rng.integers(0, m, B), rng.integers(0, T, B)], 1), jnp.int32)
    sc = jnp.asarray(rng.random((B, 4)) + 0.5, jnp.float32)
    co = jnp.asarray(rng.random((B, T)), jnp.float32)
    block = RingBlock(ints=ints, scalars=sc, co=co)
    for decay in (1.0, 0.997):
        hyp = dict(lr=0.6, decay=decay, step_damp=0.5, solo_eps=0.05,
                   max_lost_frac=0.5, use_pallas=False, interpret=False)
        dense, n_d = _update_bank(bank, block, **hyp)
        sparse, n_s = _update_bank(bank, block, sparse_tables=True, **hyp)
        assert int(n_d) == int(n_s)
        for name in dense._fields:
            np.testing.assert_allclose(
                np.asarray(getattr(sparse, name)),
                np.asarray(getattr(dense, name)),
                atol=1e-6, err_msg=f"{name} @ decay={decay}")


def test_engine_cache_survives_mask_change(monkeypatch):
    """PR-7 satellite: the segment-engine cache keys on (specs, active
    mask) while PackedDynamics caches on specs alone -- a drift schedule
    revisiting a world after an eviction changed the mask must not rebuild
    the dynamics tables."""
    import repro.core.engine as engine_mod

    builds = []
    orig = engine_mod.PackedDynamics.build

    def counting(specs, *a, **kw):
        builds.append(tuple(specs))
        return orig(specs, *a, **kw)

    monkeypatch.setattr(engine_mod.PackedDynamics, "build",
                        staticmethod(counting))
    segments, failing = 6, 1

    def drift(servers):
        return gradual_decay(servers, server=failing, rate=0.65, start=1,
                             segments=segments)

    servers = [M1] * 3
    fleet = FleetController(mesh=MeshConfig())
    eng = AdaptiveEngine(servers, prior=0.0, decay=0.997,
                         drift=drift(servers), fleet=fleet)
    res = eng.run(_replay(_segment(11, 14), segments), segments=segments)
    assert any(ev.kind == "evict" for evs in res.health for ev in evs)
    worlds = {tuple(eng.drift.specs_at(tuple(servers), k))
              for k in range(segments)}
    # one build per distinct world; the mask change after the eviction
    # re-keys the engine cache but reuses every cached dynamics table
    assert len(builds) == len(set(builds)) == len(worlds)


def test_device_loop_rejects_ragged_and_callbacks():
    eng = AdaptiveEngine([M1] * 2, prior=0.0, stream=True)
    arrivals = _replay(_segment(3, 3), 2)
    with pytest.raises(ValueError, match="divisible"):
        eng.run(arrivals, segments=4, device_loop=True)
    with pytest.raises(ValueError, match="on_segment"):
        eng.run(arrivals, segments=2, device_loop=True,
                on_segment=lambda *a: None)
    plain = AdaptiveEngine([M1] * 2, prior=0.0)
    with pytest.raises(ValueError, match="stream"):
        plain.run(arrivals, segments=2, device_loop=True)
