"""TPU-fleet adaptation of the consolidation algorithm (core/cluster.py)."""
import numpy as np
import pytest

from repro.core import (
    FleetState,
    JobProfile,
    PodSpec,
    additive_degradations,
    fleet_throughput_report,
    pack_jobs,
    pair_degradation,
    roofline_degradations,
)
from repro.core.cluster import HBM_BYTES


def _job(name="j", flops=1e15, bytes_=2e14, coll=1e13, hbm=4 * 2**30):
    return JobProfile(name=name, flops=flops, bytes_accessed=bytes_,
                      collective_bytes=coll, hbm_bytes=hbm, chips=256)


def test_step_time_is_max_of_terms():
    j = _job()
    t = j.step_time()
    assert t == pytest.approx(max(
        j.flops / (256 * 197e12), j.bytes_accessed / (256 * 819e9),
        j.collective_bytes / (256 * 50e9)))


def test_demands_sum_to_at_most_count():
    d = _job().demands()
    assert max(d.values()) == pytest.approx(1.0)  # the binding resource saturates
    assert all(0 <= v <= 1 for v in d.values())


def test_pack_respects_hbm_budget():
    fleet = FleetState.empty([PodSpec(name="p0")])
    big = _job(hbm=HBM_BYTES)  # 16GB/device x 256 devices = the whole budget
    placements, fleet = pack_jobs(fleet, [big, big])
    assert placements[0] == 0
    assert placements[1] is None  # second job would exceed HBM -> queued


def test_pack_respects_degradation_rule():
    fleet = FleetState.empty([PodSpec(name="p0")], model="additive")
    jobs = [_job(name=f"j{i}", hbm=2 * 2**30) for i in range(6)]
    placements, fleet = pack_jobs(fleet, jobs)
    d = fleet.degradations(0)
    assert d.size == 0 or d.max() < 0.5
    assert any(p is None for p in placements)  # compute-saturated jobs queue


def test_roofline_model_detects_saturation():
    jobs = [_job(), _job()]  # two fully compute-bound jobs
    d = roofline_degradations(jobs)
    assert np.all(d > 0.4)  # sharing one pipe at 2x demand -> ~50% each
    assert np.all(roofline_degradations([_job()]) == 0.0)


def test_additive_matches_pairwise_at_n2():
    a, b = _job("a"), _job("b", flops=1e14)
    d = additive_degradations([a, b])
    assert d[1] == pytest.approx(pair_degradation(a, b))
    assert d[0] == pytest.approx(pair_degradation(b, a))


def test_report_shapes():
    fleet = FleetState.empty([PodSpec(name="p0"), PodSpec(name="p1")])
    jobs = [_job(name=f"j{i}", flops=2e13, hbm=2**30) for i in range(4)]
    pack_jobs(fleet, jobs)
    rows = fleet_throughput_report(fleet)
    assert len(rows) == sum(len(a) for a in fleet.assignments)
    for r in rows:
        assert r["eff_steps_per_s"] <= r["solo_steps_per_s"] + 1e-9
