"""ConsolidationEngine: the device-resident online runtime vs the oracle.

The acceptance contract of the unification refactor: the jitted
``engine_jax.run_trace`` loop reproduces the pure-Python ``OnlineScheduler``
-- identical placements and queue decisions, makespan within 1e-3 relative --
when both are driven through the same ``ConsolidationEngine`` front-end.
"""
import numpy as np
import pytest

from repro.core import (
    M1,
    M2,
    ConsolidationEngine,
    PackedCluster,
    PackedDynamics,
    Workload,
    corun_rates,
    counts_from_assignments,
    profile_pairwise_fast,
    simulate_corun,
    snap_to_grid,
)
from repro.core.units import KB, MB
from repro.core.workload import FS_GRID, RS_GRID


def _trace(n, gap, passes=1, seed=0, heavy=False):
    rng = np.random.default_rng(seed)
    fs_pool = FS_GRID[12:18] if heavy else FS_GRID[:18]
    rs_pool = RS_GRID[5:] if heavy else RS_GRID
    out, t = [], 0.0
    for _ in range(n):
        fs = float(rng.choice(fs_pool))
        w = snap_to_grid(
            Workload(fs=fs, rs=float(rng.choice(rs_pool)), data_total=fs * passes))
        t += float(rng.exponential(gap))
        out.append((t, w))
    return out


@pytest.fixture(scope="module")
def rack16():
    """16-server rack (alternating M1/M2) with shared profiling passes."""
    servers = [M1, M2] * 8
    return ConsolidationEngine(servers)


def _assert_parity(engine, arrivals, makespan_rtol=1e-3):
    py = engine.run(arrivals, backend="numpy")
    jx = engine.run(arrivals, backend="jax")
    assert jx.placements == py.placements
    assert jx.was_queued == py.was_queued
    assert jx.makespan == pytest.approx(py.makespan, rel=makespan_rtol)
    return py, jx


def test_engine_parity_16srv_64_arrivals(rack16):
    """The acceptance trace: 16 servers, 64 arrivals, jitted end to end."""
    _assert_parity(rack16, _trace(64, gap=1e-3))


def test_engine_parity_queueing_and_drain(rack16):
    """Bursty arrivals force criterion-1 queueing; completions must drain the
    queue in arrival order on both backends."""
    arrivals = _trace(64, gap=2e-5, passes=8, seed=3, heavy=True)
    py, jx = _assert_parity(rack16, arrivals)
    assert sum(py.was_queued) >= 1  # the trace actually exercises the queue
    # queued-then-placed workloads start at/after the first completion
    first_fin = min(t for t in py.finish_times if np.isfinite(t))
    for i in range(len(arrivals)):
        if py.was_queued[i] and py.placements[i] is not None:
            assert jx.place_times[i] >= first_fin - 1e-6


def test_engine_parity_epoch_scale_timestamps(rack16):
    """Absolute wall-clock arrival times must not collapse under f32: the
    engine normalizes to the first arrival before casting."""
    base = 1.7e9
    arrivals = [(base + t, w) for t, w in _trace(48, gap=1e-3, seed=11)]
    py, jx = _assert_parity(rack16, arrivals)
    assert py.makespan > base


def test_engine_parity_single_server_queue():
    """§V single-server scenario: heavy workloads queue, then run to completion."""
    engine = ConsolidationEngine([M1])
    heavy = snap_to_grid(Workload(fs=64 * MB, rs=512 * KB))
    py, jx = _assert_parity(engine, [(0.0, heavy)] * 5)
    assert sum(py.was_queued) >= 1
    assert all(p is not None for p in py.placements)
    assert all(np.isfinite(t) for t in jx.finish_times)


def test_engine_pallas_scorer_matches_oracle():
    """The Pallas Q x m scorer slots into the engine as a drop-in backend."""
    engine = ConsolidationEngine([M1, M2], scorer="pallas")
    arrivals = _trace(12, gap=1e-4, seed=5)
    _assert_parity(engine, arrivals)


def test_engine_max_degradation_close_to_oracle(rack16):
    arrivals = _trace(48, gap=5e-5, passes=4, seed=7)
    py = rack16.run(arrivals, backend="numpy")
    jx = rack16.run(arrivals, backend="jax")
    assert jx.max_observed_degradation == pytest.approx(
        py.max_observed_degradation, abs=1e-3)


def test_corun_rates_match_simulator():
    """The engine's type-table rate model == simulate_corun, per co-run set."""
    import jax.numpy as jnp

    from repro.core.workload import type_index

    servers = [M1, M2]
    D = [profile_pairwise_fast(s) for s in servers]
    cluster = PackedCluster.build(servers, D, alpha=1.3)
    dyn = PackedDynamics.build(servers)
    ws = [snap_to_grid(Workload(fs=fs, rs=rs))
          for fs, rs in [(512 * KB, 64 * KB), (2 * MB, 256 * KB), (64 * MB, 512 * KB)]]
    assignments = [ws, ws[:2]]
    counts = counts_from_assignments(cluster, assignments)
    K = max(len(a) for a in assignments)
    slot_type = np.full((2, K), -1, np.int32)
    for s, a in enumerate(assignments):
        for k, w in enumerate(a):
            slot_type[s, k] = type_index(w)
    rates = np.asarray(corun_rates(cluster, dyn, counts, jnp.asarray(slot_type)))
    for s, a in enumerate(assignments):
        want = simulate_corun(servers[s], a).throughputs
        got = rates[s, :len(a)]
        np.testing.assert_allclose(got, want, rtol=1e-4)


def test_engine_empty_trace_resolves_backend():
    """Empty traces report the backend that *would* have run -- consumers
    branch on ``EngineResult.backend`` uniformly (no 'empty' sentinel)."""
    engine = ConsolidationEngine([M1])
    assert engine.run([]).backend == "numpy"  # auto, below the jit threshold
    assert engine.run([], backend="jax").backend == "jax"
    assert engine.run([], backend="numpy").backend == "numpy"
    res = engine.run([], backend="jax", telemetry=True)
    assert res.backend == "jax" and len(res.observations) == 0
    # telemetry needs the device engine's event loop
    with pytest.raises(ValueError):
        engine.run([], backend="numpy", telemetry=True)
    assert engine.run([], telemetry=True).backend == "jax"  # auto picks jax


def test_engine_deadlock_raises():
    """A workload that fits no empty server deadlocks both backends alike."""
    tiny = ConsolidationEngine([M1], alpha=0.01)  # budget too small for anything
    w = snap_to_grid(Workload(fs=8 * MB, rs=512 * KB))
    with pytest.raises(RuntimeError):
        tiny.run([(0.0, w)], backend="numpy")
    with pytest.raises(RuntimeError):
        tiny.run([(0.0, w)], backend="jax")
