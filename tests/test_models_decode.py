"""Decode-with-cache must reproduce the full teacher-forcing forward exactly
(validates KV caches, recurrent states, token-shift states, cross-KV)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import SMOKES
from repro.models import build_model, materialize

RNG = jax.random.PRNGKey(7)


@pytest.mark.parametrize("arch", sorted(SMOKES))
def test_decode_matches_full_forward(arch):
    cfg = SMOKES[arch]
    if cfg.moe_experts:  # capacity truncation differs between groupings
        cfg = dataclasses.replace(cfg, moe_capacity_factor=8.0)
    model = build_model(cfg)
    params = materialize(model.param_infos(), RNG)
    B, S = 2, 33
    tokens = jax.random.randint(RNG, (B, S), 0, cfg.vocab)
    extras = {}
    if cfg.family == "vlm":
        extras["vis_embeds"] = jax.random.normal(RNG, (B, cfg.vis_tokens, cfg.d_model), jnp.float32)
    if cfg.family == "encdec":
        extras["audio_embeds"] = jax.random.normal(RNG, (B, cfg.enc_seq, cfg.d_model), jnp.float32)

    full_logits, _ = model._forward(params, tokens, None, extras, False)
    cache = materialize(model.cache_infos(B, S + 8), RNG)
    _, cache = model.prefill(params, dict(extras, tokens=tokens[:, : S - 1]), cache)
    dec_logits, _ = model.decode_step(params, cache, tokens[:, S - 1 : S])

    a = np.asarray(full_logits[:, -1, :], np.float32)
    b = np.asarray(dec_logits[:, 0, :], np.float32)
    err = np.abs(a - b).max() / (np.abs(a).max() + 1e-9)
    assert err < 1e-3, f"{arch}: {err}"


@pytest.mark.parametrize("arch", ["llama3.2-3b", "rwkv6-7b", "jamba-v0.1-52b"])
def test_incremental_decode_chain(arch):
    """Prefill + N single-token decodes == one long forward at every step.

    MoE archs need drop-free capacity (truncation differs between the
    per-sequence and per-batch dispatch groupings); rwkv compares in fp32
    (the chunked prefill and the per-token recurrence accumulate in
    different orders, which bf16 amplifies)."""
    cfg = SMOKES[arch]
    if cfg.moe_experts:
        cfg = dataclasses.replace(cfg, moe_capacity_factor=8.0)
    if cfg.family == "ssm":
        cfg = dataclasses.replace(cfg, compute_dtype=jnp.float32)
    model = build_model(cfg)
    params = materialize(model.param_infos(), RNG)
    B, S0, N = 1, 16, 4
    tokens = jax.random.randint(RNG, (B, S0 + N), 0, cfg.vocab)

    cache = materialize(model.cache_infos(B, S0 + N + 4), RNG)
    _, cache = model.prefill(params, {"tokens": tokens[:, :S0]}, cache)
    for t in range(N):
        step_logits, cache = model.decode_step(params, cache, tokens[:, S0 + t : S0 + t + 1])
        full_logits, _ = model._forward(params, tokens[:, : S0 + t + 1], None, {}, False)
        a = np.asarray(full_logits[:, -1], np.float32)
        b = np.asarray(step_logits[:, 0], np.float32)
        err = np.abs(a - b).max() / (np.abs(a).max() + 1e-9)
        assert err < 1e-3, (arch, t, err)


def test_sliding_window_attention_masks_old_tokens():
    """Jamba's windowed attention: tokens beyond the window are invisible."""
    cfg = dataclasses.replace(SMOKES["jamba-v0.1-52b"], sliding_window=8)
    model = build_model(cfg)
    params = materialize(model.param_infos(), RNG)
    B, S = 1, 24
    t1 = jax.random.randint(RNG, (B, S), 0, cfg.vocab)
    # change tokens far outside the window of the last position
    t2 = t1.at[:, 0:4].set((t1[:, 0:4] + 1) % cfg.vocab)
    l1, _ = model._forward(params, t1, None, {}, False)
    l2, _ = model._forward(params, t2, None, {}, False)
    # NOTE: mamba layers still carry state across the whole prefix, so logits
    # are not identical -- but the attention sublayer contribution of the
    # changed tokens must be masked; verify the drift is smallvs a same-window change
    near = t1.at[:, -4:-2].set((t1[:, -4:-2] + 1) % cfg.vocab)
    l3, _ = model._forward(params, near, None, {}, False)
    d_far = float(jnp.abs(l1[:, -1] - l2[:, -1]).max())
    d_near = float(jnp.abs(l1[:, -1] - l3[:, -1]).max())
    assert d_near > d_far
