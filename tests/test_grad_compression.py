"""Gradient compression with error feedback (optim/grad_utils.py), verified
under a real shard_map data-parallel reduction in a subprocess mesh."""
import os
import subprocess
import sys
import textwrap

import jax.numpy as jnp
import numpy as np
import pytest

from repro.optim.grad_utils import compress, decompress

def test_error_feedback_preserves_sum_over_time():
    """With error feedback, the *accumulated* compressed signal converges to
    the accumulated true signal (quantization noise does not bias SGD)."""
    rng = np.random.default_rng(0)
    g_true_sum = np.zeros(256, np.float32)
    g_sent_sum = np.zeros(256, np.float32)
    err = jnp.zeros(256)
    for _ in range(50):
        g = rng.normal(size=256).astype(np.float32) * 0.1
        payload, aux, err = compress(jnp.asarray(g), "int8", err)
        g_sent_sum += np.asarray(decompress(payload, aux, "int8"))
        g_true_sum += g
    # without EF the error would be ~50 * qstep; with EF it stays ~1 qstep
    assert np.abs(g_sent_sum - g_true_sum).max() < 0.02

PROBE = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import PartitionSpec as P
    from repro.optim.grad_utils import compressed_psum_mean
    from repro.models.layers import _shard_map  # the one version-compat shim
    mesh = jax.make_mesh((8,), ("data",))
    grads = {"w": jnp.arange(64, dtype=jnp.float32).reshape(8, 8)}
    def body(g):
        mean, _ = compressed_psum_mean(g, ("data",), method="bf16")
        return mean
    f = jax.jit(_shard_map(body, mesh=mesh, in_specs=({"w": P("data", None)},),
                           out_specs={"w": P("data", None)}, axis_names={"data"}))
    out = np.asarray(f(grads)["w"])
    # psum-mean over shards of rows 0..7: every shard's row i -> mean over shards
    want = np.asarray(grads["w"], np.float32)
    want = np.tile(want.reshape(8, 8).mean(axis=0, keepdims=True), (8, 1))
    np.testing.assert_allclose(out, want, rtol=0.02, atol=0.05)
    print("COMPRESSED-PSUM OK")
""")

@pytest.mark.slow
def test_compressed_psum_under_shard_map():
    env = dict(os.environ)
    env["PYTHONPATH"] = env.get("PYTHONPATH", "") + os.pathsep + os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src"))
    # pin the subprocess to the host platform: the device-count forcing below
    # only applies to CPU, and probing for a TPU runtime hangs in CI sandboxes
    env["JAX_PLATFORMS"] = "cpu"
    r = subprocess.run([sys.executable, "-c", PROBE], capture_output=True, text=True,
                       env=env, timeout=300)
    assert "COMPRESSED-PSUM OK" in r.stdout, r.stdout + r.stderr[-2000:]
