"""Optional-`hypothesis` shim for the property-based tests.

The tier-1 environment does not ship ``hypothesis``; importing it at module
scope used to abort collection of five whole test files. Test modules import
``given``/``settings``/``st`` from here instead: when hypothesis is present
they are the real thing, otherwise ``given`` marks the test skipped and
``st``/``settings`` are inert stand-ins so decorator expressions still
evaluate at import time.
"""
from __future__ import annotations

import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - exercised when hypothesis is absent
    HAVE_HYPOTHESIS = False

    class _AnyStrategy:
        """Stands in for any ``st.<builder>(...)`` call chain."""

        def __call__(self, *args, **kwargs):
            return self

        def __getattr__(self, name):
            return self

    st = _AnyStrategy()

    def settings(*args, **kwargs):
        return lambda fn: fn

    def given(*args, **kwargs):
        return pytest.mark.skip(reason="hypothesis not installed")


__all__ = ["HAVE_HYPOTHESIS", "given", "settings", "st"]
