"""Shard-boundary invariance of the server axis (PR 9).

The ServerAxis contract: sharding the ``[m, ...]`` server arrays over 1, 2,
or 4 devices must not change a single scheduling decision -- placements
bitwise-equal to the dense program, estimator-bank posterior and CUSUM
detector state equal to 1e-5. The multi-device matrix runs in a subprocess
(``--xla_force_host_platform_device_count`` must be set before jax imports;
the main pytest process keeps its single device); the dense-axis algebra
(pod hierarchy vs flat scan, pool namespacing, axis validation) is
property-tested in-process.
"""
import dataclasses
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from _hyp import given, settings, st

# --- in-process: ServerAxis helpers + hierarchy-vs-dense ----------------------


def test_server_axis_dense_contract():
    from repro.distributed.server_axis import DENSE, ServerAxis

    assert not DENSE.is_sharded and DENSE.shards == 1 and DENSE.pods == 1
    assert DENSE.local_m(16) == 16 and DENSE.offset(16) == 0
    DENSE.validate(16)
    ax = ServerAxis(pods=4)
    ax.validate(16)
    with pytest.raises(ValueError):
        ax.validate(6)  # 6 % 4 != 0
    # dense axis collectives are identities
    x = np.arange(4.0)
    assert np.array_equal(np.asarray(DENSE.pmin(x)), x)
    assert np.array_equal(np.asarray(DENSE.psum(x)), x)


@settings(max_examples=20, deadline=None)
@given(st.integers(1, 4), st.integers(0, 2**31 - 1))
def test_shard_local_pools_stay_local(shards, seed):
    """Every namespaced pool must live wholly inside one shard."""
    from repro.fleet.pool import shard_local_pools

    m = 16
    rng = np.random.default_rng(seed)
    pools = [f"p{rng.integers(0, 4)}" for _ in range(m)]
    local = shard_local_pools(pools, m, shards)
    m_local = m // shards
    for s, lab in enumerate(local):
        members = [i for i, l in enumerate(local) if l == lab]
        assert {i // m_local for i in members} == {s // m_local}


def _small_cluster(m, seed):
    from repro.core import M1, M2, PackedCluster, profile_pairwise_fast

    rng = np.random.default_rng(seed)
    jitter = rng.uniform(0.9, 1.1, m)
    servers = [
        dataclasses.replace([M1, M2][i % 2],
                            llc_bytes=[M1, M2][i % 2].llc_bytes * jitter[i])
        for i in range(m)]
    D2 = [profile_pairwise_fast(M1), profile_pairwise_fast(M2)]
    return PackedCluster.build(servers, D2 * (m // 2), alpha=1.3)


@pytest.mark.parametrize("pods", [2, 4, 8])
def test_hier_decisions_match_dense(pods):
    """Pod-hierarchical greedy == flat dense greedy, bitwise placements."""
    import jax.numpy as jnp

    from repro.core import counts_from_assignments, greedy_sequence_jax, type_index
    from repro.core.binpack_jax import greedy_sequence_hier
    from repro.core.workload import FS_GRID, RS_GRID, Workload, snap_to_grid
    from repro.distributed.server_axis import ServerAxis

    m = 16
    cluster = _small_cluster(m, seed=5)
    c0 = counts_from_assignments(cluster, [[] for _ in range(m)])
    rng = np.random.default_rng(9)
    wl = [snap_to_grid(Workload(fs=float(rng.choice(FS_GRID[:18])),
                                rs=float(rng.choice(RS_GRID))))
          for _ in range(48)]
    wtypes = jnp.asarray([type_index(w) for w in wl])
    _, p_dense = greedy_sequence_jax(cluster, c0, wtypes)
    cf, p_hier = greedy_sequence_hier(cluster, c0, wtypes, ServerAxis(pods=pods))
    assert np.array_equal(np.asarray(p_dense), np.asarray(p_hier))
    # final counts agree too (same placements, same scatter)
    cf_dense, _ = greedy_sequence_jax(cluster, c0, wtypes)
    np.testing.assert_array_equal(np.asarray(cf), np.asarray(cf_dense))


@settings(max_examples=5, deadline=None)
@given(st.integers(0, 2**31 - 1))
def test_hier_decisions_match_dense_prop(seed):
    import jax.numpy as jnp

    from repro.core import counts_from_assignments, greedy_sequence_jax
    from repro.core.binpack_jax import greedy_sequence_hier
    from repro.distributed.server_axis import ServerAxis

    m = 8
    cluster = _small_cluster(m, seed=seed)
    c0 = counts_from_assignments(cluster, [[] for _ in range(m)])
    rng = np.random.default_rng(seed)
    wtypes = jnp.asarray(rng.integers(0, cluster.T, 24).astype(np.int32))
    _, p_dense = greedy_sequence_jax(cluster, c0, wtypes)
    _, p_hier = greedy_sequence_hier(cluster, c0, wtypes, ServerAxis(pods=4))
    assert np.array_equal(np.asarray(p_dense), np.asarray(p_hier))


# --- subprocess: 1/2/4-shard invariance of the full stack ---------------------

PROBE = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import dataclasses as dc
    import numpy as np
    import jax
    import jax.numpy as jnp

    from repro.core.closed_loop import (ClosedLoopConfig, LoopCarry, SegmentIn,
                                        run_closed_loop)
    from repro.core.binpack_jax import PackedCluster
    from repro.core.engine_jax import PackedDynamics, run_trace
    from repro.core.server import M1, M2
    from repro.fleet.detect import CusumState
    from repro.telemetry.estimator import DeviceEstimatorState
    from repro.telemetry.log import RingBlock
    from repro.distributed.server_axis import ServerAxis
    import repro.obs.metrics as OM
    import repro.obs.recorder as OR

    T = 23
    m, n_seg, S_cap, cap = 8, 4, 4, 256
    R = n_seg
    rng = np.random.default_rng(7)

    servers = [dc.replace([M1, M2][i % 2], name=f"s{i}") for i in range(m)]
    c0 = PackedCluster.build(
        servers, [np.full((230, 230), 0.05, np.float32)] * m, alpha=1.3)
    cluster = dc.replace(
        c0, D=jnp.asarray(rng.uniform(0, 0.1, (m, T, T)).astype(np.float32)),
        rs=c0.rs[:T], fs=c0.fs[:T], resident=c0.resident[:, :T])

    logd = rng.uniform(-0.2, -0.01, (m, T, T)).astype(np.float32)
    dyn = PackedDynamics(
        solo=jnp.asarray(rng.uniform(5e5, 2e6, (m, T)).astype(np.float32)),
        base_lost=jnp.asarray(rng.uniform(1e5, 5e5, (m, T)).astype(np.float32)),
        log_keep=jnp.asarray(logd), log_lost=jnp.asarray(logd * 2.0),
        comp_bytes=jnp.asarray(rng.uniform(5e4, 2e5, (m, T)).astype(np.float32)),
        tol_budget=jnp.asarray(rng.uniform(5e6, 2e7, (m,)).astype(np.float32)))

    # --- engine: run_trace dense vs 1/2/4 shards -----------------------------
    n = 24
    arr_time = jnp.asarray(np.sort(rng.uniform(0, 2, n)).astype(np.float32))
    arr_type = jnp.asarray(rng.integers(0, T, n).astype(np.int32))
    arr_bytes = jnp.asarray(rng.uniform(2e5, 2e6, n).astype(np.float32))
    ref = run_trace(cluster, dyn, arr_time, arr_type, arr_bytes,
                    telemetry=True, metrics=True, record=True)
    ref = jax.tree_util.tree_map(np.asarray, ref)
    for shards in (1, 2, 4):
        ax = ServerAxis.over_host_devices(shards)
        out = run_trace(cluster, dyn, arr_time, arr_type, arr_bytes,
                        telemetry=True, metrics=True, record=True, axis=ax)
        out = jax.tree_util.tree_map(np.asarray, out)
        assert np.array_equal(ref.placement, out.placement), (shards,)
        np.testing.assert_allclose(ref.finish_time, out.finish_time, rtol=1e-5)
        np.testing.assert_allclose(ref.obs_logr, out.obs_logr,
                                   rtol=1e-5, atol=1e-6)
        assert np.array_equal(ref.metrics.counters, out.metrics.counters)
        # decision ring: every shard holds the identical record
        assert int(ref.rec.total) == int(out.rec.total), (shards,)
        assert np.array_equal(ref.rec.block.ints, out.rec.block.ints), (shards,)
        np.testing.assert_allclose(ref.rec.block.floats, out.rec.block.floats,
                                   rtol=1e-5, atol=1e-6)
        print(f"run_trace shards={shards}: OK")

    # --- closed loop: fleet controller + metrics, dense vs 1/2/4 shards ------
    bank = DeviceEstimatorState(
        L_t=jnp.zeros((m, T, T)), log_b=jnp.zeros((m, T)),
        n_pair_t=jnp.zeros((m, T, T)), n_base=jnp.zeros((m, T)),
        n_obs=jnp.zeros((m,), jnp.int32))
    ring = RingBlock(
        ints=jnp.full((cap, 2), -1, jnp.int32),
        scalars=jnp.zeros((cap, 6), jnp.float32),
        co=jnp.zeros((cap, T), jnp.float32))
    row_map = jnp.asarray((np.arange(m) // 2 * 2).astype(np.int32))
    carry0 = LoopCarry(
        bank=bank, det=CusumState.zeros(m),
        row_map=row_map, read_row=row_map,
        active=jnp.ones((m,), bool), seen=jnp.int32(0),
        req_type=jnp.zeros((R,), jnp.int32),
        req_bytes=jnp.ones((R,), jnp.float32), req_n=jnp.int32(0),
        ring=ring, ring_ptr=jnp.int32(0), ring_total=jnp.int32(0),
        metrics=OM.zeros(m), rec=OR.init(64))
    xs = SegmentIn(
        arr_time=jnp.asarray(
            np.sort(rng.uniform(0, 2, (S_cap, n_seg)), axis=1)
            .astype(np.float32)),
        arr_type=jnp.asarray(rng.integers(0, T, (S_cap, n_seg))
                             .astype(np.int32)),
        arr_bytes=jnp.asarray(rng.uniform(2e5, 2e6, (S_cap, n_seg))
                              .astype(np.float32)),
        dyn_idx=jnp.zeros((S_cap,), jnp.int32),
        seg_valid=jnp.ones((S_cap,), bool))
    dyn_stack = jax.tree_util.tree_map(lambda a: a[None], dyn)
    Lp_t = jnp.full((m, T, T), float(np.log1p(-0.05)), jnp.float32)
    logb = jnp.asarray(np.log(rng.uniform(5e5, 2e6, (m, T))).astype(np.float32))

    cfg = ClosedLoopConfig(fleet=True, metrics=True, record=True,
                           warmup_segments=1, cusum_h=0.5)
    ref_c, ref_y = run_closed_loop(cluster, dyn_stack, Lp_t, logb, carry0,
                                   xs, cfg)
    ref_c = jax.tree_util.tree_map(np.asarray, ref_c)
    ref_y = jax.tree_util.tree_map(np.asarray, ref_y)
    for shards in (1, 2, 4):
        ax = ServerAxis.over_host_devices(shards)
        out_c, out_y = run_closed_loop(cluster, dyn_stack, Lp_t, logb, carry0,
                                       xs, dc.replace(cfg, axis=ax))
        out_c = jax.tree_util.tree_map(np.asarray, out_c)
        out_y = jax.tree_util.tree_map(np.asarray, out_y)
        assert np.array_equal(ref_y.placement, out_y.placement), (shards,)
        assert np.array_equal(ref_c.row_map, out_c.row_map), (shards,)
        assert np.array_equal(ref_c.active, out_c.active), (shards,)
        assert np.array_equal(ref_y.split_fired, out_y.split_fired), (shards,)
        assert np.array_equal(ref_y.evict_fired, out_y.evict_fired), (shards,)
        np.testing.assert_allclose(ref_c.bank.log_b, out_c.bank.log_b,
                                   rtol=1e-5, atol=1e-7)
        np.testing.assert_allclose(ref_c.bank.L_t, out_c.bank.L_t,
                                   rtol=1e-5, atol=1e-7)
        np.testing.assert_allclose(ref_c.det.stat, out_c.det.stat,
                                   rtol=1e-5, atol=1e-6)
        np.testing.assert_allclose(ref_c.det.level, out_c.det.level,
                                   rtol=1e-5, atol=1e-6)
        assert np.array_equal(ref_c.ring.ints, out_c.ring.ints), (shards,)
        assert int(ref_c.rec.total) == int(out_c.rec.total), (shards,)
        assert np.array_equal(ref_c.rec.block.ints,
                              out_c.rec.block.ints), (shards,)
        np.testing.assert_allclose(ref_c.rec.block.floats,
                                   out_c.rec.block.floats,
                                   rtol=1e-5, atol=1e-6)
        assert np.array_equal(ref_c.metrics.counters,
                              out_c.metrics.counters), (shards,)
        np.testing.assert_allclose(ref_c.metrics.per_server,
                                   out_c.metrics.per_server, atol=1e-6)
        print(f"closed_loop shards={shards}: OK")
    print("SERVER-SHARD-INVARIANCE OK")
    """)


@pytest.mark.slow
def test_server_axis_shard_invariance_multidev():
    env = dict(os.environ)
    env["PYTHONPATH"] = env.get("PYTHONPATH", "") + os.pathsep + os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src"))
    env["JAX_PLATFORMS"] = "cpu"
    r = subprocess.run([sys.executable, "-c", PROBE], capture_output=True,
                       text=True, env=env, timeout=560)
    assert "SERVER-SHARD-INVARIANCE OK" in r.stdout, (
        r.stdout + "\n" + r.stderr[-3000:])
