"""Single-workload throughput model (paper §III, Figures 1-2)."""
import numpy as np
import pytest
from _hyp import given, settings, st

from repro.core import M1, M2, Workload, solo_throughput, solo_throughput_grid
from repro.core.throughput import level_of
from repro.core.units import GB, KB, MB
from repro.core.workload import FS_GRID, RS_GRID


@pytest.mark.parametrize("server", [M1, M2])
@pytest.mark.parametrize("op", ["read", "write"])
def test_levels_partition_fs_axis(server, op):
    """Fig 1-2: three throughput levels for write, two for read (§III.C)."""
    seen = set()
    for fs in FS_GRID:
        seen.add(level_of(server, fs, op))
    assert seen == ({1, 2, 3} if op == "write" else {1, 2})


@pytest.mark.parametrize("server", [M1, M2])
def test_level_boundaries_match_table1(server):
    assert level_of(server, server.llc_bytes, "write") == 1
    assert level_of(server, server.llc_bytes * 1.01, "write") == 2
    spill = server.cache_spill_bytes
    assert level_of(server, spill, "write") == 2
    assert level_of(server, spill * 1.01, "write") == 3
    # paper: the write level-3 boundary sits at file cache + disk cache
    assert spill == server.file_cache_bytes + server.disk_cache_bytes


@pytest.mark.parametrize("server", [M1, M2])
@pytest.mark.parametrize("op", ["read", "write"])
def test_throughput_monotone_in_rs(server, op):
    """§III.C: 'throughput is always improved by increasing size of RS'."""
    for fs in (64 * KB, 4 * MB, 64 * MB, 2 * GB):
        ts = [solo_throughput(server, Workload(fs=fs, rs=rs, op=op)) for rs in RS_GRID]
        assert all(b > a for a, b in zip(ts, ts[1:])), (fs, ts)


@pytest.mark.parametrize("server", [M1, M2])
def test_throughput_levels_ordered(server):
    """Level-1 (LLC) > level-2 (file cache) > level-3 (disk) at equal RS."""
    rs = 64 * KB
    t1 = solo_throughput(server, Workload(fs=1 * MB, rs=rs, op="write"))
    t2 = solo_throughput(server, Workload(fs=64 * MB, rs=rs, op="write"))
    t3 = solo_throughput(server, Workload(fs=2 * GB, rs=rs, op="write"))
    assert t1 > t2 > t3


def test_grid_vectorization_matches_scalar():
    grid = solo_throughput_grid(M1, RS_GRID, FS_GRID, "write")
    for i, rs in enumerate(RS_GRID):
        for j, fs in enumerate(FS_GRID):
            scalar = solo_throughput(M1, Workload(fs=fs, rs=rs, op="write"))
            assert grid[i, j] == pytest.approx(scalar, rel=1e-12)


@settings(max_examples=30, deadline=None)
@given(
    rs=st.floats(1 * KB, 512 * KB),
    fs=st.floats(1 * KB, 2 * GB),
    op=st.sampled_from(["read", "write"]),
)
def test_throughput_positive_and_bounded(rs, fs, op):
    t = solo_throughput(M1, Workload(fs=fs, rs=rs, op=op))
    assert 0 < t <= max(M1.bw_l1_read, M1.bw_l1_write)
