"""HDFS-inspired chunk store + input pipeline."""
import numpy as np
import pytest
from _hyp import given, settings, st

from repro.core.workload import Workload, characterize, parse_workloads
from repro.data import ChunkStore, FileMeta, TokenPipeline, synthetic_store

MB = 1024 * 1024


def test_chunk_math():
    store = ChunkStore([FileMeta(0, 200 * MB)], block_bytes=64 * MB)
    chunks = store.chunks(0)
    assert len(chunks) == 4  # 64+64+64+8
    assert chunks[-1].size == 8 * MB
    assert sum(c.size for c in chunks) == 200 * MB


def test_reads_deterministic_and_offset_consistent():
    store = synthetic_store()
    ref = store.chunks(0)[0]
    a = store.read(ref, 0, 4096)
    b = store.read(ref, 0, 4096)
    np.testing.assert_array_equal(a, b)
    # reading in two RS-sized halves equals one big read
    whole = store.read(ref, 0, 8192)
    half1 = store.read(ref, 0, 4096)
    half2 = store.read(ref, 4096, 4096)
    np.testing.assert_array_equal(whole, np.concatenate([half1, half2]))


def test_replication_placement():
    store = synthetic_store(n_files=1)
    ref = store.chunks(0)[0]
    reps = store.replicas(ref)
    assert len(reps) == store.replication == 3
    assert len(set(reps)) == 3
    assert store.replicas(ref) == reps  # deterministic


def test_store_characterizes_as_paper_workload():
    store = synthetic_store(block_mb=64)
    w = store.as_workload(256 * 1024)
    assert w.fs == 64 * MB and w.rs == 256 * 1024 and w.op == "read"


def test_pipeline_deterministic_across_restart():
    store = synthetic_store(n_files=2, file_mb=16, block_mb=8)
    p1 = TokenPipeline(store, vocab=1000, batch=2, seq_len=64)
    batches = [next(p1) for _ in range(5)]
    state = p1.state_dict()

    p2 = TokenPipeline(store, vocab=1000, batch=2, seq_len=64)
    p2.load_state_dict({"epoch": 0, "step": 0})
    for i in range(5):
        np.testing.assert_array_equal(next(p2)["tokens"], batches[i]["tokens"])

    # resume from checkpointed cursor reproduces the *next* batch
    p3 = TokenPipeline(store, vocab=1000, batch=2, seq_len=64)
    p3.load_state_dict(state)
    nxt1, nxt2 = next(p1), next(p3)
    np.testing.assert_array_equal(nxt1["tokens"], nxt2["tokens"])


def test_pipeline_prefetch_thread_matches_sync():
    store = synthetic_store(n_files=2, file_mb=16, block_mb=8)
    sync = TokenPipeline(store, vocab=500, batch=2, seq_len=32)
    want = [next(sync)["tokens"] for _ in range(4)]
    threaded = TokenPipeline(store, vocab=500, batch=2, seq_len=32, prefetch=2).start()
    got = [next(threaded)["tokens"] for _ in range(4)]
    threaded.stop()
    for w, g in zip(want, got):
        np.testing.assert_array_equal(w, g)


def test_rank_sharding_disjoint():
    store = synthetic_store(n_files=2, file_mb=16, block_mb=8)
    a = TokenPipeline(store, vocab=500, batch=1, seq_len=32, rank=0, world=2)
    b = TokenPipeline(store, vocab=500, batch=1, seq_len=32, rank=1, world=2)
    ba, bb = next(a)["tokens"], next(b)["tokens"]
    assert not np.array_equal(ba, bb)


def test_labels_shift():
    store = synthetic_store(n_files=1, file_mb=16, block_mb=8)
    p = TokenPipeline(store, vocab=500, batch=2, seq_len=32)
    b = next(p)
    assert b["tokens"].shape == b["labels"].shape == (2, 32)


@settings(max_examples=20, deadline=None)
@given(st.integers(1, 1 << 22), st.integers(256, 1 << 20))
def test_property_read_chunk_complete(file_size, rs):
    store = ChunkStore([FileMeta(0, file_size)], block_bytes=1 << 20)
    ref = store.chunks(0)[0]
    data = store.read_chunk(ref, rs)
    assert data.size == ref.size


def test_characterize_trace():
    w = characterize([("read", 65536)] * 100 + [("write", 128)], 64 * MB)
    assert w.op == "read"
    assert 32 * 1024 < w.rs < 128 * 1024
