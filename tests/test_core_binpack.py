"""2-D bin packing: the greedy (Fig 8 / Table II), brute force, and the JAX
fast path -- paper §VI-§VIII."""
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st

from repro.core import (
    DEGRADATION_LIMIT,
    M1,
    M2,
    PAPER_CLUSTER,
    ClusterState,
    PackedCluster,
    Workload,
    brute_force,
    brute_force_jax,
    check_consolidation,
    counts_from_assignments,
    first_fit,
    greedy_place,
    greedy_sequence,
    greedy_sequence_jax,
    parse_workloads,
    profile_pairwise_fast,
    run_allocator,
    snap_to_grid,
    type_index,
)
from repro.core.units import KB, MB

# Paper Table III, verbatim.
INITIAL = {
    0: "(32KB, 64KB), (4KB, 16KB), (16KB, 32MB)",
    1: "(32KB, 64MB), (512KB, 2MB), (128KB, 512KB)",
    2: "(256KB, 1MB), (4KB, 2MB), (32KB, 8MB)",
    3: "(2KB, 32KB), (512KB, 64MB), (8KB, 4MB)",
}
SEQUENCES = [
    "(16KB, 64KB), (32KB, 1MB), (64KB, 64MB), (32KB, 2MB), (8KB, 64MB)",
    "(4KB, 16KB), (2KB, 16MB), (2KB, 8KB), (32KB, 256KB), (16KB, 64MB)",
    "(256KB, 2MB), (8KB, 3MB), (32KB, 64MB), (4KB, 256MB), (8KB, 32MB)",
]

_D_CACHE = {}


def paper_state(alpha=1.3) -> ClusterState:
    servers = list(PAPER_CLUSTER)
    if "D" not in _D_CACHE:
        _D_CACHE["D"] = [profile_pairwise_fast(s) for s in servers]
    state = ClusterState.empty(servers, _D_CACHE["D"], alpha=alpha)
    for i, txt in INITIAL.items():
        state.assignments[i] = [snap_to_grid(w) for w in parse_workloads(txt)]
    return state


def test_initial_state_feasible():
    assert paper_state().feasible()


@pytest.mark.parametrize("seq", SEQUENCES)
def test_greedy_never_violates_criteria(seq):
    state = paper_state()
    arrivals = [snap_to_grid(w) for w in parse_workloads(seq)]
    greedy_sequence(state, arrivals)
    for i in range(len(state.servers)):
        c = state.check(i)
        assert c.ok, (i, c)
        assert c.max_degradation < DEGRADATION_LIMIT
        assert c.cache_in_use <= 1.0


@pytest.mark.parametrize("seq", SEQUENCES)
def test_greedy_near_optimal(seq):
    """Fig 9 / §VIII: 'our greedy approach is able to achieve near optimal
    solution in all experimented cases' -- within 10% of brute force."""
    arrivals = [snap_to_grid(w) for w in parse_workloads(seq)]
    state = paper_state()
    opt_cost, _ = brute_force(paper_state(), arrivals)
    placements, queued = greedy_sequence(state, arrivals)
    greedy_cost = state.total_avg_load() + len(queued)
    assert greedy_cost <= opt_cost * 1.10 + 1e-9


def test_table2_semantics_prefers_smaller_increase():
    """Table II: the greedy minimizes the *increase* in average load, which
    can prefer the more-loaded server (B) over the lighter one (A)."""
    state = paper_state()
    w = snap_to_grid(Workload(fs=1 * MB, rs=32 * KB))
    before = [state.check(i).avg_load for i in range(4)]
    placed = greedy_place(state, w, objective="sum_avg")
    assert placed is not None
    after = state.check(placed).avg_load
    # the chosen server minimizes (after - before) among feasible servers
    deltas = []
    for i in range(4):
        trial = paper_state()
        trial.assignments[i].append(w)
        c = trial.check(i)
        if c.ok:
            deltas.append((c.avg_load - before[i], i))
    assert placed == min(deltas)[1]


def test_queueing_when_no_server_fits():
    """§V criterion 1: the workload queues when no server satisfies both rules."""
    servers = [M1]
    D = profile_pairwise_fast(M1)
    state = ClusterState.empty(servers, D, alpha=1.0)
    heavy = snap_to_grid(Workload(fs=64 * MB, rs=512 * KB))
    placements, queued = greedy_sequence(state, [heavy] * 6)
    assert len(queued) >= 1  # mutual degradation > 50% forces queueing
    assert state.feasible()


def test_jax_greedy_matches_python():
    for seq in SEQUENCES:
        arrivals = [snap_to_grid(w) for w in parse_workloads(seq)]
        state = paper_state()
        py_placements, _ = greedy_sequence(state, arrivals)

        cluster = PackedCluster.build(list(PAPER_CLUSTER), _D_CACHE["D"], alpha=1.3)
        counts = counts_from_assignments(cluster, paper_state().assignments)
        wtypes = jnp.asarray([type_index(w) for w in arrivals])
        _, jx = greedy_sequence_jax(cluster, counts, wtypes)
        jx = [int(v) if v >= 0 else None for v in np.asarray(jx)]
        assert jx == py_placements


def test_jax_brute_force_matches_python():
    arrivals = [snap_to_grid(w) for w in parse_workloads(SEQUENCES[0])]
    cost_py, assign_py = brute_force(paper_state(), arrivals)
    cluster = PackedCluster.build(list(PAPER_CLUSTER), _D_CACHE["D"], alpha=1.3)
    counts = counts_from_assignments(cluster, paper_state().assignments)
    wtypes = jnp.asarray([type_index(w) for w in arrivals])
    cost_jx, assign_jx = brute_force_jax(cluster, counts, wtypes)
    assert cost_jx == pytest.approx(cost_py, rel=1e-5)


@settings(max_examples=20, deadline=None)
@given(
    st.lists(
        st.tuples(
            st.sampled_from([2 * KB, 16 * KB, 128 * KB, 512 * KB]),
            st.sampled_from([64 * KB, 1 * MB, 8 * MB, 64 * MB]),
        ),
        min_size=1,
        max_size=6,
    )
)
def test_property_greedy_state_always_feasible(pairs):
    """Invariant: whatever arrives, the greedy never leaves the cluster in a
    criteria-violating state (it queues instead)."""
    state = paper_state()
    arrivals = [snap_to_grid(Workload(fs=fs, rs=rs)) for rs, fs in pairs]
    greedy_sequence(state, arrivals)
    assert state.feasible()


@settings(max_examples=15, deadline=None)
@given(
    st.lists(
        st.tuples(
            st.sampled_from([2 * KB, 16 * KB, 128 * KB]),
            st.sampled_from([64 * KB, 1 * MB, 8 * MB]),
        ),
        min_size=1,
        max_size=4,
    )
)
def test_property_first_fit_no_better_than_greedy_objective(pairs):
    """The 2-D objective matters: greedy's total average load never exceeds
    first-fit's by more than the queue differential."""
    arrivals = [snap_to_grid(Workload(fs=fs, rs=rs)) for rs, fs in pairs]
    g = paper_state()
    gp, gq = greedy_sequence(g, arrivals)
    f_placements, f = run_allocator(paper_state(), arrivals, first_fit)
    fq = sum(1 for p in f_placements if p is None)
    assert g.total_avg_load() + len(gq) <= f.total_avg_load() + fq + 1e-9
