"""Per-architecture smoke tests (deliverable f): reduced same-family config,
one forward/train step on CPU, output shapes + no NaNs; plus the full-config
declarations (shapes only, no allocation)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, SHAPES, SMOKES
from repro.configs.registry import cells
from repro.models import abstract, build_model, count_params, materialize

RNG = jax.random.PRNGKey(0)


def _batch(cfg, B=2, S=32):
    b = {"tokens": jax.random.randint(RNG, (B, S), 0, cfg.vocab),
         "labels": jax.random.randint(RNG, (B, S), 0, cfg.vocab)}
    if cfg.family == "vlm":
        b["vis_embeds"] = jax.random.normal(RNG, (B, cfg.vis_tokens, cfg.d_model), cfg.compute_dtype)
    if cfg.family == "encdec":
        b["audio_embeds"] = jax.random.normal(RNG, (B, cfg.enc_seq, cfg.d_model), cfg.compute_dtype)
    return b


@pytest.mark.parametrize("arch", sorted(SMOKES))
def test_smoke_loss_and_grad_step(arch):
    cfg = SMOKES[arch]
    model = build_model(cfg)
    params = materialize(model.param_infos(), RNG)
    batch = _batch(cfg)
    loss, metrics = model.loss(params, batch)
    assert np.isfinite(float(loss)) and float(loss) > 0
    grads = jax.grad(lambda p: model.loss(p, batch)[0])(params)
    gnorm = sum(float(jnp.sum(jnp.square(g.astype(jnp.float32)))) for g in jax.tree_util.tree_leaves(grads))
    assert np.isfinite(gnorm) and gnorm > 0


@pytest.mark.parametrize("arch", sorted(SMOKES))
def test_smoke_prefill_decode_shapes(arch):
    cfg = SMOKES[arch]
    model = build_model(cfg)
    params = materialize(model.param_infos(), RNG)
    B, S = 2, 32
    batch = _batch(cfg, B, S)
    cache = materialize(model.cache_infos(B, S + 4), RNG)
    logits, cache = model.prefill(params, {k: v for k, v in batch.items() if k != "labels"}, cache)
    assert logits.shape[0] == B and logits.shape[1] == 1
    assert np.all(np.isfinite(np.asarray(logits, np.float32)))
    logits2, cache = model.decode_step(params, cache, batch["tokens"][:, :1])
    assert logits2.shape[:2] == (B, 1)
    assert np.all(np.isfinite(np.asarray(logits2, np.float32)))


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_full_config_declares_correct_shapes(arch):
    """FULL configs exercised via shapes only (ShapeDtypeStruct, no alloc)."""
    cfg = ARCHS[arch]
    model = build_model(cfg)
    infos = model.param_infos()
    n = count_params(infos)
    expected_range = {
        "llama3.2-3b": (2.5e9, 5e9),
        "qwen2-72b": (65e9, 85e9),
        "starcoder2-7b": (6e9, 9e9),
        "tinyllama-1.1b": (0.9e9, 1.4e9),
        # the assignment table's 48L x 64e x d_ff=1408 gives ~27B total
        # (16B is the hf checkpoint's marketing count at 27 layers)
        "moonshot-v1-16b-a3b": (20e9, 30e9),
        "kimi-k2-1t-a32b": (0.85e12, 1.3e12),
        "whisper-medium": (0.5e9, 1.0e9),
        "internvl2-2b": (1.5e9, 2.8e9),
        "jamba-v0.1-52b": (45e9, 60e9),
        "rwkv6-7b": (6e9, 9e9),
    }[arch]
    assert expected_range[0] <= n <= expected_range[1], f"{arch}: {n:,} params"
    # every shape's input specs are well-formed
    for shape, info in SHAPES.items():
        if shape == "long_500k" and not cfg.is_subquadratic:
            continue
        specs = model.input_specs(shape)
        assert "tokens" in specs
        assert specs["tokens"].shape[0] == info["global_batch"]


def test_cells_cover_40():
    all_cells = cells(include_skipped=True)
    assert len(all_cells) == 40
    skipped = [c for c in all_cells if c[2]]
    assert len(skipped) == 8  # long_500k on the 8 full-attention archs
    for arch, shape, skip in skipped:
        assert shape == "long_500k"


def test_moe_capacity_drops_are_bounded():
    """At the default capacity factor, dropped tokens are the exception."""
    cfg = SMOKES["moonshot-v1-16b-a3b"]
    model = build_model(cfg)
    params = materialize(model.param_infos(), RNG)
    big = dataclasses.replace(cfg, moe_capacity_factor=8.0)
    model_big = build_model(big)
    batch = _batch(cfg, B=2, S=64)
    l1, _ = model.loss(params, batch)
    l2, _ = model_big.loss(params, batch)
    # losses differ only via capacity drops; they must be close
    assert abs(float(l1) - float(l2)) < 0.25
