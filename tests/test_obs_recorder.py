"""Decision flight recorder: ring provenance, identity, and attribution.

The contracts the ``repro.obs.recorder`` / ``repro.obs.explain`` pair make
(DESIGN.md §16): the fixed-capacity ring keeps exactly the last
``min(capacity, rows-ever-written)`` on-rows oldest-first regardless of
wrap or off-rows interleaved between them; ``record=True`` changes no
decision (the conditional scatter adds no branch to the event loop); the
host-alternating and fused device-loop paths write bit-identical rings;
and the telescoping forced replay reconstructs every recorded placement
and sums per-decision deltas exactly to each segment's regret.
"""
from __future__ import annotations

import numpy as np
import pytest

from _hyp import given, settings, st
from repro.configs.base import MeshConfig
from repro.core import AdaptiveEngine, ConsolidationEngine, M1, M2
from repro.core.workload import FS_GRID, RS_GRID, Workload, snap_to_grid
from repro.fleet import FleetController
from repro.obs import explain
from repro.obs import recorder as R

SEG_GAP = 10.0


def _segment(seed: int, n: int, gap: float = 2e-5):
    rng = np.random.default_rng(seed)
    out, t = [], 0.0
    for _ in range(n):
        fs = float(rng.choice(FS_GRID[10:14]))
        w = snap_to_grid(Workload(fs=fs, rs=float(rng.choice(RS_GRID[5:8])),
                                  data_total=fs * 6))
        t += float(rng.exponential(gap))
        out.append((t, w))
    return out


def _replay(seg, segments):
    return [(t + k * SEG_GAP, w) for k in range(segments) for t, w in seg]


def _dense_arrivals(n=12):
    out = []
    for i in range(n):
        w = snap_to_grid(Workload(
            fs=FS_GRID[(5 * i) % len(FS_GRID)], rs=RS_GRID[i % len(RS_GRID)],
            data_total=48e6))
        out.append((0.5 * i, w))
    return out


# -- ring semantics ------------------------------------------------------------

def _write(rec, i: int, on: bool, segment: int):
    import jax.numpy as jnp
    k = R.REC_TOPK
    return R.record_row(
        rec, on=jnp.asarray(on), arrival=i, segment=segment,
        server=i % 3, kind=i % 2, qdepth=i % 4, pool_row=i % 3,
        cand=jnp.arange(k, dtype=jnp.int32) + i,
        scores=jnp.arange(k, dtype=jnp.float32) + 0.5 * i,
        t=0.25 * i, headroom=0.125 * i, margin=float(i), n_pair_min=-1.0,
        cusum=0.0)


@settings(max_examples=40, deadline=None)
@given(cap=st.integers(1, 8),
       ons=st.lists(st.booleans(), min_size=0, max_size=24))
def test_ring_keeps_last_on_rows_oldest_first(cap, ons):
    """Wrap invariance: whatever mixture of on/off writes crosses the
    capacity boundary, the decoded ring is the last min(cap, n_on) on-rows
    in write order, and off-rows leave no trace."""
    rec = R.init(cap)
    expect = []
    for i, on in enumerate(ons):
        rec = _write(rec, i, on, segment=i // 3)
        if on:
            expect.append(i)
    expect = expect[-cap:]
    ring = R.DecisionRing(cap)
    ring.adopt(rec)
    assert len(ring) == len(expect)
    cols = ring.columns()
    np.testing.assert_array_equal(cols["arrival"], expect)
    np.testing.assert_array_equal(cols["segment"], [i // 3 for i in expect])
    np.testing.assert_array_equal(cols["server"], [i % 3 for i in expect])
    np.testing.assert_array_equal(cols["kind"], [i % 2 for i in expect])
    np.testing.assert_allclose(cols["time"], [0.25 * i for i in expect])
    np.testing.assert_allclose(cols["margin"], [float(i) for i in expect])
    for i, row in zip(expect, cols["cand"]):
        np.testing.assert_array_equal(row, np.arange(R.REC_TOPK) + i)


def test_ring_adopt_rejects_capacity_mismatch():
    ring = R.DecisionRing(4)
    with pytest.raises(ValueError, match="capacity"):
        ring.adopt(R.init(8))


def test_record_requires_jax_backend():
    engine = ConsolidationEngine([M1, M2], backend="numpy")
    with pytest.raises(ValueError, match="jax"):
        engine.run(_dense_arrivals(2), record=True)


# -- decision identity and provenance ------------------------------------------

def test_record_on_off_decision_identity():
    """record=True must be bitwise decision-invariant: same placements,
    same queueing, same finish times, same makespan."""
    engine = ConsolidationEngine([M1, M2], backend="jax")
    arrivals = _dense_arrivals()
    base = engine.run(arrivals)
    rec = engine.run(arrivals, record=True)
    assert list(base.placements) == list(rec.placements)
    assert list(base.was_queued) == list(rec.was_queued)
    np.testing.assert_array_equal(np.asarray(base.finish_times),
                                  np.asarray(rec.finish_times))
    assert base.makespan == rec.makespan
    assert base.decisions is None and rec.decisions is not None


def test_ring_reconstructs_every_placement():
    engine = ConsolidationEngine([M1, M2], backend="jax")
    res = engine.run(_dense_arrivals(), record=True)
    ring = R.DecisionRing(int(res.decisions.block.ints.shape[0]))
    ring.adopt(res.decisions)
    assert explain.check_reconstruction(ring, [res.placements]) == []
    cols = ring.columns()
    queued_rows = {int(a) for a, k in zip(cols["arrival"], cols["kind"])
                   if int(k) == R.KIND_QUEUED}
    assert queued_rows == {a for a, q in enumerate(res.was_queued) if q}


def test_adaptive_record_off_returns_none():
    arrivals = _replay(_segment(3, 4), 2)
    eng = AdaptiveEngine([M1] * 2, prior=0.0, stream=True)
    assert eng.run(arrivals, segments=2).decisions is None
    assert eng.run(arrivals, segments=2, device_loop=True).decisions is None


def test_host_device_record_parity():
    """The host-alternating path and the fused device loop write the same
    ring bit-for-bit: same rows, same order, same sampled context."""
    segments, n_seg = 4, 10
    arrivals = _replay(_segment(11, n_seg), segments)
    rings = []
    for device_loop in (False, True):
        eng = AdaptiveEngine([M1] * 3, prior=0.0, decay=1.0, stream=True,
                             fleet=FleetController(mesh=MeshConfig()),
                             ring_capacity=256)
        res = eng.run(arrivals, segments=segments, device_loop=device_loop,
                      record=True)
        assert res.decisions is not None
        rings.append(res.decisions.columns())
    host, dev = rings
    assert set(host) == set(dev)
    for name in ("arrival", "segment", "server", "kind", "qdepth",
                 "pool_row", "cand"):
        np.testing.assert_array_equal(host[name], dev[name], err_msg=name)
    for name in ("time", "headroom", "margin", "n_pair_min", "cusum",
                 "score"):
        np.testing.assert_allclose(host[name], dev[name], rtol=1e-5,
                                   atol=1e-6, err_msg=name)


# -- regret attribution --------------------------------------------------------

def test_attribution_sums_to_regret_and_reconstructs():
    """The telescoping-replay gate: per-decision deltas sum to each
    segment's regret within 1e-5 and the forced replay reconstructs every
    recorded placement."""
    from repro.obs.__main__ import _attribute, _canned_adaptive

    eng, res, chunks = _canned_adaptive(segments=2, per_seg=8)
    atts, recon = _attribute(eng, res, chunks)
    assert len(atts) == 2
    assert recon == []
    assert explain.check_exactness(atts) == []
    for att in atts:
        assert len(att.decisions) > 0
        total = sum(d.delta for d in att.decisions)
        assert abs(total - att.regret) <= 1e-5
        assert set(att.by_bucket) <= {"aligned", "estimation", "queueing",
                                      "detection"}
        for d in att.decisions:
            assert d.bucket in ("aligned", "estimation", "queueing",
                                "detection")
