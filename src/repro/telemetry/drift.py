"""Drift scenarios: worlds where the profiled model goes stale.

The motivation for the telemetry loop is that interference profiles are not
stationary -- co-tenancy noise, hardware variability, aging disks. This
module builds perturbed/decaying/degraded variants of a ``ServerSpec`` and
schedules when they take effect, so the closed-loop engine can be evaluated
against a ground truth that *changes under it* while its estimator has to
notice purely from observations.

Only the *performance* constants drift (bandwidths, shared-subsystem
capacity, per-op CPU costs). Structural facts the scheduler legitimately
knows -- cache sizes, core counts, the Eqn-2 resident-set rule -- stay fixed:
drift models wear and contention, not hardware swaps.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np

from ..core.server import ServerSpec

#: the ServerSpec fields that represent measured performance (drift targets)
PERF_FIELDS = (
    "bw_l1_read", "bw_l2_read", "bw_l1_write", "bw_l2_write", "bw_l3_write",
    "shared_bw",
)


def scale_perf(spec: ServerSpec, factor: float, suffix: str) -> ServerSpec:
    """Uniformly scale every performance constant by ``factor``."""
    updates = {f: getattr(spec, f) * factor for f in PERF_FIELDS}
    return dataclasses.replace(spec, name=f"{spec.name}{suffix}", **updates)


def degrade_server(spec: ServerSpec, factor: float = 0.5) -> ServerSpec:
    """Degraded-server injection: a failing disk / throttled node.

    Every performance constant drops to ``factor`` of nominal; per-request
    CPU cost rises inversely (retries, error handling burn cycles). Because
    demands and capacities scale together, *pair* degradations barely move --
    this drift is observable mainly through the base rates (solo telemetry).
    """
    out = scale_perf(spec, factor, f":deg{factor:g}")
    return dataclasses.replace(out, cpu_req_cost=spec.cpu_req_cost / factor)


def congest_server(spec: ServerSpec, factor: float = 0.5) -> ServerSpec:
    """Shared-subsystem congestion: aggregate storage bandwidth drops to
    ``factor`` of nominal while per-level burst rates stay -- a failing RAID
    controller, or co-tenant noise outside the scheduler's view (the Ivanov
    et al. virtualized-Hadoop scenario). Unlike :func:`degrade_server`, this
    moves demand/capacity ratios, so the *pairwise D-matrix itself* changes:
    the drift the estimator can only see through co-run observations.
    """
    return dataclasses.replace(
        spec, name=f"{spec.name}:cong{factor:g}", shared_bw=spec.shared_bw * factor)


def perturb_spec(spec: ServerSpec, scale: float = 0.1, seed: int = 0) -> ServerSpec:
    """Log-normal multiplicative jitter on each performance constant.

    Models unit-to-unit hardware variability: same nominal part, different
    realized bandwidths (sigma = ``scale`` in log space, independent per
    field).
    """
    rng = np.random.default_rng(seed)
    updates = {
        f: getattr(spec, f) * float(np.exp(rng.normal(0.0, scale)))
        for f in PERF_FIELDS
    }
    return dataclasses.replace(spec, name=f"{spec.name}:pert{seed}", **updates)


def decayed_spec(spec: ServerSpec, rate: float, steps: int) -> ServerSpec:
    """Geometric wear: performance after ``steps`` segments of ``rate`` decay."""
    return scale_perf(spec, (1.0 - rate) ** steps, f":dec{steps}")


@dataclasses.dataclass(frozen=True)
class DriftEvent:
    """At the start of ``segment``, server ``server`` becomes ``spec``."""

    segment: int
    server: int
    spec: ServerSpec


@dataclasses.dataclass(frozen=True)
class DriftSchedule:
    """An ordered set of spec replacements applied at segment boundaries."""

    events: tuple[DriftEvent, ...] = ()

    def specs_at(self, base: Sequence[ServerSpec], segment: int) -> tuple[ServerSpec, ...]:
        """Fleet specs in effect during ``segment`` (events applied in order)."""
        out = list(base)
        for ev in self.events:
            if ev.segment <= segment:
                out[ev.server] = ev.spec
        return tuple(out)

    def changes_at(self, segment: int) -> tuple[DriftEvent, ...]:
        return tuple(ev for ev in self.events if ev.segment == segment)

    @property
    def first_segment(self) -> int | None:
        return min((ev.segment for ev in self.events), default=None)


def degradation_at(
    base: Sequence[ServerSpec], segment: int, server: int, factor: float = 0.5
) -> DriftSchedule:
    """The canonical benchmark scenario: one server degrades mid-run."""
    return DriftSchedule(
        (DriftEvent(segment, server, degrade_server(base[server], factor)),))


def congestion_at(
    base: Sequence[ServerSpec], segment: int, server: int, factor: float = 0.5
) -> DriftSchedule:
    """One server's shared subsystem congests mid-run (D-matrix drift)."""
    return DriftSchedule(
        (DriftEvent(segment, server, congest_server(base[server], factor)),))


def stochastic_congestion(
    base: Sequence[ServerSpec],
    rate: float,
    seed: int = 0,
    *,
    segments: int = 8,
    low: float = 0.4,
    high: float = 0.9,
    servers: Sequence[int] | None = None,
) -> DriftSchedule:
    """Multi-tenant background noise: a stochastic co-tenant per segment.

    The Ivanov et al. virtualized-Hadoop setting: co-tenants outside the
    scheduler's view come and go, stealing shared storage bandwidth. Each
    segment, each server is independently congested with probability
    ``rate`` (a ``congest_server`` event with factor ~ U[low, high] -- the
    drift that moves the pairwise D-matrix itself) and otherwise reverts to
    its nominal spec. Events are emitted only on state *changes* (congestion
    onset, factor change, or clearing), so a quiet fleet stays a short
    schedule. ``servers`` restricts the process to a subset of the fleet --
    benchmarks use it to keep one server's injected deterministic divergence
    out of the noise floor. Deterministic in ``seed``.
    """
    if not 0.0 <= rate <= 1.0:
        raise ValueError(f"congestion rate must be in [0, 1], got {rate}")
    rng = np.random.default_rng(seed)
    idx = list(range(len(base))) if servers is None else list(servers)
    events: list[DriftEvent] = []
    congested: dict[int, float] = {}  # server -> active congestion factor
    for seg in range(segments):
        for s in idx:
            if rng.random() < rate:
                factor = float(rng.uniform(low, high))
                if congested.get(s) != factor:
                    events.append(DriftEvent(seg, s, congest_server(base[s], factor)))
                    congested[s] = factor
            elif s in congested:
                events.append(DriftEvent(seg, s, base[s]))  # co-tenant left
                del congested[s]
    return DriftSchedule(tuple(events))


def merge_schedules(*schedules: DriftSchedule) -> DriftSchedule:
    """Overlay drift schedules (stable order within a segment; later
    arguments win ties on the same server+segment, since ``specs_at``
    applies events in sequence)."""
    events = [ev for sch in schedules for ev in sch.events]
    order = np.argsort([ev.segment for ev in events], kind="stable")
    return DriftSchedule(tuple(events[i] for i in order))


def gradual_decay(
    base: Sequence[ServerSpec],
    server: int,
    rate: float = 0.05,
    start: int = 0,
    segments: int = 8,
) -> DriftSchedule:
    """Per-segment geometric decay of one server from ``start`` onward."""
    events = tuple(
        DriftEvent(seg, server, decayed_spec(base[server], rate, seg - start + 1))
        for seg in range(start, segments))
    return DriftSchedule(events)
