"""The completion-observation log: what a production fleet actually sees.

One record per *completed* workload run (the unit the paper's TestDFSIO
profiling also measures), assembled host-side from the fixed-shape telemetry
arrays ``engine_jax.run_trace`` emits with ``telemetry=True``:

  wtype      -- the workload's profiling-grid type (§III characterization)
  server     -- which server it ran on
  duration   -- wall-clock run time (place -> finish)
  rate       -- observed effective throughput, data_total / duration (bytes/s)
  geo_rate   -- geometric-mean throughput, exp(mean of log instantaneous
                rate) -- what sampling the server's throughput counters and
                averaging in log space yields. This is the estimator's y:
                time-averaging *log* rate keeps the log-linear model exact
                when co-residency changes mid-run, where the arithmetic
                ``rate`` mixes regimes (Jensen gap, large at heavy
                degradation).
  co_counts  -- time-*averaged* co-resident type counts over the run [T]
                (the integral of the co-run multiset, excluding the workload
                itself, divided by the duration -- partial overlaps weighted
                exactly by how long they lasted)
  lost_frac  -- fraction of the run spent while the server was past its
                physical TDP (the estimator can down-weight or split on it)

This is deliberately *not* the simulator's internals: no solo throughputs, no
pairwise slowdowns, no cache state -- only quantities a real deployment can
log (completion times and co-residency intervals from the scheduler's own
records). The streaming estimator (``telemetry.estimator``) recovers the
paper's empirical foundation -- per-type base rates and the pairwise D-matrix
-- from exactly this.
"""
from __future__ import annotations

import dataclasses
from typing import Iterable, Sequence

import numpy as np


@dataclasses.dataclass(frozen=True)
class ObservationLog:
    """A batch of completion observations (arrays share the leading axis)."""

    wtype: np.ndarray  # i32[N] grid type per completed run
    server: np.ndarray  # i32[N] server the run was placed on
    duration: np.ndarray  # f64[N] place -> finish wall time (s)
    rate: np.ndarray  # f64[N] observed effective throughput (bytes/s)
    geo_rate: np.ndarray  # f64[N] geometric-mean throughput (bytes/s)
    co_counts: np.ndarray  # f64[N, T] time-averaged co-resident type counts
    lost_frac: np.ndarray  # f64[N] fraction of the run spent past the TDP

    def __post_init__(self):
        n = len(self.wtype)
        for f in dataclasses.fields(self):
            arr = getattr(self, f.name)
            assert len(arr) == n, f"{f.name} length {len(arr)} != {n}"

    def __len__(self) -> int:
        return len(self.wtype)

    @property
    def T(self) -> int:
        return self.co_counts.shape[1]

    @classmethod
    def empty(cls, T: int) -> "ObservationLog":
        return cls(
            wtype=np.zeros(0, np.int32),
            server=np.zeros(0, np.int32),
            duration=np.zeros(0),
            rate=np.zeros(0),
            geo_rate=np.zeros(0),
            co_counts=np.zeros((0, T)),
            lost_frac=np.zeros(0),
        )

    def select(self, mask: np.ndarray) -> "ObservationLog":
        """Subset of the log (boolean mask or index array)."""
        return ObservationLog(
            **{f.name: getattr(self, f.name)[mask] for f in dataclasses.fields(self)})

    def for_server(self, server: int) -> "ObservationLog":
        return self.select(self.server == server)

    @classmethod
    def merge(cls, logs: Iterable["ObservationLog"]) -> "ObservationLog":
        logs = list(logs)
        if not logs:
            raise ValueError("merge of zero logs (T unknown)")
        return cls(**{
            f.name: np.concatenate([getattr(l, f.name) for l in logs])
            for f in dataclasses.fields(cls)})


def observations_from_trace(
    trace,
    arr_type: Sequence[int] | np.ndarray,
    arr_bytes: Sequence[float] | np.ndarray,
    min_duration: float = 1e-12,
) -> ObservationLog:
    """Build the log from a telemetry-enabled ``EngineTrace``.

    Never-placed or never-finished arrivals (queued at deadlock, zero-length
    runs below ``min_duration``) are dropped -- a fleet cannot observe a rate
    for work that did not complete. Order follows the trace's arrival axis.
    """
    place = np.asarray(trace.place_time, np.float64)
    finish = np.asarray(trace.finish_time, np.float64)
    placement = np.asarray(trace.placement)
    duration = finish - place
    ok = (placement >= 0) & (place >= 0.0) & np.isfinite(finish) & (duration > min_duration)

    wtype = np.asarray(arr_type, np.int32)[ok]
    nbytes = np.asarray(arr_bytes, np.float64)[ok]
    duration = duration[ok]
    obs_co = np.asarray(trace.obs_co, np.float64)[ok]
    obs_lost = np.asarray(trace.obs_lost, np.float64)[ok]
    obs_logr = np.asarray(trace.obs_logr, np.float64)[ok]
    return ObservationLog(
        wtype=wtype,
        server=placement[ok].astype(np.int32),
        duration=duration,
        rate=nbytes / duration,
        geo_rate=np.exp(obs_logr / duration),
        co_counts=obs_co / duration[:, None],
        lost_frac=np.clip(obs_lost / duration, 0.0, 1.0),
    )
