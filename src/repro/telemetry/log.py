"""The completion-observation log: what a production fleet actually sees.

One record per *completed* workload run (the unit the paper's TestDFSIO
profiling also measures), assembled host-side from the fixed-shape telemetry
arrays ``engine_jax.run_trace`` emits with ``telemetry=True``:

  wtype      -- the workload's profiling-grid type (§III characterization)
  server     -- which server it ran on
  duration   -- wall-clock run time (place -> finish)
  rate       -- observed effective throughput, data_total / duration (bytes/s)
  geo_rate   -- geometric-mean throughput, exp(mean of log instantaneous
                rate) -- what sampling the server's throughput counters and
                averaging in log space yields. This is the estimator's y:
                time-averaging *log* rate keeps the log-linear model exact
                when co-residency changes mid-run, where the arithmetic
                ``rate`` mixes regimes (Jensen gap, large at heavy
                degradation).
  co_counts  -- time-*averaged* co-resident type counts over the run [T]
                (the integral of the co-run multiset, excluding the workload
                itself, divided by the duration -- partial overlaps weighted
                exactly by how long they lasted)
  lost_frac  -- fraction of the run spent while the server was past its
                physical TDP (the estimator can down-weight or split on it)

This is deliberately *not* the simulator's internals: no solo throughputs, no
pairwise slowdowns, no cache state -- only quantities a real deployment can
log (completion times and co-residency intervals from the scheduler's own
records). The streaming estimator (``telemetry.estimator``) recovers the
paper's empirical foundation -- per-type base rates and the pairwise D-matrix
-- from exactly this.

Two representations of the same stream live here:

* :class:`ObservationLog` -- the host-side numpy batch, one row per
  *completed* run, filtered at construction. The reference representation,
  and what the host estimator path consumes.
* :class:`ObservationRing` -- the device-resident fixed-capacity ring buffer
  the fleet-scale path streams through. Rows keep the trace's fixed shape and
  carry a **validity mask** instead of being host-filtered: never-placed /
  never-finished arrivals occupy a slot with ``valid=False`` and are dropped
  by the estimator's scatter (their type scatters out of range), so the whole
  observe -> estimate path stays inside one jax program with no ``np.asarray``
  round trip per segment (``StreamingEstimator.update_device``).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Iterable, NamedTuple, Sequence

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class ObservationLog:
    """A batch of completion observations (arrays share the leading axis)."""

    wtype: np.ndarray  # i32[N] grid type per completed run
    server: np.ndarray  # i32[N] server the run was placed on
    duration: np.ndarray  # f64[N] place -> finish wall time (s)
    rate: np.ndarray  # f64[N] observed effective throughput (bytes/s)
    geo_rate: np.ndarray  # f64[N] geometric-mean throughput (bytes/s)
    co_counts: np.ndarray  # f64[N, T] time-averaged co-resident type counts
    lost_frac: np.ndarray  # f64[N] fraction of the run spent past the TDP

    def __post_init__(self):
        n = len(self.wtype)
        for f in dataclasses.fields(self):
            arr = getattr(self, f.name)
            assert len(arr) == n, f"{f.name} length {len(arr)} != {n}"

    def __len__(self) -> int:
        return len(self.wtype)

    @property
    def T(self) -> int:
        return self.co_counts.shape[1]

    @classmethod
    def empty(cls, T: int) -> "ObservationLog":
        return cls(
            wtype=np.zeros(0, np.int32),
            server=np.zeros(0, np.int32),
            duration=np.zeros(0),
            rate=np.zeros(0),
            geo_rate=np.zeros(0),
            co_counts=np.zeros((0, T)),
            lost_frac=np.zeros(0),
        )

    def select(self, mask: np.ndarray) -> "ObservationLog":
        """Subset of the log (boolean mask or index array)."""
        return ObservationLog(
            **{f.name: getattr(self, f.name)[mask] for f in dataclasses.fields(self)})

    def for_server(self, server: int) -> "ObservationLog":
        return self.select(self.server == server)

    @classmethod
    def merge(cls, logs: Iterable["ObservationLog"]) -> "ObservationLog":
        logs = list(logs)
        if not logs:
            raise ValueError("merge of zero logs (T unknown)")
        return cls(**{
            f.name: np.concatenate([getattr(l, f.name) for l in logs])
            for f in dataclasses.fields(cls)})


def observations_from_trace(
    trace,
    arr_type: Sequence[int] | np.ndarray,
    arr_bytes: Sequence[float] | np.ndarray,
    min_duration: float = 1e-12,
) -> ObservationLog:
    """Build the log from a telemetry-enabled ``EngineTrace``.

    Never-placed or never-finished arrivals (queued at deadlock, zero-length
    runs below ``min_duration``) are dropped -- a fleet cannot observe a rate
    for work that did not complete. Order follows the trace's arrival axis.
    """
    place = np.asarray(trace.place_time, np.float64)
    finish = np.asarray(trace.finish_time, np.float64)
    placement = np.asarray(trace.placement)
    duration = finish - place
    ok = (placement >= 0) & (place >= 0.0) & np.isfinite(finish) & (duration > min_duration)

    wtype = np.asarray(arr_type, np.int32)[ok]
    nbytes = np.asarray(arr_bytes, np.float64)[ok]
    duration = duration[ok]
    obs_co = np.asarray(trace.obs_co, np.float64)[ok]
    obs_lost = np.asarray(trace.obs_lost, np.float64)[ok]
    obs_logr = np.asarray(trace.obs_logr, np.float64)[ok]
    return ObservationLog(
        wtype=wtype,
        server=placement[ok].astype(np.int32),
        duration=duration,
        rate=nbytes / duration,
        geo_rate=np.exp(obs_logr / duration),
        co_counts=obs_co / duration[:, None],
        lost_frac=np.clip(obs_lost / duration, 0.0, 1.0),
    )


# --- the device-resident stream ----------------------------------------------

class RingBlock(NamedTuple):
    """One fixed-shape block of observation rows, resident on device.

    The device twin of an :class:`ObservationLog` batch: same per-run
    quantities, but invalid rows (never placed, never finished, zero-length)
    stay in place with ``valid=False`` instead of being filtered -- every
    array keeps the trace's static shape, so the block can flow straight from
    ``run_trace`` into the jitted estimator update. ``y`` is the estimator's
    regressand ``log(geo_rate)`` directly (the only form the estimator ever
    takes the rate in).

    Storage is **packed into three arrays** (the integer fields, the scalar
    float fields, and the co-residency matrix): a ring push then costs three
    in-place slice writes instead of seven, and the named accessors below are
    lazy column slices that fuse into whatever jitted consumer reads them.
    The scalar array also carries two *materialized columns* derived from the
    co matrix (its row sum and squared row norm): they are computed once
    where the row is born, so every later estimator refresh -- which may
    re-read a ring window -- saves two full passes over the [n, T] matrix.
    """

    ints: jax.Array  # i32[n, 2]: (wtype, server); -1 on invalid rows
    scalars: jax.Array  # f32[n, 6]: (duration, y, lost_frac, valid, co_sum, co_sq)
    co: jax.Array  # f32[n, T] time-averaged co-resident type counts

    # NB: tuple semantics (len == 3 fields) must stay intact for namedtuple
    # machinery and pytree flattening -- row count is a property instead
    rows = property(lambda s: int(s.ints.shape[0]))

    @property
    def T(self) -> int:
        return int(self.co.shape[1])

    wtype = property(lambda s: s.ints[:, 0])  # grid type per row
    server = property(lambda s: s.ints[:, 1])  # placement server
    duration = property(lambda s: s.scalars[:, 0])  # place -> finish wall time
    y = property(lambda s: s.scalars[:, 1])  # log geometric-mean throughput
    lost_frac = property(lambda s: s.scalars[:, 2])  # run fraction past the TDP
    valid = property(lambda s: s.scalars[:, 3] > 0.5)  # row is a real observation
    co_sum = property(lambda s: s.scalars[:, 4])  # total co-resident exposure
    co_sq = property(lambda s: s.scalars[:, 5])  # squared norm of the co row

    @classmethod
    def build(cls, wtype, server, duration, y, co, lost_frac, valid) -> "RingBlock":
        """Pack per-field arrays (device or host) into the stored layout."""
        f32 = jnp.float32
        co = jnp.asarray(co, f32)
        return cls(
            ints=jnp.stack([jnp.asarray(wtype, jnp.int32),
                            jnp.asarray(server, jnp.int32)], axis=1),
            scalars=jnp.stack([jnp.asarray(duration, f32), jnp.asarray(y, f32),
                               jnp.asarray(lost_frac, f32),
                               jnp.asarray(valid, f32),
                               co.sum(axis=1), (co * co).sum(axis=1)], axis=1),
            co=co,
        )


def _rows_from_trace(trace, arr_type: jax.Array, min_duration: float = 1e-12) -> RingBlock:
    place = trace.place_time
    finish = trace.finish_time
    duration = finish - place
    ok = ((trace.placement >= 0) & (place >= 0.0)
          & jnp.isfinite(finish) & (duration > min_duration))
    dur = jnp.where(ok, duration, 1.0)  # dummy divisor on voided rows
    return RingBlock.build(
        wtype=jnp.where(ok, arr_type.astype(jnp.int32), -1),
        server=jnp.where(ok, trace.placement.astype(jnp.int32), -1),
        duration=jnp.where(ok, duration, 0.0),
        y=trace.obs_logr / dur,
        co=trace.obs_co / dur[:, None],
        lost_frac=jnp.clip(trace.obs_lost / dur, 0.0, 1.0),
        valid=ok,
    )


def rows_from_trace(trace, arr_type: jax.Array, min_duration: float = 1e-12) -> RingBlock:
    """Device-side :func:`observations_from_trace`: trace -> masked rows.

    Same completion semantics (never-placed / never-finished / sub-
    ``min_duration`` runs are not observations) but expressed as a validity
    mask over the trace's fixed arrival axis instead of host-side filtering,
    so the block never leaves the device.
    """
    return _rows_from_trace_jit(trace, jnp.asarray(arr_type), min_duration)


_rows_from_trace_jit = jax.jit(_rows_from_trace)


def _write_rows_contig(buf: RingBlock, block: RingBlock, ptr) -> RingBlock:
    """In-place slice write of a non-wrapping block (shared by both jitted
    push programs -- the packed layout lives in exactly one place)."""
    return RingBlock(*(
        jax.lax.dynamic_update_slice(
            b, v.astype(b.dtype), (ptr,) + (0,) * (b.ndim - 1))
        for b, v in zip(buf, block)))


@partial(jax.jit, donate_argnums=(0,))
def _ring_write_trace(
    buf: RingBlock, trace, arr_type: jax.Array, ptr: jax.Array, min_duration: float
) -> tuple[RingBlock, RingBlock]:
    """Fused trace -> rows -> contiguous ring write: one program launch per
    segment on the ingest hot path (returns the written block as well)."""
    block = _rows_from_trace(trace, arr_type, min_duration)
    return _write_rows_contig(buf, block, ptr), block


def _ring_write_masked(buf: RingBlock, block: RingBlock, ptr, n_valid) -> RingBlock:
    """Masked modular ring write: rows [0, n_valid) land at [ptr, ptr +
    n_valid) mod capacity, the rest scatter out of bounds and are dropped.

    Plain (un-jitted) on purpose: the device-resident closed loop embeds it
    in its own scan, where the written row count is a *traced* quantity --
    the jitted pushes below keep their static-shape fast paths.
    """
    cap = buf.ints.shape[0]
    n = block.ints.shape[0]
    i = jnp.arange(n, dtype=jnp.int32)
    idx = jnp.where(i < n_valid, (ptr + i) % cap, cap)
    return RingBlock(*(b.at[idx].set(v.astype(b.dtype))
                       for b, v in zip(buf, block)))


@partial(jax.jit, donate_argnums=(0,))
def _ring_write(buf: RingBlock, block: RingBlock, ptr: jax.Array) -> RingBlock:
    """Scatter ``block``'s rows into the ring at [ptr, ptr + n) mod capacity."""
    n = block.wtype.shape[0]
    idx = (ptr + jnp.arange(n)) % buf.wtype.shape[0]
    return RingBlock(*(b.at[idx].set(v.astype(b.dtype))
                       for b, v in zip(buf, block)))


@partial(jax.jit, donate_argnums=(0,))
def _ring_write_contig(buf: RingBlock, block: RingBlock, ptr: jax.Array) -> RingBlock:
    """Contiguous fast path: the block fits without wrapping, so every array
    updates with one in-place dynamic slice (cheaper than the general
    modular scatter -- and the common case, since pushes are segment-sized
    and capacities are segment multiples)."""
    return _write_rows_contig(buf, block, ptr)


class ObservationRing:
    """Fixed-capacity device-resident ring of observation rows.

    The working set of the fleet-scale estimator (ISSUE 4 / ROADMAP
    "telemetry at fleet scale"): completion telemetry accumulates here across
    traces as fixed-shape :class:`RingBlock` rows -- validity mask included,
    no host filtering -- and the estimator's fused ``update_device`` consumes
    blocks (or re-reads ring windows) without materializing a host
    :class:`ObservationLog`. Capacity is spent in *trace rows*, valid or not:
    a voided row (arrival that never completed) occupies its slot like any
    other, which keeps every push a static-shape scatter. Once full, the
    oldest rows are overwritten -- exactly the forgetting a bounded
    observation window is supposed to do.

    The ring is a host-side object holding device arrays; pushes mutate it in
    place (the underlying jitted scatter donates the old buffers, so a push
    does not copy the ring).
    """

    def __init__(self, capacity: int, T: int):
        if capacity <= 0:
            raise ValueError(f"capacity must be positive (got {capacity})")
        self.capacity = int(capacity)
        self._buf = RingBlock(
            ints=jnp.full((capacity, 2), -1, jnp.int32),
            scalars=jnp.zeros((capacity, 6), jnp.float32),
            co=jnp.zeros((capacity, T), jnp.float32),
        )
        self.ptr = 0  # next write slot
        self.total = 0  # rows ever pushed (valid or not)

    @property
    def T(self) -> int:
        return self._buf.T

    def __len__(self) -> int:
        """Rows currently held (ring slots written at least once)."""
        return min(self.total, self.capacity)

    def push(self, block: RingBlock) -> RingBlock:
        """Append one block of rows; returns the block (for chained updates).

        Blocks longer than the ring keep only their newest ``capacity`` rows
        (the older ones would have been overwritten within the same push).
        """
        n = block.rows
        if n == 0:
            return block
        if n > self.capacity:
            block = RingBlock(*(a[n - self.capacity:] for a in block))
            n = self.capacity
        write = _ring_write_contig if self.ptr + n <= self.capacity else _ring_write
        self._buf = write(self._buf, block, jnp.int32(self.ptr))
        self.ptr = (self.ptr + n) % self.capacity
        self.total += n
        return block

    def push_trace(self, trace, arr_type: jax.Array, min_duration: float = 1e-12) -> RingBlock:
        """Fold one telemetry-enabled ``EngineTrace`` into the ring, on device.

        The common case (the block fits before the wrap point) fuses the
        trace -> rows conversion and the ring write into one program launch.
        """
        arr_type = jnp.asarray(arr_type)
        n = int(arr_type.shape[0])
        if n == 0:
            return rows_from_trace(trace, arr_type, min_duration)
        if self.ptr + n <= self.capacity:
            self._buf, block = _ring_write_trace(
                self._buf, trace, arr_type, jnp.int32(self.ptr), min_duration)
            self.ptr = (self.ptr + n) % self.capacity
            self.total += n
            return block
        return self.push(rows_from_trace(trace, arr_type, min_duration))

    def view(self) -> RingBlock:
        """The ring's full contents as one masked block (device arrays).

        Never-written slots carry ``valid=False`` (and type -1), so the view
        is safe to feed to any masked consumer regardless of fill level.

        Lifetime: a view is valid until the **next push** -- pushes donate
        the underlying buffers to the in-place write, which deletes the
        arrays a previously returned view holds (reading one afterwards
        raises jax's "Array has been deleted"). Consume the view (or copy
        it) before pushing again; dispatching a jitted consumer before the
        push is safe -- in-flight reads complete before donation reuses the
        buffers.
        """
        return self._buf

    def host_log(self) -> ObservationLog:
        """Host :class:`ObservationLog` of the currently-valid rows.

        Debug/test view: ``rate`` mirrors ``geo_rate`` (the ring does not
        keep per-run byte totals -- the estimator never consumes the
        arithmetic rate).
        """
        ints = np.asarray(self._buf.ints)
        scalars = np.asarray(self._buf.scalars, np.float64)
        valid = scalars[:, 3] > 0.5
        geo = np.exp(scalars[valid, 1])
        return ObservationLog(
            wtype=ints[valid, 0].astype(np.int32),
            server=ints[valid, 1].astype(np.int32),
            duration=scalars[valid, 0],
            rate=geo,
            geo_rate=geo,
            co_counts=np.asarray(self._buf.co, np.float64)[valid],
            lost_frac=scalars[valid, 2],
        )


def block_from_log(obs: ObservationLog) -> RingBlock:
    """Lift a host :class:`ObservationLog` to a device block (all rows valid).

    The bridge for tests and host-collected streams: the device estimator
    path consumes the result exactly as it consumes trace-born blocks.
    """
    return RingBlock.build(
        wtype=np.asarray(obs.wtype, np.int32),
        server=np.asarray(obs.server, np.int32),
        duration=np.asarray(obs.duration, np.float32),
        y=np.log(np.asarray(obs.geo_rate, np.float64)).astype(np.float32),
        co=np.asarray(obs.co_counts, np.float32),
        lost_frac=np.asarray(obs.lost_frac, np.float32),
        valid=np.ones(len(obs), np.float32),
    )
