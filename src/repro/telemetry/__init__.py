"""Telemetry & online D-matrix estimation: the observe -> estimate -> schedule loop.

The paper's scheduler stands on a 52 900-pair offline profiling pass; this
package replaces that frozen ground truth with a closed loop a production
fleet can actually run:

  observe   ``engine_jax.run_trace(..., telemetry=True)`` emits a fixed-shape
            device-resident observation log; ``log.observations_from_trace``
            lifts it to per-completion host records (type, co-residency,
            rate), while ``log.rows_from_trace`` keeps the same records on
            device as validity-masked rows and ``log.ObservationRing``
            accumulates them across traces in a fixed-capacity device ring.
  estimate  ``estimator.StreamingEstimator`` recovers per-type base rates and
            the pairwise D-matrix in log-slowdown space, with per-pair
            confidence counts and prior fallback; ``update`` is the host
            float64 reference, ``update_device`` the fused jitted path that
            consumes ring blocks without a host round trip. The batched
            pair-statistic scatter is a Pallas kernel (``kernels.telemetry``)
            carrying K stacked statistics per pass.
  schedule  ``core.engine.AdaptiveEngine`` alternates trace segments with
            estimator refreshes, placing from *estimated* dynamics while the
            simulator stays ground truth; ``stream=True`` keeps the whole
            observe -> estimate path device-resident through the ring.
  drift     ``drift`` builds the non-stationary worlds (perturbed, decaying,
            degraded servers) the loop must track.

Benchmarked end to end by ``benchmarks/adaptive_regret.py`` (makespan regret
vs the true-D oracle as observations accumulate) and
``benchmarks/telemetry_throughput.py`` (host vs device observations/sec).
See DESIGN.md §9-§10.
"""
from .drift import (
    DriftEvent,
    DriftSchedule,
    congest_server,
    congestion_at,
    decayed_spec,
    degradation_at,
    degrade_server,
    gradual_decay,
    merge_schedules,
    perturb_spec,
    scale_perf,
    stochastic_congestion,
)
from .estimator import (
    DeviceEstimatorState,
    EstimatorBank,
    StreamingEstimator,
    make_scatter,
)
from .log import (
    ObservationLog,
    ObservationRing,
    RingBlock,
    block_from_log,
    observations_from_trace,
    rows_from_trace,
)

__all__ = [
    "DeviceEstimatorState",
    "DriftEvent",
    "EstimatorBank",
    "DriftSchedule",
    "ObservationLog",
    "ObservationRing",
    "RingBlock",
    "StreamingEstimator",
    "block_from_log",
    "congest_server",
    "congestion_at",
    "decayed_spec",
    "degradation_at",
    "degrade_server",
    "gradual_decay",
    "make_scatter",
    "merge_schedules",
    "observations_from_trace",
    "perturb_spec",
    "rows_from_trace",
    "scale_perf",
    "stochastic_congestion",
]
