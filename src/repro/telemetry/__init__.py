"""Telemetry & online D-matrix estimation: the observe -> estimate -> schedule loop.

The paper's scheduler stands on a 52 900-pair offline profiling pass; this
package replaces that frozen ground truth with a closed loop a production
fleet can actually run:

  observe   ``engine_jax.run_trace(..., telemetry=True)`` emits a fixed-shape
            device-resident observation log; ``log.observations_from_trace``
            lifts it to per-completion records (type, co-residency, rate).
  estimate  ``estimator.StreamingEstimator`` recovers per-type base rates and
            the pairwise D-matrix in log-slowdown space, with per-pair
            confidence counts and prior fallback; the batched pair-statistic
            scatter is a Pallas kernel (``kernels.telemetry``).
  schedule  ``core.engine.AdaptiveEngine`` alternates trace segments with
            estimator refreshes, placing from *estimated* dynamics while the
            simulator stays ground truth.
  drift     ``drift`` builds the non-stationary worlds (perturbed, decaying,
            degraded servers) the loop must track.

Benchmarked end to end by ``benchmarks/adaptive_regret.py`` (makespan regret
vs the true-D oracle as observations accumulate). See DESIGN.md §9.
"""
from .drift import (
    DriftEvent,
    DriftSchedule,
    congest_server,
    congestion_at,
    decayed_spec,
    degradation_at,
    degrade_server,
    gradual_decay,
    perturb_spec,
    scale_perf,
)
from .estimator import StreamingEstimator, make_scatter
from .log import ObservationLog, observations_from_trace

__all__ = [
    "DriftEvent",
    "DriftSchedule",
    "ObservationLog",
    "StreamingEstimator",
    "congest_server",
    "congestion_at",
    "decayed_spec",
    "degradation_at",
    "degrade_server",
    "gradual_decay",
    "make_scatter",
    "observations_from_trace",
    "perturb_spec",
    "scale_perf",
]
