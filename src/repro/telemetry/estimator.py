"""Streaming recovery of the scheduler's empirical foundation from telemetry.

The paper fits its additive degradation model (Eqn 3) from a 52 900-pair
offline profiling pass; no production fleet has that, and interference
profiles drift with co-tenancy and hardware wear. This module recovers the
same quantities *online*, from the completion observations of
``telemetry.log``:

  per-type base throughput  b_t        (the solo curves of Fig 1-2)
  pairwise degradation      D[u, t]    (the profiled matrix of §IV.B)

Estimation happens in **log-slowdown space**, where the ground truth is
linear: pairwise slowdowns compose multiplicatively, so a type-t run whose
time-averaged co-resident counts were ``cbar`` satisfies (keep-cache regime)

  y  :=  log(rate)  =  log b_t  +  sum_u cbar_u * L[u, t],      L = log(1 - d)

-- a linear model in (log b_t, L[:, t]). Co-run observations determine only
the *sum* ``log b_t + cbar @ L[:, t]`` (base rate and pair effects trade off
along an unidentifiable direction), so updates are decoupled along
identifiability lines: solo runs -- the only unbiased base signal -- update
``log_b``; co-run residuals against the freshly updated base take one
damped, exposure-weighted least-squares step on ``L`` alone (a
batch-normalized LMS update whose step size is invariant to batch
composition). Per-pair confidence counts accumulate alongside; below a
confidence floor the estimate falls back to a prior (profiled, or a
uniform/optimistic constant), and an EWMA ``decay`` on the confidence lets
fresh evidence overturn stale estimates after a drift. Forgetting is
**exposure-based**: decay compounds per observation-unit (``decay ** n`` per
batch of n used observations, with matching triangular weights inside the
batch), so the confidence half-life is a property of the stream, not of how
callers chunk it -- eight segment-sized updates and one merged log leave the
confidence state identical.

Two update paths implement the same estimator:

  ``update``         host numpy (float64), consuming an ``ObservationLog``;
                     the reference semantics.
  ``update_device``  one fused jax program consuming a device-resident
                     ``RingBlock`` (``telemetry.log.ObservationRing``):
                     validity/lost-frac masking, solo/co split, residuals,
                     and the LMS step compile into a single jitted call whose
                     pair statistics come from one stacked-statistic scatter
                     -- no host round trip per batch. Estimator state lives
                     on device between calls and syncs back lazily when an
                     estimate is read.

The batched pair-statistic scatter-accumulation -- the only O(B T) hot loop
-- is the shared contract implemented by the Pallas kernel
(``kernels.telemetry.pair_scatter``, MXU one-hot contraction), a jnp
fallback, and the float64 numpy reference (``kernels.ref.pair_scatter_ref``).
All three scatter K stacked statistics per pass; the estimator streams the
batch exactly once per update (residual numerator and exposure weight ride
together).

Known model limits (documented, by design -- the estimator's model and the
simulated world *can* disagree): observations that straddle the TDP mix the
keep/lost base rates, and time-varying co-residency makes log-of-mean differ
from mean-of-log. Both appear as residual noise; ``max_lost_frac`` filters
the worst of the former. Chunk-invariance is exact for the confidence state
(``n_pair``/``n_base``) and first-order for the point estimates: the damped
LMS steps themselves remain batch-sequential, so splitting a log changes
``L``/``log_b`` only at O(lr^2) (tested to a tight tolerance).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Callable, Literal, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from .log import ObservationLog, RingBlock

ScatterName = Literal["auto", "jnp", "pallas", "numpy"]

#: scatter contract: (types i32[B], cbar f[B, T], vals f[B] or f[K, B]) ->
#: (pair [T, T], base [T]) -- or ([K, T, T], [K, T]) for stacked vals -- with
#: pair[k, u, t] = sum_b cbar[b, u] vals[k, b] 1{t_b = t}
Scatter = Callable[[np.ndarray, np.ndarray, np.ndarray], tuple[np.ndarray, np.ndarray]]


def _scatter_jnp_device(types, cbar, vals):
    """The jnp scatter on device arrays (jit-safe; stacked or 1-D vals).

    The contraction carries an explicit ``preferred_element_type`` and
    highest precision so accumulation stays full f32 on every backend (TPU
    matmuls would otherwise downcast to bf16, drifting from the float64
    reference contract on large batches).
    """
    T = cbar.shape[1]
    squeeze = vals.ndim == 1
    vals2 = (vals[None, :] if squeeze else vals).astype(jnp.float32)  # [K, B]
    onehot = (jnp.arange(T)[None, :] == types[:, None]).astype(jnp.float32)
    cbar = cbar.astype(jnp.float32)
    base = jax.lax.dot_general(  # [K, T] = vals2 @ onehot
        vals2, onehot, (((1,), (0,)), ((), ())),
        precision=jax.lax.Precision.HIGHEST, preferred_element_type=jnp.float32)
    sel = onehot[None, :, :] * vals2[:, :, None]  # [K, B, T]
    pair = jax.lax.dot_general(  # [K, T(u), T(t)]: contract the batch axis
        jnp.broadcast_to(cbar[None], sel.shape[:1] + cbar.shape), sel,
        (((1,), (1,)), ((0,), (0,))),
        precision=jax.lax.Precision.HIGHEST, preferred_element_type=jnp.float32)
    return (pair[0], base[0]) if squeeze else (pair, base)


_scatter_jnp_jit = jax.jit(_scatter_jnp_device)


def make_scatter(backend: ScatterName = "auto") -> Scatter:
    """Resolve a pair-statistic scatter backend to the shared host contract."""
    if backend == "auto":
        backend = "pallas" if jax.default_backend() == "tpu" else "jnp"
    if backend == "numpy":
        from ..kernels.ref import pair_scatter_ref

        return pair_scatter_ref
    if backend == "jnp":
        def scatter_jnp(types, cbar, vals):
            # one module-level jitted program shared by every estimator;
            # retracing then happens only per (B, T, K) shape instead of
            # rebuilding the op-by-op eager graph on every call
            pair, base = _scatter_jnp_jit(
                jnp.asarray(np.asarray(types), jnp.int32),
                jnp.asarray(np.asarray(cbar, np.float32)),
                jnp.asarray(np.asarray(vals, np.float32)))
            return np.asarray(pair, np.float64), np.asarray(base, np.float64)

        return scatter_jnp
    if backend == "pallas":
        from ..kernels.telemetry import pair_scatter

        interpret = jax.default_backend() != "tpu"

        def scatter_pallas(types, cbar, vals):
            pair, base = pair_scatter(
                np.asarray(types, np.int32), np.asarray(cbar, np.float32),
                np.asarray(vals, np.float32), interpret=interpret)
            return np.asarray(pair, np.float64), np.asarray(base, np.float64)

        return scatter_pallas
    raise ValueError(f"unknown scatter backend {backend!r}")


class DeviceEstimatorState(NamedTuple):
    """The estimator's mutable state as device arrays (``update_device``).

    The pair tables live **target-major** ([t, u] -- the transpose of the
    host's canonical [u, t]): the fused update then reads each observation's
    coefficient row ``L_t[t_b]`` as one contiguous row gather and the column
    scatter-add lands rows without a transpose. ``device_state``/``_pull``
    transpose at the host boundary only.
    """

    L_t: "object"  # f32[T, T] log(1 - d) estimate, transposed ([t, u])
    log_b: "object"  # f32[T] log base-throughput estimate
    n_pair_t: "object"  # f32[T, T] decayed per-pair exposure, transposed
    n_base: "object"  # f32[T] decayed per-type solo counts
    n_obs: "object"  # i32 scalar observations consumed


def _blend_prior_t(L_t, n_pair_t, L_prior_t, confidence_floor):
    """``estimate_D``'s confidence blend, in target-major device form.

    Below the floor the pair estimate falls back linearly (in accumulated
    exposure) to the prior -- exactly the host read's blend, kept plain so
    the device-resident closed loop (``core.closed_loop``) embeds it in its
    own trace instead of pulling [T, T] tables to the host every segment.
    """
    w = jnp.minimum(n_pair_t / confidence_floor, 1.0)
    return w * L_t + (1.0 - w) * L_prior_t


def _bank_core(
    state: DeviceEstimatorState,  # arrays carry a leading server axis [m, ...]
    block: RingBlock,
    *,
    lr: float,
    decay: float,
    step_damp: float,
    solo_eps: float,
    max_lost_frac: float,
    use_pallas: bool,
    interpret: bool,
    sparse_tables: bool = False,
):
    """The fused observe -> estimate step: m per-server estimators, one pass.

    Mirrors ``StreamingEstimator.update`` exactly, independently per server
    -- lost-frac filter, exposure-based decay with **per-server** triangular
    weights (a server's half-life counts its own observations, not the
    fleet's), solo-then-co ordering (co residuals see the freshly updated
    base) -- on masked fixed-shape rows. Every row updates only the server
    its ``server`` column names: the per-server split is a scatter index,
    so the batch streams once regardless of m. The single-estimator
    ``_update_device`` is this program with m = 1 (no duplicated twin to
    drift out of parity). Returns (new_state, used_total).

    ``sparse_tables`` routes the [m, T, T] table updates through scatters
    that touch only the <= B (server, type) rows the batch names, instead
    of dense full-table accumulators -- numerically identical (same
    contributions, same in-order summation; untouched entries skip a
    ``* 1.0`` / ``+ 0.0``), but O(B T) instead of O(m T^2) per call. The
    device-resident closed loop runs with it on; the host-alternating path
    keeps the dense form that the purity/x64 audits pin.
    """
    L_t, log_b, n_pair_t, n_base, n_obs = state
    m, T = log_b.shape
    valid = block.valid & (block.lost_frac <= max_lost_frac)
    valid &= (block.server >= 0) & (block.server < m)
    s_clip = jnp.clip(block.server, 0, m - 1)
    onehot_s = (jnp.arange(m)[None, :] == s_clip[:, None]) & valid[:, None]  # [B, m]
    n_used = onehot_s.sum(axis=0)  # [m] rows per server

    if decay < 1.0:
        # decay^(n_used[s] - rank within s): same triangular weights as the
        # host path, so the confidence state is invariant to how the stream
        # is chunked
        rank = jnp.cumsum(onehot_s.astype(jnp.float32), axis=0)  # [B, m]
        w_bm = jnp.where(onehot_s,
                         decay ** (n_used[None, :].astype(jnp.float32) - rank), 0.0)
        w = w_bm.sum(axis=1)  # [B]: each row has at most one server column
        sdecay = decay ** n_used.astype(jnp.float32)  # [m]
        if sparse_tables:
            # untouched servers have n_used = 0 -> sdecay exactly 1.0: decay
            # only the rows present, once each (first occurrence per server)
            first = onehot_s & (rank == 1.0)
            fi = jnp.where(first.any(axis=1), s_clip, m)  # OOB drops the rest
            n_pair_t = n_pair_t.at[fi].multiply(sdecay[s_clip][:, None, None])
        else:
            n_pair_t = n_pair_t * sdecay[:, None, None]
        n_base = n_base * sdecay[:, None]
    else:
        w = valid.astype(jnp.float32)

    t_clip = jnp.clip(block.wtype, 0, T - 1)
    co_sum = block.co_sum  # materialized at row birth (see RingBlock)
    solo = valid & (co_sum <= solo_eps)

    # solo runs anchor the base (see module docstring); rows land in a dump
    # slot (index T) that is sliced away, both statistics in one scatter
    t_solo = jnp.where(solo, block.wtype, T)
    r0 = block.y - log_b[s_clip, t_clip]
    ws = jnp.where(solo, w, 0.0)
    acc0 = jnp.zeros((m, T + 1, 2), jnp.float32).at[s_clip, t_solo].add(
        jnp.stack([ws * r0, ws], axis=1))
    num0, cnt0 = acc0[:, :T, 0], acc0[:, :T, 1]
    log_b = log_b + lr * num0 / (cnt0 + step_damp)
    n_base = n_base + cnt0

    # co-run residuals against the *updated* base take the LMS step on L;
    # the [t, u] layout makes the coefficient lookup one contiguous row
    # gather fused into the multiply-reduce
    is_co = valid & (co_sum > solo_eps)
    pred = log_b[s_clip, t_clip] + (block.co * L_t[s_clip, t_clip]).sum(axis=1)
    xnorm = jnp.maximum(block.co_sq, solo_eps)
    h = (block.y - pred) / xnorm
    wc = jnp.where(is_co, w, 0.0)
    tt = jnp.where(is_co & (block.wtype >= 0) & (block.wtype < T),
                   block.wtype, T)  # dump slot; the kernel drops >= T too
    stats = jnp.stack([wc * h, wc])  # residual numerator + exposure weight
    if use_pallas and m == 1:
        # TPU lowering: the one-hot MXU contraction (O(B T^2) flops are free
        # there, scatters are not). A multi-server MXU variant (one-hot over
        # the combined (server, type) column space) is a kernel follow-up;
        # banks with m > 1 take the scatter-add below meanwhile.
        from ..kernels.telemetry import pair_scatter

        pair, _ = pair_scatter(tt, block.co, stats, interpret=interpret)
        pair_t = pair.swapaxes(1, 2)[:, None]  # [K, 1, T(t), T(u)]
        L_t = L_t + lr * pair_t[0] / (pair_t[1] + step_damp)
        n_pair_t = n_pair_t + pair_t[1]
    elif sparse_tables:
        # accumulate per distinct (server, type) key into compact [B, T]
        # slots (each row folds into its key's first occurrence, in row
        # order -- the same in-order duplicate summation as the dense
        # scatter below), then one row-scatter applies the damped step to
        # exactly the rows the batch names
        B = block.co.shape[0]
        contrib = block.co[None, :, :] * stats[:, :, None]  # [K, B, T(u)]
        key = s_clip * (T + 1) + tt
        idx_b = jnp.arange(B, dtype=jnp.int32)
        fo = jnp.min(jnp.where(key[None, :] == key[:, None],
                               idx_b[None, :], B), axis=1)  # first occurrence
        slots = jnp.zeros((2, B, T), jnp.float32).at[:, fo].add(contrib)
        delta = lr * slots[0] / (slots[1] + step_damp)
        ls = jnp.where(idx_b == fo, s_clip, m)  # dups/OOB rows drop
        L_t = L_t.at[ls, tt].add(delta)  # tt = T (dump) drops too
        n_pair_t = n_pair_t.at[ls, tt].add(slots[1])
    else:
        # CPU/GPU lowering: a duplicate-index scatter-add touches only the
        # O(B T) contributing elements (~200x less work at T = 230 than the
        # contraction) and lands target-major rows directly -- no transpose
        contrib = block.co[None, :, :] * stats[:, :, None]  # [K, B, T(u)]
        acc = jnp.zeros((2, m, T + 1, T), jnp.float32).at[:, s_clip, tt].add(contrib)
        pair_t = acc[:, :, :T]  # [K, m, T(t), T(u)]
        L_t = L_t + lr * pair_t[0] / (pair_t[1] + step_damp)
        n_pair_t = n_pair_t + pair_t[1]

    new = DeviceEstimatorState(L_t, log_b, n_pair_t, n_base, n_obs + n_used)
    return new, n_used.sum()


@partial(
    jax.jit,
    static_argnames=("lr", "decay", "step_damp", "solo_eps", "max_lost_frac",
                     "use_pallas", "interpret"),
)
def _update_device(
    state: DeviceEstimatorState,
    block: RingBlock,
    server,  # i32 scalar; < 0 accepts every server
    **hypers,
):
    """Single-estimator fused update: ``_bank_core`` as a bank of one.

    Rows matching ``server`` (or every row when ``server < 0``) are remapped
    to bank row 0; everything else drops inside the core's validity mask.
    """
    sel = (server < 0) | (block.server == server)
    block = block._replace(
        ints=jnp.stack([block.wtype, jnp.where(sel, 0, -1)], axis=1))
    lifted = DeviceEstimatorState(*(a[None] for a in state))
    new, used = _bank_core(lifted, block, **hypers)
    return DeviceEstimatorState(*(a[0] for a in new)), used


@dataclasses.dataclass
class StreamingEstimator:
    """Online (base-rate, D-matrix) estimator for one server.

    Parameters
    ----------
    T : grid size (230 for the paper's 10 x 23 grid).
    prior_D : profiled D matrix [T, T], or a scalar uniform prior (0.0 = the
        optimistic "no interference" prior that makes the un-observed
        scheduler consolidate aggressively and *learn* the cost).
    prior_solo : per-type solo throughput prior [T] (bytes/s). Solo profiling
        is the cheap 230-run pass -- it is the 52 900-pair matrix that this
        estimator amortizes away -- but the base rate still adapts online
        (from solo runs), so a drifting/degraded server is tracked even with
        a stale prior. ``None`` starts from 1 byte/s and learns the base
        from solo observations alone.
    lr : damping of each batch's exposure-weighted least-squares step (0, 1].
    decay : EWMA forgetting of the confidence counts **per observation-unit**
        (compounded ``decay ** n`` over a batch of n used observations, with
        matching triangular weights inside the batch). < 1 lets the estimator
        re-converge after drift; the half-life is ``log 0.5 / log decay``
        observations regardless of how callers chunk the log, so values live
        much closer to 1 than the old per-call decay (e.g. 0.997 ~ forgetting
        half the evidence every ~230 observations).
    confidence_floor : per-pair exposure below which ``estimate_D`` blends
        toward the prior (linearly in accumulated exposure).
    max_lost_frac : observations that spent more than this fraction of their
        run past the physical TDP are excluded (they mix base-rate regimes).
    scatter : pair-statistic backend ('auto' picks pallas on TPU, jnp else).
        ``update_device`` maps 'numpy' (not jit-able) to the jnp contraction.
    """

    T: int
    prior_D: float | np.ndarray = 0.0
    prior_solo: np.ndarray | None = None
    lr: float = 0.5
    decay: float = 1.0
    confidence_floor: float = 4.0
    max_lost_frac: float = 0.5
    scatter: ScatterName = "auto"
    #: exposure added to the step denominator: damps updates from batches
    #: whose total exposure to a pair is far below one full co-run
    step_damp: float = 0.5
    #: co-resident exposure below which a run counts as a *solo* observation
    solo_eps: float = 0.05

    def __post_init__(self):
        prior = self.prior_D
        if np.isscalar(prior):
            prior = np.full((self.T, self.T), float(prior))
        prior = np.clip(np.asarray(prior, np.float64), 0.0, 1.0 - 1e-9)
        self._L_prior = np.log1p(-prior)  # log(1 - d) prior
        if self.prior_solo is None:
            self._logb_prior = np.zeros(self.T)
        else:
            self._logb_prior = np.log(np.asarray(self.prior_solo, np.float64))
        # state: current estimates + accumulated confidence (host canonical;
        # a device mirror takes over between update_device calls)
        self._L = self._L_prior.copy()
        self._log_b = self._logb_prior.copy()
        self._n_pair = np.zeros((self.T, self.T))
        self._n_base = np.zeros(self.T)
        self._n_obs = 0
        self._dev: DeviceEstimatorState | None = None
        self._stale: set[str] = set()  # host fields behind the device mirror
        self._bank = None  # EstimatorBank holding this member, if any
        self._scatter = make_scatter(self.scatter)
        # static update config, resolved once: the fused update's jit cache
        # keys on these, so per-call float(...) coercions (or re-probing the
        # backend) would rebuild the key on the per-segment hot path
        self._hypers = dict(
            lr=float(self.lr), decay=float(self.decay),
            step_damp=float(self.step_damp), solo_eps=float(self.solo_eps),
            max_lost_frac=float(self.max_lost_frac),
            use_pallas=self.scatter == "pallas" or (
                self.scatter == "auto" and jax.default_backend() == "tpu"),
            interpret=jax.default_backend() != "tpu")

    # -- host <-> device state management ---------------------------------
    def _mutated(self) -> None:
        """This estimator's state moved ahead of any bank's stacked copy."""
        if self._bank is not None:
            self._bank._invalidate()

    #: host-canonical field names, in device-state order
    _FIELDS = ("L", "log_b", "n_pair", "n_base", "n_obs")

    def _pull(self, fields: "tuple[str, ...] | None" = None) -> None:
        """Sync host fields from the device mirror where they are behind.

        ``fields=None`` syncs everything; each property read passes only its
        own field, so reading the [T] base-rate vector never pulls the
        [T, T] pair tables across the device boundary (the selective-flush
        half of the no-host-sync contract the purity auditor checks).
        """
        if self._bank is not None:
            self._bank._flush()  # a banked update may hold the newest state
        want = self._stale if fields is None else (self._stale & set(fields))
        if not want:
            return
        dev = self._dev
        if "L" in want:
            self._L = np.asarray(dev.L_t, np.float64).T
        if "log_b" in want:
            self._log_b = np.asarray(dev.log_b, np.float64)
        if "n_pair" in want:
            self._n_pair = np.asarray(dev.n_pair_t, np.float64).T
        if "n_base" in want:
            self._n_base = np.asarray(dev.n_base, np.float64)
        if "n_obs" in want:
            self._n_obs = int(dev.n_obs)
        self._stale = self._stale - want

    def _host_write(self, name, value) -> None:
        self._pull()
        self._dev = None  # mirror no longer matches: rebuild on next device use
        self._mutated()
        setattr(self, "_" + name, value)

    # host-canonical views: reading syncs *its own field* from the device
    # mirror, writing (the host update path, tests poking state) pulls the
    # rest and invalidates the mirror
    L = property(lambda s: (s._pull(("L",)), s._L)[1],
                 lambda s, v: s._host_write("L", v))
    log_b = property(lambda s: (s._pull(("log_b",)), s._log_b)[1],
                     lambda s, v: s._host_write("log_b", v))
    n_pair = property(lambda s: (s._pull(("n_pair",)), s._n_pair)[1],
                      lambda s, v: s._host_write("n_pair", v))
    n_base = property(lambda s: (s._pull(("n_base",)), s._n_base)[1],
                      lambda s, v: s._host_write("n_base", v))
    n_obs = property(lambda s: (s._pull(("n_obs",)), s._n_obs)[1],
                     lambda s, v: s._host_write("n_obs", v))

    def device_state(self) -> DeviceEstimatorState:
        """The estimator's state as device arrays (building it on first use)."""
        if self._bank is not None:
            self._bank._flush()
        if self._dev is None:
            f32 = lambda x: jnp.asarray(x, jnp.float32)
            self._dev = DeviceEstimatorState(
                f32(self._L.T), f32(self._log_b), f32(self._n_pair.T),
                f32(self._n_base), jnp.int32(self._n_obs))
        return self._dev

    # -- updates ----------------------------------------------------------
    def _batch_weights(self, n: int) -> np.ndarray:
        """Per-observation decay weights, newest last (see ``decay`` docs)."""
        if self.decay >= 1.0:
            return np.ones(n)
        return self.decay ** np.arange(n - 1, -1, -1, dtype=np.float64)

    def update(self, obs: ObservationLog) -> int:
        """Consume one observation batch; returns how many records were used."""
        if len(obs) == 0:
            return 0
        keep = obs.lost_frac <= self.max_lost_frac
        obs = obs.select(keep)
        n = len(obs)
        if n == 0:
            return 0
        self._pull()
        self._dev = None
        self._mutated()
        t = np.asarray(obs.wtype, np.int32)
        cbar = np.asarray(obs.co_counts, np.float64)
        # geometric-mean rate: the log-linear model is exact in it per cache
        # regime, whereas log(bytes/duration) carries a Jensen gap whenever
        # co-residency changed mid-run
        y = np.log(np.asarray(obs.geo_rate, np.float64))

        # exposure-based forgetting: the state decays once per observation
        # consumed (not once per call), and each observation's contribution
        # carries the decay the rest of the batch will apply after it --
        # splitting a log across calls leaves the confidences identical
        w = self._batch_weights(n)
        if self.decay < 1.0:
            self._n_pair *= self.decay ** n
            self._n_base *= self.decay ** n

        # Co-run telemetry determines only the sum log_b_t + cbar @ L[:, t]:
        # base rate and pair effects trade off along an unidentifiable
        # direction, so letting co-runs touch the base bleeds any base-rate
        # drift (a degraded server) into every co-resident pair estimate.
        # The updates are therefore decoupled along identifiability lines:
        # *solo* runs -- the only unbiased base signal -- update log_b; co-run
        # residuals (against the freshly updated base) update only L. A fleet
        # whose types never run alone keeps its base prior, and the pair
        # estimates absorb the discrepancy -- the best any estimator could do.
        solo = cbar.sum(axis=1) <= self.solo_eps
        if solo.any():
            r0 = y[solo] - self._log_b[t[solo]]
            num0 = np.bincount(t[solo], weights=w[solo] * r0, minlength=self.T)
            cnt0 = np.bincount(t[solo], weights=w[solo], minlength=self.T)
            self._log_b += self.lr * num0 / (cnt0 + self.step_damp)
            self._n_base += cnt0

        co = ~solo
        if co.any():
            tc, cc, yc, wc = t[co], cbar[co], y[co], w[co]
            pred = self._log_b[tc] + np.einsum("bu,ub->b", cc, self._L[:, tc])
            xnorm = np.maximum((cc**2).sum(axis=1), self.solo_eps)
            h = (yc - pred) / xnorm  # normalized residual (LMS direction)

            # one stacked scatter carries both sufficient statistics: the
            # residual numerator and the exposure weight of the same step
            pair, _ = self._scatter(tc, cc, np.stack([wc * h, wc]))
            num_pair, wgt_pair = pair[0], pair[1]
            # exposure-weighted average step: invariant to batch composition
            self._L += self.lr * num_pair / (wgt_pair + self.step_damp)
            self._n_pair += wgt_pair

        self._n_obs += n
        return n

    def update_device(self, block: RingBlock, server: int = -1, sync: bool = True):
        """Consume one device-resident block (the fused fleet-scale path).

        ``block`` is a ``RingBlock`` -- typically what ``ObservationRing.push
        _trace`` just wrote, or a ring ``view()`` -- whose invalid rows are
        dropped by the validity mask inside the program. ``server`` restricts
        the update to rows placed on that server (< 0 consumes every row);
        per-server estimators each call this on the same block. Returns the
        number of rows consumed -- as a host int when ``sync`` (the only
        host sync this path performs), as the raw device scalar with
        ``sync=False`` so back-to-back updates pipeline without blocking.
        State stays on device until an estimate is read either way.
        """
        new, used = _update_device(
            self.device_state(), block, jnp.int32(server), **self._hypers)
        self._dev = new
        self._stale = set(self._FIELDS)
        self._mutated()
        return int(used) if sync else used

    # -- estimates --------------------------------------------------------
    # -- internal: bank interop -------------------------------------------
    def _absorb_device(self, state: DeviceEstimatorState) -> None:
        """Adopt externally-updated device state (see ``EstimatorBank``)."""
        self._dev = state
        self._stale = set(self._FIELDS)

    # -- posterior export / seed (fleet pooling) ---------------------------
    def export_posterior(self) -> DeviceEstimatorState:
        """Device snapshot of the full posterior (estimates + confidence).

        What a pool hands to a server being split out: point estimates
        (``L_t``, ``log_b``) *and* the accumulated exposure
        (``n_pair_t``/``n_base``), so the split-out estimator starts exactly
        as warm as the pool it left (``fleet.pool.PooledEstimatorBank``).
        """
        return self.device_state()

    def seed_from(self, state: DeviceEstimatorState) -> None:
        """Adopt an exported posterior as this estimator's state.

        The inverse of :meth:`export_posterior`: the prior (and every
        hyperparameter) stays this estimator's own -- only the posterior
        state is replaced. Safe on banked estimators (the bank's stacked
        copy is flushed first, then invalidated).
        """
        self._pull()  # flush any banked state before overwriting it
        self._dev = DeviceEstimatorState(*state)
        self._stale = set(self._FIELDS)
        self._mutated()

    def pair_confidence(self) -> np.ndarray:
        """Accumulated (decayed) exposure per pair, in co-run units [T, T]."""
        return self.n_pair.copy()

    def observed_mask(self, floor: float | None = None) -> np.ndarray:
        """Pairs whose accumulated exposure reached the confidence floor."""
        return self.n_pair >= (self.confidence_floor if floor is None else floor)

    def estimate_D(self) -> np.ndarray:
        """Current D-matrix estimate, prior-blended below the confidence floor."""
        w = np.minimum(self.n_pair / self.confidence_floor, 1.0)
        L_eff = w * self.L + (1.0 - w) * self._L_prior
        return np.clip(-np.expm1(L_eff), 0.0, 0.999999)

    def estimate_solo(self) -> np.ndarray:
        """Current per-type base-throughput estimate (bytes/s) [T]."""
        w = np.minimum(self.n_base / self.confidence_floor, 1.0)
        return np.exp(w * self.log_b + (1.0 - w) * self._logb_prior)


# --- the fleet bank: m per-server estimators, one fused update ----------------

#: the banked update is ``_bank_core`` jitted as-is (m from the state shape)
_update_bank = partial(
    jax.jit,
    static_argnames=("lr", "decay", "step_damp", "solo_eps", "max_lost_frac",
                     "use_pallas", "interpret", "sparse_tables"),
)(_bank_core)


@jax.jit
def _remap_rows(block: RingBlock, row_map) -> RingBlock:
    """Rewrite a block's server column through ``row_map`` (server -> row).

    The whole of estimator pooling, as data movement: ``row_map[s]`` names
    the bank row server ``s``'s observations update, so same-spec servers
    sharing a row warm that row up with every member's telemetry, and a
    ``-1`` entry (an evicted server) routes its rows to the core's dump
    mask. Servers outside the map are dropped likewise.
    """
    n = row_map.shape[0]
    s = block.server
    ok = (s >= 0) & (s < n)
    row = jnp.where(ok, row_map[jnp.clip(s, 0, n - 1)], -1)
    return block._replace(ints=jnp.stack([block.wtype, row], axis=1))


def _localize_block(block: RingBlock, lo) -> RingBlock:
    """Rebase a block's server column to this shard's slice.

    Off-shard rows land outside ``[0, m_local)`` and are dropped by the
    core's own range mask (`-1` voids stay negative on every shard); each
    observation therefore updates exactly one shard's rows, which keeps the
    sharded bank bitwise-equal to the dense one row by row.
    """
    return block._replace(
        ints=jnp.stack([block.wtype, block.server - lo], axis=1))


def bank_update_sharded(axis, state: DeviceEstimatorState, block: RingBlock,
                        **hypers):
    """``_update_bank`` with the bank rows sharded over a ``ServerAxis``.

    The block replicates (it is small: B rows of O(T)), the [m, ...] state
    shards by row, and every shard runs the *same* fused core on its slice
    with the server column rebased -- per-row arithmetic, scatter order and
    triangular decay weights are all shard-local, so each bank row's update
    is bitwise the dense one. Only the consumed-row count crosses the mesh
    (one ``psum``). A dense axis calls ``_update_bank`` directly: the
    single-device program is untouched.
    """
    if not axis.is_sharded:
        return _update_bank(state, block, **hypers)
    m = state.log_b.shape[0]
    axis.validate(m)
    m_local = axis.local_m(m)

    def body(state_l, block):
        block_l = _localize_block(block, axis.offset(m_local))
        new, used = _bank_core(state_l, block_l, **hypers)
        return new, axis.psum(used)

    mapped = axis.shard_map(
        body,
        in_specs=(axis.shard_leading(state, m), axis.rep_tree(block)),
        out_specs=(axis.shard_leading(state, m), axis.rep()))
    return mapped(state, block)


class EstimatorBank:
    """m per-server :class:`StreamingEstimator`\\ s updated by one program.

    The fleet-scale front half of the closed loop: ``AdaptiveEngine`` (and
    anything else holding one estimator per server) folds a trace block into
    every server's estimator with a single ``update_device`` call -- the
    batch streams through the fused program once, with per-server scatters,
    instead of once per server. The member estimators stay the source of
    truth for reads (``estimate_D`` etc.) and for the host ``update`` path;
    the bank stacks their device states before each fused run and hands the
    split states back after, so banked and member-wise updates interleave
    freely.

    All members must share hyperparameters (asserted) -- they are per-server
    *states*, not per-server policies.

    Between banked updates the stacked [m, ...] state is the live copy (the
    members are not re-split per call -- back-to-back banked updates touch
    only the stacked arrays); it flushes back into the members lazily, the
    first time any member's state is read or mutated outside the bank.
    """

    def __init__(self, estimators: "list[StreamingEstimator]"):
        if not estimators:
            raise ValueError("EstimatorBank needs at least one estimator")
        e0 = estimators[0]
        for e in estimators[1:]:
            same = (e.T == e0.T and e.lr == e0.lr and e.decay == e0.decay
                    and e.step_damp == e0.step_damp and e.solo_eps == e0.solo_eps
                    and e.max_lost_frac == e0.max_lost_frac)
            if not same:
                raise ValueError("banked estimators must share hyperparameters")
        self.estimators = list(estimators)
        self._stacked: DeviceEstimatorState | None = None
        self._dirty = False  # stacked state is ahead of the members
        # shared static update config (asserted equal above), resolved once
        self._hypers = dict(e0._hypers)
        for e in self.estimators:
            e._bank = self

    def _invalidate(self) -> None:
        """A member moved ahead of the stacked copy: restack on next update."""
        self._stacked = None

    def _flush(self) -> None:
        """Split the stacked state back into the members (lazy, idempotent)."""
        if self._dirty:
            self._dirty = False  # first: _absorb_device re-enters via _pull
            for s, est in enumerate(self.estimators):
                est._absorb_device(
                    DeviceEstimatorState(*(a[s] for a in self._stacked)))

    def stacked_state(self) -> DeviceEstimatorState:
        """The bank's live [m, ...] device state (stacking members on first
        use). Between banked updates this IS the newest state; readers that
        stay on device (the fleet detector's reference gather, posterior
        copies) consume it directly instead of forcing a member flush."""
        if self._stacked is None:
            self._stacked = DeviceEstimatorState(
                *(jnp.stack(parts)
                  for parts in zip(*(e.device_state() for e in self.estimators))))
        return self._stacked

    def copy_row(self, src: int, dst: int) -> None:
        """Seed bank row ``dst`` from row ``src``'s posterior, on device.

        The pool-split primitive: a server leaving a shared row takes the
        pool's full posterior (estimates + confidence) with it, so it starts
        exactly as warm as the pool it diverged from. Row ``dst``'s member
        estimator keeps its own prior and hyperparameters.
        """
        m = len(self.estimators)
        if not (0 <= src < m and 0 <= dst < m):
            raise IndexError(f"copy_row({src}, {dst}) outside bank of {m}")
        if src == dst:
            return
        st = self.stacked_state()
        self._stacked = DeviceEstimatorState(*(a.at[dst].set(a[src]) for a in st))
        self._dirty = True

    def update_device(self, block: RingBlock, sync: bool = True, *,
                      row_map=None):
        """One fused observe -> estimate step for every server's estimator.

        Rows update the estimator their ``server`` column names; rows with a
        server outside [0, m) (including voided rows) are dropped. With
        ``row_map`` (i32[n_servers], entries in [0, m) or -1), the server
        column is first rewritten through the map -- the pooling hook: the
        scatter indices inside the fused program then address *bank rows*
        (pool ids), not servers, and several servers may share one row
        (``fleet.pool.PooledEstimatorBank``). Returns the total rows
        consumed (host int when ``sync``, device scalar otherwise).
        """
        stacked = self.stacked_state()
        if row_map is not None:
            block = _remap_rows(block, jnp.asarray(row_map, jnp.int32))
        new, used = _update_bank(stacked, block, **self._hypers)
        self._stacked = new
        self._dirty = True
        return int(used) if sync else used
