"""Streaming recovery of the scheduler's empirical foundation from telemetry.

The paper fits its additive degradation model (Eqn 3) from a 52 900-pair
offline profiling pass; no production fleet has that, and interference
profiles drift with co-tenancy and hardware wear. This module recovers the
same quantities *online*, from the completion observations of
``telemetry.log``:

  per-type base throughput  b_t        (the solo curves of Fig 1-2)
  pairwise degradation      D[u, t]    (the profiled matrix of §IV.B)

Estimation happens in **log-slowdown space**, where the ground truth is
linear: pairwise slowdowns compose multiplicatively, so a type-t run whose
time-averaged co-resident counts were ``cbar`` satisfies (keep-cache regime)

  y  :=  log(rate)  =  log b_t  +  sum_u cbar_u * L[u, t],      L = log(1 - d)

-- a linear model in (log b_t, L[:, t]). Co-run observations determine only
the *sum* ``log b_t + cbar @ L[:, t]`` (base rate and pair effects trade off
along an unidentifiable direction), so updates are decoupled along
identifiability lines: solo runs -- the only unbiased base signal -- update
``log_b``; co-run residuals against the freshly updated base take one
damped, exposure-weighted least-squares step on ``L`` alone (a
batch-normalized LMS update whose step size is invariant to batch
composition). Per-pair confidence counts accumulate alongside; below a
confidence floor the estimate falls back to a prior (profiled, or a
uniform/optimistic constant), and an EWMA ``decay`` on the confidence lets
fresh evidence overturn stale estimates after a drift.

The batched pair-statistic scatter-accumulation -- the only O(B T) hot loop
-- is the shared contract implemented by the Pallas kernel
(``kernels.telemetry.pair_scatter``, MXU one-hot contraction), a jnp
fallback, and the float64 numpy reference (``kernels.ref.pair_scatter_ref``).

Known model limits (documented, by design -- the estimator's model and the
simulated world *can* disagree): observations that straddle the TDP mix the
keep/lost base rates, and time-varying co-residency makes log-of-mean differ
from mean-of-log. Both appear as residual noise; ``max_lost_frac`` filters
the worst of the former.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Literal

import numpy as np

from .log import ObservationLog

ScatterName = Literal["auto", "jnp", "pallas", "numpy"]

#: scatter contract: (types i32[B], cbar f[B, T], vals f[B]) ->
#: (pair [T, T], base [T]) with pair[u, t] = sum_b cbar[b, u] vals[b] 1{t_b = t}
Scatter = Callable[[np.ndarray, np.ndarray, np.ndarray], tuple[np.ndarray, np.ndarray]]


def make_scatter(backend: ScatterName = "auto") -> Scatter:
    """Resolve a pair-statistic scatter backend to the shared contract."""
    if backend == "auto":
        import jax

        backend = "pallas" if jax.default_backend() == "tpu" else "jnp"
    if backend == "numpy":
        from ..kernels.ref import pair_scatter_ref

        return pair_scatter_ref
    if backend == "jnp":
        import jax.numpy as jnp

        def scatter_jnp(types, cbar, vals):
            T = cbar.shape[1]
            onehot = (jnp.arange(T)[None, :] == jnp.asarray(types)[:, None])
            sel = onehot.astype(jnp.float32) * jnp.asarray(vals, jnp.float32)[:, None]
            pair = jnp.asarray(cbar, jnp.float32).T @ sel
            return np.asarray(pair, np.float64), np.asarray(sel.sum(0), np.float64)

        return scatter_jnp
    if backend == "pallas":
        import jax

        from ..kernels.telemetry import pair_scatter

        interpret = jax.default_backend() != "tpu"

        def scatter_pallas(types, cbar, vals):
            pair, base = pair_scatter(
                np.asarray(types, np.int32), np.asarray(cbar, np.float32),
                np.asarray(vals, np.float32), interpret=interpret)
            return np.asarray(pair, np.float64), np.asarray(base, np.float64)

        return scatter_pallas
    raise ValueError(f"unknown scatter backend {backend!r}")


@dataclasses.dataclass
class StreamingEstimator:
    """Online (base-rate, D-matrix) estimator for one server.

    Parameters
    ----------
    T : grid size (230 for the paper's 10 x 23 grid).
    prior_D : profiled D matrix [T, T], or a scalar uniform prior (0.0 = the
        optimistic "no interference" prior that makes the un-observed
        scheduler consolidate aggressively and *learn* the cost).
    prior_solo : per-type solo throughput prior [T] (bytes/s). Solo profiling
        is the cheap 230-run pass -- it is the 52 900-pair matrix that this
        estimator amortizes away -- but the base rate still adapts online
        (from solo runs), so a drifting/degraded server is tracked even with
        a stale prior. ``None`` starts from 1 byte/s and learns the base
        from solo observations alone.
    lr : damping of each batch's exposure-weighted least-squares step (0, 1].
    decay : EWMA forgetting applied to the confidence counts per update
        batch; < 1 lets the estimator re-converge after drift.
    confidence_floor : per-pair exposure below which ``estimate_D`` blends
        toward the prior (linearly in accumulated exposure).
    max_lost_frac : observations that spent more than this fraction of their
        run past the physical TDP are excluded (they mix base-rate regimes).
    scatter : pair-statistic backend ('auto' picks pallas on TPU, jnp else).
    """

    T: int
    prior_D: float | np.ndarray = 0.0
    prior_solo: np.ndarray | None = None
    lr: float = 0.5
    decay: float = 1.0
    confidence_floor: float = 4.0
    max_lost_frac: float = 0.5
    scatter: ScatterName = "auto"
    #: exposure added to the step denominator: damps updates from batches
    #: whose total exposure to a pair is far below one full co-run
    step_damp: float = 0.5
    #: co-resident exposure below which a run counts as a *solo* observation
    solo_eps: float = 0.05

    def __post_init__(self):
        prior = self.prior_D
        if np.isscalar(prior):
            prior = np.full((self.T, self.T), float(prior))
        prior = np.clip(np.asarray(prior, np.float64), 0.0, 1.0 - 1e-9)
        self._L_prior = np.log1p(-prior)  # log(1 - d) prior
        if self.prior_solo is None:
            self._logb_prior = np.zeros(self.T)
        else:
            self._logb_prior = np.log(np.asarray(self.prior_solo, np.float64))
        # state: current estimates + accumulated confidence
        self.L = self._L_prior.copy()
        self.log_b = self._logb_prior.copy()
        self.n_pair = np.zeros((self.T, self.T))
        self.n_base = np.zeros(self.T)
        self.n_obs = 0
        self._scatter = make_scatter(self.scatter)

    # -- updates ----------------------------------------------------------
    def update(self, obs: ObservationLog) -> int:
        """Consume one observation batch; returns how many records were used."""
        if len(obs) == 0:
            return 0
        keep = obs.lost_frac <= self.max_lost_frac
        obs = obs.select(keep)
        if len(obs) == 0:
            return 0
        t = np.asarray(obs.wtype, np.int32)
        cbar = np.asarray(obs.co_counts, np.float64)
        # geometric-mean rate: the log-linear model is exact in it per cache
        # regime, whereas log(bytes/duration) carries a Jensen gap whenever
        # co-residency changed mid-run
        y = np.log(np.asarray(obs.geo_rate, np.float64))

        if self.decay < 1.0:
            self.n_pair *= self.decay
            self.n_base *= self.decay

        # Co-run telemetry determines only the sum log_b_t + cbar @ L[:, t]:
        # base rate and pair effects trade off along an unidentifiable
        # direction, so letting co-runs touch the base bleeds any base-rate
        # drift (a degraded server) into every co-resident pair estimate.
        # The updates are therefore decoupled along identifiability lines:
        # *solo* runs -- the only unbiased base signal -- update log_b; co-run
        # residuals (against the freshly updated base) update only L. A fleet
        # whose types never run alone keeps its base prior, and the pair
        # estimates absorb the discrepancy -- the best any estimator could do.
        solo = cbar.sum(axis=1) <= self.solo_eps
        if solo.any():
            r0 = y[solo] - self.log_b[t[solo]]
            num0 = np.bincount(t[solo], weights=r0, minlength=self.T)
            cnt0 = np.bincount(t[solo], minlength=self.T).astype(np.float64)
            self.log_b += self.lr * num0 / (cnt0 + self.step_damp)
            self.n_base += cnt0

        co = ~solo
        if co.any():
            tc, cc, yc = t[co], cbar[co], y[co]
            pred = self.log_b[tc] + np.einsum("bu,ub->b", cc, self.L[:, tc])
            xnorm = np.maximum((cc**2).sum(axis=1), self.solo_eps)
            h = (yc - pred) / xnorm  # normalized residual (LMS direction)

            num_pair, _ = self._scatter(tc, cc, h)
            wgt_pair, _ = self._scatter(tc, cc, np.ones_like(h))
            # exposure-weighted average step: invariant to batch composition
            self.L += self.lr * num_pair / (wgt_pair + self.step_damp)
            self.n_pair += wgt_pair

        self.n_obs += len(obs)
        return len(obs)

    # -- estimates --------------------------------------------------------
    def pair_confidence(self) -> np.ndarray:
        """Accumulated (decayed) exposure per pair, in co-run units [T, T]."""
        return self.n_pair.copy()

    def observed_mask(self, floor: float | None = None) -> np.ndarray:
        """Pairs whose accumulated exposure reached the confidence floor."""
        return self.n_pair >= (self.confidence_floor if floor is None else floor)

    def estimate_D(self) -> np.ndarray:
        """Current D-matrix estimate, prior-blended below the confidence floor."""
        w = np.minimum(self.n_pair / self.confidence_floor, 1.0)
        L_eff = w * self.L + (1.0 - w) * self._L_prior
        return np.clip(-np.expm1(L_eff), 0.0, 0.999999)

    def estimate_solo(self) -> np.ndarray:
        """Current per-type base-throughput estimate (bytes/s) [T]."""
        w = np.minimum(self.n_base / self.confidence_floor, 1.0)
        return np.exp(w * self.log_b + (1.0 - w) * self._logb_prior)
