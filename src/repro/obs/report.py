"""Run reports over the metrics plane: tables, timelines, BENCH records.

Renders an ``EngineResult`` / ``AdaptiveResult`` (run with ``metrics=True``)
into the fixed-width text report ``python -m repro.obs`` prints: counter and
gauge tables, p50/p95/p99 percentile tables for every histogram, per-server
placement/finish/floor-violation columns, and -- for adaptive runs with a
fleet controller -- the health-event timeline from ``result.health``.
:func:`snapshot_records` flattens a frame into the ``(name, value, unit)``
rows the benchmark harness stamps into ``BENCH_*.json``.
"""
from __future__ import annotations

import numpy as np

from . import metrics as M


def _fmt(v: float) -> str:
    if np.isnan(v):
        return "nan"
    if np.isinf(v):
        return "inf" if v > 0 else "-inf"
    if v == 0:
        return "0"
    if abs(v) >= 1e5 or abs(v) < 1e-3:
        return f"{v:.3g}"
    return f"{v:.4g}"


def counter_table(frame: M.MetricFrame) -> str:
    rows = [(n, M.counter_value(frame, n)) for n in M.COUNTERS]
    width = max(len(n) for n, _ in rows)
    return "\n".join(f"  {n:<{width}}  {v:>10d}" for n, v in rows)


def gauge_table(frame: M.MetricFrame) -> str:
    rows = [(n, M.gauge_value(frame, n)) for n in M.GAUGES]
    width = max(len(n) for n, _ in rows)
    return "\n".join(f"  {n:<{width}}  {_fmt(v):>10}" for n, v in rows)


def percentile_table(frame: M.MetricFrame,
                     names: "tuple[str, ...] | None" = None) -> str:
    """count / p50 / p95 / p99 per histogram (all of them by default)."""
    names = tuple(names) if names is not None else tuple(
        s.name for s in M.HISTOGRAMS)
    width = max(len(n) for n in names)
    lines = [f"  {'':<{width}}  {'count':>9} {'p50':>10} {'p95':>10} {'p99':>10}"]
    for n in names:
        total = float(M.hist_counts(frame, n).sum())
        p50, p95, p99 = M.percentiles(frame, n)
        lines.append(
            f"  {n:<{width}}  {total:>9.0f} {_fmt(p50):>10} {_fmt(p95):>10} "
            f"{_fmt(p99):>10}")
    return "\n".join(lines)


#: fleets up to this size render one row per server; past it the table
#: switches to pod rollups + the top-k busiest rows (a 10k-server fleet
#: would otherwise print 10k lines nobody reads)
FULL_TABLE_MAX = 64


def _server_rows(cols, servers) -> list:
    lines = []
    for s in servers:
        flag = "!" if cols["floor_violations"][s] > 0 else " "
        lines.append(f"  {s:>5}{flag}  " + " ".join(
            f"{cols[n][s]:>16.0f}" for n in M.PER_SERVER))
    return lines


def per_server_table(frame: M.MetricFrame, top_k: int = 16,
                     pods: "int | None" = None) -> str:
    """Per-server placement/finish/violation columns; '!' flags servers that
    violated the paper's utilization floor.

    Fleets up to ``FULL_TABLE_MAX`` servers get the classic one-row-per-
    server table. Larger fleets get pod rollups (sum per contiguous pod,
    with the pod count taken from ``pods`` or defaulted to ~32 servers per
    pod) followed by the ``top_k`` busiest servers by placements -- the rows
    an operator actually scans for hot spots.
    """
    cols = {n: M.server_values(frame, n) for n in M.PER_SERVER}
    header = ["  server  " + " ".join(f"{n:>16}" for n in M.PER_SERVER)]
    m = frame.m
    if m <= FULL_TABLE_MAX:
        return "\n".join(header + _server_rows(cols, range(m)))

    if pods is None or pods <= 1 or m % pods:
        pods = max(1, m // 32)
        while m % pods:
            pods -= 1
    S = m // pods
    lines = [f"  pod rollups ({pods} pods x {S} servers):"]
    lines += ["  pod     " + " ".join(f"{n:>16}" for n in M.PER_SERVER)]
    for p in range(pods):
        sums = {n: float(cols[n][p * S:(p + 1) * S].sum())
                for n in M.PER_SERVER}
        flag = "!" if sums["floor_violations"] > 0 else " "
        lines.append(f"  {p:>5}{flag}  " + " ".join(
            f"{sums[n]:>16.0f}" for n in M.PER_SERVER))
    busy = np.argsort(-np.asarray(cols["placements"]),
                      kind="stable")[:min(top_k, m)]
    lines += ["", f"  top {len(busy)} busiest servers (by placements):"]
    lines += header
    lines += _server_rows(cols, (int(s) for s in busy))
    return "\n".join(lines)


def health_timeline(health) -> str:
    """Flatten AdaptiveResult.health into one line per fired event."""
    lines = []
    for k, events in enumerate(health):
        for ev in events:
            lines.append(
                f"  segment {k:>3}  {ev.kind:<6} server {ev.server:>4}  "
                f"stat {_fmt(float(ev.stat)):>8}  {ev.detail}")
    return "\n".join(lines) if lines else "  (no health events)"


def phase_tree(log) -> str:
    """Render a ``trace.SpanLog`` as an indented host-phase tree: children
    nest under the span that was open when they started, in open order."""
    spans = sorted(log.spans, key=lambda s: s.id)
    if not spans:
        return "  (no spans)"
    by_id = {s.id: s for s in spans}
    children: dict = {}
    roots = []
    for s in spans:
        if s.parent is None or s.parent not in by_id:
            roots.append(s)
        else:
            children.setdefault(s.parent, []).append(s)
    width = max(2 * s.depth + len(s.name) for s in spans)
    lines = []

    def walk(s, indent):
        label = "  " * indent + s.name
        attrs = " ".join(f"{k}={v}" for k, v in s.attrs.items())
        lines.append(f"  {label:<{width}}  {s.duration_s * 1e3:>10.3f} ms"
                     + (f"  {attrs}" if attrs else ""))
        for c in children.get(s.id, ()):
            walk(c, indent + 1)

    for r in roots:
        walk(r, 0)
    return "\n".join(lines)


def worst_decisions_table(attributions, k: int = 10) -> str:
    """The k costliest recorded decisions by attributed makespan delta
    (``obs.explain`` output): the rows an operator triages first."""
    decs = sorted((d for att in attributions for d in att.decisions),
                  key=lambda d: -d.delta)[:k]
    if not decs:
        return "  (no recorded decisions)"
    lines = ["  seg  arr  kind   srv  shadow   delta(s)  bucket     "
             "    margin   headroom    cusum"]
    kind_name = {0: "place", 1: "drain", 2: "queue"}
    for d in decs:
        shadow = "-" if d.shadow_server is None else str(d.shadow_server)
        lines.append(
            f"  {d.segment:>3} {d.arrival:>4}  {kind_name.get(d.kind, '?'):<5}"
            f" {d.server:>4}  {shadow:>6} {d.delta:>10.4g}  {d.bucket:<10}"
            f" {_fmt(d.margin):>9} {_fmt(d.headroom):>10} {_fmt(d.cusum):>8}")
    return "\n".join(lines)


def render_report(result=None, frame: "M.MetricFrame | None" = None,
                  title: str = "run report", attribution=None,
                  spans=None) -> str:
    """The full text report. ``result`` may be an ``EngineResult`` or an
    ``AdaptiveResult`` (its ``metrics`` supplies the frame unless ``frame``
    is given explicitly); a bare frame renders without the run header.
    ``attribution`` (a list of ``obs.explain.SegmentAttribution``) appends
    the worst-decisions section; ``spans`` (a ``trace.SpanLog``, defaulting
    to the active one when tracing is enabled) appends the host-phase
    tree."""
    if frame is None:
        frame = getattr(result, "metrics", None)
    if frame is None:
        raise ValueError(
            "no MetricFrame to report: run the engine with metrics=True")
    lines = [f"== {title} ==", ""]
    if result is not None and hasattr(result, "segments"):  # AdaptiveResult
        durs = result.durations
        lines += [
            f"segments: {len(result.segments)}   "
            f"observations: {result.total_obs}   "
            f"total segment time: {_fmt(float(np.sum(durs)))} s", ""]
    elif result is not None and hasattr(result, "makespan"):  # EngineResult
        lines += [
            f"arrivals: {len(result.placements)}   backend: {result.backend}  "
            f" makespan: {_fmt(result.makespan)} s   max degradation: "
            f"{_fmt(result.max_observed_degradation)}", ""]
    lines += ["counters:", counter_table(frame), ""]
    lines += ["gauges (high-water):", gauge_table(frame), ""]
    lines += ["percentiles:", percentile_table(frame), ""]
    lines += ["per-server:", per_server_table(frame)]
    health = getattr(result, "health", None)
    if health:
        lines += ["", "health-event timeline:", health_timeline(health)]
    if attribution is not None:
        lines += ["", "worst 10 decisions (by attributed regret):",
                  worst_decisions_table(attribution)]
    if spans is None:
        from . import trace
        spans = trace.active_log()
    if spans is not None and spans.spans:
        lines += ["", "host phases:", phase_tree(spans)]
    return "\n".join(lines)


def snapshot_records(frame: M.MetricFrame, prefix: str = "obs"):
    """Flatten a frame into (name, value, unit) rows for BENCH_*.json.

    Counters all land; histograms contribute count/p50/p99 when non-empty.
    Every gauge lands with an explicit ``_set`` companion (1 = recorded at
    least once): a peak of 0 is a legitimate reading (requeue_peak on a run
    with no evictions), so presence in the record set must not encode
    set-ness -- ``--compare`` needs the set stable across runs.
    """
    records = []
    for n in M.COUNTERS:
        records.append((f"{prefix}/counter_{n}", float(M.counter_value(frame, n)),
                        "count"))
    for n in M.GAUGES:
        records.append((f"{prefix}/gauge_{n}", float(M.gauge_value(frame, n)),
                        "peak"))
        records.append((f"{prefix}/gauge_{n}_set",
                        1.0 if M.gauge_set(frame, n) else 0.0, "bool"))
    for spec in M.HISTOGRAMS:
        total = float(M.hist_counts(frame, spec.name).sum())
        if total <= 0:
            continue
        p50, _, p99 = M.percentiles(frame, spec.name)
        records.append((f"{prefix}/{spec.name}_count", total, "count"))
        records.append((f"{prefix}/{spec.name}_p50", float(p50), spec.desc or "value"))
        records.append((f"{prefix}/{spec.name}_p99", float(p99), spec.desc or "value"))
    return records
