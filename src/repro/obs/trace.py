"""Host-side structured spans around the device-resident programs.

The hot path itself is one XLA program -- there is nothing host-visible to
time inside it, by design (DESIGN.md §12). What *is* host-visible, and what
dominates interactive latency, are the phases around it: packing segment
buffers, the blocking dispatch (compile on a cold cache, execute on a warm
one), and the epilogue that adopts device outcomes back into host
bookkeeping. :func:`span` wraps those phases with

  * ``jax.profiler.TraceAnnotation`` -- so ``--profile`` traces from the
    benchmark harness are navigable by phase name, and
  * an optional JSONL log (:class:`SpanLog`) of ``{"kind": "span", ...}``
    rows stamped with wall-clock times and the git commit, plus
    ``{"kind": "snapshot", ...}`` rows for MetricFrame snapshots.

Tracing is off by default; :func:`span` then degrades to a bare profiler
annotation (nanoseconds when no profiler is attached). Enable with
``enable_tracing(path)``; rows append eagerly so a crashed run keeps its
prefix.
"""
from __future__ import annotations

import contextlib
import dataclasses
import json
import pathlib
import subprocess
import time

import jax


def _git_commit() -> str:
    try:
        root = pathlib.Path(__file__).resolve().parents[3]
        out = subprocess.run(
            ["git", "rev-parse", "--short", "HEAD"], cwd=root,
            capture_output=True, text=True, timeout=5)
        if out.returncode == 0:
            return out.stdout.strip()
    except (OSError, subprocess.SubprocessError):
        pass
    return "unknown"


_COMMIT: "str | None" = None


def commit_stamp() -> str:
    global _COMMIT
    if _COMMIT is None:
        _COMMIT = _git_commit()
    return _COMMIT


@dataclasses.dataclass
class Span:
    name: str
    t_start: float
    duration_s: float
    attrs: dict
    #: stable per-log id, assigned at span *open* so parents number before
    #: their children even though children close (and append) first
    id: int = 0
    #: id of the enclosing open span, None for top-level phases
    parent: "int | None" = None
    #: nesting depth (0 = top level); redundant with the parent chain but
    #: kept on the row so JSONL consumers can indent without a join
    depth: int = 0


class SpanLog:
    """Collects spans and metric snapshots; optionally appends JSONL rows.

    Nested :meth:`span` calls are linked: each span records the ``id`` of
    the span that was open when it started (``parent``) and its nesting
    ``depth``, so a dispatch phase that packs, compiles, and adopts inside
    an outer segment span renders as a tree rather than a flat list
    (:func:`repro.obs.report.phase_tree`).
    """

    def __init__(self, path: "str | pathlib.Path | None" = None):
        self.path = pathlib.Path(path) if path is not None else None
        self.spans: "list[Span]" = []
        self._t0 = time.time()
        self._next_id = 0
        self._open: "list[int]" = []  # ids of currently open spans

    def _write(self, row: dict) -> None:
        if self.path is None:
            return
        row = dict(row, commit=commit_stamp())
        with self.path.open("a") as fh:
            fh.write(json.dumps(row) + "\n")

    @contextlib.contextmanager
    def span(self, name: str, **attrs):
        sid = self._next_id
        self._next_id += 1
        parent = self._open[-1] if self._open else None
        depth = len(self._open)
        self._open.append(sid)
        t0 = time.time()
        p0 = time.perf_counter()
        try:
            with jax.profiler.TraceAnnotation(name):
                yield
        finally:
            self._open.pop()
        dt = time.perf_counter() - p0
        self.spans.append(Span(name, t0, dt, attrs, id=sid, parent=parent,
                               depth=depth))
        self._write({"kind": "span", "name": name, "t_start": t0,
                     "duration_s": dt, "attrs": attrs, "id": sid,
                     "parent": parent, "depth": depth})

    def snapshot(self, name: str, payload: dict) -> None:
        """Record a point-in-time payload (e.g. ``metrics.snapshot(frame)``)."""
        self._write({"kind": "snapshot", "name": name, "t": time.time(),
                     "payload": payload})

    def durations(self) -> "dict[str, float]":
        """Total seconds per span name."""
        out: dict[str, float] = {}
        for s in self.spans:
            out[s.name] = out.get(s.name, 0.0) + s.duration_s
        return out


_ACTIVE: "SpanLog | None" = None


def enable_tracing(path: "str | pathlib.Path | None" = None) -> SpanLog:
    """Install a process-wide SpanLog (optionally JSONL-backed)."""
    global _ACTIVE
    _ACTIVE = SpanLog(path)
    return _ACTIVE


def disable_tracing() -> None:
    global _ACTIVE
    _ACTIVE = None


def active_log() -> "SpanLog | None":
    """The installed SpanLog, if tracing is enabled."""
    return _ACTIVE


@contextlib.contextmanager
def span(name: str, **attrs):
    """Annotate a host-side phase; logs to the active SpanLog if any."""
    if _ACTIVE is not None:
        with _ACTIVE.span(name, **attrs):
            yield
    else:
        with jax.profiler.TraceAnnotation(name):
            yield
