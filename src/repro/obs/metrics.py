"""Device-resident metrics plane: counters, gauges, histograms in the carry.

The paper's consolidation criterion is an observability claim -- per-server
throughput "never falls below a predefined utilization level" -- but the
device-resident engine keeps the host out of the hot path, so nothing
host-side can watch the loop run. The resolution: a :class:`MetricFrame` is
a small fixed-shape pytree of metric state (integer counters, high-water
gauges, log-spaced histograms, and a per-server block) threaded *through*
the jitted programs -- inside ``EngineState`` and ``LoopCarry`` behind a
static ``metrics=`` flag -- and read out exactly once, at the end of a run.

Slots are named at trace time and indexed at run time: the registry tuples
below map metric names to static array indices, so every record op is a
fixed-index add/max/scatter -- no strings, no data-dependent shapes, no
host anywhere near the loop. Adding a metric means appending a name (or a
:class:`HistSpec`) to its registry tuple: the frame shapes change once, at
import, every jitted consumer recompiles exactly once on its next call, and
nothing keys on metric names per call -- a warm loop never re-traces
because of the plane (``analysis/retrace.py`` pins this).

Histograms are fixed-bin and log-spaced (``HIST_BINS`` bins between a
spec's ``lo`` and ``hi``): streaming percentile state whose merge is plain
addition. :func:`percentiles` extracts p50/p95/p99 deterministically by
geometric interpolation inside the covering bin -- within one bin ratio
``(hi/lo)**(1/HIST_BINS)`` of ``numpy.percentile`` on the raw samples for
in-range data (tests/test_obs.py and ``python -m repro.obs --selfcheck``
verify this). Values at or below ``lo`` clamp into bin 0 (underflow);
values at or above ``hi`` clamp into the last bin (overflow).

Merge semantics make frames **chunk-invariant**: counters, histograms, and
the per-server block add; gauges are high-water marks and take the
elementwise max. All weights the engines record are integer-valued and far
below 2**24, so f32 accumulation is associative and bit-exact -- splitting
a run into segments and merging the per-segment frames reproduces the
single-run frame bitwise, the property the closed loop's scan relies on.
"""
from __future__ import annotations

import dataclasses
import math
from typing import NamedTuple

import jax.numpy as jnp
import numpy as np

# Bins per histogram. Shared so the hist block is one dense [H, B] array.
HIST_BINS = 64


@dataclasses.dataclass(frozen=True)
class HistSpec:
    """A named log-spaced histogram: HIST_BINS bins covering [lo, hi)."""

    name: str
    lo: float
    hi: float
    desc: str = ""

    def edges(self) -> np.ndarray:
        """Bin edges, f64[HIST_BINS + 1], geometric."""
        return np.geomspace(self.lo, self.hi, HIST_BINS + 1)

    def bin_ratio(self) -> float:
        """Multiplicative width of one bin = the percentile resolution."""
        return (self.hi / self.lo) ** (1.0 / HIST_BINS)


# ---------------------------------------------------------------------------
# Slot registries. Order is the array index; append to add a metric.
# ---------------------------------------------------------------------------

COUNTERS: "tuple[str, ...]" = (
    "events",            # engine micro-events (one per while_loop iteration)
    "arrivals",          # arrival events consumed
    "placements",        # committed placements (arrival-time + drain)
    "queued",            # arrivals sent to the §V wait queue
    "drain_steps",       # drain events scored
    "drain_placements",  # placements committed from the drain window
    "drain_full_scans",  # drains that fell past the W-candidate window
    "finishes",          # workload completions
    "deadlocks",         # deadlock-flag transitions (0 -> 1)
    "segments",          # closed-loop segments observed
    "splits",            # fleet pool splits fired
    "evictions",         # fleet evictions fired
    "requeues",          # in-flight arrivals requeued after evictions
    "ring_rows",         # telemetry rows pushed into the observation ring
    "d_cols_refreshed",  # D-matrix type-columns re-blended incrementally
)

# High-water marks; merge takes the elementwise max.
GAUGES: "tuple[str, ...]" = (
    "queue_peak",           # max §V queue depth over all events
    "ring_occupancy_peak",  # max rows resident in the observation ring
    "evicted_peak",         # max servers simultaneously marked dead
    "requeue_peak",         # max arrivals requeued out of one segment
)

HISTOGRAMS: "tuple[HistSpec, ...]" = (
    HistSpec("waiting_time", 1e-4, 1e4, "arrival -> placement wall time (s)"),
    HistSpec("slowdown", 1.0, 64.0, "observed duration / solo duration"),
    HistSpec("queue_depth", 0.5, 2048.0, "queued arrivals, sampled per event"),
    HistSpec("headroom", 1e-4, 1.0, "Eqn-4 margin at commit (limit - max deg)"),
    HistSpec("cusum_level", 1e-3, 64.0, "per-server CUSUM stat per segment"),
)

PER_SERVER: "tuple[str, ...]" = (
    "placements",        # commits routed to this server
    "finishes",          # completions on this server
    "floor_violations",  # events where a slot's degradation exceeded the limit
    "busy_events",       # events with at least one active slot
)

_C_IDX = {name: i for i, name in enumerate(COUNTERS)}
_G_IDX = {name: i for i, name in enumerate(GAUGES)}
_H_IDX = {spec.name: i for i, spec in enumerate(HISTOGRAMS)}
_S_IDX = {name: i for i, name in enumerate(PER_SERVER)}


class MetricFrame(NamedTuple):
    """Fixed-shape metric state; a pytree of four dense arrays.

    counters    i32[len(COUNTERS)]              merge: add (exact)
    gauges      f32[len(GAUGES)]                merge: elementwise max
    hist        f32[len(HISTOGRAMS), HIST_BINS] merge: add (bit-exact for
                                                integer weights < 2**24)
    per_server  f32[m, len(PER_SERVER)]         merge: add
    """

    counters: jnp.ndarray
    gauges: jnp.ndarray
    hist: jnp.ndarray
    per_server: jnp.ndarray

    @property
    def m(self) -> int:
        return int(self.per_server.shape[0])


def zeros(m: int) -> MetricFrame:
    """An empty frame for an m-server fleet.

    Gauges start at ``-inf``, not 0: a high-water mark of 0 is a legitimate
    reading (e.g. requeue_peak on a run with no evictions), and the
    sentinel keeps "never set" distinguishable from "peak was zero"
    (``gauge_set``). ``-inf`` is the identity of max, so ``gauge_max`` and
    ``merge`` need no special cases.
    """
    return MetricFrame(
        counters=jnp.zeros((len(COUNTERS),), jnp.int32),
        gauges=jnp.full((len(GAUGES),), -jnp.inf, jnp.float32),
        hist=jnp.zeros((len(HISTOGRAMS), HIST_BINS), jnp.float32),
        per_server=jnp.zeros((m, len(PER_SERVER)), jnp.float32),
    )


def frame_specs(axis) -> MetricFrame:
    """PartitionSpec pytree for a frame on a ServerAxis: the per-server
    columns shard with their servers, everything else replicates (each
    shard records fleet-global counters/histograms identically -- commit
    decisions are broadcast, so the scalar streams match bitwise)."""
    return MetricFrame(
        counters=axis.rep(), gauges=axis.rep(), hist=axis.rep(),
        per_server=axis.spec())


# ---------------------------------------------------------------------------
# Pure record ops -- safe inside jit / while_loop / scan bodies.
# ---------------------------------------------------------------------------

def count(frame: MetricFrame, name: str, inc=1) -> MetricFrame:
    """counters[name] += inc (scalar int or traced i32)."""
    return frame._replace(
        counters=frame.counters.at[_C_IDX[name]].add(
            jnp.asarray(inc, jnp.int32)))


def gauge_max(frame: MetricFrame, name: str, value) -> MetricFrame:
    """gauges[name] = max(gauges[name], value) -- a high-water mark."""
    return frame._replace(
        gauges=frame.gauges.at[_G_IDX[name]].max(
            jnp.asarray(value, jnp.float32)))


def _bin_of(spec: HistSpec, v: jnp.ndarray) -> jnp.ndarray:
    """Log-spaced bin index of each value; clamps under/overflow."""
    log_lo = math.log(spec.lo)
    scale = HIST_BINS / (math.log(spec.hi) - log_lo)
    x = (jnp.log(jnp.maximum(v, jnp.float32(1e-37))) - jnp.float32(log_lo))
    x = jnp.clip(x * jnp.float32(scale), 0.0, HIST_BINS - 1)
    return jnp.floor(x).astype(jnp.int32)


def observe(frame: MetricFrame, name: str, values, weight=1.0) -> MetricFrame:
    """Scatter ``weight`` into hist[name] at each value's bin.

    ``weight`` broadcasts against ``values``; a weight of 0 masks a row out
    exactly (the scatter adds 0). Integer-valued weights keep accumulation
    order-independent, hence chunk-invariant.
    """
    h = _H_IDX[name]
    v = jnp.atleast_1d(jnp.asarray(values, jnp.float32))
    w = jnp.broadcast_to(jnp.asarray(weight, jnp.float32), v.shape)
    return frame._replace(
        hist=frame.hist.at[h, _bin_of(HISTOGRAMS[h], v)].add(w))


def add_server(frame: MetricFrame, name: str, values) -> MetricFrame:
    """per_server[:, name] += values (f32[m])."""
    return frame._replace(
        per_server=frame.per_server.at[:, _S_IDX[name]].add(
            jnp.asarray(values, jnp.float32)))


def merge(a: MetricFrame, b: MetricFrame) -> MetricFrame:
    """Combine two frames; associative and commutative."""
    return MetricFrame(
        counters=a.counters + b.counters,
        gauges=jnp.maximum(a.gauges, b.gauges),
        hist=a.hist + b.hist,
        per_server=a.per_server + b.per_server,
    )


# ---------------------------------------------------------------------------
# Host-side readout.
# ---------------------------------------------------------------------------

def counter_value(frame: MetricFrame, name: str) -> int:
    return int(np.asarray(frame.counters)[_C_IDX[name]])


def gauge_value(frame: MetricFrame, name: str) -> float:
    """The gauge's peak; 0.0 when it was never set (see ``gauge_set``)."""
    v = float(np.asarray(frame.gauges)[_G_IDX[name]])
    return v if np.isfinite(v) else 0.0


def gauge_set(frame: MetricFrame, name: str) -> bool:
    """Whether the gauge recorded at least one value (its ``-inf``
    never-set sentinel has been displaced)."""
    return bool(np.isfinite(np.asarray(frame.gauges)[_G_IDX[name]]))


def hist_counts(frame: MetricFrame, name: str) -> np.ndarray:
    """Raw bin weights, f64[HIST_BINS]."""
    return np.asarray(frame.hist, dtype=np.float64)[_H_IDX[name]]


def server_values(frame: MetricFrame, name: str) -> np.ndarray:
    """Per-server column, f64[m]."""
    return np.asarray(frame.per_server, dtype=np.float64)[:, _S_IDX[name]]


def percentiles(frame: MetricFrame, name: str,
                qs=(50.0, 95.0, 99.0)) -> np.ndarray:
    """Percentile estimates from the binned weights.

    Walks the bin CDF to the covering bin, then interpolates geometrically
    inside it -- deterministic, and within one bin ratio of the true sample
    percentile for in-range data. NaN where the histogram is empty.
    """
    spec = HISTOGRAMS[_H_IDX[name]]
    h = hist_counts(frame, name)
    total = h.sum()
    if total <= 0:
        return np.full(len(qs), np.nan)
    edges = spec.edges()
    cdf = np.cumsum(h)
    out = np.empty(len(qs))
    for k, q in enumerate(qs):
        target = (q / 100.0) * total
        b = min(int(np.searchsorted(cdf, target, side="left")), HIST_BINS - 1)
        inbin = h[b]
        below = cdf[b] - inbin
        frac = (target - below) / inbin if inbin > 0 else 0.0
        frac = min(max(frac, 0.0), 1.0)
        out[k] = edges[b] * (edges[b + 1] / edges[b]) ** frac
    return out


def snapshot(frame: MetricFrame) -> dict:
    """Flatten a frame into a JSON-serializable dict (for BENCH records,
    span logs, and the report CLI)."""
    counters = np.asarray(frame.counters)
    gauges = np.asarray(frame.gauges)
    hists = {}
    for spec in HISTOGRAMS:
        h = hist_counts(frame, spec.name)
        total = float(h.sum())
        entry = {"count": total}
        if total > 0:
            p50, p95, p99 = percentiles(frame, spec.name)
            entry.update(p50=float(p50), p95=float(p95), p99=float(p99))
        hists[spec.name] = entry
    return {
        "counters": {n: int(counters[i]) for i, n in enumerate(COUNTERS)},
        "gauges": {n: (float(gauges[i]) if np.isfinite(gauges[i]) else 0.0)
                   for i, n in enumerate(GAUGES)},
        "gauges_set": {n: bool(np.isfinite(gauges[i]))
                       for i, n in enumerate(GAUGES)},
        "histograms": hists,
        "per_server": {
            n: [float(x) for x in server_values(frame, n)]
            for n in PER_SERVER},
    }
