"""Regret attribution over a recorded run: replay every decision against
the true dynamics and say what each one cost.

The decision ring (``obs.recorder``) says *what* the scheduler did -- which
server, at what score margin, under what headroom and estimator confidence.
This module says *what it cost*: for each recorded decision, the makespan
delta attributable to taking it instead of what the true-D oracle would
have done, decomposed into the three ways the closed loop loses time:

``estimation``  the scheduler's D-hat ranked a worse server above the true
                best (model error at commit);
``queueing``    the same server was (or would have been) chosen, but the
                commit happened at a different time -- work waited in the
                section-V queue that the oracle would have started, or vice
                versa;
``detection``   the divergent choice involved a server whose CUSUM level
                was already elevated at commit -- the detector had evidence
                of drift the scheduler had not yet acted on.

Method: *telescoping forced replay*. For a segment with p recorded
decisions, run p + 1 float64 reference replays (the trusted
``core.scheduler.OnlineScheduler`` event loop over the true profiled D).
Replay ``R_j`` forces the first j recorded decisions -- workload-j's server
at arrival, or its queue-then-commit at the recorded commit time -- and
lets the true-D greedy finish the rest. ``R_0`` is the oracle, ``R_p`` the
recorded run re-enacted. Each decision's cost is the adjacent difference

    delta_j = duration(R_j) - duration(R_{j-1})

so the per-decision costs sum to ``duration(R_p) - duration(R_0)`` --
the segment's regret -- *exactly* (it telescopes; the acceptance gate's
1e-5 is pure float-summation slack). The counterfactual for bucketing
decision j is workload j's fate in ``R_{j-1}``, where it is the first
unforced decision.

The replays are host-side and O(p) per decision -- this is a post-mortem
tool, not a hot path. It needs the per-segment arrival chunks and true
specs alongside the ring; ``python -m repro.obs --explain`` wires a canned
stationary adaptive run end to end.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Sequence

import numpy as np

from ..core.binpack import ClusterState, greedy_place
from ..core.scheduler import OnlineScheduler
from .recorder import KIND_ARRIVE, KIND_DRAIN, KIND_QUEUED, DecisionRing

#: recorded CUSUM level at or above which a divergent decision is blamed on
#: detection lag rather than estimation error (half the default split
#: threshold ``cusum_h=2.0`` -- evidence was accumulating, action had not
#: fired yet)
CUSUM_GATE = 1.0

#: relative slack when matching a forced drain commit to a replay finish
#: event (the ring stores f32 chunk-relative times; the replay runs f64)
TIME_RTOL = 1e-4
TIME_ATOL = 1e-6


@dataclasses.dataclass(frozen=True)
class DecisionAttribution:
    """One recorded decision, costed against its oracle counterfactual."""

    row: int  # ring row (oldest-first decode order)
    segment: int
    arrival: int  # trace-local arrival id
    kind: int  # recorder KIND_*
    server: int  # recorded committed server (-1 on queue rows)
    shadow_server: "int | None"  # true-D greedy's choice in R_{j-1}
    delta: float  # duration(R_j) - duration(R_{j-1}), seconds
    bucket: str  # 'estimation' | 'queueing' | 'detection' | 'aligned'
    time: float  # recorded commit time (chunk-relative)
    margin: float  # recorded argmin tie margin
    headroom: float  # recorded Eqn-4 headroom at commit
    cusum: float  # recorded CUSUM level of the committed server
    n_pair_min: float  # recorded min pair-confidence exposure


@dataclasses.dataclass(frozen=True)
class SegmentAttribution:
    """A segment's full decomposition: oracle -> recorded, one delta per
    decision, summing exactly to the regret."""

    segment: int
    duration_oracle: float  # R_0: free true-D greedy replay
    duration_forced: float  # R_p: every recorded decision forced
    regret: float  # duration_forced - duration_oracle == sum of deltas
    decisions: tuple[DecisionAttribution, ...]
    #: recorded run duration minus duration_forced: how faithfully the f64
    #: replay re-enacts the f32 engine (diagnostic; ~0 on healthy runs)
    replay_gap: "float | None" = None

    @property
    def by_bucket(self) -> dict:
        out: dict[str, float] = {}
        for d in self.decisions:
            out[d.bucket] = out.get(d.bucket, 0.0) + d.delta
        return out


@dataclasses.dataclass
class _Forced:
    """How a forced workload behaves in a replay."""

    server: "int | None" = None  # arrival-time server (kind 0)
    queued: bool = False  # kind-2 row in the prefix
    commit_server: "int | None" = None  # kind-1 row in the prefix
    commit_time: float = 0.0


def _replay(
    chunk: Sequence[tuple[float, object]],
    servers,
    D,
    alpha,
    objective: str,
    forced: "dict[int, _Forced]",
):
    """One reference replay with a forced prefix; returns the
    ``ScheduleResult`` (placements keyed by chunk position)."""
    copies = [(t, dataclasses.replace(w)) for t, w in chunk]
    wid = {id(w): i for i, (_, w) in enumerate(copies)}
    state = ClusterState.empty(list(servers), [np.array(d) for d in D], alpha)
    calls: dict[int, int] = {}
    sched_box: list[OnlineScheduler] = []

    def place(st: ClusterState, w) -> "int | None":
        idx = wid[id(w)]
        calls[idx] = calls.get(idx, 0) + 1
        f = forced.get(idx)
        if f is None:
            return greedy_place(st, w, objective=objective)
        if f.server is not None:  # forced arrival-time placement
            st.assignments[f.server].append(w)
            return f.server
        # forced queue-at-arrival
        if calls[idx] == 1:
            return None
        if f.commit_server is None:
            # the commit row is past the forced prefix: free greedy retries
            return greedy_place(st, w, objective=objective)
        events = sched_box[0].events
        now = events[-1].time if events else 0.0
        if now + TIME_ATOL + TIME_RTOL * abs(f.commit_time) >= f.commit_time:
            st.assignments[f.commit_server].append(w)
            return f.commit_server
        return None  # the recorded commit is still in the future

    sched = OnlineScheduler(state, place=place)
    sched_box.append(sched)
    return sched.run(copies)


def attribute_segment(
    segment: int,
    rows: dict,
    chunk: Sequence[tuple[float, object]],
    servers,
    true_D,
    *,
    alpha=1.3,
    objective: str = "sum_avg",
    recorded_duration: "float | None" = None,
    cusum_gate: float = CUSUM_GATE,
) -> SegmentAttribution:
    """Attribute one segment's recorded decisions (``rows``: the ring's
    decoded columns already filtered to this segment, in ring order).

    ``chunk`` must be the segment's arrivals in *trace order* (time-sorted,
    requeued work first -- the order recorded ``arrival`` ids index) on the
    chunk-relative clock, and ``true_D`` the true profiled D per server.
    """
    t0 = chunk[0][0] if len(chunk) else 0.0
    chunk = [(t - t0, w) for t, w in chunk]
    p = len(rows["arrival"])

    # build the forced-decision table for each prefix length incrementally
    prefixes: list[dict[int, _Forced]] = [dict()]
    acc: dict[int, _Forced] = {}
    for j in range(p):
        a = int(rows["arrival"][j])
        kind = int(rows["kind"][j])
        f = dataclasses.replace(acc.get(a, _Forced()))
        if kind == KIND_ARRIVE:
            f.server = int(rows["server"][j])
        elif kind == KIND_QUEUED:
            f.queued = True
        else:  # KIND_DRAIN
            f.commit_server = int(rows["server"][j])
            f.commit_time = float(rows["time"][j])
        acc = dict(acc)
        acc[a] = f
        prefixes.append(acc)

    durations: list[float] = []
    results = []
    for forced in prefixes:
        res = _replay(chunk, servers, true_D, alpha, objective, forced)
        results.append(res)
        durations.append(float(res.makespan))

    decisions = []
    for j in range(p):
        a = int(rows["arrival"][j])
        kind = int(rows["kind"][j])
        rec_server = int(rows["server"][j])
        prev = results[j]  # R_{j-1}: decision j is the first unforced one
        shadow = prev.placements.get(a)
        shadow_queued = a in _queued_positions(prev, chunk)
        delta = durations[j + 1] - durations[j]

        if kind == KIND_ARRIVE:
            divergent = shadow_queued or shadow != rec_server
            same_server = (not shadow_queued) and shadow == rec_server
        elif kind == KIND_QUEUED:
            divergent = not shadow_queued
            same_server = False
        else:  # KIND_DRAIN
            divergent = shadow != rec_server
            same_server = shadow == rec_server
        if not divergent and kind != KIND_DRAIN:
            bucket = "aligned"
        elif not divergent and kind == KIND_DRAIN:
            bucket = "aligned" if abs(delta) < 1e-9 else "queueing"
        elif same_server or kind == KIND_QUEUED:
            bucket = "queueing"
        elif float(rows["cusum"][j]) >= cusum_gate:
            bucket = "detection"
        else:
            bucket = "estimation"
        decisions.append(DecisionAttribution(
            row=int(rows.get("row", np.arange(p))[j]), segment=segment,
            arrival=a, kind=kind, server=rec_server,
            shadow_server=None if shadow is None else int(shadow),
            delta=delta, bucket=bucket,
            time=float(rows["time"][j]), margin=float(rows["margin"][j]),
            headroom=float(rows["headroom"][j]),
            cusum=float(rows["cusum"][j]),
            n_pair_min=float(rows["n_pair_min"][j])))

    forced_dur = durations[-1]
    return SegmentAttribution(
        segment=segment,
        duration_oracle=durations[0],
        duration_forced=forced_dur,
        regret=forced_dur - durations[0],
        decisions=tuple(decisions),
        replay_gap=(None if recorded_duration is None
                    else recorded_duration - forced_dur))


def _queued_positions(result, chunk) -> set:
    """Chunk positions whose workload hit the queue in a replay (matched by
    arrival order: 'arrive' events fire in chunk order, and a 'queue' event
    immediately follows its arrival)."""
    queued: set[int] = set()
    order = iter(range(len(chunk)))
    pos = -1
    for ev in result.events:
        if ev.kind == "arrive":
            pos = next(order)
        elif ev.kind == "queue":
            queued.add(pos)
    return queued


def attribute_run(
    ring: DecisionRing,
    chunks: Sequence[Sequence[tuple[float, object]]],
    specs_of: Callable[[int], Sequence],
    true_D_of: Callable[[int], Sequence],
    *,
    alpha=1.3,
    objective: str = "sum_avg",
    durations: "Sequence[float] | None" = None,
    cusum_gate: float = CUSUM_GATE,
) -> list[SegmentAttribution]:
    """Attribute every segment surviving in the ring.

    ``chunks[k]`` is segment k's arrivals in trace order; ``specs_of(k)`` /
    ``true_D_of(k)`` the true server specs and profiled D for that segment
    (drift-aware callers resolve per segment). Segments whose rows were
    overwritten by ring wrap-around are skipped -- the flight recorder
    keeps the newest decisions.
    """
    cols = ring.columns()
    cols = dict(cols, row=np.arange(len(cols["arrival"])))
    out = []
    for k, chunk in enumerate(chunks):
        sel = cols["segment"] == k
        if not sel.any():
            continue
        rows = {name: v[sel] for name, v in cols.items()}
        # a wrapped ring may have lost this segment's head: decisions can
        # only be replayed from a complete prefix
        if int(rows["arrival"].min()) != 0 or len(chunk) == 0:
            continue
        out.append(attribute_segment(
            k, rows, chunk, specs_of(k), true_D_of(k), alpha=alpha,
            objective=objective,
            recorded_duration=(None if durations is None else
                               float(durations[k])),
            cusum_gate=cusum_gate))
    return out


# --- rendering -------------------------------------------------------------

def _fmt(v: float) -> str:
    if not np.isfinite(v):
        return "inf" if v > 0 else "-inf"
    if v == 0:
        return "0"
    if abs(v) >= 1e5 or abs(v) < 1e-3:
        return f"{v:.3g}"
    return f"{v:.4g}"


_KIND_NAME = {KIND_ARRIVE: "place", KIND_DRAIN: "drain", KIND_QUEUED: "queue"}


def render_timeline(atts: Sequence[SegmentAttribution]) -> str:
    """The per-decision timeline: one line per recorded decision."""
    lines = [
        "  seg  row    t(rel)  kind   arr  srv  shadow     margin   headroom"
        "    cusum      delta  bucket"]
    for att in atts:
        for d in att.decisions:
            shadow = "-" if d.shadow_server is None else str(d.shadow_server)
            lines.append(
                f"  {d.segment:>3}  {d.row:>3} {_fmt(d.time):>9}  "
                f"{_KIND_NAME.get(d.kind, '?'):<5} {d.arrival:>4} "
                f"{d.server:>4}  {shadow:>6} {_fmt(d.margin):>10} "
                f"{_fmt(d.headroom):>10} {_fmt(d.cusum):>8} "
                f"{d.delta:>10.4g}  {d.bucket}")
    return "\n".join(lines)


def render_attribution(atts: Sequence[SegmentAttribution]) -> str:
    """The per-segment attribution table: regret split by bucket, with the
    telescoping identity made visible."""
    buckets = ("estimation", "queueing", "detection", "aligned")
    head = ("  seg   oracle(s)   forced(s)   regret(s) "
            + " ".join(f"{b:>12}" for b in buckets) + "   sum-check")
    lines = [head]
    for att in atts:
        by = att.by_bucket
        total = sum(d.delta for d in att.decisions)
        lines.append(
            f"  {att.segment:>3} {att.duration_oracle:>11.5g} "
            f"{att.duration_forced:>11.5g} {att.regret:>11.4g} "
            + " ".join(f"{by.get(b, 0.0):>12.4g}" for b in buckets)
            + f" {abs(total - att.regret):>11.2g}")
    return "\n".join(lines)


def check_reconstruction(ring: DecisionRing, placements) -> "list[str]":
    """Verify the ring reconstructs every placement of the run it recorded.

    ``placements``: per segment, the run's own arrival -> server outcome
    list (``EngineResult.placements``; None = never placed). Every placed
    arrival must have exactly one commit row (arrive or drain) naming the
    same server, and never-placed arrivals must have no commit row.
    Returns human-readable failures (empty = ring is a faithful record).
    """
    cols = ring.columns()
    failures = []
    for k, segp in enumerate(placements):
        sel = cols["segment"] == k
        commits: dict[int, list[int]] = {}
        for j in np.flatnonzero(sel):
            if int(cols["kind"][j]) == KIND_QUEUED:
                continue
            commits.setdefault(int(cols["arrival"][j]), []).append(
                int(cols["server"][j]))
        for a, s in enumerate(segp):
            got = commits.get(a, [])
            if s is None:
                if got:
                    failures.append(
                        f"segment {k} arrival {a}: ring has commit rows "
                        f"{got} but the run never placed it")
            elif got != [int(s)]:
                failures.append(
                    f"segment {k} arrival {a}: run placed on {s}, ring "
                    f"says {got or 'nothing'}")
    return failures


def check_exactness(atts: Sequence[SegmentAttribution],
                    tol: float = 1e-5) -> "list[str]":
    """The acceptance gate: per-decision deltas sum to the segment regret
    within ``tol``. Returns human-readable failures (empty = pass)."""
    failures = []
    for att in atts:
        total = sum(d.delta for d in att.decisions)
        if abs(total - att.regret) > tol:
            failures.append(
                f"segment {att.segment}: sum(deltas) {total:.8g} != regret "
                f"{att.regret:.8g} (|err| {abs(total - att.regret):.3g})")
    return failures
