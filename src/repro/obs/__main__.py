"""CLI: run reports, regret attribution, and the obs-plane selfcheck.

  python -m repro.obs              render a run report from a small canned
                                   adaptive run (2 servers, metrics on)
  python -m repro.obs --json       same, as a JSON snapshot
  python -m repro.obs --explain    record a canned stationary adaptive run
                                   with the decision flight recorder, replay
                                   it against the true dynamics, and render
                                   the per-decision timeline + per-segment
                                   regret attribution + worst-decisions
                                   tables (``obs.explain``); exit 1 if the
                                   ring fails to reconstruct the run or the
                                   attribution does not sum to the regret
  python -m repro.obs --selfcheck  verify the histogram/percentile math, the
                                   chunk-invariant merge, counter exactness
                                   against a host-visible engine result, the
                                   report render, decision-ring provenance
                                   (record=True leaves decisions bit-
                                   identical and the ring reconstructs every
                                   placement), and attribution exactness;
                                   exit 1 on any failure (CI runs this in
                                   the static-analysis job)
"""
from __future__ import annotations

import argparse
import json
import sys

import numpy as np

from . import metrics as M
from . import report


def _log_tol(spec: M.HistSpec) -> float:
    """Percentile agreement tolerance in log space: 1.5 bin widths (one bin
    of quantization plus interpolation slack at bin boundaries)."""
    return 1.5 * np.log(spec.bin_ratio())


def _check_percentiles(failures: "list[str]") -> None:
    rng = np.random.default_rng(0)
    for spec in M.HISTOGRAMS:
        # log-uniform samples strictly inside the spec's range
        lo, hi = spec.lo * spec.bin_ratio(), spec.hi / spec.bin_ratio()
        vals = np.exp(rng.uniform(np.log(lo), np.log(hi), size=4096))
        frame = M.observe(M.zeros(1), spec.name, vals.astype(np.float32))
        est = M.percentiles(frame, spec.name, (50.0, 95.0, 99.0))
        ref = np.percentile(vals, [50.0, 95.0, 99.0])
        err = np.abs(np.log(est) - np.log(ref))
        if not (err <= _log_tol(spec)).all():
            failures.append(
                f"percentiles[{spec.name}]: est {est} vs numpy {ref} "
                f"(log error {err}, tol {_log_tol(spec):.4f})")


def _check_merge(failures: "list[str]") -> None:
    rng = np.random.default_rng(1)
    spec = M.HISTOGRAMS[0]
    vals = np.exp(rng.uniform(np.log(spec.lo), np.log(spec.hi),
                              size=999)).astype(np.float32)
    whole = M.observe(M.zeros(2), spec.name, vals)
    whole = M.count(whole, "events", 999)
    parts = M.zeros(2)
    for chunk in np.array_split(vals, 7):
        part = M.observe(M.zeros(2), spec.name, chunk)
        part = M.count(part, "events", len(chunk))
        parts = M.merge(parts, part)
    if not (np.array_equal(np.asarray(whole.hist), np.asarray(parts.hist))
            and np.array_equal(np.asarray(whole.counters),
                               np.asarray(parts.counters))):
        failures.append("merge: split-and-merge frame != single-pass frame "
                        "(chunk invariance broken)")


def _canned_run():
    from ..core.engine import ConsolidationEngine
    from ..core.server import M1, M2
    from ..core.workload import FS_GRID, RS_GRID, Workload, snap_to_grid

    arrivals = []
    for i in range(12):
        w = snap_to_grid(Workload(
            fs=FS_GRID[(5 * i) % len(FS_GRID)], rs=RS_GRID[i % len(RS_GRID)],
            data_total=48e6))
        arrivals.append((0.5 * i, w))
    engine = ConsolidationEngine([M1, M2], backend="jax")
    return engine.run(arrivals, metrics=True)


def _check_engine_counters(failures: "list[str]") -> None:
    res = _canned_run()
    frame = res.metrics
    oracle = {
        "arrivals": len(res.placements),
        "placements": sum(1 for p in res.placements if p is not None),
        "queued": sum(1 for q in res.was_queued if q),
        "finishes": sum(1 for t in res.finish_times if np.isfinite(t)),
        "deadlocks": 0,
    }
    for name, want in oracle.items():
        got = M.counter_value(frame, name)
        if got != want:
            failures.append(f"counter[{name}]: frame says {got}, "
                            f"host result says {want}")
    per_server = M.server_values(frame, "placements")
    for s in range(2):
        want = sum(1 for p in res.placements if p == s)
        if int(per_server[s]) != want:
            failures.append(f"per_server placements[{s}]: frame says "
                            f"{int(per_server[s])}, host result says {want}")
    # every placement contributes exactly one waiting-time/headroom sample
    for hist in ("waiting_time", "headroom"):
        total = int(M.hist_counts(frame, hist).sum())
        if total != oracle["placements"]:
            failures.append(f"hist[{hist}]: {total} samples != "
                            f"{oracle['placements']} placements")
    try:
        text = report.render_report(res, title="selfcheck")
    except Exception as e:  # pragma: no cover - render must not throw
        failures.append(f"render_report raised {e!r}")
        return
    for needle in ("counters:", "percentiles:", "per-server:", "waiting_time"):
        if needle not in text:
            failures.append(f"render_report output missing {needle!r}")


#: gap between the canned stationary segments (each segment restarts from an
#: empty cluster, so this only keeps the trace clock readable)
_SEG_GAP = 60.0


def _canned_adaptive(segments: int = 3, per_seg: int = 10):
    """A stationary adaptive run with the flight recorder on: the same
    heavy LLC-resident workload mixture replayed per segment (the
    benchmarks/adaptive_regret.py recipe at small scale, near-simultaneous
    arrivals so co-run pressure is real), scheduler learning from a cold
    optimistic prior. Returns (engine, result, per-segment chunks in the
    trace order the recorded arrival ids index)."""
    from ..core.engine import AdaptiveEngine
    from ..core.server import M1, M2
    from ..core.workload import FS_GRID, RS_GRID, Workload, snap_to_grid

    rng = np.random.default_rng(3)
    seg, t = [], 0.0
    for _ in range(per_seg):
        fs = float(rng.choice(FS_GRID[10:15]))
        w = snap_to_grid(Workload(fs=fs, rs=float(rng.choice(RS_GRID[5:8])),
                                  data_total=fs * 8))
        t += float(rng.exponential(2e-5))
        seg.append((t, w))
    arrivals = [(t + k * _SEG_GAP, w) for k in range(segments)
                for t, w in seg]
    eng = AdaptiveEngine([M1, M2], prior=0.0, decay=0.997)
    res = eng.run(arrivals, segments=segments, record=True)
    ordered = sorted(arrivals, key=lambda tw: tw[0])
    bounds = np.linspace(0, len(ordered), segments + 1).astype(int)
    chunks = [ordered[bounds[k]:bounds[k + 1]] for k in range(segments)]
    return eng, res, chunks


def _attribute(eng, res, chunks):
    """Run obs.explain over a recorded adaptive run; returns
    (attributions, reconstruction failures)."""
    from ..core.contention import profile_pairwise_fast
    from . import explain

    cache = {}
    for s in eng.servers:
        if s not in cache:
            cache[s] = profile_pairwise_fast(s)
    true_D = [cache[s] for s in eng.servers]
    atts = explain.attribute_run(
        res.decisions, chunks, lambda k: eng.servers, lambda k: true_D,
        alpha=eng.alpha, objective=eng.objective, durations=res.durations)
    recon = explain.check_reconstruction(
        res.decisions, [r.placements for r in res.segments])
    return atts, recon


def _check_recorder(failures: "list[str]") -> None:
    """record=True must not change one decision, and the ring must be a
    faithful record: one commit row per placement, queue rows for queued
    arrivals, nothing else."""
    from ..core.engine import ConsolidationEngine
    from ..core.server import M1, M2
    from ..core.workload import FS_GRID, RS_GRID, Workload, snap_to_grid
    from . import explain
    from .recorder import DecisionRing

    arrivals = []
    for i in range(12):
        w = snap_to_grid(Workload(
            fs=FS_GRID[(5 * i) % len(FS_GRID)], rs=RS_GRID[i % len(RS_GRID)],
            data_total=48e6))
        arrivals.append((0.5 * i, w))
    engine = ConsolidationEngine([M1, M2], backend="jax")
    base = engine.run(arrivals)
    rec = engine.run(arrivals, record=True)
    if list(base.placements) != list(rec.placements):
        failures.append("recorder: record=True changed placements "
                        f"({base.placements} vs {rec.placements})")
    if list(base.was_queued) != list(rec.was_queued):
        failures.append("recorder: record=True changed queueing behaviour")
    if rec.decisions is None:
        failures.append("recorder: record=True returned no decision ring")
        return
    ring = DecisionRing(int(rec.decisions.block.ints.shape[0]))
    ring.adopt(rec.decisions)
    for f in explain.check_reconstruction(ring, [rec.placements]):
        failures.append(f"recorder: {f}")
    queued_rows = {int(a) for a, kind in zip(ring.columns()["arrival"],
                                             ring.columns()["kind"])
                   if int(kind) == 2}
    want_queued = {a for a, q in enumerate(rec.was_queued) if q}
    if queued_rows != want_queued:
        failures.append(f"recorder: queue rows {sorted(queued_rows)} != "
                        f"queued arrivals {sorted(want_queued)}")


def _check_attribution(failures: "list[str]") -> None:
    """The telescoping-replay gate: per-decision deltas sum to each
    segment's regret within 1e-5 and the ring reconstructs the run."""
    from . import explain

    eng, res, chunks = _canned_adaptive(segments=2, per_seg=8)
    atts, recon = _attribute(eng, res, chunks)
    if len(atts) != 2:
        failures.append(
            f"attribution: expected 2 attributed segments, got {len(atts)}")
    failures.extend(f"attribution: {f}" for f in explain.check_exactness(atts))
    failures.extend(f"attribution: {f}" for f in recon)


def selfcheck() -> int:
    failures: list[str] = []
    for name, check in (("percentiles-vs-numpy", _check_percentiles),
                        ("merge-chunk-invariance", _check_merge),
                        ("engine-counter-exactness", _check_engine_counters),
                        ("recorder-ring-provenance", _check_recorder),
                        ("attribution-exactness", _check_attribution)):
        before = len(failures)
        check(failures)
        status = "ok" if len(failures) == before else "FAIL"
        print(f"obs selfcheck: {name:<28} {status}")
    for f in failures:
        print(f"  FAIL: {f}", file=sys.stderr)
    return 1 if failures else 0


def main(argv: "list[str] | None" = None) -> int:
    parser = argparse.ArgumentParser(
        prog="python -m repro.obs",
        description="metrics-plane run reports and selfcheck")
    parser.add_argument("--selfcheck", action="store_true",
                        help="verify histogram/merge/counter/recorder/"
                             "attribution invariants")
    parser.add_argument("--explain", action="store_true",
                        help="record a canned stationary adaptive run and "
                             "render its regret attribution")
    parser.add_argument("--json", action="store_true",
                        help="print the metric snapshot as JSON")
    args = parser.parse_args(argv)
    if args.selfcheck:
        return selfcheck()
    if args.explain:
        return explain_main(json_out=args.json)
    res = _canned_run()
    if args.json:
        print(json.dumps(M.snapshot(res.metrics), indent=2))
    else:
        print(report.render_report(res, title="canned consolidation run"))
    return 0


def explain_main(json_out: bool = False) -> int:
    """``--explain``: the flight-recorder post-mortem, end to end."""
    from . import explain

    eng, res, chunks = _canned_adaptive()
    atts, recon = _attribute(eng, res, chunks)
    exact = explain.check_exactness(atts)
    if json_out:
        print(json.dumps({
            "segments": [{
                "segment": a.segment,
                "duration_oracle": a.duration_oracle,
                "duration_forced": a.duration_forced,
                "regret": a.regret,
                "replay_gap": a.replay_gap,
                "by_bucket": a.by_bucket,
                "decisions": [vars(d) for d in a.decisions],
            } for a in atts],
            "reconstruction_failures": recon,
            "exactness_failures": exact,
        }, indent=2))
    else:
        n_dec = sum(len(a.decisions) for a in atts)
        print("== decision flight recorder: regret attribution "
              "(canned stationary adaptive run) ==\n")
        print(f"segments: {len(atts)}   recorded decisions: "
              f"{len(res.decisions)}   attributed: {n_dec}\n")
        print("per-decision timeline:")
        print(explain.render_timeline(atts))
        print("\nper-segment attribution (deltas telescope to the regret):")
        print(explain.render_attribution(atts))
        print("\nworst 10 decisions (by attributed regret):")
        print(report.worst_decisions_table(atts))
        status = "ok" if not recon else "FAIL"
        print(f"\nring reconstructs every placement of the run: {status}")
    for f in recon + exact:
        print(f"  FAIL: {f}", file=sys.stderr)
    return 1 if (recon or exact) else 0


if __name__ == "__main__":
    sys.exit(main())
