"""CLI: run reports and the metrics-plane selfcheck.

  python -m repro.obs              render a run report from a small canned
                                   adaptive run (2 servers, metrics on)
  python -m repro.obs --json       same, as a JSON snapshot
  python -m repro.obs --selfcheck  verify the histogram/percentile math, the
                                   chunk-invariant merge, counter exactness
                                   against a host-visible engine result, and
                                   the report render; exit 1 on any failure
                                   (CI runs this in the static-analysis job)
"""
from __future__ import annotations

import argparse
import json
import sys

import numpy as np

from . import metrics as M
from . import report


def _log_tol(spec: M.HistSpec) -> float:
    """Percentile agreement tolerance in log space: 1.5 bin widths (one bin
    of quantization plus interpolation slack at bin boundaries)."""
    return 1.5 * np.log(spec.bin_ratio())


def _check_percentiles(failures: "list[str]") -> None:
    rng = np.random.default_rng(0)
    for spec in M.HISTOGRAMS:
        # log-uniform samples strictly inside the spec's range
        lo, hi = spec.lo * spec.bin_ratio(), spec.hi / spec.bin_ratio()
        vals = np.exp(rng.uniform(np.log(lo), np.log(hi), size=4096))
        frame = M.observe(M.zeros(1), spec.name, vals.astype(np.float32))
        est = M.percentiles(frame, spec.name, (50.0, 95.0, 99.0))
        ref = np.percentile(vals, [50.0, 95.0, 99.0])
        err = np.abs(np.log(est) - np.log(ref))
        if not (err <= _log_tol(spec)).all():
            failures.append(
                f"percentiles[{spec.name}]: est {est} vs numpy {ref} "
                f"(log error {err}, tol {_log_tol(spec):.4f})")


def _check_merge(failures: "list[str]") -> None:
    rng = np.random.default_rng(1)
    spec = M.HISTOGRAMS[0]
    vals = np.exp(rng.uniform(np.log(spec.lo), np.log(spec.hi),
                              size=999)).astype(np.float32)
    whole = M.observe(M.zeros(2), spec.name, vals)
    whole = M.count(whole, "events", 999)
    parts = M.zeros(2)
    for chunk in np.array_split(vals, 7):
        part = M.observe(M.zeros(2), spec.name, chunk)
        part = M.count(part, "events", len(chunk))
        parts = M.merge(parts, part)
    if not (np.array_equal(np.asarray(whole.hist), np.asarray(parts.hist))
            and np.array_equal(np.asarray(whole.counters),
                               np.asarray(parts.counters))):
        failures.append("merge: split-and-merge frame != single-pass frame "
                        "(chunk invariance broken)")


def _canned_run():
    from ..core.engine import ConsolidationEngine
    from ..core.server import M1, M2
    from ..core.workload import FS_GRID, RS_GRID, Workload, snap_to_grid

    arrivals = []
    for i in range(12):
        w = snap_to_grid(Workload(
            fs=FS_GRID[(5 * i) % len(FS_GRID)], rs=RS_GRID[i % len(RS_GRID)],
            data_total=48e6))
        arrivals.append((0.5 * i, w))
    engine = ConsolidationEngine([M1, M2], backend="jax")
    return engine.run(arrivals, metrics=True)


def _check_engine_counters(failures: "list[str]") -> None:
    res = _canned_run()
    frame = res.metrics
    oracle = {
        "arrivals": len(res.placements),
        "placements": sum(1 for p in res.placements if p is not None),
        "queued": sum(1 for q in res.was_queued if q),
        "finishes": sum(1 for t in res.finish_times if np.isfinite(t)),
        "deadlocks": 0,
    }
    for name, want in oracle.items():
        got = M.counter_value(frame, name)
        if got != want:
            failures.append(f"counter[{name}]: frame says {got}, "
                            f"host result says {want}")
    per_server = M.server_values(frame, "placements")
    for s in range(2):
        want = sum(1 for p in res.placements if p == s)
        if int(per_server[s]) != want:
            failures.append(f"per_server placements[{s}]: frame says "
                            f"{int(per_server[s])}, host result says {want}")
    # every placement contributes exactly one waiting-time/headroom sample
    for hist in ("waiting_time", "headroom"):
        total = int(M.hist_counts(frame, hist).sum())
        if total != oracle["placements"]:
            failures.append(f"hist[{hist}]: {total} samples != "
                            f"{oracle['placements']} placements")
    try:
        text = report.render_report(res, title="selfcheck")
    except Exception as e:  # pragma: no cover - render must not throw
        failures.append(f"render_report raised {e!r}")
        return
    for needle in ("counters:", "percentiles:", "per-server:", "waiting_time"):
        if needle not in text:
            failures.append(f"render_report output missing {needle!r}")


def selfcheck() -> int:
    failures: list[str] = []
    for name, check in (("percentiles-vs-numpy", _check_percentiles),
                        ("merge-chunk-invariance", _check_merge),
                        ("engine-counter-exactness", _check_engine_counters)):
        before = len(failures)
        check(failures)
        status = "ok" if len(failures) == before else "FAIL"
        print(f"obs selfcheck: {name:<28} {status}")
    for f in failures:
        print(f"  FAIL: {f}", file=sys.stderr)
    return 1 if failures else 0


def main(argv: "list[str] | None" = None) -> int:
    parser = argparse.ArgumentParser(
        prog="python -m repro.obs",
        description="metrics-plane run reports and selfcheck")
    parser.add_argument("--selfcheck", action="store_true",
                        help="verify histogram/merge/counter invariants")
    parser.add_argument("--json", action="store_true",
                        help="print the metric snapshot as JSON")
    args = parser.parse_args(argv)
    if args.selfcheck:
        return selfcheck()
    res = _canned_run()
    if args.json:
        print(json.dumps(M.snapshot(res.metrics), indent=2))
    else:
        print(report.render_report(res, title="canned consolidation run"))
    return 0


if __name__ == "__main__":
    sys.exit(main())
