"""Decision flight recorder: device-resident placement provenance.

The metrics plane (PR 8) aggregates -- counters, histograms, high-water
gauges -- and can say *that* a floor violation or a regret spike happened,
never *why decision k chose server s*. This module records the decision
itself: one packed row per placement commit (and per queue-at-arrival
decision), written as pure array ops inside ``engine_jax``'s event loop at
the single point every placement flows through (``place_if``), and carried
through ``run_closed_loop``'s scan exactly like the ObservationRing before
it. The off switch is the PR 8 pattern: a static ``record=`` flag plus a
None-defaulted carry field, so recorder-off programs keep the byte-identical
structure, and recorder-on runs are *decision-identical* -- nothing here
feeds back into scoring.

Row layout (``REC_TOPK = K`` candidate slots; DESIGN.md section 16):

  ints   i32[cap, 6 + K]
    0 arrival   trace-local arrival index (requeued work first, then chunk)
    1 segment   closed-loop segment counter (``LoopCarry.seen`` at entry)
    2 server    committed global server id, or -1 when queued
    3 kind      0 = placed at arrival, 1 = drain commit, 2 = queued
    4 qdepth    queued arrivals at commit (drain rows count the drained one)
    5 pool_row  the estimator read row the scheduler consulted (-1 queued)
    6: cand     the K lowest-score candidate global server ids (-1 = none
                feasible / past the fleet edge)
  floats f32[cap, 5 + K]
    0 time      commit time, chunk-relative (the trace clock)
    1 headroom  Eqn-4 budget left on the committed server, post-commit
    2 margin    runner-up score minus winner score (argmin tie margin)
    3 n_pair    min pair-confidence exposure over the newly co-located
                pairs (-1 = no co-residents, or no estimator context)
    4 cusum     the committed server's CUSUM level (max of the S+/S- pair)
    5: score    the K candidate scores (inf = infeasible)

Sharded runs keep every recorded field replicated: per-decision scalars are
owner-computed and ``pmin``-broadcast (the ``place_if`` metrics idiom), and
the candidate row is ``all_gather``-ed before the top-K cut, so the ring is
bitwise identical on every shard and rides the scan carry under
``axis.rep_tree`` specs -- the epilogue adopts any one shard's copy.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

#: candidate slots recorded per decision (winner first)
REC_TOPK = 4

#: row kinds (the ints[:, 3] column)
KIND_ARRIVE, KIND_DRAIN, KIND_QUEUED = 0, 1, 2

_INT_COLS = 6 + REC_TOPK
_FLOAT_COLS = 5 + REC_TOPK


class DecisionBlock(NamedTuple):
    """The packed decision rows (two arrays, like ``RingBlock``)."""

    ints: jax.Array  # i32[cap, 6 + K]
    floats: jax.Array  # f32[cap, 5 + K]

    arrival = property(lambda s: s.ints[:, 0])
    segment = property(lambda s: s.ints[:, 1])
    server = property(lambda s: s.ints[:, 2])
    kind = property(lambda s: s.ints[:, 3])
    qdepth = property(lambda s: s.ints[:, 4])
    pool_row = property(lambda s: s.ints[:, 5])
    cand = property(lambda s: s.ints[:, 6:])
    time = property(lambda s: s.floats[:, 0])
    headroom = property(lambda s: s.floats[:, 1])
    margin = property(lambda s: s.floats[:, 2])
    n_pair_min = property(lambda s: s.floats[:, 3])
    cusum = property(lambda s: s.floats[:, 4])
    score = property(lambda s: s.floats[:, 5:])


class RecState(NamedTuple):
    """The recorder's carry: ring block + cursor, one pytree."""

    block: DecisionBlock
    ptr: jax.Array  # i32 next write slot (kept modulo capacity)
    total: jax.Array  # i32 rows ever recorded


class RecCtx(NamedTuple):
    """Estimator/detector context the recorder samples at each commit.

    Built once per segment (host: from the live fleet objects; device loop:
    from the scan carry) -- the state the scheduler *consulted*, not the
    post-segment state.

    ``n_pair``/``row_of``/``cusum`` are shard-local under a sharded axis
    (bank rows and detector state shard by server row); ``pool_row`` stays
    global/replicated so the recorded row is meaningful fleet-wide.
    """

    n_pair: "jax.Array | None"  # f32[rows, T, T] pair-exposure bank rows
    row_of: jax.Array  # i32[m_local] local server -> local bank row
    cusum: jax.Array  # f32[m_local] per-server CUSUM level (max S+/S-)
    pool_row: jax.Array  # i32[m_global] recorded read row per server
    segment: jax.Array  # i32 segment counter at entry


def init(capacity: int) -> RecState:
    """Fresh all-sentinel recorder state (``ints`` -1, ``floats`` 0)."""
    if capacity <= 0:
        raise ValueError(f"capacity must be positive (got {capacity})")
    return RecState(
        block=DecisionBlock(
            ints=jnp.full((capacity, _INT_COLS), -1, jnp.int32),
            floats=jnp.zeros((capacity, _FLOAT_COLS), jnp.float32)),
        ptr=jnp.int32(0), total=jnp.int32(0))


def default_ctx(m_local: int, m_global: "int | None" = None) -> RecCtx:
    """Context for engines without an estimator in the loop: identity pool
    routing, zero CUSUM, no pair-exposure table (n_pair records -1)."""
    m_global = m_local if m_global is None else m_global
    return RecCtx(
        n_pair=None,
        row_of=jnp.arange(m_local, dtype=jnp.int32),
        cusum=jnp.zeros((m_local,), jnp.float32),
        pool_row=jnp.arange(m_global, dtype=jnp.int32),
        segment=jnp.int32(0))


def rec_specs(axis) -> RecState:
    """All-replicated PartitionSpec tree matching a ``RecState`` (the ring
    is bitwise identical on every shard; any copy is the ring)."""
    rep = axis.rep()
    return RecState(block=DecisionBlock(ints=rep, floats=rep),
                    ptr=rep, total=rep)


def ctx_specs(axis, ctx: RecCtx) -> RecCtx:
    """PartitionSpec tree for a globally-shaped ``RecCtx``: per-server state
    shards by leading row, the global pool map and clock replicate."""
    return RecCtx(
        n_pair=None if ctx.n_pair is None else axis.spec(),
        row_of=axis.spec(), cusum=axis.spec(),
        pool_row=axis.rep(), segment=axis.rep())


def top_candidates(score_row: jax.Array) -> tuple[jax.Array, jax.Array]:
    """(cand i32[K], score f32[K]): the K lowest-score global candidates.

    ``score_row`` is the feasibility-masked score over all *global* servers
    (infeasible = inf). Stable argsort reproduces the scheduler's
    lowest-index tie-break; infeasible slots keep their inf score but null
    their candidate id. Fleets smaller than K pad with (-1, inf).
    """
    m = int(score_row.shape[0])
    idx = jnp.arange(m, dtype=jnp.int32)
    if m < REC_TOPK:
        pad = REC_TOPK - m
        score_row = jnp.concatenate(
            [score_row, jnp.full((pad,), jnp.inf, score_row.dtype)])
        idx = jnp.concatenate([idx, jnp.full((pad,), -1, jnp.int32)])
    order = jnp.argsort(score_row)[:REC_TOPK]  # stable: index breaks ties
    sc = score_row[order]
    cand = jnp.where(jnp.isfinite(sc), idx[order], -1)
    return cand, sc


def tie_margin(scores: jax.Array) -> jax.Array:
    """Runner-up minus winner from a sorted top-K score row (inf when there
    is no finite runner-up -- a one-horse race has no tie to break)."""
    return jnp.where(jnp.isfinite(scores[1]) & jnp.isfinite(scores[0]),
                     scores[1] - scores[0], jnp.inf)


def pair_exposure_min(n_pair_row: jax.Array, counts_row: jax.Array,
                      wtype: jax.Array) -> jax.Array:
    """Min pair-confidence exposure over the newly co-located pairs.

    ``n_pair_row`` is one estimator row's decayed per-pair exposure table
    [T, T] (orientation-insensitive here: both orientations are min-ed, so
    the estimator's target-major transpose does not matter);
    ``counts_row`` the committed server's *post-commit* type counts. Returns
    -1 when the placement co-locates with nothing.
    """
    T = int(counts_row.shape[0])
    t = jnp.clip(wtype, 0, T - 1)
    co = counts_row - jax.nn.one_hot(t, T, dtype=counts_row.dtype)
    present = co > 0
    both = jnp.minimum(n_pair_row[t, :], n_pair_row[:, t])  # [T]
    val = jnp.min(jnp.where(present, both, jnp.inf))
    return jnp.where(jnp.any(present), val, jnp.float32(-1.0))


def record_row(rec: RecState, *, on, arrival, segment, server, kind, qdepth,
               pool_row, cand, scores, t, headroom, margin, n_pair_min,
               cusum) -> RecState:
    """Write one decision row when ``on``; a dropped (out-of-bounds) scatter
    otherwise -- the ``place_if`` conditional-write idiom, so the recorder
    adds no branches to the event loop."""
    cap = int(rec.block.ints.shape[0])
    slot = jnp.where(on, rec.ptr % cap, cap)  # OOB -> dropped under jit
    i32 = jnp.int32
    ints_row = jnp.concatenate([
        jnp.stack([i32(arrival), i32(segment), i32(server), i32(kind),
                   i32(qdepth), i32(pool_row)]),
        cand.astype(jnp.int32)])
    f32 = jnp.float32
    floats_row = jnp.concatenate([
        jnp.stack([f32(t), f32(headroom), f32(margin), f32(n_pair_min),
                   f32(cusum)]),
        scores.astype(jnp.float32)])
    one = jnp.asarray(on).astype(jnp.int32)
    return RecState(
        block=DecisionBlock(
            ints=rec.block.ints.at[slot].set(ints_row),
            floats=rec.block.floats.at[slot].set(floats_row)),
        ptr=(rec.ptr + one) % cap,
        total=rec.total + one)


class DecisionRing:
    """Host mirror of the device-resident decision ring.

    Like :class:`~repro.telemetry.log.ObservationRing`: a host object
    holding the device ``RecState``, adopted wholesale after each recorded
    run (host-alternating per segment, device loop once per dispatch).
    Capacity is spent in decisions; once full, the oldest are overwritten --
    flight-recorder semantics.
    """

    def __init__(self, capacity: int):
        self.capacity = int(capacity)
        self._state = init(capacity)

    @property
    def state(self) -> RecState:
        return self._state

    @property
    def ptr(self) -> int:
        return int(self._state.ptr)

    @property
    def total(self) -> int:
        return int(self._state.total)

    def __len__(self) -> int:
        return min(self.total, self.capacity)

    def adopt(self, state: RecState) -> None:
        """Adopt a post-run device state (the host mirror of the carry)."""
        if int(state.block.ints.shape[0]) != self.capacity:
            raise ValueError(
                f"adopting a ring of capacity {int(state.block.ints.shape[0])}"
                f" into one of {self.capacity}")
        self._state = state

    def columns(self) -> dict[str, np.ndarray]:
        """Decoded rows, oldest-first, as named numpy columns.

        Never-written slots are dropped; wrapped rings unwrap so row 0 is
        the oldest surviving decision.
        """
        ints = np.asarray(self._state.block.ints)
        floats = np.asarray(self._state.block.floats, np.float64)
        n = len(self)
        if self.total > self.capacity:  # wrapped: oldest row sits at ptr
            p = self.ptr
            sel = np.concatenate([np.arange(p, self.capacity), np.arange(p)])
        else:
            sel = np.arange(n)
        ints, floats = ints[sel], floats[sel]
        return {
            "arrival": ints[:, 0], "segment": ints[:, 1],
            "server": ints[:, 2], "kind": ints[:, 3],
            "qdepth": ints[:, 4], "pool_row": ints[:, 5],
            "cand": ints[:, 6:],
            "time": floats[:, 0], "headroom": floats[:, 1],
            "margin": floats[:, 2], "n_pair_min": floats[:, 3],
            "cusum": floats[:, 4], "score": floats[:, 5:],
        }
