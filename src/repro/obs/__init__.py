"""repro.obs: the observability plane (DESIGN.md §14).

Three layers, hot to cold:

``metrics``   fixed-shape metric state (:class:`MetricFrame`) that rides
              *inside* the jitted loops -- counters, high-water gauges,
              log-spaced streaming histograms, a per-server block -- with
              pure ``count/observe/merge`` ops and host-side percentile
              extraction. Enabled per run by a static ``metrics=`` flag on
              the engines; off means the carried slot is ``None`` (an empty
              pytree) and the compiled program is byte-identical.
``trace``     host-side structured spans around the phases that *surround*
              the device programs (pack/dispatch/epilogue), emitted both as
              ``jax.profiler`` annotations (so ``--profile`` traces are
              navigable) and as an optional JSONL span+snapshot log stamped
              with the git commit.
``report``    renders a run report (counter/gauge/percentile tables,
              per-server utilization-floor violations, fleet health-event
              timeline) from an ``EngineResult``/``AdaptiveResult``, and
              flattens frames into ``BENCH_*.json`` records.

``python -m repro.obs --selfcheck`` exercises the histogram math and the
report path end to end; CI runs it in the static-analysis job.
"""
from .metrics import (
    COUNTERS,
    GAUGES,
    HIST_BINS,
    HISTOGRAMS,
    PER_SERVER,
    HistSpec,
    MetricFrame,
    add_server,
    count,
    counter_value,
    gauge_max,
    gauge_value,
    hist_counts,
    merge,
    observe,
    percentiles,
    snapshot,
    zeros,
)
from .trace import SpanLog, disable_tracing, enable_tracing, span

__all__ = [
    "COUNTERS",
    "GAUGES",
    "HIST_BINS",
    "HISTOGRAMS",
    "PER_SERVER",
    "HistSpec",
    "MetricFrame",
    "SpanLog",
    "add_server",
    "count",
    "counter_value",
    "disable_tracing",
    "enable_tracing",
    "gauge_max",
    "gauge_value",
    "hist_counts",
    "merge",
    "observe",
    "percentiles",
    "snapshot",
    "span",
    "zeros",
]
