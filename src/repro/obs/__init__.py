"""repro.obs: the observability plane (DESIGN.md §14).

Three layers, hot to cold:

``metrics``   fixed-shape metric state (:class:`MetricFrame`) that rides
              *inside* the jitted loops -- counters, high-water gauges,
              log-spaced streaming histograms, a per-server block -- with
              pure ``count/observe/merge`` ops and host-side percentile
              extraction. Enabled per run by a static ``metrics=`` flag on
              the engines; off means the carried slot is ``None`` (an empty
              pytree) and the compiled program is byte-identical.
``trace``     host-side structured spans around the phases that *surround*
              the device programs (pack/dispatch/epilogue), emitted both as
              ``jax.profiler`` annotations (so ``--profile`` traces are
              navigable) and as an optional JSONL span+snapshot log stamped
              with the git commit.
``report``    renders a run report (counter/gauge/percentile tables,
              per-server utilization-floor violations, fleet health-event
              timeline) from an ``EngineResult``/``AdaptiveResult``, and
              flattens frames into ``BENCH_*.json`` records.

Two colder layers ride on the same carry mechanism:

``recorder``  the decision flight recorder: a fixed-capacity ring of packed
              per-placement provenance rows (chosen server, top-k candidate
              scores, tie margin, Eqn-4 headroom, queue depth, pair-
              confidence exposure, CUSUM level, pool row) written inside the
              event loop behind a static ``record=`` flag -- recorder-off
              programs stay byte-identical, recorder-on runs stay
              decision-identical.
``explain``   host-side regret attribution over an exported ring: forced
              true-dynamics replays decompose each recorded decision's
              makespan contribution into estimation error / queueing delay /
              detection lag, telescoping exactly to the total regret.

``python -m repro.obs --selfcheck`` exercises the histogram math, the report
path, and the recorder/attribution plane end to end; CI runs it in the
static-analysis job. ``python -m repro.obs --explain`` renders a recorded
run's per-decision timeline and attribution table.
"""
from .metrics import (
    COUNTERS,
    GAUGES,
    HIST_BINS,
    HISTOGRAMS,
    PER_SERVER,
    HistSpec,
    MetricFrame,
    add_server,
    count,
    counter_value,
    gauge_max,
    gauge_set,
    gauge_value,
    hist_counts,
    merge,
    observe,
    percentiles,
    snapshot,
    zeros,
)
from .recorder import KIND_ARRIVE, KIND_DRAIN, KIND_QUEUED, REC_TOPK, DecisionRing, RecCtx, RecState
from .trace import SpanLog, disable_tracing, enable_tracing, span

__all__ = [
    "COUNTERS",
    "GAUGES",
    "HIST_BINS",
    "HISTOGRAMS",
    "KIND_ARRIVE",
    "KIND_DRAIN",
    "KIND_QUEUED",
    "PER_SERVER",
    "REC_TOPK",
    "DecisionRing",
    "HistSpec",
    "MetricFrame",
    "RecCtx",
    "RecState",
    "SpanLog",
    "add_server",
    "count",
    "counter_value",
    "disable_tracing",
    "enable_tracing",
    "gauge_max",
    "gauge_set",
    "gauge_value",
    "hist_counts",
    "merge",
    "observe",
    "percentiles",
    "snapshot",
    "span",
    "zeros",
]
