from .checkpointer import Checkpointer
