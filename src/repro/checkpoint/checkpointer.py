"""Sharded, async, manifest-based checkpointing with elastic restore.

Layout of a checkpoint step directory:
    <root>/step_000123/
        manifest.json       -- tree structure, shapes, dtypes, hashes, mesh
        arrays/<name>.npy   -- one file per leaf (host-gathered on process 0;
                               on a real multi-host fleet each process writes
                               its addressable shards -- the manifest schema
                               already carries the sharding to reassemble)

Fault-tolerance contract:
  * writes go to a temp dir, fsynced, then atomically renamed -- a crash
    mid-write never corrupts the latest-complete pointer;
  * ``latest_step`` only reports directories whose manifest passes the hash
    check, so restart-after-failure always loads a consistent step;
  * ``restore`` accepts a *different* mesh/sharding than the save used
    (elastic re-mesh after pod loss): arrays are loaded to host then
    device_put with the new sharding.
"""
from __future__ import annotations

import hashlib
import json
import os
import pathlib
import shutil
import threading
from typing import Any

import jax
import numpy as np


def _leaf_name(path) -> str:
    return jax.tree_util.keystr(path).replace("/", "_").replace("'", "").strip("[].")


def _tree_leaves_with_names(tree):
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    names = [_leaf_name(p) for p, _ in flat]
    assert len(set(names)) == len(names), "leaf names must be unique"
    return [(n, leaf) for n, (_, leaf) in zip(names, flat)]


class Checkpointer:
    def __init__(self, root: str | os.PathLike, keep: int = 3, async_save: bool = True):
        self.root = pathlib.Path(root)
        self.root.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self.async_save = async_save
        self._thread: threading.Thread | None = None

    # -- save -----------------------------------------------------------
    def save(self, step: int, tree: Any, *, blocking: bool | None = None) -> None:
        host_tree = jax.tree_util.tree_map(lambda x: np.asarray(jax.device_get(x)), tree)
        if blocking is None:
            blocking = not self.async_save
        self.wait()  # never overlap two saves
        if blocking:
            self._write(step, host_tree)
        else:
            self._thread = threading.Thread(target=self._write, args=(step, host_tree))
            self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _write(self, step: int, host_tree: Any) -> None:
        final = self.root / f"step_{step:09d}"
        tmp = self.root / f".tmp_step_{step:09d}"
        if tmp.exists():
            shutil.rmtree(tmp)
        (tmp / "arrays").mkdir(parents=True)

        leaves = _tree_leaves_with_names(host_tree)
        entries = {}
        for name, arr in leaves:
            fn = tmp / "arrays" / f"{name}.npy"
            np.save(fn, arr)
            entries[name] = {
                "shape": list(arr.shape),
                "dtype": str(arr.dtype),
                "sha256": hashlib.sha256(arr.tobytes()).hexdigest(),
            }
        treedef = jax.tree_util.tree_structure(host_tree)
        manifest = {"step": step, "leaves": entries, "treedef": str(treedef)}
        with open(tmp / "manifest.json", "w") as f:
            json.dump(manifest, f, indent=1)
            f.flush()
            os.fsync(f.fileno())
        if final.exists():
            shutil.rmtree(final)
        tmp.rename(final)  # atomic publish
        self._gc()

    def _gc(self) -> None:
        steps = sorted(self.steps())
        for s in steps[: -self.keep]:
            shutil.rmtree(self.root / f"step_{s:09d}", ignore_errors=True)

    # -- restore ----------------------------------------------------------
    def steps(self) -> list[int]:
        out = []
        for d in self.root.glob("step_*"):
            if (d / "manifest.json").exists():
                out.append(int(d.name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> int | None:
        steps = self.steps()
        return steps[-1] if steps else None

    def restore(self, step: int, like: Any, shardings: Any | None = None,
                verify: bool = True) -> Any:
        """Load step into the structure of ``like`` (shapes must match).

        ``shardings``: optional NamedSharding pytree for the *current* mesh;
        this is the elastic-reshard path -- the on-disk layout is
        mesh-agnostic (full arrays), so restoring onto a different mesh is
        just a device_put with the new shardings.
        """
        d = self.root / f"step_{step:09d}"
        manifest = json.loads((d / "manifest.json").read_text())
        flat = jax.tree_util.tree_flatten_with_path(like)[0]
        treedef = jax.tree_util.tree_structure(like)
        arrays = []
        for path, leaf in flat:
            name = _leaf_name(path)
            arr = np.load(d / "arrays" / f"{name}.npy")
            meta = manifest["leaves"][name]
            if verify:
                h = hashlib.sha256(arr.tobytes()).hexdigest()
                if h != meta["sha256"]:
                    raise IOError(f"checkpoint corruption in leaf {name}")
            if tuple(arr.shape) != tuple(np.shape(leaf)):
                raise ValueError(f"shape mismatch for {name}: {arr.shape} vs {np.shape(leaf)}")
            arrays.append(arr)
        tree = jax.tree_util.tree_unflatten(treedef, arrays)
        if shardings is not None:
            tree = jax.tree_util.tree_map(
                lambda a, s: jax.device_put(a, s), tree, shardings
            )
        return tree
