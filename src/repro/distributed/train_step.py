"""The pjit training step: loss -> grads -> optimizer, with microbatch
gradient accumulation, remat (configured per-model), and compute/comm overlap.

Overlap note (DESIGN.md §5): with microbatches > 1 the accumulation is a
lax.scan whose per-iteration backward produces partial gradients; XLA's
async collectives let the data-parallel reduction of microbatch k overlap
the compute of microbatch k+1 (latency-hiding is the scheduler's job once
the dependence structure permits it -- which this loop does).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

from ..configs.base import RunConfig
from ..models.api import Model
from ..optim import OptConfig, make_optimizer, warmup_cosine


def _split_micro(batch: dict, k: int) -> dict:
    def r(x):
        b = x.shape[0]
        assert b % k == 0, (b, k)
        return x.reshape(k, b // k, *x.shape[1:])

    return {key: r(v) for key, v in batch.items()}


def make_train_step(model: Model, run: RunConfig) -> tuple[Callable, Callable]:
    """Returns (init_fn(rng)->(params,opt_state), train_step_fn)."""
    ocfg = OptConfig(weight_decay=run.weight_decay, grad_clip=run.grad_clip)
    opt_init, opt_update = make_optimizer(model.cfg.optimizer, ocfg)

    def init(rng):
        from ..models.params import materialize

        params = materialize(model.param_infos(), rng)
        return params, opt_init(params)

    def loss_fn(params, batch):
        loss, metrics = model.loss(params, batch)
        return loss, metrics

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def train_step(params, opt_state, batch, step):
        lr = warmup_cosine(
            step, peak_lr=run.learning_rate,
            warmup_steps=run.warmup_steps, total_steps=run.total_steps,
        )
        if run.microbatches <= 1:
            (loss, metrics), grads = grad_fn(params, batch)
        else:
            micro = _split_micro(batch, run.microbatches)

            def acc(carry, mb):
                g_acc, l_acc = carry
                (l, _), g = grad_fn(params, mb)
                g_acc = jax.tree_util.tree_map(
                    lambda a, b: a + b.astype(jnp.float32), g_acc, g
                )
                return (g_acc, l_acc + l), None

            g0 = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params
            )
            (grads, loss), _ = jax.lax.scan(acc, (g0, 0.0), micro)
            k = float(run.microbatches)
            grads = jax.tree_util.tree_map(lambda g: g / k, grads)
            loss = loss / k
            metrics = {}

        new_params, new_opt, stats = opt_update(params, grads, opt_state, lr, ocfg)
        out = {"loss": loss, "lr": lr, **metrics, **stats}
        return new_params, new_opt, out

    return init, train_step
