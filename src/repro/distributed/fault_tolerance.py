"""Fault tolerance: heartbeats, straggler mitigation, elastic re-mesh.

At 1000+ nodes the failure model is: (a) hard host loss (heartbeat timeout),
(b) stragglers (host alive but slow -- flaky HBM, thermal throttle, noisy
neighbor on the host NIC), (c) whole-pod loss (DCN partition). The policies
here are deliberately *mechanism-level* and runtime-agnostic: the training
driver (launch/train.py) consumes their decisions; tests drive them with a
simulated clock.

The elastic path composes with checkpoint/checkpointer.py: on shrink, the
planner emits a new MeshConfig; restore() re-shards the last complete step
onto the new mesh (checkpoints are mesh-agnostic by design).

Straggler mitigation and the consolidation paper: a straggler is exactly a
server whose *observed* mutual degradation exceeds the model's prediction --
the monitor below reuses the paper's criterion (Eqn 4): hosts whose step
time inflation D = O/(AR+O) exceeds the 50% rule are evicted/replaced, the
same threshold the scheduler uses for admission.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import numpy as np

from ..configs.base import MeshConfig
from ..core.criteria import DEGRADATION_LIMIT, eviction_rate_floor


@dataclasses.dataclass
class HostState:
    host_id: int
    last_heartbeat: float
    step_times: list[float] = dataclasses.field(default_factory=list)
    alive: bool = True


class HeartbeatMonitor:
    """Tracks per-host liveness + step-time statistics."""

    def __init__(self, n_hosts: int, timeout_s: float = 60.0, window: int = 20):
        self.hosts = {i: HostState(i, 0.0) for i in range(n_hosts)}
        self.timeout_s = timeout_s
        self.window = window

    def heartbeat(self, host: int, now: float, step_time: float | None = None):
        h = self.hosts[host]
        h.last_heartbeat = now
        if step_time is not None:
            h.step_times.append(step_time)
            del h.step_times[: -self.window]

    def dead_hosts(self, now: float) -> list[int]:
        return [i for i, h in self.hosts.items()
                if h.alive and now - h.last_heartbeat > self.timeout_s]

    def stragglers(self, limit: float = DEGRADATION_LIMIT) -> list[int]:
        """Hosts whose step-time inflation violates the paper's 50% rule.

        Inflation of host i is measured against the fleet-median step time
        AR: D_i = O_i / (AR + O_i) with O_i = t_i - AR. D_i >= `limit`
        (default 0.5, Eqn 4) marks a straggler -- its presence would double
        the synchronous step time, the same condition under which the paper
        refuses to consolidate. The comparison routes through
        ``criteria.eviction_rate_floor`` -- the same threshold conversion
        the fleet failure detector uses (effective rate med/t_i at or below
        the floor <=> inflation at or past ``limit``) -- so straggler and
        eviction policy share one knob.
        """
        med = np.median([np.mean(h.step_times) for h in self.hosts.values()
                         if h.alive and h.step_times] or [0.0])
        if med <= 0:
            return []
        floor = eviction_rate_floor(limit)
        out = []
        for i, h in self.hosts.items():
            if not h.alive or not h.step_times:
                continue
            t = float(np.mean(h.step_times[-5:]))
            if t > 0 and med / t <= floor:
                out.append(i)
        return out

    def mark_dead(self, host: int):
        self.hosts[host].alive = False


@dataclasses.dataclass(frozen=True)
class ReMeshPlan:
    reason: str
    old: MeshConfig
    new: MeshConfig
    restore_step: str = "latest"  # checkpoint policy

    @property
    def lost_fraction(self) -> float:
        return 1.0 - self.new.n_devices / self.old.n_devices


def plan_elastic_remesh(mesh: MeshConfig, lost_hosts: list[int], hosts_per_pod: int = 32) -> ReMeshPlan | None:
    """Shrink policy: losing any host degrades its whole pod slice (ICI is a
    physical torus -- you cannot route around a missing host), so the unit of
    elasticity is the pod. Multi-pod -> drop the affected pod(s) and continue
    data-parallel on the survivors; single-pod -> halve the data axis (use
    the surviving 8x16 sub-torus)."""
    if not lost_hosts:
        return None
    lost_pods = sorted({h // hosts_per_pod for h in lost_hosts})
    if mesh.multi_pod:
        surviving = mesh.pods - len([p for p in lost_pods if p < mesh.pods])
        if surviving <= 0:
            raise RuntimeError("all pods lost")
        new = dataclasses.replace(mesh, pods=surviving) if surviving > 1 else MeshConfig(
            multi_pod=False, data=mesh.data, model=mesh.model
        )
        return ReMeshPlan(f"lost pods {lost_pods}", mesh, new)
    new = dataclasses.replace(mesh, data=max(1, mesh.data // 2))
    return ReMeshPlan(f"lost hosts {lost_hosts} (single pod: shrink data axis)", mesh, new)


def scale_batch_for_mesh(global_batch: int, old: MeshConfig, new: MeshConfig,
                         keep_global: bool = True) -> int:
    """Elastic batch policy: keep the global batch (per-device batch grows)
    when memory allows, else scale it with the fleet."""
    if keep_global:
        return global_batch
    return max(new.dp, global_batch * new.n_devices // old.n_devices)
