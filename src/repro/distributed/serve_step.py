"""Serving steps: prefill and decode with sharded caches + sampling."""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from ..models.api import Model


def make_serve_steps(model: Model) -> tuple[Callable, Callable]:
    def prefill_step(params, batch, cache):
        logits, cache = model.prefill(params, batch, cache)
        next_tok = jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)
        return next_tok, cache

    def decode_step(params, cache, tokens, rng=None, temperature: float = 0.0):
        logits, cache = model.decode_step(params, cache, tokens)
        last = logits[:, -1, :].astype(jnp.float32)
        if rng is None or temperature == 0.0:
            next_tok = jnp.argmax(last, axis=-1)
        else:
            next_tok = jax.random.categorical(rng, last / temperature, axis=-1)
        return next_tok.astype(jnp.int32), cache

    return prefill_step, decode_step


def greedy_generate(model: Model, params, batch, cache, steps: int):
    """Simple autoregressive loop used by examples/serving driver."""
    prefill_step, decode_step = make_serve_steps(model)
    tok, cache = prefill_step(params, batch, cache)
    toks = [tok]

    def body(carry, _):
        tok, cache = carry
        nxt, cache = decode_step(params, cache, tok[:, None])
        return (nxt, cache), nxt

    (_, cache), rest = jax.lax.scan(body, (tok, cache), None, length=steps - 1)
    return jnp.concatenate([tok[:, None], rest.T], axis=1), cache
