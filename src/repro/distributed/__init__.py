from .fault_tolerance import (
    HeartbeatMonitor,
    ReMeshPlan,
    plan_elastic_remesh,
    scale_batch_for_mesh,
)
from .serve_step import greedy_generate, make_serve_steps
from .sharding import (
    batch_specs,
    cache_specs,
    mesh_axis_sizes,
    named,
    opt_state_specs,
    param_specs,
)
from .train_step import make_train_step
