"""ServerAxis: one switch for dense-replicated vs mesh-sharded server state.

Every layer of the consolidation plane owns at least one ``[m, ...]`` array
(the pairwise-D tables of :class:`~repro.core.binpack_jax.PackedCluster`, the
stacked :class:`EstimatorBank` rows, CUSUM state, per-server metric columns).
At 16 servers those live happily replicated on one device; at 10k the D
stack alone is gigabytes and the Q x m scorer is the per-decision bottleneck.

:class:`ServerAxis` names the policy once so each layer can be written a
single time:

``ServerAxis()`` (dense)
    ``mesh is None``. Every helper is the *identity at trace time* -- no
    ``- 0`` offsets, no size-1 collectives, no ``shard_map`` wrapper. A
    program threaded through a dense axis traces to the byte-identical jaxpr
    of the unthreaded code (the PR 8 ``metrics=None`` off-switch pattern),
    so the single-device path keeps its equivalence oracles, retrace
    guarantees and purity-registry snapshots untouched.

``ServerAxis(mesh=...)`` (sharded)
    ``[m, ...]`` arrays shard on their leading dim over ``mesh.axis``; the
    helpers become real collectives (``lax.pmin``/``psum``/``axis_index``)
    and :meth:`shard_map` wraps the SPMD body. The contract for exactness
    (DESIGN.md section 15): per-server arithmetic is shard-local and
    bitwise-equal to the dense rows, and only *order-insensitive* scalars
    (min / max / single-owner sums) cross the mesh.

The dataclass is frozen and hashable (``jax.sharding.Mesh`` hashes by
value), so an axis rides in ``static_argnames`` of jitted entry points and
in the static ``ClosedLoopConfig``.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import numpy as np
from jax import lax
from jax.sharding import Mesh, PartitionSpec

try:  # jax >= 0.6 exports it at top level
    _shard_map = jax.shard_map  # type: ignore[attr-defined]
except AttributeError:  # 0.4.x: the experimental module, check_rep kwarg
    from jax.experimental.shard_map import shard_map as _shard_map


def _wrap_shard_map(fn: Callable, mesh: Mesh, in_specs, out_specs) -> Callable:
    """Version-portable shard_map. Replication checking is off: the scheduler
    bodies return post-``pmin`` values the checker cannot prove replicated."""
    try:
        return _shard_map(fn, mesh, in_specs=in_specs, out_specs=out_specs,
                          check_rep=False)
    except TypeError:  # newer API: mesh keyword-only, check_vma instead
        return _shard_map(fn, mesh=mesh, in_specs=in_specs,
                          out_specs=out_specs, check_vma=False)


@dataclasses.dataclass(frozen=True)
class ServerAxis:
    """How the server dimension ``m`` is laid out across devices.

    mesh
        ``None`` for the dense-replicated layout; otherwise a
        :class:`jax.sharding.Mesh` whose ``axis`` names the dimension the
        server axis shards over.
    axis
        Mesh axis name carrying server shards.
    pods
        Scheduler pods for hierarchical greedy selection (independent of the
        mesh: a single device may still schedule hierarchically, and each
        shard owns ``pods // shards`` pods). ``1`` disables the hierarchy.
    """

    mesh: Optional[Mesh] = None
    axis: str = "servers"
    pods: int = 1

    # -- layout queries ----------------------------------------------------
    @property
    def is_sharded(self) -> bool:
        return self.mesh is not None

    @property
    def shards(self) -> int:
        return 1 if self.mesh is None else int(self.mesh.shape[self.axis])

    def local_m(self, m: int) -> int:
        return m // self.shards

    def validate(self, m: int) -> "ServerAxis":
        """Divisibility contract: shards | pods | m (each pod whole within
        one shard, each shard an integer number of servers)."""
        if m % max(self.pods, 1):
            raise ValueError(f"m={m} not divisible by pods={self.pods}")
        if self.is_sharded:
            if self.axis not in self.mesh.shape:
                raise ValueError(
                    f"mesh has no axis {self.axis!r}: {self.mesh.shape}")
            if m % self.shards:
                raise ValueError(f"m={m} not divisible by shards={self.shards}")
            if self.pods > 1 and self.pods % self.shards:
                raise ValueError(
                    f"pods={self.pods} not divisible by shards={self.shards}")
        return self

    # -- collectives (identity when dense) ---------------------------------
    # Only call these from code that runs under self.shard_map(...); on the
    # dense axis they return their argument untouched *at trace time* so the
    # dense jaxpr carries no sharding residue.
    def pmin(self, x):
        return lax.pmin(x, self.axis) if self.is_sharded else x

    def pmax(self, x):
        return lax.pmax(x, self.axis) if self.is_sharded else x

    def psum(self, x):
        return lax.psum(x, self.axis) if self.is_sharded else x

    def index(self):
        return lax.axis_index(self.axis) if self.is_sharded else 0

    def offset(self, m_local: int):
        """Global index of this shard's first server (0 when dense)."""
        return lax.axis_index(self.axis) * m_local if self.is_sharded else 0

    def all_gather(self, x, axis: int = 0):
        return (lax.all_gather(x, self.axis, axis=axis, tiled=True)
                if self.is_sharded else x)

    def any(self, x):
        """Global boolean any over the axis (bools psum as i32)."""
        if not self.is_sharded:
            return x
        return lax.psum(x.astype(np.int32), self.axis) > 0

    # -- spec / wrapper helpers --------------------------------------------
    def spec(self, *rest) -> PartitionSpec:
        """PartitionSpec sharding the leading dim (replicated when dense)."""
        if not self.is_sharded:
            return PartitionSpec()
        return PartitionSpec(self.axis, *rest)

    def rep(self) -> PartitionSpec:
        return PartitionSpec()

    def shard_leading(self, tree, m: int):
        """Spec pytree for ``tree``: leaves whose leading dim is ``m`` shard
        on the axis, everything else replicates. The one rule of DESIGN.md
        section 15 -- a new ``[m, ...]`` array picks up the right layout by
        construction."""
        def leaf_spec(x):
            shape = getattr(x, "shape", None)
            if shape and len(shape) >= 1 and shape[0] == m:
                return self.spec()
            return PartitionSpec()
        return jax.tree_util.tree_map(leaf_spec, tree)

    def rep_tree(self, tree):
        """All-replicated spec pytree matching ``tree``."""
        return jax.tree_util.tree_map(lambda _: PartitionSpec(), tree)

    def shard_map(self, fn: Callable, in_specs, out_specs) -> Callable:
        """SPMD-map ``fn`` over the mesh; the dense axis returns ``fn``
        itself (no wrapper, no tracing overhead, byte-identical program)."""
        if not self.is_sharded:
            return fn
        return _wrap_shard_map(fn, self.mesh, in_specs, out_specs)

    def device_put(self, tree, spec_tree):
        """Lay out ``tree`` per ``spec_tree`` (no-op when dense)."""
        if not self.is_sharded:
            return tree
        from jax.sharding import NamedSharding
        return jax.tree_util.tree_map(
            lambda x, s: jax.device_put(x, NamedSharding(self.mesh, s)),
            tree, spec_tree,
            is_leaf=lambda x: isinstance(x, PartitionSpec))

    # -- constructors ------------------------------------------------------
    @classmethod
    def over_host_devices(cls, shards: int, pods: int = 1,
                          axis: str = "servers") -> "ServerAxis":
        """A 1-D mesh over the first ``shards`` local devices. With
        ``XLA_FLAGS=--xla_force_host_platform_device_count=N`` this is the
        CPU multi-device test harness; ``shards=1`` still exercises the
        full shard_map path (size-1 collectives included)."""
        devs = jax.devices()
        if len(devs) < shards:
            raise ValueError(
                f"need {shards} devices, have {len(devs)} "
                "(set --xla_force_host_platform_device_count)")
        mesh = Mesh(np.asarray(devs[:shards]), (axis,))
        return cls(mesh=mesh, axis=axis, pods=pods)


#: The dense-replicated axis: the default everywhere, byte-identical to the
#: pre-ServerAxis program.
DENSE = ServerAxis()
