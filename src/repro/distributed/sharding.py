"""Sharding resolution: logical-axis rules -> NamedSharding pytrees for
params, optimizer state, caches, and input batches."""
from __future__ import annotations

from typing import Any

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec

from ..configs.base import MeshConfig, ModelConfig, batch_axes, sharding_rules
from ..models.api import Model
from ..models.params import abstract, partition_specs


def named(mesh: Mesh, spec_tree):
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s),
        spec_tree,
        is_leaf=lambda x: isinstance(x, PartitionSpec),
    )


def axis_sizes(mesh_cfg: MeshConfig) -> dict[str, int]:
    d = {"data": mesh_cfg.data, "model": mesh_cfg.model}
    if mesh_cfg.multi_pod:
        d["pod"] = mesh_cfg.pods
    return d


def param_specs(model: Model, mesh_cfg: MeshConfig):
    return partition_specs(
        model.param_infos(), sharding_rules(model.cfg, mesh_cfg), axis_sizes(mesh_cfg)
    )


def cache_specs(model: Model, mesh_cfg: MeshConfig, batch: int, max_len: int):
    return partition_specs(
        model.cache_infos(batch, max_len),
        sharding_rules(model.cfg, mesh_cfg),
        axis_sizes(mesh_cfg),
    )


def batch_specs(model: Model, mesh_cfg: MeshConfig, input_specs: dict):
    """PartitionSpecs for a model-input dict: leading dim is the batch
    (replicated when the global batch does not divide the DP axes, e.g.
    long_500k's batch of 1)."""
    b = batch_axes(mesh_cfg)
    dp = mesh_cfg.dp
    out = {}
    for k, v in input_specs.items():
        lead = b if v.shape and v.shape[0] % dp == 0 else None
        out[k] = PartitionSpec(lead, *([None] * (len(v.shape) - 1)))
    return out


def opt_state_specs(opt_init, params_abstract, p_specs):
    """Optimizer-state specs: mirror the param spec where shapes match
    (m/v of AdamW -> ZeRO-1 via the param sharding); for reduced-shape state
    (Adafactor row factors) inherit the param spec as a *prefix* when the
    state shape is a prefix of a param shape; replicate the rest (scalars,
    column factors, quantization scales -- all small by construction)."""
    state_shape = jax.eval_shape(opt_init, params_abstract)

    flat_p, _ = jax.tree_util.tree_flatten(params_abstract)
    flat_s = jax.tree_util.tree_flatten(p_specs,
                                        is_leaf=lambda x: isinstance(x, PartitionSpec))[0]
    by_shape: dict[tuple, PartitionSpec] = {}
    prefixes: list[tuple[tuple, PartitionSpec]] = []
    for p, s in zip(flat_p, flat_s):
        by_shape.setdefault(tuple(p.shape), s)
        prefixes.append((tuple(p.shape), s))

    def spec_for(leaf):
        shape = tuple(leaf.shape)
        if shape in by_shape:
            return by_shape[shape]
        for pshape, pspec in prefixes:
            if len(shape) < len(pshape) and pshape[: len(shape)] == shape:
                return PartitionSpec(*list(pspec)[: len(shape)])
        return PartitionSpec()

    return jax.tree_util.tree_map(spec_for, state_shape)


def mesh_axis_sizes(mesh: Mesh) -> dict[str, int]:
    return dict(zip(mesh.axis_names, mesh.devices.shape))
