"""Gradient utilities: compression with error feedback, bucketing.

Two layers of gradient-bandwidth control (DESIGN.md §5):
  1. *Implicit*: training computes gradients in bf16 (compute_dtype), so the
     GSPMD-inserted data-parallel reduce-scatter/all-reduce payloads are
     already half-width. That is the production default.
  2. *Explicit* (this module): a shard_map-based compressed cross-replica
     mean with error feedback, for the manual-DP path and for int8 payloads
     that GSPMD will not produce on its own. Error feedback keeps the
     quantization noise from biasing SGD: the residual of each step's
     quantization is added back before the next quantization.
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec


def _quantize_int8(x: jax.Array):
    scale = jnp.max(jnp.abs(x)) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def compress(g: jax.Array, method: str, err: jax.Array | None):
    """-> (payload, aux, new_error). err is the error-feedback residual."""
    gf = g.astype(jnp.float32)
    if err is not None:
        gf = gf + err
    if method == "bf16":
        p = gf.astype(jnp.bfloat16)
        return p, None, gf - p.astype(jnp.float32)
    if method == "int8":
        q, s = _quantize_int8(gf)
        return q, s, gf - q.astype(jnp.float32) * s
    return gf, None, jnp.zeros_like(gf) if err is not None else None


def decompress(payload: jax.Array, aux, method: str) -> jax.Array:
    if method == "int8":
        return payload.astype(jnp.float32) * aux
    return payload.astype(jnp.float32)


def compressed_psum_mean(grads, axis_names: tuple[str, ...], method: str = "bf16",
                         errors=None):
    """Cross-replica mean with compressed payload (call inside shard_map).

    Returns (mean_grads_fp32, new_errors). With method='none' this is a plain
    psum-mean.
    """
    n = 1
    for a in axis_names:
        # jax.lax.axis_size is a recent addition; psum(1) is its portable form
        size_of = getattr(jax.lax, "axis_size", None)
        n *= size_of(a) if size_of is not None else jax.lax.psum(1, a)

    def one(g, e):
        p, aux, new_e = compress(g, method, e)
        tot = jax.lax.psum(decompress(p, aux, method), axis_names)
        return tot / n, new_e

    if errors is None:
        errors = jax.tree_util.tree_map(lambda _: None, grads,
                                        is_leaf=lambda x: x is None)
        flat_g, tdef = jax.tree_util.tree_flatten(grads)
        out = [one(g, None) for g in flat_g]
        return (tdef.unflatten([o[0] for o in out]),
                tdef.unflatten([o[1] for o in out]))
    flat_g, tdef = jax.tree_util.tree_flatten(grads)
    flat_e = tdef.flatten_up_to(errors)
    out = [one(g, e) for g, e in zip(flat_g, flat_e)]
    return (tdef.unflatten([o[0] for o in out]),
            tdef.unflatten([o[1] for o in out]))


def bucket_by_size(tree, bucket_bytes: int = 4 << 20):
    """Greedy size-bucketing of leaves (order-preserving) for fused reductions.

    Returns a list of lists of tree paths. Production collectives fire one
    fused reduction per bucket so small tensors amortize latency (the
    classic DDP trick, applied to the manual-DP path).
    """
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    buckets, cur, cur_bytes = [], [], 0
    for path, leaf in flat:
        nbytes = leaf.size * leaf.dtype.itemsize
        if cur and cur_bytes + nbytes > bucket_bytes:
            buckets.append(cur)
            cur, cur_bytes = [], 0
        cur.append(jax.tree_util.keystr(path))
        cur_bytes += nbytes
    if cur:
        buckets.append(cur)
    return buckets
