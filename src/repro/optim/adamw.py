"""Optimizers built from scratch: AdamW, 8-bit AdamW, Adafactor.

All three share one functional interface:
    state = init(params)
    new_params, new_state, stats = update(params, grads, state, lr, cfg)

Memory policy (why three):
  * adamw     -- fp32 m/v; the default for <=10B-param archs.
  * adamw8bit -- int8 block-quantized m/v with per-block scales (beyond-paper
                 distributed-optimization trick: 4x optimizer-state HBM cut,
                 the quantization error is re-absorbed each step because the
                 quantized state is the accumulator).
  * adafactor -- factored second moment (rank-1) for the 1T-param kimi-k2;
                 state is O(rows+cols) instead of O(rows*cols).

Optimizer state inherits each parameter's sharding (ZeRO-1 falls out of the
param partition specs; under FSDP configs the state is sharded over data too).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptConfig:
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    block: int = 256  # 8-bit quantization block size
    # adafactor
    eps2: float = 1e-30
    clip_threshold: float = 1.0


def global_norm(tree) -> jax.Array:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in leaves))


def clip_scale(grads, max_norm: float):
    """Global-norm clip as a lazy scalar: never materializes an fp32 grad
    tree (at 1T params that tree is 16GB/device). Callers fold the scale
    into their per-leaf fused update expressions."""
    gn = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-9))
    return scale, gn


def clip_by_global_norm(grads, max_norm: float):
    scale, gn = clip_scale(grads, max_norm)
    return jax.tree_util.tree_map(lambda g: (g.astype(jnp.float32) * scale), grads), gn


# --- plain AdamW ---------------------------------------------------------------

def adamw_init(params):
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {
        "m": jax.tree_util.tree_map(zeros, params),
        "v": jax.tree_util.tree_map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def adamw_update(params, grads, state, lr, cfg: OptConfig):
    scale, gn = clip_scale(grads, cfg.grad_clip)
    step = state["step"] + 1
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale  # fused into the elementwise update
        m2 = cfg.b1 * m + (1 - cfg.b1) * g
        v2 = cfg.b2 * v + (1 - cfg.b2) * g * g
        mh = m2 / b1c
        vh = v2 / b2c
        d = mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * d).astype(p.dtype), m2, v2

    flat_p, tdef = jax.tree_util.tree_flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_m = tdef.flatten_up_to(state["m"])
    flat_v = tdef.flatten_up_to(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = tdef.unflatten([o[0] for o in out])
    new_m = tdef.unflatten([o[1] for o in out])
    new_v = tdef.unflatten([o[2] for o in out])
    return new_p, {"m": new_m, "v": new_v, "step": step}, {"grad_norm": gn}


# --- 8-bit AdamW ------------------------------------------------------------------

def _q8(x: jax.Array, block: int):
    """Block-wise symmetric int8 quantization of a flat fp32 array."""
    n = x.size
    pad = (-n) % block
    xf = jnp.pad(x.reshape(-1), (0, pad)).reshape(-1, block)
    scale = jnp.max(jnp.abs(xf), axis=1, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def _dq8(q: jax.Array, scale: jax.Array, shape, block: int):
    xf = q.astype(jnp.float32) * scale
    return xf.reshape(-1)[: int(jnp.prod(jnp.asarray(shape)))].reshape(shape)


def adamw8bit_init(params, block: int = 256):
    def q(p):
        qq, s = _q8(jnp.zeros(p.shape, jnp.float32), block)
        return {"q": qq, "s": s}

    return {
        "m": jax.tree_util.tree_map(q, params),
        "v": jax.tree_util.tree_map(q, params),
        "step": jnp.zeros((), jnp.int32),
    }


def adamw8bit_update(params, grads, state, lr, cfg: OptConfig):
    scale, gn = clip_scale(grads, cfg.grad_clip)
    step = state["step"] + 1
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, mq, vq):
        g = g.astype(jnp.float32) * scale
        m = _dq8(mq["q"], mq["s"], p.shape, cfg.block)
        v = _dq8(vq["q"], vq["s"], p.shape, cfg.block)
        m2 = cfg.b1 * m + (1 - cfg.b1) * g
        v2 = cfg.b2 * v + (1 - cfg.b2) * g * g
        mh = m2 / b1c
        vh = jnp.maximum(v2, 0.0) / b2c
        d = mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        p2 = (p.astype(jnp.float32) - lr * d).astype(p.dtype)
        q_m, s_m = _q8(m2, cfg.block)
        q_v, s_v = _q8(v2, cfg.block)
        return p2, {"q": q_m, "s": s_m}, {"q": q_v, "s": s_v}

    flat_p, tdef = jax.tree_util.tree_flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_m = tdef.flatten_up_to(state["m"])
    flat_v = tdef.flatten_up_to(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    return (
        tdef.unflatten([o[0] for o in out]),
        {"m": tdef.unflatten([o[1] for o in out]),
         "v": tdef.unflatten([o[2] for o in out]),
         "step": step},
        {"grad_norm": gn},
    )


# --- Adafactor ----------------------------------------------------------------------

def adafactor_init(params):
    """Factored second moment: row factor over shape[:-1] (inherits the param
    sharding as a prefix -> stays sharded under FSDP/EP), column factor over
    the last dim only (tiny, replicated). Rank-1 reconstruction."""

    def per(p):
        if p.ndim >= 2:
            return {
                "vr": jnp.zeros(p.shape[:-1], jnp.float32),
                "vc": jnp.zeros(p.shape[-1:], jnp.float32),
            }
        return {"v": jnp.zeros(p.shape, jnp.float32)}

    return {"state": jax.tree_util.tree_map(per, params),
            "step": jnp.zeros((), jnp.int32)}


def adafactor_update(params, grads, state, lr, cfg: OptConfig):
    scale, gn = clip_scale(grads, cfg.grad_clip)
    step = state["step"] + 1
    decay = 1.0 - (step.astype(jnp.float32) + 1.0) ** -0.8

    def upd(p, g, s):
        g = g.astype(jnp.float32) * scale
        g2 = g * g + cfg.eps2
        if p.ndim >= 2:
            lead = tuple(range(p.ndim - 1))
            vr = decay * s["vr"] + (1 - decay) * g2.mean(axis=-1)
            vc = decay * s["vc"] + (1 - decay) * g2.mean(axis=lead)
            denom = jnp.maximum(vr.mean(), cfg.eps2)
            vhat = vr[..., None] * vc / denom
            new_s = {"vr": vr, "vc": vc}
        else:
            vhat = decay * s["v"] + (1 - decay) * g2
            new_s = {"v": vhat}
        # relative update clipping (Adafactor's RMS clip), computed as a
        # scalar from g/vhat directly so the fp32 update tensor never
        # materializes (it is re-fused into the final elementwise pass).
        rms = jnp.sqrt(jnp.mean(g2 / (vhat + cfg.eps2)) + 1e-30)
        denom = jnp.maximum(1.0, rms / cfg.clip_threshold)
        u = g / jnp.sqrt(vhat + cfg.eps2) / denom
        d = u + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * d).astype(p.dtype), new_s

    flat_p, tdef = jax.tree_util.tree_flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_s = tdef.flatten_up_to(state["state"])
    out = [upd(p, g, s) for p, g, s in zip(flat_p, flat_g, flat_s)]
    return (
        tdef.unflatten([o[0] for o in out]),
        {"state": tdef.unflatten([o[1] for o in out]), "step": step},
        {"grad_norm": gn},
    )


# --- dispatch --------------------------------------------------------------------------

def make_optimizer(name: str, cfg: OptConfig):
    if name == "adamw":
        return adamw_init, adamw_update
    if name == "adamw8bit":
        return (lambda p: adamw8bit_init(p, cfg.block)), adamw8bit_update
    if name == "adafactor":
        return adafactor_init, adafactor_update
    raise ValueError(f"unknown optimizer {name!r}")
