from .adamw import (
    OptConfig,
    adafactor_init,
    adafactor_update,
    adamw8bit_init,
    adamw8bit_update,
    adamw_init,
    adamw_update,
    clip_by_global_norm,
    global_norm,
    make_optimizer,
)
from .grad_utils import bucket_by_size, compressed_psum_mean
from .schedules import constant, warmup_cosine
