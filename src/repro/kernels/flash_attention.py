"""Flash attention for TPU (pl.pallas_call + explicit BlockSpec VMEM tiling).

Design (TPU-native, not a CUDA port):
  * grid = (heads*batch, q_blocks, kv_blocks); the kv dimension is the
    innermost, *sequential* grid axis, so the online-softmax running state
    (m, l, acc) lives in VMEM scratch and persists across kv steps -- the
    standard TPU flash schedule (sequential grid ≈ a fori loop the Mosaic
    compiler pipelines, with HBM->VMEM block DMA double-buffered for us).
  * BlockSpec tiles: q [1, bq, dh], k/v [1, bk, dh]. bq=bk=256 with dh<=128
    keeps the working set (q + k + v + acc + 2 score tiles) well under 4MB
    of VMEM and the matmul dims MXU-aligned (multiples of 128 where the
    model's dh allows; dh=64/112 archs pay MXU padding, noted in DESIGN.md).
  * causal masking is positional (q_offset supports decode/cache offsets);
    fully-masked kv blocks are skipped via pl.when on the block index.

Validated against kernels/ref.py::attention_ref in interpret mode (CPU), see
tests/test_kernels_flash.py. The jnp production fallback is
models/layers.py::chunked_attention.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(
    q_ref, k_ref, v_ref,  # blocks: [1, bq, dh], [1, bk, dh], [1, bk, dh]
    o_ref,  # [1, bq, dh]
    m_scr, l_scr, acc_scr,  # VMEM scratch: [bq, 1], [bq, 1], [bq, dh]
    *,
    causal: bool,
    sm_scale: float,
    q_offset: int,
    kv_blocks: int,
    block_q: int,
    block_k: int,
):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_pos = q_offset + qi * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
    k_pos = ki * block_k + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)

    def compute():
        q = q_ref[0].astype(jnp.float32)  # [bq, dh]
        k = k_ref[0].astype(jnp.float32)  # [bk, dh]
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ()))) * sm_scale  # [bq, bk]
        if causal:
            s = jnp.where(q_pos >= k_pos, s, NEG_INF)

        m_prev = m_scr[...]  # [bq, 1]
        m_cur = jnp.max(s, axis=1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)  # [bq, bk]
        l_new = alpha * l_scr[...] + jnp.sum(p, axis=1, keepdims=True)
        v = v_ref[0].astype(jnp.float32)
        acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot(p, v)
        m_scr[...] = m_new
        l_scr[...] = l_new

    if causal:
        # skip kv blocks entirely above the diagonal
        first_q = q_offset + qi * block_q
        pl.when(ki * block_k <= first_q + block_q - 1)(compute)
    else:
        compute()

    @pl.when(ki == kv_blocks - 1)
    def _finalize():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, ...] = (acc_scr[...] / l).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("causal", "q_offset", "block_q", "block_k", "interpret")
)
def flash_attention(
    q: jax.Array,  # [N, Sq, dh]   (N = batch*heads, kv already GQA-expanded)
    k: jax.Array,  # [N, Skv, dh]
    v: jax.Array,  # [N, Skv, dh]
    *,
    causal: bool = True,
    q_offset: int = 0,
    block_q: int = 256,
    block_k: int = 256,
    interpret: bool = False,
) -> jax.Array:
    N, Sq, dh = q.shape
    Skv = k.shape[1]
    block_q = min(block_q, Sq)
    block_k = min(block_k, Skv)
    assert Sq % block_q == 0 and Skv % block_k == 0, (Sq, Skv, block_q, block_k)
    grid = (N, Sq // block_q, Skv // block_k)

    kernel = functools.partial(
        _flash_kernel,
        causal=causal,
        sm_scale=1.0 / math.sqrt(dh),
        q_offset=q_offset,
        kv_blocks=grid[2],
        block_q=block_q,
        block_k=block_k,
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, dh), lambda n, qi, ki: (n, qi, 0)),
            pl.BlockSpec((1, block_k, dh), lambda n, qi, ki: (n, ki, 0)),
            pl.BlockSpec((1, block_k, dh), lambda n, qi, ki: (n, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, dh), lambda n, qi, ki: (n, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((N, Sq, dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),  # running max m
            pltpu.VMEM((block_q, 1), jnp.float32),  # running denom l
            pltpu.VMEM((block_q, dh), jnp.float32),  # output accumulator
        ],
        interpret=interpret,
    )(q, k, v)
