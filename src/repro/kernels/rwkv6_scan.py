"""Chunked WKV6 recurrence as a Pallas TPU kernel.

TPU adaptation of RWKV6's sequential recurrence: within a chunk of C tokens
the output is an attention-like triangular matmul (MXU work); across chunks
the per-head state S in R^[dh, dh] persists in VMEM scratch over the
*sequential* chunk grid axis -- so the HBM traffic is one pass over r/k/v/w
and the state never leaves VMEM (dh=64 -> 16KB fp32).

grid = (N, S/C) with N = batch*heads. BlockSpec tiles [1, C, dh] for the four
streams. C=32 keeps the [C, C, dh] decay tensor at 256KB fp32.

Exactness: identical recurrence to ref.py::rwkv6_ref (log-space relative
decays, fp32); validated in tests/test_kernels_rwkv.py.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _wkv_kernel(r_ref, k_ref, v_ref, w_ref, u_ref, s0_ref, y_ref, sT_ref, s_scr, *, chunks):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        s_scr[...] = s0_ref[0].astype(jnp.float32)

    r = r_ref[0].astype(jnp.float32)  # [C, dh]
    k = k_ref[0].astype(jnp.float32)
    v = v_ref[0].astype(jnp.float32)
    w = w_ref[0].astype(jnp.float32)  # log decay, < 0
    u = u_ref[0].astype(jnp.float32)  # [dh]
    s = s_scr[...]  # [dh, dh] (key-dim first)

    C = r.shape[0]
    cl = jnp.cumsum(w, axis=0)  # [C, dh]
    cl_excl = cl - w

    # inter-chunk: y_state[t] = sum_i r[t,i] exp(cl_excl[t,i]) s[i,j]
    r_dec = r * jnp.exp(cl_excl)
    y = jax.lax.dot(r_dec, s)  # [C, dh]

    # intra-chunk: D[t,tau,i] = exp(cl_excl[t,i] - cl[tau,i]) for tau < t.
    # mask in LOG domain: above-diagonal exponents are positive and would
    # overflow exp() (inf would poison the contraction before the tri mask).
    t_idx = jax.lax.broadcasted_iota(jnp.int32, (C, C), 0)
    u_idx = jax.lax.broadcasted_iota(jnp.int32, (C, C), 1)
    tri = u_idx < t_idx
    dlog = cl_excl[:, None, :] - cl[None, :, :]  # [C, C, dh]
    dmat = jnp.exp(jnp.where(tri[:, :, None], dlog, -1e30))
    att = jnp.einsum("ti,tui,ui->tu", r, dmat, k)
    y = y + jax.lax.dot(att, v)
    # bonus diagonal (tau == t)
    y = y + jnp.sum(r * u[None, :] * k, axis=1, keepdims=True) * v

    # state update: s' = exp(cl[-1]) * s + sum_u exp(cl[-1]-cl[u]) k_u v_u^T
    k_dec = k * jnp.exp(cl[-1:, :] - cl)
    s_scr[...] = jnp.exp(cl[-1])[:, None] * s + jax.lax.dot_general(
        k_dec, v, (((0,), (0,)), ((), ()))
    )
    y_ref[0, ...] = y.astype(y_ref.dtype)

    @pl.when(ci == chunks - 1)
    def _done():
        sT_ref[0, ...] = s_scr[...]


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def rwkv6_scan(
    r: jax.Array,  # [N, S, dh]
    k: jax.Array,
    v: jax.Array,
    wlog: jax.Array,  # [N, S, dh], log decay < 0
    u: jax.Array,  # [N, dh] bonus
    s0: jax.Array,  # [N, dh, dh] initial state
    *,
    chunk: int = 32,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    N, S, dh = r.shape
    chunk = min(chunk, S)
    assert S % chunk == 0, (S, chunk)
    grid = (N, S // chunk)
    kernel = functools.partial(_wkv_kernel, chunks=grid[1])
    stream = pl.BlockSpec((1, chunk, dh), lambda n, c: (n, c, 0))
    y, sT = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            stream, stream, stream, stream,
            pl.BlockSpec((1, dh), lambda n, c: (n, 0)),
            pl.BlockSpec((1, dh, dh), lambda n, c: (n, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, dh), lambda n, c: (n, c, 0)),
            pl.BlockSpec((1, dh, dh), lambda n, c: (n, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((N, S, dh), jnp.float32),
            jax.ShapeDtypeStruct((N, dh, dh), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((dh, dh), jnp.float32)],
        interpret=interpret,
    )(r, k, v, wlog, u, s0)
    return y, sT
