"""Batched pair-statistic scatter-accumulation as a Pallas TPU kernel.

The telemetry estimator (``repro.telemetry.estimator``) reduces every batch
of completion observations to per-pair sufficient statistics: for a batch of
B observations -- target grid type ``t_b``, co-resident exposure row
``cbar_b`` [T], and a scalar statistic ``v_b`` (a normalized residual, a
confidence weight, ...) -- it needs

  pair[u, t] = sum_b cbar_b[u] * v_b * 1{t_b == t}        [T, T]
  base[t]    = sum_b          v_b * 1{t_b == t}           [T]

i.e. a scatter over the *target-type column* with the co-resident row as the
update. At fleet scale this runs once per trace segment over thousands of
observations with T = 230, so the batch is streamed through the MXU as a
[T, Bb] x [Bb, T] contraction per block instead of a python-level scatter:
the one-hot column selector turns the scatter into a matmul, and the [T, T]
output block stays resident in VMEM across the whole batch (the grid walks
the batch axis only, revisiting the same output tile).

Validated against the float64 numpy reference ``kernels.ref.pair_scatter_ref``
in tests/test_kernels.py. Out-of-range types (e.g. the -1 padding the wrapper
adds to fill the last block) select no column and contribute nothing, exactly
like the reference's explicit skip.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _pair_scatter_kernel(types_ref, cbar_ref, vals_ref, pair_ref, base_ref):
    b = pl.program_id(0)

    types = types_ref[:, 0]  # [Bb] i32
    vals = vals_ref[:, 0].astype(jnp.float32)  # [Bb]
    cbar = cbar_ref[...].astype(jnp.float32)  # [Bb, T]
    Bb, T = cbar.shape

    # one-hot target-type selector; padding types (< 0 or >= T) select nothing
    onehot = (
        jax.lax.broadcasted_iota(jnp.int32, (Bb, T), 1) == types[:, None]
    ).astype(jnp.float32)
    sel = onehot * vals[:, None]  # [Bb, T]

    @pl.when(b == 0)
    def _init():
        pair_ref[...] = jnp.zeros_like(pair_ref)
        base_ref[...] = jnp.zeros_like(base_ref)

    # cbar^T @ sel: contract the batch axis on the MXU -> [T, T] column scatter
    pair_ref[...] += jax.lax.dot_general(
        cbar, sel, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    base_ref[...] += jnp.sum(sel, axis=0, keepdims=True)


@functools.partial(jax.jit, static_argnames=("block_b", "interpret"))
def pair_scatter(
    types: jax.Array,  # i32[B] target grid type per observation
    cbar: jax.Array,  # f32[B, T] co-resident exposure rows
    vals: jax.Array,  # f32[B] scalar statistic per observation
    *,
    block_b: int = 128,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """(pair [T, T], base [T]) sufficient statistics for one observation batch."""
    B, T = cbar.shape
    if B == 0:  # match the jnp/numpy backends of the contract
        return jnp.zeros((T, T), jnp.float32), jnp.zeros((T,), jnp.float32)
    Bb = min(block_b, B)
    pad = (-B) % Bb
    if pad:
        # padded rows carry type -1: the one-hot selector drops them
        types = jnp.concatenate([types, jnp.full((pad,), -1, types.dtype)])
        cbar = jnp.concatenate([cbar, jnp.zeros((pad, T), cbar.dtype)])
        vals = jnp.concatenate([vals, jnp.zeros((pad,), vals.dtype)])
    nb = (B + pad) // Bb

    pair, base = pl.pallas_call(
        _pair_scatter_kernel,
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((Bb, 1), lambda i: (i, 0)),
            pl.BlockSpec((Bb, T), lambda i: (i, 0)),
            pl.BlockSpec((Bb, 1), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((T, T), lambda i: (0, 0)),
            pl.BlockSpec((1, T), lambda i: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((T, T), jnp.float32),
            jax.ShapeDtypeStruct((1, T), jnp.float32),
        ],
        interpret=interpret,
    )(types.reshape(-1, 1).astype(jnp.int32),
      cbar.astype(jnp.float32),
      vals.reshape(-1, 1).astype(jnp.float32))
    return pair, base[0]
