"""Batched pair-statistic scatter-accumulation as a Pallas TPU kernel.

The telemetry estimator (``repro.telemetry.estimator``) reduces every batch
of completion observations to per-pair sufficient statistics: for a batch of
B observations -- target grid type ``t_b``, co-resident exposure row
``cbar_b`` [T], and K scalar statistics ``v_b^k`` per observation (the
residual numerator and the exposure weight of one LMS step, stacked) -- it
needs, for every statistic k,

  pair[k, u, t] = sum_b cbar_b[u] * v_b^k * 1{t_b == t}        [K, T, T]
  base[k, t]    = sum_b            v_b^k * 1{t_b == t}         [K, T]

i.e. a scatter over the *target-type column* with the co-resident row as the
update. At fleet scale this runs once per trace segment over thousands of
observations with T = 230, so the batch is streamed through the MXU as a
[T, Bb] x [Bb, T] contraction per (block, statistic) instead of a
python-level scatter: the one-hot column selector turns the scatter into a
matmul, and the [K, T, T] output block stays resident in VMEM across the
whole batch (the grid walks the batch axis only, revisiting the same output
tile). Stacking the K statistics amortizes the batch stream: the one-hot
selector is built once per block and every statistic reuses it -- the
estimator's residual numerator and exposure weight ride one pass where they
used to take two kernel launches.

Validated against the float64 numpy reference ``kernels.ref.pair_scatter_ref``
in tests/test_kernels.py. Out-of-range types (e.g. the -1 padding the wrapper
adds to fill the last block, or rows a validity mask voided upstream) select
no column and contribute nothing, exactly like the reference's explicit skip.

Index-space contract: the scatter is agnostic to what its row indices *mean*.
The estimator bank feeds it per-server splits, and since the fleet-health
subsystem (``repro.fleet``) those indices are **pool ids** -- several servers
remapped onto one shared estimator row (``EstimatorBank.update_device(...,
row_map=...)``) -- so a pooled row's statistics accumulate every member's
observations in the same pass. Rows remapped to -1 (evicted servers) ride the
same out-of-range drop as padding. Indices *past* the table (>= T) are also
dropped by the kernel, but no well-formed caller produces them -- in debug
mode (``debug=True``, defaulting to ``interpret``) the wrapper pulls the
eager types to the host and raises on any type >= T before launch.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl


def _pair_scatter_kernel(types_ref, cbar_ref, vals_ref, pair_ref, base_ref):
    b = pl.program_id(0)

    types = types_ref[:, 0]  # [Bb] i32
    vals = vals_ref[...].astype(jnp.float32)  # [Bb, K]
    cbar = cbar_ref[...].astype(jnp.float32)  # [Bb, T]
    Bb, T = cbar.shape
    K = vals.shape[1]

    # one-hot target-type selector; padding types (< 0 or >= T) select nothing
    onehot = (
        jax.lax.broadcasted_iota(jnp.int32, (Bb, T), 1) == types[:, None]
    ).astype(jnp.float32)

    @pl.when(b == 0)
    def _init():
        pair_ref[...] = jnp.zeros_like(pair_ref)
        base_ref[...] = jnp.zeros_like(base_ref)

    # base[k, t] += sum_b vals[b, k] 1{t_b = t}: one [K, Bb] x [Bb, T] MXU pass
    base_ref[...] += jax.lax.dot_general(
        vals, onehot, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    # cbar^T @ (onehot * v_k): contract the batch axis on the MXU per statistic
    # -> K [T, T] column scatters sharing one selector build (K is static and
    # small -- 1 or 2 in the estimator -- so the unrolled loop costs nothing)
    for k in range(K):
        pair_ref[k] += jax.lax.dot_general(
            cbar, onehot * vals[:, k][:, None], (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)


def pair_scatter(
    types: jax.Array,  # i32[B] target grid type per observation
    cbar: jax.Array,  # f32[B, T] co-resident exposure rows
    vals: jax.Array,  # f32[B] or f32[K, B]: K stacked statistics per observation
    *,
    block_b: int = 128,
    interpret: bool = False,
    debug: "bool | None" = None,
) -> tuple[jax.Array, jax.Array]:
    """Sufficient statistics for one observation batch.

    ``vals`` of shape [B] returns ``(pair [T, T], base [T])`` (the original
    single-statistic contract); [K, B] returns ``(pair [K, T, T], base
    [K, T])`` with all K statistics accumulated in one batch stream.

    ``debug`` (defaults to ``interpret``) enforces the index-space contract
    before launch: *negative* types are part of the contract -- padding rows
    and evicted pool ids deliberately select no column -- but a type ``>= T``
    is never produced by a well-formed caller; it means a pool id or grid
    type was misrouted past the table, and the silent-drop semantics would
    swallow that observation. The check pulls ``types`` to the host, so it
    only runs eagerly (skipped under an enclosing trace) and only when
    ``debug`` is on.
    """
    if debug is None:
        debug = interpret
    if debug and not isinstance(types, jax.core.Tracer):
        T = cbar.shape[1]
        t = np.asarray(types)
        if t.size and int(t.max(initial=-1)) >= T:
            bad = int(np.argmax(t >= T))
            raise ValueError(
                f"pair_scatter index-space contract violated: types[{bad}] = "
                f"{int(t[bad])} >= T = {T}. Negative types (padding / evicted "
                f"pool rows) are dropped by design, but an index past the "
                f"table means a misrouted pool id or grid type -- the scatter "
                f"would silently discard that observation.")
    return _pair_scatter_impl(
        types, cbar, vals, block_b=block_b, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("block_b", "interpret"))
def _pair_scatter_impl(
    types: jax.Array,
    cbar: jax.Array,
    vals: jax.Array,
    *,
    block_b: int = 128,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    B, T = cbar.shape
    squeeze = vals.ndim == 1
    vals2 = vals[None, :] if squeeze else vals  # [K, B]
    K = vals2.shape[0]
    if B == 0:  # match the jnp/numpy backends of the contract
        pair = jnp.zeros((K, T, T), jnp.float32)
        base = jnp.zeros((K, T), jnp.float32)
        return (pair[0], base[0]) if squeeze else (pair, base)
    vals_bk = vals2.T.astype(jnp.float32)  # [B, K] batch-major for blocking
    Bb = min(block_b, B)
    pad = (-B) % Bb
    if pad:
        # padded rows carry type -1: the one-hot selector drops them
        types = jnp.concatenate([types, jnp.full((pad,), -1, types.dtype)])
        cbar = jnp.concatenate([cbar, jnp.zeros((pad, T), cbar.dtype)])
        vals_bk = jnp.concatenate([vals_bk, jnp.zeros((pad, K), vals_bk.dtype)])
    nb = (B + pad) // Bb

    pair, base = pl.pallas_call(
        _pair_scatter_kernel,
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((Bb, 1), lambda i: (i, 0)),
            pl.BlockSpec((Bb, T), lambda i: (i, 0)),
            pl.BlockSpec((Bb, K), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((K, T, T), lambda i: (0, 0, 0)),
            pl.BlockSpec((K, T), lambda i: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((K, T, T), jnp.float32),
            jax.ShapeDtypeStruct((K, T), jnp.float32),
        ],
        interpret=interpret,
    )(types.reshape(-1, 1).astype(jnp.int32),
      cbar.astype(jnp.float32),
      vals_bk)
    return (pair[0], base[0]) if squeeze else (pair, base)
