"""The paper's greedy-placement scoring loop as a Pallas TPU kernel.

This is the consolidation scheduler's hot spot at fleet scale: for each of Q
queued workloads, score all m servers by tentatively placing the workload
(Fig 8 steps 2-3): cache_in_use' and Max(D_y)' under the additive model
(Eqn 3). The Python/jnp paths (core/binpack*.py) evaluate one candidate at a
time; this kernel batches Q x m candidate evaluations with the profiled
D-matrix tile [T, T] resident in VMEM (T=230 -> 212KB fp32) while the
candidate axis streams -- one D fetch per server for the whole queue.

grid = (m, Q); per step: counts row [T], D tile [T, T], grid-constant rs/fs.
out: cache_after [Q, m], maxd_after [Q, m] -- argmin over the feasible set
happens outside (cheap [Q, m] reduction).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _score_kernel(counts_ref, d_ref, diag_ref, rsfs_ref, budget_ref, wtype_ref,
                  cache_ref, maxd_ref):
    counts = counts_ref[0].astype(jnp.float32)  # [T]
    D = d_ref[0].astype(jnp.float32)  # [T, T]
    diag = diag_ref[0].astype(jnp.float32)  # [T]
    rs = rsfs_ref[0, 0]  # [T]
    fs_res = rsfs_ref[0, 1]  # [T] fs * resident mask (0 where non-competing)
    budget = budget_ref[0, 0]
    t_new = wtype_ref[0, 0]

    T = counts.shape[0]
    onehot = (jax.lax.broadcasted_iota(jnp.int32, (T,), 0) == t_new).astype(jnp.float32)
    c = counts + onehot

    comp = jnp.sum(c * rs) + jnp.sum(c * fs_res)
    cache_ref[0, 0] = comp / budget

    col = jax.lax.dot_general(c[None, :], D, (((1,), (0,)), ((), ())))[0]  # c @ D
    d_pred = jnp.clip(col - diag, 0.0, 1.0)
    present = c > 0
    maxd_ref[0, 0] = jnp.max(jnp.where(present, d_pred, -jnp.inf))


@functools.partial(jax.jit, static_argnames=("interpret",))
def consolidation_scores(
    counts: jax.Array,  # [m, T] resident workload counts per server
    D: jax.Array,  # [m, T, T] profiled pairwise degradations
    rs: jax.Array,  # [T] request sizes (bytes)
    fs_resident: jax.Array,  # [m, T] fs * (fs <= llc) per server
    llc_budget: jax.Array,  # [m] alpha * CacheSize
    wtypes: jax.Array,  # [Q] candidate grid types (int32)
    *,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    m, T = counts.shape
    Q = wtypes.shape[0]
    diag = jnp.diagonal(D, axis1=1, axis2=2)  # [m, T]
    rsfs = jnp.stack([jnp.broadcast_to(rs, (m, T)), fs_resident], axis=1)  # [m, 2, T]
    budget = llc_budget.reshape(m, 1).astype(jnp.float32)
    wt = wtypes.reshape(Q, 1).astype(jnp.int32)

    cache, maxd = pl.pallas_call(
        _score_kernel,
        grid=(m, Q),
        in_specs=[
            pl.BlockSpec((1, T), lambda s, q: (s, 0)),
            pl.BlockSpec((1, T, T), lambda s, q: (s, 0, 0)),
            pl.BlockSpec((1, T), lambda s, q: (s, 0)),
            pl.BlockSpec((1, 2, T), lambda s, q: (s, 0, 0)),
            pl.BlockSpec((1, 1), lambda s, q: (s, 0)),
            pl.BlockSpec((1, 1), lambda s, q: (q, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1), lambda s, q: (q, s)),
            pl.BlockSpec((1, 1), lambda s, q: (q, s)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Q, m), jnp.float32),
            jax.ShapeDtypeStruct((Q, m), jnp.float32),
        ],
        interpret=interpret,
    )(counts.astype(jnp.float32), D.astype(jnp.float32), diag.astype(jnp.float32),
      rsfs.astype(jnp.float32), budget, wt)
    return cache, maxd
