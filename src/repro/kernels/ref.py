"""Pure-jnp oracles for every Pallas kernel (the ``ref.py`` of each kernel).

These are the ground truth the kernels are validated against
(tests/test_kernels_*.py sweep shapes/dtypes and assert_allclose).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
import numpy as np


def attention_ref(q, k, v, *, causal=True, q_offset=0):
    """q: [N, Sq, dh]; k, v: [N, Skv, dh] -> [N, Sq, dh]. fp32 softmax."""
    N, Sq, dh = q.shape
    Skv = k.shape[1]
    s = jnp.einsum("nqd,ntd->nqt", q.astype(jnp.float32), k.astype(jnp.float32))
    s = s / math.sqrt(dh)
    if causal:
        qp = q_offset + jnp.arange(Sq)[:, None]
        kp = jnp.arange(Skv)[None, :]
        s = jnp.where(qp >= kp, s, -1e30)
    w = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("nqt,ntd->nqd", w, v.astype(jnp.float32)).astype(q.dtype)


def rwkv6_ref(r, k, v, wlog, u, s0):
    """Sequential WKV6 recurrence (the definition). All [N, S, dh] + u [N, dh],
    s0 [N, dh, dh] (key dim first). Returns (y [N, S, dh], sT)."""
    N, S, dh = r.shape

    def step(s, t):
        rt, kt, vt, wt = r[:, t], k[:, t], v[:, t], wlog[:, t]
        # y_t[j] = sum_i r[i] * (s[i,j] + u[i] k[i] v[j])
        y = jnp.einsum("ni,nij->nj", rt, s) + jnp.einsum("ni,ni,ni,nj->nj", rt, u, kt, vt)
        s = jnp.exp(wt)[:, :, None] * s + kt[:, :, None] * vt[:, None, :]
        return s, y

    s = s0.astype(jnp.float32)
    ys = []
    for t in range(S):  # python loop: this is an oracle, clarity over speed
        s, y = step(s, t)
        ys.append(y)
    return jnp.stack(ys, axis=1), s


def mamba_ref(da, dbu, c):
    """Sequential selective-scan recurrence.
    da, dbu: [B, S, E, N]; c: [B, S, N]. Returns (y [B, S, E], hT [B, E, N])."""
    B, S, E, N = da.shape
    h = jnp.zeros((B, E, N), jnp.float32)
    ys = []
    for t in range(S):
        h = da[:, t] * h + dbu[:, t]
        ys.append(jnp.einsum("ben,bn->be", h, c[:, t]))
    return jnp.stack(ys, axis=1), h


def pair_scatter_ref(types, cbar, vals):
    """Pair-statistic scatter accumulation (telemetry estimator), float64.

    types i32[B]; cbar [B, T]; vals [B] or [K, B] (K stacked statistics).
    Returns (pair [T, T], base [T]) for 1-D vals, (pair [K, T, T], base
    [K, T]) for stacked, with per statistic k
      pair[k, u, t] = sum_b cbar[b, u] * vals[k, b] * 1{types[b] == t}
      base[k, t]    = sum_b             vals[k, b] * 1{types[b] == t}.
    Out-of-range types (padding, masked-invalid rows) contribute nothing.
    """
    cbar = np.asarray(cbar, np.float64)
    vals = np.asarray(vals, np.float64)
    types = np.asarray(types)
    squeeze = vals.ndim == 1
    vals = np.atleast_2d(vals)  # [K, B]
    K = vals.shape[0]
    B, T = cbar.shape
    pair = np.zeros((K, T, T))
    base = np.zeros((K, T))
    for b in range(B):
        t = int(types[b])
        if not 0 <= t < T:
            continue
        for k in range(K):
            pair[k, :, t] += cbar[b] * vals[k, b]
            base[k, t] += vals[k, b]
    return (pair[0], base[0]) if squeeze else (pair, base)


def consolidation_scores_ref(counts, D, rs, fs, llc_budget, resident, wtypes):
    """Greedy candidate scoring (the paper's Fig-8 inner loop), per candidate.

    counts [m, T]; D [m, T, T]; rs/fs [T]; llc_budget [m]; resident [m, T];
    wtypes [Q]. Returns (cache_after [Q, m], maxd_after [Q, m]).
    """
    m, T = counts.shape
    Q = wtypes.shape[0]
    cache = np.zeros((Q, m))
    maxd = np.zeros((Q, m))
    counts = np.asarray(counts, np.float64)
    D = np.asarray(D, np.float64)
    for qi, t in enumerate(np.asarray(wtypes)):
        for s in range(m):
            c = counts[s].copy()
            c[t] += 1
            comp = (c * rs).sum() + (c * resident[s] * fs).sum()
            cache[qi, s] = comp / llc_budget[s]
            col = c @ D[s] - np.diagonal(D[s])
            col = np.clip(col, 0.0, 1.0)
            present = c > 0
            maxd[qi, s] = col[present].max() if present.any() else 0.0
    return jnp.asarray(cache, jnp.float32), jnp.asarray(maxd, jnp.float32)
