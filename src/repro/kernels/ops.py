"""Jit'd public wrappers for the Pallas kernels (the ``ops.py`` layer).

These adapt model-layer tensor layouts to kernel layouts (GQA expansion,
head flattening) and select the execution mode: 'tpu' (real Mosaic lowering),
'interpret' (kernel body executed in Python on CPU -- how this container
validates correctness), or 'jnp' (the pure-jnp reference path the production
models default to off-TPU).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import ref
from .consolidation import consolidation_scores
from .flash_attention import flash_attention
from .mamba_scan import mamba_scan
from .rwkv6_scan import rwkv6_scan
from .telemetry import pair_scatter


def _mode_kwargs(mode: str) -> dict:
    if mode == "tpu":
        return {"interpret": False}
    if mode == "interpret":
        return {"interpret": True}
    raise ValueError(f"mode must be tpu|interpret (got {mode!r}); use *_ref for jnp")


def gqa_flash_attention(
    q: jax.Array,  # [B, Sq, H, dh]
    k: jax.Array,  # [B, Skv, Hkv, dh]
    v: jax.Array,  # [B, Skv, Hkv, dh]
    *,
    causal: bool = True,
    q_offset: int = 0,
    mode: str = "interpret",
    block_q: int = 256,
    block_k: int = 256,
) -> jax.Array:
    """Model-layout wrapper: expands GQA kv heads and flattens (B, H)->N."""
    B, Sq, H, dh = q.shape
    Hkv = k.shape[2]
    G = H // Hkv
    kx = jnp.repeat(k, G, axis=2).transpose(0, 2, 1, 3).reshape(B * H, -1, dh)
    vx = jnp.repeat(v, G, axis=2).transpose(0, 2, 1, 3).reshape(B * H, -1, dh)
    qx = q.transpose(0, 2, 1, 3).reshape(B * H, Sq, dh)
    out = flash_attention(
        qx, kx, vx, causal=causal, q_offset=q_offset,
        block_q=block_q, block_k=block_k, **_mode_kwargs(mode),
    )
    return out.reshape(B, H, Sq, dh).transpose(0, 2, 1, 3)


def rwkv6_wkv(
    r: jax.Array,  # [B, S, H, dh]
    k: jax.Array,
    v: jax.Array,
    wlog: jax.Array,
    u: jax.Array,  # [H, dh]
    s0: jax.Array,  # [B, H, dh, dh]
    *,
    chunk: int = 32,
    mode: str = "interpret",
) -> tuple[jax.Array, jax.Array]:
    B, S, H, dh = r.shape
    fold = lambda x: x.transpose(0, 2, 1, 3).reshape(B * H, S, dh)
    y, sT = rwkv6_scan(
        fold(r), fold(k), fold(v), fold(wlog),
        jnp.broadcast_to(u[None], (B, H, dh)).reshape(B * H, dh),
        s0.reshape(B * H, dh, dh),
        chunk=chunk, **_mode_kwargs(mode),
    )
    return (y.reshape(B, H, S, dh).transpose(0, 2, 1, 3), sT.reshape(B, H, dh, dh))


def mamba_ssm_scan(
    da: jax.Array, dbu: jax.Array, c: jax.Array, h0: jax.Array,
    *, chunk: int = 64, eblock: int = 512, mode: str = "interpret",
) -> tuple[jax.Array, jax.Array]:
    return mamba_scan(da, dbu, c, h0, chunk=chunk, eblock=eblock, **_mode_kwargs(mode))


def greedy_scores(
    counts, D, rs, fs_resident, llc_budget, wtypes, *, mode: str = "interpret"
):
    return consolidation_scores(
        counts, D, rs, fs_resident, llc_budget, wtypes, **_mode_kwargs(mode)
    )


def telemetry_pair_scatter(types, cbar, vals, *, mode: str = "interpret"):
    """Pair-statistic scatter; ``vals`` [B] or [K, B] (K stacked statistics
    accumulated in one batch stream -- see ``kernels.telemetry``)."""
    return pair_scatter(types, cbar, vals, **_mode_kwargs(mode))
