"""Selective-SSM (Mamba/S6) scan as a Pallas TPU kernel.

TPU adaptation: the recurrence h_t = da_t * h_{t-1} + dbu_t is elementwise
over [E, N] state, so the kernel's job is *bandwidth*, not MXU: stream
da/dbu/C chunks HBM->VMEM once, keep the [eb, N] state slice resident in
VMEM scratch across the sequential chunk axis, and emit y. Channel blocking
(eb) makes the state slice + chunk working set fit VMEM for any d_inner.

grid = (B, E/eb, S/C); the chunk axis is innermost/sequential.
Block working set at eb=512, C=64, N=16: da+dbu 2 x 512KB + state 32KB.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _mamba_kernel(da_ref, dbu_ref, c_ref, h0_ref, y_ref, hT_ref, h_scr, *, chunks, chunk):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        h_scr[...] = h0_ref[0].astype(jnp.float32)

    da = da_ref[0].astype(jnp.float32)  # [C, eb, N]
    dbu = dbu_ref[0].astype(jnp.float32)  # [C, eb, N]
    c = c_ref[0].astype(jnp.float32)  # [C, N]

    def step(t, carry):
        h, y = carry
        h = da[t] * h + dbu[t]  # [eb, N]
        y = y.at[t].set(jnp.sum(h * c[t][None, :], axis=1))
        return h, y

    y0 = jnp.zeros((chunk, da.shape[1]), jnp.float32)
    h, y = jax.lax.fori_loop(0, chunk, step, (h_scr[...], y0))
    h_scr[...] = h
    y_ref[0, ...] = y.astype(y_ref.dtype)

    @pl.when(ci == chunks - 1)
    def _done():
        hT_ref[0, ...] = h


@functools.partial(jax.jit, static_argnames=("chunk", "eblock", "interpret"))
def mamba_scan(
    da: jax.Array,  # [B, S, E, N]  exp(delta*A)
    dbu: jax.Array,  # [B, S, E, N]  delta*B*u
    c: jax.Array,  # [B, S, N]
    h0: jax.Array,  # [B, E, N]
    *,
    chunk: int = 64,
    eblock: int = 512,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    B, S, E, N = da.shape
    chunk = min(chunk, S)
    eblock = min(eblock, E)
    assert S % chunk == 0 and E % eblock == 0, (S, chunk, E, eblock)
    grid = (B, E // eblock, S // chunk)
    kernel = functools.partial(_mamba_kernel, chunks=grid[2], chunk=chunk)
    y, hT = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, eblock, N), lambda b, e, ci: (b, ci, e, 0)),
            pl.BlockSpec((1, chunk, eblock, N), lambda b, e, ci: (b, ci, e, 0)),
            pl.BlockSpec((1, chunk, N), lambda b, e, ci: (b, ci, 0)),
            pl.BlockSpec((1, eblock, N), lambda b, e, ci: (b, e, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, eblock), lambda b, e, ci: (b, ci, e)),
            pl.BlockSpec((1, eblock, N), lambda b, e, ci: (b, e, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, S, E), jnp.float32),
            jax.ShapeDtypeStruct((B, E, N), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((eblock, N), jnp.float32)],
        interpret=interpret,
    )(da, dbu, c, h0)
    return y, hT
