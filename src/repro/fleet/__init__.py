"""Fleet health: pooled estimation, drift detection, failure-driven eviction.

The control plane above the telemetry loop (DESIGN.md §11). PR 2/4 gave
every server its own online estimator and a fused device path to update them
all at once; this package decides *which servers should share a model* and
*which servers should stop receiving work*:

  pool        ``PooledEstimatorBank`` -- same-spec servers share one
              estimator row via a device-side server -> row map (pooling as
              index remapping over the PR 4 ``EstimatorBank``), warming up
              ~m x faster; splits re-route a server to its own row seeded
              with the pool posterior.
  detect      ``DriftDetector`` -- a jitted, chunk-invariant CUSUM over each
              server's residual stream against its pool's model, plus an
              exposure-weighted residual level for failure detection, both
              thresholded through ``criteria.eviction_rate_floor``.
  controller  ``FleetController`` -- consumes each segment's telemetry
              block, applies splits, and evicts failing servers: placement
              mask (candidate scoring refuses them), pool routing dropped,
              ``HeartbeatMonitor.mark_dead`` + ``plan_elastic_remesh``
              notified, in-flight work requeued by ``AdaptiveEngine``.

Driven end to end by ``AdaptiveEngine(fleet=FleetController(...))`` and
benchmarked by ``benchmarks/fleet_health.py`` (pooled-vs-per-server warm-up
across hardware heterogeneity, split latency under multi-tenant noise, and
the gradual-decay eviction trace).
"""
from .controller import FleetController, HealthEvent
from .detect import CusumState, DriftDetector
from .pool import PooledEstimatorBank

__all__ = [
    "CusumState",
    "DriftDetector",
    "FleetController",
    "HealthEvent",
    "PooledEstimatorBank",
]
