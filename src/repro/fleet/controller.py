"""FleetController: the estimate -> diagnose -> act control plane.

``fleet.pool`` decides which servers share a model; ``fleet.detect`` notices
when that stops being true, or when a server stops being viable at all. This
module closes the loop: it consumes each segment's device-resident telemetry
block, updates the pooled estimators and the detector in the same pass, and
turns detector signals into fleet actions:

  split   a pooled server whose CUSUM crossed ``h`` is re-routed to its own
          estimator row, seeded with the pool posterior (it keeps the pool's
          warm-up, loses its noise), and its detector rows reset.
  evict   a server failing either failure test leaves the fleet: its pool
          routing is dropped, its placement mask goes False (candidate
          scoring in the engine treats it as infeasible --
          ``ConsolidationEngine.set_active``), the fault-tolerance plane is
          notified (``HeartbeatMonitor.mark_dead``; with a ``mesh``, a
          ``plan_elastic_remesh`` shrink plan is recorded and applied), and
          the driving ``AdaptiveEngine`` requeues the work it had in flight.

Two failure routes cover both pool topologies, both against the single
threshold of ``criteria.eviction_rate_floor``:

  level  the detector's residual level, measured against the **fleet
         median** level -- precisely ``HeartbeatMonitor.stragglers``'s rule
         (slower than ``1/(1 - limit)`` x the fleet median marks you dead)
         transported from step times to telemetry residuals. The relative
         form makes the route immune to fleet-wide model misfit (a cold
         prior warming up, a drift hitting everyone); like the straggler
         rule, it goes blind if the *whole* fleet fails at once.
  base   the server's *own* estimated base rate at or below ``fail_floor``
         x the nominal prior -- the absolute backstop for servers with a
         *private* estimator row (a pooled row's ratio is shared by every
         member, so it cannot single one out); it needs solo observations
         to move. Whatever fires, the controller never evicts the last
         active server -- a sick fleet still beats an empty one.

The controller is deliberately host-side policy over device-side mechanism:
one fused bank update + one fused detector update per segment, then a few
[m]-sized host reads to make decisions. It binds late (``bind``): construct
it with policy knobs, hand it to ``AdaptiveEngine(fleet=...)``, and the
engine binds it to the fleet's servers and estimators it already builds.
"""
from __future__ import annotations

import dataclasses
import math
from typing import TYPE_CHECKING, Hashable, Literal, NamedTuple, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import MeshConfig
from ..core.criteria import eviction_rate_floor
from ..core.server import ServerSpec
from ..telemetry.estimator import DeviceEstimatorState, StreamingEstimator
from ..telemetry.log import RingBlock
from .detect import CusumState, DriftDetector
from .pool import PooledEstimatorBank

if TYPE_CHECKING:  # deferred: distributed.__init__ imports back into fleet
    from ..distributed.fault_tolerance import HeartbeatMonitor, ReMeshPlan


@jax.jit
def _base_ratio(log_b, n_base, priors, read_row, min_exposure):
    """Per-server base-rate / nominal-prior ratio, on device.

    ``log_b``/``n_base``/``priors`` are bank-row tables [rows, T];
    ``read_row`` i32[m] maps each server to the row it reads (its pool's
    leader, or its own). The ratio is the solo-exposure-weighted geometric
    mean of ``exp(log_b - prior)`` per type; rows with total exposure under
    ``min_exposure`` report 1.0 (no evidence = healthy).
    """
    lb, w, pr = log_b[read_row], n_base[read_row], priors[read_row]  # [m, T]
    tot = w.sum(axis=1)
    ratio = jnp.exp((w * (lb - pr)).sum(axis=1) / jnp.maximum(tot, 1e-12))
    return jnp.where(tot >= min_exposure, ratio, 1.0)


class FleetStepOut(NamedTuple):
    """One traced controller step's outcome (see :func:`fleet_step`)."""

    bank: DeviceEstimatorState  # post-action stacked bank [m rows]
    det: CusumState  # post-action detector state
    row_map: jax.Array  # i32[m] update routing (-1 = dropped)
    read_row: jax.Array  # i32[m] read routing (survives drops)
    active: jax.Array  # bool[m] placement eligibility
    split_fired: jax.Array  # bool[m]
    split_stat: jax.Array  # f32[m] CUSUM max per server, pre-reset
    evict_fired: jax.Array  # bool[m]
    evict_stat: jax.Array  # f32[m] level-vs-median or log base ratio
    evict_route: jax.Array  # bool[m] True = level route, False = base route


def fleet_step(
    bank: DeviceEstimatorState,
    det: CusumState,
    row_map: jax.Array,
    read_row: jax.Array,
    active: jax.Array,
    logb_priors: jax.Array,
    act_ok: jax.Array,
    *,
    h: float,
    level_decay: float,
    fail_floor: float,
    min_exposure: float,
    axis=None,
) -> FleetStepOut:
    """``FleetController.observe``'s decision logic as a traceable program.

    The exact split-then-evict policy of :meth:`FleetController.observe`,
    with every pool action (``PooledEstimatorBank.split``/``drop`` plus the
    detector's pool-row migration) expressed as pure array ops, so the
    device-resident closed loop (``core.closed_loop``) can run the whole
    observe -> estimate -> detect -> act cycle without a host round trip.
    Hyperparameters are static Python floats -- the function is plain (not
    jitted) and inlines into its caller's trace.

    Sequencing matches the host exactly: flags/level/ratio/median are
    snapshots taken before each action loop (as ``observe`` precomputes
    them), while pool membership evolves *live* inside the loops (as the
    host's live ``row_of`` does) -- two flagged members of one pool split in
    index order against the topology the earlier split left behind.
    ``act_ok`` False turns the whole step into routing/identity (warm-up
    segments, padded segments).

    Every bank write inside the action loops is a pure row *copy* (seeding
    a departing row with the pool posterior), so the loops never touch the
    [rows, T, T] tables: they carry a row-provenance map ``src_of`` (final
    content of row r = input row ``src_of[r]``; copies compose as
    ``src_of[dst] = src_of[src]``) over [m]-sized arrays only, and one
    gather applies all copies at the end -- skipped entirely (``lax.cond``)
    on the common no-action segment. The base-rate read between the loops
    resolves content through the same map (``log_b[src_of[read_row]]``)
    while keeping the *nominal* prior per reading row (``priors[read_row]``,
    as the host's fixed ``_logb_priors`` stack does).

    The whole action machinery sits behind one ``lax.cond``: the pre-action
    screen (flags, level hits, base hits against pre-action state) decides
    *exactly* whether any split or eviction can fire -- the first action to
    fire would see pre-action state, so "no action under pre-action state"
    means no action at all -- and the quiet segment (the steady state) pays
    for two [m]-length loop dispatches only when something actually moves.

    With a sharded ``axis`` (the caller runs this under ``shard_map``),
    ``bank``/``det`` hold the shard's local rows while the routing arrays
    stay replicated. Fleet policy is inherently global (the failure median,
    live pool membership across the action loops), so the *small* per-server
    tables -- detector state and the bank's [m, T] base-rate columns, never
    the [m, T, T] estimators -- are allgathered once, the identical decision
    program runs replicated on every shard, and only the final bank-row
    gather is localized (pool locality keeps ``src_of`` shard-diagonal).
    """
    sharded = axis is not None and axis.is_sharded
    if sharded:
        m_loc = int(bank.log_b.shape[0])
        lo = axis.offset(m_loc)
        det = jax.tree_util.tree_map(axis.all_gather, det)
        bank_lb = axis.all_gather(bank.log_b)
        bank_nb = axis.all_gather(bank.n_base)
    else:
        bank_lb, bank_nb = bank.log_b, bank.n_base
    m = int(row_map.shape[0])
    rows_cap = int(bank_lb.shape[0])
    rows_n = int(det.pool_level.shape[0])
    idx_m = jnp.arange(m, dtype=jnp.int32)
    ident = jnp.arange(rows_cap, dtype=jnp.int32)

    def migrate_pool_rows(det, src, do, new):
        # the detector's pool-centering EWMA rows follow a leader migration
        # (DriftDetector.move_pool_row); OOB index drops the write otherwise
        v_l = det.pool_level[src]
        v_n = det.pool_n[src]
        mdst = jnp.where(do, new, rows_n)
        msrc = jnp.where(do, src, rows_n)
        return det._replace(
            pool_level=det.pool_level.at[mdst].set(v_l).at[msrc].set(0.0),
            pool_n=det.pool_n.at[mdst].set(v_n).at[msrc].set(0.0))

    # -- snapshots: the host precomputes these before acting, and the split
    # loop touches none of their inputs (det.n/level and active survive it),
    # so they serve the action loops and the pre-action screen alike
    split_stat = det.stat.max(axis=1)  # [m]
    flags = (split_stat >= h) & active & act_ok
    exposure = det.n  # f32[m]
    level = jnp.where(
        exposure > 0.0,
        det.level / jnp.maximum((1.0 - level_decay) * exposure, 1e-12),
        0.0)
    seen = active & (exposure > 0.0)
    cnt = seen.sum()
    sv = jnp.sort(jnp.where(seen, level, jnp.inf))
    med = jnp.where(
        cnt > 0,
        0.5 * (sv[jnp.clip((cnt - 1) // 2, 0, m - 1)]
               + sv[jnp.clip(cnt // 2, 0, m - 1)]),
        0.0)
    level_hits = ((exposure >= min_exposure)
                  & (level - med <= math.log(fail_floor)) & act_ok)

    def base_ratio(src_of, read_row):
        # _base_ratio on the (post-split) bank, content resolved through
        # src_of; the prior stays the reading row's own
        rr = jnp.clip(read_row, 0, rows_cap - 1)
        lb, wexp = bank_lb[src_of[rr]], bank_nb[src_of[rr]]
        tot = wexp.sum(axis=1)
        ratio = jnp.exp((wexp * (lb - logb_priors[rr])).sum(axis=1)
                        / jnp.maximum(tot, 1e-12))
        return jnp.where(tot >= jnp.float32(min_exposure), ratio, 1.0)

    # -- the screen: can anything fire against pre-action state? -----------
    ratio0 = base_ratio(ident, read_row)
    row_live = row_map >= 0
    size0 = ((row_map[:, None] == row_map[None, :])
             & row_live[None, :] & row_live[:, None]).sum(axis=1)
    gate0 = active & (active.sum() > 1) & act_ok
    maybe_evict = gate0 & (level_hits
                           | ((size0 == 1) & (ratio0 <= fail_floor)))
    take_slow = jnp.any(flags) | jnp.any(maybe_evict)

    def split_body(s, carry):
        src_of, det, row_map, read_row, fired = carry
        row = row_map[s]
        members = (row_map == row) & (row_map >= 0)
        can = flags[s] & (row >= 0) & (members.sum() > 1)
        is_leader = can & (row == s)
        others = members & (idx_m != s)
        new = jnp.min(jnp.where(others, idx_m, m)).astype(jnp.int32)
        # seed the departing row with the pool posterior: leader split copies
        # src -> new (the pool migrates, the leader keeps src); non-leader
        # split copies src -> s (the member leaves with the shared state)
        src = jnp.clip(row, 0, rows_cap - 1)
        cp = jnp.where(can, jnp.where(is_leader, new, s), rows_cap)
        src_of = src_of.at[cp].set(src_of[src])
        move = is_leader & others
        row_map = jnp.where(move, new, row_map)
        read_row = jnp.where(move, new, read_row)
        nl = jnp.where(can & ~is_leader, s, m)
        row_map = row_map.at[nl].set(s)
        read_row = read_row.at[nl].set(s)
        det = migrate_pool_rows(det, src, is_leader, new)
        # CUSUM evidence was acted on (or is the solo estimator's to
        # absorb): reset the stat pair for every *flagged* server, split or
        # not -- the residual level keeps its history across the split
        det = det._replace(stat=det.stat.at[jnp.where(flags[s], s, m)].set(0.0))
        fired = fired.at[jnp.where(can, s, m)].set(True)
        return src_of, det, row_map, read_row, fired

    def slow(args):
        bank, det, row_map, read_row, active = args
        src_of, det, row_map, read_row, split_fired = jax.lax.fori_loop(
            0, m, split_body,
            (ident, det, row_map, read_row, jnp.zeros((m,), bool)))

        # -- failures: level route vs fleet median, base route vs nominal --
        ratio = base_ratio(src_of, read_row)

        def evict_body(s, carry):
            src_of, det, row_map, read_row, active, fired, stats = carry
            row = row_map[s]
            members = (row_map == row) & (row_map >= 0)
            size = members.sum()
            gate = active[s] & (active.sum() > 1) & act_ok
            base_hit = (size == 1) & (ratio[s] <= fail_floor)
            fire = gate & (level_hits[s] | base_hit)
            # an evicted leader detaches its survivors first (drop ->
            # split): the pool migrates to the next member's row, src -> new
            is_leader = fire & (row == s) & (size > 1)
            others = members & (idx_m != s)
            new = jnp.min(jnp.where(others, idx_m, m)).astype(jnp.int32)
            src = jnp.clip(row, 0, rows_cap - 1)
            cp = jnp.where(is_leader, new, rows_cap)
            src_of = src_of.at[cp].set(src_of[src])
            move = is_leader & others
            row_map = jnp.where(move, new, row_map)
            read_row = jnp.where(move, new, read_row)
            det = migrate_pool_rows(det, src, is_leader, new)
            # the drop itself: routing -1, mask False, detector rows reset
            # (read_row keeps resolving to the last live row, as on host)
            di = jnp.where(fire, s, m)
            row_map = row_map.at[di].set(-1)
            active = active.at[di].set(False)
            det = det._replace(stat=det.stat.at[di].set(0.0),
                               level=det.level.at[di].set(0.0),
                               n=det.n.at[di].set(0.0))
            fired = fired.at[di].set(True)
            stats = stats.at[di].set(
                jnp.where(level_hits[s], level[s] - med, jnp.log(ratio[s])))
            return src_of, det, row_map, read_row, active, fired, stats

        src_of, det, row_map, read_row, active, evict_fired, evict_stat = (
            jax.lax.fori_loop(
                0, m, evict_body,
                (src_of, det, row_map, read_row, active,
                 jnp.zeros((m,), bool), jnp.zeros((m,), jnp.float32))))

        if sharded:
            # pool locality keeps every copy within its shard: the local
            # slice of src_of rebases to local row indices, and the big
            # [m_loc, T, T] tables never cross the mesh
            src_l = jnp.clip(
                jax.lax.dynamic_slice_in_dim(src_of, lo, m_loc) - lo,
                0, m_loc - 1)
            gather = lambda b: DeviceEstimatorState(*(a[src_l] for a in b))
        else:
            gather = lambda b: DeviceEstimatorState(*(a[src_of] for a in b))
        bank2 = jax.lax.cond(
            jnp.any(src_of != ident), gather, lambda b: b, bank)
        return FleetStepOut(
            bank=bank2, det=det, row_map=row_map, read_row=read_row,
            active=active, split_fired=split_fired, split_stat=split_stat,
            evict_fired=evict_fired, evict_stat=evict_stat,
            evict_route=level_hits)

    def fast(args):
        bank, det, row_map, read_row, active = args
        quiet = jnp.zeros((m,), bool)
        return FleetStepOut(
            bank=bank, det=det, row_map=row_map, read_row=read_row,
            active=active, split_fired=quiet, split_stat=split_stat,
            evict_fired=quiet, evict_stat=jnp.zeros((m,), jnp.float32),
            evict_route=level_hits)

    out = jax.lax.cond(take_slow, slow, fast,
                       (bank, det, row_map, read_row, active))
    if sharded:
        # routing ran on the gathered detector; hand back this shard's rows
        out = out._replace(det=jax.tree_util.tree_map(
            lambda a: jax.lax.dynamic_slice_in_dim(a, lo, m_loc), out.det))
    return out


@dataclasses.dataclass(frozen=True)
class HealthEvent:
    """One fleet-health decision, as the controller's audit record."""

    kind: Literal["split", "evict"]
    server: int
    segment: int
    stat: float  # the detector statistic that fired (CUSUM max or level)
    detail: str = ""


class FleetController:
    """Fleet-health policy bound to a fleet's estimators (module docstring).

    Parameters
    ----------
    pools : 'spec' groups servers whose ``ServerSpec`` compare equal (the
        same-part fleet); an explicit label sequence groups arbitrarily
        (e.g. nominally-identical perturbed units); None disables pooling.
    cusum_k, cusum_h, level_decay, min_exposure, max_lost_frac :
        forwarded to :class:`~repro.fleet.detect.DriftDetector`.
    fail_floor : eviction rate floor; defaults to the shared
        ``criteria.eviction_rate_floor()`` threshold.
    mesh : optional training-mesh config; evictions then also produce (and
        apply, so consecutive failures compose) ``plan_elastic_remesh``
        shrink plans in ``plans``.
    heartbeat_timeout : forwarded to the :class:`HeartbeatMonitor` liveness
        plane. The controller heartbeats on the *segment* clock (one beat
        per surviving server per ``observe``), so the unit is segments --
        the default of 2 means "missed two consecutive segments" --  not
        wall seconds.
    warmup_segments : health actions are withheld for this many initial
        ``observe`` calls (counted by the controller, not by the caller's
        segment numbering), and the detector state is discarded during
        them. A cold model (the optimistic zero prior) under-predicts
        co-run degradation, so early residuals confound "this server is
        broken" with "this server got the deepest co-run stack" -- no
        detector can separate the two until the model has converged once.
        Burn-in is the standard change-detection answer, and it happens
        once per controller lifetime (the model stays warm across runs);
        fleets starting from a profiled prior can set 0.
    """

    def __init__(
        self,
        pools: "Literal['spec'] | Sequence[Hashable] | None" = "spec",
        *,
        cusum_k: float = 0.25,
        cusum_h: float = 2.0,
        level_decay: float = 0.9,
        fail_floor: float | None = None,
        min_exposure: float = 4.0,
        max_lost_frac: float = 0.5,
        mesh: MeshConfig | None = None,
        heartbeat_timeout: float = 2.0,
        warmup_segments: int = 2,
    ):
        self._pools_spec = pools
        self.cusum_k = cusum_k
        self.cusum_h = cusum_h
        self.level_decay = level_decay
        self.fail_floor = (eviction_rate_floor() if fail_floor is None
                           else fail_floor)
        self.min_exposure = min_exposure
        self.max_lost_frac = max_lost_frac
        self.mesh = mesh
        self._heartbeat_timeout = heartbeat_timeout
        self.warmup_segments = int(warmup_segments)
        self._segments_seen = 0  # observe() calls consumed (burn-in clock)
        self.events: list[HealthEvent] = []
        self.plans: list[ReMeshPlan] = []
        self.pool: PooledEstimatorBank | None = None
        self.detector: DriftDetector | None = None
        self.monitor: HeartbeatMonitor | None = None
        self._active: np.ndarray | None = None

    # -- binding -----------------------------------------------------------
    def bind(
        self,
        servers: Sequence[ServerSpec],
        estimators: Sequence[StreamingEstimator],
    ) -> "FleetController":
        """Attach to a fleet: build the pool map, detector, and monitor.

        Called by ``AdaptiveEngine`` with the estimators it constructs (one
        per server, as always); standalone users may call it directly. A
        controller binds once -- it accumulates fleet history.
        """
        if self.pool is not None:
            raise RuntimeError("FleetController is already bound to a fleet")
        if len(servers) != len(estimators):
            raise ValueError(f"{len(servers)} servers, {len(estimators)} estimators")
        m = len(servers)
        if self._pools_spec == "spec":
            seen: dict[ServerSpec, int] = {}
            labels: Sequence[Hashable] = [
                seen.setdefault(s, len(seen)) for s in servers]
        else:
            labels = self._pools_spec
        self.pool = PooledEstimatorBank(estimators, labels)
        self.detector = DriftDetector(
            m=m, k=self.cusum_k, h=self.cusum_h,
            level_decay=self.level_decay, fail_floor=self.fail_floor,
            min_exposure=self.min_exposure, max_lost_frac=self.max_lost_frac)
        from ..distributed.fault_tolerance import HeartbeatMonitor

        self.monitor = HeartbeatMonitor(m, timeout_s=self._heartbeat_timeout)
        self._active = np.ones(m, bool)
        # nominal per-row log base priors, stacked once: priors are fixed at
        # construction, so the per-segment base-rate health read never has to
        # touch the member estimators (or pull their state) again
        self._logb_priors = jnp.asarray(
            np.stack([e._logb_prior for e in self.pool.bank.estimators]),
            jnp.float32)
        return self

    def _require_bound(self) -> None:
        if self.pool is None:
            raise RuntimeError("FleetController.bind(servers, estimators) first")

    @property
    def m(self) -> int:
        self._require_bound()
        return self.pool.m

    # -- fleet state reads -------------------------------------------------
    def active_mask(self) -> np.ndarray:
        """Placement eligibility per server (bool [m], False = evicted)."""
        self._require_bound()
        return self._active.copy()

    def current_D(self) -> list[np.ndarray]:
        """Per-server D estimates through the pool map (shared when pooled)."""
        self._require_bound()
        return self.pool.estimate_D()

    def base_ratio(self) -> np.ndarray:
        """Estimated base rate / nominal prior per server [m] (geometric,
        weighted by per-type solo exposure; 1.0 where evidence is thin).

        The *base* failure route: once a server runs solo (split out of its
        pool), its own estimator tracks its collapse and this ratio is the
        honest health read. Pooled servers report their pool's ratio.

        Computed from the bank's live stacked state entirely on device --
        one [m]-sized pull at the end, never the [rows, T] tables (the
        host-sync leak the purity auditor exists to keep out).
        """
        self._require_bound()
        st = self.pool.bank.stacked_state()
        ratio = _base_ratio(
            st.log_b, st.n_base, self._logb_priors,
            jnp.asarray(self.pool._read_row, jnp.int32),
            jnp.float32(self.min_exposure))
        return np.asarray(ratio, np.float64)

    def recorder_ctx(self, segment: int):
        """The decision recorder's per-segment context (``obs.recorder``):
        the pair-exposure bank rows, pool read routing, and per-server CUSUM
        levels exactly as the *next* segment's scheduler consults them --
        call after this segment's ``observe`` (mirroring the device loop,
        which samples the carry at segment entry)."""
        from ..obs import recorder as obs_recorder

        self._require_bound()
        read_row = jnp.asarray(self.pool._read_row, jnp.int32)
        return obs_recorder.RecCtx(
            n_pair=self.pool.bank.stacked_state().n_pair_t,
            row_of=read_row,
            cusum=self.detector.state.stat.max(axis=1),
            pool_row=read_row,
            segment=jnp.int32(segment))

    # -- the per-segment step ---------------------------------------------
    def observe(self, block: RingBlock, segment: int) -> tuple[int, list[HealthEvent]]:
        """Fold one segment's telemetry in; diagnose; act.

        One fused pooled-bank update, one fused detector update (against the
        *post-update* pooled model -- the one the next segment schedules
        with), then host-side policy. Returns (rows consumed, events fired
        this call); events also accumulate on ``self.events``.
        """
        self._require_bound()
        # both fused updates dispatch without blocking; the single int()
        # below is the segment's one host sync (and it fences both programs
        # -- the detector consumes the post-update refs, so its result is
        # ordered after the bank's)
        used_dev = self.pool.update_device(block, sync=False)
        log_b, L_t, row_map = self.pool.refs()
        self.detector.update(block, log_b, L_t, row_map, sync=False)
        used = int(used_dev)
        events: list[HealthEvent] = []

        # liveness plane: surviving servers heartbeat on the segment clock
        for s in range(self.m):
            if self._active[s]:
                self.monitor.heartbeat(s, now=float(segment))

        self._segments_seen += 1
        if self._segments_seen <= self.warmup_segments:
            # burn-in (once per controller lifetime, on the controller's own
            # observe count -- callers may number segments per run): the
            # model is still converging, so residual evidence confounds load
            # imbalance with divergence -- discard it and take no action
            self.detector.reset_all()
            return used, events

        # splits: pooled servers whose residual stream diverged
        split = self.detector.split_flags()
        stat = self.detector.stat_max()
        for s in map(int, np.flatnonzero(split)):
            if not self._active[s]:
                continue
            if self.pool.split(s):
                self._follow_migration()
                events.append(HealthEvent(
                    "split", s, segment, float(stat[s]),
                    detail=f"cusum {stat[s]:.2f} >= h {self.detector.h:g}"))
            # CUSUM evidence was acted on (or, for an already-solo server,
            # is the estimator's to absorb) -- but only the CUSUM: the
            # residual stream is *continuous* across a split (the private
            # row is seeded with the identical posterior), so the failure
            # level keeps its history. A collapsing server split out on the
            # way down still evicts on schedule; a merely-congested one
            # recenters as its private model adapts.
            self.detector.reset_stat(s)

        # failures: the level route (residual level vs the *fleet median*
        # level -- the straggler monitor's exact rule, via the detector's
        # one predicate: a server is failing when it observably runs at
        # <= fail_floor x its siblings; the relative baseline also immunizes
        # the route against fleet-wide model misfit) or the base route (own
        # estimated base rate vs nominal -- only meaningful for a server
        # with a *private* row: a pooled row's ratio is shared by every
        # member and cannot single one out), both on the shared floor
        level = self.detector.level_hat()
        exposure = self.detector.exposure()
        ratio = self.base_ratio()
        seen = self._active & (exposure > 0)
        med = float(np.median(level[seen])) if seen.any() else 0.0
        level_hits = self.detector.fail_flags(center=med)
        for s in range(self.m):
            if not self._active[s]:
                continue
            if self._active.sum() <= 1:
                break  # never evict the last server: a sick fleet > none
            level_hit = bool(level_hits[s])
            base_hit = (self.pool.pool_size(s) == 1
                        and ratio[s] <= self.fail_floor)
            if not (level_hit or base_hit):
                continue
            stat_val = float(level[s] - med if level_hit else np.log(ratio[s]))
            detail = ("residual level vs fleet median" if level_hit
                      else "estimated base") + (
                f" {np.exp(stat_val):.3f} <= floor {self.fail_floor:g}")
            events.append(self._evict(s, segment, stat_val, detail))

        self.events.extend(events)
        return used, events

    def adopt_device_outcome(
        self,
        bank_state: DeviceEstimatorState,
        det_state: CusumState,
        row_map: np.ndarray,
        read_row: np.ndarray,
        active: np.ndarray,
        outcomes: Sequence[dict],
    ) -> list[list[HealthEvent]]:
        """Mirror a device-resident closed-loop run into host fleet state.

        ``core.closed_loop`` runs :func:`fleet_step` inside its scan; this
        swallows the run's final arrays whole (routing via
        ``pool.adopt_rows``, mask, detector state, stacked bank) and replays
        only the *host-side* per-segment bookkeeping the device cannot
        carry: heartbeats on the segment clock, the burn-in counter,
        :class:`HealthEvent` records, ``mark_dead`` and re-mesh plans per
        eviction. ``outcomes`` is one dict per real segment, ascending, with
        the ``FleetStepOut`` decision arrays pulled to numpy. Returns the
        events per segment (also accumulated on ``self.events``).
        """
        self._require_bound()
        per_segment: list[list[HealthEvent]] = []
        entry_active = self._active.copy()
        for out in outcomes:
            seg = int(out["segment"])
            for s in range(self.m):
                if entry_active[s]:
                    self.monitor.heartbeat(s, now=float(seg))
            self._segments_seen += 1
            events: list[HealthEvent] = []
            stat = np.asarray(out["split_stat"], np.float64)
            for s in map(int, np.flatnonzero(out["split_fired"])):
                events.append(HealthEvent(
                    "split", s, seg, float(stat[s]),
                    detail=f"cusum {stat[s]:.2f} >= h {self.detector.h:g}"))
            est = np.asarray(out["evict_stat"], np.float64)
            route = np.asarray(out["evict_route"], bool)
            for s in map(int, np.flatnonzero(out["evict_fired"])):
                stat_val = float(est[s])
                detail = ("residual level vs fleet median" if route[s]
                          else "estimated base") + (
                    f" {np.exp(stat_val):.3f} <= floor {self.fail_floor:g}")
                events.append(HealthEvent("evict", s, seg, stat_val,
                                          detail=detail))
                self.monitor.mark_dead(s)
                if self.mesh is not None:
                    from ..distributed.fault_tolerance import plan_elastic_remesh

                    plan = plan_elastic_remesh(self.mesh, [s])
                    if plan is not None:
                        self.plans.append(plan)
                        self.mesh = plan.new  # consecutive failures compose
            self.events.extend(events)
            per_segment.append(events)
            entry_active = np.asarray(out["active_after"], bool).copy()
        self.pool.adopt_rows(row_map, read_row)
        self._active = np.asarray(active, bool).copy()
        self.detector.state = CusumState(*det_state)
        self.pool.bank._stacked = DeviceEstimatorState(*bank_state)
        self.pool.bank._dirty = True
        return per_segment

    def _follow_migration(self) -> None:
        """Keep the detector's pool-centering rows aligned with a pool that
        just migrated to a new leader row (see ``pool.last_migration``)."""
        mig = self.pool.last_migration
        if mig is not None:
            self.detector.move_pool_row(*mig)

    def _evict(self, server: int, segment: int, stat: float, detail: str) -> HealthEvent:
        """Remove ``server`` from the fleet (mask, routing, fault plane)."""
        self._active[server] = False
        self.pool.drop(server)
        self._follow_migration()
        self.detector.reset(server)
        self.monitor.mark_dead(server)
        if self.mesh is not None:
            from ..distributed.fault_tolerance import plan_elastic_remesh

            plan = plan_elastic_remesh(self.mesh, [server])
            if plan is not None:
                self.plans.append(plan)
                self.mesh = plan.new  # consecutive failures compose
        return HealthEvent("evict", server, segment, stat, detail=detail)

    # -- audit helpers -----------------------------------------------------
    def evicted(self) -> tuple[int, ...]:
        self._require_bound()
        return tuple(int(s) for s in np.flatnonzero(~self._active))

    def events_of(self, kind: str) -> tuple[HealthEvent, ...]:
        return tuple(ev for ev in self.events if ev.kind == kind)
