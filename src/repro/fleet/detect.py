"""CUSUM drift detection on per-server residual streams.

The pooling bet (``fleet.pool``) is that same-spec servers share one world;
this module watches for the moment that stops being true. Every completion
observation yields a *solo residual* -- the server's own log-rate minus what
its pool's model predicts for that run::

    r = y - (log_b_pool[t] + cbar @ L_pool[:, t])

("solo" as in per-server: the residual of one server's stream against the
shared model; co-run rows are included, which is what makes D-matrix drift
-- a congested shared subsystem -- visible at all). For a healthy pool
member r is zero-mean noise; a diverging server pushes it persistently to
one side.

Two statistics per server, both updated by one jitted, **chunk-invariant**
program (rows are folded strictly in stream order by a ``lax.scan``, so
splitting a batch anywhere leaves the device state bitwise identical --
mirroring the PR 4 exposure-based EWMA contract, and tested the same way):

  CUSUM [m, 2]  the classic one-sided pair S+ = max(0, S+ + (x - k)),
                S- = max(0, S- - (x + k)) on the **pool-centered** residual
                x = r - pool_level_hat: cumulative evidence of a mean shift
                beyond the allowance ``k``, self-resetting through the
                max(0, .) whenever the stream behaves. The centering
                reference is an EWMA of the *pool row's own* residual,
                maintained sequentially in the same scan (so it costs no
                chunk-invariance), which cancels model error every member
                shares -- a cold pool warming up, a drift hitting the whole
                pool -- and leaves exactly the *relative* divergence the
                split decision is about. Crossing ``h`` is the split signal:
                the server no longer belongs to its pool.
  level [m]     an exposure-weighted EWMA of the **raw** residual (decay
                compounded per observation, like the estimator's confidence
                decay) with its exact bias correction: ``level_hat = level /
                ((1 - decay) n)`` recovers the running mean of r. A level at
                or below ``log(fail_floor)`` means the server *runs at* a
                fraction ``fail_floor`` of its model -- the failure signal,
                whose default floor is ``criteria.eviction_rate_floor()``
                (the Eqn-4 straggler threshold, shared so eviction and
                straggler policy cannot drift apart). Failure is absolute
                (the machine is slow, whoever's fault the model thinks it
                is), so this one is deliberately *not* pool-centered.

The detector holds no estimator state: the pooled model enters each update
as explicit references (``PooledEstimatorBank.refs``), so residuals are
always measured against the model the fleet is *currently* scheduling with.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import NamedTuple, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from ..core.criteria import eviction_rate_floor
from ..telemetry.log import RingBlock


class CusumState(NamedTuple):
    """Per-server (and per-pool-row) detector state as device arrays."""

    stat: jax.Array  # f32[m, 2] (S+, S-) CUSUM pair, pool-centered residual
    level: jax.Array  # f32[m] exposure-weighted EWMA of the raw residual
    n: jax.Array  # f32[m] decayed exposure behind ``level``
    pool_level: jax.Array  # f32[m rows] EWMA of each pool row's residual
    pool_n: jax.Array  # f32[m rows] decayed exposure behind ``pool_level``

    @classmethod
    def zeros(cls, m: int, rows: "int | None" = None) -> "CusumState":
        """Fresh all-zero state for ``m`` servers (``rows`` pool rows)."""
        rows = m if rows is None else rows
        return cls(stat=jnp.zeros((m, 2), jnp.float32),
                   level=jnp.zeros(m, jnp.float32),
                   n=jnp.zeros(m, jnp.float32),
                   pool_level=jnp.zeros(rows, jnp.float32),
                   pool_n=jnp.zeros(rows, jnp.float32))


@partial(jax.jit,
         static_argnames=("k", "level_decay", "max_lost_frac"))
def _cusum_update(
    state: CusumState,
    block: RingBlock,
    log_b,  # f32[p, T] pooled base estimates (bank rows)
    L_t,  # f32[p, T, T] pooled pair estimates, target-major [t, u]
    row_map,  # i32[m] server -> bank row (-1 drops the server)
    *,
    k: float,
    level_decay: float,
    max_lost_frac: float,
):
    """Fold one block of observation rows into the detector state.

    Residuals are computed vectorized (each row is independent); only the
    accumulation is sequential -- a ``lax.scan`` in stream order, which is
    what makes the state exactly chunk-invariant (an associative-scan tree
    would reassociate float adds and break bitwise equality between split
    and merged batches). Rows outside [0, m), unmapped, voided, or past the
    lost-frac filter scatter to a dropped index.
    """
    m = state.level.shape[0]
    p, T = log_b.shape
    srv = block.server
    valid = block.valid & (block.lost_frac <= max_lost_frac)
    valid &= (srv >= 0) & (srv < m)
    s_clip = jnp.clip(srv, 0, m - 1)
    row = row_map[s_clip]
    valid &= (row >= 0) & (row < p)
    r_clip = jnp.clip(row, 0, p - 1)
    t_clip = jnp.clip(block.wtype, 0, T - 1)
    pred = log_b[r_clip, t_clip] + (block.co * L_t[r_clip, t_clip]).sum(axis=1)
    resid = block.y - pred  # [B]
    rows_n = state.pool_level.shape[0]
    r_idx = jnp.clip(r_clip, 0, rows_n - 1)

    def step(carry, x):
        stat, level, n, pool_level, pool_n = carry
        s, rw, r, ok = x
        # the pool's running residual mean at this row's arrival (bias-
        # corrected EWMA; an empty pool centers at 0 -- the first row per
        # pool is the only one that sees uncentered model error)
        hat = pool_level[rw] / jnp.maximum((1.0 - level_decay) * pool_n[rw], 1e-12)
        hat = jnp.where(pool_n[rw] > 0, hat, 0.0)
        x_c = r - hat  # pool-centered: shared model error cancels
        pos = jnp.maximum(0.0, stat[s, 0] + (x_c - k))
        neg = jnp.maximum(0.0, stat[s, 1] - (x_c + k))
        lvl = level_decay * level[s] + (1.0 - level_decay) * r
        cnt = level_decay * n[s] + 1.0
        p_lvl = level_decay * pool_level[rw] + (1.0 - level_decay) * r
        p_cnt = level_decay * pool_n[rw] + 1.0
        idx = jnp.where(ok, s, m)  # out-of-range scatter: dropped row
        ridx = jnp.where(ok, rw, rows_n)
        return (stat.at[idx, 0].set(pos).at[idx, 1].set(neg),
                level.at[idx].set(lvl), n.at[idx].set(cnt),
                pool_level.at[ridx].set(p_lvl), pool_n.at[ridx].set(p_cnt)), None

    (stat, level, n, pool_level, pool_n), _ = jax.lax.scan(
        step, tuple(state), (s_clip, r_idx, resid, valid))
    return CusumState(stat, level, n, pool_level, pool_n), valid.sum()


def cusum_update_sharded(
    axis, state: CusumState, block: RingBlock, log_b, L_t, row_map,
    *, k: float, level_decay: float, max_lost_frac: float,
):
    """``_cusum_update`` with detector rows + pooled tables sharded.

    Requires the pool-locality contract (DESIGN.md section 15): every pool
    lives whole inside one shard, so ``row_map[s]`` points into the shard
    that owns server ``s``. Each shard then folds the *full* replicated
    block in stream order with off-shard rows masked to the dropped index --
    every (server, pool-row) state sees exactly the dense sequence of
    updates, bitwise (a row that crossed shards would instead be silently
    dropped by the localized range mask; the pool layer never builds one).
    Only the consumed-row count crosses the mesh. A dense axis is the plain
    jitted update, untouched.
    """
    if not axis.is_sharded:
        return _cusum_update(state, block, log_b, L_t, row_map, k=k,
                             level_decay=level_decay,
                             max_lost_frac=max_lost_frac)
    m = row_map.shape[0]
    axis.validate(m)
    m_local = axis.local_m(m)

    def body(state_l, block, log_b_l, L_t_l, row_map):
        lo = axis.offset(m_local)
        row_l = jax.lax.dynamic_slice_in_dim(row_map, lo, m_local) - lo
        block_l = block._replace(
            ints=jnp.stack([block.wtype, block.server - lo], axis=1))
        new, used = _cusum_update(state_l, block_l, log_b_l, L_t_l, row_l,
                                  k=k, level_decay=level_decay,
                                  max_lost_frac=max_lost_frac)
        return new, axis.psum(used)

    mapped = axis.shard_map(
        body,
        in_specs=(axis.shard_leading(state, m), axis.rep_tree(block),
                  axis.spec(), axis.spec(), axis.rep()),
        out_specs=(axis.shard_leading(state, m), axis.rep()))
    return mapped(state, block, log_b, L_t, row_map)


@jax.jit
def _reset_rows(state: CusumState, servers) -> CusumState:
    # per-server state only: pool_level rows are shared (a split or evicted
    # server's *new* row starts zeroed anyway; its old pool keeps its own)
    return state._replace(stat=state.stat.at[servers].set(0.0),
                          level=state.level.at[servers].set(0.0),
                          n=state.n.at[servers].set(0.0))


@jax.jit
def _reset_stat_rows(state: CusumState, servers) -> CusumState:
    return state._replace(stat=state.stat.at[servers].set(0.0))


@jax.jit
def _move_pool_row(state: CusumState, src, dst) -> CusumState:
    lvl, n = state.pool_level, state.pool_n
    return state._replace(
        pool_level=lvl.at[dst].set(lvl[src]).at[src].set(0.0),
        pool_n=n.at[dst].set(n[src]).at[src].set(0.0))


@dataclasses.dataclass
class DriftDetector:
    """Per-server CUSUM + residual-level detector (see module docstring).

    Parameters
    ----------
    m : fleet size (servers, not pools).
    k : CUSUM allowance, in log-slowdown units -- persistent mean shifts
        smaller than this are absorbed as noise. Model error of a healthy
        pool member (Jensen gaps, mid-run co-residency changes) lives well
        under 0.1; the drifts worth splitting over (a congested subsystem,
        a decaying disk) shift log-rates by 0.3+.
    h : CUSUM split threshold: cumulative evidence (in the same log units,
        beyond the allowance) before a split fires. ~n_obs * (shift - k)
        accumulates per segment, so h = 2 catches a 0.5-shift within a
        segment or two of ~10 observations.
    level_decay : per-observation EWMA decay of the failure level (0.9 ~ a
        12-observation half-life).
    fail_floor : observed/predicted rate ratio at or below which a server
        is failing. Defaults to ``criteria.eviction_rate_floor()`` -- the
        Eqn-4 threshold the straggler monitor also uses.
    min_exposure : decayed observations required before the failure signal
        may fire (an empty EWMA reads 0 = healthy, but a couple of unlucky
        rows should not evict a server).
    max_lost_frac : rows past this TDP-overflow fraction are ignored,
        matching the estimator's filter.
    """

    m: int
    k: float = 0.25
    h: float = 2.0
    level_decay: float = 0.9
    fail_floor: float | None = None
    min_exposure: float = 4.0
    max_lost_frac: float = 0.5

    def __post_init__(self):
        if self.fail_floor is None:
            self.fail_floor = eviction_rate_floor()
        if not 0.0 < self.fail_floor < 1.0:
            raise ValueError(f"fail_floor must be in (0, 1), got {self.fail_floor}")
        self.state = CusumState.zeros(self.m)

    # -- updates -----------------------------------------------------------
    def update(self, block: RingBlock, log_b, L_t, row_map, sync: bool = True):
        """Consume one observation block against the pooled model refs.

        ``log_b``/``L_t``/``row_map`` are what ``PooledEstimatorBank.refs``
        returns (post-update estimates: residuals are measured against the
        model the next segment will schedule with). Returns rows consumed.
        """
        self.state, used = _cusum_update(
            self.state, block, log_b, L_t,
            jnp.asarray(row_map, jnp.int32),
            k=float(self.k), level_decay=float(self.level_decay),
            max_lost_frac=float(self.max_lost_frac))
        return int(used) if sync else used

    def reset(self, server: "int | Sequence[int]") -> None:
        """Zero a server's detector rows (after a split or an eviction, so
        the acted-on evidence does not immediately re-fire)."""
        self.state = _reset_rows(self.state, jnp.asarray(server, jnp.int32))

    def reset_all(self) -> None:
        """Zero the whole detector (end of the controller's warm-up: the
        evidence accumulated against a cold model confounds load imbalance
        with divergence and is discarded wholesale)."""
        self.state = CusumState(*(jnp.zeros_like(a) for a in self.state))

    def move_pool_row(self, src: int, dst: int) -> None:
        """Move one pool's centering EWMA to a new row (leader split/drop).

        ``PooledEstimatorBank`` records the migration in ``last_migration``;
        applying the same move here keeps the surviving pool's centering
        history (instead of restarting it cold on the new leader row) while
        the departing leader's now-private row starts centering afresh.
        """
        self.state = _move_pool_row(self.state, jnp.int32(src), jnp.int32(dst))

    def reset_stat(self, server: "int | Sequence[int]") -> None:
        """Zero only the CUSUM pair, keeping the failure level.

        For a CUSUM that fires on an already-solo server: there is no pool
        left to split from (the estimator absorbs the drift), but the
        residual level must keep accumulating -- it is the failure evidence.
        """
        self.state = _reset_stat_rows(self.state, jnp.asarray(server, jnp.int32))

    # -- host-side reads ---------------------------------------------------
    def stat_max(self) -> np.ndarray:
        """max(S+, S-) per server -- the split statistic [m]."""
        return np.asarray(self.state.stat).max(axis=1)

    def split_flags(self) -> np.ndarray:
        """Servers whose CUSUM crossed ``h`` (bool [m])."""
        return self.stat_max() >= self.h

    def exposure(self) -> np.ndarray:
        """Decayed observation count behind the failure level [m]."""
        return np.asarray(self.state.n, np.float64)

    def level_hat(self) -> np.ndarray:
        """Bias-corrected running mean of the residual per server [m].

        ``level / ((1 - decay) n)`` is exact: for a constant stream both
        numerator and denominator carry the same ``(1 - decay^j)`` ramp.
        Servers with no exposure read 0 (no evidence of anything).
        """
        n = self.exposure()
        denom = np.maximum((1.0 - self.level_decay) * n, 1e-12)
        out = np.asarray(self.state.level, np.float64) / denom
        return np.where(n > 0, out, 0.0)

    def fail_flags(self, center: float | np.ndarray = 0.0) -> np.ndarray:
        """Servers running at or below ``fail_floor`` x reference (bool [m]).

        ``center`` shifts the reference: 0 tests the level absolutely (at or
        below ``fail_floor`` x what the model predicts); the fleet
        controller passes the fleet-median level, turning this into the
        straggler monitor's relative rule (slower than ``fail_floor`` x your
        siblings) -- one predicate, one knob, two baselines. Gated on
        ``min_exposure`` so an unobserved (or barely observed) server is
        never flagged.
        """
        lvl = self.level_hat()
        return (self.exposure() >= self.min_exposure) & (
            lvl - center <= float(np.log(self.fail_floor)))
