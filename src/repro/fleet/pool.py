"""Pooled estimation: same-spec servers share one estimator row.

Per-server estimators (PR 2/4) are correct under drift but slow to warm up:
every server re-learns the same D-matrix from only its own completions. A
fleet built from m units of the same part can pool their telemetry -- one
shared estimator warms up ~m x faster -- *until* a unit stops behaving like
its siblings, at which point pooling averages incompatible worlds (the
reason ``AdaptiveEngine`` refused to pool in PR 2).

:class:`PooledEstimatorBank` makes pooling a *routing* decision instead of a
structural one, which is what lets the fleet controller change it online.
The underlying :class:`~repro.telemetry.EstimatorBank` keeps one row per
server (its stacked [m, ...] device state never changes shape); a
server -> row map, applied on device by the bank's ``row_map`` hook, decides
which row each server's observations update:

  pooled   every member of a pool maps to the pool's *leader row* (the
           lowest member index); the other members' rows lie dormant. One
           fused banked update still consumes the whole fleet's telemetry in
           a single pass -- the scatter indices inside the program are
           simply pool ids now.
  split    a diverging server (``fleet.detect`` CUSUM) is re-routed to its
           own row, seeded with the pool's full posterior
           (``EstimatorBank.copy_row``): it starts exactly as warm as the
           pool it left and tracks its private world from there. When the
           *leader* splits, the pool migrates to the next member's row
           (seeded the same way) and the leader keeps its own.
  dropped  an evicted server maps to -1: its rows (there should be none,
           placement is masked) fall into the update's dump mask. Reads
           keep returning its last estimator so in-flight consumers never
           see a hole.

Reads (``estimator_for`` / ``estimate_D``) resolve through the same map, so
all pool members report the shared estimate and a split server reports its
own -- callers never see pool topology, only per-server estimators.
"""
from __future__ import annotations

from typing import Hashable, Sequence

import jax.numpy as jnp
import numpy as np

from ..telemetry.estimator import EstimatorBank, StreamingEstimator
from ..telemetry.log import RingBlock


def shard_local_pools(pools: "Sequence[Hashable]", m: int,
                      shards: int) -> list:
    """Namespace pool labels by shard so no pool crosses a shard boundary.

    The sharded detector/bank contract (DESIGN.md section 15) is that
    ``row_map[s]`` stays inside server ``s``'s shard -- CUSUM pool rows and
    bank row-copies are then shard-local and the sharded update is bitwise
    the dense one. Two same-spec servers on different shards become two
    pools; the pooling win shrinks at shard boundaries instead of the
    correctness breaking there.
    """
    if m % shards:
        raise ValueError(f"m={m} not divisible by shards={shards}")
    m_local = m // shards
    return [(s // m_local, lab) for s, lab in enumerate(pools)]


def resolve_leaders_device(axis, pool_ids):
    """Device-side leader election: ``row_map`` from per-shard pool ids.

    ``pool_ids`` is i32[m] (sharded over ``axis`` or dense): servers sharing
    an id share a pool, negative ids are dropped servers. Exactly one
    allgather moves the ids across the mesh; every shard then resolves each
    server to the *lowest* member index of its pool (the host constructor's
    ``leader.setdefault`` rule) on the replicated [m] vector. O(m^2) compare
    -- leader election runs at fleet-management frequency, not per decision.
    Returns the replicated row_map i32[m].
    """

    def body(ids_l):
        ids = axis.all_gather(ids_l)
        eq = ids[None, :] == ids[:, None]
        first = jnp.argmax(eq, axis=1).astype(jnp.int32)
        return jnp.where(ids >= 0, first, -1)

    mapped = axis.shard_map(body, in_specs=(axis.spec(),),
                            out_specs=axis.rep())
    return mapped(pool_ids)


class PooledEstimatorBank:
    """An :class:`EstimatorBank` routed through a mutable server -> row map.

    ``pools`` labels each server with an arbitrary hashable pool id (servers
    sharing a label share a row); ``None`` puts every server in its own pool
    (plain per-server estimation through the same code path). ``axis`` (a
    :class:`~repro.distributed.server_axis.ServerAxis`) namespaces the
    labels per shard via :func:`shard_local_pools`, enforcing the
    pool-locality contract the sharded closed loop relies on.
    """

    def __init__(
        self,
        estimators: Sequence[StreamingEstimator],
        pools: Sequence[Hashable] | None = None,
        axis=None,
    ):
        self.bank = EstimatorBank(list(estimators))
        m = len(self.bank.estimators)
        if pools is None:
            pools = list(range(m))
        if len(pools) != m:
            raise ValueError(f"{len(pools)} pool labels for {m} estimators")
        if axis is not None and axis.is_sharded:
            pools = shard_local_pools(list(pools), m, axis.shards)
        leader: dict[Hashable, int] = {}
        self.row_of = np.empty(m, np.int32)  # -1 once dropped
        for s, lab in enumerate(pools):
            self.row_of[s] = leader.setdefault(lab, s)
        self._read_row = self.row_of.copy()  # survives drop() for reads
        self._row_map = jnp.asarray(self.row_of)
        #: (src_row, dst_row) when the last split()/drop() migrated a pool to
        #: a new leader row, else None -- consumers holding per-row state
        #: keyed on pool rows (the drift detector's centering EWMA) move the
        #: same rows to stay aligned
        self.last_migration: tuple[int, int] | None = None

    # -- introspection -----------------------------------------------------
    @property
    def m(self) -> int:
        return len(self.bank.estimators)

    @property
    def estimators(self) -> list[StreamingEstimator]:
        return self.bank.estimators

    def members(self, server: int) -> tuple[int, ...]:
        """Servers currently sharing ``server``'s row (itself included)."""
        row = self.row_of[server]
        if row < 0:
            return ()
        return tuple(int(s) for s in np.flatnonzero(self.row_of == row))

    def pool_size(self, server: int) -> int:
        return len(self.members(server))

    # -- the fused update --------------------------------------------------
    def update_device(self, block: RingBlock, sync: bool = True):
        """One fused observe -> estimate step through the pool map.

        A pooled row consumes every member's rows in the same pass (the
        ~m x warm-up), dropped servers contribute nothing; otherwise
        identical to ``EstimatorBank.update_device``.
        """
        return self.bank.update_device(block, sync=sync, row_map=self._row_map)

    # -- reads -------------------------------------------------------------
    def estimator_for(self, server: int) -> StreamingEstimator:
        """The estimator whose state backs ``server`` (shared when pooled).

        Evicted servers keep resolving to their last row, so consumers
        holding a reference never see a hole.
        """
        return self.bank.estimators[int(self._read_row[server])]

    def estimate_D(self) -> list[np.ndarray]:
        """Per-server D estimates, computed once per live row."""
        cache: dict[int, np.ndarray] = {}
        out = []
        for s in range(self.m):
            row = int(self._read_row[s])
            if row not in cache:
                cache[row] = self.bank.estimators[row].estimate_D()
            out.append(cache[row])
        return out

    def refs(self):
        """(log_b [m_rows, T], L_t [m_rows, T, T] target-major, row_map [m])
        -- the pooled model as device arrays, for the drift detector's
        residual computation. Reads the bank's live stacked state directly
        (no member flush, no host round trip)."""
        st = self.bank.stacked_state()
        return st.log_b, st.L_t, self._row_map

    # -- topology changes (the controller's actions) -----------------------
    def split(self, server: int) -> bool:
        """Split ``server`` out of its pool onto its own row.

        The departing row is seeded with the pool posterior (estimates and
        confidence -- ``EstimatorBank.copy_row``), so both sides continue
        from the shared warm state and diverge only with future telemetry.
        Returns False (no-op) when the server is already solo or dropped.
        A leader split records the pool's row move in ``last_migration``.
        """
        self.last_migration = None
        src = int(self.row_of[server])
        if src < 0:
            return False
        group = [s for s in range(self.m) if self.row_of[s] == src]
        if len(group) <= 1:
            return False
        if src == server:
            # the leader is leaving: the pool migrates to a new leader row
            # (seeded from the shared posterior) and the leader keeps src
            rest = [s for s in group if s != server]
            new = min(rest)
            self.bank.copy_row(src, new)
            for s in rest:
                self.row_of[s] = new
                self._read_row[s] = new
            self.last_migration = (src, new)
        else:
            self.bank.copy_row(src, server)
            self.row_of[server] = server
            self._read_row[server] = server
        self._row_map = jnp.asarray(self.row_of)
        return True

    def adopt_rows(self, row_of, read_row) -> None:
        """Adopt routing computed off-host.

        The device-resident closed loop (``core.closed_loop``) applies
        splits and drops as array ops inside its scan; after the run the
        host mirror swallows the final maps whole instead of replaying each
        action. Any pending ``last_migration`` is cleared -- per-row
        consumer state was already moved on device.
        """
        self.last_migration = None
        self.row_of = np.asarray(row_of, np.int32).copy()
        self._read_row = np.asarray(read_row, np.int32).copy()
        self._row_map = jnp.asarray(self.row_of)

    def drop(self, server: int) -> None:
        """Stop routing ``server``'s observations anywhere (eviction).

        If the server *led* a pool with other members, the pool migrates to
        a new leader row first (:meth:`split` semantics, recorded in
        ``last_migration``) so survivors keep their shared state; a
        non-leader member just leaves (its dormant row is never touched).
        Reads continue resolving to the last live row either way.
        """
        self.last_migration = None
        if self.row_of[server] == server and self.pool_size(server) > 1:
            self.split(server)  # leader: detach the survivors first
        self.row_of[server] = -1
        self._row_map = jnp.asarray(self.row_of)
