"""ConsolidationEngine: one front-end over the consolidation runtime backends.

The repo used to have three disconnected consolidation paths:

  * ``core/binpack.py`` + ``core/scheduler.py`` -- pure-Python greedy and the
    event-driven ``OnlineScheduler`` (heapq + numpy);
  * ``core/binpack_jax.py`` -- the jitted greedy over arrival *sequences*,
    with no notion of time, completions, or queue draining;
  * ``kernels/consolidation.py`` -- the Pallas Q x m candidate scorer.

This module unifies them. ``ConsolidationEngine`` exposes the paper's full
online operating model (arrive -> score -> place-or-queue -> run -> complete
-> drain, §V/§VIII) behind one API with two runtime backends:

  backend='jax'    the device-resident ``engine_jax.run_trace`` scan;
  backend='numpy'  the demoted pure-Python ``OnlineScheduler``, kept as the
                   reference oracle the JAX engine is parity-tested against;
  backend='auto'   numpy below ``AUTO_JAX_THRESHOLD`` arrivals (jit overhead
                   dominates tiny traces), jax at scale.

Candidate scoring is a *separate* axis: all runtime backends consume the same
(counts, wtypes) -> (cache_after, maxd_after) scoring interface, provided by

  scorer='jnp'     ``binpack_jax.score_candidates_jnp`` (default, any device);
  scorer='pallas'  the Pallas kernel -- the fleet-scale Q x m path on TPU
                   (interpret mode elsewhere);
  scorer='numpy'   ``kernels.ref.consolidation_scores_ref`` -- host-side
                   float64 reference for contract tests (not jit-able).

See DESIGN.md §8 for the backend matrix and the architecture notes.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Literal, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from .binpack import ClusterState, greedy_place
from .binpack_jax import PackedCluster, score_candidates_jnp
from .contention import profile_pairwise_fast
from .engine_jax import QUEUED, PackedDynamics, Scorer, run_trace
from .scheduler import OnlineScheduler
from .server import ServerSpec
from .workload import Workload, type_index

Backend = Literal["auto", "jax", "numpy"]
ScorerName = Literal["jnp", "pallas", "numpy"]

#: below this many arrivals the oracle outruns a fresh jit compile
AUTO_JAX_THRESHOLD = 32


@functools.lru_cache(maxsize=None)
def make_scorer(backend: ScorerName = "jnp", interpret: bool | None = None) -> Scorer:
    """Resolve a scoring-backend name to the shared-interface callable.

    Cached so the returned closure is identity-stable -- ``run_trace`` treats
    the scorer as a static jit argument and would otherwise recompile per
    call.
    """
    if backend == "jnp":
        return score_candidates_jnp
    if backend == "pallas":
        from ..kernels.consolidation import consolidation_scores

        if interpret is None:
            interpret = jax.default_backend() != "tpu"

        def pallas_scorer(cluster, counts, wtypes):
            fs_res = cluster.resident * cluster.fs[None, :]
            return consolidation_scores(
                counts, cluster.D, cluster.rs, fs_res, cluster.llc_budget,
                jnp.atleast_1d(wtypes), interpret=interpret)

        return pallas_scorer
    if backend == "numpy":
        from ..kernels.ref import consolidation_scores_ref

        def numpy_scorer(cluster, counts, wtypes):
            return consolidation_scores_ref(
                counts, cluster.D, np.asarray(cluster.rs), np.asarray(cluster.fs),
                np.asarray(cluster.llc_budget), np.asarray(cluster.resident),
                jnp.atleast_1d(wtypes))

        return numpy_scorer
    raise ValueError(f"unknown scorer backend {backend!r}")


def score_candidates(
    cluster: PackedCluster, counts, wtypes, backend: ScorerName = "jnp"
) -> tuple[jax.Array, jax.Array]:
    """The shared scoring interface, dispatched by backend name."""
    return make_scorer(backend)(cluster, jnp.asarray(counts), jnp.asarray(wtypes))


@dataclasses.dataclass(frozen=True)
class EngineResult:
    """Backend-independent outcome of one arrival trace."""

    placements: tuple[int | None, ...]  # final server per arrival (None = never ran)
    was_queued: tuple[bool, ...]  # §V queue decision at arrival time
    place_times: tuple[float, ...]  # -1 where never placed
    finish_times: tuple[float, ...]  # +inf where never finished
    makespan: float
    max_observed_degradation: float
    backend: str

    @property
    def queued_indices(self) -> tuple[int, ...]:
        return tuple(i for i, q in enumerate(self.was_queued) if q)


class ConsolidationEngine:
    """The unified online consolidation runtime (see module docstring)."""

    def __init__(
        self,
        servers: Sequence[ServerSpec],
        D: Sequence[np.ndarray] | np.ndarray | None = None,
        alpha: float | Sequence[float] = 1.3,
        objective: str = "sum_avg",
        backend: Backend = "auto",
        scorer: ScorerName = "jnp",
    ):
        if scorer == "numpy":
            # fail at construction, not at the trace length where 'auto'
            # happens to pick the jax runtime: the host-side float64 scorer
            # cannot run inside the jitted engine
            raise ValueError(
                "scorer='numpy' is the host-side float64 reference for "
                "score_candidates(); the engine runtimes take scorer "
                "'jnp' or 'pallas' (use backend='numpy' for the oracle)")
        self.servers = tuple(servers)
        if D is None:
            # keyed by the frozen spec value, not its name: same-name variant
            # specs (dataclasses.replace) must not share a profiling pass
            cache: dict[ServerSpec, np.ndarray] = {}
            for s in self.servers:  # identical specs share one profiling pass
                if s not in cache:
                    cache[s] = profile_pairwise_fast(s)
            D = [cache[s] for s in self.servers]
        elif isinstance(D, np.ndarray):
            D = [D] * len(self.servers)
        self.D = list(D)
        self.alpha = alpha
        self.objective = objective
        self.backend = backend
        self.scorer = scorer
        self.cluster = PackedCluster.build(list(self.servers), self.D, alpha)
        self._dyn: PackedDynamics | None = None

    @property
    def dyn(self) -> PackedDynamics:
        """Ground-truth rate tables, built on first device-backend use."""
        if self._dyn is None:
            self._dyn = PackedDynamics.build(self.servers)
        return self._dyn

    # -- public API -------------------------------------------------------
    def run(
        self, arrivals: Sequence[tuple[float, Workload]], backend: Backend | None = None
    ) -> EngineResult:
        """Simulate arrivals [(time, workload)] to completion of all work.

        Workloads are snapped to the profiling grid (as the paper's scheduler
        snaps every candidate for its D-matrix lookup); ``data_total`` is
        honoured per arrival. Raises ``RuntimeError`` on deadlock (a queued
        workload no *empty* server can take), like the oracle.
        """
        if not arrivals:
            return EngineResult((), (), (), (), 0.0, 0.0, "empty")
        backend = backend or self.backend
        if backend == "auto":
            backend = "jax" if len(arrivals) >= AUTO_JAX_THRESHOLD else "numpy"
        if backend == "jax":
            return self._run_jax(arrivals)
        if backend == "numpy":
            return self._run_oracle(arrivals)
        raise ValueError(f"unknown engine backend {backend!r}")

    # -- device backend ---------------------------------------------------
    def _run_jax(self, arrivals: Sequence[tuple[float, Workload]]) -> EngineResult:
        n = len(arrivals)
        times = np.asarray([t for t, _ in arrivals], np.float64)
        order = np.argsort(times, kind="stable")
        # normalize to the first arrival before the f32 cast: absolute
        # epoch-scale timestamps would otherwise collapse below f32 resolution
        t0 = float(times.min()) if n else 0.0
        arr_time = jnp.asarray(times[order] - t0, jnp.float32)
        arr_type = jnp.asarray([type_index(arrivals[i][1]) for i in order], jnp.int32)
        arr_bytes = jnp.asarray([arrivals[i][1].data_total for i in order], jnp.float32)

        # scorer='jnp' -> None: run_trace's incremental evaluation of the same
        # contract (no per-step counts @ D re-reduction); other backends are
        # routed through the generic interface.
        scorer = None if self.scorer == "jnp" else make_scorer(self.scorer)
        trace = run_trace(
            self.cluster, self.dyn, arr_time, arr_type, arr_bytes,
            objective=self.objective, scorer=scorer)
        if bool(trace.deadlock):
            raise RuntimeError("deadlock: queued workloads fit no empty server")

        inv = np.empty(n, np.int64)
        inv[order] = np.arange(n)
        placement = np.asarray(trace.placement)[inv]
        was_queued = np.asarray(trace.was_queued)[inv]
        place_time = np.asarray(trace.place_time, np.float64)[inv]
        finish_time = np.asarray(trace.finish_time, np.float64)[inv]
        place_time = np.where(place_time >= 0.0, place_time + t0, place_time)
        finish_time = np.where(np.isfinite(finish_time), finish_time + t0, finish_time)
        return EngineResult(
            placements=tuple(int(p) if p != QUEUED else None for p in placement),
            was_queued=tuple(bool(q) for q in was_queued),
            place_times=tuple(float(t) for t in place_time),
            finish_times=tuple(float(t) for t in finish_time),
            makespan=float(trace.makespan) + t0,
            max_observed_degradation=float(trace.max_deg),
            backend="jax",
        )

    # -- reference oracle -------------------------------------------------
    def _run_oracle(self, arrivals: Sequence[tuple[float, Workload]]) -> EngineResult:
        from .workload import snap_to_grid

        state = ClusterState.empty(list(self.servers), self.D, self.alpha)
        place = functools.partial(greedy_place, objective=self.objective)
        sched = OnlineScheduler(state, place=place)
        # distinct object identities per arrival so events map back uniquely
        # (callers may legitimately pass the same Workload object many times)
        copies = [(t, dataclasses.replace(snap_to_grid(w))) for t, w in arrivals]
        result = sched.run(copies)

        idx_of = {id(w): i for i, (_, w) in enumerate(copies)}
        n = len(copies)
        was_queued = [False] * n
        place_time = [-1.0] * n
        finish_time = [float("inf")] * n
        for e in result.events:
            i = idx_of.get(id(e.workload))
            if i is None:
                continue
            if e.kind == "queue":
                was_queued[i] = True
            elif e.kind == "place":
                place_time[i] = e.time
            elif e.kind == "finish":
                finish_time[i] = e.time
        return EngineResult(
            placements=tuple(result.placements[i] for i in range(n)),
            was_queued=tuple(was_queued),
            place_times=tuple(place_time),
            finish_times=tuple(finish_time),
            makespan=float(result.makespan),
            max_observed_degradation=float(result.max_observed_degradation),
            backend="numpy",
        )
