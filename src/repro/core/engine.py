"""ConsolidationEngine: one front-end over the consolidation runtime backends.

The repo used to have three disconnected consolidation paths:

  * ``core/binpack.py`` + ``core/scheduler.py`` -- pure-Python greedy and the
    event-driven ``OnlineScheduler`` (heapq + numpy);
  * ``core/binpack_jax.py`` -- the jitted greedy over arrival *sequences*,
    with no notion of time, completions, or queue draining;
  * ``kernels/consolidation.py`` -- the Pallas Q x m candidate scorer.

This module unifies them. ``ConsolidationEngine`` exposes the paper's full
online operating model (arrive -> score -> place-or-queue -> run -> complete
-> drain, §V/§VIII) behind one API with two runtime backends:

  backend='jax'    the device-resident ``engine_jax.run_trace`` scan;
  backend='numpy'  the demoted pure-Python ``OnlineScheduler``, kept as the
                   reference oracle the JAX engine is parity-tested against;
  backend='auto'   numpy below ``AUTO_JAX_THRESHOLD`` arrivals (jit overhead
                   dominates tiny traces), jax at scale.

Candidate scoring is a *separate* axis: all runtime backends consume the same
(counts, wtypes) -> (cache_after, maxd_after) scoring interface, provided by

  scorer='jnp'     ``binpack_jax.score_candidates_jnp`` (default, any device);
  scorer='pallas'  the Pallas kernel -- the fleet-scale Q x m path on TPU
                   (interpret mode elsewhere);
  scorer='numpy'   ``kernels.ref.consolidation_scores_ref`` -- host-side
                   float64 reference for contract tests (not jit-able).

``AdaptiveEngine`` closes the observe -> estimate -> schedule loop on top of
this: it feeds telemetry-enabled runs into streaming D-estimators
(``repro.telemetry``) and places each trace segment from the *estimated*
dynamics while the simulator stays ground truth (DESIGN.md §9).

See DESIGN.md §8 for the backend matrix and the architecture notes.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import TYPE_CHECKING, Callable, Literal, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from .binpack import ClusterState, greedy_place
from .binpack_jax import PackedCluster, score_candidates_jnp
from .contention import profile_pairwise_fast, type_tables
from .engine_jax import QUEUED, PackedDynamics, Scorer, run_trace
from .scheduler import OnlineScheduler
from .server import ServerSpec
from .workload import FS_GRID, RS_GRID, Workload, type_index
from ..obs import metrics as obs_metrics
from ..obs import recorder as obs_recorder
from ..obs import trace as obs_trace
from ..obs.metrics import MetricFrame
from ..obs.recorder import DecisionRing
from ..telemetry.estimator import EstimatorBank, ScatterName, StreamingEstimator
from ..telemetry.log import (
    ObservationLog,
    ObservationRing,
    RingBlock,
    observations_from_trace,
    rows_from_trace,
)

if TYPE_CHECKING:
    from ..fleet.controller import FleetController, HealthEvent
    from ..telemetry.drift import DriftSchedule

Backend = Literal["auto", "jax", "numpy"]
ScorerName = Literal["jnp", "pallas", "numpy"]

#: below this many arrivals the oracle outruns a fresh jit compile
AUTO_JAX_THRESHOLD = 32


@functools.lru_cache(maxsize=None)
def make_scorer(backend: ScorerName = "jnp", interpret: bool | None = None) -> Scorer:
    """Resolve a scoring-backend name to the shared-interface callable.

    Cached so the returned closure is identity-stable -- ``run_trace`` treats
    the scorer as a static jit argument and would otherwise recompile per
    call.
    """
    if backend == "jnp":
        return score_candidates_jnp
    if backend == "pallas":
        from ..kernels.consolidation import consolidation_scores

        if interpret is None:
            interpret = jax.default_backend() != "tpu"

        def pallas_scorer(cluster, counts, wtypes):
            fs_res = cluster.resident * cluster.fs[None, :]
            return consolidation_scores(
                counts, cluster.D, cluster.rs, fs_res, cluster.llc_budget,
                jnp.atleast_1d(wtypes), interpret=interpret)

        return pallas_scorer
    if backend == "numpy":
        from ..kernels.ref import consolidation_scores_ref

        def numpy_scorer(cluster, counts, wtypes):
            return consolidation_scores_ref(
                counts, cluster.D, np.asarray(cluster.rs), np.asarray(cluster.fs),
                np.asarray(cluster.llc_budget), np.asarray(cluster.resident),
                jnp.atleast_1d(wtypes))

        return numpy_scorer
    raise ValueError(f"unknown scorer backend {backend!r}")


def score_candidates(
    cluster: PackedCluster, counts, wtypes, backend: ScorerName = "jnp"
) -> tuple[jax.Array, jax.Array]:
    """The shared scoring interface, dispatched by backend name."""
    return make_scorer(backend)(cluster, jnp.asarray(counts), jnp.asarray(wtypes))


@dataclasses.dataclass(frozen=True)
class EngineResult:
    """Backend-independent outcome of one arrival trace."""

    placements: tuple[int | None, ...]  # final server per arrival (None = never ran)
    was_queued: tuple[bool, ...]  # §V queue decision at arrival time
    place_times: tuple[float, ...]  # -1 where never placed
    finish_times: tuple[float, ...]  # +inf where never finished
    makespan: float
    max_observed_degradation: float
    backend: str
    observations: ObservationLog | None = None  # filled when run(telemetry=True)
    #: device-resident observation rows (run(telemetry='device')): the same
    #: records as ``observations`` but as a validity-masked RingBlock that
    #: never left the device -- what AdaptiveEngine's stream mode folds into
    #: its ObservationRing
    stream_block: RingBlock | None = None
    #: in-carry metrics plane (run(metrics=True)): queue depth, waiting time,
    #: Eqn-4 headroom, slowdown, per-server floor violations (repro.obs)
    metrics: MetricFrame | None = None
    #: decision flight recorder state (run(record=True)): one provenance row
    #: per placement commit / queue decision, in trace (arrival-sorted) order
    decisions: "obs_recorder.RecState | None" = None

    @property
    def queued_indices(self) -> tuple[int, ...]:
        return tuple(i for i, q in enumerate(self.was_queued) if q)


class ConsolidationEngine:
    """The unified online consolidation runtime (see module docstring)."""

    def __init__(
        self,
        servers: Sequence[ServerSpec],
        D: Sequence[np.ndarray] | np.ndarray | None = None,
        alpha: float | Sequence[float] = 1.3,
        objective: str = "sum_avg",
        backend: Backend = "auto",
        scorer: ScorerName = "jnp",
        active: Sequence[bool] | np.ndarray | None = None,
    ):
        if scorer == "numpy":
            # fail at construction, not at the trace length where 'auto'
            # happens to pick the jax runtime: the host-side float64 scorer
            # cannot run inside the jitted engine
            raise ValueError(
                "scorer='numpy' is the host-side float64 reference for "
                "score_candidates(); the engine runtimes take scorer "
                "'jnp' or 'pallas' (use backend='numpy' for the oracle)")
        self.servers = tuple(servers)
        if D is None:
            # keyed by the frozen spec value, not its name: same-name variant
            # specs (dataclasses.replace) must not share a profiling pass
            cache: dict[ServerSpec, np.ndarray] = {}
            for s in self.servers:  # identical specs share one profiling pass
                if s not in cache:
                    cache[s] = profile_pairwise_fast(s)
            D = [cache[s] for s in self.servers]
        elif isinstance(D, np.ndarray):
            D = [D] * len(self.servers)
        self.D = list(D)
        self.alpha = alpha
        self.objective = objective
        self.backend = backend
        self.scorer = scorer
        self._active: np.ndarray | None = (  # fleet-health placement mask
            None if active is None else np.asarray(active, bool))
        self.cluster = PackedCluster.build(
            list(self.servers), self.D, alpha, active=self._active)
        self._dyn: PackedDynamics | None = None

    @property
    def dyn(self) -> PackedDynamics:
        """Ground-truth rate tables, built on first device-backend use."""
        if self._dyn is None:
            self._dyn = PackedDynamics.build(self.servers)
        return self._dyn

    def set_D(
        self,
        D: Sequence[np.ndarray] | np.ndarray,
        active: Sequence[bool] | np.ndarray | None = None,
    ) -> None:
        """Swap the scoring D-matrices in place, rebuilding only what depends
        on them (the PackedCluster). The ground-truth ``PackedDynamics`` and
        the jitted trace programs key on server specs, not D, so a closed
        loop refreshing its estimate every segment pays for one [m, T, T]
        restack instead of a full engine rebuild. ``active`` optionally
        swaps the placement mask in the same build (the fleet loop updates
        both per segment; two separate calls would restack twice)."""
        if active is not None:
            self._active = self._check_mask(active)
        if isinstance(D, np.ndarray):
            D = [D] * len(self.servers)
        self.D = list(D)
        self.cluster = PackedCluster.build(
            list(self.servers), self.D, self.alpha, active=self._active)

    def _check_mask(self, active) -> np.ndarray:
        mask = np.asarray(active, bool)
        if mask.shape != (len(self.servers),):
            raise ValueError(
                f"active mask shape {mask.shape} != ({len(self.servers)},)")
        return mask

    def set_active(self, active: Sequence[bool] | np.ndarray) -> None:
        """Swap the fleet-health placement mask (True = eligible).

        Masked servers stay in every table -- shapes are unchanged, so the
        jitted trace programs are not re-traced -- but candidate scoring
        treats them as infeasible (``binpack_jax.greedy_choice`` and the
        engine's internal pick both veto them), so they receive no further
        placements. Masking lives in the device scoring path only: the numpy
        reference oracle does not consume it (``run`` refuses the
        combination).
        """
        mask = self._check_mask(active)
        if self._active is not None and np.array_equal(mask, self._active):
            return
        if self._active is None and mask.all():
            self._active = mask  # cluster is already all-active
            return
        self._active = mask
        self.cluster = PackedCluster.build(
            list(self.servers), self.D, self.alpha, active=mask)

    # -- public API -------------------------------------------------------
    def run(
        self,
        arrivals: Sequence[tuple[float, Workload]],
        backend: Backend | None = None,
        *,
        telemetry: bool | Literal["host", "device"] = False,
        metrics: bool = False,
        record: bool = False,
        rec: "obs_recorder.RecState | None" = None,
        rec_ctx: "obs_recorder.RecCtx | None" = None,
    ) -> EngineResult:
        """Simulate arrivals [(time, workload)] to completion of all work.

        Workloads are snapped to the profiling grid (as the paper's scheduler
        snaps every candidate for its D-matrix lookup); ``data_total`` is
        honoured per arrival. Raises ``RuntimeError`` on deadlock (a queued
        workload no *empty* server can take), like the oracle.

        ``telemetry=True`` (or ``'host'``) attaches the completion-observation
        log (``repro.telemetry.ObservationLog``) to the result -- the input
        of the streaming D-estimator's host path. ``'device'`` attaches the
        same records as a device-resident validity-masked ``stream_block``
        instead, never materializing a host log (the fleet-scale path:
        ``ObservationRing`` / ``StreamingEstimator.update_device``).
        Telemetry is emitted by the device engine's event loop, so it
        requires (and, under 'auto', selects) the jax backend.

        ``metrics=True`` threads the ``repro.obs`` MetricFrame through the
        event loop and attaches it as ``result.metrics`` (waiting-time /
        headroom / slowdown histograms, queue depth, per-server floor
        violations). Like telemetry, a device-engine feature: 'auto' selects
        jax for it.

        ``record=True`` threads the decision flight recorder through the
        event loop and attaches the resulting ring state as
        ``result.decisions`` (``obs.recorder``): one provenance row per
        placement commit or queue decision, decision-identical to an
        unrecorded run. ``rec`` continues an existing ring across calls and
        ``rec_ctx`` supplies estimator/detector context to sample; both
        default per run. A device-engine feature like the others.
        """
        if telemetry not in (False, True, "host", "device"):
            raise ValueError(f"unknown telemetry mode {telemetry!r}")
        backend = backend or self.backend
        masked = self._active is not None and not self._active.all()
        if backend == "auto":
            # telemetry, metrics, recording, and the fleet-health mask are
            # device-engine features: 'auto' selects jax for them regardless
            # of trace length
            backend = ("jax" if telemetry or masked or metrics or record
                       or len(arrivals) >= AUTO_JAX_THRESHOLD else "numpy")
        if backend not in ("jax", "numpy"):
            raise ValueError(f"unknown engine backend {backend!r}")
        if telemetry and backend != "jax":
            raise ValueError("telemetry requires the jax engine backend")
        if metrics and backend != "jax":
            raise ValueError("metrics requires the jax engine backend")
        if record and backend != "jax":
            raise ValueError("record requires the jax engine backend")
        if backend == "numpy" and masked:
            raise ValueError("server masking (set_active) requires the jax "
                             "engine backend; the numpy oracle has no mask")
        if not arrivals:
            obs = (ObservationLog.empty(self.cluster.T)
                   if telemetry in (True, "host") else None)
            frame = obs_metrics.zeros(len(self.servers)) if metrics else None
            return EngineResult((), (), (), (), 0.0, 0.0, backend, obs,
                                metrics=frame, decisions=rec if record else None)
        if backend == "jax":
            return self._run_jax(arrivals, telemetry=telemetry,
                                 metrics=metrics, record=record, rec=rec,
                                 rec_ctx=rec_ctx)
        return self._run_oracle(arrivals)

    # -- device backend ---------------------------------------------------
    def _run_jax(
        self,
        arrivals: Sequence[tuple[float, Workload]],
        telemetry: bool | Literal["host", "device"] = False,
        metrics: bool = False,
        record: bool = False,
        rec: "obs_recorder.RecState | None" = None,
        rec_ctx: "obs_recorder.RecCtx | None" = None,
    ) -> EngineResult:
        n = len(arrivals)
        times = np.asarray([t for t, _ in arrivals], np.float64)
        order = np.argsort(times, kind="stable")
        # normalize to the first arrival before the f32 cast: absolute
        # epoch-scale timestamps would otherwise collapse below f32 resolution
        t0 = float(times.min()) if n else 0.0
        arr_time = jnp.asarray(times[order] - t0, jnp.float32)
        arr_type = jnp.asarray([type_index(arrivals[i][1]) for i in order], jnp.int32)
        arr_bytes = jnp.asarray([arrivals[i][1].data_total for i in order], jnp.float32)

        # scorer='jnp' -> None: run_trace's incremental evaluation of the same
        # contract (no per-step counts @ D re-reduction); other backends are
        # routed through the generic interface.
        scorer = None if self.scorer == "jnp" else make_scorer(self.scorer)
        trace = run_trace(
            self.cluster, self.dyn, arr_time, arr_type, arr_bytes,
            objective=self.objective, scorer=scorer, telemetry=bool(telemetry),
            metrics=metrics, record=record, rec=rec, rec_ctx=rec_ctx)
        if bool(trace.deadlock):
            raise RuntimeError("deadlock: queued workloads fit no empty server")
        # observation records are per-run; the trace's arrival-sorted order is
        # as good as submission order, so no inverse permutation is needed
        obs = block = None
        if telemetry == "device":
            block = rows_from_trace(trace, arr_type)
        elif telemetry:
            obs = observations_from_trace(trace, arr_type, arr_bytes)

        inv = np.empty(n, np.int64)
        inv[order] = np.arange(n)
        placement = np.asarray(trace.placement)[inv]
        was_queued = np.asarray(trace.was_queued)[inv]
        place_time = np.asarray(trace.place_time, np.float64)[inv]
        finish_time = np.asarray(trace.finish_time, np.float64)[inv]
        place_time = np.where(place_time >= 0.0, place_time + t0, place_time)
        finish_time = np.where(np.isfinite(finish_time), finish_time + t0, finish_time)
        return EngineResult(
            placements=tuple(int(p) if p != QUEUED else None for p in placement),
            was_queued=tuple(bool(q) for q in was_queued),
            place_times=tuple(float(t) for t in place_time),
            finish_times=tuple(float(t) for t in finish_time),
            makespan=float(trace.makespan) + t0,
            max_observed_degradation=float(trace.max_deg),
            backend="jax",
            observations=obs,
            stream_block=block,
            metrics=trace.metrics,
            decisions=trace.rec,
        )

    # -- reference oracle -------------------------------------------------
    def _run_oracle(self, arrivals: Sequence[tuple[float, Workload]]) -> EngineResult:
        from .workload import snap_to_grid

        state = ClusterState.empty(list(self.servers), self.D, self.alpha)
        place = functools.partial(greedy_place, objective=self.objective)
        sched = OnlineScheduler(state, place=place)
        # distinct object identities per arrival so events map back uniquely
        # (callers may legitimately pass the same Workload object many times)
        copies = [(t, dataclasses.replace(snap_to_grid(w))) for t, w in arrivals]
        result = sched.run(copies)

        idx_of = {id(w): i for i, (_, w) in enumerate(copies)}
        n = len(copies)
        was_queued = [False] * n
        place_time = [-1.0] * n
        finish_time = [float("inf")] * n
        for e in result.events:
            i = idx_of.get(id(e.workload))
            if i is None:
                continue
            if e.kind == "queue":
                was_queued[i] = True
            elif e.kind == "place":
                place_time[i] = e.time
            elif e.kind == "finish":
                finish_time[i] = e.time
        return EngineResult(
            placements=tuple(result.placements[i] for i in range(n)),
            was_queued=tuple(was_queued),
            place_times=tuple(place_time),
            finish_times=tuple(finish_time),
            makespan=float(result.makespan),
            max_observed_degradation=float(result.max_observed_degradation),
            backend="numpy",
        )


# --- the closed observe -> estimate -> schedule loop ----------------------------

#: the paper's profiling grid size (10 RS x 23 FS)
GRID_T = len(RS_GRID) * len(FS_GRID)


@dataclasses.dataclass(frozen=True)
class AdaptiveResult:
    """Outcome of one :meth:`AdaptiveEngine.run`: per-segment engine results."""

    segments: tuple[EngineResult, ...]
    n_obs: tuple[int, ...]  # observations consumed by the estimators per segment
    t_starts: tuple[float, ...]  # first arrival time per segment
    #: fleet-health events fired after each segment (empty without a fleet
    #: controller): splits and evictions, in the order they were taken
    health: "tuple[tuple[HealthEvent, ...], ...]" = ()
    #: merged run-level MetricFrame (run(metrics=True)): the per-segment
    #: engine frames folded together plus the closed-loop accounting
    #: (segments/splits/evictions/requeues/ring occupancy). The counters
    #: shared with ``health`` match it exactly; the cusum_level histogram
    #: and d_cols_refreshed counter are device-loop-only (the host path
    #: rebuilds D wholesale and keeps detector stats in host objects)
    metrics: MetricFrame | None = None
    #: the engine's decision flight recorder after the run (run(record=True)):
    #: the host mirror whose ring holds every recorded placement decision,
    #: oldest overwritten first once capacity wraps (``obs.recorder``)
    decisions: "DecisionRing | None" = None

    @property
    def makespans(self) -> tuple[float, ...]:
        """Absolute completion time per segment (the engine's makespan)."""
        return tuple(r.makespan for r in self.segments)

    @property
    def durations(self) -> tuple[float, ...]:
        """First-arrival -> last-completion span per segment: the quantity
        comparable across segments (and against an oracle run of the same
        chunk), independent of where the chunk sits on the trace clock."""
        return tuple(r.makespan - t0 for r, t0 in zip(self.segments, self.t_starts))

    @property
    def total_obs(self) -> int:
        return int(sum(self.n_obs))


class AdaptiveEngine:
    """The closed-loop front-end: place from *estimated* dynamics, observe the
    (simulated) world, refresh the estimate, repeat.

    This is the first subsystem where the scheduler's model and the world can
    disagree. A :class:`ConsolidationEngine` consumes its D-matrix as frozen
    ground truth; here the D each placement consults comes from a per-server
    :class:`~repro.telemetry.StreamingEstimator` fed purely by completion
    observations, while the device engine's ``PackedDynamics`` (built from
    the *true* server specs, which a :class:`~repro.telemetry.DriftSchedule`
    may change under the scheduler) remains the ground truth that generates
    those observations.

    ``run`` splits the arrival trace into contiguous segments and alternates:
    run one segment to completion with the current estimate -> fold its
    observation log into the estimators -> rebuild D for the next segment.
    Each segment starts from an empty cluster, so segment makespans are
    directly comparable against a true-D oracle run under the same protocol
    (``benchmarks/adaptive_regret.py`` measures exactly that regret).

    ``stream=True`` is the fleet-scale variant of the same loop: each
    segment runs with ``telemetry='device'``, its observation rows fold into
    a shared device-resident :class:`~repro.telemetry.ObservationRing`, and
    every estimator refresh is one fused ``update_device`` call -- no host
    ``ObservationLog`` is ever materialized (DESIGN.md §10).

    ``fleet=FleetController(...)`` puts the fleet-health control plane
    (``repro.fleet``, DESIGN.md §11) in the loop, implying ``stream=True``:
    the controller binds to this engine's servers and estimators, same-spec
    servers pool onto shared estimator rows (warming up ~m x faster), each
    segment's telemetry block feeds the controller's CUSUM drift detector,
    and its decisions act on the very next segment -- split servers get
    their own seeded estimator, evicted servers are masked out of candidate
    scoring (``set_active``) and their in-flight workloads (placed on the
    evicted server in the detection segment, or never placed) are requeued
    into the following segment. Without a controller, estimators stay
    strictly per-server as before.
    """

    def __init__(
        self,
        servers: Sequence[ServerSpec],
        prior: float | str | np.ndarray | Sequence[np.ndarray] = 0.0,
        alpha: float | Sequence[float] = 1.3,
        objective: str = "sum_avg",
        scorer: ScorerName = "jnp",
        drift: "DriftSchedule | None" = None,
        lr: float = 0.6,
        decay: float = 1.0,
        confidence_floor: float = 2.0,
        max_lost_frac: float = 0.5,
        scatter: ScatterName = "auto",
        stream: bool = False,
        ring_capacity: int = 4096,
        fleet: "FleetController | None" = None,
        decision_capacity: int = 1024,
    ):
        """``prior`` selects what the scheduler believes before any telemetry:
        a scalar is a uniform D prior (0.0 = optimistic "no interference" --
        the fleet consolidates aggressively and learns the cost), 'profiled'
        seeds each estimator with the offline pairwise pass on the *initial*
        spec (stale once drift hits), and an array (or one per server) is an
        explicit prior. Solo base rates always start from the cheap per-type
        solo profile of the initial spec -- it is the 52 900-pair matrix, not
        the 230-run solo pass, that telemetry amortizes away."""
        self.servers = tuple(servers)
        self.alpha = alpha
        self.objective = objective
        self.scorer = scorer
        self.drift = drift
        self.fleet = fleet
        stream = stream or fleet is not None  # the control plane is stream-fed
        self.stream = stream
        self.ring = ObservationRing(ring_capacity, GRID_T) if stream else None
        # the decision flight recorder's host mirror, minted on the first
        # run(record=True) (capacity is spent in decisions, not segments)
        self.decision_capacity = int(decision_capacity)
        self.decisions: DecisionRing | None = None
        # segment-engine cache: under an unchanged world (drift is None, or a
        # schedule window with no event) only the D-matrices move between
        # segments, so the engine -- and with it the PackedDynamics tables and
        # the jitted trace programs keyed on them -- is reused via set_D.
        # Keyed by (specs, active-mask): drift schedules revisit worlds and
        # evictions change the mask between visits, and either a single-slot
        # cache or a specs-only key would rebuild (or cross-wire) engines on
        # the revisit. PackedDynamics is mask-independent, so it caches on
        # specs alone and is shared by all mask variants of a world.
        self._engine_cache: dict[tuple, ConsolidationEngine] = {}
        self._dyn_cache: dict[tuple[ServerSpec, ...], PackedDynamics] = {}

        priors: list[np.ndarray | float]
        if isinstance(prior, str):
            if prior != "profiled":
                raise ValueError(f"unknown prior {prior!r}")
            cache: dict[ServerSpec, np.ndarray] = {}
            for s in self.servers:
                if s not in cache:
                    cache[s] = profile_pairwise_fast(s)
            priors = [cache[s] for s in self.servers]
        elif isinstance(prior, (int, float)):
            priors = [float(prior)] * len(self.servers)
        elif isinstance(prior, np.ndarray):
            priors = [prior] * len(self.servers)
        else:
            priors = list(prior)

        self.estimators = [
            StreamingEstimator(
                T=GRID_T,
                prior_D=priors[i],
                prior_solo=type_tables(s)["solo"],
                lr=lr,
                decay=decay,
                confidence_floor=confidence_floor,
                max_lost_frac=max_lost_frac,
                scatter=scatter,
            )
            for i, s in enumerate(self.servers)
        ]
        #: stream mode refreshes every server's estimator in one fused call;
        #: with a fleet controller the controller's pooled bank is that call
        #: (two banks over the same estimators would fight for their state)
        if fleet is not None:
            fleet.bind(self.servers, self.estimators)
            self.bank = None
        else:
            self.bank = EstimatorBank(self.estimators) if stream else None

    # -- estimates --------------------------------------------------------
    def current_D(self) -> list[np.ndarray]:
        """The per-server D-matrices the next segment's placements will use.

        With a fleet controller these resolve through the pool map: pooled
        servers share their pool's estimate, split servers their own.
        """
        if self.fleet is not None:
            return self.fleet.current_D()
        return [est.estimate_D() for est in self.estimators]

    def engine_for_segment(self, segment: int) -> ConsolidationEngine:
        """A ConsolidationEngine scoring with estimates over the true world.

        Engines are cached across segments: while the specs are unchanged
        only the estimated D moves, and ``set_D`` swaps it without rebuilding
        the ground-truth dynamics (or re-tracing the engine's jit programs).
        When drift changes the specs, the new engine still reuses any
        previously built ``PackedDynamics`` for that world (drift schedules
        revisit worlds: congest -> recover)."""
        specs = (tuple(self.drift.specs_at(self.servers, segment))
                 if self.drift is not None else self.servers)
        mask = self.fleet.active_mask() if self.fleet is not None else None
        key = (specs, None if mask is None else mask.tobytes())
        engine = self._engine_cache.get(key)
        if engine is not None:
            engine.set_D(self.current_D(), active=mask)
            return engine
        engine = ConsolidationEngine(
            list(specs), D=self.current_D(), alpha=self.alpha,
            objective=self.objective, backend="jax", scorer=self.scorer,
            active=mask)
        if specs in self._dyn_cache:
            engine._dyn = self._dyn_cache[specs]
        else:
            self._dyn_cache[specs] = engine.dyn  # builds the tables once
        self._engine_cache[key] = engine
        return engine

    def _decision_ring(self) -> DecisionRing:
        """The recorder's host mirror, minted on first use."""
        if self.decisions is None:
            self.decisions = DecisionRing(self.decision_capacity)
        return self.decisions

    def _recorder_ctx(self, segment: int) -> "obs_recorder.RecCtx":
        """Per-segment recorder context from the live host-side state --
        what the *next* engine dispatch's scheduler will consult."""
        if self.fleet is not None:
            # stamp with the controller's live burn-in clock -- the device
            # loop stamps carry.seen, which starts at _segments_seen
            return self.fleet.recorder_ctx(self.fleet._segments_seen)
        m = len(self.servers)
        if self.bank is not None:
            n_pair = self.bank.stacked_state().n_pair_t
        else:
            n_pair = jnp.asarray(
                np.stack([np.asarray(e.n_pair).T for e in self.estimators]),
                jnp.float32)
        ident = jnp.arange(m, dtype=jnp.int32)
        return obs_recorder.RecCtx(
            n_pair=n_pair, row_of=ident,
            cusum=jnp.zeros((m,), jnp.float32),  # no detector in the loop
            pool_row=ident, segment=jnp.int32(segment))

    # -- the loop ---------------------------------------------------------
    def run(
        self,
        arrivals: Sequence[tuple[float, Workload]],
        segments: int = 8,
        on_segment: Callable[[int, EngineResult, "AdaptiveEngine"], None] | None = None,
        *,
        device_loop: bool = False,
        metrics: bool = False,
        record: bool = False,
    ) -> AdaptiveResult:
        """Alternate ``segments`` trace chunks with estimator refreshes.

        ``on_segment(k, result, self)`` fires after each segment's
        observations have been folded in (and, with a fleet controller,
        after its health actions for the segment) -- benchmarks use it to
        snapshot estimation error and regret as observation volume grows.

        With a fleet controller, an eviction requeues the evicted server's
        in-flight work: the detection segment's arrivals that ran on the
        evicted server (their observed service came from a collapsing
        machine), plus any never-placed arrivals, re-enter at the head of
        the next segment's chunk. An eviction fired by the *final* segment
        has no next chunk; its in-flight work stays reported in that
        segment's result.

        ``device_loop=True`` compiles the whole multi-segment cycle into
        one device program (``core.closed_loop``) instead of alternating
        host and device per segment -- same decisions, same final state, a
        fraction of the dispatch overhead. It requires stream mode, an
        arrival count divisible by ``segments``, structure-preserving drift
        (``llc_bytes``/``llc_tolerance`` fixed), and no ``on_segment``
        callback (there is no host between segments to call it from); this
        host-alternating path remains the reference oracle (DESIGN.md
        section 13).

        ``metrics=True`` threads the ``repro.obs`` MetricFrame through every
        segment and attaches the merged run frame as ``result.metrics``; the
        split/evict/requeue counters bit-match ``result.health`` on both
        paths. On the device loop the frame rides the scan carry; here it is
        merged per segment on the host -- same decision-level counters, with
        the device-only extras noted on :class:`AdaptiveResult`.

        ``record=True`` threads the decision flight recorder through every
        segment's event loop (``obs.recorder``): one provenance row per
        placement, sampling the estimator pair-exposure / detector CUSUM
        state the segment's scheduler consulted, accumulated into one ring
        (``self.decisions``, capacity ``decision_capacity``) across segments
        and returned on ``result.decisions``. Decisions are unchanged; on
        the device loop the ring rides the scan carry.
        """
        if device_loop:
            if on_segment is not None:
                raise ValueError(
                    "device_loop=True runs all segments in one compiled "
                    "program; there is no per-segment host point for "
                    "on_segment -- use the host-alternating path")
            return self._run_device_loop(arrivals, segments, metrics=metrics,
                                         record=record)
        m = len(self.servers)
        frame = obs_metrics.zeros(m) if metrics else None
        ring = self._decision_ring() if record else None
        ordered = sorted(arrivals, key=lambda tw: tw[0])
        bounds = np.linspace(0, len(ordered), segments + 1).astype(int)
        results, n_obs, t_starts, health = [], [], [], []
        requeue: list[Workload] = []
        for k in range(segments):
            chunk = ordered[bounds[k]:bounds[k + 1]]
            if requeue:
                t0 = chunk[0][0] if chunk else 0.0
                chunk = [(t0, w) for w in requeue] + chunk
                requeue = []
            engine = self.engine_for_segment(k)
            rec_kw = (dict(record=True, rec=ring.state,
                           rec_ctx=self._recorder_ctx(k))
                      if record else {})
            events: "tuple[HealthEvent, ...]" = ()
            if self.stream:
                # fleet-scale path: the segment's rows go trace -> ring ->
                # one banked estimator update without leaving the device
                res = engine.run(chunk, telemetry="device", metrics=metrics,
                                 **rec_kw)
                used = 0
                if res.stream_block is not None:
                    # estimators consume the segment's FULL block; the ring
                    # (which keeps only its newest capacity rows) is the
                    # bounded history for re-reads, not the update source
                    self.ring.push(res.stream_block)
                    if self.fleet is not None:
                        used, evs = self.fleet.observe(res.stream_block, segment=k)
                        events = tuple(evs)
                        evicted = {ev.server for ev in evs if ev.kind == "evict"}
                        if evicted:
                            requeue = [w for (t, w), p in
                                       zip(chunk, res.placements)
                                       if p in evicted or p is None]
                    else:
                        used = self.bank.update_device(res.stream_block)
            else:
                res = engine.run(chunk, telemetry=True, metrics=metrics,
                                 **rec_kw)
                used = sum(est.update(res.observations.for_server(s))
                           for s, est in enumerate(self.estimators))
            if record and res.decisions is not None:
                ring.adopt(res.decisions)  # the next segment continues it
            if metrics:
                # the same closed-loop accounting the device scan keeps in
                # its carry, from the host's own bookkeeping
                frame = obs_metrics.merge(frame, res.metrics)
                frame = obs_metrics.count(frame, "segments", 1)
                frame = obs_metrics.count(
                    frame, "splits",
                    sum(1 for ev in events if ev.kind == "split"))
                frame = obs_metrics.count(
                    frame, "evictions",
                    sum(1 for ev in events if ev.kind == "evict"))
                frame = obs_metrics.count(frame, "requeues", len(requeue))
                frame = obs_metrics.gauge_max(
                    frame, "requeue_peak", float(len(requeue)))
                if self.stream:
                    frame = obs_metrics.count(frame, "ring_rows", len(chunk))
                    frame = obs_metrics.gauge_max(
                        frame, "ring_occupancy_peak",
                        float(min(self.ring.total, self.ring.capacity)))
                if self.fleet is not None:
                    frame = obs_metrics.gauge_max(
                        frame, "evicted_peak",
                        float((~self.fleet.active_mask()).sum()))
            results.append(res)
            n_obs.append(used)
            t_starts.append(chunk[0][0] if chunk else 0.0)
            health.append(events)
            if on_segment is not None:
                on_segment(k, res, self)
        return AdaptiveResult(tuple(results), tuple(n_obs), tuple(t_starts),
                              tuple(health), metrics=frame, decisions=ring)

    # -- the fused device-resident loop -----------------------------------
    def _run_device_loop(
        self, arrivals: Sequence[tuple[float, Workload]], segments: int,
        *, metrics: bool = False, record: bool = False,
    ) -> AdaptiveResult:
        """One ``run_closed_loop`` dispatch for the whole multi-segment run.

        Host work is strictly prologue (pack arrivals/dynamics, snapshot the
        live estimator/detector/pool state into the scan carry) and epilogue
        (unpack per-segment results, mirror the final carry back into the
        host objects via ``FleetController.adopt_device_outcome`` /
        ``PooledEstimatorBank.adopt_rows``). Per-segment ``EngineResult``s
        carry no ``observations``/``stream_block``: the telemetry was
        consumed inside the program (the ring holds the bounded history).

        The three host phases are wrapped in ``repro.obs.trace`` spans
        (``closed_loop.pack`` / ``.dispatch`` / ``.epilogue``) so profiler
        traces and span logs separate packing and adoption cost from the
        blocking dispatch (which includes compilation on a cold cache).
        With ``metrics=True`` the MetricFrame rides the scan carry and the
        merged run frame is returned on ``AdaptiveResult.metrics``.
        """
        from ..fleet.detect import CusumState
        from .closed_loop import (
            ClosedLoopConfig,
            LoopCarry,
            SegmentIn,
            run_closed_loop,
        )

        if not self.stream:
            raise ValueError("device_loop=True requires stream mode "
                             "(stream=True or a fleet controller)")
        n = len(arrivals)
        if n == 0 or segments <= 0 or n % segments != 0:
            raise ValueError(
                f"device_loop=True needs a non-empty arrival trace divisible "
                f"by segments (got {n} arrivals / {segments} segments); the "
                f"host-alternating path handles ragged chunks")
        m = len(self.servers)
        n_seg = n // segments
        R = n_seg  # requeue capacity: one segment's worth of in-flight work
        if R + n_seg > self.ring.capacity:
            raise ValueError(
                f"segment size {n_seg} (+{R} requeue slots) exceeds the "
                f"telemetry ring capacity {self.ring.capacity}")
        e0 = self.estimators[0]
        if any(e.confidence_floor != e0.confidence_floor
               for e in self.estimators):
            raise ValueError("device_loop=True blends every row's D with one "
                             "confidence_floor; estimators disagree")

        with obs_trace.span("closed_loop.pack", segments=segments, m=m):
            ordered = sorted(arrivals, key=lambda tw: tw[0])
            times = np.asarray([t for t, _ in ordered], np.float64)
            wtypes = np.asarray([type_index(w) for _, w in ordered], np.int32)
            nbytes = np.asarray([w.data_total for _, w in ordered], np.float64)

            # segments bucket to a power-of-two count (padding masked by
            # seg_valid) so warm runs across different segment counts of the
            # same fleet hit one compilation
            S_cap = 4
            while S_cap < segments:
                S_cap *= 2
            arr_time = np.zeros((S_cap, n_seg), np.float32)
            arr_type = np.zeros((S_cap, n_seg), np.int32)
            arr_bytes = np.ones((S_cap, n_seg), np.float32)
            t0s = []
            for k in range(segments):
                sl = slice(k * n_seg, (k + 1) * n_seg)
                t0 = float(times[k * n_seg])
                t0s.append(t0)
                arr_time[k] = times[sl] - t0
                arr_type[k] = wtypes[sl]
                arr_bytes[k] = nbytes[sl]

            # per-segment worlds, deduplicated into one stacked dynamics bank;
            # the compiled cluster's structural tables must hold for all of them
            structural = [(s.llc_bytes, s.llc_tolerance) for s in self.servers]
            spec_of: dict[tuple[ServerSpec, ...], int] = {}
            dyn_idx = np.zeros(S_cap, np.int32)
            for k in range(segments):
                specs = (tuple(self.drift.specs_at(self.servers, k))
                         if self.drift is not None else self.servers)
                if [(s.llc_bytes, s.llc_tolerance) for s in specs] != structural:
                    raise ValueError(
                        "device_loop=True compiles one cluster for all segments: "
                        "drift may not change llc_bytes/llc_tolerance (run the "
                        "host-alternating path for structural drift)")
                dyn_idx[k] = spec_of.setdefault(specs, len(spec_of))
            for specs in spec_of:
                if specs not in self._dyn_cache:
                    self._dyn_cache[specs] = PackedDynamics.build(list(specs))
            dyn_stack = jax.tree_util.tree_map(
                lambda *a: jnp.stack(a), *(self._dyn_cache[s] for s in spec_of))
            cluster = PackedCluster.build(
                list(self.servers),
                [np.zeros((GRID_T, GRID_T), np.float32)] * m, self.alpha)

            Lp_t = jnp.asarray(
                np.stack([e._L_prior.T for e in self.estimators]), jnp.float32)
            logb_priors = jnp.asarray(
                np.stack([e._logb_prior for e in self.estimators]), jnp.float32)

            scorer = None if self.scorer == "jnp" else make_scorer(self.scorer)
            h = e0._hypers
            est_h = dict(
                lr=h["lr"], decay=h["decay"], step_damp=h["step_damp"],
                solo_eps=h["solo_eps"], est_max_lost_frac=h["max_lost_frac"],
                use_pallas=h["use_pallas"], interpret=h["interpret"])
            frame0 = obs_metrics.zeros(m) if metrics else None
            rec0 = self._decision_ring().state if record else None
            fc = self.fleet
            if fc is not None:
                fc._require_bound()
                config = ClosedLoopConfig(
                    objective=self.objective, scorer=scorer, fleet=True,
                    warmup_segments=fc.warmup_segments, cusum_k=fc.cusum_k,
                    cusum_h=fc.cusum_h, level_decay=fc.level_decay,
                    fail_floor=fc.fail_floor, min_exposure=fc.min_exposure,
                    det_max_lost_frac=fc.max_lost_frac,
                    confidence_floor=float(e0.confidence_floor),
                    metrics=metrics, record=record, **est_h)
                carry0 = LoopCarry(
                    bank=fc.pool.bank.stacked_state(), det=fc.detector.state,
                    row_map=jnp.asarray(fc.pool.row_of, jnp.int32),
                    read_row=jnp.asarray(fc.pool._read_row, jnp.int32),
                    active=jnp.asarray(fc._active),
                    seen=jnp.int32(fc._segments_seen),
                    req_type=jnp.zeros((R,), jnp.int32),
                    req_bytes=jnp.ones((R,), jnp.float32),
                    req_n=jnp.int32(0),
                    ring=self.ring._buf, ring_ptr=jnp.int32(self.ring.ptr),
                    ring_total=jnp.int32(self.ring.total),
                    metrics=frame0, rec=rec0)
            else:
                config = ClosedLoopConfig(
                    objective=self.objective, scorer=scorer, fleet=False,
                    confidence_floor=float(e0.confidence_floor),
                    metrics=metrics, record=record, **est_h)
                carry0 = LoopCarry(
                    bank=self.bank.stacked_state(), det=CusumState.zeros(m),
                    row_map=jnp.arange(m, dtype=jnp.int32),
                    read_row=jnp.arange(m, dtype=jnp.int32),
                    active=jnp.ones(m, bool), seen=jnp.int32(0),
                    req_type=jnp.zeros((R,), jnp.int32),
                    req_bytes=jnp.ones((R,), jnp.float32),
                    req_n=jnp.int32(0),
                    ring=self.ring._buf, ring_ptr=jnp.int32(self.ring.ptr),
                    ring_total=jnp.int32(self.ring.total),
                    metrics=frame0, rec=rec0)
            xs = SegmentIn(
                arr_time=jnp.asarray(arr_time), arr_type=jnp.asarray(arr_type),
                arr_bytes=jnp.asarray(arr_bytes), dyn_idx=jnp.asarray(dyn_idx),
                seg_valid=jnp.asarray(np.arange(S_cap) < segments))

        with obs_trace.span("closed_loop.dispatch", segments=segments, m=m,
                            s_cap=S_cap):
            final, ys = run_closed_loop(
                cluster, dyn_stack, Lp_t, logb_priors, carry0, xs, config)
            ys = jax.tree_util.tree_map(np.asarray, ys)

        # failures surface before any state is adopted, leaving the host
        # objects where they were (the failed run never happened)
        if ys.deadlock[:segments].any():
            raise RuntimeError(
                "deadlock: queued workloads fit no empty server")
        if ys.req_overflow[:segments].any():
            raise RuntimeError(
                f"eviction requeued more than one segment's worth of work "
                f"({R} slots); run the host-alternating path")

        with obs_trace.span("closed_loop.epilogue", segments=segments):
            results, n_obs = [], []
            for k in range(segments):
                nv = int(ys.n_valid[k])
                t0 = t0s[k]
                placement = ys.placement[k][:nv]
                pt = ys.place_time[k][:nv].astype(np.float64)
                ft = ys.finish_time[k][:nv].astype(np.float64)
                pt = np.where(pt >= 0.0, pt + t0, pt)
                ft = np.where(np.isfinite(ft), ft + t0, ft)
                results.append(EngineResult(
                    placements=tuple(int(p) if p != QUEUED else None
                                     for p in placement),
                    was_queued=tuple(bool(q) for q in ys.was_queued[k][:nv]),
                    place_times=tuple(float(t) for t in pt),
                    finish_times=tuple(float(t) for t in ft),
                    makespan=float(ys.makespan[k]) + t0,
                    max_observed_degradation=float(ys.max_deg[k]),
                    backend="jax"))
                n_obs.append(int(ys.used[k]))

            if fc is not None:
                outcomes = [
                    dict(segment=k, split_fired=ys.split_fired[k],
                         split_stat=ys.split_stat[k],
                         evict_fired=ys.evict_fired[k],
                         evict_stat=ys.evict_stat[k],
                         evict_route=ys.evict_route[k],
                         active_after=ys.active_after[k])
                    for k in range(segments)]
                per_seg = fc.adopt_device_outcome(
                    final.bank, final.det, np.asarray(final.row_map),
                    np.asarray(final.read_row), np.asarray(final.active),
                    outcomes)
                health = [tuple(evs) for evs in per_seg]
            else:
                self.bank._stacked = final.bank
                self.bank._dirty = True
                health = [() for _ in range(segments)]
            self.ring._buf = final.ring
            self.ring.ptr = int(final.ring_ptr)
            self.ring.total = int(final.ring_total)
            if record:
                self.decisions.adopt(final.rec)
            log = obs_trace.active_log()
            if metrics and log is not None:
                log.snapshot("closed_loop.metrics",
                             obs_metrics.snapshot(final.metrics))
        return AdaptiveResult(tuple(results), tuple(n_obs), tuple(t0s),
                              tuple(health), metrics=final.metrics,
                              decisions=self.decisions if record else None)
