"""Online consolidation scheduler with criterion-1 queueing (paper §V, §VIII).

NOTE: this module is the *numpy reference oracle* of the unified engine
(DESIGN.md §8). Production traffic goes through
``core.engine.ConsolidationEngine`` (whose jitted ``engine_jax.run_trace``
backend is parity-tested against this implementation in
tests/test_engine.py); the float64 event loop below is kept as the
readable, trusted specification of the runtime semantics.

The paper's operating model: workloads *arrive* one at a time; the greedy
(Fig 8) places each on the best feasible server, or queues it "until a server
to satisfy this criterion is found -- most probably upon completion of
another workload" (§V). This module adds the missing runtime half: workload
completions, queue draining, and makespan accounting, so the Fig-5 argument
(consolidate only when every D_i < 50%) can be verified end to end.

Time model: a workload placed at time t with solo runtime AR finishes at
t + AR / (1 - D), where D is its (simulated, ground-truth) degradation under
whatever co-run set it experiences; we conservatively re-evaluate remaining
work whenever the co-run set changes (piecewise-constant rates).
"""
from __future__ import annotations

import dataclasses
import heapq
from typing import Callable, Sequence

import numpy as np

from .binpack import ClusterState, greedy_place
from .simulator import simulate_corun
from .throughput import solo_throughput
from .workload import Workload


@dataclasses.dataclass
class _Running:
    w: Workload
    server: int
    remaining_bytes: float
    rate: float  # current bytes/s under the present co-run set


@dataclasses.dataclass
class ScheduleEvent:
    time: float
    kind: str  # 'arrive' | 'place' | 'queue' | 'finish'
    workload: Workload
    server: int | None = None


@dataclasses.dataclass
class ScheduleResult:
    events: list[ScheduleEvent]
    makespan: float
    placements: dict[int, int | None]  # arrival index -> server (None = never ran!)
    max_observed_degradation: float


class OnlineScheduler:
    """Event-driven consolidation runtime around the paper's greedy."""

    def __init__(self, state: ClusterState, place: Callable = greedy_place):
        self.state = state
        self.place = place
        self.running: dict[int, list[_Running]] = {i: [] for i in range(len(state.servers))}
        self.queue: list[tuple[int, Workload]] = []
        self.events: list[ScheduleEvent] = []
        self.max_deg = 0.0

    # -- rate bookkeeping ------------------------------------------------
    def _refresh_rates(self, server: int) -> None:
        rs = self.running[server]
        if not rs:
            return
        res = simulate_corun(self.state.servers[server], [r.w for r in rs])
        for r, t, d in zip(rs, res.throughputs, res.degradations):
            r.rate = t
            self.max_deg = max(self.max_deg, d)

    def _next_finish(self, server: int) -> tuple[float, _Running] | None:
        rs = self.running[server]
        if not rs:
            return None
        r = min(rs, key=lambda r: r.remaining_bytes / r.rate)
        return r.remaining_bytes / r.rate, r

    def _advance(self, server: int, dt: float) -> None:
        for r in self.running[server]:
            r.remaining_bytes = max(0.0, r.remaining_bytes - r.rate * dt)

    # -- the simulation loop ----------------------------------------------
    def run(self, arrivals: Sequence[tuple[float, Workload]]) -> ScheduleResult:
        """Simulate arrivals [(time, workload)] to completion of all work."""
        arrivals = sorted(enumerate(arrivals), key=lambda kv: kv[1][0])
        heap: list[tuple[float, int, str, int]] = []  # (time, seq, kind, idx)
        seq = 0
        for idx, (t, _) in arrivals:
            heapq.heappush(heap, (t, seq, "arrive", idx))
            seq += 1
        arrival_map = {idx: w for idx, (_, w) in arrivals}
        placements: dict[int, int | None] = {}
        now = 0.0

        def try_place(idx: int, w: Workload, t: float) -> bool:
            s = self.place(self.state, w)
            if s is None:
                return False
            placements[idx] = s
            solo = solo_throughput(self.state.servers[s], w)
            self.running[s].append(_Running(w, s, w.data_total, solo))
            self._refresh_rates(s)
            self.events.append(ScheduleEvent(t, "place", w, s))
            return True

        while heap:
            # advance every server to the earlier of (next heap event, next finish)
            t_event = heap[0][0]
            # find earliest finish across servers
            finishes = []
            for s in self.running:
                nf = self._next_finish(s)
                if nf is not None:
                    finishes.append((now + nf[0], s, nf[1]))
            if finishes:
                t_fin, s_fin, r_fin = min(finishes, key=lambda x: x[0])
            else:
                t_fin = np.inf
            if t_fin <= t_event:
                # a completion happens first
                dt = t_fin - now
                for s in self.running:
                    self._advance(s, dt)
                now = t_fin
                self.running[s_fin] = [r for r in self.running[s_fin] if r is not r_fin]
                self.state.assignments[s_fin] = [
                    w for w in self.state.assignments[s_fin] if w is not r_fin.w
                ]
                self._refresh_rates(s_fin)
                self.events.append(ScheduleEvent(now, "finish", r_fin.w, s_fin))
                # completion may unblock the queue (§V)
                still = []
                for idx, w in self.queue:
                    if not try_place(idx, w, now):
                        still.append((idx, w))
                self.queue = still
                continue

            t, _, kind, idx = heapq.heappop(heap)
            dt = t - now
            for s in self.running:
                self._advance(s, dt)
            now = t
            w = arrival_map[idx]
            self.events.append(ScheduleEvent(now, "arrive", w))
            if not try_place(idx, w, now):
                placements[idx] = None
                self.queue.append((idx, w))
                self.events.append(ScheduleEvent(now, "queue", w))

        # drain: no more arrivals; let everything finish, placing queue as room opens
        while any(self.running.values()) or self.queue:
            finishes = []
            for s in self.running:
                nf = self._next_finish(s)
                if nf is not None:
                    finishes.append((now + nf[0], s, nf[1]))
            if not finishes:
                # queue non-empty but nothing running: place greedily on empty cluster
                progressed = False
                still = []
                for idx, w in self.queue:
                    if try_place(idx, w, now):
                        progressed = True
                    else:
                        still.append((idx, w))
                self.queue = still
                if not progressed:
                    raise RuntimeError("deadlock: queued workloads fit no empty server")
                continue
            t_fin, s_fin, r_fin = min(finishes, key=lambda x: x[0])
            dt = t_fin - now
            for s in self.running:
                self._advance(s, dt)
            now = t_fin
            self.running[s_fin] = [r for r in self.running[s_fin] if r is not r_fin]
            self.state.assignments[s_fin] = [
                w for w in self.state.assignments[s_fin] if w is not r_fin.w
            ]
            self._refresh_rates(s_fin)
            self.events.append(ScheduleEvent(now, "finish", r_fin.w, s_fin))
            still = []
            for idx, w in self.queue:
                if not try_place(idx, w, now):
                    still.append((idx, w))
            self.queue = still

        final_placements = {}
        for idx in arrival_map:
            # last placement wins (queued-then-placed updates the entry)
            final_placements[idx] = placements.get(idx)
        return ScheduleResult(self.events, now, final_placements, self.max_deg)
