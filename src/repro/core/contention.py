"""Contention models: TDP prediction (Eqns 1-2) and the additive
mutual-degradation model (Eqn 3) with its pairwise-profiling pipeline (C3+C4).

The paper's methodology, reproduced here:

  * §IV.A predicts the throughput-degradation point (TDP) with Eqn (2):
      CacheSize = sum_i RS_i + sum_{i in CS} FS_i,  CS = {i | FS_i <= CacheSize}
  * §IV.B profiles D_{i,j} -- the degradation workload i causes on j -- by
    running every *pair* of grid workload types: (10x23)^2 = 52_900 runs per
    server. The additive model D_j = sum_{i != j} D_{i,j} (Eqn 3) then
    predicts N-way co-run degradation from pairs only.

Profiling here runs against the simulator (our testbed stand-in); on a real
deployment the same ``profile_pairwise`` is pointed at TestDFSIO-style
measurements (the interface takes any callable measuring a pair).
"""
from __future__ import annotations

import functools
from typing import Callable, Sequence

import numpy as np

from .server import ServerSpec
from .simulator import competing_cache_bytes, simulate_corun
from .workload import FS_GRID, RS_GRID, Workload, grid_types, type_index


# --- TDP prediction (Eqns 1-2) -----------------------------------------------

def tdp_lhs_naive(workloads: Sequence[Workload]) -> float:
    """Eqn (1): sum_i (RS_i + FS_i) -- valid only when all FS <= CacheSize."""
    return float(sum(w.rs + w.fs for w in workloads))


def tdp_lhs(server: ServerSpec, workloads: Sequence[Workload]) -> float:
    """Eqn (2) LHS: competing data, excluding FS of workloads larger than LLC."""
    return competing_cache_bytes(server, workloads)


def predict_tdp_hit(server: ServerSpec, workloads: Sequence[Workload], alpha: float = 1.0) -> bool:
    """Predict whether this co-run set is past its TDP (Eqn 2 vs alpha*CacheSize)."""
    return tdp_lhs(server, workloads) > alpha * server.llc_bytes


def predict_tdp_n(server: ServerSpec, rs: float, fs: float, alpha: float = 1.0) -> float:
    """For N identical (RS, FS) workloads, the critical N where TDP occurs.

    From Eqn (1): N * (RS + FS) = alpha * CacheSize  (the paper's worked
    example: N=4, RS=256KB, FS=1280KB -> 4*(1536KB) = 6MB on M1).
    """
    per = rs + (fs if fs <= server.llc_bytes else 0.0)
    return alpha * server.llc_bytes / per


# --- Pairwise-degradation profiling (§IV.B, §VIII) -----------------------------

PairMeasure = Callable[[Workload, Workload], float]


def measure_pair_simulated(server: ServerSpec) -> PairMeasure:
    """D_{i,j} measured on the simulator: degradation of j when co-run with i.

    NOTE: the pair co-run includes the cache outcome of *the pair only*; the
    additive model then extrapolates to N-way sets. This mirrors the paper's
    physical profiling exactly (they, too, can only observe pair effects).
    """

    def measure(w_i: Workload, w_j: Workload) -> float:
        res = simulate_corun(server, [w_i, w_j])
        return res.degradations[1]

    return measure


def profile_pairwise(
    server: ServerSpec,
    types: Sequence[Workload] | None = None,
    measure: PairMeasure | None = None,
) -> np.ndarray:
    """The paper's 52_900-run profiling pass -> D matrix, D[i, j] = D_{i,j}.

    D[i, j] is the degradation that a workload of type i causes on a
    co-running workload of type j (both snapped to the profiling grid).
    """
    if types is None:
        types = grid_types("read")
    if measure is None:
        measure = measure_pair_simulated(server)
    n = len(types)
    D = np.zeros((n, n))
    for i, wi in enumerate(types):
        for j, wj in enumerate(types):
            D[i, j] = measure(wi, wj)
    return D


def type_tables(
    server: ServerSpec, types: Sequence[Workload] | None = None
) -> dict[str, np.ndarray]:
    """Per-type simulator tables in both cache states (keep / lost).

    Returns arrays indexed by grid type: ``solo`` / ``base_lost`` throughputs
    [T], per-resource ``dem_keep``/``dem_lost``/``sens_keep``/``sens_lost``
    [T, 3] (resources ordered mem, disk, cpu), resource capacities ``cap``
    [3], and ``comp_bytes`` [T] (RS + FS when LLC-resident, Eqn 2's per-type
    contribution). Shared by :func:`profile_pairwise_fast` and the device
    engine's rate tables (engine_jax.PackedDynamics); the default-grid case
    is cached per server spec (callers treat the tables as read-only), so
    profiling, pair matrices, and engine construction compute them once.
    """
    if types is None:
        return _grid_type_tables(server)
    return _type_tables_uncached(server, types)


@functools.lru_cache(maxsize=None)
def _grid_type_tables(server: ServerSpec) -> dict[str, np.ndarray]:
    return _type_tables_uncached(server, grid_types("read"))


def _type_tables_uncached(
    server: ServerSpec, types: Sequence[Workload]
) -> dict[str, np.ndarray]:
    from .simulator import _capacities, _demands, _sensitivity, throughput_after_cache
    from .throughput import solo_throughput

    rs = np.array([w.rs for w in types])
    fs = np.array([w.fs for w in types])

    solo = np.array([solo_throughput(server, w) for w in types])
    base_lost = np.array([throughput_after_cache(server, w, True) for w in types])

    caps = _capacities(server)
    res_names = ("mem", "disk", "cpu")

    def stack(lost: bool):
        base = base_lost if lost else solo
        dem = np.zeros((len(types), 3))
        sens = np.zeros((len(types), 3))
        for t, w in enumerate(types):
            d = _demands(server, w, base[t], lost)
            s = _sensitivity(server, w, base[t], d)
            dem[t] = [d[r] for r in res_names]
            sens[t] = [s[r] for r in res_names]
        return dem, sens

    dem_k, sens_k = stack(False)
    dem_l, sens_l = stack(True)
    return {
        "rs": rs,
        "fs": fs,
        "solo": solo,
        "base_lost": base_lost,
        "dem_keep": dem_k,
        "dem_lost": dem_l,
        "sens_keep": sens_k,
        "sens_lost": sens_l,
        "cap": np.array([caps[r] for r in res_names]),
        "comp_bytes": rs + np.where(fs <= server.llc_bytes, fs, 0.0),
    }


def _pair_slowdown_grid(
    dem_i: np.ndarray, dem_j: np.ndarray, sens_j: np.ndarray, cap: np.ndarray
) -> np.ndarray:
    """d_{i,j} for every type pair under fixed demand/sensitivity tables.

    Vectorization of :func:`simulator.pair_slowdown`: per resource,
    excess-over-capacity sharing plus the baseline-interference term, composed
    multiplicatively over resources. Inputs are [i, j, r] broadcastable.
    """
    from .simulator import _BASELINE

    total = dem_i + dem_j
    with np.errstate(divide="ignore", invalid="ignore"):
        excess = np.where(total > 0, np.maximum(0.0, 1.0 - cap[None, None, :] / total), 0.0)
    baseline = dem_i / (dem_i + _BASELINE * cap[None, None, :])
    slow = 1.0 - (1.0 - excess) * (1.0 - baseline)
    return 1.0 - np.prod(1.0 - sens_j * slow, axis=-1)


def pair_slowdown_matrices(
    server: ServerSpec, types: Sequence[Workload] | None = None
) -> tuple[np.ndarray, np.ndarray]:
    """(d_keep [T, T], d_lost [T, T]): slowdown type i imposes on type j.

    Unlike :func:`profile_pairwise_fast` (which resolves the cache outcome
    *per pair*, as physical pair profiling would), these matrices fix the
    cache state globally: ``d_keep`` assumes the set kept the LLC, ``d_lost``
    that it overflowed. The online engine picks per-step which matrix applies
    from the live co-run set, reproducing ``simulate_corun`` exactly for
    grid-typed workloads.
    """
    tt = type_tables(server, types)
    d_keep = _pair_slowdown_grid(
        tt["dem_keep"][:, None, :], tt["dem_keep"][None, :, :],
        tt["sens_keep"][None, :, :], tt["cap"])
    d_lost = _pair_slowdown_grid(
        tt["dem_lost"][:, None, :], tt["dem_lost"][None, :, :],
        tt["sens_lost"][None, :, :], tt["cap"])
    return d_keep, d_lost


def profile_pairwise_fast(server: ServerSpec, types: Sequence[Workload] | None = None) -> np.ndarray:
    """Vectorized (numpy) equivalent of :func:`profile_pairwise` on the simulator.

    Runs the full 230x230 grid in milliseconds instead of 52_900 python-level
    simulator calls. Used by benchmarks; validated against the scalar path in
    tests (test_contention.py::test_fast_profile_matches_scalar).
    """
    tt = type_tables(server, types)  # default grid hits the per-spec cache
    rs, fs = tt["rs"], tt["fs"]
    solo, base_lost = tt["solo"], tt["base_lost"]
    base_k, dem_k, sens_k = solo, tt["dem_keep"], tt["sens_keep"]
    base_l, dem_l, sens_l = base_lost, tt["dem_lost"], tt["sens_lost"]
    cap = tt["cap"]

    # pair cache outcome: competing bytes of {i, j} vs the physical tolerance
    comp = (rs[:, None] + rs[None, :]
            + np.where(fs <= server.llc_bytes, fs, 0.0)[:, None]
            + np.where(fs <= server.llc_bytes, fs, 0.0)[None, :])
    overflow = comp > server.llc_tolerance * server.llc_bytes  # [i, j]

    ov = overflow[:, :, None]
    dem_i = np.where(ov, dem_l[:, None, :], dem_k[:, None, :])  # [i, j, r]
    dem_j = np.where(ov, dem_l[None, :, :], dem_k[None, :, :])  # [i, j, r]
    sens_j = np.where(ov, sens_l[None, :, :], sens_k[None, :, :])  # [i, j, r]
    base_j = np.where(overflow, base_l[None, :], base_k[None, :])  # [i, j]

    d = _pair_slowdown_grid(dem_i, dem_j, sens_j, cap)
    t_j = base_j * (1.0 - d)
    return 1.0 - t_j / solo[None, :]


# --- Additive model (Eqn 3) ----------------------------------------------------

def additive_degradation(D: np.ndarray, members: Sequence[int]) -> np.ndarray:
    """Eqn (3): predicted D_j = sum_{i != j} D[i, j] for each member j.

    ``members`` are profiling-grid type indices of the co-located set
    (duplicates allowed -- N identical workloads is the Fig 3-4 case).
    """
    idx = np.asarray(members, dtype=int)
    if idx.size == 0:
        return np.zeros(0)
    sub = D[np.ix_(idx, idx)]
    col_sum = sub.sum(axis=0)
    self_term = np.diagonal(sub)
    return col_sum - self_term


def predict_degradations(
    D: np.ndarray, workloads: Sequence[Workload]
) -> np.ndarray:
    """Additive-model degradation prediction for concrete workloads.

    Workloads are snapped to the profiling grid for D-matrix lookup, exactly
    as the paper's scheduler consults previously collected D_{x,y}s (Fig 8).
    Predictions are clipped to [0, 1): a degradation can't exceed 100%.
    """
    members = [type_index(w) for w in workloads]
    return np.clip(additive_degradation(D, members), 0.0, 0.999999)
