"""Beyond-paper: local-search refinement of a consolidation assignment.

The paper's greedy is *online* (placements are final on arrival). Real
fleets get chances to re-pack offline — after elastic re-mesh events, queue
drains, or periodic rebalancing. ``local_search`` takes any feasible
assignment (usually the greedy's) and hill-climbs with single-workload moves
and pairwise swaps under the same two §V criteria, minimizing the paper's
global objective (total average load). It can only improve the objective and
never leaves the feasible region, so greedy + local_search is a strictly-
better offline allocator at O(iters x W x m) model evaluations (each one the
same Fig-8 check the Pallas scoring kernel batches).

``local_search_engine`` is the device-backed variant: it packs the state
into the unified engine's array representation, runs
``engine_jax.local_search_jax`` (best-improvement relocations scored through
the same incremental load algebra as the shared candidate scorer), and
reconstructs the assignment. Python first-improvement and array
best-improvement may take different descent paths; both are monotone and
criteria-preserving.
"""
from __future__ import annotations

import collections

import numpy as np

from .binpack import ClusterState


def _objective(state: ClusterState) -> float:
    return state.total_avg_load()


def local_search(state: ClusterState, max_iters: int = 100) -> tuple[ClusterState, int]:
    """Greedy first-improvement moves + swaps. Returns (state, n_improvements)."""
    cur = state.clone()
    best = _objective(cur)
    improved_total = 0
    for _ in range(max_iters):
        improved = False
        m = len(cur.servers)
        # single-workload relocations
        for s in range(m):
            for wi in range(len(cur.assignments[s])):
                w = cur.assignments[s][wi]
                for t in range(m):
                    if t == s:
                        continue
                    trial = cur.clone()
                    trial.assignments[s].pop(wi)
                    trial.assignments[t].append(w)
                    if not (trial.check(s).ok and trial.check(t).ok):
                        continue
                    obj = _objective(trial)
                    if obj < best - 1e-12:
                        cur, best = trial, obj
                        improved = True
                        improved_total += 1
                        break
                if improved:
                    break
            if improved:
                break
        if improved:
            continue
        # pairwise swaps
        for s in range(m):
            for t in range(s + 1, m):
                for wi in range(len(cur.assignments[s])):
                    for wj in range(len(cur.assignments[t])):
                        trial = cur.clone()
                        a = trial.assignments[s].pop(wi)
                        b = trial.assignments[t].pop(wj)
                        trial.assignments[s].append(b)
                        trial.assignments[t].append(a)
                        if not (trial.check(s).ok and trial.check(t).ok):
                            continue
                        obj = _objective(trial)
                        if obj < best - 1e-12:
                            cur, best = trial, obj
                            improved = True
                            improved_total += 1
                            break
                    if improved:
                        break
                if improved:
                    break
            if improved:
                break
        if not improved:
            break
    return cur, improved_total


def local_search_engine(state: ClusterState, max_iters: int = 100) -> tuple[ClusterState, int]:
    """Array-native relocation search on device; returns (state, n_moves).

    Workloads are interchangeable within a profiling-grid type for both §V
    criteria, so the refined type counts are mapped back to concrete
    workloads by redistributing the originals type by type.
    """
    from .binpack_jax import PackedCluster, counts_from_assignments
    from .engine_jax import local_search_jax
    from .workload import type_index

    cluster = PackedCluster.build(list(state.servers), state.D, list(state.alphas))
    counts0 = counts_from_assignments(cluster, state.assignments)
    counts1, moves = local_search_jax(cluster, counts0, max_iters=max_iters)

    pool = collections.defaultdict(list)
    for ws in state.assignments:
        for w in ws:
            pool[type_index(w)].append(w)
    c = np.asarray(counts1).round().astype(int)
    assignments = []
    for s in range(len(state.servers)):
        ws = []
        for t in np.nonzero(c[s])[0]:
            for _ in range(c[s, t]):
                ws.append(pool[int(t)].pop())
        assignments.append(ws)
    refined = ClusterState(state.servers, state.D, state.alphas, assignments)
    return refined, int(moves)
