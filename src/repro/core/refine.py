"""Beyond-paper: local-search refinement of a consolidation assignment.

The paper's greedy is *online* (placements are final on arrival). Real
fleets get chances to re-pack offline — after elastic re-mesh events, queue
drains, or periodic rebalancing. ``local_search`` takes any feasible
assignment (usually the greedy's) and hill-climbs with single-workload moves
and pairwise swaps under the same two §V criteria, minimizing the paper's
global objective (total average load). It can only improve the objective and
never leaves the feasible region, so greedy + local_search is a strictly-
better offline allocator at O(iters x W x m) model evaluations (each one the
same Fig-8 check the Pallas scoring kernel batches).
"""
from __future__ import annotations

from .binpack import ClusterState


def _objective(state: ClusterState) -> float:
    return state.total_avg_load()


def local_search(state: ClusterState, max_iters: int = 100) -> tuple[ClusterState, int]:
    """Greedy first-improvement moves + swaps. Returns (state, n_improvements)."""
    cur = state.clone()
    best = _objective(cur)
    improved_total = 0
    for _ in range(max_iters):
        improved = False
        m = len(cur.servers)
        # single-workload relocations
        for s in range(m):
            for wi in range(len(cur.assignments[s])):
                w = cur.assignments[s][wi]
                for t in range(m):
                    if t == s:
                        continue
                    trial = cur.clone()
                    trial.assignments[s].pop(wi)
                    trial.assignments[t].append(w)
                    if not (trial.check(s).ok and trial.check(t).ok):
                        continue
                    obj = _objective(trial)
                    if obj < best - 1e-12:
                        cur, best = trial, obj
                        improved = True
                        improved_total += 1
                        break
                if improved:
                    break
            if improved:
                break
        if improved:
            continue
        # pairwise swaps
        for s in range(m):
            for t in range(s + 1, m):
                for wi in range(len(cur.assignments[s])):
                    for wj in range(len(cur.assignments[t])):
                        trial = cur.clone()
                        a = trial.assignments[s].pop(wi)
                        b = trial.assignments[t].pop(wj)
                        trial.assignments[s].append(b)
                        trial.assignments[t].append(a)
                        if not (trial.check(s).ok and trial.check(t).ok):
                            continue
                        obj = _objective(trial)
                        if obj < best - 1e-12:
                            cur, best = trial, obj
                            improved = True
                            improved_total += 1
                            break
                    if improved:
                        break
                if improved:
                    break
            if improved:
                break
        if not improved:
            break
    return cur, improved_total
