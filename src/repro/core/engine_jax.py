"""Device-resident online consolidation engine (paper §V + §VIII as one scan).

This is the array-native runtime half that ``binpack_jax`` lacks: the full
arrive -> score -> place-or-queue -> run -> complete -> drain loop of the
paper's operating model, expressed as fixed-shape array state stepped by a
``jax.lax.while_loop`` (one micro-event per iteration, early exit when the
trace completes) so an entire arrival trace -- including completions and the
criterion-1 queue draining of §V -- runs jitted on device. The pure-Python
``core.scheduler.OnlineScheduler`` is the numpy reference oracle this module
is parity-tested against (tests/test_engine.py).

State encoding (m servers, K run-slots per server, n arrivals, T grid types):

  counts    : f32[m, T]  -- resident type counts (drives the Fig-8 scorer)
  comp      : f32[m]     -- Eqn-2 competing bytes, maintained incrementally
  col0      : f32[m, T]  -- additive-model column sums counts @ D (Eqn 3)
  colog_*   : f32[m, T]  -- counts @ log(1 - d) under the keep/lost cache
                            outcome (ground-truth co-run slowdown sums)
  slot_type : i32[m, K]  -- grid type per run slot (-1 = free)
  slot_rem  : f32[m, K]  -- remaining bytes per slot
  slot_arr  : i32[m, K]  -- arrival index occupying the slot
  queued    : bool[n]    -- criterion-1 queue; order == arrival order, which
                            matches the oracle because workloads are enqueued
                            in arrival order and never re-queued (a mask is
                            therefore equivalent to a ring buffer here)

The incremental sums make every event O(T) per server instead of O(T^2):
placing/finishing a type-t workload on server s adds/subtracts one row of
D[s] (model) and of log(1-d_s) (ground truth) -- the engine never re-reduces
the full [m, T, T] tensors inside the scan.

Each scan step consumes exactly one micro-event, picked by `lax.switch`:

  DRAIN  -- after a completion (or when the cluster idles with a non-empty
            queue), score *all* queued candidates against all servers in one
            batched call to the scoring interface and place the first
            (lowest arrival index) feasible one; repeat until none fits.
            Correct single-placement-per-step semantics because adding a
            workload never makes another candidate feasible (both criteria
            are monotone in additions).
  FINISH -- advance time to the earliest completion, free its slot, then
            switch to DRAIN ("most probably upon completion of another
            workload", §V).
  ARRIVE -- advance time to the next arrival, run the Fig-8 greedy on it,
            queue it if no server passes both criteria.

Ground-truth rates (the oracle's ``simulate_corun``) are reproduced exactly
for grid-typed workloads: pairwise slowdown factors compose multiplicatively,
so with per-type counts c the log co-run slowdown of a type-t workload on
server s is

  log T_t / T_base,t = sum_u c_u * log(1 - d_s[u, t]) - log(1 - d_s[t, t])

with the keep/lost variant of ``d_s`` (and of the base throughput) selected
by the server's *physical* cache state (Eqn 2 vs llc_tolerance * CacheSize).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Callable, NamedTuple, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from ..obs import metrics as obs_metrics
from ..obs import recorder as obs_recorder
from .binpack_jax import (
    PackedCluster,
    _choose_from_scores,
    argmin_with_margin,
    score_candidates_jnp,
    server_loads,
)
from .contention import pair_slowdown_matrices, type_tables
from .server import ServerSpec

QUEUED = -1  # placement sentinel, same as binpack_jax

#: scoring backend signature: (cluster, counts [m,T], wtypes [Q]) ->
#: (cache_after [Q, m], maxd_after [Q, m])
Scorer = Callable[[PackedCluster, jax.Array, jax.Array], tuple[jax.Array, jax.Array]]


@dataclasses.dataclass(frozen=True)
class PackedDynamics:
    """Per-type ground-truth rate tables (device-side ``simulate_corun``)."""

    solo: jax.Array  # f32[m, T] solo throughput (bytes/s)
    base_lost: jax.Array  # f32[m, T] throughput after losing the LLC
    log_keep: jax.Array  # f32[m, T, T] log(1 - d_keep[i, j])
    log_lost: jax.Array  # f32[m, T, T] log(1 - d_lost[i, j])
    comp_bytes: jax.Array  # f32[m, T] per-type competing bytes (Eqn 2 terms)
    tol_budget: jax.Array  # f32[m] llc_tolerance * CacheSize (physical TDP)

    @classmethod
    def build(cls, servers: Sequence[ServerSpec]) -> "PackedDynamics":
        tables, logs = {}, {}
        solo, lost, lkeep, llost, comp, tol = [], [], [], [], [], []
        for s in servers:
            # keyed by the frozen spec value (not name): identical specs share
            # one pass, same-name variants do not; the default grid also hits
            # contention.py's per-spec table cache
            if s not in tables:
                tables[s] = type_tables(s)
                logs[s] = pair_slowdown_matrices(s)
            tt, (d_keep, d_lost) = tables[s], logs[s]
            solo.append(tt["solo"])
            lost.append(tt["base_lost"])
            lkeep.append(np.log1p(-np.clip(d_keep, 0.0, 1.0 - 1e-9)))
            llost.append(np.log1p(-np.clip(d_lost, 0.0, 1.0 - 1e-9)))
            comp.append(tt["comp_bytes"])
            tol.append(s.llc_tolerance * s.llc_bytes)
        f32 = lambda x: jnp.asarray(np.stack(x), jnp.float32)
        return cls(f32(solo), f32(lost), f32(lkeep), f32(llost), f32(comp),
                   jnp.asarray(tol, jnp.float32))


jax.tree_util.register_pytree_node(
    PackedDynamics,
    lambda d: ((d.solo, d.base_lost, d.log_keep, d.log_lost, d.comp_bytes, d.tol_budget), None),
    lambda aux, ch: PackedDynamics(*ch),
)


class EngineState(NamedTuple):
    now: jax.Array  # f32 scalar simulation clock
    ai: jax.Array  # i32 next-arrival pointer
    counts: jax.Array  # f32[m, T]
    comp: jax.Array  # f32[m] competing bytes (Eqn 2 LHS), incremental
    col0: jax.Array  # f32[m, T] counts @ D, incremental
    colog_keep: jax.Array  # f32[m, T] counts @ log(1-d_keep), incremental
    colog_lost: jax.Array  # f32[m, T] counts @ log(1-d_lost), incremental
    slot_type: jax.Array  # i32[m, K]
    slot_rem: jax.Array  # f32[m, K]
    slot_arr: jax.Array  # i32[m, K]
    queued: jax.Array  # bool[n]
    was_queued: jax.Array  # bool[n] -- the §V queue *decision* per arrival
    placement: jax.Array  # i32[n] server index or QUEUED
    place_time: jax.Array  # f32[n]
    finish_time: jax.Array  # f32[n]
    makespan: jax.Array  # f32 scalar (time of latest completion)
    max_deg: jax.Array  # f32 scalar max *observed* (simulated) degradation
    draining: jax.Array  # bool -- queue re-check pending
    deadlock: jax.Array  # bool -- queued work that no empty server can take
    obs_co: jax.Array  # f32[n, T] time-integrated co-resident type counts
    obs_lost: jax.Array  # f32[n] time spent past the physical TDP
    obs_logr: jax.Array  # f32[n] time-integrated log instantaneous rate
    # in-carry metrics plane; None (an empty pytree) unless metrics=True, so
    # the uninstrumented program is byte-identical to the pre-metrics jaxpr
    metrics: "obs_metrics.MetricFrame | None" = None
    # decision flight recorder (same off-switch contract as metrics)
    rec: "obs_recorder.RecState | None" = None


class EngineTrace(NamedTuple):
    """Raw device-side result of :func:`run_trace` (arrival-sorted order)."""

    placement: jax.Array  # i32[n]
    was_queued: jax.Array  # bool[n]
    place_time: jax.Array  # f32[n]
    finish_time: jax.Array  # f32[n]
    makespan: jax.Array  # f32
    max_deg: jax.Array  # f32
    deadlock: jax.Array  # bool
    obs_co: jax.Array  # f32[n, T] (zeros unless telemetry=True)
    obs_lost: jax.Array  # f32[n] (zeros unless telemetry=True)
    obs_logr: jax.Array  # f32[n] (zeros unless telemetry=True)
    metrics: "obs_metrics.MetricFrame | None" = None  # None unless metrics=True
    rec: "obs_recorder.RecState | None" = None  # None unless record=True


def corun_rates(
    cluster: PackedCluster, dyn: PackedDynamics, counts: jax.Array, slot_type: jax.Array
) -> jax.Array:
    """Ground-truth bytes/s per run slot under the current co-run sets [m, K].

    Standalone (counts-based) form of the rate model the scan maintains
    incrementally; exported for tests and one-off evaluations.
    """
    overflow = (counts * dyn.comp_bytes).sum(-1) > dyn.tol_budget  # [m] physical TDP
    ck = jnp.einsum("mt,mtu->mu", counts, dyn.log_keep)
    cl = jnp.einsum("mt,mtu->mu", counts, dyn.log_lost)
    ldiag_keep = jnp.diagonal(dyn.log_keep, axis1=1, axis2=2)
    ldiag_lost = jnp.diagonal(dyn.log_lost, axis1=1, axis2=2)
    return _slot_rates(dyn, ldiag_keep, ldiag_lost, overflow, ck, cl, slot_type)


def _slot_rates(dyn, ldiag_keep, ldiag_lost, overflow, colog_keep, colog_lost, slot_type):
    """Per-slot rates from the maintained log-slowdown sums."""
    colog = jnp.where(overflow[:, None], colog_lost, colog_keep)  # [m, T]
    ldiag = jnp.where(overflow[:, None], ldiag_lost, ldiag_keep)  # [m, T]
    base = jnp.where(overflow[:, None], dyn.base_lost, dyn.solo)  # [m, T]
    t = jnp.clip(slot_type, 0)  # [m, K]
    logslow = jnp.take_along_axis(colog - ldiag, t, axis=1)
    return jnp.take_along_axis(base, t, axis=1) * jnp.exp(logslow)  # [m, K]


def _trace_segment(
    cluster: PackedCluster,
    dyn: PackedDynamics,
    arr_time: jax.Array,  # f32[n], non-decreasing over the first n_valid
    arr_type: jax.Array,  # i32[n] grid types
    arr_bytes: jax.Array,  # f32[n] data_total per arrival
    n_valid: jax.Array,  # i32 scalar: arrivals actually present (<= n)
    *,
    objective: str = "sum_avg",
    scorer: Scorer | None = None,
    n_steps: int | None = None,
    telemetry: bool = False,
    metrics: bool = False,
    record: bool = False,
    rec: "obs_recorder.RecState | None" = None,
    rec_ctx: "obs_recorder.RecCtx | None" = None,
    axis=None,
) -> EngineTrace:
    """Trace body of :func:`run_trace`, with a *traced* arrival count.

    ``n = arr_time.shape[0]`` stays the static capacity (slot counts, step
    budget, scatter sentinels), while ``n_valid`` bounds how many arrivals
    the event loop consumes. The device-resident closed loop
    (``core.closed_loop``) scans this body over segments whose real size
    varies per step inside one fixed-capacity compilation; padding rows past
    ``n_valid`` are never arrived, so their trace outputs keep the initial
    sentinels (placement QUEUED, finish inf) and ``n_valid = 0`` exits at
    iteration zero. Plain (un-jitted) so callers embed it in their own jit.

    With a sharded ``axis`` this is the *per-shard* body (the caller runs it
    under ``shard_map``): ``cluster``/``dyn`` carry the local server slice,
    arrival arrays and the queue replicate, placements are global server
    indices, and per-micro-event globals (earliest finish, any-active,
    argmin-with-margin winners) cross the mesh as scalar ``pmin``/``psum``
    pairs. ``axis=None`` (or a dense axis) leaves every code path byte-for-
    byte identical to the unthreaded engine.
    """
    n = int(arr_time.shape[0])
    m, K = cluster.m, n
    if n_steps is None:
        n_steps = 4 * n + 8
    sharded = axis is not None and axis.is_sharded
    if sharded:
        lo = axis.offset(m)  # this shard's first global server index
        m_g = m * axis.shards
    else:
        lo, m_g = 0, m
    if record:
        rec0 = rec if rec is not None else obs_recorder.init(2 * n)
        ctx = rec_ctx if rec_ctx is not None else obs_recorder.default_ctx(
            m, m_g)
    else:
        rec0 = None

    diag = jnp.diagonal(cluster.D, axis1=1, axis2=2)  # [m, T]
    comp_delta = cluster.rs[None, :] + cluster.resident * cluster.fs[None, :]  # [m, T]
    ldiag_keep = jnp.diagonal(dyn.log_keep, axis1=1, axis2=2)  # [m, T]
    ldiag_lost = jnp.diagonal(dyn.log_lost, axis1=1, axis2=2)  # [m, T]
    T = cluster.T
    # all per-server sum tables side by side: one dynamic slice + one matvec
    # refreshes every maintained sum of the touched server (see apply_delta)
    tables = jnp.concatenate(
        [cluster.D, dyn.log_keep, dyn.log_lost, comp_delta[:, :, None]], axis=2
    )  # [m, T, 3T + 1]

    st0 = EngineState(
        now=jnp.float32(0.0),
        ai=jnp.int32(0),
        counts=jnp.zeros((m, cluster.T), jnp.float32),
        comp=jnp.zeros((m,), jnp.float32),
        col0=jnp.zeros((m, cluster.T), jnp.float32),
        colog_keep=jnp.zeros((m, cluster.T), jnp.float32),
        colog_lost=jnp.zeros((m, cluster.T), jnp.float32),
        slot_type=jnp.full((m, K), -1, jnp.int32),
        slot_rem=jnp.zeros((m, K), jnp.float32),
        slot_arr=jnp.full((m, K), -1, jnp.int32),
        queued=jnp.zeros((n,), bool),
        was_queued=jnp.zeros((n,), bool),
        placement=jnp.full((n,), QUEUED, jnp.int32),
        place_time=jnp.full((n,), -1.0, jnp.float32),
        finish_time=jnp.full((n,), jnp.inf, jnp.float32),
        makespan=jnp.float32(0.0),
        max_deg=jnp.float32(0.0),
        draining=jnp.asarray(False),
        deadlock=jnp.asarray(False),
        obs_co=jnp.zeros((n, cluster.T), jnp.float32),
        obs_lost=jnp.zeros((n,), jnp.float32),
        obs_logr=jnp.zeros((n,), jnp.float32),
        metrics=obs_metrics.zeros(m) if metrics else None,
        rec=rec0,
    )

    def score_fast(st, wtypes):
        """Shared scoring contract from the maintained sums (no einsum)."""
        delta = comp_delta[:, wtypes]  # [m, Q]
        cache_after = (st.comp[:, None] + delta) / cluster.llc_budget[:, None]
        col_after = st.col0[:, None, :] + cluster.D[:, wtypes, :]  # [m, Q, T]
        d_pred = jnp.clip(col_after - diag[:, None, :], 0.0, 1.0)
        onehot = jax.nn.one_hot(wtypes, cluster.T, dtype=st.counts.dtype)  # [Q, T]
        present = (st.counts[:, None, :] + onehot[None, :, :]) > 0
        maxd_after = jnp.max(jnp.where(present, d_pred, -jnp.inf), axis=-1)
        return cache_after.T, maxd_after.T  # [Q, m] each

    def loads_now(st):
        """(cache [m], maxd [m]) of the current state from the maintained sums."""
        cache = st.comp / cluster.llc_budget
        d_pred = jnp.clip(st.col0 - diag, 0.0, 1.0)
        present = st.counts > 0
        maxd = jnp.max(jnp.where(present, d_pred, -jnp.inf), axis=1)
        maxd = jnp.where(jnp.any(present, axis=1), maxd, 0.0)
        return cache, maxd

    def greedy_pick(st, wtypes):
        """Scoring + Fig-8 argmin (Table II / Fig-8 objective) for a batch."""
        wtypes = jnp.atleast_1d(wtypes)
        if scorer is None:
            cache_a, maxd_a = score_fast(st, wtypes)
        else:
            cache_a, maxd_a = scorer(cluster, st.counts, wtypes)
        # the fleet-health mask makes evicted servers infeasible on every
        # scoring backend (scores are computed, feasibility is vetoed here)
        feasible = ((maxd_a < cluster.degradation_limit) & (cache_a <= 1.0)
                    & (cluster.active > 0.5)[None, :])
        if objective == "sum_avg":  # Table II: minimize the load *increase*
            cache_now, maxd_now = loads_now(st)
            if scorer is None:
                # the cache increase is known in closed form; using it directly
                # avoids the f32 cancellation of (cache_after - cache_now)
                dcache = (comp_delta[:, wtypes] / cluster.llc_budget[:, None]).T
            else:
                dcache = cache_a - cache_now[None, :]
            score = 0.5 * (dcache + (maxd_a - maxd_now[None, :]))
        else:  # literal Fig 8: minimize the post-allocation average
            score = 0.5 * (cache_a + maxd_a)
        score = jnp.where(feasible, score, jnp.inf)
        if sharded:
            # score-local-then-argmin-allreduce: only (score, index) scalars
            # cross the mesh; tie-breaking is the dense first-global-index
            best, ok = _choose_from_scores(axis, score, m)
            return best, ok, score
        best = argmin_with_margin(score)  # oracle tie-breaking (lowest index)
        ok = jnp.any(feasible, axis=1)
        return jnp.where(ok, best, QUEUED), ok, score

    def apply_delta(st, server, wtype, sign):
        """counts update + canonical refresh of the touched server's sums.

        The sums are recomputed *from the counts row* (one [T] @ [T, T]
        matvec per table, only for the modified server) rather than updated
        incrementally: identical servers with identical co-run multisets then
        hold bitwise-identical sums regardless of event history, so score
        ties break by server index exactly like the float64 oracle's strict-
        improvement loop, and nothing drifts over long traces. ``sign=0`` is
        a no-op refresh (used when a conditional placement did not happen).

        ``server`` is a *global* index; on a sharded axis the owning shard
        rebases it and every other shard's writes fall off the scatter edge.
        """
        if sharded:
            s_l = server - lo
            owned = (s_l >= 0) & (s_l < m)
            s_safe = jnp.clip(s_l, 0, m - 1)
            sdst = jnp.where(owned, s_l, m)  # off-shard write drops
            counts = st.counts.at[sdst, wtype].add(sign)
            sums = counts[s_safe] @ tables[s_safe]
            return st._replace(
                counts=counts,
                comp=st.comp.at[sdst].set(sums[3 * T]),
                col0=st.col0.at[sdst].set(sums[:T]),
                colog_keep=st.colog_keep.at[sdst].set(sums[T:2 * T]),
                colog_lost=st.colog_lost.at[sdst].set(sums[2 * T:3 * T]),
            )
        counts = st.counts.at[server, wtype].add(sign)
        sums = counts[server] @ tables[server]  # [3T + 1]
        return st._replace(
            counts=counts,
            comp=st.comp.at[server].set(sums[3 * T]),
            col0=st.col0.at[server].set(sums[:T]),
            colog_keep=st.colog_keep.at[server].set(sums[T:2 * T]),
            colog_lost=st.colog_lost.at[server].set(sums[2 * T:3 * T]),
        )

    def place_if(st, found, idx, server, wtype, nbytes, t, queue_on_fail,
                 score_row=None):
        """Commit arrival ``idx`` to ``server`` when ``found``, else queue it.

        Conditional writes are expressed as scatters whose index is pushed
        out of bounds (and therefore dropped) on the untaken side -- much
        cheaper inside the event loop than materializing and merging two
        full states.

        ``score_row`` (record=True only) is the committed candidate's
        feasibility-masked score over this shard's servers -- the recorder's
        provenance for *why* this server won.
        """
        if record:
            server_g = jnp.where(found, server, QUEUED)
            qdepth = jnp.sum(st.queued, dtype=jnp.int32)
        server = jnp.where(found, server, 0)
        st = apply_delta(st, server, wtype, jnp.where(found, 1.0, 0.0))
        if sharded:
            # slot bookkeeping is owner-local: the owning shard picks the
            # free slot of its local row, everyone else's writes drop; the
            # replicated [n] queue/placement arrays take the same global
            # values on every shard
            s_l = jnp.clip(server - lo, 0, m - 1)
            owned = found & (server >= lo) & (server < lo + m)
            free = st.slot_type[s_l] < 0  # [K]
            k = jnp.where(owned, jnp.argmax(free), K)
            srow = jnp.where(owned, s_l, m)
        else:
            free = st.slot_type[server] < 0  # [K]
            k = jnp.where(found, jnp.argmax(free), K)  # K == n: a free slot exists
            srow = server
        on_place = jnp.where(found, idx, n)  # n / K index -> scatter dropped
        on_fail = jnp.where(found, n, idx) if queue_on_fail else n
        st = st._replace(
            slot_type=st.slot_type.at[srow, k].set(wtype),
            slot_rem=st.slot_rem.at[srow, k].set(nbytes),
            slot_arr=st.slot_arr.at[srow, k].set(idx),
            queued=st.queued.at[on_place].set(False).at[on_fail].set(True),
            was_queued=st.was_queued.at[on_fail].set(True),
            placement=st.placement.at[on_place].set(server),
            place_time=st.place_time.at[on_place].set(t),
        )
        if metrics or record:
            # Eqn-4 headroom of the committed server, post-commit: how much
            # of the degradation budget this placement left on the table
            if sharded:
                s_l = jnp.clip(server - lo, 0, m - 1)
                owned = (server >= lo) & (server < lo + m)
                d_pred = jnp.clip(st.col0[s_l] - diag[s_l], 0.0, 1.0)
                present = st.counts[s_l] > 0
                maxd_s = jnp.max(jnp.where(present, d_pred, -jnp.inf))
                maxd_s = jnp.where(jnp.any(present), maxd_s, 0.0)
                # single-owner broadcast: the consumers replicate
                maxd_s = axis.pmin(jnp.where(owned, maxd_s, jnp.inf))
            else:
                d_pred = jnp.clip(st.col0[server] - diag[server], 0.0, 1.0)
                present = st.counts[server] > 0
                maxd_s = jnp.max(jnp.where(present, d_pred, -jnp.inf))
                maxd_s = jnp.where(jnp.any(present), maxd_s, 0.0)
            headroom = cluster.degradation_limit - maxd_s
        if metrics:
            placed = found.astype(jnp.int32)
            mf = obs_metrics.count(st.metrics, "placements", placed)
            if queue_on_fail:  # arrival-time commit: the §V queue decision
                mf = obs_metrics.count(mf, "queued", 1 - placed)
            else:  # drain-window commit
                mf = obs_metrics.count(mf, "drain_placements", placed)
            w = found.astype(jnp.float32)
            mf = obs_metrics.observe(
                mf, "waiting_time", t - arr_time[jnp.clip(idx, 0, n - 1)],
                weight=w)
            if sharded:
                col = jax.nn.one_hot(
                    jnp.where(found & owned, s_l, m), m, dtype=jnp.float32)
            else:
                col = jax.nn.one_hot(
                    jnp.where(found, server, m), m, dtype=jnp.float32)
            mf = obs_metrics.observe(mf, "headroom", headroom, weight=w)
            mf = obs_metrics.add_server(mf, "placements", col)
            st = st._replace(metrics=mf)
        if record:
            # provenance row: candidates from the committed pick's score row
            # (all_gather-ed so every shard records the identical global
            # top-K), estimator/detector context owner-sampled at the chosen
            # server and pmin-broadcast like the headroom above
            score_g = axis.all_gather(score_row) if sharded else score_row
            cand, csc = obs_recorder.top_candidates(score_g)
            margin = obs_recorder.tie_margin(csc)
            if ctx.n_pair is None:
                npmin = jnp.float32(-1.0)
            elif sharded:
                rowi = jnp.clip(ctx.row_of[s_l], 0, ctx.n_pair.shape[0] - 1)
                val = obs_recorder.pair_exposure_min(
                    ctx.n_pair[rowi], st.counts[s_l], wtype)
                npmin = axis.pmin(jnp.where(owned, val, jnp.inf))
            else:
                rowi = jnp.clip(ctx.row_of[server], 0,
                                ctx.n_pair.shape[0] - 1)
                npmin = obs_recorder.pair_exposure_min(
                    ctx.n_pair[rowi], st.counts[server], wtype)
            if sharded:
                cus = axis.pmin(jnp.where(owned, ctx.cusum[s_l], jnp.inf))
            else:
                cus = ctx.cusum[server]
            if queue_on_fail:  # arrival-time decision: always one row
                rec_on = jnp.asarray(True)
                kind = jnp.where(found, obs_recorder.KIND_ARRIVE,
                                 obs_recorder.KIND_QUEUED)
            else:  # drain commit: a row only when something placed
                rec_on = found
                kind = jnp.int32(obs_recorder.KIND_DRAIN)
            st = st._replace(rec=obs_recorder.record_row(
                st.rec, on=rec_on, arrival=idx, segment=ctx.segment,
                server=server_g, kind=kind, qdepth=qdepth,
                pool_row=jnp.where(found, ctx.pool_row[server], -1),
                cand=cand, scores=csc, t=t,
                headroom=jnp.where(found, headroom, 0.0), margin=margin,
                n_pair_min=jnp.where(found, npmin, jnp.float32(-1.0)),
                cusum=jnp.where(found, cus, 0.0)))
        return st

    def advance(st, rates, dt):
        active = st.slot_type >= 0
        rem = jnp.where(active, jnp.maximum(st.slot_rem - rates * dt, 0.0), st.slot_rem)
        st = st._replace(slot_rem=rem)
        if telemetry:
            # integrate each running workload's co-resident counts, TDP
            # exposure, and log instantaneous rate over [now, now + dt);
            # inactive slots scatter to index n and are dropped. The log-rate
            # integral is what a fleet gets from sampling its throughput
            # counters: time-averaging log(rate) keeps the estimator's
            # log-linear model exact across within-run co-residency changes
            # (a plain bytes/duration rate mixes regimes arithmetically).
            idx = jnp.where(active, st.slot_arr, n).reshape(-1)  # [m K]
            own = jax.nn.one_hot(jnp.clip(st.slot_type, 0), T, dtype=st.counts.dtype)
            co = jnp.maximum(st.counts[:, None, :] - own, 0.0)  # [m, K, T]
            overflow = st.comp > dyn.tol_budget  # [m]
            logr = jnp.log(jnp.where(active, rates, 1.0))
            st = st._replace(
                obs_co=st.obs_co.at[idx].add(dt * co.reshape(-1, T)),
                obs_lost=st.obs_lost.at[idx].add(
                    dt * jnp.broadcast_to(overflow[:, None], (m, K)).reshape(-1)),
                obs_logr=st.obs_logr.at[idx].add(dt * logr.reshape(-1)),
            )
        return st

    W = min(8, n)  # drain fast-path window (first W queued candidates)

    def drain_branch(st, rates, tt):
        del rates, tt
        # Queue order == arrival order (workloads are never re-queued), so the
        # first feasible *queued arrival index* is the item the oracle places.
        pos = jnp.cumsum(st.queued.astype(jnp.int32))  # 1-based rank among queued
        qlen = pos[-1]
        # arrival indices of the first W queued items (n where fewer than W)
        slot_of = jnp.where(st.queued & (pos <= W), pos - 1, W)
        widx = jnp.full((W + 1,), n, jnp.int32).at[slot_of].min(
            jnp.arange(n, dtype=jnp.int32))[:W]
        in_window = widx < n
        servers_w, ok_w, sc_w = greedy_pick(st, arr_type[jnp.clip(widx, 0, n - 1)])
        ok_w &= in_window
        found_w = jnp.any(ok_w)
        w_first = jnp.argmax(ok_w)
        q_w, srv_w = widx[w_first], servers_w[w_first]

        # the recorder needs the committed candidate's score row as well;
        # keeping it out of the cond when record=False preserves the
        # uninstrumented program structure
        def full_scan(_):
            # every window candidate failed but more are queued: score them all
            servers, ok, sc = greedy_pick(st, arr_type)  # [n]
            cand = st.queued & ok
            q = jnp.argmax(cand)
            out = (q, servers[q], jnp.any(cand))
            return out + (sc[q],) if record else out

        def window_hit(_):
            out = (q_w, srv_w, found_w)
            return out + (sc_w[w_first],) if record else out

        picked = jax.lax.cond(
            ~found_w & (qlen > W), full_scan, window_hit, operand=None)
        q, server, found = picked[:3]
        score_row = picked[3] if record else None

        st = place_if(st, found, q, server, arr_type[q], arr_bytes[q], st.now,
                      queue_on_fail=False, score_row=score_row)
        act_any = jnp.any(st.slot_type >= 0)
        if sharded:
            act_any = axis.any(act_any)
        no_active = ~act_any
        dead = ~found & no_active & (st.ai >= n_valid) & jnp.any(st.queued)
        if metrics:
            mf = obs_metrics.count(st.metrics, "drain_steps", 1)
            mf = obs_metrics.count(
                mf, "drain_full_scans", (~found_w & (qlen > W)).astype(jnp.int32))
            mf = obs_metrics.count(
                mf, "deadlocks", (dead & ~st.deadlock).astype(jnp.int32))
            st = st._replace(metrics=mf)
        return st._replace(draining=found, deadlock=st.deadlock | dead)

    def finish_branch(st, rates, tt):
        # margin argmin: exactly-simultaneous completions (identical workloads
        # on same-spec servers) must resolve lowest-server-first like the
        # oracle's event loop; f32 noise would otherwise order them arbitrarily
        flat = tt.reshape(-1)
        if sharded:
            # the same margin-argmin, distributed: global min time by pmin,
            # local first-hit globalized by the shard's flat offset (global
            # flat order is (server, slot), so lo*K preserves it), then the
            # owning shard broadcasts the chosen slot's dt/arrival/type via
            # single-owner pmin reductions
            t_min = axis.pmin(jnp.min(flat))
            hit = flat <= t_min * (1.0 + 1e-5)
            k_loc = jnp.argmax(hit)
            g_flat = jnp.where(jnp.any(hit), lo * K + k_loc, m_g * K)
            k_flat_g = axis.pmin(g_flat)
            s_fin = k_flat_g // K  # global server index
            k_fin = k_flat_g % K
            s_l = jnp.clip(s_fin - lo, 0, m - 1)
            owned = (s_fin >= lo) & (s_fin < lo + m)
            dt = axis.pmin(jnp.where(
                owned, flat[jnp.clip(k_flat_g - lo * K, 0, m * K - 1)],
                jnp.inf))
            t_fin = st.now + dt
            st = advance(st, rates, t_fin - st.now)
            idx = axis.pmin(jnp.where(owned, st.slot_arr[s_l, k_fin], n))
            wtype = axis.pmin(jnp.where(owned, st.slot_type[s_l, k_fin], T))
            srow = jnp.where(owned, s_l, m)  # local clear; others drop
        else:
            t_min = jnp.min(flat)
            k_flat = jnp.argmax(flat <= t_min * (1.0 + 1e-5))
            s_fin, k_fin = k_flat // K, k_flat % K
            t_fin = st.now + flat[k_flat]
            st = advance(st, rates, t_fin - st.now)
            idx = st.slot_arr[s_fin, k_fin]
            wtype = st.slot_type[s_fin, k_fin]
            srow = s_fin
        st = apply_delta(st, s_fin, wtype, -1.0)
        if metrics:
            # observed slowdown = actual duration / solo duration on the
            # server that ran it -- the serving-SLO quantity next to waiting
            if sharded:
                srate = axis.pmin(jnp.where(
                    owned, dyn.solo[s_l, jnp.clip(wtype, 0)], jnp.inf))
                fin_col = jax.nn.one_hot(srow, m, dtype=jnp.float32)
            else:
                srate = dyn.solo[s_fin, jnp.clip(wtype, 0)]
                fin_col = jax.nn.one_hot(s_fin, m, dtype=jnp.float32)
            solo_dur = arr_bytes[jnp.clip(idx, 0, n - 1)] / jnp.maximum(
                srate, jnp.float32(1e-30))
            actual = t_fin - st.place_time[idx]
            mf = obs_metrics.count(st.metrics, "finishes", 1)
            mf = obs_metrics.observe(
                mf, "slowdown", actual / jnp.maximum(solo_dur, jnp.float32(1e-30)))
            mf = obs_metrics.add_server(mf, "finishes", fin_col)
            st = st._replace(metrics=mf)
        return st._replace(
            now=t_fin,
            makespan=t_fin,
            slot_type=st.slot_type.at[srow, k_fin].set(-1),
            slot_arr=st.slot_arr.at[srow, k_fin].set(-1),
            finish_time=st.finish_time.at[idx].set(t_fin),
            draining=jnp.any(st.queued),  # §V: completion may unblock the queue
        )

    def arrive_branch(st, rates, tt):
        del tt
        t_arr = arr_time[st.ai]
        st = advance(st, rates, t_arr - st.now)._replace(now=t_arr)
        if metrics:
            st = st._replace(metrics=obs_metrics.count(st.metrics, "arrivals", 1))
        wtype, nbytes = arr_type[st.ai], arr_bytes[st.ai]
        servers, ok, sc = greedy_pick(st, wtype[None])
        st = place_if(st, ok[0], st.ai, servers[0], wtype, nbytes, t_arr,
                      queue_on_fail=True, score_row=sc[0] if record else None)
        return st._replace(ai=st.ai + 1)

    def is_done(st):
        return st.deadlock | (
            (st.ai >= n_valid) & ~jnp.any(st.slot_type >= 0) & ~jnp.any(st.queued))

    def event_step(st):
        overflow = st.comp > dyn.tol_budget
        rates = _slot_rates(dyn, ldiag_keep, ldiag_lost, overflow,
                            st.colog_keep, st.colog_lost, st.slot_type)
        active = st.slot_type >= 0
        # observed (ground-truth) degradation of the running set, for Fig-5 audits
        solo = jnp.take_along_axis(dyn.solo, jnp.clip(st.slot_type, 0), axis=1)
        deg = jnp.where(active, 1.0 - rates / solo, -jnp.inf)
        # per-shard running max when sharded; globalized once after the loop
        st = st._replace(max_deg=jnp.maximum(st.max_deg, jnp.max(deg, initial=-jnp.inf)))
        if metrics:
            qdepth = jnp.sum(st.queued, dtype=jnp.float32)
            mf = obs_metrics.count(st.metrics, "events", 1)
            mf = obs_metrics.observe(mf, "queue_depth", qdepth)
            mf = obs_metrics.gauge_max(mf, "queue_peak", qdepth)
            # utilization-floor violations: events where a slot's *observed*
            # degradation exceeded the paper's limit, per server
            mf = obs_metrics.add_server(
                mf, "floor_violations",
                jnp.any(deg > cluster.degradation_limit, axis=1).astype(jnp.float32))
            mf = obs_metrics.add_server(
                mf, "busy_events", jnp.any(active, axis=1).astype(jnp.float32))
            st = st._replace(metrics=mf)

        tt = jnp.where(active, st.slot_rem / rates, jnp.inf)
        t_fin_local = st.now + jnp.min(tt)
        t_arr = jnp.where(st.ai < n_valid, arr_time[jnp.clip(st.ai, 0, n - 1)], jnp.inf)
        if sharded:
            # the event picker needs fleet-wide scalars: earliest completion
            # anywhere, any slot busy anywhere. One pmin + one psum per
            # micro-event; the branch index then replicates, so every shard
            # enters the same lax.switch arm and collectives stay aligned.
            t_fin = axis.pmin(t_fin_local)
            any_active = axis.any(jnp.any(active))
        else:
            t_fin = t_fin_local
            any_active = jnp.any(active)
        queue_any = jnp.any(st.queued)
        drain = st.draining | (queue_any & ~any_active & (st.ai >= n_valid))
        branch = jnp.where(drain, 0, jnp.where(any_active & (t_fin <= t_arr), 1, 2))
        return jax.lax.switch(
            branch, [drain_branch, finish_branch, arrive_branch], st, rates, tt)

    if sharded:
        # collectives may not run in a while_loop's cond; carry the (fully
        # replicated) done flag computed at the end of each body instead
        def body(carry):
            st, it, _ = carry
            st = event_step(st)
            act_any = axis.any(jnp.any(st.slot_type >= 0))
            done = st.deadlock | (
                (st.ai >= n_valid) & ~act_any & ~jnp.any(st.queued))
            return st, it + 1, done

        def cond(carry):
            st, it, done = carry
            return (it < n_steps) & ~done

        st, _, _ = jax.lax.while_loop(
            cond, body, (st0, jnp.int32(0), jnp.int32(0) >= n_valid))
        max_deg = axis.pmax(st.max_deg)
        if telemetry:
            # each arrival's observation integrals accumulated on the single
            # shard owning its server: the psum is a plain gather, bit-exact
            st = st._replace(obs_co=axis.psum(st.obs_co),
                             obs_lost=axis.psum(st.obs_lost),
                             obs_logr=axis.psum(st.obs_logr))
        st = st._replace(max_deg=max_deg)
    else:
        def body(carry):
            st, it = carry
            return event_step(st), it + 1

        def cond(carry):
            st, it = carry
            return (it < n_steps) & ~is_done(st)

        st, _ = jax.lax.while_loop(cond, body, (st0, jnp.int32(0)))
    return EngineTrace(st.placement, st.was_queued, st.place_time, st.finish_time,
                       st.makespan, st.max_deg, st.deadlock, st.obs_co, st.obs_lost,
                       st.obs_logr, st.metrics, st.rec)


@partial(jax.jit,
         static_argnames=("objective", "scorer", "n_steps", "telemetry",
                          "metrics", "record", "axis"))
def run_trace(
    cluster: PackedCluster,
    dyn: PackedDynamics,
    arr_time: jax.Array,  # f32[n], non-decreasing
    arr_type: jax.Array,  # i32[n] grid types
    arr_bytes: jax.Array,  # f32[n] data_total per arrival
    *,
    objective: str = "sum_avg",
    scorer: Scorer | None = None,
    n_steps: int | None = None,
    telemetry: bool = False,
    metrics: bool = False,
    record: bool = False,
    rec: "obs_recorder.RecState | None" = None,
    rec_ctx: "obs_recorder.RecCtx | None" = None,
    axis=None,
) -> EngineTrace:
    """Run one arrival trace to completion entirely on device.

    Every iteration is one micro-event; 4n + 8 steps are provably enough (n
    arrivals, <= n completions, <= n successful drain placements, and one
    failed drain check per completion), the loop exits early once all work
    has completed, and the whole loop jit-compiles once per (m, n) shape.

    Placements and queue decisions reproduce the float64 oracle: canonical
    per-server sum refreshes keep same-spec servers bitwise-tied, and
    ``argmin_with_margin`` resolves sub-margin score/finish-time ties to the
    lowest index exactly like the oracle's strict-improvement loops.

    ``scorer=None`` uses the engine's incremental evaluation of the shared
    scoring contract (O(Q m T) with no counts @ D re-reduction); passing an
    explicit backend (e.g. the Pallas kernel via ``engine.make_scorer``)
    routes every candidate batch through it instead.

    ``telemetry=True`` additionally emits the fixed-shape observation log the
    streaming D-estimator consumes (``repro.telemetry``): per arrival, the
    time-integrated co-resident type counts over its run (``obs_co`` [n, T],
    excluding the workload itself) and the time it spent while its server was
    past the physical TDP (``obs_lost`` [n]). Both integrate between
    micro-events, so partial co-residency overlaps are weighted exactly by
    their duration. Off by default: the accumulation adds an O(m K T) scatter
    per time-advancing event, and the static flag compiles it out entirely.

    ``metrics=True`` threads an ``obs.MetricFrame`` through the event loop
    (queue depth per event, waiting time / Eqn-4 headroom at commit, drain
    occupancy, observed slowdown at finish, per-server floor violations) and
    returns it on ``EngineTrace.metrics``. Purely additive to the carry:
    decisions are unchanged, and with the flag off the slot is ``None`` --
    an empty pytree -- so the compiled program is byte-identical.

    ``record=True`` threads the decision flight recorder (``obs.recorder``)
    through the loop: one packed provenance row per placement commit or
    queue-at-arrival decision, returned on ``EngineTrace.rec``. Same
    off-switch contract as ``metrics``; recording never feeds back into
    scoring, so recorded runs stay decision-identical. ``rec`` continues an
    existing ring (defaults to a fresh ring of capacity 2n) and ``rec_ctx``
    supplies the estimator/detector context to sample (defaults to the
    no-estimator context).

    ``axis`` (a :class:`~repro.distributed.server_axis.ServerAxis`) shards
    every ``[m, ...]`` input over its mesh and runs the event loop SPMD:
    each shard scores and books its own servers, and only the per-event
    scalars (winning score/index, earliest finish, any-active) cross the
    mesh. ``None``/dense lowers to the byte-identical single-device program.
    """
    if axis is None or not axis.is_sharded:
        return _trace_segment(
            cluster, dyn, arr_time, arr_type, arr_bytes,
            jnp.int32(arr_time.shape[0]), objective=objective, scorer=scorer,
            n_steps=n_steps, telemetry=telemetry, metrics=metrics,
            record=record, rec=rec, rec_ctx=rec_ctx)

    m_g = cluster.m
    axis.validate(m_g)

    if record:
        # resolve defaults *outside* the shard_map so rec/rec_ctx arrive as
        # operands with well-defined specs (ctx rows shard, the ring
        # replicates)
        n = int(arr_time.shape[0])
        rec = rec if rec is not None else obs_recorder.init(2 * n)
        rec_ctx = rec_ctx if rec_ctx is not None else \
            obs_recorder.default_ctx(m_g, m_g)

        def seg(cluster_l, dyn_l, a_time, a_type, a_bytes, n_valid,
                rec_l, ctx_l):
            return _trace_segment(
                cluster_l, dyn_l, a_time, a_type, a_bytes, n_valid,
                objective=objective, scorer=scorer, n_steps=n_steps,
                telemetry=telemetry, metrics=metrics, record=True,
                rec=rec_l, rec_ctx=ctx_l, axis=axis)

        extra_in = (obs_recorder.rec_specs(axis),
                    obs_recorder.ctx_specs(axis, rec_ctx))
        extra_args = (rec, rec_ctx)
    else:
        def seg(cluster_l, dyn_l, a_time, a_type, a_bytes, n_valid):
            return _trace_segment(
                cluster_l, dyn_l, a_time, a_type, a_bytes, n_valid,
                objective=objective, scorer=scorer, n_steps=n_steps,
                telemetry=telemetry, metrics=metrics, axis=axis)

        extra_in = ()
        extra_args = ()

    out_specs = EngineTrace(
        placement=axis.rep(), was_queued=axis.rep(), place_time=axis.rep(),
        finish_time=axis.rep(), makespan=axis.rep(), max_deg=axis.rep(),
        deadlock=axis.rep(), obs_co=axis.rep(), obs_lost=axis.rep(),
        obs_logr=axis.rep(),
        metrics=obs_metrics.frame_specs(axis) if metrics else None,
        rec=obs_recorder.rec_specs(axis) if record else None)
    mapped = axis.shard_map(
        seg,
        in_specs=(axis.shard_leading(cluster, m_g),
                  axis.shard_leading(dyn, m_g),
                  axis.rep(), axis.rep(), axis.rep(), axis.rep()) + extra_in,
        out_specs=out_specs)
    return mapped(cluster, dyn, arr_time, arr_type, arr_bytes,
                  jnp.int32(arr_time.shape[0]), *extra_args)


# --- array-native local search (core/refine.py's device backend) ----------------

@partial(jax.jit, static_argnames=("max_iters",))
def local_search_jax(
    cluster: PackedCluster, counts: jax.Array, max_iters: int = 100
) -> tuple[jax.Array, jax.Array]:
    """Best-improvement hill-climb over single-workload relocations.

    The array counterpart of ``refine.local_search``'s relocation moves: every
    (source server s, resident type t, target server u) move is scored in one
    vectorized evaluation through the same incremental load algebra as the
    shared scorer, and the steepest feasible descent step is applied until no
    move improves the paper's global objective (sum of per-server average
    loads). Returns (counts, n_moves).
    """
    m, T = counts.shape
    diag = jnp.diagonal(cluster.D, axis1=1, axis2=2)  # [m, T]

    def loads_after_removal(c):
        """avg_load [m, T] of each server after removing one of each type.

        (The *addition* side is exactly the shared scorer over all T types;
        only removal needs its own algebra.)
        """
        comp0 = c @ cluster.rs + (c * cluster.resident) @ cluster.fs  # [m]
        delta = cluster.rs[None, :] + cluster.resident * cluster.fs[None, :]  # [m, T]
        cache = (comp0[:, None] - delta) / cluster.llc_budget[:, None]
        col0 = jnp.einsum("mt,mtu->mu", c, cluster.D)  # [m, T]
        col = col0[:, None, :] - cluster.D  # [m, T(moved), T]
        d_pred = jnp.clip(col - diag[:, None, :], 0.0, 1.0)
        present = (c[:, None, :] - jnp.eye(T, dtype=c.dtype)[None, :, :]) > 0
        maxd = jnp.max(jnp.where(present, d_pred, -jnp.inf), axis=-1)
        maxd = jnp.where(jnp.any(present, axis=-1), maxd, 0.0)
        return cache, maxd

    def body(carry):
        c, moves, improved = carry
        cache_now, maxd_now = server_loads(cluster, c)
        avg0 = 0.5 * (cache_now + maxd_now)  # [m]
        cache_rm, maxd_rm = loads_after_removal(c)  # [m, T]
        cache_ad, maxd_ad = (  # shared scorer: every type on every server
            a.T for a in score_candidates_jnp(cluster, c, jnp.arange(T)))
        avg_rm = 0.5 * (cache_rm + maxd_rm)
        avg_ad = 0.5 * (cache_ad + maxd_ad)
        # relocation targets honour the fleet-health mask like every other
        # scoring consumer: no move may land work on an evicted server
        feas_ad = ((maxd_ad < cluster.degradation_limit) & (cache_ad <= 1.0)
                   & (cluster.active > 0.5)[:, None])

        # delta[s, t, u] = objective change of moving one type-t from s to u
        delta = (avg_rm - avg0[:, None])[:, :, None] + (avg_ad - avg0[:, None]).T[None, :, :]
        valid = (c[:, :, None] > 0) & feas_ad.T[None, :, :]
        valid &= ~jnp.eye(m, dtype=bool)[:, None, :]
        delta = jnp.where(valid, delta, jnp.inf)
        flat = jnp.argmin(delta.reshape(-1))
        best = delta.reshape(-1)[flat]
        s, t, u = flat // (T * m), (flat // m) % T, flat % m
        improve = best < -1e-9
        c = jnp.where(improve, c.at[s, t].add(-1.0).at[u, t].add(1.0), c)
        return c, moves + improve.astype(jnp.int32), improve

    def cond(carry):
        _, moves, improved = carry
        return improved & (moves < max_iters)

    c, moves, _ = jax.lax.while_loop(
        cond, body, (counts, jnp.int32(0), jnp.asarray(True)))
    return c, moves
