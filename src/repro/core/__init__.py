"""Core library: the paper's workload-consolidation contribution.

Public API (stable):
  Workload, parse_workloads, grid_types        -- §III characterization
  ServerSpec, M1, M2, PAPER_CLUSTER            -- Table I testbed
  solo_throughput, solo_throughput_grid        -- §III model (Fig 1-2)
  simulate_corun, competing_cache_bytes        -- §IV ground truth
  predict_tdp_hit, profile_pairwise*, predict_degradations  -- Eqns 1-3
  check_consolidation, DEGRADATION_LIMIT       -- §V criteria (Eqns 4-5)
  ConsolidationEngine, EngineResult            -- THE unified online runtime
  AdaptiveEngine, AdaptiveResult               -- closed observe/estimate/schedule
                                                  loop (repro.telemetry)
  score_candidates, make_scorer                -- shared Q x m scoring iface
  PackedDynamics, run_trace, corun_rates       -- device engine internals
  ClosedLoopConfig, run_closed_loop            -- fused multi-segment loop
  PackedCluster, greedy_sequence_jax, brute_force_jax, score_candidates_jnp
                                               -- jitted allocation paths
  ClusterState, greedy_place, greedy_sequence, brute_force, OnlineScheduler
                                               -- numpy reference oracle
  local_search, local_search_engine, local_search_jax -- offline refinement
  JobProfile, PodSpec, FleetState, pack_jobs   -- TPU-fleet adaptation

See DESIGN.md §8 for the engine architecture and the backend matrix.
"""
from .binpack import (
    ClusterState,
    average_min_throughput,
    average_min_throughput_simulated,
    best_fit_cache,
    brute_force,
    first_fit,
    greedy_place,
    greedy_sequence,
    run_allocator,
)
from .calibrate import calibrate_alpha, pick_alpha, sweep_alpha
from .refine import local_search, local_search_engine
from .binpack_jax import (
    QUEUED,
    PackedCluster,
    brute_force_jax,
    counts_from_assignments,
    evaluate_assignment,
    greedy_choice,
    greedy_sequence_jax,
    greedy_step,
    score_candidates_jnp,
    server_loads,
)
from .cluster import (
    FleetState,
    JobProfile,
    PodSpec,
    additive_degradations,
    fleet_throughput_report,
    pack_jobs,
    pair_degradation,
    roofline_degradations,
)
from .contention import (
    additive_degradation,
    predict_degradations,
    predict_tdp_hit,
    predict_tdp_n,
    profile_pairwise,
    profile_pairwise_fast,
    tdp_lhs,
    tdp_lhs_naive,
)
from .criteria import DEGRADATION_LIMIT, AdmissionCheck, check_consolidation
from .engine import (
    AdaptiveEngine,
    AdaptiveResult,
    ConsolidationEngine,
    EngineResult,
    make_scorer,
    score_candidates,
)
from .engine_jax import PackedDynamics, corun_rates, local_search_jax, run_trace
from .closed_loop import ClosedLoopConfig, run_closed_loop
from .scheduler import OnlineScheduler, ScheduleResult
from .server import M1, M2, PAPER_CLUSTER, TPU_V5E_HOST, TPU_V5E_POD256, ServerSpec
from .simulator import (
    CoRunResult,
    cache_overflow,
    competing_cache_bytes,
    corun_throughput_grid,
    makespan_consolidated,
    makespan_sequential,
    simulate_corun,
)
from .throughput import solo_runtime, solo_throughput, solo_throughput_grid
from .workload import (
    FS_GRID,
    RS_GRID,
    Workload,
    characterize,
    grid_types,
    parse_workloads,
    snap_to_grid,
    type_index,
)
