"""Physical-server models (paper Table I) and TPU-fleet analogues.

The paper's testbed (Table I):

  M1: Core i7 @2.00GHz,      LLC 6MB, mem 8GB, system file cache 980MB, disk cache 12MB
  M2: Core2 Duo @3.00GHz,    LLC 6MB, mem 3GB, system file cache 455MB, disk cache  8MB

Beyond the raw Table-I numbers, the simulator needs per-level performance
constants (bandwidths + per-request overheads). These are *calibration*
constants chosen so the simulator reproduces the paper's qualitative and
quantitative claims:

  * three throughput levels for write / two for read (§III.C, Fig 1-2);
  * throughput monotonically increasing in RS (disk-overhead amortization);
  * losing LLC costs >50% throughput for RS > 8KB (§V, Fig 6);
  * the *actual* TDP sits at ~7.76MB vs the 6MB LLC, i.e. the physical
    cache tolerates ~1.29x oversubscription -> the paper calibrates α≈1.3.
"""
from __future__ import annotations

import dataclasses

from .units import GB, KB, MB, MS, US


@dataclasses.dataclass(frozen=True)
class ServerSpec:
    """A physical server as seen by the consolidation model (one 2-D bin)."""

    name: str
    llc_bytes: float  # last-level cache (the paper's hard contention resource)
    mem_bytes: float
    file_cache_bytes: float  # OS system-file-cache
    disk_cache_bytes: float  # drive-embedded cache
    cores: int
    ghz: float

    # --- per-level performance constants (simulator calibration) ---------
    # level 1: working set fits LLC;  level 2: fits file-cache + disk-cache;
    # level 3 (write only): spills to actual disk.
    bw_l1_read: float = 6.2 * GB
    bw_l2_read: float = 2.2 * GB
    bw_l1_write: float = 5.1 * GB
    bw_l2_write: float = 1.8 * GB
    bw_l3_write: float = 115 * MB  # actual disk write speed
    ov_l12: float = 1.2 * US  # per-request overhead at cache levels
    ov_l3: float = 7.0 * MS  # seek + rotational + controller at disk level

    # shared-resource capacities for co-run contention (§IV.B)
    shared_bw: float = 3.2 * GB  # storage-subsystem aggregate bandwidth
    cpu_req_cost: float = 2.5 * US  # CPU time per file operation
    cpu_byte_cost: float = 0.08e-9  # CPU time per byte moved

    # physical LLC over-subscription tolerance: actual TDP / LLC size.
    # The paper measures actual TDPs at ~7.76MB against a 6MB LLC -> ~1.29.
    # (This is a property of the *hardware*; α in Eqn (5) is the scheduler's
    # estimate of it, swept in Fig 9.)
    llc_tolerance: float = 7.76 / 6.0

    @property
    def cache_spill_bytes(self) -> float:
        """Capacity of the level-2 tier (file cache + disk cache), §III.C."""
        return self.file_cache_bytes + self.disk_cache_bytes


# --- Paper Table I ------------------------------------------------------------
M1 = ServerSpec(
    name="M1",
    llc_bytes=6 * MB,
    mem_bytes=8 * GB,
    file_cache_bytes=980 * MB,
    disk_cache_bytes=12 * MB,
    cores=4,
    ghz=2.0,
)

# M2 is older/smaller: scale the cache-level bandwidths down, disk similar.
M2 = ServerSpec(
    name="M2",
    llc_bytes=6 * MB,
    mem_bytes=3 * GB,
    file_cache_bytes=455 * MB,
    disk_cache_bytes=8 * MB,
    cores=2,
    ghz=3.0,
    bw_l1_read=4.6 * GB,
    bw_l2_read=1.7 * GB,
    bw_l1_write=3.9 * GB,
    bw_l2_write=1.4 * GB,
    bw_l3_write=95 * MB,
    shared_bw=2.4 * GB,
)

#: The paper's evaluation cluster (§VIII): 2x M1 + 2x M2.
PAPER_CLUSTER = (M1, dataclasses.replace(M1, name="M1b"), M2, dataclasses.replace(M2, name="M2b"))


# --- TPU analogues (hardware-adaptation, DESIGN.md §2) ------------------------
# A TPU v5e host: 8 chips, 16GB HBM each. The consolidation "cache" dimension
# becomes the HBM byte budget; the shared bandwidth becomes aggregate HBM bw.
TPU_V5E_HOST = ServerSpec(
    name="tpu-v5e-host",
    llc_bytes=8 * 16 * GB,  # HBM capacity = the hard contention resource
    mem_bytes=512 * GB,  # host DRAM
    file_cache_bytes=256 * GB,  # host staging buffers (input pipeline)
    disk_cache_bytes=4 * GB,
    cores=224,
    ghz=2.0,
    shared_bw=8 * 819 * GB,  # aggregate HBM bandwidth
    llc_tolerance=1.0,  # HBM does not over-subscribe: OOM is a cliff
)

# One v5e pod-slice of 256 chips treated as a single consolidation bin
# (used by core/cluster.py when packing whole jobs onto pod slices).
TPU_V5E_POD256 = ServerSpec(
    name="tpu-v5e-pod256",
    llc_bytes=256 * 16 * GB,
    mem_bytes=32 * 512 * GB,
    file_cache_bytes=32 * 256 * GB,
    disk_cache_bytes=128 * GB,
    cores=32 * 224,
    ghz=2.0,
    shared_bw=256 * 819 * GB,
    llc_tolerance=1.0,
)
