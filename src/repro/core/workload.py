"""Workload characterization (paper §III.A, contribution C1).

A data-intensive workload is characterized by two parameters, exactly as in
the paper (inspired by Iometer / IOzone / TestDFSIO / Bonnie++):

  FS -- file size: the block-sized chunk a Hadoop *task* works on
        (order of 64MB by default, NOT the terabyte-scale job size).
  RS -- request size: bytes read/written per file operation.

The paper profiles the pairwise-degradation matrix on a grid of
10 request sizes (1KB..512KB) x 23 file sizes (1KB..1GB), i.e. 230 workload
*types* per operation, 52_900 pair experiments per server (§IV.B, §VIII).
We reproduce that grid verbatim.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Iterable, Sequence

import numpy as np

from .units import GB, KB, MB, fmt_size, parse_size

# --- The paper's profiling grid (§IV.B / §VIII) -----------------------------
# Ten request sizes: 1KB - 512KB (powers of two).
RS_GRID = tuple(float(KB * 2**i) for i in range(10))
# Twenty-three file sizes: 1KB - 1GB, log-spaced (2^0 .. 2^20 KB covers 21
# points; the paper uses 23, so we insert the half-way points 1.5GB-style
# steps at the top of the cache-transition region where resolution matters).
FS_GRID = tuple(
    float(v)
    for v in sorted(
        set(
            [KB * 2**i for i in range(21)]  # 1KB .. 1GB
            + [6 * MB, 448 * MB]  # LLC edge + file-cache edge resolution
        )
    )
)
assert len(RS_GRID) == 10 and len(FS_GRID) == 23, (len(RS_GRID), len(FS_GRID))

OPS = ("read", "write")


@dataclasses.dataclass(frozen=True)
class Workload:
    """One data-intensive workload (a Hadoop map task against HDFS).

    ``data_total`` is the total number of bytes the task must move before it
    completes; it determines the solo run time AR_i = data_total / T_solo
    used by the makespan analysis of §V. It defaults to one pass over the
    file.
    """

    fs: float  # file size in bytes (block-sized chunk)
    rs: float  # request size in bytes
    op: str = "read"  # 'read' | 'write'
    data_total: float | None = None
    name: str = ""

    def __post_init__(self):
        if self.op not in OPS:
            raise ValueError(f"op must be one of {OPS}, got {self.op!r}")
        if self.fs <= 0 or self.rs <= 0:
            raise ValueError("fs and rs must be positive")
        if self.data_total is None:
            object.__setattr__(self, "data_total", float(self.fs))

    def __repr__(self) -> str:  # matches the paper's "(RS, FS)" tuples
        tag = f" {self.name}" if self.name else ""
        return f"W({fmt_size(self.rs)}, {fmt_size(self.fs)}, {self.op}{tag})"


# --- Grid indexing -----------------------------------------------------------

def grid_types(op: str = "read") -> list[Workload]:
    """All 230 (RS, FS) workload types of the paper's profiling grid."""
    return [Workload(fs=fs, rs=rs, op=op) for rs in RS_GRID for fs in FS_GRID]


def type_index(w: Workload) -> int:
    """Index of the nearest grid type for workload ``w`` (nearest in log space)."""
    ri = int(np.argmin(np.abs(np.log(np.asarray(RS_GRID)) - np.log(w.rs))))
    fi = int(np.argmin(np.abs(np.log(np.asarray(FS_GRID)) - np.log(w.fs))))
    return ri * len(FS_GRID) + fi


def snap_to_grid(w: Workload) -> Workload:
    """Snap a workload to its nearest profiling-grid type (for D-matrix lookup)."""
    idx = type_index(w)
    ri, fi = divmod(idx, len(FS_GRID))
    return dataclasses.replace(w, rs=RS_GRID[ri], fs=FS_GRID[fi])


# --- Parsing the paper's Table III tuples ------------------------------------
_TUPLE_RE = re.compile(r"\(\s*([^,()]+)\s*,\s*([^,()]+)\s*\)")


def parse_workloads(text: str, op: str = "read") -> list[Workload]:
    """Parse '(32KB, 64KB), (4KB, 16KB), ...' as (RS, FS) pairs -> Workloads.

    The paper writes tuples as (RS, FS) -- request size first (Table III).
    """
    out = []
    for m in _TUPLE_RE.finditer(text):
        rs, fs = parse_size(m.group(1)), parse_size(m.group(2))
        out.append(Workload(fs=fs, rs=rs, op=op))
    if not out:
        raise ValueError(f"no (RS, FS) tuples found in {text!r}")
    return out


def characterize(request_trace: Sequence[tuple[str, float]], file_bytes: float) -> Workload:
    """Characterize an observed I/O trace into a (FS, RS) workload (C1).

    ``request_trace`` is a sequence of (op, nbytes) file operations. The
    request size is the byte-weighted typical operation size (geometric mean
    weighted by bytes, robust to a few metadata-sized ops); the op is the
    majority op by bytes.
    """
    if not request_trace:
        raise ValueError("empty trace")
    sizes = np.array([n for _, n in request_trace], dtype=float)
    by_op = {op: 0.0 for op in OPS}
    for op, n in request_trace:
        by_op[op] = by_op.get(op, 0.0) + n
    op = max(by_op, key=lambda k: by_op[k])
    rs = float(np.exp(np.average(np.log(sizes), weights=sizes)))
    return Workload(fs=float(file_bytes), rs=rs, op=op)


def total_bytes(workloads: Iterable[Workload]) -> float:
    return float(sum(w.data_total for w in workloads))
