"""Single-workload-on-single-server throughput model (paper §III, C2).

The paper's Figures 1-2 show that solo throughput is a piecewise function of
FS with per-request-overhead amortization in RS:

  level 1 (highest):       FS fits the LLC                (FS <= LLC)
  level 2 (intermediate):  FS fits file cache + disk cache
  level 3 (write only):    FS exceeds file+disk cache -> true disk speed

Within a level with bandwidth ``bw`` and per-request overhead ``ov`` the
throughput of request size RS is the classic amortization curve

  T(RS) = RS / (ov + RS / bw)          (monotone increasing in RS, -> bw)

which reproduces the paper's "accessing disks with large RSs is always much
more efficient" observation (§III.C: 1MB at RS=1KB pays the overhead 1000x,
at RS=512KB only 2x).
"""
from __future__ import annotations

import numpy as np

from .server import ServerSpec
from .workload import Workload


def level_of(server: ServerSpec, fs: float, op: str) -> int:
    """Which throughput level (1/2/3) a solo workload with file size ``fs`` runs at."""
    if fs <= server.llc_bytes:
        return 1
    if op == "read" or fs <= server.cache_spill_bytes:
        return 2  # reads stay at level 2 (paper reports two read levels)
    return 3


def level_params(server: ServerSpec, level: int, op: str) -> tuple[float, float]:
    """(bandwidth, per-request overhead) for a level. Level 3 exists for writes."""
    if op == "read":
        bw = {1: server.bw_l1_read, 2: server.bw_l2_read}[min(level, 2)]
        return bw, server.ov_l12
    bw = {1: server.bw_l1_write, 2: server.bw_l2_write, 3: server.bw_l3_write}[level]
    ov = server.ov_l3 if level == 3 else server.ov_l12
    return bw, ov


def amortized(bw: float, ov: float, rs: float) -> float:
    """T(RS) = RS / (ov + RS/bw)."""
    return rs / (ov + rs / bw)


def solo_throughput(server: ServerSpec, w: Workload) -> float:
    """Solo throughput (bytes/s) of workload ``w`` on ``server`` (Figures 1-2)."""
    lvl = level_of(server, w.fs, w.op)
    bw, ov = level_params(server, lvl, w.op)
    return amortized(bw, ov, w.rs)


def solo_throughput_grid(server: ServerSpec, rs_grid, fs_grid, op: str) -> np.ndarray:
    """Vectorized solo throughput over a (RS x FS) grid -> array [len(rs), len(fs)].

    This is the surface plotted in the paper's Figures 1 (M1) and 2 (M2).
    """
    rs = np.asarray(rs_grid, dtype=float)[:, None]
    fs = np.asarray(fs_grid, dtype=float)[None, :]

    lvl = np.where(fs <= server.llc_bytes, 1, 2)
    if op == "write":
        lvl = np.where(fs > server.cache_spill_bytes, 3, lvl)

    out = np.zeros((rs.shape[0], fs.shape[1]))
    for level in (1, 2, 3):
        mask = lvl == level
        if not mask.any():
            continue
        bw, ov = level_params(server, level, op)
        out = np.where(mask, amortized(bw, ov, rs), out)
    return out


def solo_runtime(server: ServerSpec, w: Workload) -> float:
    """AR_i of §V: time to move ``data_total`` bytes when running alone."""
    return w.data_total / solo_throughput(server, w)
