"""Byte/time unit helpers used across the consolidation core.

All core-model quantities are plain floats in SI-ish units:
  sizes      -> bytes
  times      -> seconds
  throughput -> bytes / second
"""

KB = 1024.0
MB = 1024.0 * KB
GB = 1024.0 * MB

US = 1e-6
MS = 1e-3


def parse_size(text: str) -> float:
    """Parse sizes like '32KB', '64MB', '1GB', '512' (bytes) into bytes.

    Used to ingest the paper's Table III workload tuples verbatim.
    """
    s = text.strip().upper().replace(" ", "")
    for suffix, mult in (("KB", KB), ("MB", MB), ("GB", GB), ("K", KB), ("M", MB), ("G", GB), ("B", 1.0)):
        if s.endswith(suffix):
            return float(s[: -len(suffix)]) * mult
    return float(s)


def fmt_size(n: float) -> str:
    for mult, suffix in ((GB, "GB"), (MB, "MB"), (KB, "KB")):
        if n >= mult:
            v = n / mult
            return f"{v:.0f}{suffix}" if abs(v - round(v)) < 1e-9 else f"{v:.2f}{suffix}"
    return f"{n:.0f}B"
