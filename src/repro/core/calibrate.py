"""Empirical alpha calibration (paper §V).

"alpha can be empirically found through comparing the actual TDPs of a
system versus its calculated ones. In our case ... actual TDPs are around
7.76MB, whereas the calculated TDPs are 6MB. Thus, for our system alpha
should be about 7.76/6 ~= 1.3."

``calibrate_alpha`` automates exactly that procedure: sweep co-run sets
along N for a grid of (RS, FS) combinations, locate the observed degradation
cliff, convert it to competing-bytes at the cliff, and divide by the Eqn-2
prediction. ``sweep_alpha`` additionally reproduces Fig 9's outer loop:
evaluate the scheduler end to end at several alphas and report the
average-minimum-throughput metric, so deployments can pick the balanced
setting the way the paper does.
"""
from __future__ import annotations

import numpy as np

from .binpack import ClusterState, average_min_throughput_simulated, greedy_sequence
from .criteria import DEGRADATION_LIMIT
from .server import ServerSpec
from .simulator import simulate_corun
from .units import KB, MB
from .workload import Workload


def observed_tdp_bytes(
    server: ServerSpec,
    rs: float,
    fs: float,
    max_n: int = 12,
    threshold: float = DEGRADATION_LIMIT,
) -> float | None:
    """Competing-byte total at the first N whose degradation exceeds the §V
    limit (``criteria.DEGRADATION_LIMIT`` -- the one source of truth for the
    50% threshold)."""
    if fs > server.llc_bytes:
        return None  # not LLC-resident: no TDP exists (Eqn 2's CS set)
    for n in range(2, max_n + 1):
        res = simulate_corun(server, [Workload(fs=fs, rs=rs)] * n)
        if res.degradations[0] > threshold:
            return n * (rs + fs)
    return None


def calibrate_alpha(
    server: ServerSpec,
    rs_grid=(64 * KB, 128 * KB, 256 * KB),
    fs_grid=(512 * KB, 1 * MB, 1280 * KB, 2 * MB),
) -> float:
    """The paper's alpha = mean(observed TDP bytes / calculated TDP bytes)."""
    ratios = []
    for rs in rs_grid:
        for fs in fs_grid:
            obs = observed_tdp_bytes(server, rs, fs)
            if obs is not None:
                ratios.append(obs / server.llc_bytes)
    if not ratios:
        raise RuntimeError("no TDP observed on the calibration grid")
    return float(np.mean(ratios))


def sweep_alpha(
    servers, D, initial_assignments, arrivals, alphas=(1.0, 1.1, 1.2, 1.3, 1.4, 1.5)
) -> dict[float, float]:
    """Fig 9's outer loop: end-to-end scheduler quality per alpha."""
    out = {}
    for alpha in alphas:
        state = ClusterState.empty(list(servers), list(D), alpha=alpha)
        state.assignments = [list(a) for a in initial_assignments]
        _, queued = greedy_sequence(state, arrivals)
        # queued workloads count as zero throughput against the metric
        metric = average_min_throughput_simulated(state)
        out[alpha] = metric - 0.1 * len(queued) / max(len(arrivals), 1)
    return out


def pick_alpha(sweep: dict[float, float]) -> float:
    return max(sweep, key=lambda a: sweep[a])
