"""The two consolidation criteria of paper §V (C5).

Criterion 1 (Eqn 4, makespan): admit a new workload onto a server only if
  every co-run workload's *predicted* total degradation stays below 50%:
      D_i = O_i / (AR_i + O_i) < 0.5    for all i (including the new one).
  Fig 5's argument: D_i < 0.5  <=>  O_i < AR_i, so consolidation always beats
  running the set sequentially. If no server qualifies, the workload queues.

Criterion 2 (Eqn 5, cache): the total data competing for the LLC must fit an
  over-subscription budget:
      sum_i RS_i + sum_{i in CS} FS_i <= alpha * CacheSize,
      CS = {i | FS_i <= CacheSize}.
  alpha is the scheduler's estimate of the hardware's tolerance (the paper
  calibrates alpha ~= 7.76/6 ~= 1.3 on its testbed and sweeps {1, 1.3, 1.5}
  in Fig 9).
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np

from .contention import predict_degradations, tdp_lhs
from .server import ServerSpec
from .workload import Workload

#: Eqn (4) threshold: degradation beyond this doubles execution time (§IV).
DEGRADATION_LIMIT = 0.5


def eviction_rate_floor(limit: float = DEGRADATION_LIMIT) -> float:
    """The observed-throughput fraction at which a server leaves the fleet.

    Criterion 1's threshold, read as a *health* rule: step-time inflation
    D = O / (AR + O) >= ``limit`` is the same condition as the observed rate
    dropping to <= (1 - limit) x its reference (for limit = 0.5, running at
    half speed, i.e. 2x slower). Both consumers of that rule -- the
    straggler monitor (``distributed.fault_tolerance.HeartbeatMonitor
    .stragglers``) and the fleet failure detector (``fleet.detect``, whose
    reference is the estimated base rate) -- read this single conversion, so
    eviction and straggler policy cannot drift apart.
    """
    if not 0.0 < limit < 1.0:
        raise ValueError(f"degradation limit must be in (0, 1), got {limit}")
    return 1.0 - limit


@dataclasses.dataclass(frozen=True)
class AdmissionCheck:
    """Result of evaluating both criteria for a candidate co-run set."""

    ok: bool
    max_degradation: float  # max_j predicted D_j           (criterion 1 load)
    cache_in_use: float  # competing bytes / (alpha*LLC)  (criterion 2 load), 1.0 == full
    degradations: tuple[float, ...]

    @property
    def avg_load(self) -> float:
        """Fig 8's Avg(CacheInUse, Max(Dy)) -- the greedy's per-server score."""
        return 0.5 * (self.cache_in_use + self.max_degradation)


def check_consolidation(
    server: ServerSpec,
    workloads: Sequence[Workload],
    D: np.ndarray,
    alpha: float = 1.3,
    degradation_limit: float = DEGRADATION_LIMIT,
) -> AdmissionCheck:
    """Evaluate criteria (4) and (5) for placing ``workloads`` together.

    The degradation estimate comes from the profiled D matrix via the
    additive model -- this is exactly what Fig 8's greedy consults
    ("Max(Dy) is calculated based on previously collected D_{x,y}s").
    """
    if not workloads:
        return AdmissionCheck(True, 0.0, 0.0, ())
    deg = predict_degradations(D, workloads)
    max_d = float(deg.max())
    cache = tdp_lhs(server, workloads) / (alpha * server.llc_bytes)
    ok = (max_d < degradation_limit) and (cache <= 1.0)
    return AdmissionCheck(ok, max_d, cache, tuple(float(x) for x in deg))
