"""Two-dimensional bin packing for workload consolidation (paper §VI-§VII).

Servers are 2-D bins (Fig 7): dimension 1 is the LLC-competing data budget
(criterion 2), dimension 2 is the maximum mutual throughput degradation
(criterion 1). Workloads are *interacting* objects -- placing one changes the
size of the others (the paper notes this makes the problem strictly harder
than classical bin packing).

Implemented allocators:
  * ``greedy_place``    -- the paper's greedy (Fig 8 + the Table II objective)
  * ``brute_force``     -- exhaustive optimal, the paper's evaluation baseline
  * ``first_fit`` / ``best_fit_cache`` -- classical baselines (beyond paper,
    used to show the 2-D objective matters)

NOTE: like ``core.scheduler``, this pure-Python float64 path is the
*reference oracle* of the unified engine (DESIGN.md §8); the production
allocation paths are ``binpack_jax`` (jitted greedy + shared candidate
scorer) and ``core.engine.ConsolidationEngine`` (the online runtime).

Objective: the paper's text ("minimizes the sum of the average loads ... on
all physical servers after allocation") and its Table II walk-through pick
the server whose *post-allocation* average-load increase is smallest -- note
Table II picks server B (sum 80 < 82.5) even though B's post-allocation
average (45) is larger than A's (40). The literal pseudocode in Fig 8
("If Avg_i < minimum") instead compares post-allocation averages directly.
Both are provided; ``objective='sum_avg'`` (Table II semantics) is the
default, ``objective='min_after'`` is the literal-Fig-8 variant. The
discrepancy is documented here and in DESIGN.md.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Literal, Sequence

import numpy as np

from .criteria import DEGRADATION_LIMIT, AdmissionCheck, check_consolidation
from .server import ServerSpec
from .workload import Workload

Objective = Literal["sum_avg", "min_after"]


@dataclasses.dataclass
class ClusterState:
    """Mutable view of a cluster during allocation: who runs where."""

    servers: tuple[ServerSpec, ...]
    D: Sequence[np.ndarray]  # one profiled D matrix per server (§VIII)
    alphas: tuple[float, ...]
    assignments: list[list[Workload]]  # per-server co-run sets

    @classmethod
    def empty(
        cls,
        servers: Sequence[ServerSpec],
        D: Sequence[np.ndarray] | np.ndarray,
        alpha: float | Sequence[float] = 1.3,
    ) -> "ClusterState":
        servers = tuple(servers)
        if isinstance(D, np.ndarray):
            D = [D] * len(servers)
        if isinstance(alpha, (int, float)):
            alphas = tuple(float(alpha) for _ in servers)
        else:
            alphas = tuple(float(a) for a in alpha)
        return cls(servers, list(D), alphas, [[] for _ in servers])

    def check(self, i: int, extra: Workload | None = None) -> AdmissionCheck:
        ws = list(self.assignments[i]) + ([extra] if extra is not None else [])
        return check_consolidation(self.servers[i], ws, self.D[i], self.alphas[i])

    def loads(self) -> list[AdmissionCheck]:
        return [self.check(i) for i in range(len(self.servers))]

    def total_avg_load(self) -> float:
        """The paper's global objective: sum over servers of Avg(CacheInUse, MaxD)."""
        return float(sum(c.avg_load for c in self.loads()))

    def feasible(self) -> bool:
        return all(c.ok for c in self.loads())

    def clone(self) -> "ClusterState":
        return ClusterState(
            self.servers, self.D, self.alphas, [list(a) for a in self.assignments]
        )


# --- The paper's greedy (Fig 8) -----------------------------------------------

def greedy_place(
    state: ClusterState, w: Workload, objective: Objective = "sum_avg"
) -> int | None:
    """Place one arriving workload; returns the chosen server index or None.

    Fig 8, per server i:
      1. tentatively assign W to S_i
      2. CacheInUse_i = competing data / (alpha_i * CacheSize_i)
      3. Max(D_y) from the profiled D_{x,y}s via the additive model
      4. reject S_i if Max(D_y) > 50% or CacheInUse_i > 100%
      5. score = Avg(CacheInUse_i, Max(D_y)); keep the argmin
    ``None`` means no server satisfies the criteria -> the caller queues W
    (criterion 1's queueing rule, §V).
    """
    best, best_score = None, np.inf
    for i in range(len(state.servers)):
        after = state.check(i, extra=w)
        if not after.ok:
            continue
        if objective == "sum_avg":
            score = after.avg_load - state.check(i).avg_load  # Table II semantics
        else:
            score = after.avg_load  # literal Fig 8
        if score < best_score - 1e-12:
            best, best_score = i, score
    if best is not None:
        state.assignments[best].append(w)
    return best


def greedy_sequence(
    state: ClusterState,
    arrivals: Sequence[Workload],
    objective: Objective = "sum_avg",
) -> tuple[list[int | None], list[Workload]]:
    """Allocate an arrival sequence one by one (§VIII). Returns (placements, queued)."""
    placements: list[int | None] = []
    queued: list[Workload] = []
    for w in arrivals:
        i = greedy_place(state, w, objective)
        placements.append(i)
        if i is None:
            queued.append(w)
    return placements, queued


# --- Brute force (the paper's optimality baseline, §VIII) -----------------------

def brute_force(
    state: ClusterState,
    arrivals: Sequence[Workload],
    allow_queue: bool = True,
) -> tuple[float, list[int | None]]:
    """Exhaustive search over all assignments of ``arrivals`` to servers.

    Minimizes the paper's global objective (total sum of per-server average
    loads) subject to both criteria on every server; a workload may be left
    unplaced (queued) if ``allow_queue``, at the cost of counting it as a
    full unit of load (so queueing is never preferred over a feasible spot).
    Exponential (m+1)^n -- usable for the paper-scale evaluation (m=4, n=5).
    """
    m = len(state.servers)
    options = list(range(m)) + ([None] if allow_queue else [])
    best_cost, best_assign = np.inf, None

    for combo in itertools.product(options, repeat=len(arrivals)):
        trial = state.clone()
        for w, s in zip(arrivals, combo):
            if s is not None:
                trial.assignments[s].append(w)
        checks = trial.loads()
        if not all(c.ok for c in checks):
            continue
        cost = sum(c.avg_load for c in checks)
        cost += sum(1.0 for s in combo if s is None)  # queue penalty
        if cost < best_cost - 1e-12:
            best_cost, best_assign = cost, list(combo)
    if best_assign is None:
        raise RuntimeError("brute force found no feasible assignment")
    return float(best_cost), best_assign


# --- Classical baselines (beyond paper) ----------------------------------------

def first_fit(state: ClusterState, w: Workload) -> int | None:
    for i in range(len(state.servers)):
        if state.check(i, extra=w).ok:
            state.assignments[i].append(w)
            return i
    return None


def best_fit_cache(state: ClusterState, w: Workload) -> int | None:
    """Best-fit on the cache dimension only (ignores the degradation dim)."""
    best, best_slack = None, np.inf
    for i in range(len(state.servers)):
        after = state.check(i, extra=w)
        if not after.ok:
            continue
        slack = 1.0 - after.cache_in_use
        if slack < best_slack:
            best, best_slack = i, slack
    if best is not None:
        state.assignments[best].append(w)
    return best


def run_allocator(
    state: ClusterState, arrivals: Sequence[Workload], allocator
) -> tuple[list[int | None], ClusterState]:
    st = state.clone()
    placements = [allocator(st, w) for w in arrivals]
    return placements, st


# --- Evaluation metric of Fig 9 -------------------------------------------------

def average_min_throughput(state: ClusterState) -> float:
    """Fig 9's bar metric: average over servers of the *minimum* per-workload
    relative throughput (1 - D) on that server, via the additive model."""
    vals = []
    for i in range(len(state.servers)):
        c = state.check(i)
        vals.append(1.0 - (max(c.degradations) if c.degradations else 0.0))
    return float(np.mean(vals))


def average_min_throughput_simulated(state: ClusterState) -> float:
    """Same metric but measured on the ground-truth simulator (not the model)."""
    from .simulator import simulate_corun

    vals = []
    for i, server in enumerate(state.servers):
        ws = state.assignments[i]
        if not ws:
            vals.append(1.0)
            continue
        res = simulate_corun(server, ws)
        vals.append(1.0 - res.max_degradation)
    return float(np.mean(vals))
