"""The fully device-resident closed loop: all segments in one program.

``AdaptiveEngine.run`` (PR 2-6) alternates device and host every segment:
run the jitted event loop, pull telemetry, update the estimator bank, step
the drift detector, let the fleet controller split/evict, rebuild the
cluster from the new D estimate, dispatch the next segment. Each iteration
costs a dozen jit dispatches, an ``int()`` fence, and an m x [T, T] host
pull for ``estimate_D`` -- fixed overhead that dwarfs the device work once
segments are small and fleets are large.

:func:`run_closed_loop` folds the whole cycle into a single compiled
program: one ``lax.scan`` over segments whose carry holds everything the
host used to shuttle --

  bank       the stacked :class:`DeviceEstimatorState` (all estimator rows)
  det        the drift detector's :class:`CusumState`
  row_map /  the pool's update and read routing (``PooledEstimatorBank``'s
  read_row   ``row_of`` / ``_read_row`` as device arrays)
  active     the placement-eligibility mask
  seen       the controller's burn-in clock
  req_*      the requeue buffer (work evicted servers had in flight,
             re-injected at the head of the next segment)
  ring       the telemetry ring's buffer/cursor (``ObservationRing``)

and each step runs the segment's event loop (:func:`~repro.core.engine_jax`
``_trace_segment`` with a *traced* arrival count), folds the resulting
:class:`RingBlock` through the fused estimator update and CUSUM detector,
applies the controller's split/evict policy as pure array ops
(:func:`~repro.fleet.controller.fleet_step`), and re-schedules evicted
work -- no host anywhere in the loop.

Shapes are bucketed so warm runs never retrace: segments pad to a
power-of-two ``S_cap`` (masked by ``seg_valid``), arrivals per segment pad
to ``n_seg`` chunk rows plus ``n_seg`` requeue slots, and per-segment drift
is an index into a pre-stacked :class:`PackedDynamics` bank. The cluster's
structural tables are compiled once -- only ``D`` (re-blended from the
carried bank state each step, exactly ``estimate_D``'s confidence fallback)
and ``active`` vary -- which is also why this path requires drift that
leaves ``llc_bytes``/``llc_tolerance`` alone; richer drift belongs on the
host-alternating reference path, which remains the semantic oracle (see
DESIGN.md section 13 for when to prefer it).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp

from ..fleet.controller import fleet_step
from ..fleet.detect import CusumState, _cusum_update
from ..obs import metrics as obs_metrics
from ..obs import recorder as obs_recorder
from ..telemetry.estimator import (
    DeviceEstimatorState,
    _bank_core,
    _blend_prior_t,
    _localize_block,
    _remap_rows,
)
from ..telemetry.log import RingBlock, _ring_write_masked, _rows_from_trace
from .binpack_jax import PackedCluster
from .engine_jax import PackedDynamics, Scorer, _trace_segment


@dataclasses.dataclass(frozen=True)
class ClosedLoopConfig:
    """Static (hashable -> compile-keyed) configuration of the fused loop.

    Engine policy (``objective``/``scorer``), the fleet controller's knobs,
    and the estimator hyperparameters all live here so the scan body closes
    over plain Python values -- one compilation per distinct policy, reused
    across runs and fleets of the same shape. ``scorer`` must be identity
    -stable (``make_scorer`` is lru-cached) or None for the default jnp
    scorer; ``fleet=False`` runs estimation only (no detector, no actions),
    mirroring a fleetless streaming ``AdaptiveEngine``.
    """

    objective: str = "sum_avg"
    scorer: Scorer | None = None
    fleet: bool = False
    # controller knobs (FleetController fields)
    warmup_segments: int = 2
    cusum_k: float = 0.25
    cusum_h: float = 2.0
    level_decay: float = 0.9
    fail_floor: float = 0.5
    min_exposure: float = 4.0
    det_max_lost_frac: float = 0.5
    # estimator hyperparameters (StreamingEstimator._hypers + the read blend)
    confidence_floor: float = 2.0
    lr: float = 0.6
    decay: float = 1.0
    step_damp: float = 0.5
    solo_eps: float = 0.05
    est_max_lost_frac: float = 0.5
    use_pallas: bool = False
    interpret: bool = True
    # thread an obs.MetricFrame through the carry (engine event metrics +
    # per-segment split/evict/requeue/ring/D-refresh accounting); off keeps
    # LoopCarry.metrics = None and the compiled program byte-identical
    metrics: bool = False
    # thread the decision flight recorder (obs.recorder) through the carry:
    # one provenance row per placement commit, sampling the estimator's
    # pair exposure / the detector's CUSUM level *as the scheduler saw
    # them* at segment entry; requires LoopCarry.rec to hold a real
    # RecState, and the same off-switch contract as metrics applies
    record: bool = False
    # server-axis layout (distributed.server_axis.ServerAxis): None or a
    # dense axis compiles the byte-identical single-device program; a
    # sharded axis runs the whole scan under shard_map with every [m, ...]
    # carry field sharded by server row and the queue/ring replicated
    axis: "object | None" = None


class LoopCarry(NamedTuple):
    """Everything the host used to shuttle between segments, as one pytree."""

    bank: DeviceEstimatorState  # stacked estimator rows [m, ...]
    det: CusumState  # drift detector state
    row_map: jax.Array  # i32[m] pool update routing (-1 = dropped)
    read_row: jax.Array  # i32[m] pool read routing (survives drops)
    active: jax.Array  # bool[m] placement eligibility
    seen: jax.Array  # i32 controller burn-in clock (segments observed)
    req_type: jax.Array  # i32[R] requeued arrival types
    req_bytes: jax.Array  # f32[R] requeued arrival sizes
    req_n: jax.Array  # i32 live requeue count (<= R)
    ring: RingBlock  # telemetry ring buffer [capacity, ...]
    ring_ptr: jax.Array  # i32 ring write cursor
    ring_total: jax.Array  # i32 rows ever pushed
    metrics: "obs_metrics.MetricFrame | None" = None  # in-carry metrics plane
    rec: "obs_recorder.RecState | None" = None  # in-carry decision recorder


class SegmentIn(NamedTuple):
    """Per-segment scan inputs, stacked [S_cap, ...] and padded."""

    arr_time: jax.Array  # f32[S, n_seg] chunk-relative times (t - t0_k)
    arr_type: jax.Array  # i32[S, n_seg] grid types
    arr_bytes: jax.Array  # f32[S, n_seg] data_total per arrival
    dyn_idx: jax.Array  # i32[S] index into the stacked PackedDynamics bank
    seg_valid: jax.Array  # bool[S] False = padding segment (no-op)


class SegmentOut(NamedTuple):
    """Per-segment scan outputs, stacked [S_cap, ...] by ``lax.scan``."""

    placement: jax.Array  # i32[n_cap] (-1 = never placed / padding)
    was_queued: jax.Array  # bool[n_cap]
    place_time: jax.Array  # f32[n_cap] chunk-relative
    finish_time: jax.Array  # f32[n_cap] chunk-relative
    makespan: jax.Array  # f32 chunk-relative
    max_deg: jax.Array  # f32
    deadlock: jax.Array  # bool (masked False on padding segments)
    used: jax.Array  # i32 telemetry rows the estimator consumed
    n_valid: jax.Array  # i32 arrivals this segment (requeue + chunk)
    n_requeued: jax.Array  # i32 requeued arrivals at segment entry
    req_overflow: jax.Array  # bool requeue demand exceeded capacity R
    split_fired: jax.Array  # bool[m]
    split_stat: jax.Array  # f32[m]
    evict_fired: jax.Array  # bool[m]
    evict_stat: jax.Array  # f32[m]
    evict_route: jax.Array  # bool[m] True = level route
    active_after: jax.Array  # bool[m] mask after this segment's actions


def _require_ring(rec) -> None:
    """Host-side structure check at trace time: a fresh ring minted inside
    the scan body would change the carry's structure between iterations --
    the caller owns the ring."""
    if rec is None:
        raise ValueError("config.record=True requires carry.rec to hold a "
                         "RecState (see obs.recorder.init)")


@partial(jax.jit, static_argnames=("config",))
def run_closed_loop(
    cluster: PackedCluster,
    dyn_stack: PackedDynamics,  # stacked [U, m, ...] per-segment dynamics
    Lp_t: jax.Array,  # f32[m, T, T] target-major L priors per estimator row
    logb_priors: jax.Array,  # f32[m, T] nominal log base priors per row
    carry: LoopCarry,
    xs: SegmentIn,
    config: ClosedLoopConfig,
) -> tuple[LoopCarry, SegmentOut]:
    """Scan the observe -> estimate -> detect -> act cycle over all segments.

    ``cluster`` supplies the structural tables only -- its ``D``/``active``
    are replaced inside every step from the carried bank state and mask.
    Returns the final carry (adopted wholesale by the host mirror) and the
    stacked per-segment outputs.
    """
    if config.record:
        _require_ring(carry.rec)
    m = int(carry.row_map.shape[0])
    R = int(carry.req_type.shape[0])
    n_seg = int(xs.arr_time.shape[1])
    n_cap = R + n_seg
    cap = int(carry.ring.ints.shape[0])
    axis = config.axis
    sharded = axis is not None and axis.is_sharded

    def _scan(cluster, dyn_stack, Lp_t, logb_priors, carry, xs):
        # per-shard body when sharded (each shard owns Lp_t.shape[0] server
        # rows; queue, ring and every decision array stay replicated); the
        # dense call traces the byte-identical single-device program
        m_l = int(Lp_t.shape[0])
        lo = axis.offset(m_l) if sharded else 0
        # the no-drift common case gathers the single dynamics once, outside
        # the scan body, instead of a [m, T, T]-sized dynamic gather per step
        dyn_0 = (jax.tree_util.tree_map(lambda a: a[0], dyn_stack)
                 if int(dyn_stack.solo.shape[0]) == 1 else None)

        def local_rows(read_row):
            """This shard's slice of a global server->row map, rebased to
            local row indices (pool locality keeps every value in range)."""
            if sharded:
                return jnp.clip(
                    jax.lax.dynamic_slice_in_dim(read_row, lo, m_l) - lo,
                    0, m_l - 1)
            return jnp.clip(read_row, 0, m - 1)

        def full_D(bank: DeviceEstimatorState, read_row) -> jax.Array:
            """estimate_D's confidence blend for every server, from scratch:
            blend in row space (elementwise ops commute with the row gather
            bit-for-bit), then one gather + transpose to scheduler layout."""
            L_eff_t = _blend_prior_t(bank.L_t, bank.n_pair_t,
                                     Lp_t, config.confidence_floor)
            D_rows = jnp.clip(-jnp.expm1(L_eff_t), 0.0, 0.999999)
            return D_rows[local_rows(read_row)].swapaxes(1, 2)

        def refresh_D(D, bank, read_row, a_type, block):
            """Re-blend only what this segment's telemetry can have moved.

            Without forgetting (``decay >= 1``) an update touches the bank
            only at the (row, type-column) pairs the block names, so ``D``
            needs new values only in those columns -- conservatively
            recomputed for every server (an untouched entry recomputes to
            the identical value). With forgetting the whole confidence row
            moves each update and the blend recomputes in full.
            """
            if config.decay < 1.0:
                return full_D(bank, read_row)
            rr = local_rows(read_row)  # [m servers this shard]
            row = block.server  # remapped bank row per telemetry row [B]
            wt = a_type  # the types whose D columns can have moved [B]
            wtc = jnp.clip(wt, 0, cluster.T - 1)
            # blend just the touched columns, for every server: [m, B, T(u)]
            cols = _blend_prior_t(
                bank.L_t[rr[:, None], wtc[None, :]],
                bank.n_pair_t[rr[:, None], wtc[None, :]],
                Lp_t[rr[:, None], wtc[None, :]], config.confidence_floor)
            cols = jnp.clip(-jnp.expm1(cols), 0.0, 0.999999)
            # rows that updated nothing (dropped server / bad type) write OOB
            tt = jnp.where((wt >= 0) & (wt < cluster.T)
                           & (row >= 0) & (row < m), wt, cluster.T)
            return D.at[:, :, tt].set(cols.swapaxes(1, 2))

        def step(scarry, x):
            carry, D = scarry
            q = carry.req_n
            n_valid = jnp.where(x.seg_valid, q + n_seg, 0)

            # assemble the segment's arrivals: requeued work first (at the
            # chunk-relative origin, exactly where the host prepends it), then
            # the chunk rows; padding rows never arrive (time inf past n_valid)
            i = jnp.arange(n_cap, dtype=jnp.int32)
            is_req = i < q
            ci = jnp.clip(i - q, 0, n_seg - 1)
            ri = jnp.clip(i, 0, R - 1)
            a_time = jnp.where(is_req, 0.0,
                               jnp.where(i < q + n_seg, x.arr_time[ci], jnp.inf))
            a_type = jnp.where(is_req, carry.req_type[ri], x.arr_type[ci])
            a_bytes = jnp.where(is_req, carry.req_bytes[ri], x.arr_bytes[ci])

            # the scheduler's D for this segment rides the carry (maintained
            # incrementally by refresh_D; rebuilt by full_D on topology changes)
            act_k = (jax.lax.dynamic_slice_in_dim(carry.active, lo, m_l)
                     if sharded else carry.active)
            cluster_k = dataclasses.replace(
                cluster, D=D, active=act_k.astype(jnp.float32))
            dyn_k = (dyn_0 if dyn_0 is not None else
                     jax.tree_util.tree_map(lambda a: a[x.dyn_idx], dyn_stack))

            # the segment's event loop, telemetry on
            if config.record:
                # sample the estimator/detector state the scheduler consults
                # *this* segment -- before the post-segment update below
                rec_ctx = obs_recorder.RecCtx(
                    n_pair=carry.bank.n_pair_t,
                    row_of=local_rows(carry.read_row),
                    cusum=carry.det.stat.max(axis=1),
                    pool_row=carry.read_row,
                    segment=carry.seen)
            else:
                rec_ctx = None
            with jax.named_scope("obs.segment_event_loop"):
                trace = _trace_segment(
                    cluster_k, dyn_k, a_time, a_type, a_bytes, n_valid,
                    objective=config.objective, scorer=config.scorer,
                    telemetry=True, metrics=config.metrics,
                    record=config.record, rec=carry.rec, rec_ctx=rec_ctx,
                    axis=axis)

            # observe -> estimate: the same fused banked update the host path
            # dispatches (remap through the pool routing, fold the block);
            # sparse_tables keeps the in-scan cost at O(B T) per step
            with jax.named_scope("obs.estimate"):
                block = _rows_from_trace(trace, a_type)
                rblock = _remap_rows(block, carry.row_map)
                bank, used = _bank_core(
                    carry.bank,
                    _localize_block(rblock, lo) if sharded else rblock,
                    lr=config.lr, decay=config.decay, step_damp=config.step_damp,
                    solo_eps=config.solo_eps, max_lost_frac=config.est_max_lost_frac,
                    use_pallas=config.use_pallas, interpret=config.interpret,
                    sparse_tables=True)
                if sharded:
                    used = axis.psum(used)

            seen = carry.seen + x.seg_valid.astype(jnp.int32)
            if config.fleet:
                # detect against the *post-update* pooled model, on the
                # original (un-remapped) block -- FleetController.observe's
                # exact order; each shard folds its own servers' rows
                # (pool locality keeps row_map shard-local)
                if sharded:
                    det_row_map = (jax.lax.dynamic_slice_in_dim(
                        carry.row_map, lo, m_l) - lo)
                    det_block = _localize_block(block, lo)
                else:
                    det_row_map, det_block = carry.row_map, block
                det, _ = _cusum_update(
                    carry.det, det_block, bank.log_b, bank.L_t, det_row_map,
                    k=config.cusum_k, level_decay=config.level_decay,
                    max_lost_frac=config.det_max_lost_frac)
                # burn-in: discard detector evidence, withhold actions
                in_warmup = seen <= config.warmup_segments
                det = jax.tree_util.tree_map(
                    lambda a: jnp.where(in_warmup, jnp.zeros_like(a), a), det)
                out = fleet_step(
                    bank, det, carry.row_map, carry.read_row, carry.active,
                    logb_priors, x.seg_valid & ~in_warmup,
                    h=config.cusum_h, level_decay=config.level_decay,
                    fail_floor=config.fail_floor,
                    min_exposure=config.min_exposure, axis=axis)
                bank, det = out.bank, out.det
                row_map, read_row, active = out.row_map, out.read_row, out.active
                split_fired, split_stat = out.split_fired, out.split_stat
                evict_fired, evict_stat = out.evict_fired, out.evict_stat
                evict_route = out.evict_route
                # topology changes remap reads/copy rows: rebuild D outright;
                # otherwise refresh just this segment's touched columns
                D = jax.lax.cond(
                    jnp.any(split_fired) | jnp.any(evict_fired),
                    lambda d: full_D(bank, read_row),
                    lambda d: refresh_D(d, bank, read_row, a_type, rblock),
                    D)
            else:
                det = carry.det
                row_map, read_row, active = (
                    carry.row_map, carry.read_row, carry.active)
                split_fired = evict_fired = evict_route = jnp.zeros((m,), bool)
                split_stat = evict_stat = jnp.zeros((m,), jnp.float32)
                D = refresh_D(D, bank, read_row, a_type, rblock)

            # act -> re-schedule: work an evicted server held (or that never
            # placed) re-enters at the head of the next segment, in row order
            # -- the host's requeue comprehension as a cumsum scatter
            any_evict = jnp.any(evict_fired)
            pclip = jnp.clip(trace.placement, 0, m - 1)
            req_mask = ((i < n_valid) & any_evict
                        & (((trace.placement >= 0) & evict_fired[pclip])
                           | (trace.placement < 0)))
            pos = jnp.cumsum(req_mask.astype(jnp.int32)) - 1
            n_req = req_mask.sum()
            dst = jnp.where(req_mask & (pos < R), pos, R)
            req_type = jnp.zeros((R + 1,), jnp.int32).at[dst].set(a_type)[:R]
            req_bytes = jnp.ones((R + 1,), jnp.float32).at[dst].set(a_bytes)[:R]

            # mirror the host's per-segment ring push (the full block, valid
            # and invalid rows alike -- exactly n_valid rows land)
            ring = _ring_write_masked(carry.ring, block, carry.ring_ptr, n_valid)

            req_cnt = jnp.minimum(n_req, R)
            if config.metrics:
                # fold the segment's engine frame into the run frame, then add
                # the closed-loop-level accounting the host used to keep
                mf = obs_metrics.merge(carry.metrics, trace.metrics)
                mf = obs_metrics.count(mf, "segments", x.seg_valid.astype(jnp.int32))
                mf = obs_metrics.count(mf, "splits",
                                       jnp.sum(split_fired, dtype=jnp.int32))
                mf = obs_metrics.count(mf, "evictions",
                                       jnp.sum(evict_fired, dtype=jnp.int32))
                mf = obs_metrics.count(mf, "requeues", req_cnt)
                mf = obs_metrics.count(mf, "ring_rows", n_valid)
                # extent of the incremental D re-blend: block rows naming a
                # live (bank row, type) pair -- the columns refresh_D targets
                touched = jnp.sum((a_type >= 0) & (a_type < cluster.T)
                                  & (rblock.server >= 0) & (rblock.server < m),
                                  dtype=jnp.int32)
                mf = obs_metrics.count(mf, "d_cols_refreshed", touched)
                if config.fleet:
                    mf = obs_metrics.observe(
                        mf, "cusum_level", split_stat,
                        weight=(carry.active & x.seg_valid).astype(jnp.float32))
                mf = obs_metrics.gauge_max(
                    mf, "ring_occupancy_peak",
                    jnp.minimum(carry.ring_total + n_valid, cap).astype(jnp.float32))
                mf = obs_metrics.gauge_max(
                    mf, "evicted_peak", jnp.sum(~active, dtype=jnp.float32))
                mf = obs_metrics.gauge_max(
                    mf, "requeue_peak", req_cnt.astype(jnp.float32))
            else:
                mf = carry.metrics

            carry2 = LoopCarry(
                bank=bank, det=det, row_map=row_map, read_row=read_row,
                active=active, seen=seen,
                req_type=req_type, req_bytes=req_bytes,
                req_n=req_cnt,
                ring=ring, ring_ptr=(carry.ring_ptr + n_valid) % cap,
                ring_total=carry.ring_total + n_valid,
                metrics=mf,
                rec=trace.rec if config.record else carry.rec)
            out_k = SegmentOut(
                placement=trace.placement, was_queued=trace.was_queued,
                place_time=trace.place_time, finish_time=trace.finish_time,
                makespan=trace.makespan, max_deg=trace.max_deg,
                deadlock=trace.deadlock & x.seg_valid,
                used=used, n_valid=n_valid, n_requeued=q,
                req_overflow=(n_req > R) & x.seg_valid,
                split_fired=split_fired, split_stat=split_stat,
                evict_fired=evict_fired, evict_stat=evict_stat,
                evict_route=evict_route, active_after=active)
            return (carry2, D), out_k

        (carry, _), ys = jax.lax.scan(step, (carry, full_D(carry.bank,
                                                           carry.read_row)), xs)
        return carry, ys

    if not sharded:
        return _scan(cluster, dyn_stack, Lp_t, logb_priors, carry, xs)

    # one shard_map around the whole scan: [m, ...] state shards by server
    # row, the queue/ring/decision plane replicates, and the per-segment
    # collectives inside the engine / fleet_step keep every shard's
    # replicated copies bitwise aligned
    axis.validate(m)
    from jax.sharding import PartitionSpec

    carry_specs = LoopCarry(
        bank=axis.shard_leading(carry.bank, m),
        det=axis.shard_leading(carry.det, m),
        row_map=axis.rep(), read_row=axis.rep(), active=axis.rep(),
        seen=axis.rep(), req_type=axis.rep(), req_bytes=axis.rep(),
        req_n=axis.rep(), ring=axis.rep_tree(carry.ring),
        ring_ptr=axis.rep(), ring_total=axis.rep(),
        metrics=(obs_metrics.frame_specs(axis)
                 if carry.metrics is not None else None),
        rec=(obs_recorder.rec_specs(axis)
             if carry.rec is not None else None))
    dyn_specs = jax.tree_util.tree_map(
        lambda a: (PartitionSpec(None, axis.axis)
                   if a.ndim >= 2 and a.shape[1] == m else PartitionSpec()),
        dyn_stack)
    ys_specs = SegmentOut(*([axis.rep()] * len(SegmentOut._fields)))
    mapped = axis.shard_map(
        _scan,
        in_specs=(axis.shard_leading(cluster, m), dyn_specs, axis.spec(),
                  axis.rep(), carry_specs, axis.rep_tree(xs)),
        out_specs=(carry_specs, ys_specs))
    return mapped(cluster, dyn_stack, Lp_t, logb_priors, carry, xs)
