"""JAX implementation of the paper's consolidation algorithm (C7, jit-able).

This is the production-path allocator: the greedy of Fig 8 expressed as pure
array ops so it (a) scores every server in parallel, (b) scans an arrival
sequence under ``jax.lax.scan``, (c) runs on-device, and (d) can be handed a
batched candidate evaluation to the Pallas kernel in
``repro.kernels.consolidation`` for large fleets.

State encoding
--------------
Workloads live on the paper's profiling grid of T types (230 = 10 RS x 23 FS).
A cluster of m servers is

  counts  : f32[m, T]   -- number of resident workloads of each type per server
  D       : f32[m, T, T]-- profiled pairwise degradation per server, D[s, i, j]
                           = degradation type-i causes on type-j on server s
  rs, fs  : f32[T]      -- grid coordinates (bytes)
  llc     : f32[m]      -- alpha_s * CacheSize_s   (criterion-2 budget)
  resident: f32[m, T]   -- 1.0 where fs_t <= CacheSize_s (Eqn 2's CS set)

The additive model (Eqn 3) for a type-t workload on server s with counts c:
  D_pred[s, t] = (c @ D[s])[t] - D[s, t, t]        (exclude its own pair-self)
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from .server import ServerSpec
from .workload import FS_GRID, RS_GRID, Workload, grid_types, type_index

QUEUED = -1  # sentinel placement: no feasible server (criterion-1 queue)


@dataclasses.dataclass(frozen=True)
class PackedCluster:
    """Immutable device-side cluster description (see module docstring).

    ``active`` is the fleet-health mask (1.0 = eligible for placement): an
    inactive server keeps its rows in every table -- shapes never change, so
    jitted programs are not re-traced when the fleet controller evicts a
    server -- but candidate scoring treats it as infeasible, exactly like a
    server that fails both criteria (its queued work waits for a *healthy*
    server or deadlocks, never lands on the evicted one).
    """

    D: jax.Array  # f32[m, T, T]
    rs: jax.Array  # f32[T]
    fs: jax.Array  # f32[T]
    llc_budget: jax.Array  # f32[m] = alpha_s * CacheSize_s
    resident: jax.Array  # f32[m, T]
    active: jax.Array  # f32[m] 1.0 = placement-eligible (fleet-health mask)
    degradation_limit: float = 0.5

    @classmethod
    def build(
        cls,
        servers: list[ServerSpec],
        D: list[np.ndarray] | np.ndarray,
        alpha: float | list[float] = 1.3,
        active: "np.ndarray | None" = None,
    ) -> "PackedCluster":
        m = len(servers)
        if isinstance(D, np.ndarray):
            D = [D] * m
        if isinstance(alpha, (int, float)):
            alpha = [float(alpha)] * m
        rs = jnp.asarray(RS_GRID, jnp.float32)
        fs = jnp.asarray(FS_GRID, jnp.float32)
        T = rs.shape[0] * fs.shape[0]
        rs_t = jnp.repeat(rs, fs.shape[0])
        fs_t = jnp.tile(fs, rs.shape[0])
        llc = jnp.asarray([a * s.llc_bytes for a, s in zip(alpha, servers)], jnp.float32)
        resident = (fs_t[None, :] <= jnp.asarray([s.llc_bytes for s in servers], jnp.float32)[:, None]).astype(jnp.float32)
        return cls(
            D=jnp.asarray(np.stack([np.asarray(d, np.float32) for d in D])),
            rs=rs_t,
            fs=fs_t,
            llc_budget=llc,
            resident=resident,
            active=(jnp.ones(m, jnp.float32) if active is None
                    else jnp.asarray(np.asarray(active, np.float32))),
        )

    @property
    def m(self) -> int:
        return self.D.shape[0]

    @property
    def T(self) -> int:
        return self.D.shape[1]


jax.tree_util.register_pytree_node(
    PackedCluster,
    lambda c: ((c.D, c.rs, c.fs, c.llc_budget, c.resident, c.active),
               (c.degradation_limit,)),
    lambda aux, ch: PackedCluster(*ch, degradation_limit=aux[0]),
)


def counts_from_assignments(cluster: PackedCluster, assignments: list[list[Workload]]) -> jax.Array:
    c = np.zeros((cluster.m, cluster.T), np.float32)
    for s, ws in enumerate(assignments):
        for w in ws:
            c[s, type_index(w)] += 1.0
    return jnp.asarray(c)


# --- per-server loads, fully vectorized ----------------------------------------

def server_loads(cluster: PackedCluster, counts: jax.Array) -> tuple[jax.Array, jax.Array]:
    """(cache_in_use[m], max_degradation[m]) for the current counts.

    cache_in_use is criterion 2's LHS over its budget; max_degradation is
    criterion 1's Max(D_y) from the additive model over resident workloads.
    """
    comp = counts @ cluster.rs + (counts * cluster.resident) @ cluster.fs  # [m]
    cache = comp / cluster.llc_budget

    col = jnp.einsum("mt,mtu->mu", counts, cluster.D)  # [m, T] = c @ D
    d_pred = col - jnp.diagonal(cluster.D, axis1=1, axis2=2)  # exclude self-pair
    d_pred = jnp.clip(d_pred, 0.0, 1.0)
    present = counts > 0
    max_d = jnp.max(jnp.where(present, d_pred, -jnp.inf), axis=1)
    max_d = jnp.where(jnp.any(present, axis=1), max_d, 0.0)
    return cache, max_d


def avg_loads(cluster: PackedCluster, counts: jax.Array) -> jax.Array:
    cache, max_d = server_loads(cluster, counts)
    return 0.5 * (cache + max_d)


# --- the shared candidate scorer (Fig 8 steps 2-4, batched) ---------------------

def score_candidates_jnp(
    cluster: PackedCluster, counts: jax.Array, wtypes: jax.Array
) -> tuple[jax.Array, jax.Array]:
    """(cache_after [Q, m], maxd_after [Q, m]) for placing each candidate type.

    The *shared scoring interface* of the consolidation engine: the same
    (counts, D, rs/fs, budget) -> (cache', MaxD') contract is implemented by
    the Pallas kernel (``kernels.consolidation.consolidation_scores``, fleet
    scale), by this jnp fallback, and by the numpy reference oracle
    (``kernels.ref.consolidation_scores_ref``). Incremental form: per-server
    base sums are computed once and each candidate adds its own delta, so the
    cost is O(Q * m * T) instead of O(Q * m^2 * T).
    """
    wtypes = jnp.atleast_1d(wtypes)
    comp0 = counts @ cluster.rs + (counts * cluster.resident) @ cluster.fs  # [m]
    delta = cluster.rs[wtypes][None, :] + cluster.resident[:, wtypes] * cluster.fs[wtypes][None, :]
    cache_after = (comp0[:, None] + delta) / cluster.llc_budget[:, None]  # [m, Q]

    col0 = jnp.einsum("mt,mtu->mu", counts, cluster.D)  # [m, T]
    diag = jnp.diagonal(cluster.D, axis1=1, axis2=2)  # [m, T]
    col_after = col0[:, None, :] + cluster.D[:, wtypes, :]  # [m, Q, T]
    d_pred = jnp.clip(col_after - diag[:, None, :], 0.0, 1.0)
    onehot = jax.nn.one_hot(wtypes, cluster.T, dtype=counts.dtype)  # [Q, T]
    present = (counts[:, None, :] + onehot[None, :, :]) > 0
    maxd_after = jnp.max(jnp.where(present, d_pred, -jnp.inf), axis=-1)  # [m, Q]
    return cache_after.T, maxd_after.T


def greedy_choice(
    cluster: PackedCluster,
    counts: jax.Array,
    cache_after: jax.Array,  # [Q, m] from any scoring backend
    maxd_after: jax.Array,  # [Q, m]
    objective: str = "sum_avg",
) -> tuple[jax.Array, jax.Array]:
    """Fig 8 step 5 over pre-computed candidate scores.

    Returns (server [Q], feasible_any [Q]); server == QUEUED where no server
    passes both criteria. Shared by the greedy scan and the online engine.
    Servers masked out by ``cluster.active`` (fleet-health eviction) are
    infeasible regardless of their scores.
    """
    feasible = ((maxd_after < cluster.degradation_limit) & (cache_after <= 1.0)
                & (cluster.active > 0.5)[None, :])
    avg_after = 0.5 * (cache_after + maxd_after)
    if objective == "sum_avg":  # Table II semantics: minimize the load increase
        score = avg_after - avg_loads(cluster, counts)[None, :]
    else:  # literal Fig 8: minimize the post-allocation average
        score = avg_after
    score = jnp.where(feasible, score, jnp.inf)
    best = argmin_with_margin(score)
    ok = jnp.any(feasible, axis=1)
    return jnp.where(ok, best, QUEUED), ok


#: scores closer than this are treated as tied (lowest server index wins) --
#: the f32 analogue of the Python greedy's ``score < best - 1e-12`` rule
SCORE_MARGIN = 1e-6


def argmin_with_margin(score: jax.Array, margin: float = SCORE_MARGIN) -> jax.Array:
    """First index along axis 1 whose score is within ``margin`` of the min.

    The pure-Python greedy keeps the earlier server unless a later one
    improves by more than 1e-12; a plain f32 argmin instead resolves
    sub-precision differences in arbitrary order. Preferring the first
    near-minimal index reproduces the oracle's tie-breaking.
    """
    smin = jnp.min(score, axis=1, keepdims=True)
    return jnp.argmax(score <= smin + margin, axis=1)


# --- the greedy step (Fig 8), one arrival ---------------------------------------

@partial(jax.jit, static_argnames=("objective",))
def greedy_step(
    cluster: PackedCluster, counts: jax.Array, wtype: jax.Array, objective: str = "sum_avg"
) -> tuple[jax.Array, jax.Array]:
    """Place one arriving workload of grid type ``wtype``.

    Returns (new_counts, placement) where placement == QUEUED when no server
    satisfies both criteria. All m candidate placements are scored in one
    vectorized evaluation through the shared scorer.
    """
    cache_after, maxd_after = score_candidates_jnp(cluster, counts, wtype)  # [1, m]
    placement, placed = greedy_choice(cluster, counts, cache_after, maxd_after, objective)
    placement, placed = placement[0], placed[0]
    onehot = jax.nn.one_hot(wtype, cluster.T, dtype=counts.dtype)  # [T]
    new_counts = jnp.where(
        placed,
        counts.at[jnp.where(placed, placement, 0)].add(onehot),
        counts,
    )
    return new_counts, placement


@partial(jax.jit, static_argnames=("objective",))
def greedy_sequence_jax(
    cluster: PackedCluster, counts: jax.Array, wtypes: jax.Array, objective: str = "sum_avg"
) -> tuple[jax.Array, jax.Array]:
    """Allocate a whole arrival sequence with ``lax.scan`` (the §VIII experiment)."""

    def step(c, t):
        c2, p = greedy_step(cluster, c, t, objective)
        return c2, p

    final, placements = jax.lax.scan(step, counts, wtypes)
    return final, placements


# --- sharded + hierarchical sequence allocation ---------------------------------
#
# The fleet-scale variants of ``greedy_sequence_jax``. Both take a
# :class:`~repro.distributed.server_axis.ServerAxis` and are *decision-exact*
# vs the dense scan: per-server scores are computed by the same arithmetic on
# the same rows, and only order-insensitive scalars (min / first-index) cross
# shard boundaries. A dense axis delegates straight to ``greedy_sequence_jax``
# -- the single-device program is byte-identical to today's.


def _choose_from_scores(axis, score_l: jax.Array, m_local: int):
    """Global (server, ok) from per-shard score columns [Q, m_local].

    Reproduces ``argmin_with_margin`` tie-breaking exactly: global min via
    ``pmin``, then the first *global* index within the margin (local first
    hit globalized with the shard offset, ``pmin`` picks the lowest).
    Infeasible servers carry ``inf``; ``ok`` is "any feasible anywhere"
    (the min is finite iff some server is feasible).
    """
    m_g = m_local * axis.shards
    smin = axis.pmin(jnp.min(score_l, axis=1))  # [Q]
    hit = score_l <= (smin + SCORE_MARGIN)[:, None]
    has = jnp.any(hit, axis=1)
    local_first = axis.offset(m_local) + jnp.argmax(hit, axis=1)
    best = axis.pmin(jnp.where(has, local_first, m_g))
    ok = jnp.isfinite(smin)
    return jnp.where(ok, best, QUEUED), ok


def _masked_scores(cluster: PackedCluster, counts: jax.Array,
                   wtypes: jax.Array, objective: str) -> jax.Array:
    """[Q, m] greedy scores with infeasible servers at ``inf`` -- the score
    half of :func:`greedy_choice`, reusable on a local shard slice."""
    cache_after, maxd_after = score_candidates_jnp(cluster, counts, wtypes)
    feasible = ((maxd_after < cluster.degradation_limit) & (cache_after <= 1.0)
                & (cluster.active > 0.5)[None, :])
    avg_after = 0.5 * (cache_after + maxd_after)
    if objective == "sum_avg":
        score = avg_after - avg_loads(cluster, counts)[None, :]
    else:
        score = avg_after
    return jnp.where(feasible, score, jnp.inf)


@partial(jax.jit, static_argnames=("axis", "objective"))
def greedy_sequence_sharded(
    cluster: PackedCluster, counts: jax.Array, wtypes: jax.Array,
    axis, objective: str = "sum_avg",
) -> tuple[jax.Array, jax.Array]:
    """``greedy_sequence_jax`` with the server axis sharded over ``axis``.

    Each shard scores its own slice of the fleet (the full Q x m candidate
    evaluation never materializes on one device); a ``(score, index)`` pair
    crosses the mesh per decision. Placements come back replicated and
    bitwise-equal to the dense scan; counts come back sharded.
    """
    if not axis.is_sharded:
        return greedy_sequence_jax(cluster, counts, wtypes, objective)
    m = cluster.m
    axis.validate(m)
    m_local = axis.local_m(m)

    def body(cluster_l, counts_l, wtypes):
        lo = axis.offset(m_local)

        def step(c, t):
            score = _masked_scores(cluster_l, c, t, objective)  # [1, m_local]
            placement, ok = _choose_from_scores(axis, score, m_local)
            placement, ok = placement[0], ok[0]
            s_l = placement - lo
            owned = ok & (s_l >= 0) & (s_l < m_local)
            dst = jnp.where(owned, s_l, m_local)  # OOB write drops off-shard
            return c.at[dst, t].add(1.0), placement

        return jax.lax.scan(step, counts_l, wtypes)

    mapped = axis.shard_map(
        body,
        in_specs=(axis.shard_leading(cluster, m), axis.spec(), axis.rep()),
        out_specs=(axis.spec(), axis.rep()))
    return mapped(cluster, counts, wtypes)


# --- hierarchical (pod) selection ------------------------------------------------

def _incremental_scores(cluster_l: PackedCluster, counts_l: jax.Array,
                        col0: jax.Array, comp0: jax.Array, maxd0: jax.Array,
                        diag: jax.Array, t: jax.Array,
                        objective: str) -> jax.Array:
    """Exact greedy scores [m_local] from maintained per-server aggregates.

    The flat scorer pays ``counts @ D`` -- O(m T^2) and a full pass over the
    ``[m, T, T]`` degradation tensor -- on *every* decision. But a decision
    changes one server's counts, so the three row-aggregates the score
    needs -- ``col0 = counts @ D`` [m, T], ``comp0`` (cache composition)
    [m], and ``maxd0`` (current max predicted degradation) [m] -- are
    maintained in the scan carry and only ``D[:, t, :]`` (the candidate
    type's row per server, O(m T)) is touched here. The arithmetic is the
    dense scorer's exactly -- same expressions, same reduction orders (XLA
    lowers the einsum row and the single-row refresh dot identically on
    CPU, where the decision-identity suite pins this) -- so placements are
    bitwise-equal to ``greedy_sequence_jax``, not merely close.
    """
    rs, fs = cluster_l.rs, cluster_l.fs
    cache0 = comp0 / cluster_l.llc_budget
    delta = rs[t] + cluster_l.resident[:, t] * fs[t]  # [m]
    cache_after = (comp0 + delta) / cluster_l.llc_budget

    Dt = cluster_l.D[:, t, :]  # [m, T] -- the only touch of D
    d_pred_after = jnp.clip(col0 + Dt - diag, 0.0, 1.0)
    present = counts_l > 0
    present_after = present | (jnp.arange(counts_l.shape[1]) == t)[None, :]
    maxd_after = jnp.max(jnp.where(present_after, d_pred_after, -jnp.inf),
                         axis=1)

    feasible = ((maxd_after < cluster_l.degradation_limit)
                & (cache_after <= 1.0) & (cluster_l.active > 0.5))
    avg_after = 0.5 * (cache_after + maxd_after)
    if objective == "sum_avg":
        score = avg_after - 0.5 * (cache0 + maxd0)
    else:
        score = avg_after
    return jnp.where(feasible, score, jnp.inf)


def _row_aggregates(cluster_l: PackedCluster, row_c: jax.Array,
                    D_row: jax.Array, diag_row: jax.Array,
                    resident_row: jax.Array):
    """(col0, comp0, maxd0) of ONE server row, rebuilt from their
    definitions -- the refresh half of the maintenance rule."""
    new_col = row_c @ D_row  # [T]
    new_comp = row_c @ cluster_l.rs + (row_c * resident_row) @ cluster_l.fs
    pres = row_c > 0
    new_maxd = jnp.max(jnp.where(pres, jnp.clip(new_col - diag_row, 0.0, 1.0),
                                 -jnp.inf))
    new_maxd = jnp.where(jnp.any(pres), new_maxd, 0.0)
    return new_col, new_comp, new_maxd


@partial(jax.jit, static_argnames=("axis", "objective"))
def greedy_sequence_hier(
    cluster: PackedCluster, counts: jax.Array, wtypes: jax.Array,
    axis, objective: str = "sum_avg", col0=None,
) -> tuple[jax.Array, jax.Array]:
    """Pod-hierarchical greedy scan: O(m T) per decision via maintained
    aggregates, sharded over whole pods.

    ``axis.pods`` pods of ``m // axis.pods`` servers each; with a sharded
    axis every shard owns ``pods // shards`` whole pods, so pod-local
    state (the ``col0`` aggregate, pool leadership, pod rollups) never
    crosses the mesh. Decision-identical to ``greedy_sequence_jax``
    (bitwise placements: exact scores, exact tie-breaking). ``pods == 1``
    on a dense axis *is* ``greedy_sequence_jax``: same function, same
    program.

    ``col0`` optionally supplies the precomputed ``counts @ D`` seed (it
    must equal exactly that product -- pass ``jnp.zeros((m, T))`` for an
    empty fleet); ``None`` computes it here, one O(m T^2) pass amortized
    over the whole sequence. Per decision the scan then touches
    ``D[:, t, :]`` only, and refreshes the placed server's row of ``col0``
    by an exact recompute -- the pod-aggregate maintenance rule: aggregates
    are *rebuilt from their definition* on the rows a decision touched,
    never incrementally drifted (DESIGN.md §15).
    """
    pods = axis.pods
    if pods <= 1:
        if axis.is_sharded:
            return greedy_sequence_sharded(cluster, counts, wtypes, axis,
                                           objective)
        return greedy_sequence_jax(cluster, counts, wtypes, objective)
    m = cluster.m
    axis.validate(m)  # raises unless shards | pods | m
    m_local = axis.local_m(m)

    def body(cluster_l, counts_l, col0_l, wtypes):
        if col0_l is None:
            col0_l = jnp.einsum("mt,mtu->mu", counts_l, cluster_l.D)
        diag = jnp.diagonal(cluster_l.D, axis1=1, axis2=2)  # [m_local, T]
        # one-time O(m T) seeds for the scalar aggregates, from definition
        comp0_l = (counts_l @ cluster_l.rs
                   + (counts_l * cluster_l.resident) @ cluster_l.fs)
        d_pred0 = jnp.clip(col0_l - diag, 0.0, 1.0)
        present0 = counts_l > 0
        maxd0_l = jnp.max(jnp.where(present0, d_pred0, -jnp.inf), axis=1)
        maxd0_l = jnp.where(jnp.any(present0, axis=1), maxd0_l, 0.0)
        lo = axis.offset(m_local)

        def step(carry, t):
            c, col0, comp0, maxd0 = carry
            score = _incremental_scores(cluster_l, c, col0, comp0, maxd0,
                                        diag, t, objective)
            placement, ok = _choose_from_scores(axis, score[None], m_local)
            placement, ok = placement[0], ok[0]
            s_l = placement - lo
            owned = ok & (s_l >= 0) & (s_l < m_local)
            s_safe = jnp.clip(s_l, 0, m_local - 1)
            dst = jnp.where(owned, s_l, m_local)  # off-shard write drops
            c = c.at[dst, t].add(1.0)
            # exact refresh of the one changed server's aggregate rows
            new_col, new_comp, new_maxd = _row_aggregates(
                cluster_l, c[s_safe], cluster_l.D[s_safe], diag[s_safe],
                cluster_l.resident[s_safe])
            col0 = col0.at[dst].set(new_col)
            comp0 = comp0.at[dst].set(new_comp)
            maxd0 = maxd0.at[dst].set(new_maxd)
            return (c, col0, comp0, maxd0), placement

        (c_final, _, _, _), placements = jax.lax.scan(
            step, (counts_l, col0_l, comp0_l, maxd0_l), wtypes)
        return c_final, placements

    if not axis.is_sharded:
        return body(cluster, counts, col0, wtypes)
    col0_specs = axis.rep() if col0 is None else axis.spec()
    mapped = axis.shard_map(
        lambda cl, c, c0, wt: body(cl, c, None if col0 is None else c0, wt),
        in_specs=(axis.shard_leading(cluster, m), axis.spec(), col0_specs,
                  axis.rep()),
        out_specs=(axis.spec(), axis.rep()))
    return mapped(cluster, counts,
                  jnp.zeros((0,), jnp.float32) if col0 is None else col0,
                  wtypes)


# --- vectorized brute force ------------------------------------------------------

@jax.jit
def evaluate_assignment(
    cluster: PackedCluster, counts0: jax.Array, wtypes: jax.Array, assign: jax.Array
) -> tuple[jax.Array, jax.Array]:
    """Cost + feasibility of one complete assignment (QUEUED allowed).

    Cost = sum of per-server average loads + 1.0 per queued workload (so a
    feasible placement always beats queueing), matching ``binpack.brute_force``.
    """
    onehots = jax.nn.one_hot(wtypes, cluster.T, dtype=counts0.dtype)  # [n, T]
    placed = assign >= 0
    scatter = jax.nn.one_hot(jnp.where(placed, assign, 0), cluster.m, dtype=counts0.dtype)
    scatter = scatter * placed[:, None]
    counts = counts0 + jnp.einsum("nm,nt->mt", scatter, onehots)
    cache, maxd = server_loads(cluster, counts)
    # fleet-health mask: an assignment placing work on an evicted server is
    # infeasible, same as the greedy paths (pre-existing counts0 there are
    # the caller's business)
    on_inactive = jnp.any(placed & (cluster.active[jnp.where(placed, assign, 0)] <= 0.5))
    ok = jnp.all((maxd < cluster.degradation_limit) & (cache <= 1.0)) & ~on_inactive
    cost = jnp.sum(0.5 * (cache + maxd)) + jnp.sum(~placed)
    return jnp.where(ok, cost, jnp.inf), ok


def brute_force_jax(
    cluster: PackedCluster,
    counts0: jax.Array,
    wtypes: jax.Array,
    allow_queue: bool = True,
    batch: int = 4096,
) -> tuple[float, np.ndarray]:
    """Exhaustive optimum via vmapped evaluation of all (m[+1])^n assignments."""
    n = int(wtypes.shape[0])
    base = cluster.m + (1 if allow_queue else 0)
    total = base**n

    digits = np.arange(total)
    combos = np.stack([(digits // base**k) % base for k in range(n)], axis=1)
    if allow_queue:
        combos = np.where(combos == cluster.m, QUEUED, combos)

    eval_many = jax.jit(jax.vmap(evaluate_assignment, in_axes=(None, None, None, 0)))
    best_cost, best_assign = np.inf, None
    for start in range(0, total, batch):
        chunk = jnp.asarray(combos[start : start + batch], jnp.int32)
        costs, _ = eval_many(cluster, counts0, wtypes, chunk)
        costs = np.asarray(costs)
        i = int(costs.argmin())
        if costs[i] < best_cost:
            best_cost, best_assign = float(costs[i]), combos[start + i]
    if not np.isfinite(best_cost):
        raise RuntimeError("brute force (jax) found no feasible assignment")
    return best_cost, np.asarray(best_assign)
