"""TPU-fleet consolidation: the paper's algorithm applied to (arch x shape)
jobs on pod slices (the hardware adaptation of DESIGN.md §2).

A *job* here is one training/serving step of an assigned architecture at an
assigned input shape; its resource vector is read off the compiled multi-pod
dry-run artifact (deliverable e/g):

  hbm_bytes        -- per-device working set  (paper's FS: the hard capacity dim)
  bytes_accessed   -- HLO bytes per step      (paper's RS-amortization analogue)
  flops            -- HLO FLOPs per step
  collective_bytes -- bytes over ICI per step

The pod is the 2-D bin: dimension 1 is the HBM byte budget (criterion 2 with
alpha=1.0 -- HBM, unlike an LLC, does not gracefully over-subscribe),
dimension 2 is the mutual throughput degradation from time-multiplexing jobs
on the same chips (criterion 1, the 50% rule).

Two degradation models are provided:
  * 'additive'  -- the paper's Eqn (3): profile D_{i,j} for job pairs, sum.
  * 'roofline'  -- beyond paper: each shared resource r (compute, HBM bw, ICI
    bw) saturates when the summed demand exceeds capacity; degradation of j
    is 1 - 1/max(1, sum_i demand_r(i)/capacity_r) maximized over r. More
    predictive for bandwidth-shared accelerators; selectable per experiment.
"""
from __future__ import annotations

import dataclasses
import json
import pathlib
from typing import Literal, Sequence

import numpy as np

# TPU v5e hardware constants (per chip) -- same numbers as the roofline spec.
PEAK_FLOPS = 197e12  # bf16 FLOP/s
HBM_BW = 819e9  # bytes/s
ICI_BW = 50e9  # bytes/s per link
HBM_BYTES = 16 * 2**30


@dataclasses.dataclass(frozen=True)
class JobProfile:
    """Resource vector of one (arch x shape) cell from the dry-run artifact."""

    name: str
    flops: float
    bytes_accessed: float
    collective_bytes: float
    hbm_bytes: float  # per-device
    chips: int = 256

    @classmethod
    def from_artifact(cls, path: str | pathlib.Path) -> "JobProfile":
        rec = json.loads(pathlib.Path(path).read_text())
        return cls(
            name=rec["cell"],
            flops=rec["flops"],
            bytes_accessed=rec["bytes_accessed"],
            collective_bytes=rec["collective_bytes"],
            hbm_bytes=rec["peak_memory_per_device"],
            chips=rec.get("chips", 256),
        )

    def step_time(self) -> float:
        """Solo step time = max of the three roofline terms (seconds)."""
        return max(
            self.flops / (self.chips * PEAK_FLOPS),
            self.bytes_accessed / (self.chips * HBM_BW),
            self.collective_bytes / (self.chips * ICI_BW),
        )

    def demands(self) -> dict[str, float]:
        """Fractional demand on each shared resource while running solo."""
        t = self.step_time()
        return {
            "compute": self.flops / (self.chips * PEAK_FLOPS) / t,
            "hbm_bw": self.bytes_accessed / (self.chips * HBM_BW) / t,
            "ici_bw": self.collective_bytes / (self.chips * ICI_BW) / t,
        }


@dataclasses.dataclass(frozen=True)
class PodSpec:
    """One pod slice as a consolidation bin."""

    name: str
    chips: int = 256
    hbm_budget: float = 256 * HBM_BYTES
    alpha: float = 1.0  # HBM does not over-subscribe (DESIGN.md §2)


DegradationModel = Literal["additive", "roofline"]


def pair_degradation(a: JobProfile, b: JobProfile) -> float:
    """D_{a,b}: degradation a causes on b when time-multiplexed on one pod.

    Under fair time-multiplexing, job a occupies the shared pipe for a
    fraction of time equal to its own utilization of the binding resource;
    b's slowdown factor is a's demand share on b's *bottleneck* resource.
    """
    da, db = a.demands(), b.demands()
    bottleneck = max(db, key=lambda k: db[k])
    return da[bottleneck] / (da[bottleneck] + 1.0)


def additive_degradations(jobs: Sequence[JobProfile]) -> np.ndarray:
    """Paper Eqn (3) over job profiles: D_j = sum_{i != j} D_{i,j}."""
    n = len(jobs)
    out = np.zeros(n)
    for j in range(n):
        out[j] = sum(pair_degradation(jobs[i], jobs[j]) for i in range(n) if i != j)
    return np.clip(out, 0.0, 0.999999)


def roofline_degradations(jobs: Sequence[JobProfile]) -> np.ndarray:
    """Beyond-paper model: per-resource saturation of the shared pod."""
    if not jobs:
        return np.zeros(0)
    totals = {"compute": 0.0, "hbm_bw": 0.0, "ici_bw": 0.0}
    for j in jobs:
        for k, v in j.demands().items():
            totals[k] += v
    out = []
    for j in jobs:
        slow = 1.0
        for k, tot in totals.items():
            if tot > 1.0:
                slow = min(slow, 1.0 / tot)
        out.append(1.0 - slow)
    return np.asarray(out)


@dataclasses.dataclass
class FleetState:
    """Mutable fleet assignment: which jobs run on which pod."""

    pods: tuple[PodSpec, ...]
    assignments: list[list[JobProfile]]
    model: DegradationModel = "additive"

    @classmethod
    def empty(cls, pods: Sequence[PodSpec], model: DegradationModel = "additive") -> "FleetState":
        return cls(tuple(pods), [[] for _ in pods], model)

    def degradations(self, pod: int, extra: JobProfile | None = None) -> np.ndarray:
        jobs = list(self.assignments[pod]) + ([extra] if extra else [])
        fn = additive_degradations if self.model == "additive" else roofline_degradations
        return fn(jobs)

    def hbm_in_use(self, pod: int, extra: JobProfile | None = None) -> float:
        jobs = list(self.assignments[pod]) + ([extra] if extra else [])
        budget = self.pods[pod].alpha * self.pods[pod].hbm_budget
        return sum(j.hbm_bytes * j.chips for j in jobs) / budget

    def avg_load(self, pod: int, extra: JobProfile | None = None) -> float:
        d = self.degradations(pod, extra)
        return 0.5 * (self.hbm_in_use(pod, extra) + (float(d.max()) if d.size else 0.0))

    def feasible(self, pod: int, extra: JobProfile | None = None, limit: float = 0.5) -> bool:
        d = self.degradations(pod, extra)
        return (self.hbm_in_use(pod, extra) <= 1.0) and (d.size == 0 or float(d.max()) < limit)


def pack_jobs(
    fleet: FleetState, arrivals: Sequence[JobProfile]
) -> tuple[list[int | None], FleetState]:
    """The paper's greedy (Table II objective) over the TPU fleet."""
    placements: list[int | None] = []
    for job in arrivals:
        best, best_score = None, np.inf
        for p in range(len(fleet.pods)):
            if not fleet.feasible(p, job):
                continue
            score = fleet.avg_load(p, job) - fleet.avg_load(p)
            if score < best_score - 1e-12:
                best, best_score = p, score
        if best is not None:
            fleet.assignments[best].append(job)
        placements.append(best)
    return placements, fleet


def fleet_throughput_report(fleet: FleetState) -> list[dict]:
    """Per-pod report: jobs, degradations, effective steps/s -- for EXPERIMENTS.md."""
    rows = []
    for p, pod in enumerate(fleet.pods):
        d = fleet.degradations(p)
        for job, dj in zip(fleet.assignments[p], d):
            t = job.step_time() / max(1e-9, 1.0 - dj)
            rows.append(
                dict(pod=pod.name, job=job.name, degradation=float(dj),
                     solo_steps_per_s=1.0 / job.step_time(), eff_steps_per_s=1.0 / t)
            )
    return rows
