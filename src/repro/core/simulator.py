"""Consolidated co-run simulator: the framework's stand-in for the paper's
physical testbed (§IV, Figures 3-4, 6).

The paper measures co-run throughput on real M1/M2 servers; in this
reproduction the simulator below *is* the "physical" ground truth that the
paper's predictive models (TDP Eqn (2), additive degradation Eqn (3)) are
validated against, exactly mirroring the paper's methodology:

  1. LLC contention (§IV.A): the total data competing for the LLC is
       sum_i RS_i + sum_{i: FS_i <= LLC} FS_i                       (Eqn 1-2)
     The *physical* cache tolerates ``server.llc_tolerance`` (~1.29x, the
     7.76MB-vs-6MB observation of §V) before workloads start evicting each
     other. Past that point every LLC-resident workload (FS <= LLC) loses the
     cache and drops to level-2 bandwidth -- which for RS > 8KB costs more
     than 50% of its throughput (Fig 6).

  2. Mutual degradation (§IV.B): co-running workloads additionally contend
     for the storage subsystem and the CPU. Each co-runner ``i`` imposes an
     independent multiplicative slowdown factor (1 - d_i) on every other
     workload, where d_i is i's relative pressure on the shared bandwidth
     and CPU. Independent multiplicative slowdowns compose as
       T_j = T_j_base * prod_{i != j} (1 - d_i)
     so for moderate degradations the *additive* model of Eqn (3) is an
     accurate first-order prediction (1 - prod(1-d) ~= sum d), while for
     heavy consolidation it over-predicts slightly -- matching the
     "reasonable accuracy" the paper reports in Figures 3-4(b).
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np

from .server import ServerSpec
from .throughput import amortized, level_of, level_params, solo_throughput
from .workload import Workload


def competing_cache_bytes(server: ServerSpec, workloads: Sequence[Workload]) -> float:
    """LHS of Eqn (2): sum RS_i + sum_{FS_i <= CacheSize} FS_i.

    Workloads whose FS exceeds the LLC do not compete for it (§IV.A) -- they
    stream through -- so only their request buffers count.
    """
    total = 0.0
    for w in workloads:
        total += w.rs
        if w.fs <= server.llc_bytes:
            total += w.fs
    return total


def cache_overflow(server: ServerSpec, workloads: Sequence[Workload]) -> bool:
    """True when the physical LLC is past its (tolerant) capacity -> TDP hit."""
    return competing_cache_bytes(server, workloads) > server.llc_tolerance * server.llc_bytes


def _demands(server: ServerSpec, w: Workload, t_base: float, lost_cache: bool) -> dict:
    """Per-resource demand of one workload running at base throughput ``t_base``.

    Three shared resources (§IV.B: "competition ... to access shared disk
    bandwidth and processor execution time", plus the memory/file-cache
    subsystem the levels live in):

      mem  -- bytes/s drawn from the DRAM/file-cache subsystem.  An
              LLC-resident workload (level 1) barely touches it (warm-up
              traffic only); level-2/3 workloads stream through it.
      disk -- bytes/s of true disk traffic (level-3 writes; level-2 writes
              trickle write-back at a fraction of their rate).
      cpu  -- cores-worth of processor time (per-request + per-byte costs).
    """
    lvl = level_of(server, w.fs, w.op)
    if lost_cache and w.fs <= server.llc_bytes:
        lvl = max(lvl, 2)
    if lvl == 1:
        mem, disk = 0.05 * t_base, 0.0
    elif lvl == 2:
        mem = t_base
        disk = 0.1 * t_base if w.op == "write" else 0.0
    else:
        mem, disk = t_base, t_base
    reqs_per_s = t_base / w.rs
    cpu = reqs_per_s * (server.cpu_req_cost + w.rs * server.cpu_byte_cost)
    return {"mem": mem, "disk": disk, "cpu": cpu}


def _capacities(server: ServerSpec) -> dict:
    return {"mem": server.shared_bw, "disk": server.bw_l3_write, "cpu": float(server.cores)}


def _sensitivity(server: ServerSpec, w: Workload, t_base: float, dem: dict) -> dict:
    """Fraction of j's critical path bound by each resource (its exposure)."""
    return {
        "mem": min(1.0, dem["mem"] / t_base),
        "disk": min(1.0, dem["disk"] / t_base),
        "cpu": min(1.0, dem["cpu"]),
    }


#: baseline-interference scale: even an uncontended co-runner causes a little
#: degradation (OS scheduling, cache-line ping-pong) -- dem/(dem + BASE*cap).
_BASELINE = 20.0


def pair_slowdown(
    server: ServerSpec,
    w_i: Workload,
    t_i: float,
    w_j: Workload,
    t_j: float,
    lost_cache: bool,
) -> float:
    """d_{i,j}: the slowdown factor workload i imposes on co-runner j.

    Per shared resource r with capacity C_r: proportional sharing only bites
    when the summed demand exceeds capacity --
        excess_r = max(0, 1 - C_r / (dem_i(r) + dem_j(r)))
    -- plus a small baseline-interference term b_i(r). j is exposed to r for
    a fraction s_j(r) of its critical path; independent resources compose
    multiplicatively:
        d_{i,j} = 1 - prod_r (1 - s_j(r) * (1 - (1-excess_r)(1-b_i(r)))).
    """
    dem_i = _demands(server, w_i, t_i, lost_cache)
    dem_j = _demands(server, w_j, t_j, lost_cache)
    sens_j = _sensitivity(server, w_j, t_j, dem_j)
    caps = _capacities(server)
    keep = 1.0
    for r, cap in caps.items():
        total = dem_i[r] + dem_j[r]
        excess = max(0.0, 1.0 - cap / total) if total > 0 else 0.0
        baseline = dem_i[r] / (dem_i[r] + _BASELINE * cap)
        slow = 1.0 - (1.0 - excess) * (1.0 - baseline)
        keep *= 1.0 - sens_j[r] * slow
    return 1.0 - keep


@dataclasses.dataclass(frozen=True)
class CoRunResult:
    throughputs: tuple[float, ...]  # bytes/s per workload under consolidation
    solo: tuple[float, ...]  # solo throughput per workload
    degradations: tuple[float, ...]  # D_i = 1 - T_corun / T_solo  (== O_i/(AR_i+O_i))
    cache_overflowed: bool

    @property
    def max_degradation(self) -> float:
        return max(self.degradations) if self.degradations else 0.0


def throughput_after_cache(server: ServerSpec, w: Workload, overflowed: bool) -> float:
    """Base throughput of ``w`` given the LLC outcome of the co-run set.

    A workload that *loses* the LLC falls from level-1 to level-2 bandwidth
    (Fig 6: its data is evicted by co-runners, every access misses to the
    next tier). Workloads already past the LLC (FS > LLC) are unaffected --
    they never competed (§IV.A).
    """
    if not overflowed or w.fs > server.llc_bytes:
        return solo_throughput(server, w)
    lvl = max(2, level_of(server, w.fs, w.op))
    bw, ov = level_params(server, lvl, w.op)
    return amortized(bw, ov, w.rs)


def simulate_corun(server: ServerSpec, workloads: Sequence[Workload]) -> CoRunResult:
    """Ground-truth throughput of N consolidated workloads on one server."""
    if not workloads:
        return CoRunResult((), (), (), False)
    overflowed = cache_overflow(server, workloads)
    base = [throughput_after_cache(server, w, overflowed) for w in workloads]

    thr, deg, solo = [], [], []
    for j, w in enumerate(workloads):
        slow = 1.0
        for i in range(len(workloads)):
            if i != j:
                slow *= 1.0 - pair_slowdown(
                    server, workloads[i], base[i], w, base[j], overflowed
                )
        t = base[j] * slow
        s = solo_throughput(server, w)
        thr.append(t)
        solo.append(s)
        deg.append(1.0 - t / s)
    return CoRunResult(tuple(thr), tuple(solo), tuple(deg), overflowed)


def corun_throughput_grid(
    server: ServerSpec, rs: float, fs_grid, n_grid, op: str = "read"
) -> np.ndarray:
    """Throughput surface vs (N, FS) for N identical co-run workloads.

    This regenerates the paper's Figures 3(a)/4(a): fix RS (64KB / 256KB),
    sweep FS along one axis and the number of concurrent workloads N along
    the other; the sharp cliff is the TDP.
    """
    out = np.zeros((len(n_grid), len(fs_grid)))
    for ni, n in enumerate(n_grid):
        for fi, fs in enumerate(fs_grid):
            ws = [Workload(fs=float(fs), rs=float(rs), op=op)] * int(n)
            out[ni, fi] = simulate_corun(server, ws).throughputs[0]
    return out


def makespan_consolidated(server: ServerSpec, workloads: Sequence[Workload]) -> float:
    """Makespan when the set is consolidated on one server (§V, Fig 5).

    Each workload's completion time stretches from AR_i to AR_i/(1-D_i)
    (= AR_i + O_i with D_i = O_i/(AR_i+O_i)). The makespan is the max.
    This is the quantity the 50%-degradation criterion (Eqn 4) protects:
    D_i < 0.5  <=>  O_i < AR_i  <=>  consolidation beats sequential.
    """
    res = simulate_corun(server, workloads)
    t = 0.0
    for w, d, s in zip(workloads, res.degradations, res.solo):
        ar = w.data_total / s
        t = max(t, ar / max(1.0 - d, 1e-9))
    return t


def makespan_sequential(server: ServerSpec, workloads: Sequence[Workload]) -> float:
    """Makespan when the workloads run one after another (no consolidation)."""
    return sum(w.data_total / solo_throughput(server, w) for w in workloads)
