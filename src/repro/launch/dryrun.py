import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run (deliverable e): lower + compile every (arch x shape)
cell on the production meshes, prove it shards and fits, and extract the
roofline terms (deliverable g).

MUST be the first two lines above: jax locks the device count on first init,
so the XLA_FLAGS assignment precedes every other import, including repro.*.

Per cell:
  1. PRODUCTION compile (scan-over-layers, chunked attention, remat):
     ``compiled.memory_analysis()`` -> bytes/device (proves it fits 16GB HBM),
     and the compile itself proves the sharding config is coherent (no GSPMD
     errors, no unsupported collectives).
  2. COST compiles at unrolled depths L1 < L2 (see roofline.py): FLOPs /
     bytes / collective bytes extrapolated linearly in depth (XLA cost
     analysis counts while bodies once).

Usage:
  python -m repro.launch.dryrun --arch llama3.2-3b --shape train_4k --mesh single
  python -m repro.launch.dryrun --all [--mesh single|multi|both] [--skip-existing]
  python -m repro.launch.dryrun --all --print-table
"""
import argparse
import dataclasses
import json
import pathlib
import time

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec

from ..configs.base import SHAPES, MeshConfig, RunConfig, sharding_rules
from ..configs.registry import ARCHS, cells, get_config
from ..distributed.sharding import (
    batch_specs,
    cache_specs,
    named,
    opt_state_specs,
    param_specs,
)
from ..distributed.train_step import make_train_step
from ..models import layers as model_layers
from ..models.api import build_model
from ..models.params import abstract
from ..optim import OptConfig, make_optimizer
from .mesh import make_mesh, make_production_mesh
from .roofline import (
    HBM_BYTES,
    CellArtifact,
    collective_bytes,
    extrapolate,
    model_flops,
)

ARTIFACT_ROOT = pathlib.Path(__file__).resolve().parents[3] / "artifacts" / "dryrun"

#: beyond-paper optimization variants for the §Perf hillclimb. Baselines are
#: the paper-faithful/default-layout cells; variants re-lower the same cell
#: with one knob flipped so before/after is a controlled comparison.
VARIANTS = {
    "dp": dict(layout="dp"),  # pure-DP + FSDP layout (small models)
    "int8kv": dict(kv_cache_dtype="int8"),  # quantized KV cache (decode)
    "nofsdpexp": dict(expert_fsdp=False),  # resident expert weights (MoE)
    "bf16comb": dict(moe_combine_dtype="bf16"),  # half-width EP combine
    "nofsdpexp_bf16comb": dict(expert_fsdp=False, moe_combine_dtype="bf16"),
    "dp_noremat": dict(layout="dp", remat="none"),  # small models fit w/o remat
    # serving: int8 KV + bf16 weights (no optimizer state to justify fp32)
    "int8kv_bf16p": dict(kv_cache_dtype="int8", param_dtype=__import__("jax.numpy", fromlist=["bfloat16"]).bfloat16),
    # no-remat needs microbatching to fit: per-microbatch activations shrink
    # by k while the HLO byte count stays ~flat (same tokens per step)
    "dp_noremat_mb4": dict(layout="dp", remat="none", microbatches=4),
}


def _mesh_cfg(mesh_kind: str) -> MeshConfig:
    return MeshConfig(multi_pod=(mesh_kind == "multi"))


def _cost_depths(cfg) -> tuple[int, int]:
    if cfg.family == "hybrid":
        return 8, 16  # whole periods
    return 1, 2


def _cost_config(cfg, n_layers: int):
    kw = dict(
        n_layers=n_layers,
        scan_layers=False,
        attn_chunk=1 << 30,
    )
    if cfg.family == "encdec":
        kw["enc_layers"] = n_layers
    return dataclasses.replace(cfg, **kw)


def _step_and_specs(cfg, shape: str, mesh, mesh_cfg, microbatches: int = 1):
    """Build (fn, example_args, in_shardings) for this cell's step kind."""
    model = build_model(cfg)
    rules = sharding_rules(cfg, mesh_cfg)
    info = SHAPES[shape]
    kind = info["kind"]
    p_abs = abstract(model.param_infos())
    p_shard = named(mesh, param_specs(model, mesh_cfg))
    inputs = model.input_specs(shape)
    in_shard = named(mesh, batch_specs(model, mesh_cfg, inputs))

    if kind == "train":
        run = RunConfig(model=cfg, shape=shape, mesh=mesh_cfg, microbatches=microbatches)
        _, train_step = make_train_step(model, run)
        opt_init, _ = make_optimizer(cfg.optimizer, OptConfig())
        opt_abs = jax.eval_shape(opt_init, p_abs)
        opt_shard = named(mesh, opt_state_specs(opt_init, p_abs, param_specs(model, mesh_cfg)))
        step_scalar = jax.ShapeDtypeStruct((), jnp.int32)

        def fn(params, opt_state, batch, step):
            return train_step(params, opt_state, batch, step)

        args = (p_abs, opt_abs, inputs, step_scalar)
        shardings = (p_shard, opt_shard, in_shard, NamedSharding(mesh, PartitionSpec()))
        return fn, args, shardings, (0, 1)  # donate params + opt state

    if kind == "prefill":
        cache_abs = abstract(model.cache_infos(info["global_batch"], info["seq_len"]))
        cache_shard = named(mesh, cache_specs(model, mesh_cfg, info["global_batch"], info["seq_len"]))

        def fn(params, batch, cache):
            return model.prefill(params, batch, cache)

        return fn, (p_abs, inputs, cache_abs), (p_shard, in_shard, cache_shard), (2,)

    # decode: the cache is donated (production serving updates it in place;
    # without donation every step pays a full cache copy)
    cache_abs = abstract(model.cache_infos(info["global_batch"], info["seq_len"]))
    cache_shard = named(mesh, cache_specs(model, mesh_cfg, info["global_batch"], info["seq_len"]))

    def fn(params, cache, tokens):
        return model.decode_step(params, cache, tokens)

    return (fn, (p_abs, cache_abs, inputs["tokens"]),
            (p_shard, cache_shard, in_shard["tokens"]), (1,))


def _compile(cfg, shape, mesh, mesh_cfg, microbatches: int = 1):
    fn, args, shardings, donate = _step_and_specs(cfg, shape, mesh, mesh_cfg, microbatches)
    rules = sharding_rules(cfg, mesh_cfg)
    with mesh, model_layers.activation_sharding(mesh, rules):
        lowered = jax.jit(fn, in_shardings=shardings, donate_argnums=donate).lower(*args)
        compiled = lowered.compile()
    return lowered, compiled


def run_cell(arch: str, shape: str, mesh_kind: str, verbose: bool = True,
             variant: str | None = None) -> CellArtifact:
    cfg = get_config(arch)
    microbatches = 1
    if variant:
        kw = dict(VARIANTS[variant])
        microbatches = kw.pop("microbatches", 1)
        cfg = dataclasses.replace(cfg, **kw)
    mesh_cfg = _mesh_cfg(mesh_kind)
    mesh = make_mesh(mesh_cfg)
    info = SHAPES[shape]
    t0 = time.time()

    # 1. production compile: proves sharding + memory
    _, compiled = _compile(cfg, shape, mesh, mesh_cfg, microbatches)
    ma = compiled.memory_analysis()
    print(f"[{arch} x {shape} x {mesh_kind}] memory_analysis:", ma)
    peak = ma.argument_size_in_bytes + ma.output_size_in_bytes + ma.temp_size_in_bytes - ma.alias_size_in_bytes
    mem_breakdown = {
        "argument": ma.argument_size_in_bytes,
        "output": ma.output_size_in_bytes,
        "temp": ma.temp_size_in_bytes,
        "alias": ma.alias_size_in_bytes,
    }
    prod_cost = compiled.cost_analysis()
    print(f"[{arch} x {shape} x {mesh_kind}] cost_analysis(prod): "
          f"flops={prod_cost.get('flops', 0):.3e} bytes={prod_cost.get('bytes accessed', 0):.3e}")

    # 2. cost compiles at unrolled depths
    l1, l2 = _cost_depths(cfg)
    pts = {}
    for L in (l1, l2):
        ccfg = _cost_config(cfg, L)
        _, c = _compile(ccfg, shape, mesh, mesh_cfg, microbatches)
        ca = c.cost_analysis()
        pts[L] = {
            "flops": float(ca.get("flops", 0.0)),
            "bytes": float(ca.get("bytes accessed", 0.0)),
            "coll": collective_bytes(c.as_text()),
        }
    L_full = cfg.n_layers
    flops = extrapolate(pts[l1]["flops"], pts[l2]["flops"], l1, l2, L_full)
    nbytes = extrapolate(pts[l1]["bytes"], pts[l2]["bytes"], l1, l2, L_full)
    kinds = set(pts[l1]["coll"]) | set(pts[l2]["coll"])
    coll_breakdown = {
        k: extrapolate(pts[l1]["coll"].get(k, 0.0), pts[l2]["coll"].get(k, 0.0), l1, l2, L_full)
        for k in kinds
    }
    coll = sum(coll_breakdown.values())

    art = CellArtifact(
        cell=f"{arch}__{shape}__{mesh_kind}" + (f"__{variant}" if variant else ""),
        arch=arch,
        shape=shape,
        kind=info["kind"],
        mesh=mesh_kind,
        chips=mesh_cfg.n_devices,
        flops=flops,
        bytes_accessed=nbytes,
        collective_bytes=coll,
        collective_breakdown=coll_breakdown,
        peak_memory_per_device=float(peak),
        memory_breakdown=mem_breakdown,
        model_flops=model_flops(cfg, shape),
        compile_seconds=time.time() - t0,
        extras={
            "cost_points": pts,
            "prod_flops_raw": float(prod_cost.get("flops", 0.0)),
            "fits_hbm": bool(peak <= HBM_BYTES),
        },
    )
    if verbose:
        t = art.terms()
        print(
            f"[{art.cell}] mem/dev={peak/2**30:.2f}GiB fits={art.extras['fits_hbm']} "
            f"compute={t['compute_s']*1e3:.2f}ms memory={t['memory_s']*1e3:.2f}ms "
            f"collective={t['collective_s']*1e3:.2f}ms bottleneck={art.bottleneck()} "
            f"useful={art.useful_flops_ratio():.3f} ({art.compile_seconds:.0f}s)"
        )
    return art


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=sorted(ARCHS), default=None)
    ap.add_argument("--shape", choices=sorted(SHAPES), default=None)
    ap.add_argument("--mesh", choices=["single", "multi", "both"], default="both")
    ap.add_argument("--variant", choices=sorted(VARIANTS), default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--out", default=str(ARTIFACT_ROOT))
    args = ap.parse_args()

    out = pathlib.Path(args.out)
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    todo = []
    if args.all:
        for arch, shape, skip in cells(include_skipped=True):
            for mk in meshes:
                todo.append((arch, shape, mk, skip))
    else:
        assert args.arch and args.shape, "--arch and --shape (or --all)"
        for mk in meshes:
            todo.append((args.arch, args.shape, mk, None))

    failures = []
    for arch, shape, mk, skip in todo:
        cell = f"{arch}__{shape}__{mk}"
        path = out / f"{cell}.json"
        if skip:
            out.mkdir(parents=True, exist_ok=True)
            path.write_text(json.dumps({"cell": cell, "skip": skip}, indent=1))
            print(f"[{cell}] {skip}")
            continue
        if args.skip_existing and path.exists() and "skip" not in json.loads(path.read_text()):
            print(f"[{cell}] cached")
            continue
        try:
            art = run_cell(arch, shape, mk, variant=args.variant)
            art.save(out)
        except Exception as e:  # noqa: BLE001 -- a failing cell is a bug to surface
            failures.append((cell, repr(e)))
            print(f"[{cell}] FAILED: {e!r}")
    if failures:
        print(f"\n{len(failures)} FAILED cells:")
        for c, e in failures:
            print(" ", c, e[:200])
        raise SystemExit(1)
    print("\nALL CELLS COMPILED.")


if __name__ == "__main__":
    main()
