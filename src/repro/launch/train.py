"""End-to-end training driver.

Production shape:  python -m repro.launch.train --arch llama3.2-3b --shape train_4k
CPU smoke shape:   python -m repro.launch.train --arch tinyllama-1.1b --smoke \
                        --steps 200 --batch 8 --seq 128

Wires every substrate together: chunk-store data pipeline -> pjit train step
(sharded on the local mesh) -> checkpointing (async, atomic) -> fault-
tolerance hooks (heartbeats + straggler policy + elastic re-mesh plan).
Restart-safety: rerunning the same --ckpt dir resumes from the last complete
step, including the data-pipeline cursor.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..checkpoint import Checkpointer
from ..configs.base import MeshConfig, RunConfig, sharding_rules
from ..configs.registry import get_config
from ..data import TokenPipeline, synthetic_store
from ..distributed.fault_tolerance import HeartbeatMonitor
from ..distributed.sharding import batch_specs, named, opt_state_specs, param_specs
from ..distributed.train_step import make_train_step
from ..models import layers as model_layers
from ..models.api import build_model
from .mesh import make_host_mesh


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b")
    ap.add_argument("--smoke", action="store_true", help="reduced config (CPU)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ckpt", default="")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch, smoke=args.smoke)
    model = build_model(cfg)
    mesh = make_host_mesh()
    mesh_cfg = MeshConfig(data=mesh.devices.shape[0], model=mesh.devices.shape[1])
    run = RunConfig(model=cfg, shape="train_4k", mesh=mesh_cfg,
                    learning_rate=args.lr, total_steps=args.steps,
                    warmup_steps=max(1, args.steps // 10),
                    microbatches=args.microbatches)

    init, train_step = make_train_step(model, run)
    rules = sharding_rules(cfg, mesh_cfg)
    p_specs = param_specs(model, mesh_cfg)

    with mesh, model_layers.activation_sharding(mesh, rules):
        params, opt_state = jax.jit(init)(jax.random.PRNGKey(args.seed))
        step_fn = jax.jit(train_step, donate_argnums=(0, 1))

        store = synthetic_store(n_files=2, file_mb=64, block_mb=8)
        pipe = TokenPipeline(store, vocab=cfg.vocab, batch=args.batch,
                             seq_len=args.seq, prefetch=2).start()
        ckpt = Checkpointer(args.ckpt) if args.ckpt else None
        monitor = HeartbeatMonitor(n_hosts=jax.process_count())

        start_step = 0
        if ckpt and ckpt.latest_step() is not None:
            s = ckpt.latest_step()
            restored = ckpt.restore(s, {"params": params, "opt": opt_state,
                                        "data": pipe.state_dict()})
            params, opt_state = restored["params"], restored["opt"]
            pipe.load_state_dict(restored["data"])
            start_step = s
            print(f"resumed from step {s}")

        t0 = time.time()
        losses = []
        for step in range(start_step, args.steps):
            batch = {k: jnp.asarray(v) for k, v in next(pipe).items()}
            ts = time.time()
            params, opt_state, out = step_fn(params, opt_state, batch, jnp.int32(step))
            loss = float(out["loss"])
            losses.append(loss)
            monitor.heartbeat(jax.process_index(), time.time(), time.time() - ts)
            if step % args.log_every == 0 or step == args.steps - 1:
                print(f"step {step:5d} loss {loss:.4f} lr {float(out['lr']):.2e} "
                      f"gnorm {float(out['grad_norm']):.3f} "
                      f"({(time.time()-t0)/max(1, step-start_step+1):.3f}s/step)")
            if ckpt and (step + 1) % args.ckpt_every == 0:
                ckpt.save(step + 1, {"params": params, "opt": opt_state,
                                     "data": pipe.state_dict()})
            stragglers = monitor.stragglers()
            if stragglers:
                print(f"straggler hosts detected: {stragglers} (Eqn-4 policy)")
        if ckpt:
            ckpt.save(args.steps, {"params": params, "opt": opt_state,
                                   "data": pipe.state_dict()}, blocking=True)
        pipe.stop()
        print(f"final loss {losses[-1]:.4f} (first {losses[0]:.4f}); "
              f"improved: {losses[-1] < losses[0]}")
        return losses


if __name__ == "__main__":
    main()
