# Launchers: mesh.py (production mesh), dryrun.py (multi-pod compile checks),
# train.py / serve.py (end-to-end drivers). Import nothing at package level:
# dryrun.py must control XLA_FLAGS before any jax device initialization.
