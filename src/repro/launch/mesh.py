"""Production mesh construction.

IMPORTANT: functions only -- importing this module never touches jax device
state (the dry-run must set XLA_FLAGS before any device query).

Mesh topology (TPU v5e):
  single-pod: (data=16, model=16)              = 256 chips (one pod slice)
  multi-pod:  (pod=2, data=16, model=16)       = 512 chips (two pod slices)

The 'model' axis carries TP/EP/SP (intra-pod, ICI-local by construction);
'data'(+'pod') carry DP and the FSDP param sharding. DCN traffic between
pods is then only data-parallel gradient reduction -- the standard
multi-pod layout.
"""
from __future__ import annotations

import jax
import numpy as np
from jax.sharding import Mesh

from ..configs.base import MeshConfig


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(cfg: MeshConfig):
    if cfg.multi_pod:
        return jax.make_mesh((cfg.pods, cfg.data, cfg.model), ("pod", "data", "model"))
    return jax.make_mesh((cfg.data, cfg.model), ("data", "model"))


def make_host_mesh(model_axis: int = 1):
    """Tiny mesh over the locally available devices (tests/examples)."""
    n = len(jax.devices())
    data = max(1, n // model_axis)
    return jax.make_mesh((data, model_axis), ("data", "model"))
