"""Serving driver with consolidation-gated admission (the paper's scheduler
applied at request time).

Multiple model "services" can be co-located on the host fleet; an arriving
request stream (a *workload*) is admitted onto a pod only if the paper's two
criteria hold (max mutual degradation < 50%, capacity within budget) --
core/cluster.py provides the packing; this driver runs the actual batched
prefill+decode loop for whatever was admitted locally.

  python -m repro.launch.serve --arch tinyllama-1.1b --smoke --requests 4 \
      --prompt-len 32 --gen 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import MeshConfig, sharding_rules
from ..configs.registry import get_config
from ..core import TPU_V5E_HOST, ConsolidationEngine, Workload
from ..core.units import KB, MB
from ..distributed.serve_step import make_serve_steps
from ..models import layers as model_layers
from ..models.api import build_model
from ..models.params import materialize
from .mesh import make_host_mesh


def admission_check(arch: str, n_streams: int, *, metrics: bool = False):
    """Admit `n_streams` request streams onto the serving hosts through the
    unified ConsolidationEngine (the paper's online operating model, §V).

    Each stream is characterized (§III.A) by its host-side I/O: KV-cache
    paging working set as FS, per-decode-step activation traffic as RS. The
    engine runs the arrive -> score -> place-or-queue loop; ``None`` means
    the stream was not admitted on arrival and had to queue for capacity
    (criterion 1).

    ``metrics=True`` threads the ``repro.obs`` MetricFrame through the
    admission run and returns ``(placements, frame)`` -- the frame's
    waiting-time and slowdown histograms are the serving-SLO substrate the
    ROADMAP's continuous front-end reports p50/p99 from (``None`` frame on
    deadlock: the run never completed).
    """
    engine = ConsolidationEngine([TPU_V5E_HOST, TPU_V5E_HOST])
    stream = Workload(fs=64 * MB, rs=256 * KB, name=f"serve:{arch}")
    try:
        result = engine.run([(0.0, stream)] * n_streams, metrics=metrics)
    except RuntimeError:
        # deadlock (stream fits no empty host): admit nothing rather than
        # crash the serving driver at startup
        placements = [None] * n_streams
        return (placements, None) if metrics else placements
    placements = [None if q else p
                  for p, q in zip(result.placements, result.was_queued)]
    return (placements, result.metrics) if metrics else placements


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    placements, frame = admission_check(args.arch, args.requests, metrics=True)
    print(f"consolidation admission: {args.requests} stream(s) -> pods "
          f"{placements}")
    if frame is not None:
        # the paper's utilization-floor criterion as a live serving SLO:
        # waiting time (s) and slowdown (x solo) percentiles of admission
        from ..obs.report import percentile_table

        print("admission SLO percentiles:")
        print(percentile_table(frame, ("waiting_time", "slowdown")))

    cfg = get_config(args.arch, smoke=args.smoke)
    model = build_model(cfg)
    mesh = make_host_mesh()
    mesh_cfg = MeshConfig(data=mesh.devices.shape[0], model=mesh.devices.shape[1])
    rules = sharding_rules(cfg, mesh_cfg)

    rng = jax.random.PRNGKey(args.seed)
    with mesh, model_layers.activation_sharding(mesh, rules):
        params = materialize(model.param_infos(), rng)
        cache = materialize(model.cache_infos(args.requests, args.prompt_len + args.gen), rng)
        prefill_step, decode_step = make_serve_steps(model)
        prefill_step = jax.jit(prefill_step)
        decode_step = jax.jit(decode_step, donate_argnums=(1,))

        prompts = jax.random.randint(rng, (args.requests, args.prompt_len), 0, cfg.vocab)
        batch = {"tokens": prompts}
        if cfg.family == "vlm":
            batch["vis_embeds"] = jax.random.normal(
                rng, (args.requests, cfg.vis_tokens, cfg.d_model), cfg.compute_dtype)
        if cfg.family == "encdec":
            batch["audio_embeds"] = jax.random.normal(
                rng, (args.requests, cfg.enc_seq, cfg.d_model), cfg.compute_dtype)

        t0 = time.time()
        tok, cache = prefill_step(params, batch, cache)
        out = [np.asarray(tok)]
        for _ in range(args.gen - 1):
            tok, cache = decode_step(params, cache, tok[:, None])
            out.append(np.asarray(tok))
        dt = time.time() - t0
        gen = np.stack(out, axis=1)
        print(f"generated {gen.shape} tokens in {dt:.2f}s "
              f"({args.requests * args.gen / dt:.1f} tok/s)")
        print("sample:", gen[0][:12].tolist())
        return gen


if __name__ == "__main__":
    main()
