"""Roofline analysis from compiled dry-run artifacts (deliverable g).

Terms (TPU v5e, per the assignment):
    compute    = FLOPs_per_device / peak_FLOP/s          (197e12 bf16)
    memory     = bytes_per_device / HBM_bw               (819e9)
    collective = collective_bytes_per_device / link_bw   (50e9)

``compiled.cost_analysis()`` and the post-SPMD HLO are *per-device*, so each
term divides by a single chip's capability; the assignment's
"X / (chips * peak)" formulation with global X is numerically identical.

Loop-body correction: XLA's cost analysis counts while-loop bodies ONCE
(verified empirically), so production scan-over-layers compiles undercount
by ~L x. The dry-run therefore compiles each cell twice more with layers
UNROLLED at depths L1 < L2 (attention un-chunked so no inner scans remain)
and extrapolates linearly: total(L) = c(L1) + (L - L1) * (c(L2) - c(L1)) /
(L2 - L1). Residual undercount: the RWKV intra-chunk scan (~0.2% of layer
FLOPs) and the Mamba time scan body (~0.6%), both elementwise-dominated --
documented in EXPERIMENTS.md.
"""
from __future__ import annotations

import dataclasses
import json
import pathlib
import re
from typing import Any

from ..configs.base import SHAPES, ModelConfig

PEAK_FLOPS = 197e12  # bf16 per chip
HBM_BW = 819e9  # bytes/s per chip
ICI_BW = 50e9  # bytes/s per link
HBM_BYTES = 16 * 2**30

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "token": 0,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_COLL_RE = re.compile(
    r"=\s*(\([^)]*\)|[a-z0-9]+\[[0-9,]*\][^ ]*)\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\("
)


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(type_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def collective_bytes(hlo_text: str) -> dict[str, float]:
    """Per-collective-kind result bytes from a post-SPMD HLO module (per device)."""
    out: dict[str, float] = {}
    for m in _COLL_RE.finditer(hlo_text):
        kind = m.group(2)
        done_free = "-done(" not in m.group(0)
        if done_free:
            out[kind] = out.get(kind, 0.0) + _shape_bytes(m.group(1))
    return out


# --- analytic MODEL_FLOPS (6*N*D dense / 6*N_active*D MoE) ------------------------

def n_eff_per_token(cfg: ModelConfig) -> float:
    """Matmul parameters touched per decoder token (MoE: active only)."""
    D, H, Hkv, dh, F, V = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.d_head, cfg.d_ff, cfg.vocab

    def attn_params():
        return D * H * dh + 2 * D * Hkv * dh + H * dh * D

    def mlp_params(f):
        return (3 if cfg.act == "swiglu" else 2) * D * f

    def moe_params():
        return D * cfg.moe_experts + cfg.moe_topk * 3 * D * cfg.moe_dff

    head = D * V
    if cfg.family in ("dense", "vlm"):
        return cfg.n_layers * (attn_params() + mlp_params(F)) + head
    if cfg.family == "moe":
        return cfg.n_layers * (attn_params() + moe_params()) + head
    if cfg.family == "encdec":  # decoder-token share only (encoder added separately)
        cross_q = D * H * dh + H * dh * D  # q + o on decoder tokens
        return cfg.n_layers * (attn_params() + cross_q + mlp_params(F)) + head
    if cfg.family == "ssm":  # rwkv6
        lora = D * 64 + 64 * D
        time = 5 * D * D + lora
        channel = 2 * D * F + D * D
        return cfg.n_layers * (time + channel) + head
    if cfg.family == "hybrid":
        from ..models.mamba import dims as mamba_dims

        d_inner, dt_rank, d_state = mamba_dims(cfg)
        mamba_p = (2 * D * d_inner + cfg.mamba_dconv * d_inner
                   + d_inner * (dt_rank + 2 * d_state) + dt_rank * d_inner + d_inner * D)
        per_period = 0.0
        for i in range(8):
            per_period += attn_params() if i % cfg.attn_every == cfg.attn_offset else mamba_p
            is_moe = cfg.moe_experts and i % cfg.moe_every == cfg.moe_every - 1
            per_period += moe_params() if is_moe else mlp_params(F)
        return (cfg.n_layers // 8) * per_period + head
    raise ValueError(cfg.family)


def model_flops(cfg: ModelConfig, shape: str) -> float:
    """MODEL_FLOPS for one cell: 6*N*tokens train, 2*N*tokens fwd-only."""
    info = SHAPES[shape]
    B, S, kind = info["global_batch"], info["seq_len"], info["kind"]
    n = n_eff_per_token(cfg)
    mult = 6.0 if kind == "train" else 2.0
    if kind == "train":
        tokens = B * S  # vlm: vis prefix + text = S tokens through the stack
    elif kind == "prefill":
        tokens = B * S
    else:  # decode: one token per sequence
        tokens = B * 1
    total = mult * n * tokens
    if cfg.family == "encdec" and kind != "decode":
        enc_n = cfg.enc_layers * (
            cfg.d_model * cfg.n_heads * cfg.d_head * 2
            + 2 * cfg.d_model * cfg.n_kv_heads * cfg.d_head
            + (3 if cfg.act == "swiglu" else 2) * cfg.d_model * cfg.d_ff
        )
        cross_kv = cfg.n_layers * 2 * cfg.d_model * cfg.n_kv_heads * cfg.d_head
        total += mult * (enc_n + cross_kv) * B * cfg.enc_seq
    return total


# --- artifact schema + the three terms ------------------------------------------------


@dataclasses.dataclass
class CellArtifact:
    cell: str
    arch: str
    shape: str
    kind: str
    mesh: str  # 'single' | 'multi'
    chips: int
    flops: float  # per-device, loop-corrected
    bytes_accessed: float  # per-device, loop-corrected
    collective_bytes: float  # per-device, loop-corrected
    collective_breakdown: dict
    peak_memory_per_device: float
    memory_breakdown: dict
    model_flops: float
    compile_seconds: float
    extras: dict

    def terms(self) -> dict[str, float]:
        return {
            "compute_s": self.flops / PEAK_FLOPS,
            "memory_s": self.bytes_accessed / HBM_BW,
            "collective_s": self.collective_bytes / ICI_BW,
        }

    def bottleneck(self) -> str:
        t = self.terms()
        return max(t, key=lambda k: t[k]).replace("_s", "")

    def useful_flops_ratio(self) -> float:
        hlo_global = self.flops * self.chips
        return self.model_flops / hlo_global if hlo_global else 0.0

    def step_time(self) -> float:
        return max(self.terms().values())

    def roofline_fraction(self) -> float:
        """MODEL_FLOPS-based MFU bound implied by the dominant term."""
        t = self.step_time()
        if t <= 0:
            return 0.0
        return self.model_flops / (self.chips * PEAK_FLOPS) / t

    def save(self, root: str | pathlib.Path):
        p = pathlib.Path(root)
        p.mkdir(parents=True, exist_ok=True)
        with open(p / f"{self.cell}.json", "w") as f:
            json.dump(dataclasses.asdict(self), f, indent=1)

    @classmethod
    def load(cls, path: str | pathlib.Path) -> "CellArtifact":
        return cls(**json.loads(pathlib.Path(path).read_text()))


def extrapolate(c1: float, c2: float, l1: int, l2: int, l: int) -> float:
    """Linear-in-depth extrapolation of a per-device cost."""
    if l2 == l1:
        return c2
    return c1 + (l - l1) * (c2 - c1) / (l2 - l1)
