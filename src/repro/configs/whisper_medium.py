"""whisper-medium [audio] -- enc-dec, conv frontend (stub) [arXiv:2212.04356].
24L d_model=1024 16H (GQA kv=16) d_ff=4096 vocab=51865.
24 encoder + 24 decoder layers; input_specs() supplies precomputed frame
embeddings [B, 1500, D] (the conv/mel frontend is a stub per the assignment)."""
import dataclasses

from .base import ModelConfig

ARCH_ID = "whisper-medium"

CONFIG = ModelConfig(
    name=ARCH_ID,
    family="encdec",
    n_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_head=64,
    d_ff=4096,
    vocab=51865,
    norm="layernorm",
    act="gelu",
    qkv_bias=True,
    rope_theta=10_000.0,  # decoder positions: RoPE (deviation documented in DESIGN.md)
    enc_layers=24,
    enc_seq=1500,
)

SMOKE = dataclasses.replace(
    CONFIG, n_layers=2, enc_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
    d_head=16, d_ff=128, vocab=256, enc_seq=16, attn_chunk=32,
)
