"""jamba-v0.1-52b [hybrid] -- Mamba+attn 1:7 interleave, MoE [arXiv:2403.19887].
32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=65536, MoE 16e top-2.
Sub-quadratic: runs long_500k (attention layers use a 32k sliding window
inside the 500k stream; Mamba carries long-range state)."""
import dataclasses

from .base import ModelConfig

ARCH_ID = "jamba-v0.1-52b"

CONFIG = ModelConfig(
    name=ARCH_ID,
    family="hybrid",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_head=128,
    d_ff=14336,
    vocab=65536,
    norm="rmsnorm",
    act="swiglu",
    rope_theta=100_000.0,  # Jamba's attention layers use no explicit positions; RoPE kept
    sliding_window=32_768,
    moe_experts=16,
    moe_topk=2,
    moe_dff=14336,
    moe_every=2,
    attn_every=8,
    attn_offset=4,
    mamba_dstate=16,
    mamba_dconv=4,
    mamba_expand=2,
    fsdp=True,
)

SMOKE = dataclasses.replace(
    CONFIG, n_layers=8, d_model=64, n_heads=4, n_kv_heads=2, d_head=16,
    d_ff=128, vocab=256, moe_experts=4, moe_topk=2, moe_dff=128,
    sliding_window=64, mamba_dstate=4, attn_chunk=32, fsdp=False,
)
