"""llama3.2-3b [dense] -- small llama3 [hf:meta-llama/Llama-3.2-1B; unverified].
28L d_model=3072 24H (GQA kv=8) d_ff=8192 vocab=128256."""
import dataclasses

from .base import ModelConfig

ARCH_ID = "llama3.2-3b"

CONFIG = ModelConfig(
    name=ARCH_ID,
    family="dense",
    n_layers=28,
    d_model=3072,
    n_heads=24,
    n_kv_heads=8,
    d_head=128,
    d_ff=8192,
    vocab=128256,
    norm="rmsnorm",
    act="swiglu",
    rope_theta=500_000.0,
    tie_embeddings=True,
)

SMOKE = dataclasses.replace(
    CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_head=16,
    d_ff=128, vocab=256, attn_chunk=32,
)
