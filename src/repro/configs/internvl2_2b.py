"""internvl2-2b [vlm] -- InternViT + InternLM2 [arXiv:2404.16821; hf].
24L d_model=2048 16H (GQA kv=8) d_ff=8192 vocab=92553.
The ViT frontend is a stub: input_specs() supplies precomputed patch
embeddings [B, 256, D] prepended to the text stream."""
import dataclasses

from .base import ModelConfig

ARCH_ID = "internvl2-2b"

CONFIG = ModelConfig(
    name=ARCH_ID,
    family="vlm",
    n_layers=24,
    d_model=2048,
    n_heads=16,
    n_kv_heads=8,
    d_head=128,
    d_ff=8192,
    vocab=92553,
    norm="rmsnorm",
    act="swiglu",
    rope_theta=1_000_000.0,
    vis_tokens=256,
)

SMOKE = dataclasses.replace(
    CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_head=16,
    d_ff=128, vocab=256, vis_tokens=8, attn_chunk=32,
)
