"""starcoder2-7b [dense] -- GQA, RoPE [arXiv:2402.19173; hf].
32L d_model=4608 36H (GQA kv=4) d_ff=18432 vocab=49152.
StarCoder2 uses LayerNorm + plain-GELU MLP with biases."""
import dataclasses

from .base import ModelConfig

ARCH_ID = "starcoder2-7b"

CONFIG = ModelConfig(
    name=ARCH_ID,
    family="dense",
    n_layers=32,
    d_model=4608,
    n_heads=36,
    n_kv_heads=4,
    d_head=128,
    d_ff=18432,
    vocab=49152,
    norm="layernorm",
    act="gelu",
    qkv_bias=True,
    rope_theta=100_000.0,
    fsdp=True,
)

SMOKE = dataclasses.replace(
    CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_head=16,
    d_ff=128, vocab=256, attn_chunk=32, fsdp=False,
)
