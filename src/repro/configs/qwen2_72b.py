"""qwen2-72b [dense] -- GQA, QKV bias [arXiv:2407.10671; hf].
80L d_model=8192 64H (GQA kv=8) d_ff=29568 vocab=152064."""
import dataclasses

from .base import ModelConfig

ARCH_ID = "qwen2-72b"

CONFIG = ModelConfig(
    name=ARCH_ID,
    family="dense",
    n_layers=80,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_head=128,
    d_ff=29568,
    vocab=152064,
    norm="rmsnorm",
    act="swiglu",
    qkv_bias=True,
    rope_theta=1_000_000.0,
    fsdp=True,  # 72B fp32 master + AdamW state must shard over the data axes
)

SMOKE = dataclasses.replace(
    CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_head=16,
    d_ff=128, vocab=256, attn_chunk=32, fsdp=False,
)
