"""Config system: model / parallelism / run configs and the sharding rules.

Every assigned architecture gets a ``configs/<id>.py`` exporting ``CONFIG``
(the exact published configuration) and ``SMOKE`` (a reduced same-family
config for CPU smoke tests). ``configs.registry`` maps ``--arch`` ids to
them.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Mapping

import jax.numpy as jnp

# --- input shapes assigned to the LM family (all 10 archs) --------------------
#   name          seq_len   global_batch  step kind
SHAPES: Mapping[str, dict] = {
    "train_4k": dict(seq_len=4_096, global_batch=256, kind="train"),
    "prefill_32k": dict(seq_len=32_768, global_batch=32, kind="prefill"),
    "decode_32k": dict(seq_len=32_768, global_batch=128, kind="decode"),
    "long_500k": dict(seq_len=524_288, global_batch=1, kind="decode"),
}


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | hybrid | ssm | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_head: int
    d_ff: int
    vocab: int

    norm: str = "rmsnorm"  # rmsnorm | layernorm
    act: str = "swiglu"  # swiglu | gelu
    qkv_bias: bool = False
    tie_embeddings: bool = False
    rope_theta: float = 500_000.0
    sliding_window: int = 0  # 0 = full attention

    # MoE
    moe_experts: int = 0
    moe_topk: int = 0
    moe_dff: int = 0  # per-expert hidden size
    moe_every: int = 1  # MoE FFN on layers where (layer % moe_every == moe_every-1)
    moe_capacity_factor: float = 1.25

    # hybrid (Jamba): attention on layers where (layer % attn_every == attn_offset)
    attn_every: int = 1
    attn_offset: int = 0
    mamba_dstate: int = 16
    mamba_dconv: int = 4
    mamba_expand: int = 2

    # rwkv6
    rwkv_head_size: int = 64

    # encoder-decoder (whisper): encoder consumes precomputed frame embeddings
    enc_layers: int = 0
    enc_seq: int = 1_500

    # vlm (internvl): precomputed patch embeddings prepended to the text stream
    vis_tokens: int = 0

    # numerics / memory policy
    param_dtype: Any = jnp.float32
    compute_dtype: Any = jnp.bfloat16
    fsdp: bool = False  # shard params/opt-state over the data axis too (ZeRO-3ish)
    remat: str = "layer"  # none | layer | full
    attn_chunk: int = 1024  # query-chunk for the flash-style jnp attention
    use_pallas: str = "never"  # never | interpret  (TPU target: 'tpu')
    optimizer: str = "adamw"  # adamw | adamw8bit | adafactor
    # scan_layers=True compiles one layer body (production).  The roofline
    # cost-extrapolation compiles (launch/dryrun.py) set it False at L=1,2
    # because XLA cost_analysis counts while-loop bodies exactly once.
    scan_layers: bool = True

    # --- beyond-paper performance knobs (EXPERIMENTS.md §Perf) -------------
    # layout='tp' is the baseline (TP over 'model'); 'dp' shards the batch
    # over BOTH mesh axes with FSDP params -- the right layout for small
    # models where TP activation collectives dominate (tinyllama hillclimb).
    layout: str = "tp"
    # expert_fsdp=False keeps expert weights resident per TP shard instead of
    # FSDP-gathering them every layer (kimi hillclimb: the gather re-streams
    # 125GB/device/pass at 1T params).
    expert_fsdp: bool = True
    # combine dtype for the EP psum ('f32' is the conservative baseline).
    moe_combine_dtype: str = "f32"
    # int8 KV cache with per-token/head scales (llama decode hillclimb).
    kv_cache_dtype: str = "bf16"

    @property
    def is_subquadratic(self) -> bool:
        """Archs that may run the long_500k shape (SSM / hybrid / linear-attn)."""
        return self.family in ("ssm", "hybrid")

    # The production mesh fixes TP = 16 (DESIGN.md §5); head counts that do
    # not divide it get sequence-sharded attention / time-sharded KV caches.
    TP_HINT = 16

    @property
    def attn_shard(self) -> str:
        """'heads' when query heads divide TP, else 'seq' (shard_map over seq)."""
        return "heads" if self.n_heads % self.TP_HINT == 0 else "seq"

    @property
    def kv_cache_time_sharded(self) -> bool:
        """Shard the KV cache over time (flash-decoding style partial softmax
        under GSPMD) when kv heads do not divide TP."""
        return self.n_kv_heads % self.TP_HINT != 0

    @property
    def has_decoder(self) -> bool:
        return True  # every assigned arch has an autoregressive decoder path

    def active_params_per_token_factor(self) -> float:
        """MoE: fraction of expert params active per token (for MODEL_FLOPS)."""
        if self.moe_experts:
            return self.moe_topk / self.moe_experts
        return 1.0


@dataclasses.dataclass(frozen=True)
class MeshConfig:
    multi_pod: bool = False
    pods: int = 2
    data: int = 16
    model: int = 16

    @property
    def n_devices(self) -> int:
        return (self.pods if self.multi_pod else 1) * self.data * self.model

    @property
    def dp(self) -> int:
        return (self.pods if self.multi_pod else 1) * self.data


@dataclasses.dataclass(frozen=True)
class RunConfig:
    model: ModelConfig
    shape: str  # key into SHAPES
    mesh: MeshConfig = MeshConfig()
    learning_rate: float = 3e-4
    weight_decay: float = 0.1
    warmup_steps: int = 100
    total_steps: int = 10_000
    grad_clip: float = 1.0
    microbatches: int = 1  # gradient-accumulation microbatches per step
    grad_compression: str = "none"  # none | bf16 | int8 (all-reduce payload)

    @property
    def shape_info(self) -> dict:
        return SHAPES[self.shape]


# --- logical-axis -> mesh-axis rules (see models/params.py docstring) ----------

def sharding_rules(cfg: ModelConfig, mesh: MeshConfig) -> dict[str, Any]:
    """Resolve logical parameter/activation axes onto the production mesh.

    TP ('model') shards heads / mlp hidden / vocab / experts.  Under FSDP the
    residual-stream dimension of the weights is additionally sharded over the
    data axes so fp32 master params + optimizer state scale with the fleet
    (ZeRO-3 for params, ZeRO-1 falls out for optimizer state since it shares
    the param sharding).
    """
    data_axes = ("pod", "data") if mesh.multi_pod else ("data",)
    if cfg.layout == "dp":
        # pure data parallelism over the whole mesh + FSDP params: no TP
        # activation collectives at all -- the gradient reduction and the
        # per-layer FSDP weight gather are the only traffic. Right for small
        # models (see EXPERIMENTS.md §Perf / tinyllama).
        all_axes = data_axes + ("model",)
        return {
            "layer": None,
            "dmodel": all_axes,
            "heads": None, "kv_heads": None, "mlp": None,
            "vocab": None, "expert": None, "conv": None, "state": None,
            "batch": all_axes,
            "act_seq": None, "act_heads": None, "act_vocab": None,
            "act_expert": None, "cache_time": None,
        }
    return {
        "layer": None,
        "dmodel": data_axes if cfg.fsdp else None,
        "heads": "model",
        "kv_heads": "model",
        "mlp": "model",
        "vocab": "model",
        "expert": "model",
        "expert_dmodel": data_axes if (cfg.fsdp and cfg.expert_fsdp) else None,
        "conv": None,
        "state": None,
        # activation axes
        "batch": data_axes,
        "act_seq": "model",  # sequence-sharded residual stream (SP)
        "act_heads": "model",
        "act_vocab": "model",
        "act_expert": "model",
        "cache_time": "model",  # time-sharded KV cache (kv_heads < TP archs)
    }


def batch_axes(mesh: MeshConfig):
    return ("pod", "data") if mesh.multi_pod else ("data",)
