from .base import SHAPES, MeshConfig, ModelConfig, RunConfig, batch_axes, sharding_rules
from .registry import ARCHS, SMOKES, cells, get_config
