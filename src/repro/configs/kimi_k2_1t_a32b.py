"""kimi-k2-1t-a32b [moe] -- Kimi K2, trillion-param MoE (paper-table)
[arXiv:2501.kimi2; unverified].
61L d_model=7168 64H (GQA kv=8) d_ff=2048 vocab=163840, MoE 384e top-8.

Memory policy for 1T params on v5e-16GB chips: bf16 params + Adafactor
(factored second moment); fp32 AdamW state for 1T params would need
~23GB/chip even fully sharded over 512 devices.
"""
import dataclasses

import jax.numpy as jnp

from .base import ModelConfig

ARCH_ID = "kimi-k2-1t-a32b"

CONFIG = ModelConfig(
    name=ARCH_ID,
    family="moe",
    n_layers=61,
    d_model=7168,
    n_heads=64,
    n_kv_heads=8,
    d_head=112,
    d_ff=2048,  # per the assignment table: expert hidden size
    vocab=163840,
    norm="rmsnorm",
    act="swiglu",
    rope_theta=50_000.0,
    moe_experts=384,
    moe_topk=8,
    moe_dff=2048,
    fsdp=True,
    param_dtype=jnp.bfloat16,
    optimizer="adafactor",
)

SMOKE = dataclasses.replace(
    CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_head=16,
    d_ff=64, vocab=256, moe_experts=4, moe_topk=2, moe_dff=64,
    attn_chunk=32, fsdp=False, param_dtype=jnp.float32, optimizer="adamw",
)
