"""--arch registry: maps assigned architecture ids to their configs."""
from __future__ import annotations

from . import (
    internvl2_2b,
    jamba_v0_1_52b,
    kimi_k2_1t_a32b,
    llama3_2_3b,
    moonshot_v1_16b_a3b,
    qwen2_72b,
    rwkv6_7b,
    starcoder2_7b,
    tinyllama_1_1b,
    whisper_medium,
)
from .base import SHAPES, MeshConfig, ModelConfig, RunConfig

_MODULES = (
    llama3_2_3b,
    qwen2_72b,
    starcoder2_7b,
    tinyllama_1_1b,
    moonshot_v1_16b_a3b,
    kimi_k2_1t_a32b,
    whisper_medium,
    internvl2_2b,
    jamba_v0_1_52b,
    rwkv6_7b,
)

ARCHS: dict[str, ModelConfig] = {m.ARCH_ID: m.CONFIG for m in _MODULES}
SMOKES: dict[str, ModelConfig] = {m.ARCH_ID: m.SMOKE for m in _MODULES}


def get_config(arch: str, smoke: bool = False) -> ModelConfig:
    table = SMOKES if smoke else ARCHS
    if arch not in table:
        raise KeyError(f"unknown --arch {arch!r}; known: {sorted(table)}")
    return table[arch]


def cells(include_skipped: bool = False):
    """All 40 (arch x shape) cells; skipped cells carry a reason string."""
    out = []
    for arch, cfg in ARCHS.items():
        for shape in SHAPES:
            skip = None
            if shape == "long_500k" and not cfg.is_subquadratic:
                skip = "SKIP(full-attention)"  # mandated skip, DESIGN.md §4
            if skip is None or include_skipped:
                out.append((arch, shape, skip))
    return out
