"""tinyllama-1.1b [dense] -- llama2-arch small [arXiv:2401.02385; hf].
22L d_model=2048 32H (GQA kv=4) d_ff=5632 vocab=32000."""
import dataclasses

from .base import ModelConfig

ARCH_ID = "tinyllama-1.1b"

CONFIG = ModelConfig(
    name=ARCH_ID,
    family="dense",
    n_layers=22,
    d_model=2048,
    n_heads=32,
    n_kv_heads=4,
    d_head=64,
    d_ff=5632,
    vocab=32000,
    norm="rmsnorm",
    act="swiglu",
    rope_theta=10_000.0,
)

SMOKE = dataclasses.replace(
    CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_head=16,
    d_ff=128, vocab=256, attn_chunk=32,
)
