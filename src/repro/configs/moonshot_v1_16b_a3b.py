"""moonshot-v1-16b-a3b [moe] -- kimi/moonlight, 64e top-6
[hf:moonshotai/Moonlight-16B-A3B; hf].
48L d_model=2048 16H (GQA kv=16) d_ff=1408 vocab=163840, MoE 64e top-6."""
import dataclasses

from .base import ModelConfig

ARCH_ID = "moonshot-v1-16b-a3b"

CONFIG = ModelConfig(
    name=ARCH_ID,
    family="moe",
    n_layers=48,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_head=128,
    d_ff=1408,  # per the assignment table: expert hidden size
    vocab=163840,
    norm="rmsnorm",
    act="swiglu",
    rope_theta=50_000.0,
    moe_experts=64,
    moe_topk=6,
    moe_dff=1408,
    fsdp=True,
)

SMOKE = dataclasses.replace(
    CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_head=16,
    d_ff=64, vocab=256, moe_experts=4, moe_topk=2, moe_dff=64,
    attn_chunk=32, fsdp=False,
)
