"""rwkv6-7b [ssm] -- Finch, data-dependent decay [arXiv:2404.05892; hf].
32L d_model=4096 (attn-free) d_ff=14336 vocab=65536.
Sub-quadratic: runs long_500k (O(1) recurrent state per layer)."""
import dataclasses

from .base import ModelConfig

ARCH_ID = "rwkv6-7b"

CONFIG = ModelConfig(
    name=ARCH_ID,
    family="ssm",
    n_layers=32,
    d_model=4096,
    n_heads=64,  # wkv heads = d_model / rwkv_head_size
    n_kv_heads=64,
    d_head=64,
    d_ff=14336,
    vocab=65536,
    norm="layernorm",
    act="gelu",  # unused by the rwkv channel-mix (relu^2), kept for config parity
    rwkv_head_size=64,
    fsdp=True,
)

SMOKE = dataclasses.replace(
    CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_head=16,
    d_ff=128, vocab=256, rwkv_head_size=16, fsdp=False,
)
