from .chunkstore import ChunkRef, ChunkStore, FileMeta
from .pipeline import PipelineState, TokenPipeline, synthetic_store
