"""HDFS-inspired chunk store: the data-pipeline substrate the paper's
workloads run against.

Files are split into block-sized chunks (64MB default, exactly HDFS; the
paper notes "we change the filesystem installation parameters" to use
non-default block sizes -- ``block_bytes`` is that knob). Reads are issued
in request-size (RS) units, so every consumer of this store *is* a paper
workload characterized by (FS=block_bytes, RS=read_bytes) -- which is how
the training input pipeline below plugs into the consolidation scheduler:
host-side input workers are admitted onto shared input hosts by the same
greedy algorithm that placed the paper's TestDFSIO tasks.

The store is deterministic-synthetic: chunk payloads are generated from
(file_id, chunk_id) seeds, so multi-host loaders need no shared filesystem
and restarts are reproducible (the fault-tolerance story needs replayable
input).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from ..core.workload import Workload

MB = 1024 * 1024


@dataclasses.dataclass(frozen=True)
class FileMeta:
    file_id: int
    size: int  # bytes

    def n_chunks(self, block_bytes: int) -> int:
        return -(-self.size // block_bytes)


@dataclasses.dataclass(frozen=True)
class ChunkRef:
    file_id: int
    chunk_id: int
    size: int


class ChunkStore:
    """Deterministic block store: files -> 64MB chunks -> RS-sized reads."""

    def __init__(self, files: list[FileMeta], block_bytes: int = 64 * MB,
                 replication: int = 3, n_datanodes: int = 16):
        self.files = {f.file_id: f for f in files}
        self.block_bytes = block_bytes
        self.replication = replication
        self.n_datanodes = n_datanodes

    # --- namenode-ish metadata ------------------------------------------
    def chunks(self, file_id: int) -> list[ChunkRef]:
        f = self.files[file_id]
        out = []
        for c in range(f.n_chunks(self.block_bytes)):
            size = min(self.block_bytes, f.size - c * self.block_bytes)
            out.append(ChunkRef(file_id, c, size))
        return out

    def replicas(self, ref: ChunkRef) -> list[int]:
        """Datanodes holding a chunk (rendezvous placement, deterministic)."""
        scores = [
            (hash((ref.file_id, ref.chunk_id, dn)) & 0xFFFFFFFF, dn)
            for dn in range(self.n_datanodes)
        ]
        return [dn for _, dn in sorted(scores)[: self.replication]]

    # --- datanode-ish reads ------------------------------------------------
    def read(self, ref: ChunkRef, offset: int, nbytes: int) -> np.ndarray:
        """Read ``nbytes`` at ``offset`` within a chunk (one RS-sized request)."""
        nbytes = min(nbytes, ref.size - offset)
        if nbytes <= 0:
            return np.zeros(0, np.uint8)
        # deterministic payload: cheap counter-based PRNG on 8-byte words
        word0 = offset // 8
        nwords = -(-(offset % 8 + nbytes) // 8) + 1
        idx = (np.arange(word0, word0 + nwords, dtype=np.uint64)
               + np.uint64(ref.file_id) * np.uint64(0x9E3779B97F4A7C15)
               + np.uint64(ref.chunk_id) * np.uint64(0xBF58476D1CE4E5B9))
        x = idx * np.uint64(0x94D049BB133111EB)
        x ^= x >> np.uint64(31)
        raw = x.view(np.uint8)
        start = offset % 8
        return raw[start : start + nbytes]

    def read_chunk(self, ref: ChunkRef, request_bytes: int) -> np.ndarray:
        """Full chunk via RS-sized requests -> the paper's (FS, RS) access."""
        parts = [
            self.read(ref, off, request_bytes)
            for off in range(0, ref.size, request_bytes)
        ]
        return np.concatenate(parts) if parts else np.zeros(0, np.uint8)

    def as_workload(self, request_bytes: int, op: str = "read") -> Workload:
        """Characterize one loader task on this store (paper C1)."""
        return Workload(fs=float(self.block_bytes), rs=float(request_bytes), op=op)
