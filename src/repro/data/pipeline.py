"""Sharded, double-buffered training input pipeline over the chunk store.

Each data-parallel rank owns a disjoint chunk stream (rank-strided), converts
chunk bytes to token ids deterministically, and prefetches batches on a
background thread (the host-side "system file cache" tier of the paper's
hierarchy -- the prefetch depth plays the role of the write-back/read-ahead
buffer, and its RS/FS characterization feeds the consolidation scheduler via
``ChunkStore.as_workload``).

Determinism/fault tolerance: the stream position is a pure function of
(epoch, step, rank), checkpointed as two ints -- restart resumes exactly.
"""
from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Iterator

import numpy as np

from .chunkstore import ChunkStore, FileMeta

MB = 1024 * 1024


@dataclasses.dataclass
class PipelineState:
    epoch: int = 0
    step: int = 0


class TokenPipeline:
    def __init__(
        self,
        store: ChunkStore,
        *,
        vocab: int,
        batch: int,  # per-rank batch
        seq_len: int,
        rank: int = 0,
        world: int = 1,
        request_bytes: int = 256 * 1024,
        prefetch: int = 2,
        labels: bool = True,
    ):
        self.store = store
        self.vocab = vocab
        self.batch = batch
        self.seq_len = seq_len
        self.rank = rank
        self.world = world
        self.request_bytes = request_bytes
        self.prefetch = prefetch
        self.labels = labels
        self.state = PipelineState()
        self._all_chunks = [c for f in store.files.values() for c in store.chunks(f.file_id)]
        self._q: queue.Queue = queue.Queue(maxsize=prefetch)
        self._thread: threading.Thread | None = None
        self._stop = threading.Event()

    # --- deterministic batch synthesis -----------------------------------
    def _batch_at(self, epoch: int, step: int) -> dict[str, np.ndarray]:
        need = self.batch * (self.seq_len + 1)
        # rank-strided chunk selection
        idx = (step * self.world + self.rank + epoch * 7919) % len(self._all_chunks)
        ref = self._all_chunks[idx]
        raw = self.store.read_chunk(ref, self.request_bytes)
        if raw.size < need * 4:
            reps = -(-need * 4 // max(raw.size, 1))
            raw = np.tile(raw, reps)
        words = raw[: need * 4].view(np.uint32).astype(np.int64)
        toks = (words % self.vocab).astype(np.int32).reshape(self.batch, self.seq_len + 1)
        out = {"tokens": toks[:, :-1]}
        if self.labels:
            out["labels"] = toks[:, 1:]
        return out

    # --- prefetch thread -----------------------------------------------------
    def _worker(self):
        epoch, step = self.state.epoch, self.state.step
        while not self._stop.is_set():
            b = self._batch_at(epoch, step)
            step += 1
            if step * self.world >= len(self._all_chunks):
                epoch, step = epoch + 1, 0
            while not self._stop.is_set():
                try:
                    self._q.put((epoch, step, b), timeout=0.1)
                    break
                except queue.Full:
                    continue

    def start(self):
        if self._thread is None:
            self._stop.clear()
            self._thread = threading.Thread(target=self._worker, daemon=True)
            self._thread.start()
        return self

    def stop(self):
        self._stop.set()
        if self._thread is not None:
            self._thread.join(timeout=2.0)
            self._thread = None
        while not self._q.empty():
            self._q.get_nowait()

    def __iter__(self) -> Iterator[dict[str, np.ndarray]]:
        return self

    def __next__(self) -> dict[str, np.ndarray]:
        if self._thread is None:
            b = self._batch_at(self.state.epoch, self.state.step)
            self.state.step += 1
            return b
        epoch, step, b = self._q.get()
        self.state.epoch, self.state.step = epoch, step
        return b

    # --- checkpoint integration ---------------------------------------------
    def state_dict(self) -> dict:
        return {"epoch": self.state.epoch, "step": self.state.step}

    def load_state_dict(self, d: dict):
        was_running = self._thread is not None
        self.stop()
        self.state = PipelineState(int(d["epoch"]), int(d["step"]))
        if was_running:
            self.start()


def synthetic_store(n_files: int = 4, file_mb: int = 256, block_mb: int = 64,
                    **kw) -> ChunkStore:
    files = [FileMeta(i, file_mb * MB) for i in range(n_files)]
    return ChunkStore(files, block_bytes=block_mb * MB, **kw)
