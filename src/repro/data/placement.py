"""Consolidation-aware input-pipeline placement: the paper's algorithm
applied to its *original* domain inside this framework -- deciding which
input hosts run which data-loading workers.

Each TokenPipeline rank is a data-intensive workload characterized exactly
as the paper prescribes (FS = chunk size, RS = request size, op = read);
input hosts are ServerSpec bins. The same greedy that packs TestDFSIO tasks
admits loader ranks so that no host's loaders degrade past 50% -- which is
precisely the condition under which the training job's input pipeline stops
being able to hide behind compute.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from ..core.binpack import ClusterState, greedy_sequence
from ..core.contention import profile_pairwise_fast
from ..core.server import ServerSpec
from ..core.workload import Workload, snap_to_grid
from .chunkstore import ChunkStore


@dataclasses.dataclass(frozen=True)
class LoaderPlacement:
    rank: int
    host: int | None  # None = queued (input fleet saturated)


def place_loaders(
    store: ChunkStore,
    n_ranks: int,
    hosts: list[ServerSpec],
    request_bytes: int = 256 * 1024,
    alpha: float = 1.3,
) -> tuple[list[LoaderPlacement], ClusterState]:
    """Pack ``n_ranks`` loader workers onto input hosts with the Fig-8 greedy."""
    D = [profile_pairwise_fast(h) for h in hosts]
    state = ClusterState.empty(hosts, D, alpha=alpha)
    w = snap_to_grid(store.as_workload(request_bytes))
    placements, _ = greedy_sequence(state, [w] * n_ranks)
    return [LoaderPlacement(r, p) for r, p in enumerate(placements)], state


def max_safe_ranks_per_host(
    store: ChunkStore, host: ServerSpec, request_bytes: int = 256 * 1024,
    alpha: float = 1.3,
) -> int:
    """Criterion-1 capacity: how many loader ranks one host sustains <50%."""
    D = [profile_pairwise_fast(host)]
    state = ClusterState.empty([host], D, alpha=alpha)
    w = snap_to_grid(store.as_workload(request_bytes))
    placements, _ = greedy_sequence(state, [w] * 64)
    return sum(1 for p in placements if p is not None)
