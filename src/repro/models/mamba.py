"""Mamba (S6) selective-SSM block, used by the Jamba hybrid (arXiv:2403.19887).

The selective scan is sequential over time with a small carried state
[B, d_inner, d_state]; matmul-heavy projections (in/out/x/dt) sit outside the
scan and dominate FLOPs (>99% -- the scan body is elementwise), so the
lax.scan time loop is the right production form and the cost-extrapolation
undercount of the scan body is negligible (documented in EXPERIMENTS.md).
The Pallas kernel (kernels/mamba_scan.py) is the TPU-optimized chunked form.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from . import layers as L
from .params import ParamInfo


def dims(cfg) -> tuple[int, int, int]:
    d_inner = cfg.mamba_expand * cfg.d_model
    dt_rank = math.ceil(cfg.d_model / 16)
    return d_inner, dt_rank, cfg.mamba_dstate


def layer_infos(cfg) -> dict:
    D = cfg.d_model
    d_inner, dt_rank, d_state = dims(cfg)
    K = cfg.mamba_dconv
    return {
        "in_proj": ParamInfo((D, 2, d_inner), ("dmodel", None, "mlp")),
        "conv_w": ParamInfo((K, d_inner), ("conv", "mlp"), "small"),
        "conv_b": ParamInfo((d_inner,), ("mlp",), "zeros"),
        "x_proj": ParamInfo((d_inner, dt_rank + 2 * d_state), ("mlp", None)),
        "dt_proj": ParamInfo((dt_rank, d_inner), (None, "mlp")),
        "dt_bias": ParamInfo((d_inner,), ("mlp",), "small", scale=0.5),
        "a_log": ParamInfo((d_inner, d_state), ("mlp", "state"), "small", scale=0.5),
        "d_skip": ParamInfo((d_inner,), ("mlp",), "ones"),
        "out_proj": ParamInfo((d_inner, D), ("mlp", "dmodel")),
    }


def state_infos(cfg, batch: int) -> dict:
    d_inner, _, d_state = dims(cfg)
    return {
        "h": ParamInfo((batch, d_inner, d_state), ("batch", "mlp", None), "zeros"),
        "conv": ParamInfo(
            (batch, cfg.mamba_dconv - 1, d_inner), ("batch", None, "mlp"), "zeros",
            dtype=jnp.bfloat16,
        ),
    }


def _causal_conv(u: jax.Array, w: jax.Array, b: jax.Array, prev: jax.Array | None):
    """Depthwise causal conv over time. u: [B,S,E]; w: [K,E]. Returns (y, tail)."""
    K = w.shape[0]
    pad = (
        jnp.zeros((u.shape[0], K - 1, u.shape[2]), u.dtype)
        if prev is None
        else prev.astype(u.dtype)
    )
    up = jnp.concatenate([pad, u], axis=1)  # [B, S+K-1, E]
    y = sum(up[:, i : i + u.shape[1], :] * w[i][None, None] for i in range(K)) + b[None, None]
    return y, up[:, -(K - 1) :, :]


def apply(p: dict, x: jax.Array, cfg, state: dict | None):
    """Mamba block. x: [B,S,D]; state: {'h': [B,E,N] f32, 'conv': [B,K-1,E]} or None."""
    B, S, D = x.shape
    d_inner, dt_rank, d_state = dims(cfg)
    dt = cfg.compute_dtype

    uz = jnp.einsum("bsd,dce->bsce", x, p["in_proj"].astype(dt))
    uz = L.shard(uz, "batch", None, None, "act_heads")
    u, z = uz[..., 0, :], uz[..., 1, :]

    prev_conv = state["conv"] if state is not None else None
    u, conv_tail = _causal_conv(u, p["conv_w"].astype(dt), p["conv_b"].astype(dt), prev_conv)
    u = jax.nn.silu(u)

    xdbc = jnp.einsum("bse,er->bsr", u, p["x_proj"].astype(dt))
    dt_in, Bc, Cc = jnp.split(xdbc, [dt_rank, dt_rank + d_state], axis=-1)
    delta = jax.nn.softplus(
        jnp.einsum("bsr,re->bse", dt_in, p["dt_proj"].astype(dt)).astype(jnp.float32)
        + p["dt_bias"][None, None]
    )  # [B,S,E] fp32
    A = -jnp.exp(p["a_log"].astype(jnp.float32))  # [E,N]

    h0 = (
        state["h"].astype(jnp.float32)
        if state is not None
        else jnp.zeros((B, d_inner, d_state), jnp.float32)
    )

    # Two-level chunked selective scan. The [B,S,E,N] decay/input tensors are
    # NEVER materialized over the full sequence (at jamba-52b scale that is
    # >2GiB/device/layer and was the dominant temp buffer): each chunk
    # computes da/dbu on the fly from [B,c,E]-sized xs, and jax.checkpoint on
    # the chunk body bounds the backward save to one chunk + the per-chunk
    # carries (S/c states instead of S).
    c = 256 if S % 256 == 0 else S  # one chunk for short/odd sequences
    n = S // c
    uf = u.astype(jnp.float32)
    deltaf = delta  # [B,S,E] fp32
    Bf = Bc.astype(jnp.float32)
    Cf = Cc.astype(jnp.float32)

    def chunk_body(h, xs):
        d_c, u_c, b_c, c_c = xs  # [B,c,E], [B,c,E], [B,c,N], [B,c,N]
        da_c = jnp.exp(d_c[..., None] * A[None, None])  # [B,c,E,N]
        dbu_c = (d_c * u_c)[..., None] * b_c[:, :, None, :]

        def step(hh, t):
            hh = da_c[:, t] * hh + dbu_c[:, t]
            return hh, jnp.einsum("ben,bn->be", hh, c_c[:, t])

        h, ys = jax.lax.scan(step, h, jnp.arange(c))
        return h, ys  # ys: [c, B, E]

    split = lambda x: x.reshape(B, n, c, *x.shape[2:]).transpose(1, 0, 2, *range(3, x.ndim + 1))
    xs = (split(deltaf), split(uf), split(Bf), split(Cf))
    hT, ys = jax.lax.scan(jax.checkpoint(chunk_body), h0, xs)
    y = ys.reshape(n, c, B, d_inner).transpose(2, 0, 1, 3).reshape(B, S, d_inner).astype(dt)
    y = y + u * p["d_skip"].astype(dt)[None, None]
    y = y * jax.nn.silu(z)
    out = jnp.einsum("bse,ed->bsd", y, p["out_proj"].astype(dt))
    new_state = {"h": hT, "conv": conv_tail.astype(jnp.bfloat16)}
    return L.shard(out, "batch", None, None), new_state
