"""Uniform model API over the zoo families.

``build_model(cfg)`` returns a :class:`Model` whose methods cover the three
step kinds every (arch x shape) cell needs:

  loss(params, batch)                 -- training objective (train_4k)
  prefill(params, batch, cache)       -- fill a KV/state cache (prefill_32k)
  decode_step(params, cache, tokens)  -- one new token (decode_32k / long_500k)

plus declaration helpers (param_infos / cache_infos / input_specs) used by
the launcher and the multi-pod dry-run (ShapeDtypeStructs only).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

from ..configs.base import SHAPES, ModelConfig
from . import encdec, hybrid, rwkv, transformer
from .params import ParamInfo
from .transformer import cross_entropy

#: extra cache rows beyond the nominal context (decode writes at len)
CACHE_PAD = 128


def _apply_param_dtype(infos, cfg):
    """Store big weight matrices in cfg.param_dtype (bf16 for the 1T kimi);
    norms/biases/small vectors stay fp32 for numerical safety."""
    def cast(i: ParamInfo) -> ParamInfo:
        if i.init in ("normal", "embed") and len(i.shape) >= 2:
            return dataclasses.replace(i, dtype=cfg.param_dtype)
        return i

    return jax.tree_util.tree_map(cast, infos, is_leaf=lambda x: isinstance(x, ParamInfo))


@dataclasses.dataclass(frozen=True)
class Model:
    cfg: ModelConfig
    _infos: Callable[[], Any]
    _forward: Callable[..., Any]  # (params, tokens, cache, extras, last_only)
    _cache_infos: Callable[[int, int], Any]

    # --- declarations -------------------------------------------------------
    def param_infos(self):
        return _apply_param_dtype(self._infos(), self.cfg)

    def cache_infos(self, batch: int, max_len: int):
        return self._cache_infos(batch, max_len + CACHE_PAD)

    def input_specs(self, shape: str, kind: str | None = None) -> dict:
        """ShapeDtypeStruct stand-ins for one assigned input shape."""
        info = SHAPES[shape]
        kind = kind or info["kind"]
        B, S = info["global_batch"], info["seq_len"]
        cfg = self.cfg
        i32, emb = jnp.int32, cfg.compute_dtype
        if kind == "train":
            spec = {}
            s_text = S
            if cfg.family == "vlm":
                s_text = S - cfg.vis_tokens
                spec["vis_embeds"] = jax.ShapeDtypeStruct((B, cfg.vis_tokens, cfg.d_model), emb)
            if cfg.family == "encdec":
                spec["audio_embeds"] = jax.ShapeDtypeStruct((B, cfg.enc_seq, cfg.d_model), emb)
            spec["tokens"] = jax.ShapeDtypeStruct((B, s_text), i32)
            spec["labels"] = jax.ShapeDtypeStruct((B, s_text), i32)
            return spec
        if kind == "prefill":
            spec = {"tokens": jax.ShapeDtypeStruct((B, S), i32)}
            if cfg.family == "vlm":
                spec["tokens"] = jax.ShapeDtypeStruct((B, S - cfg.vis_tokens), i32)
                spec["vis_embeds"] = jax.ShapeDtypeStruct((B, cfg.vis_tokens, cfg.d_model), emb)
            if cfg.family == "encdec":
                spec["audio_embeds"] = jax.ShapeDtypeStruct((B, cfg.enc_seq, cfg.d_model), emb)
            return spec
        if kind == "decode":
            return {"tokens": jax.ShapeDtypeStruct((B, 1), i32)}
        raise ValueError(kind)

    # --- steps -------------------------------------------------------------
    def head_matrix(self, params):
        if self.cfg.family in ("dense", "moe", "vlm") and self.cfg.tie_embeddings:
            return params["embed"].T
        return params["lm_head"]

    def loss(self, params, batch: dict):
        """Training objective via final hidden states + seq-chunked CE
        (the [B,S,V] logits never materialize whole; see
        transformer.chunked_cross_entropy)."""
        extras = {k: v for k, v in batch.items() if k not in ("tokens", "labels")}
        hidden, _ = self._forward(
            params, batch["tokens"], None, extras, False, return_hidden=True
        )
        if self.cfg.family == "vlm" and "vis_embeds" in batch:
            hidden = hidden[:, batch["vis_embeds"].shape[1]:, :]
        return transformer.chunked_cross_entropy(
            hidden, self.head_matrix(params), batch["labels"], self.cfg.vocab, self.cfg
        )

    def prefill(self, params, batch: dict, cache):
        extras = {k: v for k, v in batch.items() if k != "tokens"}
        tokens = batch["tokens"]
        if self.cfg.family == "encdec":
            enc_out = encdec.encode(params, self.cfg, batch["audio_embeds"])
            cache = encdec.fill_cross_kv(params, self.cfg, cache, enc_out)
            logits, cache = encdec.decode(
                params, self.cfg, tokens, cache=cache, last_only=True
            )
            return logits, cache
        logits, cache = self._forward(params, tokens, cache, extras, True)
        return logits, cache

    def decode_step(self, params, cache, tokens):
        logits, cache = self._forward(params, tokens, cache, {}, True)
        return logits, cache


def _fw_transformer(cfg):
    def fw(params, tokens, cache, extras, last_only, return_hidden=False):
        return transformer.forward(
            params, cfg, tokens,
            prefix_embeds=extras.get("vis_embeds"), cache=cache,
            last_only=last_only, return_hidden=return_hidden,
        )
    return fw


def _fw_rwkv(cfg):
    def fw(params, tokens, cache, extras, last_only, return_hidden=False):
        return rwkv.forward(params, cfg, tokens, cache=cache, last_only=last_only,
                            return_hidden=return_hidden)
    return fw


def _fw_hybrid(cfg):
    def fw(params, tokens, cache, extras, last_only, return_hidden=False):
        return hybrid.forward(params, cfg, tokens, cache=cache, last_only=last_only,
                              return_hidden=return_hidden)
    return fw


def _fw_encdec(cfg):
    def fw(params, tokens, cache, extras, last_only, return_hidden=False):
        if cache is None:  # teacher-forcing training path
            return encdec.forward(
                params, cfg, tokens, audio_embeds=extras["audio_embeds"],
                last_only=last_only, return_hidden=return_hidden,
            )
        return encdec.decode(params, cfg, tokens, cache=cache, last_only=last_only,
                             return_hidden=return_hidden)
    return fw


def build_model(cfg: ModelConfig) -> Model:
    fam = cfg.family
    if fam in ("dense", "moe", "vlm"):
        return Model(cfg, lambda: transformer.lm_infos(cfg), _fw_transformer(cfg),
                     lambda b, m: transformer.cache_infos(cfg, b, m))
    if fam == "ssm":
        return Model(cfg, lambda: rwkv.lm_infos(cfg), _fw_rwkv(cfg),
                     lambda b, m: rwkv.cache_infos(cfg, b, m))
    if fam == "hybrid":
        return Model(cfg, lambda: hybrid.lm_infos(cfg), _fw_hybrid(cfg),
                     lambda b, m: hybrid.cache_infos(cfg, b, m))
    if fam == "encdec":
        return Model(cfg, lambda: encdec.lm_infos(cfg), _fw_encdec(cfg),
                     lambda b, m: encdec.cache_infos(cfg, b, m))
    raise ValueError(f"unknown family {fam!r}")
