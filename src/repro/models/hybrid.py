"""Jamba-style hybrid (arXiv:2403.19887): Mamba + attention at 1:7 interleave,
MoE every other layer. Assigned arch: jamba-v0.1-52b.

Layer pattern (period 8, matching Jamba): sub-layer i of each period runs
attention if i == attn_offset (default 4 -> 1 attention per 8 layers, the
paper's 1:7 ratio), Mamba otherwise; the FFN is MoE on odd sub-layers and
dense on even ones (16 experts, top-2).

Heterogeneous layers cannot share one scanned body, so we scan over
*periods* (n_layers/8 of them) with the 8 distinct sub-layer bodies unrolled
inside -- compile cost is 8 layer bodies regardless of depth.

Sub-quadratic: this arch runs long_500k. Attention sub-layers use a sliding
window (cfg.sliding_window, 32k) inside the 500k stream -- documented
deviation: Jamba itself caps attention context; Mamba carries the long-range
state.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from . import layers as L
from . import mamba
from .params import ParamInfo, stack_layers
from .transformer import cross_entropy

PERIOD = 8


def _sub_infos(cfg, i: int) -> dict:
    d = {"ln1": L.norm_infos(cfg), "ln2": L.norm_infos(cfg)}
    if i % cfg.attn_every == cfg.attn_offset:
        d["attn"] = L.attention_infos(cfg)
    else:
        d["mamba"] = mamba.layer_infos(cfg)
    if cfg.moe_experts and i % cfg.moe_every == cfg.moe_every - 1:
        d["moe"] = L.moe_infos(cfg)
    else:
        d["mlp"] = L.mlp_infos(cfg)
    return d


def period_infos(cfg) -> dict:
    return {f"sub{i}": _sub_infos(cfg, i) for i in range(PERIOD)}


def lm_infos(cfg) -> dict:
    assert cfg.n_layers % PERIOD == 0, "hybrid depth must be a multiple of 8"
    vp = L.padded_vocab(cfg.vocab)
    return {
        "embed": ParamInfo((vp, cfg.d_model), ("vocab", "dmodel"), "embed", scale=0.02),
        "periods": stack_layers(cfg.n_layers // PERIOD, period_infos(cfg)),
        "ln_f": L.norm_infos(cfg),
        "lm_head": ParamInfo((cfg.d_model, vp), ("dmodel", "vocab")),
    }


def cache_infos(cfg, batch: int, max_len: int) -> dict:
    n_p = cfg.n_layers // PERIOD
    n_attn = sum(1 for i in range(PERIOD) if i % cfg.attn_every == cfg.attn_offset)
    n_mamba = PERIOD - n_attn
    d_inner, _, d_state = mamba.dims(cfg)
    kv_axes = (("layer", None, "batch", "cache_time", None, None)
               if cfg.kv_cache_time_sharded
               else ("layer", None, "batch", None, "kv_heads", None))
    kv = ParamInfo(
        (n_p, n_attn, batch, max_len, cfg.n_kv_heads, cfg.d_head),
        kv_axes, "zeros", dtype=jnp.bfloat16,
    )
    return {
        "k": kv,
        "v": kv,
        "h": ParamInfo((n_p, n_mamba, batch, d_inner, d_state),
                       ("layer", None, "batch", "mlp", None), "zeros"),
        "conv": ParamInfo((n_p, n_mamba, batch, cfg.mamba_dconv - 1, d_inner),
                          ("layer", None, "batch", None, "mlp"), "zeros", dtype=jnp.bfloat16),
        "len": ParamInfo((), (), "zeros", dtype=jnp.int32),
    }


def _period_apply(pp: dict, x: jax.Array, cfg, *, positions, pcache, group):
    """Run the 8 heterogeneous sub-layers of one period."""
    new_kv, new_ssm = [], []
    ai = mi = 0
    for i in range(PERIOD):
        p = pp[f"sub{i}"]
        h = L.norm_apply(p["ln1"], x, cfg)
        if "attn" in p:
            cache_i = None
            if pcache is not None:
                cache_i = {"k": pcache["k"][ai], "v": pcache["v"][ai], "len": pcache["len"]}
            a, nc = L.attention_apply(
                p["attn"], h, cfg, positions=positions, cache=cache_i,
                window=cfg.sliding_window,
            )
            if pcache is not None:
                new_kv.append((nc["k"], nc["v"]))
            ai += 1
        else:
            st = None
            if pcache is not None:
                st = {"h": pcache["h"][mi], "conv": pcache["conv"][mi]}
            a, ns = mamba.apply(p["mamba"], h, cfg, st)
            if pcache is not None:
                new_ssm.append((ns["h"], ns["conv"]))
            mi += 1
        x = x + a
        h = L.norm_apply(p["ln2"], x, cfg)
        f = L.moe_apply(p["moe"], h, cfg, group=group) if "moe" in p else L.mlp_apply(p["mlp"], h, cfg)
        x = x + f
    if pcache is None:
        return x, None
    return x, {
        "k": jnp.stack([kv[0] for kv in new_kv]),
        "v": jnp.stack([kv[1] for kv in new_kv]),
        "h": jnp.stack([s[0] for s in new_ssm]),
        "conv": jnp.stack([s[1] for s in new_ssm]),
    }


def forward(params: dict, cfg, tokens: jax.Array, *, cache: dict | None = None,
            prefix_embeds=None, last_only: bool = False, return_hidden: bool = False):
    dt = cfg.compute_dtype
    x = L.shard(L.sharded_embed(params["embed"], tokens, cfg), "batch", None, None)
    S = x.shape[1]
    offset = cache["len"] if cache is not None else 0
    positions = offset + jnp.arange(S)
    group = "batch" if S == 1 else "seq"

    if cache is None:

        def body(h, pp):
            h2, _ = _period_apply(pp, h, cfg, positions=positions, pcache=None, group=group)
            return h2, None

        if cfg.remat == "layer":
            body = jax.checkpoint(body)
        if cfg.scan_layers:
            x, _ = jax.lax.scan(body, x, params["periods"])
        else:
            for i in range(cfg.n_layers // PERIOD):
                x, _ = body(x, jax.tree_util.tree_map(lambda a: a[i], params["periods"]))
        new_cache = None
    else:

        def body(h, xs):
            pp, k, v, hh, conv = xs
            pc = {"k": k, "v": v, "h": hh, "conv": conv, "len": cache["len"]}
            h2, nc = _period_apply(pp, h, cfg, positions=positions, pcache=pc, group=group)
            return h2, (nc["k"], nc["v"], nc["h"], nc["conv"])

        xs = (params["periods"], cache["k"], cache["v"], cache["h"], cache["conv"])
        if cfg.scan_layers:
            x, outs = jax.lax.scan(body, x, xs)
        else:
            acc = []
            for i in range(cfg.n_layers // PERIOD):
                x, o = body(x, jax.tree_util.tree_map(lambda a: a[i], xs))
                acc.append(o)
            outs = tuple(jnp.stack([a[j] for a in acc]) for j in range(4))
        new_cache = {"k": outs[0], "v": outs[1], "h": outs[2], "conv": outs[3],
                     "len": cache["len"] + S}

    x = L.norm_apply(params["ln_f"], x, cfg)
    if last_only:
        x = x[:, -1:, :]
    if return_hidden:
        return x, new_cache
    logits = jnp.einsum("bsd,dv->bsv", x, params["lm_head"].astype(dt))
    logits = L.mask_padded_logits(logits, cfg.vocab)
    return L.shard(logits, "batch", None, "act_vocab"), new_cache
