"""Decoder-only transformer LM: dense (llama/qwen/starcoder/tinyllama),
MoE (moonshot/kimi), and VLM-backbone (internvl) families.

Layers are stacked with ``jax.lax.scan`` over a leading layer axis so an
80-layer model compiles one layer body (critical for 512-device dry-run
compile times). Per-layer KV caches are stacked the same way and scanned
jointly with the layer parameters.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from . import layers as L
from .params import ParamInfo, stack_layers


def _is_moe_layer(cfg, _layer: int) -> bool:
    return cfg.moe_experts > 0  # uniform pattern for transformer families


def layer_infos(cfg) -> dict:
    d = {
        "ln1": L.norm_infos(cfg),
        "attn": L.attention_infos(cfg),
        "ln2": L.norm_infos(cfg),
    }
    if cfg.moe_experts:
        d["moe"] = L.moe_infos(cfg)
    else:
        d["mlp"] = L.mlp_infos(cfg)
    return d


def lm_infos(cfg) -> dict:
    vp = L.padded_vocab(cfg.vocab)
    d = {
        "embed": ParamInfo((vp, cfg.d_model), ("vocab", "dmodel"), "embed", scale=0.02),
        "layers": stack_layers(cfg.n_layers, layer_infos(cfg)),
        "ln_f": L.norm_infos(cfg),
    }
    if not cfg.tie_embeddings:
        d["lm_head"] = ParamInfo((cfg.d_model, vp), ("dmodel", "vocab"))
    return d


def kv_cache_axes(cfg) -> tuple:
    if cfg.kv_cache_time_sharded:
        return ("layer", "batch", "cache_time", None, None)
    return ("layer", "batch", None, "kv_heads", None)


def cache_infos(cfg, batch: int, max_len: int) -> dict:
    Hkv, dh = cfg.n_kv_heads, cfg.d_head
    kv_dtype = jnp.int8 if cfg.kv_cache_dtype == "int8" else jnp.bfloat16
    kv = ParamInfo(
        (cfg.n_layers, batch, max_len, Hkv, dh),
        kv_cache_axes(cfg),
        "zeros",
        dtype=kv_dtype,
    )
    d = {"k": kv, "v": kv, "len": ParamInfo((), (), "zeros", dtype=jnp.int32)}
    if cfg.kv_cache_dtype == "int8":
        sc = ParamInfo((cfg.n_layers, batch, max_len, Hkv), kv_cache_axes(cfg)[:-1],
                       "zeros", dtype=jnp.bfloat16)
        d.update(k_scale=sc, v_scale=sc)
    return d


def _layer_apply(p: dict, x: jax.Array, cfg, *, positions, cache, group: str):
    h = L.norm_apply(p["ln1"], x, cfg)
    a, new_cache = L.attention_apply(
        p["attn"], h, cfg, positions=positions, cache=cache, window=cfg.sliding_window
    )
    x = L.shard(x + a, "batch", "act_seq", None)
    h = L.norm_apply(p["ln2"], x, cfg)
    if cfg.moe_experts:
        f = L.moe_apply(p["moe"], h, cfg, group=group)
    else:
        f = L.mlp_apply(p["mlp"], h, cfg)
    return L.shard(x + f, "batch", "act_seq", None), new_cache


def _embed(params: dict, cfg, tokens: jax.Array, prefix_embeds: jax.Array | None):
    dt = cfg.compute_dtype
    x = L.sharded_embed(params["embed"], tokens, cfg)
    if prefix_embeds is not None:
        x = jnp.concatenate([prefix_embeds.astype(dt), x], axis=1)
    # sequence-parallel residual stream (Megatron-SP): the scan carry -- and
    # therefore the per-layer saved activation under remat -- is sharded over
    # 'model' on the seq dim; TP blocks all-gather internally as needed.
    return L.shard(x, "batch", "act_seq", None)


def _unembed(params: dict, cfg, x: jax.Array) -> jax.Array:
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = jnp.einsum("bsd,dv->bsv", x, head.astype(cfg.compute_dtype))
    logits = L.mask_padded_logits(logits, cfg.vocab)
    return L.shard(logits, "batch", None, "act_vocab")


def forward(
    params: dict,
    cfg,
    tokens: jax.Array,  # [B, S]
    *,
    prefix_embeds: jax.Array | None = None,  # [B, P, D] (vlm patch embeddings)
    cache: dict | None = None,
    last_only: bool = False,
    return_hidden: bool = False,
) -> tuple[jax.Array, dict | None]:
    """Run the LM. With ``cache`` the call appends S tokens at cache['len'].

    Returns (logits, new_cache). Decode is this with S == 1.
    """
    x = _embed(params, cfg, tokens, prefix_embeds)
    S = x.shape[1]
    offset = cache["len"] if cache is not None else 0
    positions = offset + jnp.arange(S)
    group = "batch" if S == 1 else "seq"

    if cache is None:

        def body(h, lp):
            h2, _ = _layer_apply(lp, h, cfg, positions=positions, cache=None, group=group)
            return h2, None

        if cfg.remat == "layer":
            body = jax.checkpoint(body)
        if cfg.scan_layers:
            x, _ = jax.lax.scan(body, x, params["layers"])
        else:
            for i in range(cfg.n_layers):
                x, _ = body(x, jax.tree_util.tree_map(lambda a: a[i], params["layers"]))
        new_cache = None
    else:
        layer_cache = {k: v for k, v in cache.items() if k != "len"}

        def body(h, xs):
            lp, lc = xs
            h2, nc = _layer_apply(
                lp, h, cfg,
                positions=positions,
                cache=dict(lc, len=cache["len"]),
                group=group,
            )
            del nc["len"]
            return h2, nc

        if cfg.scan_layers:
            x, new_lc = jax.lax.scan(body, x, (params["layers"], layer_cache))
        else:
            outs = []
            for i in range(cfg.n_layers):
                sl = lambda a: a[i]
                x, nc = body(
                    x,
                    (jax.tree_util.tree_map(sl, params["layers"]),
                     jax.tree_util.tree_map(sl, layer_cache)),
                )
                outs.append(nc)
            new_lc = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *outs)
        new_cache = dict(new_lc, len=cache["len"] + S)

    x = L.norm_apply(params["ln_f"], x, cfg)
    if last_only:
        x = x[:, -1:, :]
    if return_hidden:
        return x, new_cache
    return _unembed(params, cfg, x), new_cache


# --- losses ------------------------------------------------------------------------

def chunked_cross_entropy(
    x: jax.Array,  # [B, S, D] final hidden states
    head: jax.Array,  # [D, Vp]
    labels: jax.Array,  # [B, S]
    true_vocab: int,
    cfg,
    n_chunks: int = 8,
    z_weight: float = 1e-4,
):
    """Unembed + CE scanned over sequence chunks with rematerialization.

    The [B, S, V] logits (and their fp32 CE intermediates) never materialize
    whole -- at qwen/kimi scale that is multiple GiB per device even sharded.
    Exact: per-chunk token sums are accumulated and normalized once.
    """
    B, S, D = x.shape
    if S % n_chunks != 0:
        n_chunks = 1
    c = S // n_chunks
    xs = x.reshape(B, n_chunks, c, D).transpose(1, 0, 2, 3)
    ls = labels.reshape(B, n_chunks, c).transpose(1, 0, 2)
    hd = head.astype(cfg.compute_dtype)

    def body(carry, inp):
        ce_sum, z_sum = carry
        xc, lc = inp
        logits = jnp.einsum("bsd,dv->bsv", xc, hd)
        logits = L.mask_padded_logits(logits, true_vocab)
        logits = L.shard(logits, "batch", None, "act_vocab")
        lg = logits.astype(jnp.float32)
        lse = jax.nn.logsumexp(lg, axis=-1)
        gold = jnp.take_along_axis(lg, lc[..., None], axis=-1)[..., 0]
        ce_sum = ce_sum + jnp.sum(lse - gold)
        z_sum = z_sum + z_weight * jnp.sum(lse**2)
        return (ce_sum, z_sum), None

    (ce_sum, z_sum), _ = jax.lax.scan(jax.checkpoint(body), (0.0, 0.0), (xs, ls))
    n = B * S
    return ce_sum / n + z_sum / n, {"ce": ce_sum / n, "zloss": z_sum / n}


def cross_entropy(logits: jax.Array, labels: jax.Array, z_weight: float = 1e-4):
    """Stable softmax cross-entropy in fp32 with z-loss; mean over tokens."""
    lg = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(lg, axis=-1)
    gold = jnp.take_along_axis(lg, labels[..., None], axis=-1)[..., 0]
    ce = lse - gold
    z = z_weight * (lse**2)
    return ce.mean() + z.mean(), {"ce": ce.mean(), "zloss": z.mean()}


def loss_fn(params: dict, cfg, batch: dict):
    """batch: tokens [B,S], labels [B,S], optional vis_embeds [B,P,D]."""
    prefix = batch.get("vis_embeds")
    logits, _ = forward(params, cfg, batch["tokens"], prefix_embeds=prefix)
    if prefix is not None:
        logits = logits[:, prefix.shape[1] :, :]  # loss on text positions only
    loss, metrics = cross_entropy(logits, batch["labels"])
    if cfg.moe_experts:  # router load-balancing on the embedded input
        x = _embed(params, cfg, batch["tokens"], prefix)
        # one router probe per scanned layer is overkill; probe layer 0
        p0 = jax.tree_util.tree_map(lambda a: a[0], params["layers"])
        aux = L.aux_load_balance_loss(p0["moe"], x, cfg)
        loss = loss + 0.01 * aux
        metrics["aux"] = aux
    return loss, metrics
