"""Whisper-style encoder-decoder backbone (arXiv:2212.04356).
Assigned arch: whisper-medium (24 enc + 24 dec layers, d_model=1024).

Per the assignment, the conv/mel frontend is a STUB: ``input_specs()``
provides precomputed frame embeddings [B, enc_seq, D]. The encoder runs
bidirectional attention over them with learned positions; the decoder is a
causal LM with cross-attention whose cross-K/V are computed once at prefill
and carried in the cache.

Deviation (documented): real Whisper uses learned decoder positions capped
at 448; the assigned decode shapes reach 32k tokens, so the decoder uses
RoPE instead of a 32k-row learned table.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from . import layers as L
from .params import ParamInfo, stack_layers
from .transformer import cross_entropy


def enc_layer_infos(cfg) -> dict:
    return {
        "ln1": L.norm_infos(cfg),
        "attn": L.attention_infos(cfg),
        "ln2": L.norm_infos(cfg),
        "mlp": L.mlp_infos(cfg),
    }


def dec_layer_infos(cfg) -> dict:
    return {
        "ln1": L.norm_infos(cfg),
        "self_attn": L.attention_infos(cfg),
        "ln_x": L.norm_infos(cfg),
        "cross_attn": L.attention_infos(cfg),
        "ln2": L.norm_infos(cfg),
        "mlp": L.mlp_infos(cfg),
    }


def lm_infos(cfg) -> dict:
    vp = L.padded_vocab(cfg.vocab)
    return {
        "embed": ParamInfo((vp, cfg.d_model), ("vocab", "dmodel"), "embed", scale=0.02),
        "enc_pos": ParamInfo((cfg.enc_seq, cfg.d_model), (None, "dmodel"), "small"),
        "enc_layers": stack_layers(cfg.enc_layers, enc_layer_infos(cfg)),
        "enc_ln_f": L.norm_infos(cfg),
        "dec_layers": stack_layers(cfg.n_layers, dec_layer_infos(cfg)),
        "ln_f": L.norm_infos(cfg),
        "lm_head": ParamInfo((cfg.d_model, vp), ("dmodel", "vocab")),
    }


def cache_infos(cfg, batch: int, max_len: int) -> dict:
    Hkv, dh = cfg.n_kv_heads, cfg.d_head
    from .transformer import kv_cache_axes
    kv = ParamInfo((cfg.n_layers, batch, max_len, Hkv, dh),
                   kv_cache_axes(cfg), "zeros", dtype=jnp.bfloat16)
    xkv = ParamInfo((cfg.n_layers, batch, cfg.enc_seq, Hkv, dh),
                    ("layer", "batch", None, "kv_heads", None), "zeros", dtype=jnp.bfloat16)
    return {"k": kv, "v": kv, "xk": xkv, "xv": xkv,
            "len": ParamInfo((), (), "zeros", dtype=jnp.int32)}


def encode(params: dict, cfg, audio_embeds: jax.Array) -> jax.Array:
    """Bidirectional encoder over precomputed frame embeddings [B, T_enc, D]."""
    dt = cfg.compute_dtype
    x = audio_embeds.astype(dt) + params["enc_pos"].astype(dt)[None]
    x = L.shard(x, "batch", None, None)
    positions = jnp.arange(x.shape[1])

    def body(h, lp):
        a, _ = L.attention_apply(
            lp["attn"], L.norm_apply(lp["ln1"], h, cfg), cfg,
            positions=positions, causal=False, rope_on=False,
        )
        h = h + a
        h = h + L.mlp_apply(lp["mlp"], L.norm_apply(lp["ln2"], h, cfg), cfg)
        return h, None

    if cfg.remat == "layer":
        body = jax.checkpoint(body)
    if cfg.scan_layers:
        x, _ = jax.lax.scan(body, x, params["enc_layers"])
    else:
        for i in range(cfg.enc_layers):
            x, _ = body(x, jax.tree_util.tree_map(lambda a: a[i], params["enc_layers"]))
    return L.norm_apply(params["enc_ln_f"], x, cfg)


def _dec_layer(p: dict, x: jax.Array, cfg, *, positions, cache, enc_kv):
    h = L.norm_apply(p["ln1"], x, cfg)
    a, new_cache = L.attention_apply(p["self_attn"], h, cfg, positions=positions, cache=cache)
    x = x + a
    h = L.norm_apply(p["ln_x"], x, cfg)
    x = x + L.cross_attention_apply(p["cross_attn"], h, cfg, enc_kv)
    h = L.norm_apply(p["ln2"], x, cfg)
    x = x + L.mlp_apply(p["mlp"], h, cfg)
    return x, new_cache


def decode(params: dict, cfg, tokens: jax.Array, *, enc_out: jax.Array | None = None,
           cache: dict | None = None, last_only: bool = False, return_hidden: bool = False):
    """Decoder pass. Training: pass enc_out, cache=None. Serving: cache holds
    the (precomputed) cross-K/V; enc_out is only needed at prefill time."""
    dt = cfg.compute_dtype
    x = L.shard(L.sharded_embed(params["embed"], tokens, cfg), "batch", None, None)
    S = x.shape[1]
    offset = cache["len"] if cache is not None else 0
    positions = offset + jnp.arange(S)

    if cache is None:
        assert enc_out is not None

        def body(h, lp):
            ekv = L.encoder_kv(lp["cross_attn"], enc_out, cfg)
            h2, _ = _dec_layer(lp, h, cfg, positions=positions, cache=None, enc_kv=ekv)
            return h2, None

        if cfg.remat == "layer":
            body = jax.checkpoint(body)
        if cfg.scan_layers:
            x, _ = jax.lax.scan(body, x, params["dec_layers"])
        else:
            for i in range(cfg.n_layers):
                x, _ = body(x, jax.tree_util.tree_map(lambda a: a[i], params["dec_layers"]))
        new_cache = None
    else:

        def body(h, xs):
            lp, ck, cv, xk, xv = xs
            h2, nc = _dec_layer(
                lp, h, cfg, positions=positions,
                cache={"k": ck, "v": cv, "len": cache["len"]},
                enc_kv=(xk.astype(dt), xv.astype(dt)),
            )
            return h2, (nc["k"], nc["v"])

        xs = (params["dec_layers"], cache["k"], cache["v"], cache["xk"], cache["xv"])
        if cfg.scan_layers:
            x, (nk, nv) = jax.lax.scan(body, x, xs)
        else:
            acc = []
            for i in range(cfg.n_layers):
                x, o = body(x, jax.tree_util.tree_map(lambda a: a[i], xs))
                acc.append(o)
            nk, nv = jnp.stack([a[0] for a in acc]), jnp.stack([a[1] for a in acc])
        new_cache = dict(cache, k=nk, v=nv, len=cache["len"] + S)

    x = L.norm_apply(params["ln_f"], x, cfg)
    if last_only:
        x = x[:, -1:, :]
    if return_hidden:
        return x, new_cache
    logits = jnp.einsum("bsd,dv->bsv", x, params["lm_head"].astype(dt))
    logits = L.mask_padded_logits(logits, cfg.vocab)
    return L.shard(logits, "batch", None, "act_vocab"), new_cache


def fill_cross_kv(params: dict, cfg, cache: dict, enc_out: jax.Array) -> dict:
    """Populate the cache's cross-K/V from the encoder output (prefill step)."""

    def per_layer(lp):
        k, v = L.encoder_kv(lp["cross_attn"], enc_out, cfg)
        return k.astype(jnp.bfloat16), v.astype(jnp.bfloat16)

    if cfg.scan_layers:
        xk, xv = jax.lax.map(per_layer, params["dec_layers"])
    else:
        outs = [per_layer(jax.tree_util.tree_map(lambda a: a[i], params["dec_layers"]))
                for i in range(cfg.n_layers)]
        xk = jnp.stack([o[0] for o in outs])
        xv = jnp.stack([o[1] for o in outs])
    return dict(cache, xk=xk, xv=xv)


def forward(params: dict, cfg, tokens: jax.Array, *, audio_embeds: jax.Array,
            cache: dict | None = None, last_only: bool = False, return_hidden: bool = False):
    """Teacher-forcing path: encode then decode in one step (train shape)."""
    enc_out = encode(params, cfg, audio_embeds)
    return decode(params, cfg, tokens, enc_out=enc_out, cache=cache,
                  last_only=last_only, return_hidden=return_hidden)
