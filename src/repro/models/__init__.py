"""Model zoo: all 10 assigned architectures as composable JAX modules."""
from .api import CACHE_PAD, Model, build_model
from .params import ParamInfo, abstract, count_params, materialize, partition_specs
