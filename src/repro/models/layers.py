"""Shared neural layers for the model zoo: norms, RoPE, GQA attention
(flash-style chunked softmax in pure jnp), dense MLP, and MoE.

Everything here is *functional*: ``*_infos(cfg)`` declares parameters
(:class:`repro.models.params.ParamInfo` pytrees), ``*_apply`` consumes the
materialized (or abstract) arrays. Activation shardings are injected through
the :func:`activation_sharding` context so the same code runs unsharded on
one CPU device (smoke tests) and GSPMD-sharded on the production mesh.
"""
from __future__ import annotations

import contextlib
import math
import os
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec

from .params import ParamInfo


# resolved once at import: which shard_map the installed jax ships and
# whether it speaks the current (axis_names/check_vma) signature
_SM = getattr(jax, "shard_map", None)
if _SM is None:
    from jax.experimental.shard_map import shard_map as _SM
import inspect as _inspect

_SM_CURRENT_API = "check_vma" in _inspect.signature(_SM).parameters


def _shard_map(body, *, mesh, in_specs, out_specs, axis_names):
    """``jax.shard_map`` across jax versions.

    ``jax.shard_map`` with ``axis_names``/``check_vma`` is a recent API; older
    releases ship ``jax.experimental.shard_map.shard_map`` where the manual
    axis set is expressed through its complement (``auto``) and replication
    checking through ``check_rep``.
    """
    if _SM_CURRENT_API:
        return _SM(body, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                   axis_names=axis_names, check_vma=False)
    auto = frozenset(mesh.axis_names) - frozenset(axis_names)
    return _SM(body, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
               check_rep=False, auto=auto)

# --- activation-sharding context ------------------------------------------------

_CTX: dict = {"mesh": None, "rules": None}


@contextlib.contextmanager
def activation_sharding(mesh, rules: dict):
    """Install (mesh, logical-axis rules) for `shard()` constraints while tracing."""
    prev = dict(_CTX)
    _CTX["mesh"], _CTX["rules"] = mesh, rules
    try:
        yield
    finally:
        _CTX.update(prev)


def _axis_product(mesh, axes) -> int:
    if axes is None:
        return 1
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    if isinstance(axes, str):
        return sizes.get(axes, 1)
    n = 1
    for a in axes:
        n *= sizes.get(a, 1)
    return n


def shard(x: jax.Array, *axes: str | None) -> jax.Array:
    """Constrain activation ``x`` to the sharding implied by logical ``axes``.

    No-op when no mesh is installed (single-device tests) or when the rank
    does not match; mesh axes that do not divide the dimension are dropped
    (pjit divisibility), leaving GSPMD to choose for that dim.
    """
    mesh, rules = _CTX["mesh"], _CTX["rules"]
    if mesh is None or rules is None or len(axes) != x.ndim:
        return x
    resolved = []
    for dim, a in zip(x.shape, axes):
        mesh_axes = rules.get(a) if a is not None else None
        n = _axis_product(mesh, mesh_axes)
        resolved.append(mesh_axes if (n == 1 or dim % n == 0) and n > 1 else None)
    spec = PartitionSpec(*resolved)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def mesh_info() -> tuple:
    """(mesh, rules) currently installed, or (None, None)."""
    return _CTX["mesh"], _CTX["rules"]


# --- vocab padding ------------------------------------------------------------------
# Pad vocab to a multiple of 256 so the vocab dim always divides TP (the
# Megatron trick). Padded logit columns are masked to -1e30 before any
# softmax/argmax, so they are semantically inert.

def padded_vocab(v: int) -> int:
    return -(-v // 256) * 256


def mask_padded_logits(logits: jax.Array, true_vocab: int) -> jax.Array:
    if logits.shape[-1] == true_vocab:
        return logits
    col = jax.lax.broadcasted_iota(jnp.int32, logits.shape[-1:], 0)
    neg = jnp.asarray(-1e30, logits.dtype)
    return jnp.where(col < true_vocab, logits, neg)


def sharded_embed(table: jax.Array, tokens: jax.Array, cfg) -> jax.Array:
    """Embedding lookup with a vocab-sharded table, manual over the mesh.

    GSPMD replicates the gather *transpose* (a [V, D] f32 scatter-add per
    device -- 22GiB at kimi scale), so the lookup runs under shard_map: each
    shard gathers from its local vocab rows (ids outside the range contribute
    zeros) and one psum over 'model' assembles the embeddings; the backward
    is then a local scatter-add into the local rows only.
    """
    mesh, _ = mesh_info()
    dt = cfg.compute_dtype
    if mesh is None or _axis_product(mesh, "model") <= 1 or getattr(cfg, "layout", "tp") != "tp":
        return table.astype(dt)[tokens]
    tp = _axis_product(mesh, "model")
    Vp = table.shape[0]
    if Vp % tp != 0:
        return table.astype(dt)[tokens]
    V_loc = Vp // tp
    data_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    dp = _axis_product(mesh, data_axes)
    B = tokens.shape[0]
    batch_spec = data_axes if (B % max(dp, 1) == 0 and dp > 1) else None

    def body(tbl, ids):
        off = jax.lax.axis_index("model") * V_loc
        local = ids - off
        ok = (local >= 0) & (local < V_loc)
        x = tbl[jnp.clip(local, 0, V_loc - 1)].astype(jnp.float32)
        x = jnp.where(ok[..., None], x, 0.0)
        return jax.lax.psum(x, "model")

    # table in_spec: vocab rows over 'model'; its dmodel dim may carry the
    # FSDP data axes -- gather it at the boundary (bf16, cheap vs the grads).
    out = _shard_map(
        body,
        mesh=mesh,
        in_specs=(PartitionSpec("model", None), PartitionSpec(batch_spec, None)),
        out_specs=PartitionSpec(batch_spec, None, None),
        axis_names={"model", *data_axes},
    )(table, tokens)
    return out.astype(dt)


# --- norms -----------------------------------------------------------------------

def norm_infos(cfg, name: str = "norm") -> dict:
    d = {"scale": ParamInfo((cfg.d_model,), ("dmodel",), "ones")}
    if cfg.norm == "layernorm":
        d["bias"] = ParamInfo((cfg.d_model,), ("dmodel",), "zeros")
    return d


def norm_apply(p: dict, x: jax.Array, cfg) -> jax.Array:
    xf = x.astype(jnp.float32)
    if cfg.norm == "layernorm":
        mu = xf.mean(-1, keepdims=True)
        var = ((xf - mu) ** 2).mean(-1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + 1e-5) * p["scale"] + p["bias"]
    else:  # rmsnorm
        var = (xf**2).mean(-1, keepdims=True)
        y = xf * jax.lax.rsqrt(var + 1e-6) * p["scale"]
    return y.astype(x.dtype)


# --- rotary position embeddings ----------------------------------------------------

def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """Apply RoPE. x: [..., S, H, dh], positions: [..., S] (broadcastable)."""
    dh = x.shape[-1]
    half = dh // 2
    freqs = jnp.exp(-math.log(theta) * jnp.arange(half, dtype=jnp.float32) / half)
    angles = positions[..., :, None].astype(jnp.float32) * freqs[None, :]  # [..., S, half]
    cos = jnp.cos(angles)[..., :, None, :]  # [..., S, 1, half]
    sin = jnp.sin(angles)[..., :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    xf1, xf2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    return jnp.concatenate([xf1 * cos - xf2 * sin, xf2 * cos + xf1 * sin], axis=-1).astype(x.dtype)


def quantize_kv(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Symmetric int8 quantization per (token, head): x [B,S,H,dh] ->
    (int8 [B,S,H,dh], bf16 scales [B,S,H]). Halves the KV-cache bytes and,
    more importantly for decode, halves the per-step HBM read volume."""
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1)
    scale = jnp.maximum(amax / 127.0, 1e-8)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale[..., None]), -127, 127)
    return q.astype(jnp.int8), scale.astype(jnp.bfloat16)


# --- GQA attention ------------------------------------------------------------------

def attention_infos(cfg, cross: bool = False) -> dict:
    H, Hkv, dh, D = cfg.n_heads, cfg.n_kv_heads, cfg.d_head, cfg.d_model
    d = {
        "wq": ParamInfo((D, H, dh), ("dmodel", "heads", None)),
        "wk": ParamInfo((D, Hkv, dh), ("dmodel", "kv_heads", None)),
        "wv": ParamInfo((D, Hkv, dh), ("dmodel", "kv_heads", None)),
        "wo": ParamInfo((H, dh, D), ("heads", None, "dmodel")),
    }
    if cfg.qkv_bias:
        d["bq"] = ParamInfo((H, dh), ("heads", None), "zeros")
        d["bk"] = ParamInfo((Hkv, dh), ("kv_heads", None), "zeros")
        d["bv"] = ParamInfo((Hkv, dh), ("kv_heads", None), "zeros")
    return d


def _qkv(p: dict, x: jax.Array, cfg, positions, rope_on: bool):
    """Project to grouped q [B,S,Hkv,G,dh] and k,v [B,S,Hkv,dh]."""
    H, Hkv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    G = H // Hkv
    dt = cfg.compute_dtype
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(dt))
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"].astype(dt))
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"].astype(dt))
    if cfg.qkv_bias:
        q = q + p["bq"].astype(dt)
        k = k + p["bk"].astype(dt)
        v = v + p["bv"].astype(dt)
    if rope_on:
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)
    q = q.reshape(*q.shape[:2], Hkv, G, dh)
    return q, k, v


def chunked_attention(
    q: jax.Array,  # [B, Sq, Hkv, G, dh]
    k: jax.Array,  # [B, Skv, Hkv, dh]
    v: jax.Array,  # [B, Skv, Hkv, dh]
    *,
    causal: bool,
    q_offset: jax.Array | int = 0,  # absolute position of q[0] (decode/chunk offset)
    kv_offset: jax.Array | int = 0,  # absolute position of k[0] (windowed cache slice)
    kv_valid: jax.Array | int | None = None,  # #valid kv entries, in absolute positions
    chunk: int = 1024,
    window: int = 0,  # sliding window size, 0 = unlimited
) -> jax.Array:
    """Numerically-stable softmax attention, chunked over the query axis.

    This is the pure-jnp flash-attention reference: it never materializes a
    full [Sq, Skv] score tensor larger than [chunk, Skv], which keeps the
    32k-prefill memory footprint linear. The Pallas kernel in
    repro/kernels/flash_attention.py is the TPU-optimized equivalent and is
    validated against this function.
    """
    B, Sq, Hkv, G, dh = q.shape
    Skv = k.shape[1]
    scale = 1.0 / math.sqrt(dh)
    kv_pos = kv_offset + jnp.arange(Skv)

    def attend(q_chunk: jax.Array, q_pos: jax.Array) -> jax.Array:
        # q_chunk: [B, cq, Hkv, G, dh]; q_pos: [cq] absolute positions
        s = jnp.einsum("bqhgk,bthk->bhgqt", q_chunk, k).astype(jnp.float32) * scale
        mask = jnp.ones((q_pos.shape[0], Skv), bool)
        if causal:
            mask &= q_pos[:, None] >= kv_pos[None, :]
        if window:
            mask &= kv_pos[None, :] > q_pos[:, None] - window
        if kv_valid is not None:
            mask &= kv_pos[None, :] < kv_valid
        s = jnp.where(mask[None, None, None], s, -1e30)
        w = jax.nn.softmax(s, axis=-1).astype(q.dtype)
        return jnp.einsum("bhgqt,bthk->bqhgk", w, v)

    if Sq <= chunk:
        return attend(q, q_offset + jnp.arange(Sq))

    n = -(-Sq // chunk)
    pad = n * chunk - Sq
    if pad:  # pad queries to a whole number of chunks; extra rows are dropped
        q = jnp.concatenate([q, jnp.zeros((B, pad, Hkv, G, dh), q.dtype)], axis=1)
    qs = q.reshape(B, n, chunk, Hkv, G, dh).transpose(1, 0, 2, 3, 4, 5)
    offs = q_offset + jnp.arange(n) * chunk

    def body(_, xs):
        qc, off = xs
        return None, attend(qc, off + jnp.arange(chunk))

    # flash-style backward: recompute each chunk's scores/softmax in the
    # backward pass instead of saving [chunk, Skv] f32 weights per chunk.
    _, out = jax.lax.scan(jax.checkpoint(body), None, (qs, offs))
    out = out.transpose(1, 0, 2, 3, 4, 5).reshape(B, n * chunk, Hkv, G, dh)
    return out[:, :Sq]


def _seq_sharded_attention(q, k, v, *, causal, chunk, window, mesh):
    """shard_map attention for head counts that do not divide TP: queries are
    sequence-sharded over 'model', K/V replicated across it; each shard runs
    the chunked online-softmax locally with its absolute q offset. No
    collectives inside -- the surrounding projections reshard."""
    S = q.shape[1]
    tp = dict(zip(mesh.axis_names, mesh.devices.shape)).get("model", 1)
    if tp == 1 or S % tp != 0:
        return chunked_attention(q, k, v, causal=causal, chunk=chunk, window=window)
    local = S // tp
    dt = q.dtype

    def body(ql, kl, vl):
        off = jax.lax.axis_index("model") * local
        return chunked_attention(
            ql, kl.astype(dt), vl.astype(dt),
            causal=causal, q_offset=off, chunk=chunk, window=window,
        )

    # k/v cross the boundary in f32 (replicated-input cotangents lower to
    # copy-combiner all-reduces that XLA:CPU aborts on in bf16; see MoE note).
    return _shard_map(
        body,
        mesh=mesh,
        in_specs=(
            PartitionSpec(None, "model", None, None, None),
            PartitionSpec(None, None, None, None),
            PartitionSpec(None, None, None, None),
        ),
        out_specs=PartitionSpec(None, "model", None, None, None),
        axis_names={"model"},
    )(q, k.astype(jnp.float32), v.astype(jnp.float32))


def attention_apply(
    p: dict,
    x: jax.Array,  # [B, S, D]
    cfg,
    *,
    positions: jax.Array,  # [S] absolute positions of x
    cache: dict | None = None,  # {'k': [B,T,Hkv,dh], 'v': ..., 'len': scalar}
    causal: bool = True,
    rope_on: bool = True,
    window: int = 0,
) -> tuple[jax.Array, dict | None]:
    """Self-attention with optional KV cache. Returns (out [B,S,D], new_cache)."""
    dt = cfg.compute_dtype
    q, k, v = _qkv(p, x, cfg, positions, rope_on)
    q = shard(q, "batch", None, "act_heads", None, None)
    k = shard(k, "batch", None, "act_heads", None)
    v = shard(v, "batch", None, "act_heads", None)

    if cache is None:
        mesh, _ = mesh_info()
        tp = _axis_product(mesh, "model") if mesh is not None else 1
        if cfg.attn_shard == "seq" and mesh is not None and x.shape[1] > 1:
            out = _seq_sharded_attention(
                q, k, v, causal=causal, chunk=cfg.attn_chunk, window=window, mesh=mesh
            )
        else:
            if tp > 1 and k.shape[2] % tp != 0 and not os.environ.get("REPRO_DISABLE_KVEXP"):
                # GQA kv heads do not divide TP: expand kv to full query heads
                # so the head dim shards (q regrouped to G=1). Memory cost is
                # G x on k/v activations, /tp sharded -- net win vs replicated
                # attention scores.
                G = q.shape[3]
                k = shard(jnp.repeat(k, G, axis=2), "batch", None, "act_heads", None)
                v = shard(jnp.repeat(v, G, axis=2), "batch", None, "act_heads", None)
                B, S, Hkv, G_, dh = q.shape
                q = q.reshape(B, S, Hkv * G_, 1, dh)
                q = shard(q, "batch", None, "act_heads", None, None)
            out = chunked_attention(
                q, k, v, causal=causal, chunk=cfg.attn_chunk, window=window
            )
        new_cache = None
    else:
        idx = cache["len"]
        S = x.shape[1]
        quant = cache["k"].dtype == jnp.int8

        def write(buf, val, rank4=True):
            return jax.lax.dynamic_update_slice(
                buf, val.astype(buf.dtype), (0, idx, 0, 0) if rank4 else (0, idx, 0))

        # pin the updated cache to its canonical sharding: without the
        # constraint GSPMD ping-pongs between time-sharded and head-sharded
        # layouts around the DUS ("involuntary full rematerialization").
        if getattr(cfg, "kv_cache_time_sharded", False):
            pin = lambda a: shard(a, "batch", "cache_time", None, None)
            pin3 = lambda a: shard(a, "batch", "cache_time", None)
        else:
            pin = lambda a: shard(a, "batch", None, "kv_heads", None)
            pin3 = lambda a: shard(a, "batch", None, "kv_heads")
        if quant:
            kq, ks = quantize_kv(k)
            vq, vs = quantize_kv(v)
            ck, cv = pin(write(cache["k"], kq)), pin(write(cache["v"], vq))
            cks = pin3(write(cache["k_scale"], ks, rank4=False))
            cvs = pin3(write(cache["v_scale"], vs, rank4=False))
        else:
            ck, cv = pin(write(cache["k"], k)), pin(write(cache["v"], v))
            cks = cvs = None

        rk, rv, rks, rvs, kv_off = ck, cv, cks, cvs, 0
        if window and ck.shape[1] > window + S:
            # sliding window: read only the last `window+S` cache entries --
            # at 500k context this cuts per-step attention reads by T/window.
            kv_off = jnp.maximum(idx + S - (window + S), 0)
            sl = lambda a, r=1: jax.lax.dynamic_slice_in_dim(a, kv_off, window + S, axis=r)
            rk, rv = sl(rk), sl(rv)
            if quant:
                rks, rvs = sl(rks), sl(rvs)
        if quant:
            rk = rk.astype(dt) * rks[..., None].astype(dt)
            rv = rv.astype(dt) * rvs[..., None].astype(dt)
        out = chunked_attention(
            q, rk.astype(dt), rv.astype(dt),
            causal=causal, q_offset=idx, kv_offset=kv_off, kv_valid=idx + S,
            chunk=cfg.attn_chunk, window=window,
        )
        new_cache = {"k": ck, "v": cv, "len": idx + S}
        if quant:
            new_cache.update(k_scale=cks, v_scale=cvs)

    B, S = x.shape[:2]
    out = out.reshape(B, S, cfg.n_heads, cfg.d_head)
    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(dt))
    return shard(y, "batch", None, None), new_cache


def cross_attention_apply(p: dict, x: jax.Array, cfg, enc_kv: tuple[jax.Array, jax.Array]):
    """Cross-attention against precomputed encoder K/V (whisper decoder)."""
    dt = cfg.compute_dtype
    H, Hkv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(dt))
    if cfg.qkv_bias:
        q = q + p["bq"].astype(dt)
    q = q.reshape(*q.shape[:2], Hkv, H // Hkv, dh)
    k, v = enc_kv
    out = chunked_attention(q, k.astype(dt), v.astype(dt), causal=False, chunk=cfg.attn_chunk)
    B, S = x.shape[:2]
    out = out.reshape(B, S, H, dh)
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(dt))


def encoder_kv(p: dict, enc_out: jax.Array, cfg) -> tuple[jax.Array, jax.Array]:
    """Precompute cross-attention K/V from encoder output (done at prefill)."""
    dt = cfg.compute_dtype
    k = jnp.einsum("bsd,dhk->bshk", enc_out, p["wk"].astype(dt))
    v = jnp.einsum("bsd,dhk->bshk", enc_out, p["wv"].astype(dt))
    if cfg.qkv_bias:
        k = k + p["bk"].astype(dt)
        v = v + p["bv"].astype(dt)
    return k, v


# --- dense MLP ------------------------------------------------------------------------

def mlp_infos(cfg, d_ff: int | None = None) -> dict:
    D = cfg.d_model
    F = d_ff or cfg.d_ff
    if cfg.act == "swiglu":
        return {
            "wi": ParamInfo((D, 2, F), ("dmodel", None, "mlp")),  # gate & up fused
            "wo": ParamInfo((F, D), ("mlp", "dmodel")),
        }
    return {
        "wi": ParamInfo((D, F), ("dmodel", "mlp")),
        "bi": ParamInfo((F,), ("mlp",), "zeros"),
        "wo": ParamInfo((F, D), ("mlp", "dmodel")),
        "bo": ParamInfo((D,), ("dmodel",), "zeros"),
    }


def mlp_apply(p: dict, x: jax.Array, cfg) -> jax.Array:
    dt = cfg.compute_dtype
    if cfg.act == "swiglu":
        h = jnp.einsum("bsd,dcf->bscf", x, p["wi"].astype(dt))
        h = shard(h, "batch", None, None, "act_heads")
        h = jax.nn.silu(h[..., 0, :]) * h[..., 1, :]
    else:
        h = jnp.einsum("bsd,df->bsf", x, p["wi"].astype(dt)) + p["bi"].astype(dt)
        h = shard(h, "batch", None, "act_heads")
        h = jax.nn.gelu(h)
    y = jnp.einsum("bsf,fd->bsd", h, p["wo"].astype(dt))
    if cfg.act != "swiglu":
        y = y + p["bo"].astype(dt)
    return shard(y, "batch", None, None)


# --- Mixture of Experts -------------------------------------------------------------------

def moe_infos(cfg) -> dict:
    D, E, F = cfg.d_model, cfg.moe_experts, cfg.moe_dff
    return {
        "router": ParamInfo((D, E), ("dmodel", "expert"), "small"),
        "wi": ParamInfo((E, D, 2, F), ("expert", "expert_dmodel", None, None)),
        "wo": ParamInfo((E, F, D), ("expert", None, "expert_dmodel")),
    }


def moe_capacity(cfg, tokens_per_group: int) -> int:
    c = math.ceil(tokens_per_group * cfg.moe_topk * cfg.moe_capacity_factor / cfg.moe_experts)
    return max(4, int(c))


def _dispatch_tokens(tokens: jax.Array, expert_idx: jax.Array, gate_w: jax.Array, E: int, C: int):
    """Sort-based dispatch of one token group.

    tokens: [N, D]; expert_idx/gate_w: [N, K]. Expert ids >= E (sentinel) or
    beyond capacity are dropped. Returns
      buf   [E, C, D]  -- tokens gathered per expert (capacity-truncated)
      meta  (src [E, C] int32 token index or -1, w [E, C] gate weight)
    """
    N, K = expert_idx.shape
    flat_e = jnp.minimum(expert_idx.reshape(-1), E)  # [N*K]; E = dropped bucket
    flat_w = gate_w.reshape(-1)
    flat_tok = jnp.repeat(jnp.arange(N), K)

    order = jnp.argsort(flat_e, stable=True)
    se, sw, st = flat_e[order], flat_w[order], flat_tok[order]
    # position within the expert segment
    counts = jnp.bincount(se, length=E + 1)
    seg_start = jnp.concatenate([jnp.zeros(1, counts.dtype), jnp.cumsum(counts)[:-1]])
    pos = jnp.arange(N * K) - seg_start[se]
    keep = (pos < C) & (se < E)
    slot = jnp.where(keep, se * C + pos, E * C)  # overflow slot dropped

    src = jnp.full((E * C + 1,), -1, jnp.int32).at[slot].set(st.astype(jnp.int32))[:-1]
    w = jnp.zeros((E * C + 1,), jnp.float32).at[slot].set(sw.astype(jnp.float32))[:-1]
    src = src.reshape(E, C)
    w = w.reshape(E, C)
    buf = jnp.where(src[..., None] >= 0, tokens[jnp.maximum(src, 0)], 0.0)
    return buf, (src, w)


def _moe_expert_parallel(p: dict, x: jax.Array, cfg, group: str, mesh) -> jax.Array:
    """Expert-parallel MoE via shard_map (the production path).

    FULLY manual over every mesh axis: the sort/scatter dispatch is data-
    dependent, and GSPMD left to its own devices replicates the batch through
    it (measured 17GiB/device buffers at kimi scale). Manual data-axis
    sharding keeps everything local: each shard holds its batch rows and its
    E/tp experts, dispatches into a LOCAL capacity buffer [E/tp, C, D], runs
    its experts, and one psum over 'model' combines the partial outputs (the
    classic EP all-reduce).
    """
    E, K = cfg.moe_experts, cfg.moe_topk
    tp = _axis_product(mesh, "model")
    E_loc = E // tp
    dt = cfg.compute_dtype
    B, S, D = x.shape
    C = moe_capacity(cfg, S if group == "seq" else B * S)
    data_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    dp = _axis_product(mesh, data_axes)
    batch_spec = data_axes if (B % max(dp, 1) == 0 and dp > 1) else None

    fsdp = (bool(getattr(cfg, "fsdp", False)) and getattr(cfg, "expert_fsdp", True)
            and len(data_axes) > 0)

    def body(xl, router, wi, wo):
        xl = xl.astype(dt)
        if fsdp:
            # FSDP un-shard of the expert weights, explicit and in bf16 --
            # leaving it to the shard_map boundary materializes f32 copies
            # of weight + gradient (measured ~18GiB at kimi scale).
            wi = jax.lax.all_gather(wi.astype(dt), data_axes, axis=1, tiled=True)
            wo = jax.lax.all_gather(wo.astype(dt), data_axes, axis=2, tiled=True)
        logits = jnp.einsum("bsd,de->bse", xl, router.astype(jnp.float32))
        gates = jax.nn.softmax(logits, axis=-1)
        gate_w, expert_idx = jax.lax.top_k(gates, K)
        gate_w = gate_w / jnp.maximum(gate_w.sum(-1, keepdims=True), 1e-9)
        e0 = jax.lax.axis_index("model") * E_loc
        local_idx = jnp.where(
            (expert_idx >= e0) & (expert_idx < e0 + E_loc), expert_idx - e0, E_loc
        )

        def ffn(buf):
            h = jnp.einsum("...ecd,edgf->...ecgf", buf, wi.astype(dt))
            h = jax.nn.silu(h[..., 0, :]) * h[..., 1, :]
            return jnp.einsum("...ecf,efd->...ecd", h, wo.astype(dt))

        def one_group(tok, eidx, gw, n_tokens):
            buf, (src, w) = _dispatch_tokens(tok, eidx, gw, E_loc, C)
            out = ffn(buf.astype(dt))
            flat = (out * w[..., None].astype(dt)).reshape(E_loc * C, D)
            srcf = src.reshape(E_loc * C)
            return jnp.zeros((n_tokens, D), dt).at[jnp.maximum(srcf, 0)].add(
                jnp.where(srcf[:, None] >= 0, flat, 0.0)
            )

        nb = xl.shape[0]
        if group == "seq":
            y = jax.vmap(lambda t, e, g: one_group(t, e, g, S))(xl, local_idx, gate_w)
        else:
            y = one_group(
                xl.reshape(nb * S, D), local_idx.reshape(nb * S, K),
                gate_w.reshape(nb * S, K), nb * S,
            ).reshape(nb, S, D)
        # psum combine dtype: f32 is the conservative baseline; 'bf16' halves
        # the EP all-reduce payload (kimi hillclimb). (XLA:CPU only aborts on
        # bf16 *copy-combiner* all-reduces; this is an add-combiner.)
        if getattr(cfg, "moe_combine_dtype", "f32") == "bf16":
            return jax.lax.psum(y.astype(jnp.bfloat16), "model").astype(dt)
        return jax.lax.psum(y.astype(jnp.float32), "model").astype(dt)

    # Boundary tensors cross in f32: the cotangent of a replicated shard_map
    # input lowers to a copy-combiner all-reduce, which XLA:CPU's
    # AllReducePromotion pass aborts on for bf16 (f32 is untouched). On TPU
    # this costs nothing extra at entry (no collective on replicated-in).
    manual = {"model", *data_axes}
    wi_spec = PartitionSpec("model", data_axes if fsdp else None, None, None)
    wo_spec = PartitionSpec("model", None, data_axes if fsdp else None)
    return _shard_map(
        body,
        mesh=mesh,
        in_specs=(
            PartitionSpec(batch_spec, None, None),  # x: batch rows local
            PartitionSpec(None, None),  # router replicated
            wi_spec,  # wi: experts over TP (+ FSDP rows over data)
            wo_spec,
        ),
        out_specs=PartitionSpec(batch_spec, None, None),
        axis_names=manual,
    )(x.astype(jnp.float32), p["router"], p["wi"], p["wo"])


def moe_apply(p: dict, x: jax.Array, cfg, *, group: str = "seq") -> jax.Array:
    """Top-k routed MoE FFN (SwiGLU experts), sort-based dispatch.

    group='seq'   : dispatch independently per sequence (train/prefill) --
                    capacity is per (sequence, expert), so dispatch indices
                    stay batch-local and the batch sharding is preserved.
    group='batch' : dispatch across the whole [B*S] token set (decode, S=1).

    With a mesh installed and E divisible by TP, dispatch runs expert-
    parallel under shard_map (see _moe_expert_parallel); otherwise the
    pure-GSPMD single-device path below.
    """
    B, S, D = x.shape
    E, K = cfg.moe_experts, cfg.moe_topk
    dt = cfg.compute_dtype

    mesh, _ = mesh_info()
    if (mesh is not None and E % max(_axis_product(mesh, "model"), 1) == 0
            and _axis_product(mesh, "model") > 1
            and getattr(cfg, "layout", "tp") == "tp"
            and not os.environ.get("REPRO_DISABLE_EP")):
        return _moe_expert_parallel(p, x, cfg, group, mesh)

    logits = jnp.einsum("bsd,de->bse", x, p["router"].astype(jnp.float32))
    gates = jax.nn.softmax(logits, axis=-1)
    gate_w, expert_idx = jax.lax.top_k(gates, K)  # [B,S,K]
    gate_w = gate_w / jnp.maximum(gate_w.sum(-1, keepdims=True), 1e-9)

    def ffn(buf):  # buf: [..., E, C, D]
        h = jnp.einsum("...ecd,edgf->...ecgf", buf, p["wi"].astype(dt))
        h = jax.nn.silu(h[..., 0, :]) * h[..., 1, :]
        return jnp.einsum("...ecf,efd->...ecd", h, p["wo"].astype(dt))

    if group == "seq":
        C = moe_capacity(cfg, S)

        def per_row(tok, eidx, gw):
            buf, (src, w) = _dispatch_tokens(tok, eidx, gw, E, C)
            return buf, src, w

        buf, src, w = jax.vmap(per_row)(x, expert_idx, gate_w)  # [B,E,C,D],[B,E,C]
        buf = shard(buf, "batch", "act_expert", None, None)
        out_buf = ffn(buf.astype(dt))
        out_buf = shard(out_buf, "batch", "act_expert", None, None)

        def combine(tok_out, src_row, w_row):
            flat = (tok_out * w_row[..., None].astype(dt)).reshape(E * C, D)
            srcf = src_row.reshape(E * C)
            y = jnp.zeros((S, D), dt).at[jnp.maximum(srcf, 0)].add(
                jnp.where(srcf[:, None] >= 0, flat, 0.0)
            )
            return y

        y = jax.vmap(combine)(out_buf, src, w)
    else:
        tok = x.reshape(B * S, D)
        C = moe_capacity(cfg, B * S)
        buf, (src, w) = _dispatch_tokens(tok, expert_idx.reshape(B * S, K), gate_w.reshape(B * S, K), E, C)
        buf = shard(buf, "act_expert", None, None)
        out_buf = ffn(buf.astype(dt))
        flat = (out_buf * w[..., None].astype(dt)).reshape(E * C, D)
        srcf = src.reshape(E * C)
        y = jnp.zeros((B * S, D), dt).at[jnp.maximum(srcf, 0)].add(
            jnp.where(srcf[:, None] >= 0, flat, 0.0)
        ).reshape(B, S, D)
    return shard(y, "batch", None, None)


def aux_load_balance_loss(p: dict, x: jax.Array, cfg) -> jax.Array:
    """Switch-style load-balancing auxiliary loss (used by train_step for MoE)."""
    logits = jnp.einsum("bsd,de->bse", x, p["router"].astype(jnp.float32))
    gates = jax.nn.softmax(logits, axis=-1)
    _, idx = jax.lax.top_k(gates, cfg.moe_topk)
    E = cfg.moe_experts
    hits = jax.nn.one_hot(idx, E).sum(axis=(-3, -2))  # [B? ...] -> per expert counts
    frac_tokens = hits / jnp.maximum(hits.sum(-1, keepdims=True), 1.0)
    frac_probs = gates.mean(axis=-2)
    return E * jnp.mean(jnp.sum(frac_tokens * frac_probs, axis=-1))
