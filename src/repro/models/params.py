"""Parameter declaration system: one source of truth for shapes, sharding and init.

A model declares its parameters as a pytree of :class:`ParamInfo`; from that
single declaration we derive
  * ``materialize``   -- actual initialized arrays (smoke tests, examples),
  * ``abstract``      -- ShapeDtypeStructs (the multi-pod dry-run never allocates),
  * ``partition_specs`` -- jax.sharding.PartitionSpec pytree via logical-axis rules.

Logical axes used across the zoo (resolved by ``configs.base.sharding_rules``):
  'dmodel'       residual-stream features        -> None (or 'data' under FSDP)
  'heads'        attention query heads           -> 'model'
  'kv_heads'     attention kv heads              -> 'model' (replicated up to TP)
  'mlp'          feed-forward hidden             -> 'model'
  'vocab'        embedding rows / logits         -> 'model'
  'expert'       MoE expert dimension            -> 'model'  (expert parallelism)
  'conv','state',... small dims                  -> None
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, Mapping

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec


@dataclasses.dataclass(frozen=True)
class ParamInfo:
    shape: tuple[int, ...]
    axes: tuple[str | None, ...]  # logical axis names, same length as shape
    init: str = "normal"  # normal | zeros | ones | embed | small
    scale: float | None = None  # overrides fan-in scaling when set
    dtype: Any = jnp.float32

    def __post_init__(self):
        if len(self.shape) != len(self.axes):
            raise ValueError(f"axes {self.axes} do not match shape {self.shape}")


def _fan_in(shape: tuple[int, ...]) -> int:
    return shape[0] if len(shape) > 1 else max(1, shape[0])


def _init_one(key: jax.Array, info: ParamInfo) -> jax.Array:
    if info.init == "zeros":
        return jnp.zeros(info.shape, info.dtype)
    if info.init == "ones":
        return jnp.ones(info.shape, info.dtype)
    if info.init == "const":
        return jnp.full(info.shape, info.scale, info.dtype)
    scale = info.scale
    if info.init == "embed":
        scale = 1.0 if scale is None else scale
    elif info.init == "small":
        scale = 0.02 if scale is None else scale
    else:  # normal: truncated-normal, 1/sqrt(fan_in)
        scale = (1.0 / math.sqrt(_fan_in(info.shape))) if scale is None else scale
    return (jax.random.truncated_normal(key, -2.0, 2.0, info.shape, jnp.float32) * scale).astype(info.dtype)


def is_info(x) -> bool:
    return isinstance(x, ParamInfo)


def materialize(tree, rng: jax.Array):
    """Initialize every ParamInfo leaf with a split of ``rng``."""
    leaves, treedef = jax.tree_util.tree_flatten(tree, is_leaf=is_info)
    keys = jax.random.split(rng, len(leaves))
    arrs = [_init_one(k, info) for k, info in zip(keys, leaves)]
    return jax.tree_util.tree_unflatten(treedef, arrs)


def abstract(tree):
    """ShapeDtypeStruct pytree -- used by the dry-run (no allocation)."""
    return jax.tree_util.tree_map(
        lambda i: jax.ShapeDtypeStruct(i.shape, i.dtype), tree, is_leaf=is_info
    )


def _axes_product(axes, sizes: Mapping[str, int]) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        return sizes.get(axes, 1)
    n = 1
    for a in axes:
        n *= sizes.get(a, 1)
    return n


def _as_tuple(axes):
    if axes is None:
        return ()
    return (axes,) if isinstance(axes, str) else tuple(axes)


def partition_specs(tree, rules: Mapping[str, Any], axis_sizes: Mapping[str, int] | None = None):
    """Resolve logical axes -> PartitionSpec via ``rules`` (name -> mesh axis).

    With ``axis_sizes`` (mesh axis -> size), enforce pjit's divisibility
    requirement per dimension:
      * a mesh axis that does not divide its dimension is dropped
        (e.g. kv_heads=4 on model=16 -> replicated);
      * if that drops 'model' from a large weight entirely, fall back to
        sharding the 'dmodel' (contraction) dimension over 'model' -- memory
        still scales with TP at the cost of a partial-sum all-reduce, the
        classic contraction-parallel layout (DESIGN.md §5).
    """

    def spec(info: ParamInfo) -> PartitionSpec:
        resolved = [rules.get(a) if a is not None else None for a in info.axes]
        if axis_sizes is None:
            return PartitionSpec(*resolved)
        out = []
        for dim, axes in zip(info.shape, resolved):
            n = _axes_product(axes, axis_sizes)
            out.append(axes if (n > 1 and dim % n == 0) else
                       (axes if n == 1 else None))
        uses_model = any("model" in _as_tuple(a) for a in out)
        big = int(np.prod(info.shape)) >= (1 << 20)
        if not uses_model and big and "model" in axis_sizes:
            for i, (dim, logical) in enumerate(zip(info.shape, info.axes)):
                if logical != "dmodel":
                    continue
                combined = _as_tuple(out[i]) + ("model",)
                if dim % _axes_product(combined, axis_sizes) == 0:
                    out[i] = combined if len(combined) > 1 else combined[0]
                    break
        return PartitionSpec(*out)

    return jax.tree_util.tree_map(spec, tree, is_leaf=is_info)


def count_params(tree) -> int:
    leaves = jax.tree_util.tree_leaves(tree, is_leaf=is_info)
    return int(sum(int(np.prod(i.shape)) for i in leaves))


def stack_layers(n: int, info_tree):
    """Prepend a layer axis to every ParamInfo (for lax.scan over layers).

    The layer axis is logical axis 'layer' (never sharded -> scanned).
    """
    return jax.tree_util.tree_map(
        lambda i: ParamInfo((n, *i.shape), ("layer", *i.axes), i.init, i.scale, i.dtype),
        info_tree,
        is_leaf=is_info,
    )
