"""RWKV6 "Finch" (arXiv:2404.05892): attention-free LM with data-dependent
per-channel decay. Assigned arch: rwkv6-7b (32L, d_model=4096, d_ff=14336).

TPU adaptation: the WKV6 recurrence is computed in *chunked* form -- within a
chunk of C tokens the contribution is an attention-like [C, C, dh] einsum
(MXU-friendly), across chunks a lax.scan carries the per-head state
S in R^[dh_k, dh_v]. This is exact (log-space relative decays, fp32), and it
is the same blocking the Pallas kernel (kernels/rwkv6_scan.py) implements
with explicit VMEM tiles.

Recurrence per head (k-dim i, v-dim j):
    y_t[j] = sum_i r_t[i] * (S_{t-1}[i,j] + u[i] k_t[i] v_t[j])
    S_t    = diag(w_t) S_{t-1} + k_t v_t^T,   w_t = exp(-exp(wlog_t)) in (0,1)
with w_t data-dependent (token-shift mix + LoRA), the Finch signature.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from . import layers as L
from .params import ParamInfo, stack_layers

WKV_CHUNK = 32  # intra-chunk length for the exact chunked recurrence


def _mix_infos(cfg, n: int) -> ParamInfo:
    return ParamInfo((n, cfg.d_model), (None, "dmodel"), "small")


def layer_infos(cfg) -> dict:
    D = cfg.d_model
    H = D // cfg.rwkv_head_size
    dh = cfg.rwkv_head_size
    F = cfg.d_ff
    lora = 64
    return {
        "ln1": L.norm_infos(cfg),
        "ln2": L.norm_infos(cfg),
        "time": {
            "mix": _mix_infos(cfg, 5),  # mu_r, mu_k, mu_v, mu_g, mu_w
            "wr": ParamInfo((D, H, dh), ("dmodel", "heads", None)),
            "wk": ParamInfo((D, H, dh), ("dmodel", "heads", None)),
            "wv": ParamInfo((D, H, dh), ("dmodel", "heads", None)),
            "wg": ParamInfo((D, H, dh), ("dmodel", "heads", None)),
            "w_base": ParamInfo((H, dh), ("heads", None), "const", scale=-2.0),
            "w_lora_a": ParamInfo((D, lora), ("dmodel", None), "small"),
            "w_lora_b": ParamInfo((lora, H, dh), (None, "heads", None), "zeros"),
            "bonus": ParamInfo((H, dh), ("heads", None), "small"),
            "gn_scale": ParamInfo((H, dh), ("heads", None), "ones"),
            "wo": ParamInfo((H, dh, D), ("heads", None, "dmodel")),
        },
        "channel": {
            "mix": _mix_infos(cfg, 2),  # mu_k, mu_r
            "wk": ParamInfo((D, F), ("dmodel", "mlp")),
            "wv": ParamInfo((F, D), ("mlp", "dmodel")),
            "wr": ParamInfo((D, D), ("dmodel", None)),
        },
    }


def lm_infos(cfg) -> dict:
    vp = L.padded_vocab(cfg.vocab)
    return {
        "embed": ParamInfo((vp, cfg.d_model), ("vocab", "dmodel"), "embed", scale=0.02),
        "layers": stack_layers(cfg.n_layers, layer_infos(cfg)),
        "ln_f": L.norm_infos(cfg),
        "lm_head": ParamInfo((cfg.d_model, vp), ("dmodel", "vocab")),
    }


def cache_infos(cfg, batch: int, max_len: int) -> dict:
    D = cfg.d_model
    H, dh = D // cfg.rwkv_head_size, cfg.rwkv_head_size
    return {
        "wkv": ParamInfo((cfg.n_layers, batch, H, dh, dh), ("layer", "batch", "kv_heads", None, None), "zeros"),
        "shift_t": ParamInfo((cfg.n_layers, batch, D), ("layer", "batch", None), "zeros", dtype=jnp.bfloat16),
        "shift_c": ParamInfo((cfg.n_layers, batch, D), ("layer", "batch", None), "zeros", dtype=jnp.bfloat16),
        "len": ParamInfo((), (), "zeros", dtype=jnp.int32),
    }


def _token_shift(x: jax.Array, prev: jax.Array | None) -> jax.Array:
    """x_{t-1} stream: shift right by one; position 0 gets `prev` (or zeros)."""
    pad = jnp.zeros_like(x[:, :1]) if prev is None else prev[:, None, :].astype(x.dtype)
    return jnp.concatenate([pad, x[:, :-1]], axis=1)


def wkv_chunked(r, k, v, wlog, u, s0):
    """Exact chunked WKV6. r,k,v: [B,S,H,dh]; wlog: [B,S,H,dh] (log decay <0);
    u: [H,dh]; s0: [B,H,dh,dh]. Returns (y [B,S,H,dh], sT)."""
    B, S, H, dh = r.shape
    C = min(WKV_CHUNK, S)
    n = -(-S // C)
    pad = n * C - S
    if pad:  # pad the tail: k=r=v=0 adds nothing, wlog=0 (decay 1) keeps state
        z = jnp.zeros((B, pad, H, dh))
        r = jnp.concatenate([r, z.astype(r.dtype)], axis=1)
        k = jnp.concatenate([k, z.astype(k.dtype)], axis=1)
        v = jnp.concatenate([v, z.astype(v.dtype)], axis=1)
        wlog = jnp.concatenate([wlog, z.astype(wlog.dtype)], axis=1)
        S = n * C
    rs = r.reshape(B, n, C, H, dh).astype(jnp.float32)
    ks = k.reshape(B, n, C, H, dh).astype(jnp.float32)
    vs = v.reshape(B, n, C, H, dh).astype(jnp.float32)
    ws = wlog.reshape(B, n, C, H, dh).astype(jnp.float32)

    tri_lo = jnp.tril(jnp.ones((C, C), bool), -1)  # tau < t

    def chunk(s, xs):
        rc, kc, vc, wc = xs  # [B,C,H,dh]
        cl = jnp.cumsum(wc, axis=1)  # cumulative log decay, [B,C,H,dh]
        # decay from chunk start to *before* t (exclusive): cl_excl[t] = cl[t] - wc[t]
        cl_excl = cl - wc
        # inter-chunk: y_state[t] = sum_i r[t,i] * exp(cl_excl[t,i]) * s[i,j]
        r_dec = rc * jnp.exp(cl_excl)
        y_state = jnp.einsum("bchi,bhij->bchj", r_dec, s)
        # intra-chunk: D[t,u,i] = exp(cl_excl[t,i] - cl[u,i]) for u < t ; bonus at u == t
        # Mask in LOG domain: exponents above the diagonal are positive and
        # exp() would overflow to inf -- where(mask, inf, 0) then NaNs the
        # backward pass (inf * 0 cotangent).
        dlog = cl_excl[:, :, None] - cl[:, None, :, :]  # [B,C,C,H,dh]
        dmat = jnp.exp(jnp.where(tri_lo[None, :, :, None, None], dlog, -1e30))
        att = jnp.einsum("bthi,btuhi,buhi->btuh", rc, dmat, kc)
        y_intra = jnp.einsum("btuh,buhj->bthj", att, vc)
        y_bonus = jnp.einsum("bthi,hi,bthi->bth", rc, u.astype(jnp.float32), kc)[..., None] * vc
        # state update: s' = exp(cl[-1]) * s + sum_u exp(cl[-1] - cl[u]) k_u v_u^T
        k_dec = kc * jnp.exp(cl[:, -1:, :, :] - cl)
        s_new = jnp.exp(cl[:, -1])[:, :, :, None] * s + jnp.einsum("buhi,buhj->bhij", k_dec, vc)
        return s_new, y_state + y_intra + y_bonus

    xs = (rs.transpose(1, 0, 2, 3, 4), ks.transpose(1, 0, 2, 3, 4),
          vs.transpose(1, 0, 2, 3, 4), ws.transpose(1, 0, 2, 3, 4))
    sT, ys = jax.lax.scan(chunk, s0.astype(jnp.float32), xs)
    y = ys.transpose(1, 0, 2, 3, 4).reshape(B, S, H, dh)
    return y[:, : r.shape[1] - pad], sT


def time_mix(p: dict, x: jax.Array, cfg, state: dict | None):
    """RWKV6 time-mixing block. state: {'wkv': [B,H,dh,dh], 'shift': [B,D]} or None."""
    D = cfg.d_model
    H, dh = D // cfg.rwkv_head_size, cfg.rwkv_head_size
    dt = cfg.compute_dtype
    B, S, _ = x.shape

    prev = state["shift"] if state is not None else None
    xp = _token_shift(x, prev)
    mix = p["mix"].astype(dt)  # [5, D]
    xr, xk, xv, xg, xw = (x + mix[i][None, None] * (xp - x) for i in range(5))

    r = jnp.einsum("bsd,dhk->bshk", xr, p["wr"].astype(dt))
    k = jnp.einsum("bsd,dhk->bshk", xk, p["wk"].astype(dt))
    v = jnp.einsum("bsd,dhk->bshk", xv, p["wv"].astype(dt))
    g = jnp.einsum("bsd,dhk->bshk", xg, p["wg"].astype(dt))
    r = L.shard(r, "batch", None, "act_heads", None)
    k = L.shard(k, "batch", None, "act_heads", None)
    v = L.shard(v, "batch", None, "act_heads", None)

    # data-dependent decay (the Finch signature): base + LoRA(xw)
    wlora = jnp.einsum("bsd,dl,lhk->bshk", xw.astype(jnp.float32), p["w_lora_a"], p["w_lora_b"])
    wlog = -jnp.exp(p["w_base"].astype(jnp.float32)[None, None] + wlora)  # < 0

    s0 = (state["wkv"] if state is not None
          else jnp.zeros((B, H, dh, dh), jnp.float32))
    y, sT = wkv_chunked(r, k, v, wlog, p["bonus"], s0)

    # per-head group-norm then gate
    yf = y.astype(jnp.float32)
    var = (yf**2).mean(-1, keepdims=True)
    yf = yf * jax.lax.rsqrt(var + 1e-6) * p["gn_scale"][None, None]
    y = (yf.astype(dt) * jax.nn.silu(g))
    out = jnp.einsum("bshk,hkd->bsd", y, p["wo"].astype(dt))
    new_state = {"wkv": sT, "shift": x[:, -1, :]}
    return L.shard(out, "batch", None, None), new_state


def channel_mix(p: dict, x: jax.Array, cfg, state: dict | None):
    dt = cfg.compute_dtype
    prev = state["shift"] if state is not None else None
    xp = _token_shift(x, prev)
    mix = p["mix"].astype(dt)
    xk = x + mix[0][None, None] * (xp - x)
    xr = x + mix[1][None, None] * (xp - x)
    hidden = jnp.einsum("bsd,df->bsf", xk, p["wk"].astype(dt))
    hidden = L.shard(hidden, "batch", None, "act_heads")
    hidden = jnp.square(jax.nn.relu(hidden))
    out = jnp.einsum("bsf,fd->bsd", hidden, p["wv"].astype(dt))
    gate = jax.nn.sigmoid(jnp.einsum("bsd,de->bse", xr, p["wr"].astype(dt)))
    return gate * out, {"shift": x[:, -1, :]}


def _layer_apply(p: dict, x: jax.Array, cfg, state: dict | None):
    st_t = None if state is None else {"wkv": state["wkv"], "shift": state["shift_t"]}
    h, new_t = time_mix(p["time"], L.norm_apply(p["ln1"], x, cfg), cfg, st_t)
    x = x + h
    st_c = None if state is None else {"shift": state["shift_c"]}
    h, new_c = channel_mix(p["channel"], L.norm_apply(p["ln2"], x, cfg), cfg, st_c)
    x = x + h
    new_state = {"wkv": new_t["wkv"], "shift_t": new_t["shift"], "shift_c": new_c["shift"]}
    return x, new_state


def forward(params: dict, cfg, tokens: jax.Array, *, cache: dict | None = None,
            prefix_embeds=None, last_only: bool = False, return_hidden: bool = False):
    dt = cfg.compute_dtype
    x = L.sharded_embed(params["embed"], tokens, cfg)
    x = L.shard(x, "batch", None, None)

    if cache is None:

        def body(h, lp):
            h2, _ = _layer_apply(lp, h, cfg, None)
            return h2, None

        if cfg.remat == "layer":
            body = jax.checkpoint(body)
        if cfg.scan_layers:
            # two-level (sqrt-L) checkpointing: the recurrence needs the full
            # sequence per layer so the residual cannot be seq-sharded like
            # the transformer family; instead only every G-th carry is saved
            # and groups are recomputed during the backward pass.
            G = 8 if cfg.n_layers % 8 == 0 else 1
            if G > 1 and cfg.remat == "layer":
                grouped = jax.tree_util.tree_map(
                    lambda a: a.reshape(cfg.n_layers // G, G, *a.shape[1:]),
                    params["layers"],
                )

                def outer(h, lp_group):
                    h2, _ = jax.lax.scan(body, h, lp_group)
                    return h2, None

                x, _ = jax.lax.scan(jax.checkpoint(outer), x, grouped)
            else:
                x, _ = jax.lax.scan(body, x, params["layers"])
        else:
            for i in range(cfg.n_layers):
                x, _ = body(x, jax.tree_util.tree_map(lambda a: a[i], params["layers"]))
        new_cache = None
    else:

        def body(h, xs):
            lp, wkv, st, sc = xs
            h2, ns = _layer_apply(lp, h, cfg, {"wkv": wkv, "shift_t": st, "shift_c": sc})
            return h2, (ns["wkv"], ns["shift_t"].astype(jnp.bfloat16), ns["shift_c"].astype(jnp.bfloat16))

        xs = (params["layers"], cache["wkv"], cache["shift_t"], cache["shift_c"])
        if cfg.scan_layers:
            x, (nw, nt, nc_) = jax.lax.scan(body, x, xs)
        else:
            acc = []
            for i in range(cfg.n_layers):
                x, out = body(x, jax.tree_util.tree_map(lambda a: a[i], xs))
                acc.append(out)
            nw = jnp.stack([a[0] for a in acc])
            nt = jnp.stack([a[1] for a in acc])
            nc_ = jnp.stack([a[2] for a in acc])
        new_cache = {"wkv": nw, "shift_t": nt, "shift_c": nc_, "len": cache["len"] + tokens.shape[1]}

    x = L.norm_apply(params["ln_f"], x, cfg)
    if last_only:
        x = x[:, -1:, :]
    if return_hidden:
        return x, new_cache
    logits = jnp.einsum("bsd,dv->bsv", x, params["lm_head"].astype(dt))
    logits = L.mask_padded_logits(logits, cfg.vocab)
    return L.shard(logits, "batch", None, "act_vocab"), new_cache
