"""Compile-cache guard: the hot loop must not re-trace.

PRs 4/5 bought the closed loop its speed by making every per-segment program
a jit-cache hit -- ``run_trace`` keyed on static (objective, scorer,
n_steps, telemetry), identity-stable scorers (``make_scorer`` lru-cached),
module-level jitted estimator/ring/detector programs. Any of those can
silently regress: a scorer closure rebuilt per segment, a hyperparameter
coerced to a fresh float becoming a new static key, a shape wobble in the
ring. Each regression costs a full XLA compile *per segment* instead of
per topology -- the difference between a control plane and a compiler loop.

:class:`CompileCacheGuard` snapshots ``_cache_size()`` of every tracked
jitted entry point around a block of work and reports the per-function
trace deltas. :func:`run_retrace_audit` uses it twice on a small 3-segment
``AdaptiveEngine`` (stream mode, 2 servers, equal segment shapes):

  warm run    at most one new trace per tracked function -- the segments
              share one shape, so a delta of 2+ means something in the
              cache key churns per segment;
  rerun       zero new traces anywhere, on the same engine instance --
              the acceptance criterion (0-recompile on a 3-segment rerun).
"""
from __future__ import annotations

from typing import Callable, Mapping

import numpy as np

from . import Finding


def tracked_functions() -> "dict[str, Callable]":
    """The jitted programs of the per-segment hot loop, by audit name.

    Everything here runs at least once per segment in stream mode; a cache
    miss on any of them is a per-segment compile. (Reads the live function
    objects at call time so reloads/tests see current state.)
    """
    from ..core import closed_loop, engine_jax
    from ..fleet import detect
    from ..telemetry import estimator, log

    return {
        "core.engine_jax.run_trace": engine_jax.run_trace,
        "core.closed_loop.run_closed_loop": closed_loop.run_closed_loop,
        "telemetry.estimator._update_device": estimator._update_device,
        "telemetry.estimator._update_bank": estimator._update_bank,
        "telemetry.estimator._scatter_jnp_jit": estimator._scatter_jnp_jit,
        "telemetry.estimator._remap_rows": estimator._remap_rows,
        "telemetry.log._rows_from_trace_jit": log._rows_from_trace_jit,
        "telemetry.log._ring_write_trace": log._ring_write_trace,
        "telemetry.log._ring_write": log._ring_write,
        "telemetry.log._ring_write_contig": log._ring_write_contig,
        "fleet.detect._cusum_update": detect._cusum_update,
    }


class CompileCacheGuard:
    """Context manager over jit compile-cache size deltas.

    >>> with CompileCacheGuard() as guard:
    ...     engine.run(arrivals, segments=3)
    >>> guard.deltas  # {'core.engine_jax.run_trace': 1, ...} new traces
    >>> guard.assert_max(0)  # raises on any recompile

    Tracks :func:`tracked_functions` by default; pass ``functions`` (name ->
    jitted callable exposing ``_cache_size``) to guard something else, e.g.
    a single function in a unit test.
    """

    def __init__(self, functions: "Mapping[str, Callable] | None" = None):
        self._functions = dict(functions) if functions is not None else tracked_functions()
        self._before: dict[str, int] = {}
        self.deltas: dict[str, int] = {}

    @staticmethod
    def _size(fn) -> int:
        return int(fn._cache_size())

    def __enter__(self) -> "CompileCacheGuard":
        self._before = {name: self._size(f) for name, f in self._functions.items()}
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        self.deltas = {
            name: self._size(f) - self._before[name]
            for name, f in self._functions.items()}

    def new_traces(self) -> dict[str, int]:
        """Only the functions that actually re-traced (delta > 0)."""
        return {k: v for k, v in self.deltas.items() if v > 0}

    def assert_max(self, limit: int, context: str = "") -> None:
        bad = {k: v for k, v in self.deltas.items() if v > limit}
        if bad:
            where = f" during {context}" if context else ""
            raise AssertionError(
                f"compile-cache guard{where}: traces exceeded limit {limit}: "
                + ", ".join(f"{k} (+{v})" for k, v in sorted(bad.items())))


def _small_adaptive_engine():
    """A 2-server streaming AdaptiveEngine at audit scale (compiles in
    seconds on CPU; every per-segment program still runs)."""
    from ..core.engine import AdaptiveEngine
    from ..core.server import M1, M2

    return AdaptiveEngine([M1, M2], prior=0.0, scorer="jnp", stream=True,
                          ring_capacity=64)


def _audit_arrivals(n: int = 24):
    """n arrivals over a few grid types, divisible into equal segments (all
    segments then share one (m, n) trace shape -- one compile covers all)."""
    from ..core.workload import FS_GRID, RS_GRID, Workload, snap_to_grid

    arrivals = []
    for i in range(n):
        w = snap_to_grid(Workload(
            fs=FS_GRID[(7 * i) % len(FS_GRID)], rs=RS_GRID[i % len(RS_GRID)],
            data_total=64e6))
        arrivals.append((0.25 * i, w))
    return arrivals


def run_retrace_audit(stats: "dict | None" = None,
                      segments: int = 3) -> list[Finding]:
    """Warm-run + rerun the reference adaptive loop under the guard."""
    arrivals = _audit_arrivals(n=8 * segments)
    engine = _small_adaptive_engine()

    with CompileCacheGuard() as warm:
        engine.run(arrivals, segments=segments)
    with CompileCacheGuard() as rerun:
        engine.run(arrivals, segments=segments)

    # device-resident loop: one compile must cover every segment count in
    # an S_cap bucket. The warm run compiles at segments=4; reruns at 2 and
    # 3 segments (same power-of-two bucket, same per-segment shape) must add
    # zero traces anywhere -- a delta means the padded-scan shapes or the
    # static config churn with the segment count.
    n_seg = 8
    dev_engine = _small_adaptive_engine()
    dev_arrivals = _audit_arrivals(n=n_seg * 4)
    with CompileCacheGuard() as dev_warm:
        dev_engine.run(dev_arrivals, segments=4, device_loop=True)
    with CompileCacheGuard() as dev_rerun:
        dev_engine.run(dev_arrivals[:n_seg * 2], segments=2, device_loop=True)
        dev_engine.run(dev_arrivals[:n_seg * 3], segments=3, device_loop=True)

    # metrics plane: ``metrics=True`` is one more static key on run_trace /
    # one more field in the ClosedLoopConfig hash, so the first metrics run
    # legitimately traces once per function -- after that the instrumented
    # program must be exactly as cache-stable as the bare one. A metrics
    # rerun (host alternating segments, then device loops at 2/3 segments
    # inside the warm 4-segment bucket) must add ZERO traces.
    obs_engine = _small_adaptive_engine()
    with CompileCacheGuard() as obs_warm:
        obs_engine.run(arrivals, segments=segments, metrics=True)
    with CompileCacheGuard() as obs_rerun:
        obs_engine.run(arrivals, segments=segments, metrics=True)
    obs_dev = _small_adaptive_engine()
    with CompileCacheGuard() as obs_dev_warm:
        obs_dev.run(dev_arrivals, segments=4, device_loop=True, metrics=True)
    with CompileCacheGuard() as obs_dev_rerun:
        obs_dev.run(dev_arrivals[:n_seg * 2], segments=2, device_loop=True,
                    metrics=True)
        obs_dev.run(dev_arrivals[:n_seg * 3], segments=3, device_loop=True,
                    metrics=True)

    # decision recorder: ``record=True`` is one more static key on run_trace
    # / one more field in the ClosedLoopConfig hash, same contract as the
    # metrics plane -- the first recorder run traces once, after which
    # recorder-on reruns (host alternating, then device loops at 2/3
    # segments inside the warm 4-segment bucket) must add ZERO traces. The
    # ring riding the carry moves values, never shapes.
    rec_engine = _small_adaptive_engine()
    with CompileCacheGuard() as rec_warm:
        rec_engine.run(arrivals, segments=segments, record=True)
    with CompileCacheGuard() as rec_rerun:
        rec_engine.run(arrivals, segments=segments, record=True)
    rec_dev = _small_adaptive_engine()
    with CompileCacheGuard() as rec_dev_warm:
        rec_dev.run(dev_arrivals, segments=4, device_loop=True, record=True)
    with CompileCacheGuard() as rec_dev_rerun:
        rec_dev.run(dev_arrivals[:n_seg * 2], segments=2, device_loop=True,
                    record=True)
        rec_dev.run(dev_arrivals[:n_seg * 3], segments=3, device_loop=True,
                    record=True)

    # sharded loop: a ServerAxis over a 1-device mesh runs the whole scan
    # under shard_map -- same static config hash rules as dense (the axis is
    # a frozen dataclass, hashable by mesh value). The warm run pays one
    # trace; an identical rerun must add ZERO -- a delta means the axis (or
    # something it carries) churns the jit key per call, i.e. every segment
    # of a 10k-server run would recompile.
    from .jaxpr_audit import _build_closed_loop_sharded
    import jax

    sh_fn, sh_args = _build_closed_loop_sharded()
    with CompileCacheGuard() as sh_warm:
        jax.block_until_ready(sh_fn(*sh_args))
    with CompileCacheGuard() as sh_rerun:
        jax.block_until_ready(sh_fn(*sh_args))

    findings = [
        Finding("retrace", "per-segment-retrace", name,
                f"{delta} traces in a warm {segments}-segment run of one "
                "shape (expected at most 1: the cache key churns per segment)")
        for name, delta in sorted(warm.new_traces().items()) if delta > 1
    ] + [
        Finding("retrace", "rerun-recompile", name,
                f"{delta} new traces on an identical rerun (expected 0: "
                "the warm run should have populated every cache)")
        for name, delta in sorted(rerun.new_traces().items())
    ] + [
        Finding("retrace", "device-loop-recompile", name,
                f"{delta} new traces running 2- and 3-segment device loops "
                "after a warm 4-segment run (expected 0: segment counts in "
                "one S_cap bucket share a compilation)")
        for name, delta in sorted(dev_rerun.new_traces().items())
    ] + [
        Finding("retrace", "metrics-retrace", name,
                f"{delta} traces in a warm metrics-on {segments}-segment run "
                "(expected at most 1: the MetricFrame ops churn the cache "
                "key per segment)")
        for name, delta in sorted(obs_warm.new_traces().items()) if delta > 1
    ] + [
        Finding("retrace", "metrics-rerun-recompile", name,
                f"{delta} new traces on an identical metrics-on rerun "
                "(expected 0: instrumentation must not erode cache stability)")
        for name, delta in sorted(obs_rerun.new_traces().items())
    ] + [
        Finding("retrace", "metrics-device-loop-recompile", name,
                f"{delta} new traces running metrics-on 2- and 3-segment "
                "device loops after a warm metrics-on 4-segment run "
                "(expected 0)")
        for name, delta in sorted(obs_dev_rerun.new_traces().items())
    ] + [
        Finding("retrace", "recorder-retrace", name,
                f"{delta} traces in a warm recorder-on {segments}-segment "
                "run (expected at most 1: the decision-ring ops churn the "
                "cache key per segment)")
        for name, delta in sorted(rec_warm.new_traces().items()) if delta > 1
    ] + [
        Finding("retrace", "recorder-rerun-recompile", name,
                f"{delta} new traces on an identical recorder-on rerun "
                "(expected 0: the flight recorder must not erode cache "
                "stability)")
        for name, delta in sorted(rec_rerun.new_traces().items())
    ] + [
        Finding("retrace", "recorder-device-loop-recompile", name,
                f"{delta} new traces running recorder-on 2- and 3-segment "
                "device loops after a warm recorder-on 4-segment run "
                "(expected 0)")
        for name, delta in sorted(rec_dev_rerun.new_traces().items())
    ] + [
        Finding("retrace", "sharded-loop-recompile", name,
                f"{delta} new traces rerunning the warm sharded closed loop "
                "(expected 0: the ServerAxis static key must be call-stable)")
        for name, delta in sorted(sh_rerun.new_traces().items())
    ]
    if stats is not None:
        stats["retrace"] = {
            "segments": segments,
            "warm_traces": warm.new_traces(),
            "rerun_traces": rerun.new_traces(),
            "rerun_total": int(np.sum(list(rerun.deltas.values()) or [0])),
            "device_warm_traces": dev_warm.new_traces(),
            "device_rerun_traces": dev_rerun.new_traces(),
            "metrics_warm_traces": obs_warm.new_traces(),
            "metrics_rerun_traces": obs_rerun.new_traces(),
            "metrics_rerun_total": int(
                np.sum(list(obs_rerun.deltas.values()) or [0])),
            "metrics_device_warm_traces": obs_dev_warm.new_traces(),
            "metrics_device_rerun_traces": obs_dev_rerun.new_traces(),
            "recorder_warm_traces": rec_warm.new_traces(),
            "recorder_rerun_traces": rec_rerun.new_traces(),
            "recorder_rerun_total": int(
                np.sum(list(rec_rerun.deltas.values()) or [0])),
            "recorder_device_warm_traces": rec_dev_warm.new_traces(),
            "recorder_device_rerun_traces": rec_dev_rerun.new_traces(),
            "sharded_warm_traces": sh_warm.new_traces(),
            "sharded_rerun_traces": sh_rerun.new_traces(),
            "sharded_rerun_total": int(
                np.sum(list(sh_rerun.deltas.values()) or [0])),
        }
    return findings
